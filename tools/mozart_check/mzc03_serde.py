"""MZC03x — serialization-schema drift in `to_dict`/`from_dict` pairs.

MZC031  a dataclass defines one half of the pair without the other being
        reachable (own body or a base class in the same module) — the
        artifact either can't round-trip or silently loses the type.
MZC032  `from_dict` doesn't cover every field: each field name must
        appear as a handled key (string literal or constructor keyword)
        unless the body splats `**d` into a constructor.
"""

from __future__ import annotations

import ast

from .astutil import dotted, is_dataclass
from .driver import Finding, ParsedFile


def _own_fields(cls: ast.ClassDef) -> list[str]:
    fields = []
    for node in cls.body:
        if isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name):
            name = node.target.id
            ann = dotted(node.annotation) or ""
            if isinstance(node.annotation, ast.Subscript):
                ann = dotted(node.annotation.value) or ""
            if name.startswith("_") or ann.split(".")[-1] == "ClassVar":
                continue
            fields.append(name)
    return fields


def _method(cls: ast.ClassDef, name: str):
    for node in cls.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and node.name == name:
            return node
    return None


def _base_chain(cls: ast.ClassDef, classes: dict[str, ast.ClassDef]) -> list[ast.ClassDef]:
    chain, todo, seen = [], [cls], set()
    while todo:
        c = todo.pop()
        if c.name in seen:
            continue
        seen.add(c.name)
        chain.append(c)
        for b in c.bases:
            bn = dotted(b)
            if bn in classes:
                todo.append(classes[bn])
    return chain


def _handled_keys(fn: ast.FunctionDef) -> tuple[set[str], bool]:
    """(string-literal + constructor-keyword names in the body, saw **splat)."""
    keys: set[str] = set()
    splat = False
    for node in ast.walk(fn):
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            keys.add(node.value)
        elif isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg is None:
                    splat = True
                else:
                    keys.add(kw.arg)
    return keys, splat


def check(files: list[ParsedFile], root: str) -> list[Finding]:
    findings: list[Finding] = []
    for file in files:
        classes = {n.name: n for n in file.tree.body if isinstance(n, ast.ClassDef)}
        for cls in classes.values():
            if not is_dataclass(cls):
                continue
            chain = _base_chain(cls, classes)
            has_to = _method(cls, "to_dict") is not None
            from_fn = _method(cls, "from_dict")
            to_reachable = any(_method(c, "to_dict") for c in chain)
            from_reachable = any(_method(c, "from_dict") for c in chain)
            if has_to and not from_reachable:
                findings.append(
                    Finding(
                        file.path,
                        cls.lineno,
                        "MZC031",
                        f"dataclass {cls.name} defines to_dict but no from_dict is "
                        f"reachable — the artifact cannot round-trip",
                    )
                )
            if from_fn is not None and not to_reachable:
                findings.append(
                    Finding(
                        file.path,
                        cls.lineno,
                        "MZC031",
                        f"dataclass {cls.name} defines from_dict but no to_dict is "
                        f"reachable — nothing can produce its serialized form",
                    )
                )
            if from_fn is not None:
                fields = []
                for c in chain:
                    for f in _own_fields(c):
                        if f not in fields:
                            fields.append(f)
                keys, splat = _handled_keys(from_fn)
                missing = [f for f in fields if f not in keys]
                if missing and not splat:
                    findings.append(
                        Finding(
                            file.path,
                            from_fn.lineno,
                            "MZC032",
                            f"{cls.name}.from_dict never handles field(s) "
                            f"{', '.join(missing)} — round-trip drops them",
                        )
                    )
    return findings
