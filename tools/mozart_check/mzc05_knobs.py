"""MZC05x — the `MOZART_*` env-knob registry.

MZC051  an `os.environ[...]` / `os.environ.get` / `os.getenv` read of a
        `MOZART_*` name that is not declared in the central registry
        (`src/repro/launch/knobs.py`).
MZC052  the README knob table and the registry disagree (or the table is
        missing): the docs are generated from the registry and must
        track it exactly.
MZC053  a registry `Knob(...)` entry is malformed — name/type/default/doc
        must all be present, literal, and non-empty.
"""

from __future__ import annotations

import ast
import os
import re

from .astutil import dotted, str_const
from .driver import Finding, ParsedFile

_REGISTRY_SUFFIX = os.path.join("launch", "knobs.py")
_README_ROW_RE = re.compile(r"^\|\s*`(MOZART_[A-Z0-9_]+)`")
_KNOB_KEYS = ("name", "type", "default", "doc")


def _registry_file(files: list[ParsedFile], root: str) -> ParsedFile | None:
    for f in files:
        if f.path.endswith(_REGISTRY_SUFFIX):
            return f
    path = os.path.join(root, "src", "repro", "launch", "knobs.py")
    try:
        with open(path, encoding="utf-8") as fh:
            src = fh.read()
        return ParsedFile(path=path, source=src, lines=src.splitlines(), tree=ast.parse(src))
    except (OSError, SyntaxError):
        return None


def _registry_entries(reg: ParsedFile, findings: list[Finding]) -> set[str]:
    names: set[str] = set()
    for node in ast.walk(reg.tree):
        if not (isinstance(node, ast.Call) and (dotted(node.func) or "").endswith("Knob")):
            continue
        kwargs = {kw.arg: kw.value for kw in node.keywords if kw.arg}
        bad = []
        for k in _KNOB_KEYS:
            value = str_const(kwargs[k]) if k in kwargs else None
            if value is None or (k != "default" and not value.strip()):
                bad.append(k)
        if bad:
            findings.append(
                Finding(
                    reg.path,
                    node.lineno,
                    "MZC053",
                    f"Knob entry needs literal, non-empty {'/'.join(_KNOB_KEYS)} "
                    f"(problem with: {', '.join(sorted(set(bad)))})",
                )
            )
            continue
        names.add(str_const(kwargs["name"]))
    return names


def _env_reads(file: ParsedFile):
    """(line, knob-name) for every literal MOZART_* env read."""
    for node in ast.walk(file.tree):
        name = None
        if isinstance(node, ast.Call):
            d = dotted(node.func)
            if d == "os.getenv" and node.args:
                name = str_const(node.args[0])
            elif d == "os.environ.get" and node.args:
                name = str_const(node.args[0])
        elif isinstance(node, ast.Subscript) and dotted(node.value) == "os.environ":
            name = str_const(node.slice)
        if name is not None and name.startswith("MOZART_"):
            yield node.lineno, name


def _check_readme(root: str, registry: set[str], reg_path: str, findings: list[Finding]) -> None:
    readme = os.path.join(root, "README.md")
    try:
        with open(readme, encoding="utf-8") as f:
            lines = f.read().splitlines()
    except OSError:
        return
    documented: dict[str, int] = {}
    for i, line in enumerate(lines, start=1):
        m = _README_ROW_RE.match(line)
        if m:
            documented.setdefault(m.group(1), i)
    doc_names = set(documented)
    missing = sorted(registry - doc_names)
    stale = sorted(doc_names - registry)
    if missing:
        findings.append(
            Finding(
                readme,
                min(documented.values()) if documented else 1,
                "MZC052",
                f"README knob table is missing registry knob(s) {', '.join(missing)} — "
                f"regenerate it from {reg_path}",
            )
        )
    for name in stale:
        findings.append(
            Finding(
                readme,
                documented[name],
                "MZC052",
                f"README documents `{name}` which is not in the registry ({reg_path})",
            )
        )


def check(files: list[ParsedFile], root: str) -> list[Finding]:
    findings: list[Finding] = []
    reg = _registry_file(files, root)
    registry: set[str] = set()
    if reg is None:
        reg_path = os.path.join("src", "repro", _REGISTRY_SUFFIX)
    else:
        reg_path = reg.path
        registry = _registry_entries(reg, findings)
    for file in files:
        if file.path.endswith(_REGISTRY_SUFFIX):
            continue
        for line, name in _env_reads(file):
            if name not in registry:
                findings.append(
                    Finding(
                        file.path,
                        line,
                        "MZC051",
                        f"env knob `{name}` read outside the central registry — declare "
                        f"it in {reg_path} and read it through repro.launch.knobs",
                    )
                )
    if reg is not None:
        _check_readme(root, registry, reg_path, findings)
    return findings
