"""CLI: ``python -m tools.mozart_check [PATHS...]``.

Exits 1 when any finding survives suppression.  ``--knob-table`` prints
the README markdown table generated from the knob registry instead of
checking anything (paste its output into README.md when knobs change).
"""

from __future__ import annotations

import argparse
import os
import sys

from . import ALL_CHECKERS, run_checkers

DEFAULT_PATHS = ("src", "benchmarks", "examples")


def knob_table() -> str:
    sys.path.insert(0, "src")
    from repro.launch import knobs

    rows = [
        "| knob | type | default | effect |",
        "| --- | --- | --- | --- |",
    ]
    for k in knobs.KNOBS:
        rows.append(f"| `{k.name}` | {k.type} | `{k.default}` | {k.doc} |")
    return "\n".join(rows)


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(prog="mozart_check")
    p.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS))
    p.add_argument(
        "--knob-table",
        action="store_true",
        help="print the README MOZART_* table generated from launch/knobs.py",
    )
    args = p.parse_args(argv)
    if args.knob_table:
        print(knob_table())
        return 0
    findings = run_checkers(args.paths, ALL_CHECKERS, root=os.getcwd())
    for f in findings:
        print(f.render())
    if findings:
        print(f"mozart-check: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    print(f"mozart-check: clean over {' '.join(args.paths)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
