"""Runtime counterpart of MZC01x: count fresh XLA compilations.

`CompileMonitor` hooks JAX's internal monitoring bus and counts
``/jax/core/compile/backend_compile_duration`` events — one per fresh
executable build, zero on trace-cache hits — so tests and benchmarks can
assert that steady-state serving compiles nothing new:

    with CompileMonitor() as mon:
        engine.run()
    assert mon.count == 0

Only jax internals are touched at ``__enter__`` time, so importing this
module is safe in environments without jax.
"""

from __future__ import annotations

import contextlib


class CompileMonitor:
    """Counts backend (XLA) compilations between __enter__ and __exit__."""

    def __init__(self) -> None:
        self.count = 0
        self.events: list[str] = []
        self._active = False

    def _on_event(self, event: str, duration: float, **kwargs) -> None:
        if self._active and event.endswith("backend_compile_duration"):
            self.count += 1
            self.events.append(event)

    def __enter__(self) -> "CompileMonitor":
        from jax._src import monitoring

        self._monitoring = monitoring
        self._active = True
        monitoring.register_event_duration_secs_listener(self._on_event)
        return self

    def __exit__(self, *exc) -> bool:
        self._active = False
        unregister = getattr(
            self._monitoring, "_unregister_event_duration_listener_by_callback", None
        )
        if unregister is not None:
            unregister(self._on_event)
        # without the private unregister hook the listener stays on the
        # bus but self._active keeps it inert
        return False


@contextlib.contextmanager
def count_compiles():
    """``with count_compiles() as mon: ...`` convenience wrapper."""
    with CompileMonitor() as mon:
        yield mon
