"""Checker driver: walk files, parse, run checkers, apply suppressions.

Findings render ruff-style (``path:line: MZC0xx message``) and are
suppressed per line with ``# mzc: ignore[MZC0xx]`` (comma-separated
codes; a family prefix like ``MZC01`` suppresses every ``MZC01x`` code;
a bare ``# mzc: ignore`` suppresses everything on that line).
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re

_SUPPRESS_RE = re.compile(r"#\s*mzc:\s*ignore(?:\[([A-Za-z0-9_,\s]+)\])?")
_SKIP_DIRS = {"__pycache__", ".git", ".venv", "node_modules"}


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    code: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"


@dataclasses.dataclass
class ParsedFile:
    path: str
    source: str
    lines: list[str]
    tree: ast.Module


def iter_py_files(paths):
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
        elif os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(d for d in dirnames if d not in _SKIP_DIRS)
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        yield os.path.join(dirpath, fn)


def parse_paths(paths) -> tuple[list[ParsedFile], list[Finding]]:
    """Parse every .py under `paths`; syntax errors become MZC000 findings."""
    files: list[ParsedFile] = []
    findings: list[Finding] = []
    for path in iter_py_files(paths):
        with open(path, encoding="utf-8") as f:
            src = f.read()
        try:
            tree = ast.parse(src, filename=path)
        except SyntaxError as e:
            findings.append(Finding(path, e.lineno or 1, "MZC000", f"syntax error: {e.msg}"))
            continue
        files.append(ParsedFile(path=path, source=src, lines=src.splitlines(), tree=tree))
    return files, findings


def suppressed_codes(file: ParsedFile, line: int) -> set[str] | None:
    """Codes suppressed on `line` of `file`; None means ALL codes."""
    if not 1 <= line <= len(file.lines):
        return set()
    m = _SUPPRESS_RE.search(file.lines[line - 1])
    if not m:
        return set()
    if m.group(1) is None:
        return None
    return {c.strip().upper() for c in m.group(1).split(",") if c.strip()}


def is_suppressed(file: ParsedFile, finding: Finding) -> bool:
    codes = suppressed_codes(file, finding.line)
    if codes is None:
        return True
    return any(finding.code.startswith(c) for c in codes)


def run_checkers(paths, checkers, root: str | None = None) -> list[Finding]:
    """Run every checker over the .py files under `paths`, drop suppressed
    findings, and return the rest sorted by (path, line, code)."""
    root = root or os.getcwd()
    files, findings = parse_paths(paths)
    by_path = {f.path: f for f in files}
    for checker in checkers:
        findings.extend(checker(files, root))
    kept = []
    for f in findings:
        pf = by_path.get(f.path)
        if pf is not None and is_suppressed(pf, f):
            continue
        kept.append(f)
    return sorted(kept, key=lambda f: (f.path, f.line, f.code))
