"""MZC01x — trace/recompile hazards around `jax.jit`.

MZC011  Python `if`/`while` whose condition reads a jit-traced parameter
        (concretization error at best, silent per-value recompile at
        worst); `x is None` / `x is not None` optional-argument guards
        are exempt — None is pytree structure, not a traced value.
MZC012  host conversion (`int()`/`float()`/`bool()` of a traced
        parameter, or any `.item()`) inside a jit-compiled function.
MZC013  `jax.jit(...)` constructed inside a plain function: every call
        builds a fresh jitted callable with an empty trace cache.  Hoist
        to module scope or an `functools.lru_cache`'d builder.
"""

from __future__ import annotations

import ast

from .astutil import decorator_names, dotted
from .driver import Finding, ParsedFile

_JIT_NAMES = {"jax.jit", "jit"}
_PARTIAL_NAMES = {"functools.partial", "partial"}
_CACHING_DECOS = {"functools.lru_cache", "lru_cache", "functools.cache", "cache"}
_HOST_CASTS = {"int", "float", "bool"}


def _static_from_call(call: ast.Call) -> tuple[set[str], set[int]]:
    names: set[str] = set()
    nums: set[int] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for s in ast.walk(kw.value):
                if isinstance(s, ast.Constant) and isinstance(s.value, str):
                    names.add(s.value)
        elif kw.arg == "static_argnums":
            for s in ast.walk(kw.value):
                if isinstance(s, ast.Constant) and isinstance(s.value, int):
                    nums.add(s.value)
    return names, nums


def _jit_decorator(deco: ast.AST) -> tuple[bool, set[str], set[int]]:
    """(is_jit, static_argnames, static_argnums) for one decorator node."""
    if dotted(deco) in _JIT_NAMES:
        return True, set(), set()
    if isinstance(deco, ast.Call):
        f = dotted(deco.func)
        if f in _JIT_NAMES:
            return True, *_static_from_call(deco)
        if f in _PARTIAL_NAMES and deco.args and dotted(deco.args[0]) in _JIT_NAMES:
            return True, *_static_from_call(deco)
    return False, set(), set()


def _traced_params(fn, static_names: set[str], static_nums: set[int]) -> set[str]:
    positional = [a.arg for a in (*fn.args.posonlyargs, *fn.args.args)]
    traced = {
        name
        for i, name in enumerate(positional)
        if name not in static_names and i not in static_nums
    }
    traced.update(a.arg for a in fn.args.kwonlyargs if a.arg not in static_names)
    return traced


def _none_guarded(test: ast.AST) -> set[str]:
    """Names that only appear as `name is [not] None` in this test."""
    guarded: set[str] = set()
    for node in ast.walk(test):
        if (
            isinstance(node, ast.Compare)
            and len(node.ops) == 1
            and isinstance(node.ops[0], (ast.Is, ast.IsNot))
            and isinstance(node.comparators[0], ast.Constant)
            and node.comparators[0].value is None
            and isinstance(node.left, ast.Name)
        ):
            guarded.add(node.left.id)
    return guarded


def _check_jitted_body(path: str, fn, traced: set[str], findings: list[Finding]) -> None:
    # names rebound by nested defs/lambdas/comprehensions shadow params;
    # a simple over-approximation: drop any traced name that is ever a
    # nested-callable parameter or comprehension target.
    shadowed: set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)) and node is not fn:
            shadowed.update(a.arg for a in (*node.args.posonlyargs, *node.args.args))
            shadowed.update(a.arg for a in node.args.kwonlyargs)
        elif isinstance(node, ast.comprehension):
            shadowed.update(n.id for n in ast.walk(node.target) if isinstance(n, ast.Name))
    live = traced - shadowed
    for node in ast.walk(fn):
        if isinstance(node, (ast.If, ast.While)):
            guarded = _none_guarded(node.test)
            hazards = sorted(
                {
                    n.id
                    for n in ast.walk(node.test)
                    if isinstance(n, ast.Name) and n.id in live and n.id not in guarded
                }
            )
            if hazards:
                kw = "if" if isinstance(node, ast.If) else "while"
                findings.append(
                    Finding(
                        path,
                        node.lineno,
                        "MZC011",
                        f"Python `{kw}` on jit-traced parameter(s) {', '.join(hazards)} — "
                        f"use jax.lax.cond/jnp.where or mark the argument static",
                    )
                )
        elif isinstance(node, ast.Call):
            f = node.func
            if (
                isinstance(f, ast.Name)
                and f.id in _HOST_CASTS
                and len(node.args) == 1
                and isinstance(node.args[0], ast.Name)
                and node.args[0].id in live
            ):
                findings.append(
                    Finding(
                        path,
                        node.lineno,
                        "MZC012",
                        f"`{f.id}({node.args[0].id})` concretizes a jit-traced parameter "
                        f"inside the compiled function",
                    )
                )
            elif isinstance(f, ast.Attribute) and f.attr == "item" and not node.args:
                findings.append(
                    Finding(
                        path,
                        node.lineno,
                        "MZC012",
                        "`.item()` inside a jit-compiled function forces a host sync "
                        "(tracer error under jit)",
                    )
                )


def _check_jit_call_sites(file: ParsedFile, findings: list[Finding]) -> None:
    def visit(node: ast.AST, fn_stack: list) -> None:
        if isinstance(node, ast.Call) and dotted(node.func) in _JIT_NAMES and fn_stack:
            cached = any(
                any(d in _CACHING_DECOS for d in decorator_names(fn)) for fn in fn_stack
            )
            if not cached:
                findings.append(
                    Finding(
                        file.path,
                        node.lineno,
                        "MZC013",
                        "jax.jit(...) constructed inside a function — every call re-traces "
                        "from an empty cache; hoist to module scope or an lru_cache'd builder",
                    )
                )
        push = isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
        if push:
            fn_stack = fn_stack + [node]
        for child in ast.iter_child_nodes(node):
            visit(child, fn_stack)

    visit(file.tree, [])


def check(files: list[ParsedFile], root: str) -> list[Finding]:
    findings: list[Finding] = []
    for file in files:
        for node in ast.walk(file.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for deco in node.decorator_list:
                is_jit, static_names, static_nums = _jit_decorator(deco)
                if is_jit:
                    traced = _traced_params(node, static_names, static_nums)
                    _check_jitted_body(file.path, node, traced, findings)
                    break
        _check_jit_call_sites(file, findings)
    return findings
