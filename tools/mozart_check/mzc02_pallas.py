"""MZC02x — Pallas kernel contracts for `kernels/*/kernel.py`.

MZC021  BlockSpec `index_map` arity != grid rank (counting only
        non-default lambda parameters — `g=group` capture idiom is fine).
MZC022  `index_map` returns a tuple whose length != the block-shape rank.
MZC023  VMEM scratch (accumulator) dtype is not float32 — partial
        products must accumulate in f32 regardless of the I/O dtype.
MZC024  a `kernels/<name>/` triplet is incomplete or its public surfaces
        disagree: each of kernel.py/ops.py/ref.py must exist, kernel.py
        must export a kernel entry point, and every public `f` in ops.py
        needs an `f_ref` reference implementation in ref.py.
"""

from __future__ import annotations

import ast
import os

from .astutil import dotted, public_functions
from .driver import Finding, ParsedFile

_TRIPLET = ("kernel.py", "ops.py", "ref.py")
# dtype leaves that are definitely not f32 accumulators; bare variable
# names (e.g. a `dtype` parameter) are unresolvable and never flagged
_NON_F32_DTYPES = {
    "float16",
    "bfloat16",
    "float64",
    "float8_e4m3fn",
    "float8_e5m2",
    "int8",
    "int16",
    "int32",
    "int64",
    "uint8",
    "uint32",
}


def _tuple_env(tree: ast.AST) -> dict[str, ast.Tuple]:
    """name -> literal-tuple value for simple assignments, to resolve
    `grid=grid` style indirection."""
    env: dict[str, ast.Tuple] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            t = node.targets[0]
            if isinstance(t, ast.Name) and isinstance(node.value, ast.Tuple):
                env[t.id] = node.value
    return env


def _resolve_tuple(node: ast.AST | None, env: dict[str, ast.Tuple]) -> ast.Tuple | None:
    if isinstance(node, ast.Tuple):
        return node
    if isinstance(node, ast.Name):
        return env.get(node.id)
    return None


def _block_specs(call: ast.Call):
    """Every BlockSpec(...) Call inside a pallas_call expression."""
    for node in ast.walk(call):
        if isinstance(node, ast.Call):
            d = dotted(node.func)
            if d is not None and d.split(".")[-1] == "BlockSpec":
                yield node


def _check_kernel_file(file: ParsedFile, findings: list[Finding]) -> None:
    env = _tuple_env(file.tree)
    for node in ast.walk(file.tree):
        if not isinstance(node, ast.Call):
            continue
        d = dotted(node.func)
        name = None if d is None else d.split(".")[-1]
        if name == "pallas_call":
            grid = next((kw.value for kw in node.keywords if kw.arg == "grid"), None)
            grid_tuple = _resolve_tuple(grid, env)
            rank = None if grid_tuple is None else len(grid_tuple.elts)
            for spec in _block_specs(node):
                shape = spec.args[0] if spec.args else None
                index_map = spec.args[1] if len(spec.args) > 1 else None
                for kw in spec.keywords:
                    if kw.arg == "block_shape":
                        shape = kw.value
                    elif kw.arg == "index_map":
                        index_map = kw.value
                shape_tuple = _resolve_tuple(shape, env)
                if not isinstance(index_map, ast.Lambda):
                    continue
                arity = len(index_map.args.args) - len(index_map.args.defaults)
                if rank is not None and arity != rank:
                    findings.append(
                        Finding(
                            file.path,
                            index_map.lineno,
                            "MZC021",
                            f"BlockSpec index_map takes {arity} grid indices but the "
                            f"pallas_call grid has rank {rank}",
                        )
                    )
                if shape_tuple is not None and isinstance(index_map.body, ast.Tuple):
                    got = len(index_map.body.elts)
                    want = len(shape_tuple.elts)
                    if got != want:
                        findings.append(
                            Finding(
                                file.path,
                                index_map.lineno,
                                "MZC022",
                                f"index_map returns {got} block coordinates for a "
                                f"rank-{want} block shape",
                            )
                        )
        elif name in ("VMEM", "_vmem"):
            dtype = node.args[1] if len(node.args) > 1 else None
            for kw in node.keywords:
                if kw.arg == "dtype":
                    dtype = kw.value
            if dtype is None:
                continue
            dname = dotted(dtype)
            leaf = None if dname is None else dname.split(".")[-1]
            if leaf in _NON_F32_DTYPES:
                findings.append(
                    Finding(
                        file.path,
                        node.lineno,
                        "MZC023",
                        f"VMEM scratch declared as {leaf} — Pallas accumulators must "
                        f"be float32",
                    )
                )


def _parse_for_surface(path: str, by_path: dict[str, ParsedFile]) -> ast.Module | None:
    pf = by_path.get(path)
    if pf is not None:
        return pf.tree
    try:
        with open(path, encoding="utf-8") as f:
            return ast.parse(f.read(), filename=path)
    except (OSError, SyntaxError):
        return None


def _public_surface(tree: ast.Module) -> dict[str, int]:
    """Public defs plus `alias = existing_name` re-exports."""
    names = public_functions(tree)
    for node in tree.body:
        if (
            isinstance(node, ast.Assign)
            and len(node.targets) == 1
            and isinstance(node.targets[0], ast.Name)
            and not node.targets[0].id.startswith("_")
            and isinstance(node.value, ast.Name)
        ):
            names.setdefault(node.targets[0].id, node.lineno)
    return names


def _check_triplets(files: list[ParsedFile], findings: list[Finding]) -> None:
    by_path = {f.path: f for f in files}
    dirs = sorted(
        {
            os.path.dirname(f.path)
            for f in files
            if os.path.basename(f.path) in _TRIPLET
            and os.path.basename(os.path.dirname(os.path.dirname(f.path))) == "kernels"
        }
    )
    for d in dirs:
        anchor = next(
            (os.path.join(d, m) for m in _TRIPLET if os.path.exists(os.path.join(d, m))),
            os.path.join(d, "kernel.py"),
        )
        missing = [m for m in _TRIPLET if not os.path.exists(os.path.join(d, m))]
        if missing:
            findings.append(
                Finding(
                    anchor,
                    1,
                    "MZC024",
                    f"kernel triplet {d} is missing {', '.join(missing)}",
                )
            )
            continue
        ops_tree = _parse_for_surface(os.path.join(d, "ops.py"), by_path)
        ref_tree = _parse_for_surface(os.path.join(d, "ref.py"), by_path)
        kern_tree = _parse_for_surface(os.path.join(d, "kernel.py"), by_path)
        if kern_tree is not None and not public_functions(kern_tree):
            findings.append(
                Finding(
                    os.path.join(d, "kernel.py"),
                    1,
                    "MZC024",
                    "kernel.py exports no public kernel entry point",
                )
            )
        if ops_tree is None or ref_tree is None:
            continue
        ref_names = _public_surface(ref_tree)
        for fn, line in sorted(public_functions(ops_tree).items()):
            if f"{fn}_ref" not in ref_names:
                findings.append(
                    Finding(
                        os.path.join(d, "ops.py"),
                        line,
                        "MZC024",
                        f"public op `{fn}` has no `{fn}_ref` reference implementation "
                        f"in {os.path.join(d, 'ref.py')}",
                    )
                )


def check(files: list[ParsedFile], root: str) -> list[Finding]:
    findings: list[Finding] = []
    for file in files:
        parent = os.path.basename(os.path.dirname(os.path.dirname(file.path)))
        if os.path.basename(file.path) == "kernel.py" and parent == "kernels":
            _check_kernel_file(file, findings)
    _check_triplets(files, findings)
    return findings
