"""MZC04x — shared mutable state.

MZC041  mutable default value in a function signature or dataclass
        field (the PR-1 shared-config bug class) — every caller shares
        one object; use None + init or dataclasses.field(default_factory).
MZC042  module-level mutable cache (empty dict/list/set binding) with no
        lock and no documented single-writer note within the three lines
        above it — benign today, a data race after the next refactor.
"""

from __future__ import annotations

import ast
import re

from .astutil import dotted, is_dataclass
from .driver import Finding, ParsedFile

_MUTABLE_CALLS = {
    "list",
    "dict",
    "set",
    "defaultdict",
    "collections.defaultdict",
    "OrderedDict",
    "collections.OrderedDict",
}
_NOTE_RE = re.compile(
    r"single[- ]writer|(?<![a-zA-Z])lock|guarded|not thread-safe", re.IGNORECASE
)


def _is_mutable_default(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        return dotted(node.func) in _MUTABLE_CALLS
    return False


def _is_empty_container(node: ast.AST) -> bool:
    if isinstance(node, ast.Dict):
        return not node.keys
    if isinstance(node, ast.List):
        return not node.elts
    if isinstance(node, ast.Call):
        d = dotted(node.func)
        if d in ("defaultdict", "collections.defaultdict"):
            return True
        return d in ("list", "dict", "set", "OrderedDict", "collections.OrderedDict") and not (
            node.args or node.keywords
        )
    return False


def _has_note(file: ParsedFile, line: int) -> bool:
    lo = max(0, line - 4)
    return any(_NOTE_RE.search(text) for text in file.lines[lo:line])


def check(files: list[ParsedFile], root: str) -> list[Finding]:
    findings: list[Finding] = []
    for file in files:
        for node in ast.walk(file.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                defaults = [*node.args.defaults, *node.args.kw_defaults]
                for default in defaults:
                    if default is not None and _is_mutable_default(default):
                        findings.append(
                            Finding(
                                file.path,
                                default.lineno,
                                "MZC041",
                                "mutable default argument is shared across every call — "
                                "use None and initialize inside the function",
                            )
                        )
            elif isinstance(node, ast.ClassDef) and is_dataclass(node):
                for stmt in node.body:
                    if (
                        isinstance(stmt, ast.AnnAssign)
                        and stmt.value is not None
                        and _is_mutable_default(stmt.value)
                    ):
                        findings.append(
                            Finding(
                                file.path,
                                stmt.lineno,
                                "MZC041",
                                "mutable dataclass field default is shared across "
                                "instances — use dataclasses.field(default_factory=...)",
                            )
                        )
        for stmt in file.tree.body:
            targets = []
            if isinstance(stmt, ast.Assign):
                targets, value = stmt.targets, stmt.value
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                targets, value = [stmt.target], stmt.value
            else:
                continue
            if (
                len(targets) == 1
                and isinstance(targets[0], ast.Name)
                and _is_empty_container(value)
                and not _has_note(file, stmt.lineno)
            ):
                findings.append(
                    Finding(
                        file.path,
                        stmt.lineno,
                        "MZC042",
                        f"module-level mutable cache `{targets[0].id}` has neither a lock "
                        f"nor a documented single-writer note in the preceding comment",
                    )
                )
    return findings
