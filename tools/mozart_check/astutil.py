"""Small AST helpers shared by the MZC checkers."""

from __future__ import annotations

import ast


def dotted(node: ast.AST) -> str | None:
    """`a.b.c` for Name/Attribute chains, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return None if base is None else f"{base}.{node.attr}"
    return None


def decorator_names(node: ast.FunctionDef | ast.AsyncFunctionDef | ast.ClassDef) -> list[str]:
    """Dotted names of decorators; for `@f(...)` the name of `f`."""
    out = []
    for deco in node.decorator_list:
        target = deco.func if isinstance(deco, ast.Call) else deco
        d = dotted(target)
        if d is not None:
            out.append(d)
    return out


def is_dataclass(cls: ast.ClassDef) -> bool:
    return any(d in ("dataclass", "dataclasses.dataclass") for d in decorator_names(cls))


def str_const(node: ast.AST) -> str | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def public_functions(tree: ast.Module) -> dict[str, int]:
    """Top-level public function name -> line."""
    return {
        n.name: n.lineno
        for n in tree.body
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)) and not n.name.startswith("_")
    }
