"""mozart-check: repo-aware static analysis for the Mozart reproduction.

Five checker families, each the static form of a bug class this repo has
actually shipped and fixed by hand:

  MZC01x  trace/recompile hazards around jax.jit
  MZC02x  Pallas kernel contracts (grid/BlockSpec/accumulator/triplets)
  MZC03x  to_dict/from_dict serialization-schema drift
  MZC04x  mutable defaults and module-level shared state
  MZC05x  MOZART_* env knobs vs the central registry + README table

Run ``python -m tools.mozart_check src benchmarks examples``.  Suppress a
finding with ``# mzc: ignore[MZC0xx]`` on its line.  The runtime
counterpart of MZC01x lives in ``tools.mozart_check.tracecheck``.
"""

from __future__ import annotations

from . import mzc01_trace, mzc02_pallas, mzc03_serde, mzc04_mutable, mzc05_knobs
from .driver import Finding, ParsedFile, parse_paths, run_checkers

ALL_CHECKERS = (
    mzc01_trace.check,
    mzc02_pallas.check,
    mzc03_serde.check,
    mzc04_mutable.check,
    mzc05_knobs.check,
)

__all__ = [
    "ALL_CHECKERS",
    "Finding",
    "ParsedFile",
    "parse_paths",
    "run_checkers",
]
