"""Codesign-search throughput: fixed-seed `anneal_pool` wall-clock, seed
implementation (scalar perf model, hull solver, no cross-call caching)
vs. the cached/vectorized evaluation engine.

Both runs must return the identical best pool, score, and per-network
stage configurations — the engine is a pure acceleration.  Run as a
module (`PYTHONPATH=src python -m benchmarks.bench_codesign_search`) or
via benchmarks/run.py.
"""
from __future__ import annotations

import time

from repro.core import engine, operators
from repro.core.fusion import GAConfig
from repro.core.pool import SAConfig, anneal_pool

from .common import FAST, fmt, write_bench_json

SA_ITERATIONS = 4 if FAST else 10


def _workload():
    ws = operators.paper_workloads(seq=512)
    return {"resnet50": ws["resnet50"],
            "opt66b_decode": ws["opt66b_decode"]}


def _run_once(graphs):
    engine.clear_all_caches()
    sa = SAConfig(iterations=SA_ITERATIONS,
                  inner_ga=GAConfig(population=6, generations=2))
    t0 = time.perf_counter()
    res = anneal_pool(graphs, objective="energy", pool_size=4, cfg=sa,
                      final_ga=GAConfig(population=10, generations=10))
    return (time.perf_counter() - t0) * 1e6, res


def run():
    graphs = _workload()
    was = engine.engine_enabled()
    try:
        engine.set_engine_enabled(False)
        us_seed, res_seed = _run_once(graphs)
        engine.set_engine_enabled(True)
        us_engine, res_engine = _run_once(graphs)
    finally:
        engine.set_engine_enabled(was)
        engine.clear_all_caches()

    pools_equal = [c.label for c in res_seed.pool] == \
        [c.label for c in res_engine.pool]
    score_equal = res_seed.score == res_engine.score
    stages_equal = all(
        [o.cfg.label for o in res_seed.per_network[n].solution.stages]
        == [o.cfg.label for o in res_engine.per_network[n].solution.stages]
        for n in graphs)
    if not (pools_equal and score_equal and stages_equal):
        raise AssertionError(
            "engine changed the search result: "
            f"pool={pools_equal} score={score_equal} stages={stages_equal}")

    speedup = us_seed / max(us_engine, 1.0)
    write_bench_json("codesign_search", {
        "seed_us": round(us_seed, 1),
        "engine_us": round(us_engine, 1),
        "speedup": round(speedup, 3),
        "identical_best_design": True,       # asserted above
        "sa_iterations": SA_ITERATIONS,
        "score": res_engine.score,
    })
    return [
        ("codesign_search.seed_impl", us_seed,
         f"score={fmt(res_seed.score)}"),
        ("codesign_search.engine", us_engine,
         f"score={fmt(res_engine.score)}"),
        ("codesign_search.speedup", 0.0,
         f"{speedup:.2f}x identical_best_design=True"),
    ]


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
