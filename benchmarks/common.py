"""Shared benchmark plumbing.

Every benchmark module exposes run() -> list[(name, us_per_call, derived)]
and is registered in run.py.  REPRO_BENCH_FAST=1 trims search budgets
(same code paths, smaller populations) for CI-speed runs.
"""
from __future__ import annotations

import json
import math
import os
import time

from repro.core.chiplets import Chiplet, default_pool
from repro.core.fusion import GAConfig

FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"

# Where BENCH_*.json artifacts land (CI uploads them and feeds them to
# benchmarks/compare.py, the regression gate).
BENCH_DIR = os.environ.get("REPRO_BENCH_DIR", ".")


def write_bench_json(name: str, payload: dict) -> str:
    """Write a BENCH_<name>.json artifact next to the benchmark run."""
    path = os.path.join(BENCH_DIR, f"BENCH_{name}.json")
    blob = {"bench": name, "fast": FAST, **payload}
    with open(path, "w") as f:
        json.dump(blob, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def ga_budget(pop: int = 10, gens: int = 10, **kw) -> GAConfig:
    if FAST:
        pop, gens = min(pop, 6), min(gens, 2)
    return GAConfig(population=pop, generations=gens, **kw)


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) * 1e6
    return out, dt


def geomean(xs) -> float:
    xs = [max(x, 1e-30) for x in xs]
    return math.exp(sum(math.log(x) for x in xs) / max(len(xs), 1))


def utilization(sol) -> float:
    """Fraction of deployed peak FLOPs actually used at interval T."""
    used = sum(o.flops_per_sample for o in sol.stages)
    deployed = sum(o.cfg.chiplet.peak_flops * o.cfg.tp * o.repeat
                   for o in sol.stages)
    return used / max(deployed * sol.T, 1e-30)


def fmt(x: float, nd: int = 3) -> str:
    return f"{x:.{nd}g}"


HOMOG_CANDIDATES = tuple(default_pool())
