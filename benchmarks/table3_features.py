"""Paper Table 3: framework capability matrix.

The paper positions Mozart as the first framework supporting
heterogeneous chiplet selection + mapping-fusion-parallelism
co-optimization + monetary cost modeling simultaneously.  This
benchmark PROGRAMMATICALLY verifies each claimed capability exists and
functions in this reproduction (a self-check, not a timing benchmark).
"""
from __future__ import annotations

from .common import timed


def run():
    rows = []

    def check(name, fn):
        ok, t_us = timed(fn)
        rows.append((f"table3.{name}", t_us, "yes" if ok else "MISSING"))
        return ok

    def hw_sw_codesign():
        from repro.core.codesign import run_codesign
        return callable(run_codesign)

    def accel_heterogeneity():
        from repro.core.chiplets import full_design_space
        return len({c.dataflow for c in full_design_space()}) == 3

    def chiplet_based():
        from repro.core.costmodel import die_cost
        return die_cost(400.0) > 2 * die_cost(200.0)   # yield economics

    def ecosystem_codesign():
        from repro.core.pool import anneal_pool
        from repro.core.codesign import CodesignResult
        return callable(anneal_pool) and \
            hasattr(CodesignResult, "chiplet_reuse")

    def floorplanning():
        from repro.core.pnr import place_and_route
        return callable(place_and_route)

    def op_level_batching():
        from repro.core.perfmodel import BATCH_OPTIONS
        from repro.core.policy import ExecutionPolicy
        return len(BATCH_OPTIONS) > 1 and \
            hasattr(ExecutionPolicy, "batch_agnostic_batch")

    def tensor_fusion():
        from repro.core.fusion import optimize_fusion, groups_from_genome
        return callable(optimize_fusion)

    def parallelism():
        from repro.core.perfmodel import TP_OPTIONS
        from repro.parallel.pipeline import pipeline_apply
        return len(TP_OPTIONS) > 1 and callable(pipeline_apply)

    def cost_model():
        from repro.core.costmodel import system_cost
        return callable(system_cost)

    def emerging_workloads():
        from repro import configs
        fams = {configs.get_config(a).family for a in configs.ARCH_IDS}
        return fams >= {"transformer", "rglru", "rwkv6", "whisper"}

    checks = [
        ("hw_sw_codesign", hw_sw_codesign),
        ("accelerator_heterogeneity", accel_heterogeneity),
        ("chiplet_based", chiplet_based),
        ("chiplet_ecosystem_codesign", ecosystem_codesign),
        ("chiplet_floorplanning", floorplanning),
        ("operator_level_batching", op_level_batching),
        ("tensor_fusion", tensor_fusion),
        ("tensor_pipeline_parallelism", parallelism),
        ("monetary_cost_model", cost_model),
        ("emerging_workloads", emerging_workloads),
    ]
    n_ok = sum(1 for n, f in checks if check(n, f))
    rows.append(("table3.summary", 0.0,
                 f"capabilities={n_ok}/{len(checks)}"
                 " (paper Table 3: Mozart uniquely covers all columns)"))
    return rows
