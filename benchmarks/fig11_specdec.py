"""Paper Fig. 11 / §6.2.1: speculative decoding under a 2x speedup cap
with TAR=5.6 (k>=5) — OPT-66B target + OPT-1.3B draft.

The draft path is latency-critical, the verifier throughput-oriented
(Insight 3).  Mozart routes each to the right chiplets from the pool; the
homogeneous baseline must pick ONE SKU for both paths (chosen jointly in
its favor).  All settings must satisfy TPOT; realized speedup is capped
at 2x over non-SD by limiting the draft decode rate (paper protocol).

Paper claim (cost-aware): +24.6% (chatbot) / +58.6% (summarization)
throughput, -38.6% / -45.6% energy.

These numbers are ANALYTICAL (acceptance-rate algebra over the chiplet
latency models).  `benchmarks/bench_specdec.py` measures the live
counterpart — `serving.specdec.SpecDecodeEngine` running draft+target
co-resident in one engine — and gates the MEASURED tokens/s speedup in
benchmarks/compare.py.
"""
from __future__ import annotations

from repro.core import operators, scenarios
from repro.core.chiplets import default_pool
from repro.core.fusion import Requirement, optimize_fusion
from repro.core.operators import OPT_1_3B, lm_operator_graph

from .common import fmt, ga_budget, timed

K = scenarios.SPECDEC_K
TAR = scenarios.SPECDEC_TAR
ACCEPTED = min(TAR, K + 1)


def _iteration(d, v, cap_tps):
    """(tokens/s, J/token, $-weighted J/token) for one SD configuration."""
    t_iter = K * d.solution.delay_e2e + v.solution.delay_e2e
    tps = min(ACCEPTED / t_iter, cap_tps)
    e_tok = (K * d.solution.energy_per_sample
             + v.solution.energy_per_sample) / ACCEPTED
    ec_tok = (K * d.solution.metrics()["energy_cost"]
              + v.solution.metrics()["energy_cost"]) / ACCEPTED
    return tps, e_tok, ec_tok


def run():
    verify = lm_operator_graph(operators.OPT_66B, seq=K + 1,
                               phase="prefill")
    target_dec = operators.paper_workloads(seq=2048)["opt66b_decode"]
    draft_dec = lm_operator_graph(OPT_1_3B, 2048, "decode",
                                  cache_len=2048)
    pool = default_pool()
    rows = []
    out = {}

    # non-SD reference: target decoding alone under TPOT
    base = optimize_fusion(target_dec, pool, objective="edp",
                           req=Requirement(e2e=0.15),
                           cfg=ga_budget(pop=6, gens=2))
    base_tps = 1.0 / base.solution.delay_e2e
    cap_tps = scenarios.SPECDEC_SPEEDUP_CAP * base_tps
    # cap realized speedup by limiting the draft decode rate (paper)
    draft_deadline = ACCEPTED / cap_tps / (K + 1)
    verify_budget = ACCEPTED / cap_tps - K * draft_deadline

    for scen_name, req in (("chatbot", scenarios.CHATBOT),
                           ("summarization", scenarios.SUMMARIZATION)):
        for mode, objective in (("cost_aware", "energy_cost"),
                                ("performance", "edp")):
            def solve_pool(p, budget):
                dd = optimize_fusion(draft_dec, p, objective=objective,
                                     req=Requirement(e2e=draft_deadline),
                                     cfg=budget)
                vv = optimize_fusion(verify, p, objective=objective,
                                     req=Requirement(e2e=verify_budget),
                                     cfg=budget)
                if dd is None:    # can't hit the capped draft rate:
                    dd = optimize_fusion(draft_dec, p, objective="edp",
                                         cfg=budget)
                if vv is None:
                    vv = optimize_fusion(verify, p, objective="edp",
                                         cfg=budget)
                return dd, vv

            def solve_homog():
                best = None
                for sku in pool:
                    dv = solve_pool([sku], ga_budget(pop=4, gens=1))
                    if dv[0] is None or dv[1] is None:
                        continue
                    tps, e, ec = _iteration(*dv, cap_tps)
                    score = ec / max(tps, 1e-9) if mode == "cost_aware" \
                        else e / max(tps, 1e-9) ** 2
                    if best is None or score < best[0]:
                        best = (score, dv)
                return best[1]

            (hd, hv), t1 = timed(solve_homog)
            (md, mv), t2 = timed(solve_pool, pool, ga_budget(pop=8, gens=3))

            h_tps, h_e, h_ec = _iteration(hd, hv, cap_tps)
            m_tps, m_e, m_ec = _iteration(md, mv, cap_tps)
            dtps = 100 * (m_tps / h_tps - 1)
            de = 100 * (1 - m_e / h_e)
            key = f"{scen_name}.{mode}"
            out[key] = (dtps, de)
            rows.append((f"fig11.{key}", t1 + t2,
                         f"throughput_gain={fmt(dtps)}%"
                         f" energy_reduction={fmt(de)}%"
                         f" speedup_vs_nonSD={fmt(m_tps / base_tps)}x"
                         f" (cap {scenarios.SPECDEC_SPEEDUP_CAP}x,"
                         f" homog={fmt(h_tps / base_tps)}x)"))
    ca = out["chatbot.cost_aware"]
    sa = out["summarization.cost_aware"]
    rows.append(("fig11.summary", 0.0,
                 f"cost_aware: chatbot +{fmt(ca[0])}%tps {fmt(-ca[1])}%E;"
                 f" summarization +{fmt(sa[0])}%tps {fmt(-sa[1])}%E"
                 f" (paper: +24.6/+58.6% tps, -38.6/-45.6% E)"))
    return rows
