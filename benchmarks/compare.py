"""CI benchmark-regression gate.

Compares the BENCH_*.json artifacts written by
`benchmarks.bench_codesign_search` and `benchmarks.bench_budget_scaling`
against the checked-in thresholds in benchmarks/baselines.json, and exits
nonzero on any regression:

  * codesign_search — the cached/vectorized engine's speedup over the
    seed implementation must stay >= min_speedup (the dev container
    measures 5-6x; the threshold is deliberately loose for noisy CI
    runners), and the engine must still return the identical best design;
  * budget_scaling — both fixed-seed budget axes must remain
    monotone-or-flat, i.e. more search budget never yields a worse
    objective;
  * batch_solve — the generation-batched Layer-3 evaluation must stay
    >= min_speedup_vs_pr3 over the reconstructed PR-3 per-genome path
    (the dev container measures 2.4-2.9x; the threshold is loose for
    noisy CI runners) and keep producing identical solutions;
  * serving — compacted decode must hold its speedup over the schedule
    emulation with identical tokens and zero steady-state recompiles,
    the prompt-length-mix workload must stay inside the paged engine's
    recompile budget (len(prefill_buckets)+1 executables) with paged
    tokens matching the dense cache, and the mix's TTFT/TPOT p50/p99
    must stay under the (deliberately loose) latency ceilings;
  * cluster — the multi-replica cluster must beat the single replica on
    MEASURED aggregate tokens/s (equal per-request token counts,
    >= min_speedup_multi), the int8 KV cache must hold token-level
    parity and >= 2x pages per HBM byte, and the open-loop Poisson
    drive's aggregate p99 TTFT/TPOT must stay under their ceilings;
  * chaos — under the fixed fault script the cluster's goodput
    (deadline-respecting tokens/s) must stay >= min_goodput_frac of the
    fault-free run with zero deadline-violating tokens counted as
    goodput, every completed token stream must be byte-identical to the
    fault-free run's, the watchdog must have quarantined the silent
    faults (>= min_quarantined), and the total-outage drill must return
    cleanly and recover token-exactly after restarts;
  * specdec — live in-engine speculative decoding must hold its MEASURED
    tokens/s speedup over the target-only engine (>= min_speedup) with
    greedy outputs token-exact, acceptance at the high_tar_pair ceiling
    (>= min_acceptance — the draft IS the target's prefix by
    construction, so anything less means the verify window or cache
    rewind broke), and zero steady-state recompiles in the timed run.

Usage: PYTHONPATH=src python -m benchmarks.compare [--dir DIR]
       [--baseline benchmarks/baselines.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _load(path: str) -> dict | None:
    try:
        with open(path) as f:
            return json.load(f)
    except OSError:
        return None


def check(bench_dir: str, baselines: dict) -> list[str]:
    """Returns a list of human-readable failures (empty = gate passes)."""
    failures: list[str] = []

    path = os.path.join(bench_dir, "BENCH_codesign_search.json")
    blob = _load(path)
    base = baselines.get("codesign_search", {})
    if blob is None:
        failures.append(f"missing artifact: {path}")
    else:
        min_speedup = float(base.get("min_speedup", 1.0))
        speedup = float(blob.get("speedup", 0.0))
        if speedup < min_speedup:
            failures.append(
                f"codesign_search speedup regressed: {speedup:.2f}x < "
                f"baseline {min_speedup:.2f}x")
        else:
            print(f"OK codesign_search: speedup {speedup:.2f}x >= "
                  f"{min_speedup:.2f}x")
        if not blob.get("identical_best_design", False):
            failures.append(
                "codesign_search: engine no longer returns the identical "
                "best design")

    path = os.path.join(bench_dir, "BENCH_budget_scaling.json")
    blob = _load(path)
    base = baselines.get("budget_scaling", {})
    if blob is None:
        failures.append(f"missing artifact: {path}")
    elif base.get("require_monotone", True):
        for key in ("monotone_sa", "monotone_ga"):
            if not blob.get(key, False):
                failures.append(
                    f"budget_scaling: {key} is false — more budget "
                    f"produced a worse objective")
        if blob.get("monotone_sa") and blob.get("monotone_ga"):
            n_sa = len(blob.get("sa_levels", []))
            n_ga = len(blob.get("ga_levels", []))
            print(f"OK budget_scaling: monotone over {n_sa} SA + "
                  f"{n_ga} GA budget levels")

    path = os.path.join(bench_dir, "BENCH_batch_solve.json")
    blob = _load(path)
    base = baselines.get("batch_solve", {})
    if blob is None:
        failures.append(f"missing artifact: {path}")
    else:
        min_speedup = float(base.get("min_speedup_vs_pr3", 1.0))
        speedup = float(blob.get("speedup_vs_pr3", 0.0))
        if speedup < min_speedup:
            failures.append(
                f"batch_solve generation-eval speedup regressed: "
                f"{speedup:.2f}x < baseline {min_speedup:.2f}x")
        else:
            print(f"OK batch_solve: generation-eval {speedup:.2f}x >= "
                  f"{min_speedup:.2f}x vs the PR-3 per-genome path")
        if not blob.get("identical_solutions", False):
            failures.append(
                "batch_solve: batched generation evaluation no longer "
                "produces identical solutions")

    path = os.path.join(bench_dir, "BENCH_serving.json")
    blob = _load(path)
    base = baselines.get("serving", {})
    if blob is None:
        failures.append(f"missing artifact: {path}")
    else:
        min_speedup = float(base.get("min_speedup_compacted", 1.0))
        speedup = float(blob.get("speedup_compacted_vs_emulated", 0.0))
        if speedup < min_speedup:
            failures.append(
                f"serving compacted-decode speedup regressed: "
                f"{speedup:.2f}x < baseline {min_speedup:.2f}x")
        else:
            print(f"OK serving: compacted decode {speedup:.2f}x >= "
                  f"{min_speedup:.2f}x vs the schedule emulation")
        if not blob.get("identical_outputs", False):
            failures.append(
                "serving: compacted decode no longer emits tokens "
                "identical to the emulated schedule")
        max_rec = base.get("max_steady_state_recompiles")
        if max_rec is not None:
            rec = blob.get("steady_state_recompiles")
            if rec is None:
                failures.append(
                    "serving: artifact lacks steady_state_recompiles — "
                    "bench_serving must record the tracecheck counts")
            else:
                worst = max(rec.values())
                if worst > int(max_rec):
                    bad = {k: v for k, v in rec.items() if v > int(max_rec)}
                    failures.append(
                        f"serving: steady-state decode now recompiles "
                        f"({bad}) — baseline allows {max_rec}")
                else:
                    print(f"OK serving: steady-state recompiles <= "
                          f"{max_rec} across {sorted(rec)}")
        if base.get("require_mix_recompile_budget", False):
            budget = blob.get("mix_recompile_budget")
            rec_mix = blob.get("mix_recompiles_steady")
            if budget is None or rec_mix is None:
                failures.append(
                    "serving: artifact lacks the prompt-mix recompile "
                    "counts — bench_serving must run the mix workload")
            elif int(rec_mix) > int(budget):
                failures.append(
                    f"serving: mixed-length serving now builds {rec_mix} "
                    f"executables — budget is len(buckets)+1 = {budget}")
            else:
                print(f"OK serving: prompt-mix recompiles {rec_mix} <= "
                      f"bucket budget {budget}")
            if not blob.get("paged_matches_dense", False):
                failures.append(
                    "serving: paged decode no longer matches the dense "
                    "cache token-for-token on the prompt mix")
        for key, limit_key in (("ttft_p50_ms", "max_ttft_p50_ms"),
                               ("ttft_p99_ms", "max_ttft_p99_ms"),
                               ("tpot_p50_ms", "max_tpot_p50_ms"),
                               ("tpot_p99_ms", "max_tpot_p99_ms")):
            limit = base.get(limit_key)
            if limit is None:
                continue
            val = blob.get(key)
            if val is None:
                failures.append(
                    f"serving: artifact lacks {key} — bench_serving "
                    f"must report prompt-mix latency percentiles")
            elif float(val) > float(limit):
                failures.append(
                    f"serving: {key} regressed: {float(val):.1f}ms > "
                    f"baseline {float(limit):.1f}ms")
            else:
                print(f"OK serving: {key} {float(val):.1f}ms <= "
                      f"{float(limit):.1f}ms")

    path = os.path.join(bench_dir, "BENCH_cluster.json")
    blob = _load(path)
    base = baselines.get("cluster", {})
    if blob is None:
        failures.append(f"missing artifact: {path}")
    else:
        # the scale-out gate is on MEASURED aggregate throughput (the
        # ROADMAP's parquet-aggregator lesson: never gate on worker or
        # replica count) with equal per-request token counts, so the
        # multi-replica run cannot "win" by doing different work
        min_speedup = float(base.get("min_speedup_multi", 1.0))
        speedup = float(blob.get("speedup_multi_vs_single", 0.0))
        if speedup < min_speedup:
            failures.append(
                f"cluster scale-out throughput regressed: "
                f"{speedup:.2f}x < baseline {min_speedup:.2f}x")
        else:
            print(f"OK cluster: {blob.get('n_replicas')}-replica "
                  f"aggregate {speedup:.2f}x >= {min_speedup:.2f}x vs "
                  f"single-replica")
        if base.get("require_equal_tokens", False) and \
                not blob.get("equal_tokens", False):
            failures.append(
                "cluster: multi-replica run no longer emits the same "
                "per-request token counts as the single replica")
        min_match = base.get("min_quant_token_match")
        if min_match is not None:
            match = float(blob.get("quant_token_match_frac", 0.0))
            if match < float(min_match):
                failures.append(
                    f"cluster: int8-KV token match {match:.3f} < "
                    f"baseline {float(min_match):.3f}")
            else:
                print(f"OK cluster: int8-KV token match {match:.3f} >= "
                      f"{float(min_match):.3f}")
        min_cap = base.get("min_quant_capacity_ratio")
        if min_cap is not None:
            cap = float(blob.get("quant_capacity_ratio", 0.0))
            if cap < float(min_cap):
                failures.append(
                    f"cluster: int8-KV capacity ratio {cap:.2f}x < "
                    f"baseline {float(min_cap):.2f}x")
            else:
                print(f"OK cluster: int8-KV holds {cap:.2f}x >= "
                      f"{float(min_cap):.2f}x pages per HBM byte")
        for key, limit_key in (("ttft_p99_ms", "max_ttft_p99_ms"),
                               ("tpot_p99_ms", "max_tpot_p99_ms")):
            limit = base.get(limit_key)
            if limit is None:
                continue
            val = blob.get(key)
            if val is None:
                failures.append(
                    f"cluster: artifact lacks {key} — bench_cluster "
                    f"must report open-loop latency percentiles")
            elif float(val) > float(limit):
                failures.append(
                    f"cluster: {key} regressed: {float(val):.1f}ms > "
                    f"baseline {float(limit):.1f}ms")
            else:
                print(f"OK cluster: {key} {float(val):.1f}ms <= "
                      f"{float(limit):.1f}ms")

    path = os.path.join(bench_dir, "BENCH_chaos.json")
    blob = _load(path)
    base = baselines.get("chaos", {})
    if blob is None:
        failures.append(f"missing artifact: {path}")
    else:
        min_frac = float(base.get("min_goodput_frac", 0.0))
        frac = float(blob.get("goodput_frac", 0.0))
        if frac < min_frac:
            failures.append(
                f"chaos goodput regressed: {frac:.2f}x of fault-free < "
                f"baseline {min_frac:.2f}x")
        else:
            print(f"OK chaos: goodput under faults {frac:.2f}x >= "
                  f"{min_frac:.2f}x of the fault-free run")
        max_viol = base.get("max_goodput_violations")
        if max_viol is not None:
            viol = int(blob.get("goodput_violations", 1))
            if viol > int(max_viol):
                failures.append(
                    f"chaos: {viol} deadline-violating requests counted "
                    f"as goodput — baseline allows {max_viol}")
            else:
                print(f"OK chaos: goodput violations {viol} <= {max_viol}")
        if base.get("require_exact_tokens", False) and \
                not blob.get("completed_tokens_exact", False):
            failures.append(
                "chaos: completed token streams diverged from the "
                "fault-free run — failover recovery is no longer exact")
        if base.get("require_outage_survival", False):
            for key in ("outage_survived", "outage_tokens_exact"):
                if not blob.get(key, False):
                    failures.append(
                        f"chaos: total-outage drill failed ({key} is "
                        f"false) — the cluster must hold parked work "
                        f"and recover it token-exactly")
            if blob.get("outage_survived") and blob.get("outage_tokens_exact"):
                print(f"OK chaos: total outage held "
                      f"{blob.get('outage_unrouted')} parked requests "
                      f"and recovered token-exactly")
        min_q = base.get("min_quarantined")
        if min_q is not None:
            q = int(blob.get("quarantined", 0))
            if q < int(min_q):
                failures.append(
                    f"chaos: watchdog quarantined only {q} replicas — "
                    f"the script's silent faults require >= {min_q}")
            else:
                print(f"OK chaos: watchdog quarantined {q} >= {min_q} "
                      f"silently faulted replicas")

    path = os.path.join(bench_dir, "BENCH_specdec.json")
    blob = _load(path)
    base = baselines.get("specdec", {})
    if blob is None:
        failures.append(f"missing artifact: {path}")
    else:
        min_speedup = float(base.get("min_speedup", 1.0))
        speedup = float(blob.get("speedup_specdec_vs_target", 0.0))
        if speedup < min_speedup:
            failures.append(
                f"specdec live speedup regressed: {speedup:.2f}x < "
                f"baseline {min_speedup:.2f}x")
        else:
            print(f"OK specdec: live spec-decode {speedup:.2f}x >= "
                  f"{min_speedup:.2f}x vs target-only decode")
        if base.get("require_token_exact", False) and \
                not blob.get("token_exact", False):
            failures.append(
                "specdec: greedy spec-decode output diverged from the "
                "target-only engine — verify/rewind is no longer exact")
        min_acc = base.get("min_acceptance")
        if min_acc is not None:
            acc = float(blob.get("acceptance_rate", 0.0))
            if acc < float(min_acc):
                failures.append(
                    f"specdec: acceptance {acc:.3f} < baseline "
                    f"{float(min_acc):.3f} — the high_tar_pair draft is "
                    f"the target's prefix, so acceptance must be ~1.0")
            else:
                print(f"OK specdec: acceptance {acc:.3f} >= "
                      f"{float(min_acc):.3f} at the shared-prefix ceiling")
        max_rec = base.get("max_steady_state_recompiles")
        if max_rec is not None:
            rec = blob.get("steady_state_recompiles")
            if rec is None:
                failures.append(
                    "specdec: artifact lacks steady_state_recompiles — "
                    "bench_specdec must record the tracecheck counts")
            else:
                worst = max(rec.values())
                if worst > int(max_rec):
                    bad = {k: v for k, v in rec.items() if v > int(max_rec)}
                    failures.append(
                        f"specdec: steady-state decode now recompiles "
                        f"({bad}) — baseline allows {max_rec}")
                else:
                    print(f"OK specdec: steady-state recompiles <= "
                          f"{max_rec} across {sorted(rec)}")
    return failures


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--dir", default=os.environ.get("REPRO_BENCH_DIR", "."),
                   help="directory holding the BENCH_*.json artifacts "
                        "(default: REPRO_BENCH_DIR, matching where the "
                        "benchmarks write them)")
    p.add_argument("--baseline",
                   default=os.path.join(os.path.dirname(__file__),
                                        "baselines.json"))
    args = p.parse_args()
    baselines = _load(args.baseline)
    if baselines is None:
        print(f"cannot read baseline file {args.baseline}", file=sys.stderr)
        sys.exit(2)
    failures = check(args.dir, baselines)
    if failures:
        for f in failures:
            print(f"FAIL {f}", file=sys.stderr)
        sys.exit(1)
    print("benchmark gate passed")


if __name__ == "__main__":
    main()
