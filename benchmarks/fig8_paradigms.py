"""Paper Fig. 8: five architectural paradigms x four metrics, normalized
to Homogeneous ASIC (all networks):

  GPU (modeled A100 — see perfmodel.gpu_eval; flagged `modeled`),
  Homogeneous ASIC (one SKU serves all networks),
  Homogeneous BASIC (best single SKU per network),
  Heterogeneous BASIC (Mozart 8-chiplet pool),
  Heterogeneous BASIC unconstrained (full 96-SKU design space).

Headline reproduction: pool-of-8 energy/EDP/EDPx$ within a few % of
unconstrained; big energy/EDP savings vs homogeneous (paper: 43.5%,
67.7%) and ~17.5x geomean energy vs GPU for homogeneous ASIC.
"""
from __future__ import annotations

from repro.core import operators
from repro.core.chiplets import default_pool, full_design_space
from repro.core.codesign import best_homogeneous_design, design_for_network
from repro.core.fusion import optimize_fusion
from repro.core.perfmodel import gpu_eval

from .common import FAST, fmt, ga_budget, geomean, timed

NETWORKS = ["resnet50", "mobilenetv3", "efficientnet", "replknet31b",
            "vit_b16", "opt66b_prefill", "opt66b_decode"]
METRICS = ("energy", "edp", "energy_cost", "edp_cost")


def run():
    graphs = {n: g for n, g in operators.paper_workloads(seq=2048).items()
              if n in NETWORKS}
    pool8 = default_pool()
    full = full_design_space()
    rows = []
    results: dict[str, dict[str, dict[str, float]]] = {}

    def record(paradigm, name, metrics):
        results.setdefault(paradigm, {})[name] = metrics

    # --- Homogeneous ASIC (one SKU for ALL networks): pick the SKU with
    # the best geomean energy across networks.
    def solve_homog_all():
        best_sku, best_score, per = None, None, None
        for sku in pool8:
            vals, ms = [], {}
            ok = True
            for n, g in graphs.items():
                r = optimize_fusion(g, [sku], objective="energy",
                                    cfg=ga_budget(pop=4, gens=1))
                if r is None:
                    ok = False
                    break
                ms[n] = r.solution.metrics()
                vals.append(r.value)
            if not ok:
                continue
            s = geomean(vals)
            if best_score is None or s < best_score:
                best_sku, best_score, per = sku, s, ms
        return best_sku, per

    (sku_all, homog_all), t_us = timed(solve_homog_all)
    for n, m in homog_all.items():
        record("homog_asic", n, m)
    rows.append(("fig8.homog_asic", t_us, f"sku={sku_all.label}"))

    # --- GPU baseline (modeled)
    t_total = 0.0
    for n, g in graphs.items():
        (lat, e), t_us = timed(gpu_eval, g.operators, g.repeats, 1)
        t_total += t_us
        from repro.core.perfmodel import GPU_COST_USD
        record("gpu", n, {"energy": e, "edp": e * lat,
                          "energy_cost": e * GPU_COST_USD,
                          "edp_cost": e * lat * GPU_COST_USD})
    rows.append(("fig8.gpu_modeled", t_total, "modeled A100 (no GPU here)"))

    # --- per-network paradigms.  Each paradigm's search space contains
    # the previous one's, so enforce the dominance ordering (guards GA
    # noise): unconstrained <= pool8 <= homog_basic by objective value.
    for paradigm, pool, budget in (
            ("homog_basic", None, ga_budget(pop=6, gens=2)),
            ("hetero_pool8", pool8, ga_budget(pop=8, gens=4)),
            ("hetero_unconstrained", full, ga_budget(pop=6, gens=3))):
        t_total = 0.0
        for n, g in graphs.items():
            if paradigm == "homog_basic":
                d, t_us = timed(best_homogeneous_design, g,
                                candidates=pool8, objective="energy",
                                ga=ga_budget(pop=4, gens=1))
                m = d.fusion.solution.metrics()
            else:
                r, t_us = timed(optimize_fusion, g, pool,
                                objective="energy", cfg=budget)
                m = r.solution.metrics()
                prev = "homog_basic" if paradigm == "hetero_pool8" \
                    else "hetero_pool8"
                if results[prev][n]["energy"] < m["energy"]:
                    m = dict(results[prev][n])
            t_total += t_us
            record(paradigm, n, m)
        rows.append((f"fig8.{paradigm}", t_total, "ok"))

    # --- normalized table + headlines
    for metric in METRICS:
        for paradigm in ("gpu", "homog_basic", "hetero_pool8",
                         "hetero_unconstrained"):
            ratios = [results[paradigm][n][metric]
                      / results["homog_asic"][n][metric]
                      for n in NETWORKS]
            rows.append((f"fig8.{metric}.{paradigm}", 0.0,
                         f"geomean_vs_homog_asic={fmt(geomean(ratios))}"))

    e_gain = geomean([results["homog_asic"][n]["energy"]
                      / results["gpu"][n]["energy"] for n in NETWORKS])
    pool_vs_unc = {m: geomean(
        [results["hetero_pool8"][n][m]
         / results["hetero_unconstrained"][n][m] for n in NETWORKS])
        for m in METRICS}
    save_vs_homog = {m: 100 * (1 - geomean(
        [results["hetero_pool8"][n][m] / results["homog_asic"][n][m]
         for n in NETWORKS])) for m in METRICS}
    rows.append(("fig8.summary", 0.0,
                 f"asic_vs_gpu_energy={fmt(1 / e_gain)}x"
                 f" pool8_savings_vs_homog:"
                 f" energy={fmt(save_vs_homog['energy'])}%"
                 f" energyx$={fmt(save_vs_homog['energy_cost'])}%"
                 f" edp={fmt(save_vs_homog['edp'])}%"
                 f" edpx$={fmt(save_vs_homog['edp_cost'])}%"
                 f" | pool8_within_unconstrained:"
                 + ",".join(f" {m}={fmt(100 * (pool_vs_unc[m] - 1))}%"
                            for m in METRICS)
                 + " (paper: 43.5/25.4/67.7/78.8% savings; within 5-9%)"))
    return rows
