"""Serving throughput: compacted sub-batch decode vs the PR-4 schedule
emulation.

The Mozart policy's batch-agnostic split sets ``decode_batch`` below the
engine's slot count.  PR 4 honored the split as a *schedule* — decode
stayed static-shaped over ``max_batch``, so each sub-step paid the full
per-step FLOPs.  The compacted engine gathers the active slots' cache
slices, decodes at ``decode_batch`` width, and scatters back, so the
narrow steps actually cost less.  Three fixed-seed engine runs over the
same request trace:

  * full      — decode_batch == max_batch (one wide lock-step batch);
  * emulated  — decode_batch < max_batch, ``compact=False`` (the PR-4
    round-robin emulation: narrow schedule, full-width compute);
  * compacted — decode_batch < max_batch, compacted gather decode.

Emulated and compacted must emit IDENTICAL tokens (asserted; greedy,
fixed seed).  The gate in benchmarks/compare.py holds
``speedup_compacted_vs_emulated`` above the baseline threshold.  Run as
a module (``PYTHONPATH=src python -m benchmarks.bench_serving``) or via
benchmarks/run.py.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.models import api
from repro.models.config import ModelConfig
from repro.serving.engine import Request, ServingEngine
from tools.mozart_check.tracecheck import CompileMonitor

from .common import FAST, write_bench_json

CFG = ModelConfig(
    name="bench-serve",
    n_layers=2 if FAST else 4,
    d_model=256,
    n_heads=8,
    kv_heads=4,
    head_dim=32,
    d_ff=512,
    vocab=512,
    dtype="float32",
    param_dtype="float32",
    scan_min_layers=2,
)
MAX_BATCH = 8
DECODE_BATCH = 2
N_REQUESTS = 8 if FAST else 16
MAX_NEW = 8 if FAST else 16
MAX_LEN = 64


def _requests(rng):
    reqs = []
    for i in range(N_REQUESTS):
        plen = int(rng.integers(4, 12))
        reqs.append(
            Request(
                rid=i,
                prompt=rng.integers(0, CFG.vocab, size=plen).astype(np.int32),
                max_new_tokens=MAX_NEW,
            )
        )
    return reqs


def _run_engine(params, *, decode_batch, compact):
    rng = np.random.default_rng(0)
    eng = ServingEngine(
        CFG,
        params,
        max_batch=MAX_BATCH,
        max_len=MAX_LEN,
        decode_batch=decode_batch,
        compact=compact,
    )
    reqs = _requests(rng)
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    toks = [r.out_tokens for r in reqs]
    return toks, eng.stats, dt


def run():
    params = api.init_params(CFG, jax.random.PRNGKey(0))
    rows = []
    results = {}
    # warmup pass per variant: the jitted decode/prefill are shared per
    # (config, shape) via the engine's lru-cached builders, so a first
    # run compiles and the timed second run measures steady-state.
    for name, decode_batch, compact in (
        ("full", MAX_BATCH, True),
        ("emulated", DECODE_BATCH, False),
        ("compacted", DECODE_BATCH, True),
    ):
        _run_engine(params, decode_batch=decode_batch, compact=compact)
        # the timed second run is steady state: tracecheck (the runtime
        # half of mozart-check's MZC01) counts XLA executables built
        # during it, and compare.py gates the count at the baseline's
        # max_steady_state_recompiles (0 — shapes are static after warmup)
        with CompileMonitor() as mon:
            toks, stats, dt = _run_engine(
                params, decode_batch=decode_batch, compact=compact
            )
        tok_s = stats["tokens_out"] / max(dt, 1e-9)
        us_per_step = dt * 1e6 / max(stats["decode_steps"], 1)
        results[name] = {
            "tokens": toks,
            "tok_s": tok_s,
            "us_per_step": us_per_step,
            "decode_steps": stats["decode_steps"],
            "wall_s": dt,
            "recompiles_steady": mon.count,
        }
        rows.append(
            (
                f"serving.{name}",
                us_per_step,
                f"tok_s={tok_s:.1f} steps={stats['decode_steps']} "
                f"recompiles={mon.count}",
            )
        )

    identical = results["compacted"]["tokens"] == results["emulated"]["tokens"]
    assert identical, "compacted decode diverged from the emulated schedule"
    speedup_step = (
        results["emulated"]["us_per_step"] / results["compacted"]["us_per_step"]
    )
    speedup_wall = results["emulated"]["wall_s"] / results["compacted"]["wall_s"]
    rows.append(
        (
            "serving.compacted_vs_emulated",
            0.0,
            f"{speedup_step:.2f}x_per_step {speedup_wall:.2f}x_wall "
            f"identical_outputs={identical}",
        )
    )
    write_bench_json(
        "serving",
        {
            "max_batch": MAX_BATCH,
            "decode_batch": DECODE_BATCH,
            "n_requests": N_REQUESTS,
            "max_new_tokens": MAX_NEW,
            "tok_s_full": results["full"]["tok_s"],
            "tok_s_emulated": results["emulated"]["tok_s"],
            "tok_s_compacted": results["compacted"]["tok_s"],
            "us_per_step_emulated": results["emulated"]["us_per_step"],
            "us_per_step_compacted": results["compacted"]["us_per_step"],
            "speedup_compacted_vs_emulated": speedup_step,
            "speedup_wall_compacted_vs_emulated": speedup_wall,
            "identical_outputs": identical,
            "steady_state_recompiles": {
                name: results[name]["recompiles_steady"] for name in results
            },
        },
    )
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
