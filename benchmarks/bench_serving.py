"""Serving throughput: compacted sub-batch decode vs the PR-4 schedule
emulation.

The Mozart policy's batch-agnostic split sets ``decode_batch`` below the
engine's slot count.  PR 4 honored the split as a *schedule* — decode
stayed static-shaped over ``max_batch``, so each sub-step paid the full
per-step FLOPs.  The compacted engine gathers the active slots' cache
slices, decodes at ``decode_batch`` width, and scatters back, so the
narrow steps actually cost less.  Three fixed-seed engine runs over the
same request trace:

  * full      — decode_batch == max_batch (one wide lock-step batch);
  * emulated  — decode_batch < max_batch, ``compact=False`` (the PR-4
    round-robin emulation: narrow schedule, full-width compute);
  * compacted — decode_batch < max_batch, compacted gather decode.

Emulated and compacted must emit IDENTICAL tokens (asserted; greedy,
fixed seed).  The gate in benchmarks/compare.py holds
``speedup_compacted_vs_emulated`` above the baseline threshold.

PR 7 adds a prompt-length-MIX workload through the paged-KV engine:
Zipf-weighted short/medium/long prompts spanning every power-of-two
prefill bucket.  The timed pass reports request-level TTFT and TPOT
p50/p99 into BENCH_serving.json, and its recompile count is gated at
``len(prefill_buckets) + 1`` executables (one prefill per bucket plus
the shared paged decode) — arbitrary length mixes must not retrace.

Run as a module (``PYTHONPATH=src python -m benchmarks.bench_serving``)
or via benchmarks/run.py.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.models import api
from repro.models.config import ModelConfig
from repro.serving import workload
from repro.serving.engine import Request, ServingEngine
from tools.mozart_check.tracecheck import CompileMonitor

from .common import FAST, write_bench_json

CFG = ModelConfig(
    name="bench-serve",
    n_layers=2 if FAST else 4,
    d_model=256,
    n_heads=8,
    kv_heads=4,
    head_dim=32,
    d_ff=512,
    vocab=512,
    dtype="float32",
    param_dtype="float32",
    scan_min_layers=2,
)
MAX_BATCH = 8
DECODE_BATCH = 2
N_REQUESTS = 8 if FAST else 16
MAX_NEW = 8 if FAST else 16
MAX_LEN = 64
N_MIX = 10 if FAST else 20


def _requests(rng):
    reqs = []
    for i in range(N_REQUESTS):
        plen = int(rng.integers(4, 12))
        reqs.append(
            Request(
                rid=i,
                prompt=rng.integers(0, CFG.vocab, size=plen).astype(np.int32),
                max_new_tokens=MAX_NEW,
            )
        )
    return reqs


def _mix_requests(rng, n):
    """Zipf-weighted short/medium/long prompt mix spanning every prefill
    bucket of MAX_LEN=64 (16/32/64) — the shared seeded generator in
    `serving.workload` (same draw order, so fixed-seed traces from
    before the hoist replay unchanged)."""
    return workload.zipf_mix_requests(rng, n, CFG.vocab, max_new_tokens=MAX_NEW)


def _run_mix(params, *, paged):
    rng = np.random.default_rng(7)
    eng = ServingEngine(
        CFG,
        params,
        max_batch=MAX_BATCH,
        max_len=MAX_LEN,
        decode_batch=DECODE_BATCH,
        compact=True,
        paged=paged,
    )
    reqs = _mix_requests(rng, N_MIX)
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    return reqs, eng, dt


def _pct_ms(samples, q):
    return float(np.percentile(np.asarray(samples), q) * 1e3) if len(samples) else 0.0


def _run_engine(params, *, decode_batch, compact):
    rng = np.random.default_rng(0)
    # paged=False: this trio measures the PR-5 compacted-vs-emulated
    # DENSE-cache comparison the speedup gate is defined over; the
    # paged engine gets its own workload, parity, and gates below.
    eng = ServingEngine(
        CFG,
        params,
        max_batch=MAX_BATCH,
        max_len=MAX_LEN,
        decode_batch=decode_batch,
        compact=compact,
        paged=False,
    )
    reqs = _requests(rng)
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    toks = [r.out_tokens for r in reqs]
    return toks, eng.stats, dt


def run():
    params = api.init_params(CFG, jax.random.PRNGKey(0))
    rows = []
    results = {}
    # warmup pass per variant: the jitted decode/prefill are shared per
    # (config, shape) via the engine's lru-cached builders, so a first
    # run compiles and the timed second run measures steady-state.
    for name, decode_batch, compact in (
        ("full", MAX_BATCH, True),
        ("emulated", DECODE_BATCH, False),
        ("compacted", DECODE_BATCH, True),
    ):
        _run_engine(params, decode_batch=decode_batch, compact=compact)
        # the timed second run is steady state: tracecheck (the runtime
        # half of mozart-check's MZC01) counts XLA executables built
        # during it, and compare.py gates the count at the baseline's
        # max_steady_state_recompiles (0 — shapes are static after warmup)
        with CompileMonitor() as mon:
            toks, stats, dt = _run_engine(
                params, decode_batch=decode_batch, compact=compact
            )
        tok_s = stats["tokens_out"] / max(dt, 1e-9)
        us_per_step = dt * 1e6 / max(stats["decode_steps"], 1)
        results[name] = {
            "tokens": toks,
            "tok_s": tok_s,
            "us_per_step": us_per_step,
            "decode_steps": stats["decode_steps"],
            "wall_s": dt,
            "recompiles_steady": mon.count,
        }
        rows.append(
            (
                f"serving.{name}",
                us_per_step,
                f"tok_s={tok_s:.1f} steps={stats['decode_steps']} "
                f"recompiles={mon.count}",
            )
        )

    # prompt-length-mix workload through the paged engine: the warmup
    # pass builds one prefill executable per bucket plus the shared
    # paged decode; the timed pass must stay within that budget (the
    # tracecheck count catches any per-length retrace sneaking back in)
    # while the request timing marks give TTFT/TPOT percentiles.
    _run_mix(params, paged=True)
    with CompileMonitor() as mix_mon:
        mix_reqs, mix_eng, mix_dt = _run_mix(params, paged=True)
    ttft = [r.t_first - r.t_submit for r in mix_reqs if r.t_first is not None]
    tpot = [
        (r.t_done - r.t_first) / (len(r.out_tokens) - 1)
        for r in mix_reqs
        if r.t_done is not None and r.t_first is not None and len(r.out_tokens) > 1
    ]
    buckets = [int(b) for b in mix_eng.buckets]
    budget = len(buckets) + 1
    assert mix_mon.count <= budget, (mix_mon.count, budget, mix_mon.events)
    dense_reqs, _, _ = _run_mix(params, paged=False)
    paged_matches_dense = [r.out_tokens for r in mix_reqs] == [
        r.out_tokens for r in dense_reqs
    ]
    assert paged_matches_dense, "paged decode diverged from the dense cache"
    mix_tok_s = mix_eng.stats["tokens_out"] / max(mix_dt, 1e-9)
    rows.append(
        (
            "serving.mix_paged",
            mix_dt * 1e6 / max(mix_eng.stats["decode_steps"], 1),
            f"tok_s={mix_tok_s:.1f} ttft_p50={_pct_ms(ttft, 50):.1f}ms "
            f"ttft_p99={_pct_ms(ttft, 99):.1f}ms "
            f"tpot_p50={_pct_ms(tpot, 50):.2f}ms "
            f"tpot_p99={_pct_ms(tpot, 99):.2f}ms "
            f"recompiles={mix_mon.count}/{budget}",
        )
    )

    identical = results["compacted"]["tokens"] == results["emulated"]["tokens"]
    assert identical, "compacted decode diverged from the emulated schedule"
    speedup_step = (
        results["emulated"]["us_per_step"] / results["compacted"]["us_per_step"]
    )
    speedup_wall = results["emulated"]["wall_s"] / results["compacted"]["wall_s"]
    rows.append(
        (
            "serving.compacted_vs_emulated",
            0.0,
            f"{speedup_step:.2f}x_per_step {speedup_wall:.2f}x_wall "
            f"identical_outputs={identical}",
        )
    )
    write_bench_json(
        "serving",
        {
            "max_batch": MAX_BATCH,
            "decode_batch": DECODE_BATCH,
            "n_requests": N_REQUESTS,
            "max_new_tokens": MAX_NEW,
            "tok_s_full": results["full"]["tok_s"],
            "tok_s_emulated": results["emulated"]["tok_s"],
            "tok_s_compacted": results["compacted"]["tok_s"],
            "us_per_step_emulated": results["emulated"]["us_per_step"],
            "us_per_step_compacted": results["compacted"]["us_per_step"],
            "speedup_compacted_vs_emulated": speedup_step,
            "speedup_wall_compacted_vs_emulated": speedup_wall,
            "identical_outputs": identical,
            "steady_state_recompiles": {
                name: results[name]["recompiles_steady"] for name in results
            },
            "prefill_buckets": buckets,
            "mix_n_requests": N_MIX,
            "mix_tok_s": mix_tok_s,
            "ttft_p50_ms": _pct_ms(ttft, 50),
            "ttft_p99_ms": _pct_ms(ttft, 99),
            "tpot_p50_ms": _pct_ms(tpot, 50),
            "tpot_p99_ms": _pct_ms(tpot, 99),
            "mix_recompiles_steady": mix_mon.count,
            "mix_recompile_budget": budget,
            "paged_matches_dense": paged_matches_dense,
        },
    )
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
