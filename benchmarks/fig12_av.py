"""Paper Fig. 12 / §6.2.2: autonomous-vehicle perception under hard DET
deadlines (10 ms and 33 ms), batch=1 — Mozart heterogeneous pool vs the
homogeneous chiplet baseline, on CNN/VT backbones.

Paper claim: -25.54% energyx$ and -10.53% energy on average, both
deadlines met.
"""
from __future__ import annotations

from repro.core import operators
from repro.core.chiplets import default_pool
from repro.core.codesign import best_homogeneous_design
from repro.core.fusion import Requirement, optimize_fusion

from .common import fmt, ga_budget, geomean, timed

BACKBONES = ["vit_b16", "mobilenetv3", "replknet31b", "resnet50",
             "efficientnet"]
DEADLINES = (0.010, 0.033)


def run():
    graphs = operators.paper_workloads(seq=2048)
    rows = []
    e_ratios, ec_ratios = [], []
    for tau in DEADLINES:
        req = Requirement(e2e=tau)
        for name in BACKBONES:
            g = graphs[name]

            def solve():
                homog = best_homogeneous_design(
                    g, objective="energy_cost", req=req,
                    ga=ga_budget(pop=4, gens=1, fixed_batch=1))
                moz = optimize_fusion(
                    g, default_pool(), objective="energy_cost", req=req,
                    cfg=ga_budget(pop=8, gens=3, fixed_batch=1))
                # the pool contains every homogeneous configuration, so
                # the pool optimum can never be worse — guard GA noise
                if moz is None or (homog is not None
                                   and homog.fusion.value < moz.value):
                    moz = homog.fusion if homog is not None else moz
                return homog, moz

            (homog, moz), t_us = timed(solve)
            if homog is None or moz is None:
                rows.append((f"fig12.{name}.{int(tau * 1e3)}ms", t_us,
                             "INFEASIBLE under deadline"))
                continue
            hm = homog.fusion.solution.metrics()
            mm = moz.solution.metrics()
            er = mm["energy"] / hm["energy"]
            ecr = mm["energy_cost"] / hm["energy_cost"]
            e_ratios.append(er)
            ec_ratios.append(ecr)
            rows.append((f"fig12.{name}.{int(tau * 1e3)}ms", t_us,
                         f"energy_ratio={fmt(er)}"
                         f" energyx$_ratio={fmt(ecr)}"
                         f" lat={fmt(mm['latency_e2e'] * 1e3)}ms"
                         f"<= {int(tau * 1e3)}ms"))
    rows.append(("fig12.summary", 0.0,
                 f"avg_energy_reduction={fmt(100 * (1 - geomean(e_ratios)))}%"
                 f" avg_energyx$_reduction="
                 f"{fmt(100 * (1 - geomean(ec_ratios)))}%"
                 f" (paper: 10.53% energy, 25.54% energyx$)"))
    return rows
