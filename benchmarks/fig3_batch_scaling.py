"""Paper Fig. 3: batch-scaling heterogeneity at the operator level
(Insight 2).  Batch-agnostic operators (attention) gain no throughput
from batching; batch-sensitive operators (projections/MLP) gain until
they go compute-bound.
"""
from __future__ import annotations

from repro.core.chiplets import Chiplet
from repro.core.memory import HBM3
from repro.core.operators import OPT_66B, lm_layer_operators
from repro.core.perfmodel import StageConfig, evaluate_group

from .common import fmt, timed

BATCHES = (1, 2, 4, 8, 16, 32)


def run():
    ops = {o.name: o for o in
           lm_layer_operators(OPT_66B, seq=1, cache_len=2048,
                              phase="decode")}
    chip = Chiplet("WS", 3, 4, "2.5D")

    def throughputs(op):
        out = []
        for b in BATCHES:
            cfg = StageConfig(chiplet=chip, memory=HBM3, mem_units=2,
                              tp=1, batch=b)
            so = evaluate_group([op], cfg)
            out.append(1.0 / so.t_cmp)       # samples/s at that batch
        return out

    rows = []
    scaling = {}
    t_total = 0.0
    for name in ("attention", "qkv_proj", "mlp"):
        tp, t_us = timed(throughputs, ops[name])
        t_total += t_us
        gain = tp[-1] / tp[0]
        scaling[name] = gain
        rows.append((f"fig3.decode.{name}", t_us,
                     f"throughput_gain_b32={fmt(gain)}x "
                     f"tps={'/'.join(fmt(x) for x in tp)}"))
    ratio = scaling["mlp"] / max(scaling["attention"], 1e-9)
    rows.append(("fig3.summary", t_total,
                 f"batch_sensitive_vs_agnostic_gain_ratio={fmt(ratio)}x"
                 f" (paper: projections scale, attention does not)"))
    assert scaling["attention"] < 1.5, "attention should be batch-agnostic"
    assert scaling["mlp"] > 4.0, "mlp should be batch-sensitive"
    return rows
