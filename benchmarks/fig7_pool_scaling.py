"""Paper Fig. 7: chiplet pool size sweep — pools "optimized for different
performance metrics" (paper caption): per metric, SA-search pools of
increasing size and report that metric's curve.  Diminishing returns past
~8 SKUs = the ecosystem sweet spot balancing performance and NRE.
"""
from __future__ import annotations

from repro.core import operators
from repro.core.chiplets import default_pool
from repro.core.pool import SAConfig, anneal_pool

from .common import FAST, fmt, ga_budget, geomean, timed

POOL_SIZES = (1, 4, 8, 12) if not FAST else (1, 4, 8)
NETWORKS = ["resnet50", "replknet31b", "vit_b16", "opt66b_prefill",
            "opt66b_decode"]
METRICS = ("energy", "edp", "energy_cost", "edp_cost")


def run():
    graphs = {n: g for n, g in operators.paper_workloads(seq=2048).items()
              if n in NETWORKS}
    rows = []
    curves: dict[str, dict[int, float]] = {m: {} for m in METRICS}
    for metric in METRICS:
        prev_pool = None
        for k in POOL_SIZES:
            def solve(k=k, prev=prev_pool, metric=metric):
                init = list(prev) if prev else []
                for c in default_pool():
                    if len(init) >= k:
                        break
                    if c not in init:
                        init.append(c)
                sa = SAConfig(iterations=4 if not FAST else 2,
                              inner_ga=ga_budget(pop=6, gens=1))
                res = anneal_pool(graphs, objective=metric, pool_size=k,
                                  cfg=sa, init=init[:k],
                                  final_ga=ga_budget(pop=8, gens=3))
                vals = [fr.solution.metrics()[metric]
                        for fr in res.per_network.values()]
                return res, geomean(vals)

            (res, val), t_us = timed(solve)
            # dominance guard: a k-pool contains the (k-1)-pool optimum
            prev_vals = curves[metric]
            if prev_vals and val > min(prev_vals.values()):
                val = min(prev_vals.values())
            else:
                prev_pool = res.pool
            curves[metric][k] = val
            rows.append((f"fig7.{metric}.pool{k}", t_us, f"{fmt(val)}"))
    gains = {m: 100 * (1 - curves[m][8] / curves[m][POOL_SIZES[0]])
             for m in METRICS}
    within = {m: 100 * (curves[m][8] / min(curves[m].values()) - 1)
              for m in METRICS}
    rows.append(("fig7.summary", sum(r[1] for r in rows),
                 "pool8_vs_pool1_improvement:"
                 + ",".join(f" {m}={fmt(gains[m])}%" for m in METRICS)
                 + " | pool8_within_best:"
                 + ",".join(f" {m}={fmt(within[m])}%" for m in METRICS)
                 + " (paper: 8 chiplets is the sweet spot)"))
    return rows
