"""Paper Table 2: TTFT vs goodput trade-off (Insight 3).

Three GPT-66B prefill configurations:
  no-batching  — B=1, latency-lean mapping;
  batching     — B=8 uniform (goodput via batching, TTFT blows up);
  hetero       — operator-level disaggregation: per-operator batch +
                 right-sized chiplets decouple goodput from latency.
Reports TTFT, deployed-FLOPs utilization, and relative cost/token.
"""
from __future__ import annotations

from repro.core import operators
from repro.core.chiplets import default_pool
from repro.core.fusion import Requirement, optimize_fusion

from .common import fmt, ga_budget, timed, utilization


def run():
    g = operators.paper_workloads(seq=2048)["opt66b_prefill"]
    pool = default_pool()
    from repro.core.codesign import best_homogeneous_design

    # no-batching / batching run on the SAME homogeneous accelerator
    # (one SKU), as the paper's Table 2 does; hetero is operator-level
    # disaggregation constrained to no-batching's TTFT.
    def solve_homog(fixed_batch):
        d = best_homogeneous_design(
            g, objective="edp",
            ga=ga_budget(pop=4, gens=1, fixed_batch=fixed_batch))
        return d.fusion

    (nb, t1) = timed(solve_homog, 1)
    (bat, t2) = timed(solve_homog, 16)

    # Request-level TTFT of the no-batching design (sum of per-stage
    # batch-pass latencies).
    def request_ttft(sol):
        return sum(o.t_cmp * o.cfg.batch * o.repeat for o in sol.stages)

    ttft0 = request_ttft(nb.solution)

    # Hetero: operator-level disaggregation under a per-stage latency
    # envelope — each stage's batch-pass must fit its share of the TTFT
    # budget, so batch-sensitive stages may batch only as far as their
    # envelope allows, while attention gets right-sized chiplets instead.
    def solve_hetero():
        import repro.core.fusion as F
        from repro.core import costmodel
        from repro.core.convexhull import (default_latency_grid,
                                           solve_pipeline)
        from repro.core.perfmodel import (enumerate_stage_options,
                                          scale_option)
        seed = F._roofline_seed(g, pool, fuse=True)
        groups = F.groups_from_genome(g, seed)
        n_st = sum(gr.repeat for gr in groups)
        total_flops = sum(sum(o.flops for o in gr.ops) * gr.repeat
                          for gr in groups)
        budget_total = 1.05 * ttft0
        opts = []
        for gr in groups:
            # per-instance envelope: half work-proportional, half uniform
            fshare = (sum(o.flops for o in gr.ops) * gr.repeat
                      / max(total_flops, 1e-30))
            share = 0.5 * fshare + 0.5 * gr.repeat / n_st
            budget = budget_total * share / gr.repeat
            raw = enumerate_stage_options(gr.ops, pool, name=gr.name)
            priced = costmodel.price_stage_options(raw)
            keep = [scale_option(o, gr.repeat) for o in priced
                    if o.t_cmp * o.cfg.batch <= budget]
            if not keep:   # envelope impossible: stay latency-lean (B<=2)
                keep = [scale_option(o, gr.repeat) for o in priced
                        if o.cfg.batch <= 2]
            opts.append(keep)
        grid = default_latency_grid(opts)
        return solve_pipeline(opts, grid, objective="energy_cost",
                              n_stages=n_st)

    (het_sol, t3) = timed(solve_hetero)

    class _R:          # match FusionResult shape for report()
        solution = het_sol
    het = _R()

    def report(tag, res, t_us):
        sol = res.solution
        # REQUEST-level TTFT: sum of per-stage batch-pass latencies
        # (a stage running batch B holds a request for ~t_cmp*B).
        ttft = sum(o.t_cmp * o.cfg.batch * o.repeat for o in sol.stages)
        util = utilization(sol)
        cpt = sol.metrics()["energy_cost"]
        return tag, ttft, util, cpt, t_us

    rows_raw = [report("no_batching", nb, t1),
                report("batching", bat, t2),
                report("hetero", het, t3)]
    base_cpt = rows_raw[0][3]
    rows = []
    for tag, ttft, util, cpt, t_us in rows_raw:
        rows.append((f"table2.{tag}", t_us,
                     f"ttft={fmt(ttft)}s util={fmt(100 * util)}%"
                     f" rel_cost_per_token={fmt(cpt / base_cpt)}"))
    nb_ttft, bat_ttft, het_ttft = (r[1] for r in rows_raw)
    nb_u, bat_u, het_u = (r[2] for r in rows_raw)
    rows.append(("table2.summary", t1 + t2 + t3,
                 f"batching_ttft_blowup={fmt(bat_ttft / nb_ttft)}x"
                 f" hetero_ttft_ratio={fmt(het_ttft / nb_ttft)}"
                 f" hetero_util_gain={fmt(het_u / max(nb_u, 1e-9))}x"
                 f" (paper: hetero keeps TTFT while raising util"
                 f" 23.8%->88.6%)"))
    return rows
