# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows; REPRO_BENCH_FAST=1 trims the DSE budgets for quick runs.
from __future__ import annotations

import argparse
import sys
import traceback

MODULES = [
    "table1_internetwork",   # Table 1
    "fig2_hetero_memory",    # Figure 2
    "fig3_batch_scaling",    # Figure 3
    "table2_ttft",           # Table 2
    "table3_features",       # Table 3 (capability self-check)
    "fig7_pool_scaling",     # Figure 7
    "fig8_paradigms",        # Figure 8
    "fig9_cost_breakdown",   # Figure 9
    "fig10_llm_serving",     # Figure 10
    "fig11_specdec",         # Figure 11
    "fig12_av",              # Figure 12
    "roofline",              # §Roofline (from dry-run artifacts)
    "bench_codesign_search",  # engine speedup: cached/vectorized vs seed
    "bench_budget_scaling",  # search quality vs budget (monotone axes)
    "bench_batch_solve",     # generation-batched Layer-3 vs per-genome
    "bench_serving",         # compacted sub-batch decode vs PR-4 emulation
    "bench_cluster",         # multi-replica scale-out + int8 KV capacity
    "bench_chaos",           # goodput + token exactness under fault script
    "bench_specdec",         # live in-engine spec-decode vs target-only
]


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", nargs="*", default=None,
                   help="subset of benchmark modules to run")
    args = p.parse_args()
    mods = args.only or MODULES
    print("name,us_per_call,derived")
    failed = []
    for name in mods:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.1f},{derived}")
                sys.stdout.flush()
        except Exception:  # noqa: BLE001 — report and continue
            failed.append(name)
            print(f"{name}.ERROR,0.0,{traceback.format_exc(limit=3)!r}")
    if failed:
        # Nonzero exit so CI sees benchmark breakage; the per-module
        # ERROR rows above carry the tracebacks.
        print(f"benchmarks failed: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
