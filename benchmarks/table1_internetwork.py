"""Paper Table 1: inter-network accelerator performance penalty matrix.

Optimize a (homogeneous-tile, as in the paper caption) accelerator for
each column network, then evaluate every row network on it.  Cells are
(energy, EDP) normalized to the row network's own optimal accelerator;
off-diagonal >= 1 demonstrates "one size fits none" (Insight 4).
"""
from __future__ import annotations

from repro.core import operators
from repro.core.chiplets import default_pool
from repro.core.codesign import best_homogeneous_design, design_for_network
from repro.core.fusion import GAConfig, Requirement, optimize_fusion

from .common import fmt, ga_budget, timed

NETWORKS = ["replknet31b", "resnet50", "opt66b_prefill_b1",
            "opt66b_decode_b1", "opt66b_prefill_b4"]


def _graphs():
    g = operators.paper_workloads(seq=2048)
    return {
        "replknet31b": (g["replknet31b"], None),
        "resnet50": (g["resnet50"], None),
        "opt66b_prefill_b1": (g["opt66b_prefill"], 1),
        "opt66b_decode_b1": (g["opt66b_decode"], 1),
        "opt66b_prefill_b4": (g["opt66b_prefill"], 4),
    }


def run():
    graphs = _graphs()
    designs = {}

    def opt_for(name):
        graph, b = graphs[name]
        return best_homogeneous_design(
            graph, objective="edp",
            ga=ga_budget(pop=6, gens=2, fixed_batch=b))

    (_, t_us) = timed(lambda: [designs.update({n: opt_for(n)})
                               for n in NETWORKS])

    def accel_of(design):
        """The fixed accelerator an alien network must run on: the SKU,
        the memory system, and the batching regime chosen for its own
        network (only the software mapping may adapt)."""
        st = design.fusion.solution.stages
        sku = st[0].cfg.chiplet
        mem = st[0].cfg.memory
        batch = max(o.cfg.batch for o in st)
        return sku, mem, batch

    import repro.core.fusion as F
    from repro.core.convexhull import default_latency_grid, solve_pipeline
    from repro.core import costmodel
    from repro.core.perfmodel import enumerate_stage_options, scale_option

    def run_on(graph, b_row, sku, mem, batch):
        """Evaluate `graph` on the fixed accelerator (SKU+mem+batch)."""
        b_eff = b_row if b_row is not None else batch
        seed = F._roofline_seed(graph, [sku], fuse=True)
        groups = F.groups_from_genome(graph, seed)
        opts = []
        for gr in groups:
            raw = enumerate_stage_options(gr.ops, [sku], memories=(mem,),
                                          fixed_batch=b_eff, tps=(1, 2),
                                          name=gr.name)
            opts.append([scale_option(o, gr.repeat)
                         for o in costmodel.price_stage_options(raw)])
        grid = default_latency_grid(opts)
        return solve_pipeline(opts, grid, objective="edp",
                              n_stages=sum(g.repeat for g in groups))

    rows = []
    e_pen, edp_pen = [], []
    for row in NETWORKS:
        graph, b = graphs[row]
        own = run_on(graph, b, *accel_of(designs[row]))
        for col in NETWORKS:
            sol = run_on(graph, b, *accel_of(designs[col]))
            m, mo = sol.metrics(), own.metrics()
            e_ratio = m["energy"] / mo["energy"]
            edp_ratio = m["edp"] / mo["edp"]
            if row != col:
                e_pen.append(max(e_ratio, 1.0))
                edp_pen.append(max(edp_ratio, 1.0))
            rows.append((f"table1.{row}@{col}", t_us / 25,
                         f"energy_ratio={fmt(e_ratio)}"
                         f" edp_ratio={fmt(edp_ratio)}"))
    import statistics
    rows.append(("table1.summary", t_us,
                 f"mean_offdiag_energy_penalty="
                 f"{fmt(statistics.mean(e_pen))}x"
                 f" max_offdiag_edp_penalty={fmt(max(edp_pen))}x"
                 f" (paper: up to 41x EDP degradation cross-network)"))
    return rows
