"""Generation-eval throughput: fixed-seed wall-clock for evaluating one
GA generation (option enumeration + Layer-3 solves), three ways:

  * pr3    — the previous engine's per-genome path, reconstructed from
    the retained object APIs: per-(group, SKU) StageOption tuples,
    eagerly built StageOptionSet columns, a latency grid recomputed per
    fusion plan, and one `solve_pipeline` call per plan;
  * scalar — this engine's column caches but a per-genome
    `evaluate_genome` loop (what MOZART_BATCH_SOLVE=0 runs);
  * batched — `fusion.evaluate_genomes`: one prefetch + ONE
    `convexhull.solve_pipeline_batch` call for the whole generation.

All three must produce identical solutions (asserted).  The gate in
benchmarks/compare.py holds `speedup_vs_pr3` (batched vs pr3) above the
baseline threshold.  Run as a module
(`PYTHONPATH=src python -m benchmarks.bench_batch_solve`) or via
benchmarks/run.py.
"""

from __future__ import annotations

import random
import time

from repro.core import costmodel, engine, operators
from repro.core.chiplets import default_pool
from repro.core.convexhull import default_latency_grid, solve_pipeline
from repro.core.fusion import (
    GAConfig,
    Requirement,
    _mutate,
    evaluate_genome,
    evaluate_genomes,
    groups_from_genome,
    initial_population,
)
from repro.core.perfmodel import StageOptionSet, enumerate_stage_options_by_chiplet

from .common import FAST, write_bench_json

N_GENOMES = 12 if FAST else 24
REPEATS = 3 if FAST else 5


def _generation():
    graph = operators.paper_workloads(seq=512)["resnet50"]
    pool = default_pool()[:4]
    cfg = GAConfig(population=10, generations=10)
    rng = random.Random(0)
    base = initial_population(graph, pool, cfg)
    genomes = list(base)
    while len(genomes) < N_GENOMES:
        genomes.append(_mutate(rng.choice(base), rng, 0.2))
    return graph, pool, cfg, genomes


def _pr3_generation(graph, pool, cfg, genomes, req):
    """The PR-3 engine's generation evaluation, op for op: object-tuple
    option cache, eager column build, per-plan grid, per-plan solve."""
    cache: dict[tuple, tuple] = {}
    batches = tuple(cfg.batches)
    sols: dict[tuple, object] = {}
    for genome in genomes:
        groups = groups_from_genome(graph, genome)
        key = tuple(groups)
        if key in sols:
            continue
        options = []
        for gr in groups:
            opts: list = []
            for c in pool:
                k = (gr.ops, gr.repeat, c, gr.memory, cfg.fixed_batch, batches, gr.name)
                got = cache.get(k)
                if got is None:
                    got = enumerate_stage_options_by_chiplet(
                        gr.ops,
                        (c,),
                        memories=(gr.memory,),
                        batches=batches,
                        name=gr.name,
                        fixed_batch=cfg.fixed_batch,
                        cost_fn=costmodel.stage_hw_cost,
                        repeat=gr.repeat,
                    )[c]
                    cache[k] = got
                opts.extend(got)
            s = StageOptionSet(opts)
            s.columns()
            options.append(s)
        if any(not o for o in options):
            sols[key] = None
            continue
        grid = default_latency_grid(options, n=cfg.latency_points)
        n_stages = sum(x.repeat for x in groups)
        sols[key] = solve_pipeline(
            options, grid, objective="energy", max_e2e=req.max_e2e, n_stages=n_stages
        )
    return {g: sols[tuple(groups_from_genome(graph, g))] for g in genomes}


def _scalar_generation(graph, pool, cfg, genomes, req):
    sc: dict = {}
    out = {
        g: evaluate_genome(graph, g, pool, "energy", req, cfg, _solution_cache=sc)
        for g in genomes
    }
    return {g: None if r is None else r.solution for g, r in out.items()}


def _batched_generation(graph, pool, cfg, genomes, req):
    out = evaluate_genomes(graph, genomes, pool, "energy", req, cfg, {})
    return {g: None if r is None else r.solution for g, r in out.items()}


def _time_arm(fn, args):
    best = float("inf")
    out = None
    for _ in range(REPEATS):
        engine.clear_all_caches()
        t0 = time.perf_counter()
        out = fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6, out


def run():
    graph, pool, cfg, genomes = _generation()
    req = Requirement()
    args = (graph, pool, cfg, genomes, req)
    us_pr3, sols_pr3 = _time_arm(_pr3_generation, args)
    us_scalar, sols_scalar = _time_arm(_scalar_generation, args)
    us_batched, sols_batched = _time_arm(_batched_generation, args)
    engine.clear_all_caches()

    def fingerprint(sols):
        return {
            g: None if s is None else (s.value, s.T, tuple(o.cfg.label for o in s.stages))
            for g, s in sols.items()
        }

    if not (fingerprint(sols_pr3) == fingerprint(sols_scalar) == fingerprint(sols_batched)):
        raise AssertionError("generation evaluation paths disagree on solutions")

    vs_pr3 = us_pr3 / max(us_batched, 1.0)
    vs_scalar = us_scalar / max(us_batched, 1.0)
    write_bench_json(
        "batch_solve",
        {
            "pr3_us": round(us_pr3, 1),
            "scalar_us": round(us_scalar, 1),
            "batched_us": round(us_batched, 1),
            "speedup_vs_pr3": round(vs_pr3, 3),
            "speedup_vs_scalar": round(vs_scalar, 3),
            "identical_solutions": True,  # asserted above
            "n_genomes": len(genomes),
            "repeats": REPEATS,
        },
    )
    return [
        ("batch_solve.pr3_generation_eval", us_pr3, f"n_genomes={len(genomes)}"),
        ("batch_solve.scalar_loop", us_scalar, f"{vs_scalar:.2f}x_slower_than_batched"),
        ("batch_solve.batched", us_batched, f"{vs_pr3:.2f}x_vs_pr3 identical_solutions=True"),
    ]


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
