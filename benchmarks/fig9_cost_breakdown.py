"""Paper Fig. 9: system cost structure vs manufacturing volume and
integration strategy (ReplkNet31B accelerator; 200 target networks).

Strategies:
  monolithic      — one big die per network design, full NRE each;
  bespoke_chiplets— per-network custom chiplets, no reuse;
  chiplet_pool    — Mozart 8-SKU pool amortized across all 200 networks.
NRE dominates at small volume; pool reuse collapses it.
"""
from __future__ import annotations

from repro.core import operators
from repro.core.chiplets import default_pool
from repro.core.costmodel import system_cost
from repro.core.fusion import optimize_fusion

from .common import fmt, ga_budget, timed

VOLUMES = (1e6, 2e6, 3e6)
N_NETWORKS = 200


def run():
    g = operators.paper_workloads(seq=2048)["replknet31b"]
    pool = default_pool()

    res, t_us = timed(optimize_fusion, g, pool, objective="energy",
                      cfg=ga_budget(pop=8, gens=3))
    stages = res.solution.stages
    # every pool SKU is assumed reused by all 200 network designs
    reuse = {o.cfg.chiplet.label: N_NETWORKS for o in stages}

    rows = []

    def silicon(c):
        """Accelerator silicon cost/unit: die + packaging + NRE.  The DRAM
        bill is identical across integration strategies (same memory
        system), so it is reported once and excluded from the comparison
        — matching Fig. 9's 'die and packaging remain stable' framing."""
        return c.die + c.packaging + c.nre_per_unit

    for vol in VOLUMES:
        mono = system_cost(stages, volume=vol, monolithic=True)
        bespoke = system_cost(stages, volume=vol, n_networks_sharing={})
        poolc = system_cost(stages, volume=vol, n_networks_sharing=reuse)
        for tag, c in (("monolithic", mono), ("bespoke_chiplets", bespoke),
                       ("chiplet_pool", poolc)):
            rows.append((f"fig9.{tag}.vol{int(vol / 1e6)}M", t_us / 9,
                         f"silicon=${fmt(silicon(c))}"
                         f" die=${fmt(c.die)} pkg=${fmt(c.packaging)}"
                         f" nre/unit=${fmt(c.nre_per_unit)}"
                         f" [dram=${fmt(c.memory)} strategy-invariant]"))
    m1 = system_cost(stages, volume=VOLUMES[0], monolithic=True)
    b1 = system_cost(stages, volume=VOLUMES[0], n_networks_sharing={})
    p1 = system_cost(stages, volume=VOLUMES[0], n_networks_sharing=reuse)
    rows.append(("fig9.summary", t_us,
                 f"pool_vs_bespoke_silicon@1M="
                 f"{fmt(silicon(p1) / silicon(b1))}"
                 f" nre_share_bespoke@1M="
                 f"{fmt(100 * b1.nre_per_unit / silicon(b1))}%"
                 f" nre_share_pool@1M="
                 f"{fmt(100 * p1.nre_per_unit / silicon(p1))}%"
                 f" (paper: NRE dominates at small volume; pool reuse"
                 f" collapses it)"))
    return rows
