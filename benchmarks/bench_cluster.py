"""Multi-replica serving cluster: scale-out throughput, int8-KV
capacity, and open-loop latency.

Three measurements over the shared Zipf prompt mix
(`repro.serving.workload`), fixed seeds throughout:

* SCALE-OUT — one engine with 8 slots sharing a single constrained page
  pool versus a 4-replica cluster with the SAME total slot count (2 per
  replica) where each replica owns that pool size (the paper's fleet
  story: one BASIC's HBM is fixed, scale-out multiplies aggregate HBM).
  The workload's long sequences starve the single pool: it sustains only
  ~2-3 of the 8 slots, so the full-width lock-step decode spends most of
  its lanes on duplicate padding, and page pressure preempts slots whose
  resume re-prefills the whole accumulated sequence (a bucket-64
  forward).  Each 2-slot replica's demand fits its own pool, so its
  narrow decode stays fully live.  Both runs must finish every request
  with identical per-request token counts (asserted: the useful work is
  equal; only padding and re-prefill waste differ); the gate in
  benchmarks/compare.py holds ``speedup_multi_vs_single`` above the
  baseline threshold (>= 1.5x measured aggregate throughput — per the
  ROADMAP's parquet-aggregator warning, the gate is on measured
  tokens/s, never on replica count).
* INT8 KV — the same trace through a quantized and an f32 paged engine:
  reports the token-match fraction (token-level, not bit-level, parity)
  and the capacity ratio (pages a fixed HBM byte budget buys, int8 vs
  f32, via `serving.quant.pages_for_byte_budget`) — gated at >= 2x.
* OPEN LOOP — a Poisson arrival schedule (`LoadGenerator`) driven
  through the cluster; reports aggregate TTFT/TPOT p50/p99, gated by
  loose latency ceilings.

Run as a module (``PYTHONPATH=src python -m benchmarks.bench_cluster``)
or via benchmarks/run.py.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.models import api
from repro.models.config import ModelConfig
from repro.serving import quant as kvq
from repro.serving import workload
from repro.serving.cluster import LoadGenerator, ServingCluster
from repro.serving.engine import ServingEngine

from .common import write_bench_json

CFG = ModelConfig(
    name="bench-cluster",
    n_layers=4,
    d_model=256,
    n_heads=8,
    kv_heads=4,
    head_dim=32,
    d_ff=1024,
    vocab=512,
    dtype="float32",
    param_dtype="float32",
    scan_min_layers=2,
)
MAX_LEN = 128
PAGE_SIZE = 8
# one BASIC's HBM: 17 allocatable pages (+1 null).  Requests grow to
# 7-9 pages each, so the single replica's 8 slots (demand ~60 pages)
# sustain only ~2-3 live lanes of its 8-wide lock-step decode plus
# constant preemption churn, while a 2-slot replica (demand <= 18) fits
NUM_PAGES = 18
TOTAL_SLOTS = 8
N_REPLICAS = 4
N_REQUESTS = 8
MAX_NEW = 32
# medium prompts (bucket-32 admission) decoding out to 56-72 tokens:
# long enough that a preempted slot's resume is a bucket-64 re-prefill
BANDS = ((24, 40),)
# the int8 parity trace decodes fewer tokens over longer prompts: the
# pressure workload's geometry is tuned for churn, not for measuring
# quantization drift
PARITY_BANDS = ((8, 16), (17, 32))
PARITY_MAX_NEW = 8
RATE = 200.0


def _trace(seed: int, n: int = N_REQUESTS, bands=BANDS, max_new: int = MAX_NEW):
    rng = np.random.default_rng(seed)
    return workload.zipf_mix_requests(
        rng, n, CFG.vocab, bands=bands, max_new_tokens=max_new
    )


def _run_single(params, seed: int):
    eng = ServingEngine(
        CFG,
        params,
        max_batch=TOTAL_SLOTS,
        max_len=MAX_LEN,
        page_size=PAGE_SIZE,
        num_pages=NUM_PAGES,
        paged=True,
    )
    reqs = _trace(seed)
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    return reqs, eng.stats, dt


def _run_cluster(params, seed: int):
    cl = ServingCluster(
        CFG,
        params,
        n_replicas=N_REPLICAS,
        router="round_robin",
        max_batch=TOTAL_SLOTS // N_REPLICAS,
        max_len=MAX_LEN,
        page_size=PAGE_SIZE,
        num_pages=NUM_PAGES,
        paged=True,
    )
    reqs = _trace(seed)
    for r in reqs:
        cl.submit(r)
    t0 = time.perf_counter()
    cl.run()
    dt = time.perf_counter() - t0
    return reqs, cl, dt


def _token_match_fraction(a, b) -> float:
    """Fraction of positions where two runs' token streams agree
    (prefix-wise per request) — the int8 parity metric."""
    total = matched = 0
    for ra, rb in zip(a, b):
        n = max(len(ra.out_tokens), len(rb.out_tokens))
        total += n
        for x, y in zip(ra.out_tokens, rb.out_tokens):
            if x != y:
                break
            matched += 1
    return matched / max(total, 1)


def run():
    params = api.init_params(CFG, jax.random.PRNGKey(0))
    rows = []

    # -- scale-out under page-pool pressure (warmup pass, then timed) --
    _run_single(params, seed=3)
    _run_cluster(params, seed=3)
    s_reqs, s_stats, s_dt = _run_single(params, seed=3)
    c_reqs, cl, c_dt = _run_cluster(params, seed=3)
    assert all(r.done and r.finish_reason != "rejected" for r in s_reqs)
    assert all(r.done and r.finish_reason != "rejected" for r in c_reqs)
    equal_tokens = [len(r.out_tokens) for r in s_reqs] == [
        len(r.out_tokens) for r in c_reqs
    ]
    assert equal_tokens, "cluster and single runs emitted different token counts"
    c_sum = cl.metrics.summary(cl)
    tok_s_single = s_stats["tokens_out"] / max(s_dt, 1e-9)
    tok_s_cluster = c_sum["aggregate"]["tokens_out"] / max(c_dt, 1e-9)
    speedup = tok_s_cluster / max(tok_s_single, 1e-9)
    preempt_cluster = c_sum["aggregate"]["preemptions"]
    rows.append(
        (
            "cluster.scale_out",
            c_dt * 1e6 / max(len(c_reqs), 1),
            f"speedup={speedup:.2f}x tok_s {tok_s_single:.1f}->"
            f"{tok_s_cluster:.1f} preempt {s_stats['preemptions']}->"
            f"{preempt_cluster}",
        )
    )

    # -- int8 KV: token parity + capacity per HBM byte --
    parity_pages = 1 + TOTAL_SLOTS * (MAX_LEN // PAGE_SIZE)
    eng_kw = dict(
        max_batch=4, max_len=MAX_LEN, page_size=PAGE_SIZE,
        num_pages=parity_pages, paged=True,
    )
    runs = {}
    for name, q in (("f32", False), ("int8", True)):
        eng = ServingEngine(CFG, params, kv_quant=q, **eng_kw)
        reqs = _trace(seed=9, bands=PARITY_BANDS, max_new=PARITY_MAX_NEW)
        for r in reqs:
            eng.submit(r)
        eng.run()
        runs[name] = (reqs, eng)
    match_frac = _token_match_fraction(runs["f32"][0], runs["int8"][0])
    budget = runs["f32"][1].pool.page_nbytes * (parity_pages - 1)
    slots_f32 = kvq.pages_for_byte_budget(CFG, budget, PAGE_SIZE, quant=False)
    slots_int8 = kvq.pages_for_byte_budget(CFG, budget, PAGE_SIZE, quant=True)
    capacity_ratio = slots_int8 / max(slots_f32, 1)
    rows.append(
        (
            "cluster.kv_int8",
            0.0,
            f"token_match={match_frac:.3f} pages_per_budget "
            f"{slots_f32}->{slots_int8} ({capacity_ratio:.2f}x)",
        )
    )

    # -- open-loop Poisson drive through the cluster --
    lg = LoadGenerator(
        n_requests=N_REQUESTS,
        rate=RATE,
        vocab=CFG.vocab,
        seed=13,
        max_new_tokens=MAX_NEW,
        bands=BANDS,
    )
    cl2 = ServingCluster(
        CFG,
        params,
        n_replicas=N_REPLICAS,
        router="least_loaded",
        max_batch=TOTAL_SLOTS // N_REPLICAS,
        max_len=MAX_LEN,
        page_size=PAGE_SIZE,
        num_pages=NUM_PAGES,
        paged=True,
    )
    summary = cl2.drive(lg.schedule())
    agg = summary["aggregate"]
    rows.append(
        (
            "cluster.open_loop",
            0.0,
            f"router=least_loaded ttft_p99={agg['ttft_p99_ms']:.1f}ms "
            f"tpot_p99={agg['tpot_p99_ms']:.2f}ms "
            f"finished={agg['n_finished']}/{N_REQUESTS}",
        )
    )

    write_bench_json(
        "cluster",
        {
            "n_replicas": N_REPLICAS,
            "total_slots": TOTAL_SLOTS,
            "num_pages": NUM_PAGES,
            "page_size": PAGE_SIZE,
            "n_requests": N_REQUESTS,
            "max_new_tokens": MAX_NEW,
            "tok_s_single": tok_s_single,
            "tok_s_cluster": tok_s_cluster,
            "speedup_multi_vs_single": speedup,
            "equal_tokens": equal_tokens,
            "preemptions_single": s_stats["preemptions"],
            "preemptions_cluster": preempt_cluster,
            "quant_token_match_frac": match_frac,
            "quant_capacity_ratio": capacity_ratio,
            "quant_pages_f32": slots_f32,
            "quant_pages_int8": slots_int8,
            "open_loop_rate": RATE,
            "open_loop_finished": agg["n_finished"],
            "ttft_p50_ms": agg["ttft_p50_ms"],
            "ttft_p99_ms": agg["ttft_p99_ms"],
            "tpot_p50_ms": agg["tpot_p50_ms"],
            "tpot_p99_ms": agg["tpot_p99_ms"],
        },
    )
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
