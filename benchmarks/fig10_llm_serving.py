"""Paper Fig. 10 / §6.2.1: datacenter LLM serving — DistServe-style
phase-level heterogeneity + uniform batching vs +Mozart operator-level
heterogeneity + non-uniform batching, under chatbot QoS (TTFT 2.5s,
TPOT 0.15s; Table 5).

Paper claim: 15-19% prefill energy reduction, 35-39% E2E energyx$.
"""
from __future__ import annotations

from repro.core import operators, scenarios
from repro.core.chiplets import default_pool
from repro.core.fusion import Requirement, optimize_fusion

from .common import fmt, ga_budget, timed

N_DECODE_TOKENS = 256     # tokens decoded per request for E2E accounting


def _serve(graph, req, objective, fixed_batch, pop=8, gens=4):
    if fixed_batch is not None:
        # DistServe: PHASE-level heterogeneity — one SKU per phase,
        # uniform batching within the phase.
        from repro.core.codesign import best_homogeneous_design
        d = best_homogeneous_design(
            graph, objective=objective, req=req,
            ga=ga_budget(pop=pop, gens=gens, fixed_batch=fixed_batch))
        return d.fusion
    return optimize_fusion(graph, default_pool(), objective=objective,
                           req=req,
                           cfg=ga_budget(pop=pop, gens=gens,
                                         fixed_batch=fixed_batch))


def run():
    g = operators.paper_workloads(seq=2048)
    prefill, decode = g["opt66b_prefill"], g["opt66b_decode"]
    req_p = Requirement(e2e=scenarios.CHATBOT.ttft)
    req_d = Requirement(e2e=scenarios.CHATBOT.tpot)
    rows = []

    # DistServe: phase-level split, uniform batch per phase (B=4 prefill,
    # B=8 decode — uniform within the phase).
    (ds_p, t1) = timed(_serve, prefill, req_p, "energy_cost", 4)
    (ds_d, t2) = timed(_serve, decode, req_d, "energy_cost", 8)
    # +Mozart: operator-level batching (per-stage batch free).  The free-
    # batch space contains every uniform-batch point, so guard GA noise
    # with the dominance bound.
    (mz_p, t3) = timed(_serve, prefill, req_p, "energy_cost", None, 10, 5)
    (mz_d, t4) = timed(_serve, decode, req_d, "energy_cost", None, 10, 5)
    if mz_p.value > ds_p.value:
        mz_p = ds_p
    if mz_d.value > ds_d.value:
        mz_d = ds_d

    def e2e(p, d):
        mp, md = p.solution.metrics(), d.solution.metrics()
        return {k: mp[k] + N_DECODE_TOKENS * md[k]
                for k in ("energy", "energy_cost")}

    ds, mz = e2e(ds_p, ds_d), e2e(mz_p, mz_d)
    pe_red = 100 * (1 - mz_p.solution.metrics()["energy"]
                    / ds_p.solution.metrics()["energy"])
    e2e_ec_red = 100 * (1 - mz["energy_cost"] / ds["energy_cost"])
    e2e_e_red = 100 * (1 - mz["energy"] / ds["energy"])

    rows.append(("fig10.distserve.prefill", t1,
                 f"energy={fmt(ds_p.solution.metrics()['energy'])}J"
                 f" ttft={fmt(ds_p.solution.delay_e2e)}s"))
    rows.append(("fig10.mozart.prefill", t3,
                 f"energy={fmt(mz_p.solution.metrics()['energy'])}J"
                 f" ttft={fmt(mz_p.solution.delay_e2e)}s"))
    rows.append(("fig10.distserve.decode", t2,
                 f"energy/tok={fmt(ds_d.solution.metrics()['energy'])}J"
                 f" tpot={fmt(ds_d.solution.delay_e2e)}s"))
    rows.append(("fig10.mozart.decode", t4,
                 f"energy/tok={fmt(mz_d.solution.metrics()['energy'])}J"
                 f" tpot={fmt(mz_d.solution.delay_e2e)}s"))
    rows.append(("fig10.summary", t1 + t2 + t3 + t4,
                 f"prefill_energy_reduction={fmt(pe_red)}%"
                 f" e2e_energy_reduction={fmt(e2e_e_red)}%"
                 f" e2e_energyx$_reduction={fmt(e2e_ec_red)}%"
                 f" (paper: 15-19% prefill energy, 35-39% E2E energyx$)"))
    return rows
