"""Chaos harness: goodput and token-exactness under scripted faults.

The resilience layer's whole claim is that the serving fleet keeps doing
USEFUL work under churn: a fixed `ChaosSchedule` (kill, stall, NaN
injection, each paired with a recovery) is replayed against a 3-replica
cluster serving the same fixed-seed burst a fault-free reference run
serves, and four properties are measured and gated
(benchmarks/compare.py, "chaos" block of baselines.json):

* GOODPUT — deadline-respecting tokens/s under chaos must stay >=
  `min_goodput_frac` of the fault-free run's.  Deadlines here are
  deliberately generous (30-60 s on a sub-second workload) so the gate
  measures fault overhead — requeue re-prefills, quarantine scans,
  restarts — and not CI-runner jitter; `goodput_violations` is an
  independent recount pinned at zero.
* TOKEN EXACTNESS — every request finishes with the byte-identical
  token stream the fault-free run produced (greedy decode makes
  failover resume exact; a single divergent token fails the gate).
* WATCHDOG COVERAGE — the stall and the NaN faults are NOT cluster API
  calls, they are silent corruptions; the run must show the watchdog
  quarantined both (`min_quarantined`).
* TOTAL OUTAGE — a separate drill kills EVERY replica mid-flight:
  `run()` must return (not raise) with the stranded requests parked,
  and restarting the replicas must complete them token-exactly.

Two small drills complete the resilience surface: an already-expired
deadline must be SHED at admission (never decoded), and a
`retry_budget=0` failover must classify the bounced request as POISON
instead of requeueing it forever.

Run as a module (``PYTHONPATH=src python -m benchmarks.bench_chaos``)
or via benchmarks/run.py.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.models import api
from repro.models.config import ModelConfig
from repro.serving import workload
from repro.serving.cluster import ServingCluster
from repro.serving.engine import Request, ServingEngine
from repro.serving.resilience import (
    ChaosEvent,
    ChaosSchedule,
    Watchdog,
    goodput_tokens,
    goodput_violations,
)

from .common import write_bench_json

CFG = ModelConfig(
    name="bench-chaos",
    n_layers=4,
    d_model=256,
    n_heads=8,
    kv_heads=4,
    head_dim=32,
    d_ff=1024,
    vocab=512,
    dtype="float32",
    param_dtype="float32",
    scan_min_layers=2,
)
MAX_LEN = 128
PAGE_SIZE = 8
N_REPLICAS = 3
SLOTS_PER_REPLICA = 2
# per-replica pool sized for its slots: failures, not page pressure,
# should be the only source of preemption churn in this benchmark
NUM_PAGES = 1 + SLOTS_PER_REPLICA * (MAX_LEN // PAGE_SIZE)
N_REQUESTS = 12
MAX_NEW = 24
BANDS = ((6, 20),)
# generous SLO band (the workload finishes in well under a second):
# chaos must not make the gate flaky, only measurably slower
DEADLINE_BANDS = ((30.0, 60.0),)
TRACE_SEED = 17
# the watchdog bench config: quarantine a silent stall quickly so the
# scripted stall fault resolves within the run
STALL_STEPS = 6

# the fault script, keyed to cluster step offsets (deterministic): a
# clean kill early, a silent stall the watchdog must catch, a NaN'd KV
# page the decode guard must catch — each paired with a recovery.  The
# kill/stall/nan steps land while every replica still holds work.
CHAOS_EVENTS = (
    ChaosEvent(4, 0, "kill"),
    ChaosEvent(8, 2, "stall"),  # watchdog quarantines at ~8+STALL_STEPS
    ChaosEvent(16, 0, "restart"),
    ChaosEvent(22, 2, "restart"),
    ChaosEvent(26, 1, "nan"),  # guard flags, watchdog quarantines
    ChaosEvent(38, 1, "restart"),
)

# every engine in this benchmark (reference and cluster replicas) shares
# one geometry so token streams are comparable across drills
ENGINE_KW = dict(
    max_batch=SLOTS_PER_REPLICA,
    max_len=MAX_LEN,
    page_size=PAGE_SIZE,
    num_pages=NUM_PAGES,
    paged=True,
)


def _trace() -> list[Request]:
    rng = np.random.default_rng(TRACE_SEED)
    return workload.zipf_mix_requests(
        rng,
        N_REQUESTS,
        CFG.vocab,
        bands=BANDS,
        max_new_tokens=MAX_NEW,
        deadline_bands=DEADLINE_BANDS,
    )


def _cluster(params, **kw) -> ServingCluster:
    kw.setdefault("n_replicas", N_REPLICAS)
    kw.setdefault("watchdog", Watchdog(kw["n_replicas"], stall_steps=STALL_STEPS))
    return ServingCluster(CFG, params, router="round_robin", **ENGINE_KW, **kw)


def _burst(params, chaos: ChaosSchedule | None):
    """Submit the fixed trace as a burst and run to completion; returns
    (requests, cluster, wall_seconds, steps)."""
    cl = _cluster(params)
    reqs = _trace()
    for r in reqs:
        cl.submit(r)
    t0 = time.perf_counter()
    cl.run(chaos=chaos)
    dt = time.perf_counter() - t0
    return reqs, cl, dt, cl.stats["steps"]


def _tokens_exact(ref: list[Request], got: list[Request]) -> bool:
    return all(ra.out_tokens == rb.out_tokens for ra, rb in zip(ref, got))


def _outage_drill(params) -> dict:
    """Kill EVERY replica mid-flight: run() must hold (not raise), park
    the stranded work, and finish it token-exactly after restarts."""
    ref = ServingEngine(CFG, params, **ENGINE_KW)
    ref_reqs = _trace()[:4]
    for r in ref_reqs:
        ref.submit(r)
    ref.run()

    cl = _cluster(params, n_replicas=2)
    reqs = _trace()[:4]
    for r in reqs:
        cl.submit(r)
    for _ in range(3):
        cl.step()
    step = cl.stats["steps"]
    outage = ChaosSchedule([ChaosEvent(step, 0, "kill"), ChaosEvent(step, 1, "kill")])
    survived = True
    try:
        cl.run(chaos=outage)  # total outage: must return, never raise
    except Exception:  # noqa: BLE001 — surviving IS the measurement
        survived = False
    unrouted = len(cl.parked)
    held = sum(1 for r in reqs if not r.done)
    cl.restart_replica(0)
    cl.restart_replica(1)
    cl.run()
    return {
        "outage_survived": survived,
        "outage_unrouted": unrouted,
        "outage_held_requests": held,
        "outage_tokens_exact": bool(all(r.done for r in reqs) and _tokens_exact(ref_reqs, reqs)),
    }


def _shed_poison_drill(params) -> dict:
    """An expired deadline is shed at admission; a retry_budget=0
    failover classifies the bounced request as poison."""
    cl = _cluster(params, n_replicas=2, retry_budget=0)
    rng = np.random.default_rng(5)
    p0 = rng.integers(0, CFG.vocab, size=8).astype(np.int32)
    p1 = rng.integers(0, CFG.vocab, size=8).astype(np.int32)
    live = Request(rid=0, prompt=p0, max_new_tokens=4)
    # already expired relative to its submit time: admission must shed
    # it before it ever reaches a decode lane
    expired = Request(rid=1, prompt=p1, max_new_tokens=4, deadline_s=1e-9)
    cl.submit(live)
    cl.submit(expired)
    cl.step()
    cl.kill_replica(cl.assignment[live.rid])  # retries exhausted -> poison
    cl.run()
    summary = cl.metrics.summary(cl)["aggregate"]
    return {
        "shed": summary["shed"],
        "poisoned": summary["poisoned"],
        "shed_never_decoded": bool(expired.finish_reason == "shed" and not expired.out_tokens),
        "poison_classified": live.finish_reason == "poison",
    }


def run():
    params = api.init_params(CFG, jax.random.PRNGKey(0))

    # warm the jit caches (prefill buckets, decode widths, the finite
    # guard) so both timed runs below measure steady-state serving
    _burst(params, chaos=None)

    ref_reqs, _ref_cl, ref_dt, ref_steps = _burst(params, chaos=None)
    chaos = ChaosSchedule(CHAOS_EVENTS)
    got_reqs, cl, chaos_dt, chaos_steps = _burst(params, chaos)

    assert all(
        r.done and r.finish_reason not in ("shed", "poison", "rejected") for r in ref_reqs
    ), "fault-free reference failed to finish"
    assert all(r.done for r in got_reqs), "chaos run stranded requests"
    exact = _tokens_exact(ref_reqs, got_reqs)
    assert exact, "chaos run diverged from the fault-free token streams"
    assert len(chaos.fired) == len(CHAOS_EVENTS), "chaos script did not drain"

    summary = cl.metrics.summary(cl)["aggregate"]
    ref_good = goodput_tokens(ref_reqs)
    chaos_good = goodput_tokens(got_reqs)
    good_ref_tok_s = ref_good / max(ref_dt, 1e-9)
    good_chaos_tok_s = chaos_good / max(chaos_dt, 1e-9)
    goodput_frac = good_chaos_tok_s / max(good_ref_tok_s, 1e-9)
    violations = goodput_violations(got_reqs)

    drill = _outage_drill(params)
    shed_poison = _shed_poison_drill(params)

    rows = [
        (
            "chaos.goodput",
            chaos_dt * 1e6 / max(len(got_reqs), 1),
            f"goodput {good_ref_tok_s:.1f}->{good_chaos_tok_s:.1f} tok/s "
            f"({goodput_frac:.2f}x) steps {ref_steps}->{chaos_steps} "
            f"exact={exact}",
        ),
        (
            "chaos.watchdog",
            0.0,
            f"quarantined={summary['quarantined']} "
            f"restarts={summary['restarts']} "
            f"requeued={summary['requeued']} "
            f"events={[(s, i, why) for s, i, why in cl.watchdog.events]}",
        ),
        (
            "chaos.outage",
            0.0,
            f"survived={drill['outage_survived']} "
            f"unrouted={drill['outage_unrouted']} "
            f"exact_after_restart={drill['outage_tokens_exact']}",
        ),
        (
            "chaos.shed_poison",
            0.0,
            f"shed={shed_poison['shed']} poisoned={shed_poison['poisoned']}",
        ),
    ]

    write_bench_json(
        "chaos",
        {
            "n_replicas": N_REPLICAS,
            "n_requests": N_REQUESTS,
            "max_new_tokens": MAX_NEW,
            "chaos_events": [[e.step, e.replica, e.kind] for e in CHAOS_EVENTS],
            "goodput_ref_tokens": ref_good,
            "goodput_chaos_tokens": chaos_good,
            "goodput_ref_tok_s": good_ref_tok_s,
            "goodput_chaos_tok_s": good_chaos_tok_s,
            "goodput_frac": goodput_frac,
            "goodput_violations": violations,
            "completed_tokens_exact": bool(exact),
            "recovery_steps": chaos_steps - ref_steps,
            "recovery_s": chaos_dt - ref_dt,
            "quarantined": summary["quarantined"],
            "restarts": summary["restarts"],
            "requeued": summary["requeued"],
            "replica_failures": summary["replica_failures"],
            **drill,
            **shed_poison,
        },
    )
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
