"""§Roofline harness: renders the roofline table from the dry-run
artifacts in experiments/dryrun/*.json (see launch/dryrun.py).
"""
from __future__ import annotations

import glob
import json
import os

from .common import fmt

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_records(mesh: str | None = None) -> list[dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(fn) as f:
            r = json.load(f)
        if mesh is None or r.get("mesh") == mesh:
            recs.append(r)
    return recs


def run():
    rows = []
    recs = load_records()
    if not recs:
        return [("roofline.missing", 0.0,
                 "run `python -m repro.launch.dryrun --all --mesh both`")]
    n_ok = 0
    for r in recs:
        variant = f".{r['tag']}" if r.get("tag") else ""
        tag = f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}{variant}"
        if not r.get("ok"):
            rows.append((tag, 0.0, f"FAILED {r.get('error', '?')[:80]}"))
            continue
        n_ok += 1
        rf = r["roofline"]
        t = {"c": rf["t_compute"], "m": rf["t_memory"],
             "x": rf["t_collective"]}
        dom = max(t.values())
        frac = t["c"] / max(dom, 1e-30)     # compute fraction of roofline
        rows.append((tag, (r.get("lower_s", 0) + r.get("compile_s", 0))
                     * 1e6,
                     f"t_comp={fmt(t['c'])}s t_mem={fmt(t['m'])}s"
                     f" t_coll={fmt(t['x'])}s"
                     f" bottleneck={rf['bottleneck']}"
                     f" roofline_frac={fmt(frac)}"
                     f" mf_ratio={fmt(rf['model_flops_ratio'])}"))
    rows.append(("roofline.summary", 0.0,
                 f"cells_ok={n_ok}/{len(recs)}"))
    return rows
