"""Search-budget vs. result-quality study (the data behind the raised
default SA/GA budgets).

Two provably-monotone budget axes are swept with a fixed seed and cold
caches per level, recording best objective value and wall-clock:

  * Layer-1 SA iterations — with a fixed seed the SA trajectory of a
    longer run is a strict prefix-extension of a shorter one, so the
    best-so-far score is monotone non-increasing in the iteration budget;
  * Layer-2 GA generations — elitism carries the incumbent best genome
    into every next generation and the per-generation rng stream does not
    depend on the total generation count, so best fitness is monotone
    non-increasing in the generation budget.

The run fails (nonzero via benchmarks/run.py) if either series is not
monotone-or-flat, and writes BENCH_budget_scaling.json for the CI gate.
Run as `PYTHONPATH=src python -m benchmarks.bench_budget_scaling`.
"""
from __future__ import annotations

import time

from repro.core import engine, operators
from repro.core.chiplets import default_pool
from repro.core.fusion import GAConfig, optimize_fusion
from repro.core.pool import SAConfig, anneal_pool

from .common import FAST, fmt, write_bench_json

SA_LEVELS = (2, 4, 6) if FAST else (5, 10, 16, 24, 32)
GA_LEVELS = (1, 2, 4) if FAST else (5, 10, 16, 24, 32)


def _workload():
    ws = operators.paper_workloads(seq=512)
    return {"resnet50": ws["resnet50"],
            "opt66b_decode": ws["opt66b_decode"]}


def _sa_level(graphs, iterations: int) -> tuple[float, float]:
    """(best inner score, wall seconds) for one SA budget, cold caches.

    final_ga is deliberately None: the reported score is the inner-budget
    score the SA itself optimizes, which carries the prefix-monotonicity
    guarantee; a full-budget re-eval of a *different* best pool need not
    be monotone.
    """
    engine.clear_all_caches()
    sa = SAConfig(iterations=iterations,
                  inner_ga=GAConfig(population=6, generations=2))
    t0 = time.perf_counter()
    res = anneal_pool(graphs, objective="energy", pool_size=4, cfg=sa)
    return res.score, time.perf_counter() - t0


def _ga_level(graph, generations: int) -> tuple[float, float]:
    """(best fusion value, wall seconds) for one GA budget, cold caches."""
    engine.clear_all_caches()
    cfg = GAConfig(population=10, generations=generations)
    t0 = time.perf_counter()
    res = optimize_fusion(graph, default_pool(), objective="energy",
                          cfg=cfg)
    return (float("inf") if res is None else res.value,
            time.perf_counter() - t0)


def _monotone(scores: list[float]) -> bool:
    return all(b <= a for a, b in zip(scores, scores[1:]))


def run():
    graphs = _workload()
    rows = []

    sa_levels = []
    for it in SA_LEVELS:
        score, wall = _sa_level(graphs, it)
        sa_levels.append({"iterations": it, "score": score,
                          "wall_s": round(wall, 4)})
        rows.append((f"budget_scaling.sa_iter{it}", wall * 1e6,
                     f"score={fmt(score)}"))

    ga_levels = []
    for gen in GA_LEVELS:
        value, wall = _ga_level(graphs["opt66b_decode"], gen)
        ga_levels.append({"generations": gen, "value": value,
                          "wall_s": round(wall, 4)})
        rows.append((f"budget_scaling.ga_gen{gen}", wall * 1e6,
                     f"value={fmt(value)}"))

    monotone_sa = _monotone([lv["score"] for lv in sa_levels])
    monotone_ga = _monotone([lv["value"] for lv in ga_levels])
    defaults = {"sa_iterations": SAConfig().iterations,
                "ga_population": GAConfig().population,
                "ga_generations": GAConfig().generations}
    write_bench_json("budget_scaling", {
        "sa_levels": sa_levels, "ga_levels": ga_levels,
        "monotone_sa": monotone_sa, "monotone_ga": monotone_ga,
        "default_budget": defaults,
        "paper_budget": {"sa_iterations": 5, "ga_population": 10,
                         "ga_generations": 10},
    })
    rows.append(("budget_scaling.monotone", 0.0,
                 f"sa={monotone_sa} ga={monotone_ga} defaults={defaults}"))
    if not (monotone_sa and monotone_ga):
        raise AssertionError(
            f"budget scaling is not monotone-or-flat: sa={sa_levels} "
            f"ga={ga_levels}")
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
