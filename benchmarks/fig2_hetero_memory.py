"""Paper Fig. 2: heterogeneous memory maintains iso-latency while cutting
memory cost 25.4-96.7% (Insight 1: no memory wall, only compute-memory
mismatches).

Method: per network, build the all-HBM3 design (homogeneous memory,
paper's baseline) and record its latency; then let the GA allocate
memory types per fusion group under the SAME latency budget; report the
memory-$ reduction at iso-latency.
"""
from __future__ import annotations

import dataclasses

from repro.core import operators
from repro.core.chiplets import default_pool
from repro.core.fusion import (GAConfig, Requirement, optimize_fusion)
from repro.core.memory import HBM3, MEMORY_POOL

from .common import fmt, ga_budget, timed

NETWORKS = ["resnet50", "mobilenetv3", "efficientnet", "replknet31b",
            "vit_b16", "opt66b_prefill", "opt66b_decode"]


def _mem_cost(sol) -> float:
    # StageOptions arrive pre-scaled by `repeat` in hw cost; memory cost
    # here is recomputed per physical stage copy.
    return sum(o.cfg.memory.cost(o.cfg.mem_units) * o.repeat
               for o in sol.stages)


def run():
    graphs = operators.paper_workloads(seq=2048)
    pool = default_pool()
    rows = []
    reductions = []
    for name in NETWORKS:
        g = graphs[name]

        def solve():
            # Fix the fusion plan once (so the comparison is purely about
            # MEMORY ALLOCATION, as in Fig. 2), then:
            #   baseline: every group pinned to HBM3E;
            #   hetero:   per-group memory free, iso-latency (T <= T_hbm),
            #             cost-aware — compute-bound groups leave HBM.
            import repro.core.fusion as F
            from repro.core import costmodel
            from repro.core.convexhull import (default_latency_grid,
                                               solve_pipeline)
            from repro.core.memory import MEMORY_POOL
            from repro.core.perfmodel import (enumerate_stage_options,
                                              scale_option)
            base = optimize_fusion(g, pool, objective="energy",
                                   cfg=ga_budget(pop=6, gens=2))
            n_st = sum(gr.repeat for gr in base.groups)

            def options(memories):
                out = []
                for gr in base.groups:
                    raw = enumerate_stage_options(
                        gr.ops, pool, memories=memories, name=gr.name)
                    out.append([scale_option(o, gr.repeat) for o in
                                costmodel.price_stage_options(raw)])
                return out

            o_hbm = options((HBM3,))
            grid = default_latency_grid(o_hbm)
            hbm = solve_pipeline(o_hbm, grid, objective="energy",
                                 n_stages=n_st)
            o_all = options(tuple(MEMORY_POOL))
            het = solve_pipeline(o_all, grid, objective="energy_cost",
                                 max_interval=hbm.T, n_stages=n_st)
            return hbm, het

        (hbm, het), t_us = timed(solve)
        c0, c1 = _mem_cost(hbm), _mem_cost(het)
        lat_ratio = het.T / hbm.T
        red = 100.0 * (1 - c1 / max(c0, 1e-12))
        reductions.append(red)
        rows.append((f"fig2.{name}", t_us,
                     f"memcost_reduction={fmt(red)}%"
                     f" latency_ratio={fmt(lat_ratio)}"))
    rows.append(("fig2.summary", sum(r[1] for r in rows),
                 f"memcost_reduction_range="
                 f"{fmt(min(reductions))}%..{fmt(max(reductions))}%"
                 f" (paper: 25.4%..96.7% at iso-latency)"))
    return rows
