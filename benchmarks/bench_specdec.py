"""Live in-engine speculative decoding: measured tokens/s vs target-only.

`benchmarks/fig11_specdec.py` reproduces the paper's fig11 numbers
ANALYTICALLY (acceptance-rate algebra over chiplet latency models).
This benchmark measures the real thing: `serving.specdec.SpecDecodeEngine`
runs draft and target co-resident in one `ServingEngine` loop — the
draft proposes k tokens per iteration through a jitted scan, the target
verifies the whole window in one decode pass, and both KV caches rewind
to the accepted prefix.  The gate in benchmarks/compare.py holds the
MEASURED speedup over a plain target-only engine on the identical
fixed-seed request trace, with greedy outputs token-exact (asserted).

To isolate the serving-side speedup from draft-model quality, the pair
under test is `specdec.high_tar_pair`: the target's layers past n_draft
have their residual writes zeroed, so the draft is functionally the
target's own prefix and every proposal is accepted — acceptance is 1.0
by construction and the measurement is pure engine mechanics (scan
proposal, windowed verify, cache rewind) at the depth ratio
n_layers/n_draft.  A lossy draft only lowers acceptance below this
ceiling; fig11 covers that axis analytically.

Run as a module (``PYTHONPATH=src python -m benchmarks.bench_specdec``)
or via benchmarks/run.py.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.models import api
from repro.models.config import ModelConfig
from repro.serving.engine import Request, ServingEngine
from repro.serving.specdec import SpecDecodeEngine, high_tar_pair
from tools.mozart_check.tracecheck import CompileMonitor

from .common import write_bench_json

# deep target / shallow draft: the speedup scales with the depth ratio
# n_layers/n_draft
CFG = ModelConfig(
    name="bench-spec",
    n_layers=8,
    d_model=256,
    n_heads=8,
    kv_heads=4,
    head_dim=32,
    d_ff=512,
    vocab=512,
    dtype="float32",
    param_dtype="float32",
    scan_layers=False,
)
N_DRAFT = 2
MAX_BATCH = 4
MAX_LEN = 64
# FAST does not trim the trace: with fewer/shorter requests the spec
# engine's one-off double prefill (draft + target caches) dominates the
# wall clock and the measured speedup collapses into noise.  The full
# trace runs in a few seconds either way.
N_REQUESTS = 8
MAX_NEW = 24
# the bench pins its own draft window instead of reading MOZART_SPEC_K:
# the compare.py gate must not move when a developer exports the serving
# knob.  k=6 amortizes the per-iteration gather/verify/scatter overhead
# over more emitted tokens than serve's default k=4.
SPEC_K = 6


def _requests(rng):
    reqs = []
    for i in range(N_REQUESTS):
        plen = int(rng.integers(4, 12))
        reqs.append(
            Request(
                rid=i,
                prompt=rng.integers(0, CFG.vocab, size=plen).astype(np.int32),
                max_new_tokens=MAX_NEW,
            )
        )
    return reqs


def _run_target(tparams):
    eng = ServingEngine(CFG, tparams, max_batch=MAX_BATCH, max_len=MAX_LEN, paged=False)
    reqs = _requests(np.random.default_rng(3))
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    return [r.out_tokens for r in reqs], eng.stats, dt


def _run_spec(tparams, dcfg, dparams, k):
    eng = SpecDecodeEngine(
        CFG, tparams, dcfg, dparams, k=k, max_batch=MAX_BATCH, max_len=MAX_LEN
    )
    reqs = _requests(np.random.default_rng(3))
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    return [r.out_tokens for r in reqs], eng, dt


def run():
    params = api.init_params(CFG, jax.random.PRNGKey(0))
    tparams, dcfg, dparams = high_tar_pair(CFG, params, N_DRAFT)
    k = SPEC_K
    rows = []

    # warmup pass per engine compiles the jitted prefill/decode/propose/
    # verify executables; the timed second run is steady state and its
    # tracecheck count is gated at zero in compare.py
    _run_target(tparams)
    with CompileMonitor() as tgt_mon:
        tgt_toks, tgt_stats, tgt_dt = _run_target(tparams)
    tgt_tok_s = tgt_stats["tokens_out"] / max(tgt_dt, 1e-9)
    rows.append(
        (
            "specdec.target_only",
            tgt_dt * 1e6 / max(tgt_stats["decode_steps"], 1),
            f"tok_s={tgt_tok_s:.1f} steps={tgt_stats['decode_steps']} "
            f"recompiles={tgt_mon.count}",
        )
    )

    _run_spec(tparams, dcfg, dparams, k)
    with CompileMonitor() as spec_mon:
        spec_toks, spec_eng, spec_dt = _run_spec(tparams, dcfg, dparams, k)
    st = spec_eng.spec_stats
    spec_tok_s = spec_eng.stats["tokens_out"] / max(spec_dt, 1e-9)
    rows.append(
        (
            "specdec.live",
            spec_dt * 1e6 / max(spec_eng.stats["decode_steps"], 1),
            f"tok_s={spec_tok_s:.1f} iters={spec_eng.stats['decode_steps']} "
            f"accept={st.acceptance_rate:.2f} "
            f"tok_per_iter={st.tokens_per_iteration:.2f} "
            f"recompiles={spec_mon.count}",
        )
    )

    token_exact = spec_toks == tgt_toks
    assert token_exact, "speculative decode diverged from target-only greedy"
    speedup = spec_tok_s / max(tgt_tok_s, 1e-9)
    rows.append(
        (
            "specdec.speedup_vs_target",
            0.0,
            f"{speedup:.2f}x token_exact={token_exact} k={k} "
            f"depth_ratio={CFG.n_layers}/{N_DRAFT}",
        )
    )
    write_bench_json(
        "specdec",
        {
            "k": k,
            "n_draft": N_DRAFT,
            "n_layers": CFG.n_layers,
            "n_requests": N_REQUESTS,
            "max_new_tokens": MAX_NEW,
            "tok_s_target": tgt_tok_s,
            "tok_s_specdec": spec_tok_s,
            "speedup_specdec_vs_target": speedup,
            "token_exact": token_exact,
            "acceptance_rate": st.acceptance_rate,
            "tokens_per_iteration": st.tokens_per_iteration,
            "decode_steps_target": tgt_stats["decode_steps"],
            "verify_iterations": spec_eng.stats["decode_steps"],
            "steady_state_recompiles": {
                "target_only": tgt_mon.count,
                "specdec": spec_mon.count,
            },
        },
    )
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
