"""Shared building blocks: norms, RoPE / M-RoPE, attention implementations
(einsum, chunked memory-efficient, banded local/SWA), init helpers.

Everything is pure JAX — params are plain nested dicts of jnp arrays.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig

Params = Any   # nested dict pytree of arrays


# --- init -------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    s = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * s).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# --- norms ------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


def apply_norm(cfg: ModelConfig, p: Params, x):
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    if cfg.norm_impl == "fused":
        from repro.kernels.fused_norm import ops as nops
        return nops.fused_rmsnorm(x, p["scale"], eps=cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps)


def apply_norm_residual(cfg: ModelConfig, p: Params, res, delta):
    """Residual add + norm of the sum: returns (res + delta,
    norm(res + delta)).  With cfg.norm_impl == "fused" the add and the
    RMSNorm run as ONE Pallas kernel (the serving policy's fused_norm
    flag); otherwise this is the plain two-op reference."""
    if cfg.norm_impl == "fused" and cfg.norm != "layernorm":
        from repro.kernels.fused_norm import ops as nops
        return nops.fused_rmsnorm_residual(res, delta, p["scale"],
                                           eps=cfg.norm_eps)
    s = res + delta
    return s, apply_norm(cfg, p, s)


def init_norm(cfg: ModelConfig, key):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), cfg.jparam_dtype),
                "bias": jnp.zeros((cfg.d_model,), cfg.jparam_dtype)}
    return {"scale": jnp.zeros((cfg.d_model,), cfg.jparam_dtype)}


# --- dense MLP --------------------------------------------------------------

def mlp_block(cfg: ModelConfig, p: Params, x):
    """Dense (SwiGLU / GELU) MLP block.  cfg.mlp_impl == "fused" runs the
    whole block — both projections, gate activation, down-projection — as
    ONE Pallas kernel (the serving policy's fused_mlp flag); "dense" is
    the plain XLA path."""
    dt = cfg.jdtype
    if cfg.mlp_impl == "fused":
        from repro.kernels.fused_mlp import ops as mops
        wg = p["w_gate"].astype(dt) if cfg.swiglu else None
        return mops.fused_mlp(x, wg, p["w_in"].astype(dt),
                              p["w_out"].astype(dt), swiglu=cfg.swiglu)
    h = x @ p["w_in"].astype(dt)
    if cfg.swiglu:
        h = jax.nn.silu(x @ p["w_gate"].astype(dt)) * h
    else:
        h = jax.nn.gelu(h)
    return h @ p["w_out"].astype(dt)


# --- RoPE / M-RoPE ----------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float, rot_dim: int | None = None):
    """x: (B, S, H, hd); positions: (B, S) int32.  Rotates the first
    rot_dim dims (default all)."""
    hd = x.shape[-1]
    rd = rot_dim or hd
    freqs = rope_freqs(rd, theta)                          # (rd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,rd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    xr, rest = x[..., :rd], x[..., rd:]
    x1, x2 = xr[..., : rd // 2], xr[..., rd // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return jnp.concatenate([out.astype(x.dtype), rest], -1)


def apply_mrope(x, positions3, theta: float,
                sections: tuple[int, int, int]):
    """Qwen2-VL M-RoPE. positions3: (3, B, S) — temporal/height/width ids.
    Frequency slots are partitioned across the three position streams."""
    hd = x.shape[-1]
    n = hd // 2
    freqs = rope_freqs(hd, theta)                            # (n,)
    # section id per frequency slot
    sec = jnp.concatenate([jnp.full((s,), i) for i, s in enumerate(sections)])
    pos = jnp.stack([positions3[0], positions3[1], positions3[2]], 0)  # (3,B,S)
    pos_per_slot = jnp.take(pos, sec, axis=0)                # (n,B,S)
    ang = jnp.einsum("nbs,n->bsn", pos_per_slot.astype(jnp.float32), freqs)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :n], x[..., n:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# --- attention implementations ----------------------------------------------

NEG_INF = -1e30


def repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)) \
        .reshape(b, s, h * n_rep, d)


def attn_einsum(q, k, v, *, causal: bool, window: int | None,
                q_offset=0) -> jnp.ndarray:
    """Plain attention. q: (B,Sq,H,hd), k/v: (B,Sk,Hkv,hd)."""
    n_rep = q.shape[2] // k.shape[2]
    k, v = repeat_kv(k, n_rep), repeat_kv(v, n_rep)
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    sq, sk = q.shape[1], k.shape[1]
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attn_chunked(q, k, v, *, causal: bool, window: int | None,
                 chunk: int = 1024, q_offset=0) -> jnp.ndarray:
    """Memory-efficient attention: scan over KV chunks with a running
    (max, denominator) — the XLA-side analogue of flash attention; never
    materializes the (Sq, Sk) score matrix."""
    b, sq, h, hd = q.shape
    vd = v.shape[-1]
    sk = k.shape[1]
    n_rep = h // k.shape[2]
    if sk % chunk:
        pad = chunk - sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kvalid = jnp.arange(k.shape[1]) < sk
    else:
        kvalid = jnp.ones((k.shape[1],), bool)
    nchunks = k.shape[1] // chunk
    kc = k.reshape(b, nchunks, chunk, k.shape[2], hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nchunks, chunk, v.shape[2], vd).transpose(1, 0, 2, 3, 4)
    valid_c = kvalid.reshape(nchunks, chunk)
    scale = 1.0 / math.sqrt(hd)
    qpos = jnp.arange(sq)[:, None] + q_offset

    def body(carry, xs):
        m, l, acc = carry
        ki, vi, valid_i, ci = xs
        ki, vi = repeat_kv(ki, n_rep), repeat_kv(vi, n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, ki).astype(jnp.float32) * scale
        kpos = ci * chunk + jnp.arange(chunk)[None, :]
        msk = valid_i[None, :] & jnp.ones((sq, chunk), bool)
        if causal:
            msk &= kpos <= qpos
        if window is not None:
            msk &= kpos > qpos - window
        s = jnp.where(msk[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        # fully-masked chunks: keep p exactly 0 (avoid exp(-inf - -inf)=1)
        p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new[..., None]))
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vi).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, vd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (kc, vc, valid_c, jnp.arange(nchunks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attn_local(q, k, v, *, window: int, q_offset=0) -> jnp.ndarray:
    """Banded causal attention for SWA prefill: O(S*W) FLOPs, never
    quadratic.  Queries are chunked by `window`; each chunk attends to its
    own chunk plus the previous one."""
    b, s, h, hd = q.shape
    w = window
    if s % w:
        pad = w - s % w
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        pad = 0
        qp, kp, vp = q, k, v
    sp = qp.shape[1]
    nq = sp // w
    n_rep = h // k.shape[2]
    qc = qp.reshape(b, nq, w, h, hd)
    kc = kp.reshape(b, nq, w, kp.shape[2], hd)
    vc = vp.reshape(b, nq, w, vp.shape[2], hd)
    # previous chunk (zeros for chunk 0)
    kprev = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], 1)
    vprev = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], 1)
    kcat = jnp.concatenate([kprev, kc], 2)          # (B,nq,2w,Hkv,hd)
    vcat = jnp.concatenate([vprev, vc], 2)
    kcat = repeat_kv(kcat.reshape(b * nq, 2 * w, kp.shape[2], hd), n_rep)
    vcat = repeat_kv(vcat.reshape(b * nq, 2 * w, vp.shape[2], hd), n_rep)
    qf = qc.reshape(b * nq, w, h, hd)
    scale = 1.0 / math.sqrt(hd)
    sco = jnp.einsum("bqhd,bkhd->bhqk", qf, kcat).astype(jnp.float32) * scale
    qpos = jnp.arange(w)[:, None] + w                 # position within 2w
    kpos = jnp.arange(2 * w)[None, :]
    chunk0 = (jnp.arange(b * nq) % nq) == 0
    mask = (kpos <= qpos) & (kpos > qpos - w)
    mask = jnp.broadcast_to(mask[None], (b * nq, w, 2 * w))
    mask &= ~(chunk0[:, None, None] & (kpos[None] < w))
    sco = jnp.where(mask[:, None], sco, NEG_INF)
    probs = jax.nn.softmax(sco, -1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vcat)
    vd = v.shape[-1]
    out = out.reshape(b, nq, w, h, vd).reshape(b, sp, h, vd)
    return out[:, :s] if pad else out


def attention(cfg: ModelConfig, q, k, v, *, causal=True, q_offset=0,
              decode=False) -> jnp.ndarray:
    """Dispatch on cfg.attn_impl / shape heuristics."""
    impl = cfg.attn_impl
    s = q.shape[1]
    if impl == "auto":
        if decode or s == 1:
            impl = "einsum"
        elif cfg.window is not None and s > cfg.window:
            impl = "local"
        elif s > 4096:
            impl = "chunked"
        else:
            impl = "einsum"
    if impl == "flash":
        from repro.kernels.flash_attention import ops as fops
        return fops.flash_attention(q, k, v, causal=causal,
                                    window=cfg.window)
    if impl == "local":
        return attn_local(q, k, v, window=cfg.window, q_offset=q_offset)
    if impl == "chunked":
        return attn_chunked(q, k, v, causal=causal, window=cfg.window,
                            chunk=cfg.attn_chunk, q_offset=q_offset)
    return attn_einsum(q, k, v, causal=causal, window=cfg.window,
                       q_offset=q_offset)


# --- misc -------------------------------------------------------------------

def maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=None)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


def cross_entropy(logits, labels, ignore_id: int = -1):
    """Mean CE over valid positions. logits (B,S,V) fp32-cast internally."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None].clip(0), -1)[..., 0]
    valid = (labels != ignore_id).astype(jnp.float32)
    loss = (lse - ll) * valid
    return loss.sum() / jnp.maximum(valid.sum(), 1.0)
