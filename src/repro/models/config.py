"""Unified model configuration for the whole zoo.

One frozen dataclass parameterizes every assigned architecture family:
dense/GQA transformers (with QKV bias, SWA, tied embeddings), MoE
(shared+routed, first-k-dense), MLA latent attention + MTP (DeepSeek-V3),
M-RoPE VLM backbones, RG-LRU hybrids, RWKV6, and whisper-style enc-dec.
`repro.configs.<arch>` instantiates these with the exact assigned values.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
          "float16": jnp.float16}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "transformer"       # transformer | rglru | rwkv6 | whisper
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    kv_heads: int = 2
    d_ff: int = 256
    vocab: int = 256
    head_dim: int | None = None
    qkv_bias: bool = False
    swiglu: bool = True
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    rope_theta: float = 10000.0
    window: int | None = None         # sliding-window attention
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    first_dense_layers: int = 0       # DeepSeek: first k layers stay dense
    moe_d_ff: int | None = None       # routed-expert width if != d_ff
    capacity_factor: float = 1.25

    # MLA (DeepSeek-V3)
    mla_q_rank: int = 0
    mla_kv_rank: int = 0
    mla_rope_dim: int = 64
    mtp: bool = False                 # multi-token-prediction head (train)

    # M-RoPE (Qwen2-VL): rope dims split over (temporal, height, width)
    mrope_sections: tuple[int, int, int] | None = None

    # RG-LRU hybrid (RecurrentGemma): cyclic [rec, rec, attn] pattern
    attn_every: int = 0               # 3 => every 3rd layer is attention
    lru_width: int | None = None
    conv_width: int = 4

    # RWKV6
    wkv_chunk: int = 32               # chunk length for the chunked WKV form
    wkv_lora: int = 32                # rank of the data-dependent decay LoRA

    # Whisper enc-dec
    n_enc_layers: int = 0
    dec_seq_factor: int = 4           # decoder seq = enc seq / factor

    # Modality frontend stub ("input_specs() provides precomputed
    # frame/patch embeddings" per the assignment)
    frontend: str = "none"            # none | vision | audio
    vision_prefix_factor: int = 4     # 1/4 of train seq is patch embeds

    # Performance variants (hillclimb knobs — see EXPERIMENTS.md §Perf)
    gqa_einsum: bool = False      # grouped attention w/o KV head repeat
    shard_hints: bool = False     # with_sharding_constraint in MoE path
    fused_ce: bool = False        # chunked-vocab cross entropy (train mem)
    moe_groups: int = 0           # two-hop MoE dispatch: G shard-local
                                  # scatters + one explicit all-to-all
    moe_shard_map: bool = False   # explicit EP: shard_map + lax.all_to_all
    cache_seq_shard: bool = False # decode cache length sharded on model

    # Numerics / execution
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    remat: str = "none"               # none | full | dots
    attn_impl: str = "auto"           # auto | einsum | chunked | local | flash
    mlp_impl: str = "dense"           # dense | fused (Pallas fused gated-MLP)
    norm_impl: str = "ref"            # ref | fused (Pallas RMSNorm(+residual))
    attn_chunk: int = 1024            # kv-chunk for chunked/local attention
    scan_layers: bool = True          # lax.scan over stacked layer params
    scan_min_layers: int = 8

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.kv_heads * self.hd

    @property
    def use_mla(self) -> bool:
        return self.mla_kv_rank > 0

    @property
    def use_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def jdtype(self):
        return DTYPES[self.dtype]

    @property
    def jparam_dtype(self):
        return DTYPES[self.param_dtype]

    @property
    def routed_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def validate(self) -> None:
        assert self.family in ("transformer", "rglru", "rwkv6", "whisper")
        assert self.mlp_impl in ("dense", "fused")
        assert self.norm_impl in ("ref", "fused")
        if self.family == "transformer":
            assert self.n_heads % max(self.kv_heads, 1) == 0
        if self.use_moe:
            assert 0 < self.top_k <= self.n_experts
        if self.family == "rglru":
            assert self.attn_every >= 2
        if self.family == "whisper":
            assert self.n_enc_layers > 0


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests (assignment: small
    layers/width, few experts, tiny embedding tables)."""
    kw = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.family != "rglru" else 6),
        d_model=128,
        n_heads=4,
        kv_heads=max(1, min(cfg.kv_heads, 2)),
        head_dim=32,
        d_ff=256,
        vocab=512,
        dtype="float32", param_dtype="float32",
        scan_layers=cfg.scan_layers,
        scan_min_layers=2,
        attn_chunk=64,
    )
    if cfg.use_moe:
        kw.update(n_experts=4, top_k=2,
                  n_shared_experts=min(cfg.n_shared_experts, 1),
                  first_dense_layers=min(cfg.first_dense_layers, 1),
                  moe_d_ff=64 if cfg.moe_d_ff else None)
    if cfg.use_mla:
        kw.update(mla_q_rank=64, mla_kv_rank=32, mla_rope_dim=16)
    if cfg.window:
        kw.update(window=64)
    if cfg.family == "rglru":
        kw.update(lru_width=128, attn_every=cfg.attn_every)
    if cfg.family == "whisper":
        kw.update(n_enc_layers=2, n_layers=2)
    if cfg.mrope_sections:
        kw.update(mrope_sections=(4, 6, 6))     # sums to hd/2 = 16
    return cfg.replace(**kw)
