"""RWKV6 "Finch" (arXiv:2404.05892) — attention-free LM with
data-dependent per-channel decay.

Time mixing (per head, k-dim i, v-dim j):
    o_t[j] = sum_i r_t[i] * (S_{t-1}[i,j] + u[i] * k_t[i] * v_t[j])
    S_t[i,j] = w_t[i] * S_{t-1}[i,j] + k_t[i] * v_t[j]
with w_t = exp(-exp(w0 + lora_w(x~_t)))  (the data-dependent decay that
distinguishes Finch from RWKV5), token-shift interpolation on the inputs,
and a per-head groupnorm + SiLU gate on the output.

Train/prefill uses a CHUNKED evaluation (GLA-style): intra-chunk pairwise
decays are exact (exponent differences are <= 0, no overflow) and the
state is carried across chunks with a lax.scan, so all FLOPs are visible
dots (roofline-accountable), not a hidden while-loop.

Simplifications vs the reference implementation (documented in
DESIGN.md): static mix coefficients for r/k/v/g token-shift (the LoRA
data-dependence is kept where it matters — on the decay w), no
per-channel time-first bonus LoRA.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .common import cross_entropy, dense_init, embed_init, maybe_remat, \
    rmsnorm
from .config import ModelConfig

Params = Any


def n_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.hd


def _init_layer(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 12)
    d, f, r = cfg.d_model, cfg.d_ff, cfg.wkv_lora
    pd = cfg.jparam_dtype
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    h = n_heads(cfg)
    return {
        "ln1": jnp.zeros((d,), pd),
        "ln2": jnp.zeros((d,), pd),
        "att": {
            "mu_r": jnp.full((d,), 0.5, pd),
            "mu_k": jnp.full((d,), 0.5, pd),
            "mu_v": jnp.full((d,), 0.5, pd),
            "mu_g": jnp.full((d,), 0.5, pd),
            "mu_w": jnp.full((d,), 0.5, pd),
            "wr": dense_init(ks[0], (d, d), pd),
            "wk": dense_init(ks[1], (d, d), pd),
            "wv": dense_init(ks[2], (d, d), pd),
            "wg": dense_init(ks[3], (d, d), pd),
            "wo": dense_init(ks[4], (d, d), pd, scale=out_scale),
            "w0": (jax.random.uniform(ks[5], (d,), minval=-1.0, maxval=1.0)
                   ).astype(jnp.float32),
            "wa": dense_init(ks[6], (d, r), pd),
            "wb": dense_init(ks[7], (r, d), pd, scale=0.01),
            "u": (jax.random.normal(ks[8], (h, cfg.hd)) * 0.1
                  ).astype(jnp.float32),
            "gn_scale": jnp.ones((h, cfg.hd), pd),
        },
        "ffn": {
            "mu_k": jnp.full((d,), 0.5, pd),
            "mu_r": jnp.full((d,), 0.5, pd),
            "wk": dense_init(ks[9], (d, f), pd),
            "wv": dense_init(ks[10], (f, d), pd, scale=out_scale),
            "wr": dense_init(ks[11], (d, d), pd),
        },
    }


def init_params(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, cfg.n_layers + 3)
    return {
        "embed": embed_init(keys[-3], (cfg.vocab, cfg.d_model),
                            cfg.jparam_dtype),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.jparam_dtype),
        "head": dense_init(keys[-2], (cfg.d_model, cfg.vocab),
                           cfg.jparam_dtype, scale=0.02),
        "layers": [_init_layer(cfg, keys[i]) for i in range(cfg.n_layers)],
    }


# --- WKV core ---------------------------------------------------------------

def wkv_sequential(r, k, v, w, u, s0):
    """Reference recurrence. r/k/v/w: (B,S,H,D); u: (H,D);
    s0: (B,H,D,Dv). fp32. Returns (o, s_final)."""
    def step(s, xs):
        rt, kt, vt, wt = xs
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        o = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s_new = wt[..., None] * s + kv
        return s_new, o
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    s, o = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(o, 0, 1), s


def wkv_chunked(r, k, v, w, u, s0, chunk: int):
    """Chunked evaluation — exact, overflow-safe (all exponents <= 0)."""
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    pad = (-s) % chunk
    if pad:
        z = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    n = r.shape[1] // chunk
    resh = lambda t: t.reshape(b, n, chunk, h, t.shape[-1]) \
        .transpose(1, 0, 2, 3, 4)
    rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(w)
    logw = jnp.log(jnp.maximum(wc, 1e-12))

    tmask = (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :])

    def body(state, xs):
        rt, kt, vt, lw = xs                      # (B,C,H,D)
        L = jnp.cumsum(lw, axis=1)               # inclusive
        Lp = L - lw                              # exclusive
        # inter-chunk: decay from chunk start
        o = jnp.einsum("bchd,bhde->bche", rt * jnp.exp(Lp), state)
        # intra-chunk pairwise decays  P[t,s,i] = exp(Lp[t,i] - L[s,i])
        P = jnp.exp(jnp.clip(Lp[:, :, None] - L[:, None, :], -60.0, 0.0))
        P = P * tmask[None, :, :, None, None]
        A = jnp.einsum("bthd,bshd,btshd->bths", rt, kt, P)
        diag = jnp.einsum("bthd,hd,bthd->bth", rt, u, kt)
        A = A + diag[..., None] * jnp.eye(chunk)[None, :, None, :]
        o = o + jnp.einsum("bths,bshe->bthe", A, vt)
        # carry
        decay_all = jnp.exp(L[:, -1])                        # (B,H,D)
        decay_tail = jnp.exp(jnp.clip(L[:, -1, None] - L, -60.0, 0.0))
        s_new = state * decay_all[..., None] + \
            jnp.einsum("bshd,bshe->bhde", kt * decay_tail, vt)
        return s_new, o

    s_fin, oc = jax.lax.scan(body, s0, (rc, kc, vc, logw))
    o = oc.transpose(1, 0, 2, 3, 4).reshape(b, n * chunk, h, dv)
    return o[:, :s], s_fin


# --- blocks -----------------------------------------------------------------

def _shift(x, prev):
    """Token shift: value of the previous position. prev: (B,1,d)."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _groupnorm(o, scale, eps=64e-5):
    mu = o.mean(-1, keepdims=True)
    var = o.var(-1, keepdims=True)
    return (o - mu) * jax.lax.rsqrt(var + eps) * scale[None, None]


def time_mix(cfg: ModelConfig, p: Params, x, shift_prev, s0):
    """x: (B,S,d). Returns (out, (last_x, s_final))."""
    dt = cfg.jdtype
    h, hd = n_heads(cfg), cfg.hd
    b, s, d = x.shape
    xx = _shift(x, shift_prev)
    mix = lambda mu: x + (xx - x) * mu.astype(dt)
    xr, xk, xv, xg, xw = (mix(p["mu_r"]), mix(p["mu_k"]), mix(p["mu_v"]),
                          mix(p["mu_g"]), mix(p["mu_w"]))
    r = (xr @ p["wr"].astype(dt)).reshape(b, s, h, hd).astype(jnp.float32)
    k = (xk @ p["wk"].astype(dt)).reshape(b, s, h, hd).astype(jnp.float32)
    v = (xv @ p["wv"].astype(dt)).reshape(b, s, h, hd).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"].astype(dt))
    # data-dependent decay (the Finch mechanism)
    dw = jnp.tanh(xw @ p["wa"].astype(dt)) @ p["wb"].astype(dt)
    w = jnp.exp(-jnp.exp(p["w0"] + dw.astype(jnp.float32)))
    w = w.reshape(b, s, h, hd)
    u = p["u"]
    if s == 1:
        o, s_fin = wkv_sequential(r, k, v, w, u, s0)
    else:
        o, s_fin = wkv_chunked(r, k, v, w, u, s0, cfg.wkv_chunk)
    o = _groupnorm(o.astype(dt), p["gn_scale"].astype(dt))
    o = (o.reshape(b, s, d) * g) @ p["wo"].astype(dt)
    return o, (x[:, -1:], s_fin)


def channel_mix(cfg: ModelConfig, p: Params, x, shift_prev):
    dt = cfg.jdtype
    xx = _shift(x, shift_prev)
    xk = x + (xx - x) * p["mu_k"].astype(dt)
    xr = x + (xx - x) * p["mu_r"].astype(dt)
    kk = jnp.square(jax.nn.relu(xk @ p["wk"].astype(dt)))
    out = jax.nn.sigmoid(xr @ p["wr"].astype(dt)) * \
        (kk @ p["wv"].astype(dt))
    return out, x[:, -1:]


def _layer(cfg: ModelConfig, p: Params, x, st):
    a, (sh_att, s_fin) = time_mix(
        cfg, p["att"], rmsnorm(x, p["ln1"], cfg.norm_eps),
        st["shift_att"], st["wkv"])
    x = x + a
    c, sh_ffn = channel_mix(cfg, p["ffn"],
                            rmsnorm(x, p["ln2"], cfg.norm_eps),
                            st["shift_ffn"])
    x = x + c
    return x, {"shift_att": sh_att, "wkv": s_fin, "shift_ffn": sh_ffn}


def init_state(cfg: ModelConfig, batch: int) -> Params:
    h, hd = n_heads(cfg), cfg.hd
    mk = lambda: {
        "shift_att": jnp.zeros((batch, 1, cfg.d_model), cfg.jdtype),
        "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "shift_ffn": jnp.zeros((batch, 1, cfg.d_model), cfg.jdtype),
    }
    return {"layers": [mk() for _ in range(cfg.n_layers)],
            "index": jnp.zeros((), jnp.int32)}


def forward(cfg: ModelConfig, params: Params, tokens, *, state=None,
            collect_state: bool = False):
    x = jnp.take(params["embed"].astype(cfg.jdtype), tokens, axis=0)
    st = state or init_state(cfg, tokens.shape[0])
    new_layers = []
    for p, ls in zip(params["layers"], st["layers"]):
        body = maybe_remat(lambda h, _p=p, _ls=ls: _layer(cfg, _p, h, _ls),
                           cfg)
        x, ns = body(x)
        new_layers.append(ns)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["head"].astype(cfg.jdtype)
    if collect_state:
        return logits, {"layers": new_layers,
                        "index": st["index"] + tokens.shape[1]}
    return logits


def loss_fn(cfg: ModelConfig, params: Params, batch):
    return cross_entropy(forward(cfg, params, batch["tokens"]),
                         batch["labels"])


init_cache = lambda cfg, batch, max_len: init_state(cfg, batch)


def prefill(cfg: ModelConfig, params: Params, tokens, max_len: int = 0):
    logits, state = forward(cfg, params, tokens, collect_state=True)
    return logits[:, -1:], state


def decode_step(cfg: ModelConfig, params: Params, tokens, cache):
    logits, state = forward(cfg, params, tokens, state=cache,
                            collect_state=True)
    return logits, state
