"""Unified model facade: family dispatch for init/loss/prefill/decode.

Batch dict convention (matches launch.input_specs):
  train:   {"tokens": (B,S) i32, "labels": (B,S) i32[, "embeds": (B,P,d)]}
  prefill: {"tokens": (B,S)[, "embeds": ...]}
  decode:  {"tokens": (B,1), cache pytree}
Whisper uses {"embeds": frames, "tokens": decoder tokens, "labels": ...}.
"""
from __future__ import annotations

from typing import Any, Callable

import jax

from . import rglru, rwkv6, transformer, whisper
from .config import ModelConfig

Params = Any

_FAMS = {"transformer": transformer, "rglru": rglru, "rwkv6": rwkv6,
         "whisper": whisper}


def family_module(cfg: ModelConfig):
    return _FAMS[cfg.family]


def init_params(cfg: ModelConfig, key) -> Params:
    return family_module(cfg).init_params(cfg, key)


def loss_fn(cfg: ModelConfig, params: Params, batch) -> jax.Array:
    m = family_module(cfg)
    if cfg.family == "whisper":
        return m.loss_fn(cfg, params, batch)
    if cfg.family == "transformer":
        return m.loss_fn(cfg, params, batch)
    return m.loss_fn(cfg, params, batch)


def forward(cfg: ModelConfig, params: Params, batch):
    m = family_module(cfg)
    if cfg.family == "whisper":
        return m.forward(cfg, params, batch["embeds"], batch["tokens"])
    if cfg.family == "transformer":
        return m.forward(cfg, params, batch.get("tokens"),
                         embeds=batch.get("embeds"))
    return m.forward(cfg, params, batch["tokens"])


def prefill(cfg: ModelConfig, params: Params, batch, max_len: int):
    m = family_module(cfg)
    if cfg.family == "whisper":
        return m.prefill(cfg, params, batch["embeds"], batch["tokens"],
                         max_len)
    if cfg.family == "transformer":
        return m.prefill(cfg, params, batch.get("tokens"), max_len,
                         embeds=batch.get("embeds"))
    return m.prefill(cfg, params, batch["tokens"], max_len)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int | None = None):
    m = family_module(cfg)
    if cfg.family == "whisper":
        return m.init_cache(cfg, batch, max_len,
                            enc_len or max_len)
    return m.init_cache(cfg, batch, max_len)


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                     dtype=None):
    """Paged KV page pools (transformer-only — the serving engine falls
    back to the dense cache for every other family)."""
    if cfg.family != "transformer":
        raise NotImplementedError(
            f"paged KV cache is transformer-only, not {cfg.family}")
    return family_module(cfg).init_paged_cache(cfg, num_pages, page_size,
                                               dtype)


def decode_step(cfg: ModelConfig, params: Params, tokens, cache):
    return family_module(cfg).decode_step(cfg, params, tokens, cache)


def decode_window(cfg: ModelConfig, params: Params, tokens, cache):
    """Verify a (B, W) token window in one cached forward (spec-decode).
    Plain-attention transformers only; see `transformer.decode_window`."""
    if cfg.family != "transformer":
        raise NotImplementedError(
            f"decode_window is transformer-only, not {cfg.family}")
    return transformer.decode_window(cfg, params, tokens, cache)


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))
