"""RecurrentGemma-style hybrid (Griffin): RG-LRU recurrent blocks with a
cyclic [rec, rec, local-attn] pattern (paper arXiv:2402.19427).

Temporal mixing per layer is either
  * a recurrent block: two linear branches to `lru_width`; branch 1 goes
    through a short causal depthwise conv then the RG-LRU diagonal
    recurrence  h_t = a_t*h_{t-1} + sqrt(1-a_t^2)*(i_t*x_t),
    a_t = exp(-c * softplus(L) * r_t);  branch 2 is a GeLU gate;
  * or local (sliding-window, MQA) attention.

Train/prefill evaluates the recurrence with an associative scan
(log-depth); decode carries (h, conv window) state.  State is O(1) in
sequence length — this is why long_500k runs for this arch.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .common import (apply_norm, apply_rope, attention, dense_init,
                     embed_init, init_norm, maybe_remat, rmsnorm)
from .config import ModelConfig

Params = Any
RGLRU_C = 8.0


def is_attn_layer(cfg: ModelConfig, i: int) -> bool:
    return (i % cfg.attn_every) == cfg.attn_every - 1


def _init_rec(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 8)
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    pd = cfg.jparam_dtype
    return {
        "w_x": dense_init(ks[0], (d, w), pd),
        "w_gate": dense_init(ks[1], (d, w), pd),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, w)) * 0.1
                   ).astype(pd),
        "conv_b": jnp.zeros((w,), pd),
        "wa": dense_init(ks[3], (w, w), pd),
        "wx_in": dense_init(ks[4], (w, w), pd),
        "lam": (jax.random.uniform(ks[5], (w,), minval=0.4, maxval=0.9)
                ).astype(jnp.float32),
        "w_out": dense_init(ks[6], (w, d), pd,
                            scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }


def _init_attn(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    pd = cfg.jparam_dtype
    return {
        "wq": dense_init(ks[0], (d, qd), pd),
        "wk": dense_init(ks[1], (d, kvd), pd),
        "wv": dense_init(ks[2], (d, kvd), pd),
        "wo": dense_init(ks[3], (qd, d), pd,
                         scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }


def _init_mlp(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    pd = cfg.jparam_dtype
    return {"w_in": dense_init(ks[0], (d, f), pd),
            "w_gate": dense_init(ks[1], (d, f), pd),
            "w_out": dense_init(ks[2], (f, d), pd,
                                scale=0.02 / math.sqrt(2 * cfg.n_layers))}


def init_params(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, cfg.n_layers + 3)
    layers = []
    for i in range(cfg.n_layers):
        ks = jax.random.split(keys[i], 4)
        p = {"norm1": init_norm(cfg, ks[0]), "norm2": init_norm(cfg, ks[1]),
             "mlp": _init_mlp(cfg, ks[2])}
        if is_attn_layer(cfg, i):
            p["attn"] = _init_attn(cfg, ks[3])
        else:
            p["rec"] = _init_rec(cfg, ks[3])
        layers.append(p)
    return {"embed": embed_init(keys[-3], (cfg.vocab, cfg.d_model),
                                cfg.jparam_dtype),
            "final_norm": init_norm(cfg, keys[-2]),
            "layers": layers}   # tied embeddings (gemma-style unembed)


# --- RG-LRU core ------------------------------------------------------------

def _rglru_coeffs(cfg: ModelConfig, p: Params, x):
    """x: (B,S,w) post-conv. Returns (a, b): h_t = a_t h + b_t."""
    dt = cfg.jdtype
    r = jax.nn.sigmoid((x @ p["wa"].astype(dt)).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ p["wx_in"].astype(dt)).astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    gated = i * x.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated
    return a, b


def rglru_scan(a, b, h0=None):
    """Associative scan of h_t = a_t h_{t-1} + b_t over axis 1."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def causal_conv(cfg: ModelConfig, p: Params, x, state=None):
    """Short depthwise causal conv. x (B,S,w); state (B, cw-1, w)."""
    cw = cfg.conv_width
    pad = state if state is not None else \
        jnp.zeros(x.shape[:1] + (cw - 1,) + x.shape[2:], x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * p["conv_w"][i].astype(x.dtype)
              for i in range(cw))
    new_state = xp[:, -(cw - 1):] if cw > 1 else pad
    return out + p["conv_b"].astype(x.dtype), new_state


def rec_block(cfg: ModelConfig, p: Params, x, state=None):
    """state: {"h": (B,w) fp32, "conv": (B,cw-1,w)} or None (prefill)."""
    dt = cfg.jdtype
    u = x @ p["w_x"].astype(dt)
    g = jax.nn.gelu(x @ p["w_gate"].astype(dt))
    u, conv_state = causal_conv(cfg, p, u,
                                None if state is None else state["conv"])
    a, b = _rglru_coeffs(cfg, p, u)
    h0 = None if state is None else state["h"]
    h = rglru_scan(a, b, h0)
    y = (h.astype(dt) * g) @ p["w_out"].astype(dt)
    new_state = {"h": h[:, -1], "conv": conv_state}
    return y, new_state


def attn_full(cfg: ModelConfig, p: Params, x, positions):
    bsz, s, _ = x.shape
    dt = cfg.jdtype
    q = (x @ p["wq"].astype(dt)).reshape(bsz, s, cfg.n_heads, cfg.hd)
    k = (x @ p["wk"].astype(dt)).reshape(bsz, s, cfg.kv_heads, cfg.hd)
    v = (x @ p["wv"].astype(dt)).reshape(bsz, s, cfg.kv_heads, cfg.hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = attention(cfg, q, k, v, causal=True)
    return o.reshape(bsz, s, cfg.q_dim) @ p["wo"].astype(dt), (k, v)


def mlp(cfg: ModelConfig, p: Params, x):
    dt = cfg.jdtype
    h = jax.nn.gelu(x @ p["w_gate"].astype(dt)) * (x @ p["w_in"].astype(dt))
    return h @ p["w_out"].astype(dt)


# --- forward / decode -------------------------------------------------------

def forward(cfg: ModelConfig, params: Params, tokens, *, positions=None,
            collect_state: bool = False):
    x = jnp.take(params["embed"].astype(cfg.jdtype), tokens, axis=0)
    x = x * math.sqrt(cfg.d_model)
    bsz, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (bsz, s))
    states = []

    def layer(h, p, i):
        hn = apply_norm(cfg, p["norm1"], h)
        if is_attn_layer(cfg, i):
            a, st = attn_full(cfg, p["attn"], hn, positions)
        else:
            a, st = rec_block(cfg, p["rec"], hn)
        h = h + a
        h = h + mlp(cfg, p["mlp"], apply_norm(cfg, p["norm2"], h))
        return h, st

    for i, p in enumerate(params["layers"]):
        body = maybe_remat(lambda h, _p=p, _i=i: layer(h, _p, _i), cfg)
        x, st = body(x)
        states.append(st)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = x @ params["embed"].astype(cfg.jdtype).T
    if collect_state:
        return logits, states
    return logits


def loss_fn(cfg: ModelConfig, params: Params, batch):
    from .common import cross_entropy
    logits = forward(cfg, params, batch["tokens"])
    return cross_entropy(logits, batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    w = cfg.lru_width or cfg.d_model
    clen = min(max_len, cfg.window or max_len)
    layers = []
    for i in range(cfg.n_layers):
        if is_attn_layer(cfg, i):
            layers.append({
                "k": jnp.zeros((batch, clen, cfg.kv_heads, cfg.hd),
                               cfg.jdtype),
                "v": jnp.zeros((batch, clen, cfg.kv_heads, cfg.hd),
                               cfg.jdtype)})
        else:
            layers.append({
                "h": jnp.zeros((batch, w), jnp.float32),
                "conv": jnp.zeros((batch, cfg.conv_width - 1, w),
                                  cfg.jdtype)})
    return {"layers": layers, "index": jnp.zeros((), jnp.int32)}


def _decode_attn(cfg: ModelConfig, p: Params, x, lc, index):
    """One cached-attention step.  `index` is a per-slot (B,) position
    vector so engine-side slot rotation/compaction can hand each lane an
    independent ring position (uniform vectors are bit-identical to the
    historical scalar path)."""
    bsz = x.shape[0]
    dt = cfg.jdtype
    pos1 = index[:, None]
    q = (x @ p["wq"].astype(dt)).reshape(bsz, 1, cfg.n_heads, cfg.hd)
    k = (x @ p["wk"].astype(dt)).reshape(bsz, 1, cfg.kv_heads, cfg.hd)
    v = (x @ p["wv"].astype(dt)).reshape(bsz, 1, cfg.kv_heads, cfg.hd)
    q = apply_rope(q, pos1, cfg.rope_theta)
    k = apply_rope(k, pos1, cfg.rope_theta)
    K, V = lc["k"], lc["v"]
    clen = K.shape[1]
    slot = index % clen
    K = K.at[jnp.arange(bsz), slot].set(k[:, 0].astype(K.dtype))
    V = V.at[jnp.arange(bsz), slot].set(v[:, 0].astype(V.dtype))
    n_rep = cfg.n_heads // cfg.kv_heads
    Kr = jnp.repeat(K.astype(dt), n_rep, 2) if n_rep > 1 else K.astype(dt)
    Vr = jnp.repeat(V.astype(dt), n_rep, 2) if n_rep > 1 else V.astype(dt)
    sc = jnp.einsum("bqhd,bchd->bhqc", q, Kr).astype(jnp.float32) \
        / math.sqrt(cfg.hd)
    j = jnp.arange(clen)
    kpos = pos1 - ((pos1 - j[None]) % clen)        # (B, clen)
    mask = (kpos >= 0) & (kpos <= pos1)
    if cfg.window:
        mask &= kpos > pos1 - cfg.window
    sc = jnp.where(mask[:, None, None, :], sc, -1e30)
    pr = jax.nn.softmax(sc, -1).astype(dt)
    o = jnp.einsum("bhqc,bchd->bqhd", pr, Vr)
    out = o.reshape(bsz, 1, cfg.q_dim) @ p["wo"].astype(dt)
    return out, {"k": K, "v": V}


def decode_step(cfg: ModelConfig, params: Params, tokens, cache):
    raw_index = cache["index"]
    index = (raw_index if raw_index.ndim == 1
             else jnp.full((tokens.shape[0],), raw_index, jnp.int32))
    x = jnp.take(params["embed"].astype(cfg.jdtype), tokens, axis=0)
    x = x * math.sqrt(cfg.d_model)
    new_layers = []
    for i, (p, lc) in enumerate(zip(params["layers"], cache["layers"])):
        hn = apply_norm(cfg, p["norm1"], x)
        if is_attn_layer(cfg, i):
            a, nc = _decode_attn(cfg, p["attn"], hn, lc, index)
        else:
            a, nc = rec_block(cfg, p["rec"], hn, state=lc)
        x = x + a
        x = x + mlp(cfg, p["mlp"], apply_norm(cfg, p["norm2"], x))
        new_layers.append(nc)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = x @ params["embed"].astype(cfg.jdtype).T
    return logits, {"layers": new_layers, "index": raw_index + 1}


def prefill(cfg: ModelConfig, params: Params, tokens, max_len: int):
    s = tokens.shape[1]
    logits, states = forward(cfg, params, tokens, collect_state=True)
    cache = init_cache(cfg, tokens.shape[0], max_len)
    clen = min(max_len, cfg.window or max_len)
    new_layers = []
    for i, st in enumerate(states):
        if is_attn_layer(cfg, i):
            k, v = st                       # (B,S,kvh,hd)
            take = min(s, clen)
            def place(src):
                last = src[:, s - take:s]
                if take < clen:
                    return jnp.pad(last, ((0, 0), (0, clen - take),
                                          (0, 0), (0, 0)))
                return jnp.roll(last, shift=s % clen, axis=1)
            new_layers.append({"k": place(k).astype(cfg.jdtype),
                               "v": place(v).astype(cfg.jdtype)})
        else:
            new_layers.append(st)
    return logits[:, -1:], {"layers": new_layers,
                            "index": jnp.asarray(s, jnp.int32)}
