"""Whisper-style encoder-decoder (arXiv:2212.04356).

The conv frame frontend is a STUB per the assignment: `input_specs()`
provides precomputed frame embeddings (B, T_frames, d_model).  The
transformer backbone — bidirectional encoder, causal decoder with
cross-attention, LayerNorm + GELU — is fully implemented, with
self+cross KV caches for decode.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .common import (attn_einsum, attention, cross_entropy, dense_init,
                     embed_init, layernorm, maybe_remat)
from .config import ModelConfig

Params = Any


def _ln(cfg, key):
    return {"scale": jnp.ones((cfg.d_model,), cfg.jparam_dtype),
            "bias": jnp.zeros((cfg.d_model,), cfg.jparam_dtype)}


def _init_attn(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    pd = cfg.jparam_dtype
    sc = 0.02 / math.sqrt(2 * (cfg.n_layers + cfg.n_enc_layers))
    return {"wq": dense_init(ks[0], (d, d), pd),
            "wk": dense_init(ks[1], (d, d), pd),
            "wv": dense_init(ks[2], (d, d), pd),
            "wo": dense_init(ks[3], (d, d), pd, scale=sc),
            "bq": jnp.zeros((d,), pd), "bv": jnp.zeros((d,), pd),
            "bo": jnp.zeros((d,), pd)}


def _init_mlp(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 2)
    d, f = cfg.d_model, cfg.d_ff
    pd = cfg.jparam_dtype
    sc = 0.02 / math.sqrt(2 * (cfg.n_layers + cfg.n_enc_layers))
    return {"w_in": dense_init(ks[0], (d, f), pd),
            "b_in": jnp.zeros((f,), pd),
            "w_out": dense_init(ks[1], (f, d), pd, scale=sc),
            "b_out": jnp.zeros((d,), pd)}


def init_params(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, 8)
    pd = cfg.jparam_dtype
    enc_layers, dec_layers = [], []
    eks = jax.random.split(keys[0], cfg.n_enc_layers)
    for k in eks:
        ks = jax.random.split(k, 4)
        enc_layers.append({"ln1": _ln(cfg, ks[0]),
                           "attn": _init_attn(cfg, ks[1]),
                           "ln2": _ln(cfg, ks[2]),
                           "mlp": _init_mlp(cfg, ks[3])})
    dks = jax.random.split(keys[1], cfg.n_layers)
    for k in dks:
        ks = jax.random.split(k, 6)
        dec_layers.append({"ln1": _ln(cfg, ks[0]),
                           "self_attn": _init_attn(cfg, ks[1]),
                           "ln2": _ln(cfg, ks[2]),
                           "cross_attn": _init_attn(cfg, ks[3]),
                           "ln3": _ln(cfg, ks[4]),
                           "mlp": _init_mlp(cfg, ks[5])})
    max_pos = 8192
    return {
        "embed": embed_init(keys[2], (cfg.vocab, cfg.d_model), pd),
        "dec_pos": embed_init(keys[3], (max_pos, cfg.d_model), pd),
        "enc_ln": _ln(cfg, keys[4]),
        "dec_ln": _ln(cfg, keys[5]),
        "enc_layers": enc_layers,
        "dec_layers": dec_layers,
    }   # unembed is tied to `embed` (whisper ties them)


def _sinusoid(s: int, d: int, dtype):
    pos = jnp.arange(s)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def _mha(cfg, p, xq, xkv, *, causal, decode_cache=None, index=None):
    dt = cfg.jdtype
    b, sq, d = xq.shape
    h, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    q = (xq @ p["wq"].astype(dt) + p["bq"].astype(dt)) \
        .reshape(b, sq, h, hd)
    k = (xkv @ p["wk"].astype(dt)).reshape(b, -1, h, hd)
    v = (xkv @ p["wv"].astype(dt) + p["bv"].astype(dt)) \
        .reshape(b, -1, h, hd)
    o = attention(cfg, q, k, v, causal=causal)
    return o.reshape(b, sq, d) @ p["wo"].astype(dt) + p["bo"].astype(dt), \
        (k, v)


def _mlp(cfg, p, x):
    dt = cfg.jdtype
    h = jax.nn.gelu(x @ p["w_in"].astype(dt) + p["b_in"].astype(dt))
    return h @ p["w_out"].astype(dt) + p["b_out"].astype(dt)


def encode(cfg: ModelConfig, params: Params, frames):
    """frames: (B, T, d) precomputed embeddings (conv-frontend stub)."""
    dt = cfg.jdtype
    x = frames.astype(dt) + _sinusoid(frames.shape[1], cfg.d_model, dt)[None]
    for p in params["enc_layers"]:
        body = maybe_remat(
            lambda h, _p=p: (
                h + _mha(cfg, _p["attn"],
                         layernorm(h, _p["ln1"]["scale"], _p["ln1"]["bias"]),
                         layernorm(h, _p["ln1"]["scale"], _p["ln1"]["bias"]),
                         causal=False)[0], None), cfg)
        x, _ = body(x)
        hn = layernorm(x, p["ln2"]["scale"], p["ln2"]["bias"])
        x = x + _mlp(cfg, p["mlp"], hn)
    return layernorm(x, params["enc_ln"]["scale"], params["enc_ln"]["bias"])


def decode_train(cfg: ModelConfig, params: Params, enc_out, tokens):
    dt = cfg.jdtype
    b, s = tokens.shape
    x = jnp.take(params["embed"].astype(dt), tokens, axis=0) + \
        params["dec_pos"][:s].astype(dt)[None]
    kvs = []
    for p in params["dec_layers"]:
        hn = layernorm(x, p["ln1"]["scale"], p["ln1"]["bias"])
        a, self_kv = _mha(cfg, p["self_attn"], hn, hn, causal=True)
        x = x + a
        hn = layernorm(x, p["ln2"]["scale"], p["ln2"]["bias"])
        c, cross_kv = _mha(cfg, p["cross_attn"], hn, enc_out, causal=False)
        x = x + c
        hn = layernorm(x, p["ln3"]["scale"], p["ln3"]["bias"])
        x = x + _mlp(cfg, p["mlp"], hn)
        kvs.append((self_kv, cross_kv))
    x = layernorm(x, params["dec_ln"]["scale"], params["dec_ln"]["bias"])
    return x @ params["embed"].astype(dt).T, kvs


def forward(cfg: ModelConfig, params: Params, frames, tokens):
    enc = encode(cfg, params, frames)
    logits, _ = decode_train(cfg, params, enc, tokens)
    return logits


def loss_fn(cfg: ModelConfig, params: Params, batch):
    logits = forward(cfg, params, batch["embeds"], batch["tokens"])
    return cross_entropy(logits, batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int) -> Params:
    h, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    dt = cfg.jdtype
    layers = []
    for _ in range(cfg.n_layers):
        layers.append({
            "k": jnp.zeros((batch, max_len, h, hd), dt),
            "v": jnp.zeros((batch, max_len, h, hd), dt),
            "ck": jnp.zeros((batch, enc_len, h, hd), dt),
            "cv": jnp.zeros((batch, enc_len, h, hd), dt),
        })
    return {"layers": layers, "index": jnp.zeros((), jnp.int32)}


def prefill(cfg: ModelConfig, params: Params, frames, tokens,
            max_len: int):
    """Encode audio + run decoder prompt; returns (logits_last, cache)."""
    enc = encode(cfg, params, frames)
    logits, kvs = decode_train(cfg, params, enc, tokens)
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_len, enc.shape[1])
    layers = []
    for (self_kv, cross_kv), lc in zip(kvs, cache["layers"]):
        k, v = self_kv
        lk = jax.lax.dynamic_update_slice(lc["k"], k.astype(lc["k"].dtype),
                                          (0, 0, 0, 0))
        lv = jax.lax.dynamic_update_slice(lc["v"], v.astype(lc["v"].dtype),
                                          (0, 0, 0, 0))
        layers.append({"k": lk, "v": lv,
                       "ck": cross_kv[0].astype(lc["ck"].dtype),
                       "cv": cross_kv[1].astype(lc["cv"].dtype)})
    return logits[:, -1:], {"layers": layers,
                            "index": jnp.asarray(s, jnp.int32)}


def decode_step(cfg: ModelConfig, params: Params, tokens, cache):
    """One decoder token against self-cache + precomputed cross KV.

    `cache["index"]` may be a scalar or a per-slot (B,) vector — the
    vector form lets the serving engine rotate/compact decoder slots
    independently (uniform vectors match the scalar path bit-for-bit).
    """
    dt = cfg.jdtype
    raw_index = cache["index"]
    b = tokens.shape[0]
    index = (raw_index if raw_index.ndim == 1
             else jnp.full((b,), raw_index, jnp.int32))
    h, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    pos_emb = jnp.take(params["dec_pos"], index, axis=0)       # (B, d)
    x = jnp.take(params["embed"].astype(dt), tokens, axis=0) + \
        pos_emb.astype(dt)[:, None]
    new_layers = []
    for p, lc in zip(params["dec_layers"], cache["layers"]):
        hn = layernorm(x, p["ln1"]["scale"], p["ln1"]["bias"])
        q = (hn @ p["self_attn"]["wq"].astype(dt)
             + p["self_attn"]["bq"].astype(dt)).reshape(b, 1, h, hd)
        k = (hn @ p["self_attn"]["wk"].astype(dt)).reshape(b, 1, h, hd)
        v = (hn @ p["self_attn"]["wv"].astype(dt)
             + p["self_attn"]["bv"].astype(dt)).reshape(b, 1, h, hd)
        K = lc["k"].at[jnp.arange(b), index].set(
            k[:, 0].astype(lc["k"].dtype))
        V = lc["v"].at[jnp.arange(b), index].set(
            v[:, 0].astype(lc["v"].dtype))
        sc = jnp.einsum("bqhd,bchd->bhqc", q, K.astype(dt)) \
            .astype(jnp.float32) / math.sqrt(hd)
        mask = jnp.arange(K.shape[1])[None] <= index[:, None]  # (B, C)
        sc = jnp.where(mask[:, None, None, :], sc, -1e30)
        pr = jax.nn.softmax(sc, -1).astype(dt)
        o = jnp.einsum("bhqc,bchd->bqhd", pr, V.astype(dt))
        a = o.reshape(b, 1, cfg.d_model) @ p["self_attn"]["wo"].astype(dt) \
            + p["self_attn"]["bo"].astype(dt)
        x = x + a
        hn = layernorm(x, p["ln2"]["scale"], p["ln2"]["bias"])
        q = (hn @ p["cross_attn"]["wq"].astype(dt)
             + p["cross_attn"]["bq"].astype(dt)).reshape(b, 1, h, hd)
        sc = jnp.einsum("bqhd,bchd->bhqc", q, lc["ck"].astype(dt)) \
            .astype(jnp.float32) / math.sqrt(hd)
        pr = jax.nn.softmax(sc, -1).astype(dt)
        o = jnp.einsum("bhqc,bchd->bqhd", pr, lc["cv"].astype(dt))
        c = o.reshape(b, 1, cfg.d_model) @ p["cross_attn"]["wo"].astype(dt) \
            + p["cross_attn"]["bo"].astype(dt)
        x = x + c
        hn = layernorm(x, p["ln3"]["scale"], p["ln3"]["bias"])
        x = x + _mlp(cfg, p["mlp"], hn)
        new_layers.append({"k": K, "v": V, "ck": lc["ck"], "cv": lc["cv"]})
    x = layernorm(x, params["dec_ln"]["scale"], params["dec_ln"]["bias"])
    logits = x @ params["embed"].astype(dt).T
    return logits, {"layers": new_layers, "index": raw_index + 1}
