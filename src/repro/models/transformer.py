"""Decoder-only transformer family: covers h2o-danube (SWA), smollm,
internlm2, qwen2.5 (QKV bias), mixtral (MoE+SWA), deepseek-v3 (MLA + MoE
shared/routed + MTP), qwen2-vl (M-RoPE + vision-stub prefix).

Pure JAX; params are nested dicts; repeated layers are stacked on a
leading axis and executed with lax.scan (MaxText-style) for compile-time
sanity at 61-64 layers.  KV caches support plain, sliding-window (ring)
and MLA-latent layouts.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from .common import (apply_mrope, apply_norm, apply_norm_residual,
                     apply_rope, attention, attn_einsum, cross_entropy,
                     dense_init, embed_init, init_norm, maybe_remat,
                     mlp_block)
from .config import ModelConfig

Params = Any


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_attn(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 8)
    d, qd, kvd, hd = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.hd
    pd = cfg.jparam_dtype
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    if cfg.use_mla:
        rd, qr, kvr = cfg.mla_rope_dim, cfg.mla_q_rank, cfg.mla_kv_rank
        return {
            "wdq": dense_init(ks[0], (d, qr), pd),
            "q_norm": {"scale": jnp.zeros((qr,), pd)},
            "wuq": dense_init(ks[1], (qr, cfg.n_heads * (hd + rd)), pd),
            "wdkv": dense_init(ks[2], (d, kvr + rd), pd),
            "kv_norm": {"scale": jnp.zeros((kvr,), pd)},
            "wuk": dense_init(ks[3], (kvr, cfg.n_heads * hd), pd),
            "wuv": dense_init(ks[4], (kvr, cfg.n_heads * hd), pd),
            "wo": dense_init(ks[5], (qd, d), pd, scale=out_scale),
        }
    p = {
        "wq": dense_init(ks[0], (d, qd), pd),
        "wk": dense_init(ks[1], (d, kvd), pd),
        "wv": dense_init(ks[2], (d, kvd), pd),
        "wo": dense_init(ks[3], (qd, d), pd, scale=out_scale),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qd,), pd)
        p["bk"] = jnp.zeros((kvd,), pd)
        p["bv"] = jnp.zeros((kvd,), pd)
    return p


def _init_mlp(cfg: ModelConfig, key, d_ff: int | None = None,
              mult: int = 1) -> Params:
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, (d_ff or cfg.d_ff) * mult
    pd = cfg.jparam_dtype
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    p = {"w_in": dense_init(ks[0], (d, f), pd),
         "w_out": dense_init(ks[1], (f, d), pd, scale=out_scale)}
    if cfg.swiglu:
        p["w_gate"] = dense_init(ks[2], (d, f), pd)
    return p


def _init_moe(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 5)
    d, f, e = cfg.d_model, cfg.routed_ff, cfg.n_experts
    pd = cfg.jparam_dtype
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    p = {
        "router": dense_init(ks[0], (d, e), pd),
        "experts_in": dense_init(ks[1], (e, d, f), pd),
        "experts_out": dense_init(ks[2], (e, f, d), pd, scale=out_scale),
    }
    if cfg.swiglu:
        p["experts_gate"] = dense_init(ks[3], (e, d, f), pd)
    if cfg.n_shared_experts:
        p["shared"] = _init_mlp(cfg, ks[4], d_ff=cfg.routed_ff,
                                mult=cfg.n_shared_experts)
    return p


def _init_layer(cfg: ModelConfig, key, kind: str) -> Params:
    ks = jax.random.split(key, 4)
    p = {"norm1": init_norm(cfg, ks[0]),
         "attn": _init_attn(cfg, ks[1]),
         "norm2": init_norm(cfg, ks[2])}
    if kind == "moe":
        p["moe"] = _init_moe(cfg, ks[3])
    else:
        p["mlp"] = _init_mlp(cfg, ks[3])
    return p


def layer_segments(cfg: ModelConfig) -> list[tuple[str, int]]:
    """[(layer_kind, count)] — contiguous runs of identical structure."""
    if cfg.use_moe and cfg.first_dense_layers:
        return [("dense", cfg.first_dense_layers),
                ("moe", cfg.n_layers - cfg.first_dense_layers)]
    if cfg.use_moe:
        return [("moe", cfg.n_layers)]
    return [("dense", cfg.n_layers)]


def init_params(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, 8)
    pd = cfg.jparam_dtype
    params: Params = {
        "embed": embed_init(keys[0], (cfg.vocab, cfg.d_model), pd),
        "final_norm": init_norm(cfg, keys[1]),
        "segments": [],
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(keys[2], (cfg.d_model, cfg.vocab), pd,
                                    scale=0.02)
    kseg = jax.random.split(keys[3], len(layer_segments(cfg)))
    for (kind, count), k in zip(layer_segments(cfg), kseg):
        lkeys = jax.random.split(k, count)
        stacked = jax.vmap(lambda kk: _init_layer(cfg, kk, kind))(lkeys)
        params["segments"].append({"kind_" + kind: stacked})
    if cfg.mtp:
        params["mtp"] = {
            "proj": dense_init(keys[4], (2 * cfg.d_model, cfg.d_model), pd),
            "norm": init_norm(cfg, keys[5]),
            "layer": _init_layer(cfg, keys[6], "dense"),
        }
    return params


def segment_kind(seg: Params) -> str:
    return next(iter(seg.keys())).removeprefix("kind_")


def segment_params(seg: Params) -> Params:
    return next(iter(seg.values()))


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _mesh_axis_names() -> tuple:
    try:
        m = jax.sharding.get_abstract_mesh()
        return tuple(m.axis_names) if m is not None else ()
    except Exception:
        return ()


def _mesh_axis_size(name: str) -> int:
    try:
        m = jax.sharding.get_abstract_mesh()
        return int(m.shape[name]) if m is not None and \
            name in m.axis_names else 1
    except Exception:
        return 1


def _wsc(x, *spec):
    """with_sharding_constraint if a mesh is visible; no-op otherwise."""
    names = _mesh_axis_names()
    if "model" not in names:
        return x
    from jax.sharding import PartitionSpec as _P
    fixed = tuple(s if (s is None or all(
        n in names for n in (s if isinstance(s, tuple) else (s,))))
        else None for s in spec)
    return jax.lax.with_sharding_constraint(x, _P(*fixed))


def moe_block_grouped(cfg: ModelConfig, p: Params, x):
    """§Perf variant: two-hop expert dispatch.

    The naive scatter into an expert-sharded buffer forces SPMD to
    all-gather the whole token stream (data-dependent routing is opaque
    to the partitioner).  Instead: (1) group tokens by their DATA shard
    and scatter into per-group capacity buffers — entirely shard-local;
    (2) transpose (G, E, cap, d) -> (E, G*cap, d), an explicit layout
    change the partitioner lowers to ONE all-to-all of the routed
    activations; (3) EP expert compute; (4) inverse all-to-all + local
    combine.  Collective volume drops from O(tokens x d x devices) to
    O(tokens x d x top_k x cf)."""
    bsz, s, d = x.shape
    n = bsz * s
    g = cfg.moe_groups
    assert g > 0 and n % g == 0, (n, g)
    m = n // g
    k, e = cfg.top_k, cfg.n_experts
    dp = ("pod", "data") if "pod" in _mesh_axis_names() else "data"
    xf = x.reshape(g, m, d)
    logits = (xf @ p["router"].astype(jnp.float32)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    w, idx = jax.lax.top_k(probs, k)                        # (g, m, k)
    w = (w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)).astype(cfg.jdtype)

    cap = int(math.ceil(m * k / e * cfg.capacity_factor))
    cap = max(8, min(cap, m))
    cap = (cap + 7) // 8 * 8

    flat_idx = idx.reshape(g, m * k)
    onehot = jax.nn.one_hot(flat_idx, e, dtype=jnp.int32)   # (g, m*k, e)
    pos = jnp.cumsum(onehot, axis=1) - 1
    slot = jnp.take_along_axis(pos, flat_idx[..., None], 2)[..., 0]
    keep = slot < cap
    slot = jnp.where(keep, slot, cap - 1)

    xrep = jnp.repeat(xf, k, axis=1)                        # (g, m*k, d)
    vals = jnp.where(keep[..., None], xrep, 0).astype(cfg.jdtype)
    vals = _wsc(vals, dp, None, None)
    gix = jnp.arange(g)[:, None]
    buf = jnp.zeros((g, e, cap, d), cfg.jdtype)
    buf = buf.at[gix, flat_idx, slot].add(vals)             # shard-local
    buf = _wsc(buf, dp, None, None, None)

    # hop 2: regroup expert-major — ONE all-to-all
    bufe = buf.transpose(1, 0, 2, 3).reshape(e, g * cap, d)
    bufe = _wsc(bufe, "model", None, None)
    h = jnp.einsum("ecd,edf->ecf", bufe, p["experts_in"].astype(cfg.jdtype))
    if cfg.swiglu:
        gg = jnp.einsum("ecd,edf->ecf", bufe,
                        p["experts_gate"].astype(cfg.jdtype))
        h = jax.nn.silu(gg) * h
    else:
        h = jax.nn.gelu(h)
    oute = jnp.einsum("ecf,efd->ecd", h,
                      p["experts_out"].astype(cfg.jdtype))
    oute = _wsc(oute, "model", None, None)
    outg = oute.reshape(e, g, cap, d).transpose(1, 0, 2, 3)
    outg = _wsc(outg, dp, None, None, None)

    gathered = outg[gix, flat_idx, slot]                    # shard-local
    gathered = jnp.where(keep[..., None], gathered, 0)
    combined = (gathered.reshape(g, m, k, d)
                * w[..., None]).sum(2).astype(cfg.jdtype)
    y = combined.reshape(bsz, s, d)
    if cfg.n_shared_experts:
        y = y + mlp_block(cfg, p["shared"], x)
    return y


def moe_block_shard_map(cfg: ModelConfig, p: Params, x):
    """§Perf variant: EXPLICIT expert parallelism.

    pjit cannot turn a data-dependent scatter into routed communication
    (it all-gathers the token stream: the dominant collective term in the
    deepseek-v3 train baseline).  shard_map makes the routing explicit:
    tokens are fully sharded over (dp x model); each device builds local
    per-expert capacity buffers (zero communication), ONE
    lax.all_to_all ships each expert's rows to its owner (volume =
    tokens x d x top_k x cf / devices), local expert GEMMs run, and the
    inverse all_to_all returns the outputs.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as _P

    mesh = jax.sharding.get_abstract_mesh()
    names = tuple(mesh.axis_names)
    ep_axes = tuple(a for a in ("data", "model") if a in names)
    all_axes = tuple(a for a in ("pod", "data", "model") if a in names)
    n_ep = 1
    for a in ep_axes:
        n_ep *= int(mesh.shape[a])
    n_tot = 1
    for a in all_axes:
        n_tot *= int(mesh.shape[a])
    bsz, s, d = x.shape
    n = bsz * s
    k, e = cfg.top_k, cfg.n_experts
    if e % n_ep or n % n_tot:
        return moe_block(cfg.replace(moe_shard_map=False), p, x)
    el = e // n_ep
    nl = n // n_tot
    cap_l = max(1, int(math.ceil(nl * k / e * cfg.capacity_factor)))

    dt = cfg.jdtype

    def local_fn(xl, router, win, wgate, wout):
        # xl: (nl, d); win/wgate: (el, d, f); wout: (el, f, d)
        logits = (xl @ router.astype(jnp.float32)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, -1)
        w, idx = jax.lax.top_k(probs, k)                    # (nl, k)
        w = (w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)).astype(dt)
        flat_idx = idx.reshape(-1)
        onehot = jax.nn.one_hot(flat_idx, e, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - 1
        slot = jnp.take_along_axis(pos, flat_idx[:, None], 1)[:, 0]
        keep = slot < cap_l
        slot = jnp.where(keep, slot, cap_l - 1)
        xrep = jnp.repeat(xl, k, axis=0)
        buf = jnp.zeros((e, cap_l, d), dt)
        buf = buf.at[flat_idx, slot].add(
            jnp.where(keep[:, None], xrep, 0).astype(dt))   # LOCAL
        # ship expert rows to their owners: ONE all-to-all
        buf2 = jax.lax.all_to_all(buf, ep_axes, split_axis=0,
                                  concat_axis=1, tiled=True)
        # (el, cap_l * n_ep, d) — this device's experts, everyone's rows
        h = jnp.einsum("ecd,edf->ecf", buf2, win.astype(dt))
        if cfg.swiglu:
            g = jnp.einsum("ecd,edf->ecf", buf2, wgate.astype(dt))
            h = jax.nn.silu(g) * h
        else:
            h = jax.nn.gelu(h)
        oute = jnp.einsum("ecf,efd->ecd", h, wout.astype(dt))
        back = jax.lax.all_to_all(oute, ep_axes, split_axis=1,
                                  concat_axis=0, tiled=True)
        gathered = back[flat_idx, slot]                     # LOCAL
        gathered = jnp.where(keep[:, None], gathered, 0)
        y = (gathered.reshape(nl, k, d) * w[..., None]).sum(1)
        return y.astype(dt)

    xf = x.reshape(n, d)
    wg = p.get("experts_gate", p["experts_in"])
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(_P(all_axes, None), _P(None, None),
                  _P(ep_axes, None, None), _P(ep_axes, None, None),
                  _P(ep_axes, None, None)),
        out_specs=_P(all_axes, None),
        check_rep=False)
    y = fn(xf, p["router"], p["experts_in"], wg, p["experts_out"])
    y = y.reshape(bsz, s, d)
    # re-pin a clean batch-sharded layout (the reshape of a fully
    # token-sharded vector otherwise yields an unparseable GSPMD split)
    dp = ("pod", "data") if "pod" in names else "data"
    y = _wsc(y, dp, None, None)
    if cfg.n_shared_experts:
        y = y + mlp_block(cfg, p["shared"], x)
    return y


def moe_block(cfg: ModelConfig, p: Params, x):
    """Capacity-based top-k MoE (Switch-style dense dispatch): static
    shapes, shards experts over the model axis, all-to-all under SPMD."""
    if cfg.moe_shard_map and "model" in _mesh_axis_names():
        return moe_block_shard_map(cfg, p, x)
    if cfg.moe_groups > 0 and (x.shape[0] * x.shape[1]) \
            % cfg.moe_groups == 0:
        return moe_block_grouped(cfg, p, x)
    bsz, s, d = x.shape
    n = bsz * s
    k, e = cfg.top_k, cfg.n_experts
    xf = x.reshape(n, d)
    logits = (xf @ p["router"].astype(jnp.float32)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    w, idx = jax.lax.top_k(probs, k)                       # (n, k)
    w = (w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)).astype(cfg.jdtype)

    cap = int(math.ceil(n * k / e * cfg.capacity_factor))
    cap = max(8, min(cap, n))
    cap = (cap + 7) // 8 * 8

    # position of each (token, slot) inside its expert's buffer
    flat_idx = idx.reshape(-1)                             # (n*k,)
    onehot = jax.nn.one_hot(flat_idx, e, dtype=jnp.int32)  # (n*k, e)
    pos = jnp.cumsum(onehot, axis=0) - 1
    slot = jnp.take_along_axis(pos, flat_idx[:, None], 1)[:, 0]
    keep = slot < cap
    slot = jnp.where(keep, slot, cap - 1)

    xrep = jnp.repeat(xf, k, axis=0)                       # (n*k, d)
    vals = jnp.where(keep[:, None], xrep, 0).astype(cfg.jdtype)
    hints = cfg.shard_hints and "model" in _mesh_axis_names()
    if hints:
        # §Perf variant: pin the dispatch layout so SPMD lowers the
        # scatter to an all-to-all (tokens: DP-sharded -> buffers:
        # expert-sharded) instead of all-gathering the token stream.
        from jax.sharding import PartitionSpec as _P
        vals = jax.lax.with_sharding_constraint(
            vals, _P(("pod", "data") if "pod" in
                     _mesh_axis_names() else "data", None))
    buf = jnp.zeros((e, cap, d), cfg.jdtype)
    buf = buf.at[flat_idx, slot].add(vals)
    if hints:
        from jax.sharding import PartitionSpec as _P
        espec = "model" if e % _mesh_axis_size("model") == 0 else None
        buf = jax.lax.with_sharding_constraint(buf,
                                               _P(espec, None, None))

    h = jnp.einsum("ecd,edf->ecf", buf, p["experts_in"].astype(cfg.jdtype))
    if cfg.swiglu:
        g = jnp.einsum("ecd,edf->ecf", buf,
                       p["experts_gate"].astype(cfg.jdtype))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    out = jnp.einsum("ecf,efd->ecd", h, p["experts_out"].astype(cfg.jdtype))

    gathered = out[flat_idx, slot]                         # (n*k, d)
    if hints:
        from jax.sharding import PartitionSpec as _P
        gathered = jax.lax.with_sharding_constraint(
            gathered, _P(("pod", "data") if "pod" in
                         _mesh_axis_names() else "data", None))
    gathered = jnp.where(keep[:, None], gathered, 0)
    combined = (gathered.reshape(n, k, d)
                * w[..., None]).sum(1).astype(cfg.jdtype)
    y = combined.reshape(bsz, s, d)
    if cfg.n_shared_experts:
        y = y + mlp_block(cfg, p["shared"], x)
    return y


def _rope_qk(cfg: ModelConfig, q, k, positions, mrope_positions=None):
    if cfg.mrope_sections is not None:
        mp = mrope_positions
        if mp is None:
            mp = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return (apply_mrope(q, mp, cfg.rope_theta, cfg.mrope_sections),
                apply_mrope(k, mp, cfg.rope_theta, cfg.mrope_sections))
    return (apply_rope(q, positions, cfg.rope_theta),
            apply_rope(k, positions, cfg.rope_theta))


def attn_block(cfg: ModelConfig, p: Params, x, positions,
               mrope_positions=None):
    """Full-sequence (train/prefill) attention, returns (out, (k, v)) —
    k/v in cache layout for prefill reuse."""
    bsz, s, d = x.shape
    dt = cfg.jdtype
    if cfg.use_mla:
        rd, hd = cfg.mla_rope_dim, cfg.hd
        cq = rmsnorm_latent(x @ p["wdq"].astype(dt), p["q_norm"], cfg)
        q = (cq @ p["wuq"].astype(dt)).reshape(bsz, s, cfg.n_heads, hd + rd)
        ckv_full = x @ p["wdkv"].astype(dt)
        ckv, k_rope = ckv_full[..., :cfg.mla_kv_rank], \
            ckv_full[..., cfg.mla_kv_rank:]
        ckv = rmsnorm_latent(ckv, p["kv_norm"], cfg)
        k_nope = (ckv @ p["wuk"].astype(dt)).reshape(bsz, s, cfg.n_heads, hd)
        v = (ckv @ p["wuv"].astype(dt)).reshape(bsz, s, cfg.n_heads, hd)
        q_nope, q_rope = q[..., :hd], q[..., hd:]
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
        k_rope = apply_rope(k_rope[:, :, None, :], positions,
                            cfg.rope_theta)
        qf = jnp.concatenate([q_nope, q_rope], -1)
        kf = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope,
                                      (bsz, s, cfg.n_heads, rd))], -1)
        o = attention(cfg, qf, kf, v, causal=True)
        out = o.reshape(bsz, s, cfg.q_dim) @ p["wo"].astype(dt)
        cache_kv = jnp.concatenate([ckv, k_rope[:, :, 0, :]], -1)
        return out, (cache_kv, None)

    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q, k, v = q + p["bq"].astype(dt), k + p["bk"].astype(dt), \
            v + p["bv"].astype(dt)
    q = q.reshape(bsz, s, cfg.n_heads, cfg.hd)
    k = k.reshape(bsz, s, cfg.kv_heads, cfg.hd)
    v = v.reshape(bsz, s, cfg.kv_heads, cfg.hd)
    q, k = _rope_qk(cfg, q, k, positions, mrope_positions)
    o = attention(cfg, q, k, v, causal=True)
    out = o.reshape(bsz, s, cfg.q_dim) @ p["wo"].astype(dt)
    return out, (k, v)


def rmsnorm_latent(x, p, cfg: ModelConfig):
    from .common import rmsnorm
    return rmsnorm(x, p["scale"], cfg.norm_eps)


def layer_fwd(cfg: ModelConfig, kind: str, p: Params, x, positions,
              mrope_positions=None):
    a, kv = attn_block(cfg, p["attn"], apply_norm(cfg, p["norm1"], x),
                       positions, mrope_positions)
    # fused norm_impl runs the attn-residual add + norm2 as one kernel
    x, h = apply_norm_residual(cfg, p["norm2"], x, a)
    if kind == "moe":
        x = x + moe_block(cfg, p["moe"], h)
    else:
        x = x + mlp_block(cfg, p["mlp"], h)
    return x, kv


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ModelConfig, params: Params, tokens):
    return jnp.take(params["embed"].astype(cfg.jdtype), tokens, axis=0)


def unembed(cfg: ModelConfig, params: Params, x):
    if cfg.tie_embeddings:
        return x @ params["embed"].astype(cfg.jdtype).T
    return x @ params["head"].astype(cfg.jdtype)


def _run_segments(cfg: ModelConfig, params: Params, x, positions,
                  mrope_positions=None, collect_kv: bool = False):
    """Run all layer segments; optionally collect per-layer (k, v) stacks
    (prefill).  Returns (x, list_of_kv_stacks_per_segment)."""
    kvs = []
    for seg in params["segments"]:
        kind = segment_kind(seg)
        sp = segment_params(seg)
        count = jax.tree_util.tree_leaves(sp)[0].shape[0]

        def body(h, lp):
            h2, kv = layer_fwd(cfg, kind, lp, h, positions, mrope_positions)
            return h2, (kv if collect_kv else None)

        body = maybe_remat(body, cfg)
        if cfg.scan_layers and count >= cfg.scan_min_layers:
            x, kv = jax.lax.scan(body, x, sp)
        else:
            kv_list = []
            for i in range(count):
                lp = jax.tree.map(lambda a: a[i], sp)
                x, kvi = body(x, lp)
                kv_list.append(kvi)
            kv = (jax.tree.map(lambda *xs: jnp.stack(xs), *kv_list)
                  if collect_kv else None)
        kvs.append(kv)
    return x, kvs


def forward(cfg: ModelConfig, params: Params, tokens=None, *,
            embeds=None, positions=None, mrope_positions=None,
            collect_kv: bool = False, return_hidden: bool = False):
    """Logits for a full sequence. `embeds` (B,S,d) may replace/augment
    tokens for modality-stub prefixes (vision/audio)."""
    if tokens is not None:
        x = embed_tokens(cfg, params, tokens)
        if embeds is not None:           # vision prefix + text suffix
            x = jnp.concatenate([embeds.astype(cfg.jdtype), x], axis=1)
    else:
        x = embeds.astype(cfg.jdtype)
    bsz, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (bsz, s))
    x, kvs = _run_segments(cfg, params, x, positions, mrope_positions,
                           collect_kv=collect_kv)
    x = apply_norm(cfg, params["final_norm"], x)
    if return_hidden and not collect_kv:
        return None, x, kvs
    logits = unembed(cfg, params, x)
    if collect_kv:
        return logits, x, kvs
    return logits


def chunked_cross_entropy(cfg: ModelConfig, params: Params, hidden,
                          labels, chunk: int = 512) -> jnp.ndarray:
    """§Perf variant (fused_ce): the (B, S, V) fp32 logits tensor is the
    training-memory hot spot for small-d/large-V archs; stream the
    unembed + CE over sequence chunks so only (B, chunk, V) is ever
    live."""
    b, s, d = hidden.shape
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    n = hidden.shape[1] // chunk
    hc = hidden.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    yc = labels.reshape(b, n, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        h, y = xs
        lf = unembed(cfg, params, h).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lf, axis=-1)
        ll = jnp.take_along_axis(lf, y[..., None].clip(0), -1)[..., 0]
        valid = (y != -1).astype(jnp.float32)
        return (carry[0] + ((lse - ll) * valid).sum(),
                carry[1] + valid.sum()), None

    (num, den), _ = jax.lax.scan(body, (0.0, 0.0), (hc, yc))
    return num / jnp.maximum(den, 1.0)


def loss_fn(cfg: ModelConfig, params: Params, batch) -> jnp.ndarray:
    """Cross-entropy LM loss; adds the MTP auxiliary loss when enabled
    (DeepSeek-V3-style single-depth MTP)."""
    tokens, labels = batch["tokens"], batch["labels"]
    embeds = batch.get("embeds")
    if cfg.fused_ce and not cfg.mtp:
        _, h, _ = forward(cfg, params, tokens, embeds=embeds,
                          collect_kv=False, return_hidden=True)
        if embeds is not None:
            h = h[:, embeds.shape[1]:]
        return chunked_cross_entropy(cfg, params, h, labels)
    if cfg.mtp:
        logits, h, _ = forward(cfg, params, tokens, embeds=embeds,
                               collect_kv=True)
    else:
        logits = forward(cfg, params, tokens, embeds=embeds)
    if embeds is not None:   # prefix positions carry no labels
        logits = logits[:, embeds.shape[1]:]
    loss = cross_entropy(logits, labels)
    if cfg.mtp:
        mp = params["mtp"]
        emb_next = embed_tokens(cfg, params,
                                jnp.pad(tokens[:, 1:], ((0, 0), (0, 1))))
        hh = jnp.concatenate([h, emb_next], -1) @ mp["proj"].astype(cfg.jdtype)
        bsz, s, _ = hh.shape
        pos = jnp.broadcast_to(jnp.arange(s)[None], (bsz, s))
        hh, _ = layer_fwd(cfg, "dense", mp["layer"], hh, pos)
        hh = apply_norm(cfg, mp["norm"], hh)
        mtp_logits = unembed(cfg, params, hh)
        mtp_labels = jnp.pad(labels[:, 1:], ((0, 0), (0, 1)),
                             constant_values=-1)
        loss = loss + 0.3 * cross_entropy(mtp_logits, mtp_labels)
    return loss


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------

def cache_len(cfg: ModelConfig, max_len: int) -> int:
    """Ring-buffer length: SWA archs only ever need `window` slots."""
    return min(max_len, cfg.window) if cfg.window else max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> Params:
    dt = dtype or cfg.jdtype
    clen = cache_len(cfg, max_len)
    segs = []
    for kind, count in layer_segments(cfg):
        if cfg.use_mla:
            kv = jnp.zeros((count, batch, clen,
                            cfg.mla_kv_rank + cfg.mla_rope_dim), dt)
            segs.append({"latent": kv})
        else:
            segs.append({
                "k": jnp.zeros((count, batch, clen, cfg.kv_heads, cfg.hd), dt),
                "v": jnp.zeros((count, batch, clen, cfg.kv_heads, cfg.hd), dt),
            })
    return {"segments": segs, "index": jnp.zeros((), jnp.int32)}


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                     dtype=None) -> list:
    """Per-segment KV page pools: the paged analogue of `init_cache`'s
    (L, B, C, ...) slabs with the (B, C) rectangle replaced by a shared
    (num_pages, page_size) pool.  Page 0 is reserved as the null page
    every unused page-table entry points at; its contents are never read
    (decode masks by per-slot length).  Slot ownership / page tables live
    with the serving engine (`repro.serving.paged.PagePool`)."""
    dt = dtype or cfg.jdtype
    segs = []
    for kind, count in layer_segments(cfg):
        if cfg.use_mla:
            segs.append({"latent": jnp.zeros(
                (count, num_pages, page_size,
                 cfg.mla_kv_rank + cfg.mla_rope_dim), dt)})
        else:
            segs.append({
                "k": jnp.zeros((count, num_pages, page_size,
                                cfg.kv_heads, cfg.hd), dt),
                "v": jnp.zeros((count, num_pages, page_size,
                                cfg.kv_heads, cfg.hd), dt),
            })
    return segs


def _ring_slot(cfg: ModelConfig, index, clen: int):
    return index % clen if cfg.window else index


def _cache_positions(cfg: ModelConfig, index, clen: int):
    """Absolute position held by each cache slot (ring-aware); -1 invalid.
    index: (B,) vector -> returns (B, clen)."""
    j = jnp.arange(clen)[None, :]
    idx = index[:, None]
    if cfg.window:
        # slot j holds the largest p <= index with p % clen == j
        p = idx - ((idx - j) % clen)
        return jnp.where(p >= 0, p, -1)
    return jnp.where(j <= idx, j, -1)


def _scatter_slot(cache_arr, new_entry, slot):
    """cache_arr (B, C, ...) <- new_entry (B, 1, ...) at per-batch slot."""
    b = cache_arr.shape[0]
    return cache_arr.at[jnp.arange(b), slot].set(
        new_entry[:, 0].astype(cache_arr.dtype))


def _decode_attn(cfg: ModelConfig, p: Params, x, seg_cache, index):
    """One-token attention against the cache. x: (B,1,d); index: (B,)."""
    bsz = x.shape[0]
    dt = cfg.jdtype
    pos1 = index[:, None].astype(jnp.int32)

    if cfg.use_mla:
        rd, hd, kvr = cfg.mla_rope_dim, cfg.hd, cfg.mla_kv_rank
        cq = rmsnorm_latent(x @ p["wdq"].astype(dt), p["q_norm"], cfg)
        q = (cq @ p["wuq"].astype(dt)).reshape(bsz, 1, cfg.n_heads, hd + rd)
        q_nope, q_rope = q[..., :hd], q[..., hd:]
        q_rope = apply_rope(q_rope, pos1, cfg.rope_theta)
        ckv_full = x @ p["wdkv"].astype(dt)
        ckv, k_rope = ckv_full[..., :kvr], ckv_full[..., kvr:]
        ckv = rmsnorm_latent(ckv, p["kv_norm"], cfg)
        k_rope = apply_rope(k_rope[:, :, None, :], pos1, cfg.rope_theta)
        new_entry = jnp.concatenate([ckv, k_rope[:, :, 0, :]], -1)  # (B,1,D)
        clen = seg_cache["latent"].shape[1]
        slot = _ring_slot(cfg, index, clen)
        cache = _scatter_slot(seg_cache["latent"], new_entry, slot)
        # (B, C, kvr+rd)
        lat, lat_rope = cache[..., :kvr], cache[..., kvr:]
        # absorbed attention: q_nope^T W_uk c_kv
        wuk = p["wuk"].astype(dt).reshape(kvr, cfg.n_heads, hd)
        q_abs = jnp.einsum("bqhd,khd->bqhk", q_nope, wuk)     # (B,1,H,kvr)
        s_n = jnp.einsum("bqhk,bck->bhqc", q_abs, lat.astype(dt))
        s_r = jnp.einsum("bqhd,bcd->bhqc", q_rope, lat_rope.astype(dt))
        scores = (s_n + s_r).astype(jnp.float32) / math.sqrt(hd + rd)
        kpos = _cache_positions(cfg, index, lat.shape[1])     # (B, C)
        mask = (kpos >= 0) & (kpos <= index[:, None])
        if cfg.window:
            mask &= kpos > index[:, None] - cfg.window
        scores = jnp.where(mask[:, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, -1).astype(dt)
        o_lat = jnp.einsum("bhqc,bck->bqhk", probs, lat.astype(dt))
        wuv = p["wuv"].astype(dt).reshape(kvr, cfg.n_heads, hd)
        o = jnp.einsum("bqhk,khd->bqhd", o_lat, wuv)
        out = o.reshape(bsz, 1, cfg.q_dim) @ p["wo"].astype(dt)
        return out, {"latent": cache}

    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q, k, v = q + p["bq"].astype(dt), k + p["bk"].astype(dt), \
            v + p["bv"].astype(dt)
    q = q.reshape(bsz, 1, cfg.n_heads, cfg.hd)
    k = k.reshape(bsz, 1, cfg.kv_heads, cfg.hd)
    v = v.reshape(bsz, 1, cfg.kv_heads, cfg.hd)
    q, k = _rope_qk(cfg, q, k, pos1)
    K, V = seg_cache["k"], seg_cache["v"]           # (B, C, kvh, hd)
    clen = K.shape[1]
    slot = _ring_slot(cfg, index, clen)
    K = _scatter_slot(K, k, slot)
    V = _scatter_slot(V, v, slot)
    n_rep = cfg.n_heads // cfg.kv_heads
    kpos = _cache_positions(cfg, index, clen)       # (B, C)
    mask = (kpos >= 0) & (kpos <= index[:, None])
    if cfg.window:
        mask &= kpos > index[:, None] - cfg.window

    if cfg.gqa_einsum and n_rep > 1:
        # §Perf variant: grouped attention — contract each query-head
        # group against its kv head directly; the cache is read ONCE
        # instead of materializing an n_rep-times-expanded copy.
        qg = q.reshape(bsz, 1, cfg.kv_heads, n_rep, cfg.hd)
        scores = jnp.einsum("bqkgd,bckd->bkgqc", qg, K.astype(dt)) \
            .astype(jnp.float32) / math.sqrt(cfg.hd)
        scores = jnp.where(mask[:, None, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, -1).astype(dt)
        o = jnp.einsum("bkgqc,bckd->bqkgd", probs, V.astype(dt))
        o = o.reshape(bsz, 1, cfg.n_heads, cfg.hd)
    else:
        Kr = jnp.repeat(K.astype(dt), n_rep, axis=2) if n_rep > 1 \
            else K.astype(dt)
        Vr = jnp.repeat(V.astype(dt), n_rep, axis=2) if n_rep > 1 \
            else V.astype(dt)
        scores = jnp.einsum("bqhd,bchd->bhqc", q, Kr) \
            .astype(jnp.float32) / math.sqrt(cfg.hd)
        scores = jnp.where(mask[:, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, -1).astype(dt)
        o = jnp.einsum("bhqc,bchd->bqhd", probs, Vr)
    out = o.reshape(bsz, 1, cfg.q_dim) @ p["wo"].astype(dt)
    return out, {"k": K, "v": V}


def _decode_layer(cfg: ModelConfig, kind: str, p: Params, x, seg_cache,
                  index):
    a, new_cache = _decode_attn(cfg, p["attn"],
                                apply_norm(cfg, p["norm1"], x),
                                seg_cache, index)
    x, h = apply_norm_residual(cfg, p["norm2"], x, a)
    if kind == "moe":
        x = x + moe_block(cfg, p["moe"], h)
    else:
        x = x + mlp_block(cfg, p["mlp"], h)
    return x, new_cache


def decode_step(cfg: ModelConfig, params: Params, tokens, cache):
    """One decode step. tokens: (B, 1) int32. Returns (logits, cache).
    cache["index"] may be a scalar (uniform lengths) or a (B,) vector
    (continuous batching with mixed-length slots)."""
    raw_index = jnp.asarray(cache["index"])
    index = raw_index if raw_index.ndim == 1 \
        else jnp.full((tokens.shape[0],), raw_index, jnp.int32)
    x = embed_tokens(cfg, params, tokens)
    new_segs = []
    for seg, seg_cache in zip(params["segments"], cache["segments"]):
        kind = segment_kind(seg)
        sp = segment_params(seg)
        count = jax.tree_util.tree_leaves(sp)[0].shape[0]

        def body(h, xs):
            lp, lc = xs
            h2, nc = _decode_layer(cfg, kind, lp, h, lc, index)
            return h2, nc

        if cfg.scan_layers and count >= cfg.scan_min_layers:
            x, nc = jax.lax.scan(body, x, (sp, seg_cache))
        else:
            ncs = []
            for i in range(count):
                lp = jax.tree.map(lambda a: a[i], sp)
                lc = jax.tree.map(lambda a: a[i], seg_cache)
                x, nci = body(x, (lp, lc))
                ncs.append(nci)
            nc = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
        new_segs.append(nc)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params, x)
    return logits, {"segments": new_segs, "index": raw_index + 1}


def _window_attn(cfg: ModelConfig, p: Params, x, seg_cache, pos):
    """W-token cached attention (spec-decode verify). x: (B, W, d);
    pos: (B, W) absolute positions.  Plain (non-MLA, non-ring) path:
    position p writes cache slot p directly and attends causally to
    every slot <= its own position."""
    bsz, w = x.shape[0], x.shape[1]
    dt = cfg.jdtype
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q, k, v = q + p["bq"].astype(dt), k + p["bk"].astype(dt), \
            v + p["bv"].astype(dt)
    q = q.reshape(bsz, w, cfg.n_heads, cfg.hd)
    k = k.reshape(bsz, w, cfg.kv_heads, cfg.hd)
    v = v.reshape(bsz, w, cfg.kv_heads, cfg.hd)
    q, k = _rope_qk(cfg, q, k, pos)
    K, V = seg_cache["k"], seg_cache["v"]           # (B, C, kvh, hd)
    rows = jnp.arange(bsz)[:, None]
    K = K.at[rows, pos].set(k.astype(K.dtype))
    V = V.at[rows, pos].set(v.astype(V.dtype))
    n_rep = cfg.n_heads // cfg.kv_heads
    Kr = jnp.repeat(K.astype(dt), n_rep, axis=2) if n_rep > 1 \
        else K.astype(dt)
    Vr = jnp.repeat(V.astype(dt), n_rep, axis=2) if n_rep > 1 \
        else V.astype(dt)
    scores = jnp.einsum("bqhd,bchd->bhqc", q, Kr) \
        .astype(jnp.float32) / math.sqrt(cfg.hd)
    mask = jnp.arange(K.shape[1])[None, None, :] <= pos[:, :, None]
    scores = jnp.where(mask[:, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, -1).astype(dt)
    o = jnp.einsum("bhqc,bchd->bqhd", probs, Vr)
    out = o.reshape(bsz, w, cfg.q_dim) @ p["wo"].astype(dt)
    return out, {"k": K, "v": V}


def window_supported(cfg: ModelConfig) -> bool:
    """Configs `decode_window` handles: plain linear-cache attention."""
    return (cfg.family == "transformer" and not cfg.use_mla
            and not cfg.window)


def decode_window(cfg: ModelConfig, params: Params, tokens, cache):
    """Verify W speculated tokens in ONE cached forward.

    tokens: (B, W) int32 at positions index..index+W-1; their KV is
    written into the cache and logits for every window position come
    back as (B, W, vocab).  The caller rewinds over-written positions
    simply by resetting `cache["index"]` — slots past the index are
    masked out of every later attention, so stale KV is harmless.
    """
    if not window_supported(cfg):
        raise NotImplementedError(
            "decode_window: plain-attention transformer only "
            f"(family={cfg.family}, mla={cfg.use_mla}, "
            f"window={cfg.window})")
    raw_index = jnp.asarray(cache["index"])
    bsz, w = tokens.shape
    index = raw_index if raw_index.ndim == 1 \
        else jnp.full((bsz,), raw_index, jnp.int32)
    pos = index[:, None] + jnp.arange(w, dtype=jnp.int32)[None]
    x = embed_tokens(cfg, params, tokens)
    new_segs = []
    for seg, seg_cache in zip(params["segments"], cache["segments"]):
        kind = segment_kind(seg)
        sp = segment_params(seg)
        count = jax.tree_util.tree_leaves(sp)[0].shape[0]
        ncs = []
        for i in range(count):
            lp = jax.tree.map(lambda a: a[i], sp)
            lc = jax.tree.map(lambda a: a[i], seg_cache)
            a, nci = _window_attn(cfg, lp["attn"],
                                  apply_norm(cfg, lp["norm1"], x), lc, pos)
            x, h = apply_norm_residual(cfg, lp["norm2"], x, a)
            if kind == "moe":
                x = x + moe_block(cfg, lp["moe"], h)
            else:
                x = x + mlp_block(cfg, lp["mlp"], h)
            ncs.append(nci)
        new_segs.append(jax.tree.map(lambda *xs: jnp.stack(xs), *ncs))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params, x)
    return logits, {"segments": new_segs, "index": raw_index + w}


def prefill(cfg: ModelConfig, params: Params, tokens, max_len: int, *,
            embeds=None):
    """Run the prompt, fill the cache, return (last_logits, cache)."""
    logits, _, kvs = forward(cfg, params, tokens, embeds=embeds,
                             collect_kv=True)
    bsz = (tokens if tokens is not None else embeds).shape[0]
    s = logits.shape[1]
    cache = init_cache(cfg, bsz, max_len)
    clen = cache_len(cfg, max_len)

    def _place(src, seq_axis):
        """Write the last `take` positions into the (ring) cache so that
        position p lands in slot p % clen (ring invariant)."""
        take = min(s, clen)
        last = jax.lax.slice_in_dim(src, s - take, s, axis=seq_axis)
        if take < clen:          # prompt shorter than cache: slots 0..s-1
            pads = [(0, 0)] * src.ndim
            pads[seq_axis] = (0, clen - take)
            return jnp.pad(last, pads)
        if cfg.window:           # full ring: roll so slot j holds p%clen==j
            return jnp.roll(last, shift=s % clen, axis=seq_axis)
        return last

    new_segs = []
    for seg_kv, seg_cache in zip(kvs, cache["segments"]):
        if cfg.use_mla:
            lat = seg_kv[0]                      # (L, B, S, kvr+rd)
            new_segs.append(
                {"latent": _place(lat, 2).astype(
                    seg_cache["latent"].dtype)})
        else:
            k, v = seg_kv                        # (L, B, S, kvh, hd)
            new_segs.append({
                "k": _place(k, 2).astype(seg_cache["k"].dtype),
                "v": _place(v, 2).astype(seg_cache["v"].dtype)})
    return logits[:, -1:], {"segments": new_segs,
                            "index": jnp.asarray(s, jnp.int32)}
