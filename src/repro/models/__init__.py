from . import api, common, config, rglru, rwkv6, transformer, whisper
from .config import ModelConfig, smoke_config
