"""Deployment scenarios & constraints (paper §6.2, Tables 2 and 5).

Constraint-aware system-level optimization: each scenario fixes latency
requirements and the metric of record, and the codesign layers search
within them.
"""
from __future__ import annotations

import dataclasses

from .fusion import Requirement

# Paper Table 5 — latency requirements of workloads.
CHATBOT = Requirement(ttft=2.5, tpot=0.15)
SUMMARIZATION = Requirement(ttft=15.0, tpot=0.15)
AV_FAST = Requirement(e2e=0.010)       # 10 ms DET deadline
AV_REALTIME = Requirement(e2e=0.033)   # 33 ms / 30 FPS

# Speculative decoding (paper §6.2.1): OPT-66B target + OPT-1.3B draft,
# token acceptance rate 5.6 with k >= 5, realized speedup capped at 2x.
SPECDEC_TAR = 5.6
SPECDEC_K = 5
SPECDEC_SPEEDUP_CAP = 2.0


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    metric: str                    # objective for the codesign search
    requirement: Requirement
    description: str = ""


DATACENTER_CHATBOT = Scenario("chatbot", "energy_cost", CHATBOT,
                              "OPT-66B interactive serving")
DATACENTER_SUMMARIZATION = Scenario("summarization", "energy_cost",
                                    SUMMARIZATION, "OPT-66B summarization")
AUTONOMOUS_VEHICLE_10MS = Scenario("av_10ms", "energy_cost", AV_FAST,
                                   "perception backbone, 10 ms DET")
AUTONOMOUS_VEHICLE_33MS = Scenario("av_33ms", "energy_cost", AV_REALTIME,
                                   "perception backbone, 33 ms DET")


def spec_decode_step_latency(t_draft_token: float, t_verify_batch: float,
                             k: int = SPECDEC_K) -> float:
    """One speculative iteration: draft k tokens serially, verify batched."""
    return k * t_draft_token + t_verify_batch


def spec_decode_throughput(t_draft_token: float, t_verify_batch: float,
                           tar: float = SPECDEC_TAR,
                           k: int = SPECDEC_K) -> float:
    """Accepted tokens/s: TAR tokens land per iteration on average."""
    t_iter = spec_decode_step_latency(t_draft_token, t_verify_batch, k)
    return min(tar, k + 1) / t_iter
