"""Deployment scenarios & constraints (paper §6.2, Tables 2 and 5).

Constraint-aware system-level optimization: each scenario fixes latency
requirements and the metric of record, and the codesign layers search
within them.  Scenarios are first-class, named, and serializable so a
declarative `repro.mozart.MozartSpec` can select them by name
(`get_scenario("chatbot")`); speculative decoding is a `Scenario` like
the other four, with per-role (draft / target) requirement handling.
"""
from __future__ import annotations

import dataclasses

from .fusion import Requirement

# Paper Table 5 — latency requirements of workloads.
CHATBOT = Requirement(ttft=2.5, tpot=0.15)
SUMMARIZATION = Requirement(ttft=15.0, tpot=0.15)
AV_FAST = Requirement(e2e=0.010)       # 10 ms DET deadline
AV_REALTIME = Requirement(e2e=0.033)   # 33 ms / 30 FPS

# Speculative decoding (paper §6.2.1): OPT-66B target + OPT-1.3B draft,
# token acceptance rate 5.6 with k >= 5, realized speedup capped at 2x.
SPECDEC_TAR = 5.6
SPECDEC_K = 5
SPECDEC_SPEEDUP_CAP = 2.0


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    metric: str                    # objective for the codesign search
    requirement: Requirement
    description: str = ""

    # Roles a network can play in this scenario; () = role-free.
    roles: tuple[str, ...] = ()

    def requirement_for(self, role: str = "") -> Requirement:
        """Latency requirement for one network of the scenario.  Plain
        scenarios are role-free and return their single requirement."""
        if role and self.roles and role not in self.roles:
            raise ValueError(
                f"scenario {self.name!r} has roles {self.roles}, "
                f"not {role!r}")
        return self.requirement

    def to_dict(self) -> dict:
        return {"kind": "basic", "name": self.name, "metric": self.metric,
                "requirement": self.requirement.to_dict(),
                "description": self.description,
                "roles": list(self.roles)}

    @staticmethod
    def from_dict(d: dict) -> "Scenario":
        kind = d.get("kind", "basic")
        req = Requirement.from_dict(d["requirement"])
        if kind == "spec_decode":
            return SpecDecodeScenario(
                name=d["name"], metric=d["metric"], requirement=req,
                description=d.get("description", ""),
                roles=tuple(d.get("roles", ("draft", "target"))),
                tar=d.get("tar", SPECDEC_TAR), k=d.get("k", SPECDEC_K),
                speedup_cap=d.get("speedup_cap", SPECDEC_SPEEDUP_CAP))
        return Scenario(name=d["name"], metric=d["metric"],
                        requirement=req,
                        description=d.get("description", ""),
                        roles=tuple(d.get("roles", ())))


@dataclasses.dataclass(frozen=True)
class SpecDecodeScenario(Scenario):
    """Speculative decoding as a first-class scenario (paper §6.2.1).

    Two networks participate: a latency-critical *draft* model decoding
    k tokens serially, and a throughput-oriented *target* model verifying
    the k+1-token window in one batched pass (Insight 3).  The scenario's
    base `requirement` is the per-accepted-token QoS (e.g. chatbot TPOT);
    `requirement_for` splits one iteration's budget into per-role
    deadlines: TAR tokens land per iteration on average, so the iteration
    budget is `accepted * tpot`, divided equally over the k serial draft
    steps and the single verify pass (the paper's Fig. 11 protocol uses
    the same equal split against its capped token rate).
    """
    roles: tuple[str, ...] = ("draft", "target")
    tar: float = SPECDEC_TAR
    k: int = SPECDEC_K
    speedup_cap: float = SPECDEC_SPEEDUP_CAP

    @property
    def accepted_per_iteration(self) -> float:
        return min(self.tar, self.k + 1)

    def _slot(self) -> float:
        tpot = self.requirement.max_e2e
        if tpot is None:
            raise ValueError(
                "spec-decode scenario needs a finite base requirement")
        return self.accepted_per_iteration * tpot / (self.k + 1)

    def requirement_for(self, role: str = "") -> Requirement:
        if not role:
            return self.requirement
        if role == "draft":
            # k serial single-token decodes per iteration.
            return Requirement(tpot=self._slot())
        if role == "target":
            # one batched verify pass over the k+1-token window.
            return Requirement(e2e=self._slot())
        raise ValueError(
            f"scenario {self.name!r} has roles {self.roles}, not {role!r}")

    def to_dict(self) -> dict:
        d = super().to_dict()
        d.update(kind="spec_decode", tar=self.tar, k=self.k,
                 speedup_cap=self.speedup_cap)
        return d


DATACENTER_CHATBOT = Scenario("chatbot", "energy_cost", CHATBOT,
                              "OPT-66B interactive serving")
DATACENTER_SUMMARIZATION = Scenario("summarization", "energy_cost",
                                    SUMMARIZATION, "OPT-66B summarization")
AUTONOMOUS_VEHICLE_10MS = Scenario("av_10ms", "energy_cost", AV_FAST,
                                   "perception backbone, 10 ms DET")
AUTONOMOUS_VEHICLE_33MS = Scenario("av_33ms", "energy_cost", AV_REALTIME,
                                   "perception backbone, 33 ms DET")
SPECULATIVE_DECODING = SpecDecodeScenario(
    "spec_decode", "energy_cost", CHATBOT,
    "OPT-66B target + OPT-1.3B draft, TAR 5.6, k>=5, 2x speedup cap")

SCENARIOS: dict[str, Scenario] = {
    s.name: s for s in (DATACENTER_CHATBOT, DATACENTER_SUMMARIZATION,
                        AUTONOMOUS_VEHICLE_10MS, AUTONOMOUS_VEHICLE_33MS,
                        SPECULATIVE_DECODING)
}


def get_scenario(name: str) -> Scenario:
    """Look a scenario up by name (the `MozartSpec.scenario` strings)."""
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; known: {sorted(SCENARIOS)}"
        ) from None


def spec_decode_step_latency(t_draft_token: float, t_verify_batch: float,
                             k: int = SPECDEC_K) -> float:
    """One speculative iteration: draft k tokens serially, verify batched."""
    return k * t_draft_token + t_verify_batch


def spec_decode_throughput(t_draft_token: float, t_verify_batch: float,
                           tar: float = SPECDEC_TAR,
                           k: int = SPECDEC_K) -> float:
    """Accepted tokens/s: TAR tokens land per iteration on average."""
    t_iter = spec_decode_step_latency(t_draft_token, t_verify_batch, k)
    return min(tar, k + 1) / t_iter
