"""The Mozart hierarchical codesign driver (paper Fig. 5).

Layer 1 (pool.anneal_pool)      — SA over chiplet pool composition
Layer 2 (fusion.optimize_fusion)— GA over tensor fusion + memory allocation
Layer 3 (convexhull.solve_pipeline) — iso-latency + modified convex hull
Layer 4 (pnr.place_and_route)   — physical feasibility + footprint

This module is the *mechanism* layer.  The supported entry point for
running the stack is the `repro.mozart` facade: declare a `MozartSpec`
(networks, scenario, objective, budgets) and call
`mozart.compile(spec)`, which drives the functions below and returns a
serializable `Deployment` artifact (designs, policies, baselines).

`design_for_network` runs Layers 2–4 for one network on a fixed pool;
`run_codesign` runs the whole stack and returns the ecosystem + BASICs.
Both remain public for low-level/benchmark use.

Default search budgets are the raised, benchmark-justified ones
(SAConfig.iterations=16, GAConfig.generations=24 — see
benchmarks/bench_budget_scaling.py), not the paper's Table 4 toy
settings; pass explicit configs to reproduce the paper budgets.  The
per-network evaluation fan-out is controlled by `SAConfig.workers` /
`SAConfig.executor` (or MOZART_WORKERS / MOZART_EXECUTOR); with the
process executor, `SAConfig.warmup` (MOZART_WARMUP, default on) shares
the per-SKU option cache across workers via a pre-fork shared-memory
warmup.  Layer-3 runs generation-batched through
`convexhull.solve_pipeline_batch` (MOZART_BATCH_SOLVE=0 restores the
per-genome loop); every knob is bit-identical for a fixed seed.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from .chiplets import Chiplet, default_pool, full_design_space
from .engine import DEFAULT_ENGINE, EvaluationEngine, engine_enabled
from .fusion import (FusionResult, GAConfig, Requirement, optimize_fusion)
from .operators import OperatorGraph
from .pnr import PnrResult, place_and_route
from .pool import PoolResult, SAConfig, anneal_pool, evaluate_pool


@dataclasses.dataclass
class BasicDesign:
    """A composed BASIC: fusion plan + stage configs + physical layout."""
    network: str
    fusion: FusionResult
    pnr: PnrResult

    @property
    def metrics(self) -> dict[str, float]:
        m = self.fusion.solution.metrics()
        m["pnr_area_mm2"] = self.pnr.area_mm2
        m["pnr_feasible"] = float(self.pnr.feasible)
        return m

    def to_dict(self) -> dict:
        return {"network": self.network, "fusion": self.fusion.to_dict(),
                "pnr": self.pnr.to_dict()}

    @staticmethod
    def from_dict(d: dict) -> "BasicDesign":
        return BasicDesign(network=d["network"],
                           fusion=FusionResult.from_dict(d["fusion"]),
                           pnr=PnrResult.from_dict(d["pnr"]))


@dataclasses.dataclass
class CodesignResult:
    pool: list[Chiplet]
    designs: dict[str, BasicDesign]
    objective: str

    def pool_labels(self) -> list[str]:
        return [c.label for c in self.pool]

    def chiplet_reuse(self) -> dict[str, int]:
        return chiplet_reuse(self.designs.values())

    def to_dict(self) -> dict:
        return {"pool": [c.to_dict() for c in self.pool],
                "designs": {n: d.to_dict()
                            for n, d in self.designs.items()},
                "objective": self.objective}

    @staticmethod
    def from_dict(d: dict) -> "CodesignResult":
        return CodesignResult(
            pool=[Chiplet.from_dict(c) for c in d["pool"]],
            designs={n: BasicDesign.from_dict(b)
                     for n, b in d["designs"].items()},
            objective=d["objective"])


def chiplet_reuse(designs: Iterable[BasicDesign]) -> dict[str, int]:
    """How many BASIC designs use each pool chiplet (NRE amortization).
    Keys appear in pipeline-stage order (deterministic across runs)."""
    reuse: dict[str, int] = {}
    for d in designs:
        used = dict.fromkeys(o.cfg.chiplet.label
                             for o in d.fusion.solution.stages)
        for u in used:
            reuse[u] = reuse.get(u, 0) + 1
    return reuse


def design_for_network(graph: OperatorGraph,
                       pool: Sequence[Chiplet],
                       objective: str = "energy",
                       req: Requirement | None = None,
                       ga: GAConfig | None = None,
                       engine: EvaluationEngine | None = None
                       ) -> BasicDesign | None:
    """Layers 2-4 for one network on a fixed chiplet pool."""
    req = req if req is not None else Requirement()
    ga = ga if ga is not None else GAConfig()
    if engine is None and engine_enabled():
        engine = DEFAULT_ENGINE
    if engine is not None:
        fr = engine.evaluate_network(pool, graph, objective, req, ga)
    else:
        fr = optimize_fusion(graph, pool, objective=objective, req=req,
                             cfg=ga)
    if fr is None:
        return None
    pnr = place_and_route(fr.solution.stages)
    return BasicDesign(network=graph.network, fusion=fr, pnr=pnr)


def run_codesign(networks: dict[str, OperatorGraph],
                 objective: str = "energy",
                 pool_size: int = 8,
                 reqs: dict[str, Requirement] | None = None,
                 sa: SAConfig | None = None,
                 final_ga: GAConfig | None = None,
                 engine: EvaluationEngine | None = None) -> CodesignResult:
    """The full four-layer Mozart flow."""
    sa = sa if sa is not None else SAConfig()
    final_ga = final_ga if final_ga is not None else GAConfig()
    pr: PoolResult = anneal_pool(networks, objective=objective,
                                 pool_size=pool_size, reqs=reqs, cfg=sa,
                                 final_ga=final_ga, engine=engine)
    designs: dict[str, BasicDesign] = {}
    reqs = reqs or {}
    # The anneal's final full-budget re-eval just populated the engine
    # cache for (pr.pool, network, final_ga), so this loop only pays for
    # the Layer-4 P&R.
    for name, graph in networks.items():
        d = design_for_network(graph, pr.pool, objective=objective,
                               req=reqs.get(name, Requirement()),
                               ga=final_ga, engine=engine)
        if d is not None:
            designs[name] = d
    return CodesignResult(pool=pr.pool, designs=designs, objective=objective)


def unconstrained_design(graph: OperatorGraph,
                         objective: str = "energy",
                         req: Requirement | None = None,
                         ga: GAConfig | None = None) -> BasicDesign | None:
    """Upper bound: unlimited chiplet variety (paper's 'Heterogeneous
    BASIC (unconstrained)') — the whole 96-point design space as the pool."""
    return design_for_network(graph, full_design_space(), objective=objective,
                              req=req, ga=ga)


def homogeneous_design(graph: OperatorGraph,
                       chiplet: Chiplet,
                       objective: str = "energy",
                       req: Requirement | None = None,
                       ga: GAConfig | None = None) -> BasicDesign | None:
    """Baseline: a single chiplet SKU for every stage (paper's
    'Homogeneous BASIC' / 'Homogeneous ASIC' paradigms)."""
    ga = ga or GAConfig()
    return design_for_network(graph, [chiplet], objective=objective,
                              req=req, ga=ga)


def best_homogeneous_design(graph: OperatorGraph,
                            candidates: Sequence[Chiplet] | None = None,
                            objective: str = "energy",
                            req: Requirement | None = None,
                            ga: GAConfig | None = None) -> BasicDesign | None:
    """The best single-SKU accelerator — the fair homogeneous baseline.

    The baseline runs at the caller's GA budget (default: the full
    `GAConfig()` budget) so it is searched as hard as the heterogeneous
    design it is compared against; a reduced budget here would bias the
    comparison in Mozart's favor.
    """
    ga = ga if ga is not None else GAConfig()
    cands = list(candidates) if candidates is not None else default_pool()
    best: BasicDesign | None = None
    for c in cands:
        d = homogeneous_design(graph, c, objective=objective, req=req, ga=ga)
        if d is None:
            continue
        if best is None or d.fusion.value < best.fusion.value:
            best = d
    return best
