"""Cross-layer evaluation engine for the Mozart codesign stack.

Three independent accelerations over the seed implementation, all
result-preserving for a fixed seed:

  * vectorization — `perfmodel.enumerate_stage_options` evaluates the
    whole (chiplet x memory x mem_units x tp x batch) grid of a fusion
    group with batched NumPy instead of per-option scalar math, and
    `convexhull.solve_pipeline` sweeps the iso-latency grid as a dense
    (options x latencies) array min instead of a Python hull walk;
  * memoization — Layer-2 GA results are cached per
    (pool fingerprint, network, objective, requirement, GA budget), so
    SA iterations that revisit a pool (rejected moves, identity
    mutations, the final full-budget re-eval) skip the GA entirely, and
    stage options are additionally cached per *single chiplet* so a
    one-SKU neighbor move only enumerates options for the new SKU;
  * parallelism — `evaluate_pool`'s per-network loop can fan out over a
    thread pool or, since the GA inner loop is GIL-bound Python, a
    spawn-safe process pool (`workers` / MOZART_WORKERS for the width,
    `executor` / MOZART_EXECUTOR=thread|process for the kind).  Process
    workers are persistent and keep their own cache shard (engine memo +
    fusion option caches live for the worker's lifetime); results are
    merged back into the parent engine's memo, and any failure to spawn
    falls back to the thread path.

`MOZART_DISABLE_ENGINE=1` (or `set_engine_enabled(False)`) restores the
seed's scalar, uncached behavior — used by
benchmarks/bench_codesign_search.py for before/after timing.
"""
from __future__ import annotations

import atexit
import math
import multiprocessing
import os
import threading
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from dataclasses import astuple
from typing import TYPE_CHECKING, Sequence

if TYPE_CHECKING:   # pragma: no cover - type-only; avoids an import cycle
    from .chiplets import Chiplet
    from .fusion import FusionResult, GAConfig, Requirement
    from .operators import OperatorGraph

_enabled = os.environ.get("MOZART_DISABLE_ENGINE", "0") != "1"


def engine_enabled() -> bool:
    """Global switch consulted by perfmodel/convexhull/fusion/pool."""
    return _enabled


def set_engine_enabled(flag: bool) -> None:
    global _enabled
    _enabled = bool(flag)


def _default_workers() -> int:
    try:
        return int(os.environ.get("MOZART_WORKERS", "0") or 0)
    except ValueError:
        return 0


EXECUTOR_KINDS = ("thread", "process")


def _default_executor() -> str:
    kind = os.environ.get("MOZART_EXECUTOR", "thread").strip().lower()
    return kind if kind in EXECUTOR_KINDS else "thread"


def _process_worker(enabled: bool, pool: tuple, graph: "OperatorGraph",
                    objective: str, req: "Requirement",
                    ga: "GAConfig") -> "FusionResult | None":
    """Entry point run inside a spawned worker process.

    Evaluates one (pool, network) GA through the worker's own
    DEFAULT_ENGINE, so each worker accumulates an independent cache shard
    (engine memo + fusion option caches) that persists across tasks for
    the life of the worker.  `enabled` carries the parent's engine switch
    across the spawn boundary."""
    set_engine_enabled(enabled)
    return DEFAULT_ENGINE.evaluate_network(list(pool), graph, objective,
                                           req, ga)


class EvaluationEngine:
    """Memoized, optionally parallel evaluator for (pool, network) pairs.

    The cache key covers everything `fusion.optimize_fusion` depends on:
    the exact pool composition (order-sensitive — the GA's roofline seed
    tie-breaks on pool order), the operator graph, the objective, the
    latency requirement, and the full GA budget.
    """

    def __init__(self, workers: int | None = None,
                 executor: str | None = None):
        self.workers = _default_workers() if workers is None else workers
        self.executor = _default_executor() if executor is None else executor
        self._cache: dict[tuple, "FusionResult | None"] = {}
        self._lock = threading.Lock()
        self._procpool: ProcessPoolExecutor | None = None
        self._procpool_size = 0
        self.hits = 0
        self.misses = 0

    # -- cache plumbing ------------------------------------------------

    @staticmethod
    def _key(pool: Sequence["Chiplet"], graph: "OperatorGraph",
             objective: str, req: "Requirement", ga: "GAConfig") -> tuple:
        return (tuple(pool), graph, objective, req, astuple(ga))

    def clear(self) -> None:
        with self._lock:
            self._cache.clear()
            self.hits = 0
            self.misses = 0

    # -- process-pool plumbing -----------------------------------------

    def _ensure_process_pool(self, n: int) -> ProcessPoolExecutor:
        """Persistent spawn-context pool (created once, reused across SA
        iterations so the per-worker spawn + import cost is paid once and
        worker cache shards keep accumulating)."""
        if self._procpool is None or self._procpool_size < n:
            if self._procpool is not None:
                self._procpool.shutdown(wait=False, cancel_futures=True)
            # spawn, not fork: fork is unsafe under threads/JAX and the
            # workers must start from a clean interpreter state.
            ctx = multiprocessing.get_context("spawn")
            self._procpool = ProcessPoolExecutor(max_workers=n,
                                                 mp_context=ctx)
            self._procpool_size = n
            atexit.register(self._shutdown_process_pool)
        return self._procpool

    def _shutdown_process_pool(self) -> None:
        if self._procpool is not None:
            # wait=True: a clean join keeps this from racing
            # concurrent.futures' own interpreter-exit hook.
            self._procpool.shutdown(wait=True, cancel_futures=True)
            self._procpool = None
            self._procpool_size = 0

    def _map_process(self, pool: Sequence["Chiplet"],
                     networks: dict[str, "OperatorGraph"],
                     names: list[str], objective: str,
                     reqs: dict[str, "Requirement"], ga: "GAConfig",
                     n_workers: int) -> "list[FusionResult | None] | None":
        """Fan cache misses out over the process pool; None = could not
        use processes (caller falls back to the thread path)."""
        from .fusion import Requirement
        keys = {name: self._key(pool, networks[name], objective,
                                reqs.get(name, Requirement()), ga)
                for name in names}
        results: dict[str, "FusionResult | None"] = {}
        miss: list[str] = []
        with self._lock:
            for name in names:
                if keys[name] in self._cache:
                    self.hits += 1
                    results[name] = self._cache[keys[name]]
                else:
                    miss.append(name)
        if miss:
            try:
                ex = self._ensure_process_pool(n_workers)
                futs = {name: ex.submit(
                    _process_worker, engine_enabled(), tuple(pool),
                    networks[name], objective,
                    reqs.get(name, Requirement()), ga) for name in miss}
                got = {name: f.result() for name, f in futs.items()}
            except Exception:            # spawn/pickle failure: thread path
                self._shutdown_process_pool()
                return None
            with self._lock:
                for name in miss:
                    key = keys[name]
                    if key in self._cache:   # racing caller filled it
                        self.hits += 1
                        results[name] = self._cache[key]
                    else:
                        self.misses += 1
                        self._cache[key] = got[name]
                        results[name] = got[name]
        return [results[n] for n in names]

    # -- evaluation ----------------------------------------------------

    def evaluate_network(self, pool: Sequence["Chiplet"],
                         graph: "OperatorGraph", objective: str,
                         req: "Requirement",
                         ga: "GAConfig") -> "FusionResult | None":
        from .fusion import optimize_fusion
        key = self._key(pool, graph, objective, req, ga)
        with self._lock:
            if key in self._cache:
                self.hits += 1
                return self._cache[key]
        res = optimize_fusion(graph, pool, objective=objective, req=req,
                              cfg=ga)
        with self._lock:
            # A racing thread may have filled the slot; keep the first
            # result so repeated queries stay consistent.
            if key in self._cache:
                self.hits += 1
                return self._cache[key]
            self.misses += 1
            self._cache[key] = res
        return res

    def evaluate_pool(self, pool: Sequence["Chiplet"],
                      networks: dict[str, "OperatorGraph"],
                      objective: str,
                      reqs: dict[str, "Requirement"] | None,
                      ga: "GAConfig",
                      workers: int | None = None,
                      executor: str | None = None
                      ) -> tuple[float, dict[str, "FusionResult"]]:
        """(geomean objective value, per-network best design)."""
        from .fusion import Requirement
        reqs = reqs or {}
        names = list(networks)
        n_workers = self.workers if workers is None else workers
        kind = self.executor if executor is None else executor

        def one(name: str) -> "FusionResult | None":
            return self.evaluate_network(pool, networks[name], objective,
                                         reqs.get(name, Requirement()), ga)

        results: "list[FusionResult | None] | None" = None
        if n_workers > 1 and len(names) > 1:
            if kind == "process":
                results = self._map_process(pool, networks, names,
                                            objective, reqs, ga, n_workers)
            if results is None:
                with ThreadPoolExecutor(max_workers=n_workers) as ex:
                    results = list(ex.map(one, names))
        else:
            results = [one(n) for n in names]

        per: dict[str, "FusionResult"] = {}
        logsum = 0.0
        for name, res in zip(names, results):
            if res is None:
                return math.inf, {}
            per[name] = res
            logsum += math.log(max(res.value, 1e-30))
        return math.exp(logsum / max(len(names), 1)), per


DEFAULT_ENGINE = EvaluationEngine()


def clear_all_caches() -> None:
    """Reset every cross-call cache in the codesign stack (engine memo +
    fusion's stage-option LRUs) — used for fair before/after timing."""
    from . import fusion
    DEFAULT_ENGINE.clear()
    fusion.clear_option_caches()
