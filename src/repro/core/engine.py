"""Cross-layer evaluation engine for the Mozart codesign stack.

Three independent accelerations over the seed implementation, all
result-preserving for a fixed seed:

  * vectorization — `perfmodel.enumerate_stage_options` evaluates the
    whole (chiplet x memory x mem_units x tp x batch) grid of a fusion
    group with batched NumPy instead of per-option scalar math (the
    engine path keeps the results as column blocks — StageOption
    objects materialize lazily), `convexhull.solve_pipeline` sweeps the
    iso-latency grid as a dense (options x latencies) array min instead
    of a Python hull walk, and a whole GA generation's Layer-3 solves
    collapse into ONE `convexhull.solve_pipeline_batch` call
    (`fusion.evaluate_genomes`; MOZART_BATCH_SOLVE=0 restores the
    per-genome loop);
  * memoization — Layer-2 GA results are cached per
    (pool fingerprint, network, objective, requirement, GA budget), so
    SA iterations that revisit a pool (rejected moves, identity
    mutations, the final full-budget re-eval) skip the GA entirely;
    stage options are additionally cached per *single chiplet* so a
    one-SKU neighbor move only enumerates options for the new SKU; and
    config grids, per-block dominance masks, and default latency grids
    are memoized across groups/genomes/pools;
  * parallelism — `evaluate_pool`'s per-network loop can fan out over a
    thread pool or, since the GA inner loop is GIL-bound Python, a
    spawn-safe process pool (`workers` / MOZART_WORKERS for the width,
    `executor` / MOZART_EXECUTOR=thread|process for the kind).  Process
    workers are persistent and keep their own cache shard (engine memo +
    fusion option caches live for the worker's lifetime); results are
    merged back into the parent engine's memo, and any failure to spawn
    falls back to the thread path.  A pre-fork warmup (`warmup` /
    MOZART_WARMUP, on by default) ships the parent's per-SKU option
    columns to workers over multiprocessing shared memory (pickle
    fallback) and merges worker-discovered columns back each round, so
    no (group, SKU) option block is enumerated twice anywhere in the
    pool — `EvaluationEngine.stats()` reports the warmup_hits /
    worker_enumerations traffic.

`MOZART_DISABLE_ENGINE=1` (or `set_engine_enabled(False)`) restores the
seed's scalar, uncached behavior — used by
benchmarks/bench_codesign_search.py for before/after timing.
"""
from __future__ import annotations

import atexit
import math
import multiprocessing
import threading
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from dataclasses import astuple
from typing import TYPE_CHECKING, Sequence

from repro.launch import knobs

if TYPE_CHECKING:   # pragma: no cover - type-only; avoids an import cycle
    from .chiplets import Chiplet
    from .fusion import FusionResult, GAConfig, Requirement
    from .operators import OperatorGraph

_enabled = not knobs.get_bool("MOZART_DISABLE_ENGINE")


def engine_enabled() -> bool:
    """Global switch consulted by perfmodel/convexhull/fusion/pool."""
    return _enabled


def set_engine_enabled(flag: bool) -> None:
    global _enabled
    _enabled = bool(flag)


def batch_solve_enabled() -> bool:
    """MOZART_BATCH_SOLVE=0 disables the generation-batched Layer-3
    solve (convexhull.solve_pipeline_batch falls back to a per-genome
    loop) — an escape hatch for debugging; results are bit-identical
    either way."""
    return knobs.get_bool("MOZART_BATCH_SOLVE")


def _default_warmup() -> bool:
    return knobs.get_bool("MOZART_WARMUP")


def _default_workers() -> int:
    return knobs.get_int("MOZART_WORKERS")


EXECUTOR_KINDS = ("thread", "process")


def _default_executor() -> str:
    kind = knobs.get_str("MOZART_EXECUTOR").strip().lower()
    return kind if kind in EXECUTOR_KINDS else "thread"


class _WarmupShipment:
    """Parent-side handle for one round's shared option-cache shipment:
    the packed column matrix (in a SharedMemory block when available,
    inline otherwise) plus the metadata that lets workers rebuild
    bit-identical StageOptionColumns without re-running the perf model."""

    def __init__(self, payload: tuple, shm=None):
        self.payload = payload
        self._shm = shm

    def close(self) -> None:
        if self._shm is not None:
            try:
                self._shm.close()
                self._shm.unlink()
            except Exception:
                pass
            self._shm = None


def _attach_shm(name: str):
    """Attach to an existing SharedMemory block; the parent owns the
    block's lifetime.  On 3.13+ `track=False` skips resource-tracker
    registration entirely.  On <=3.12 attaching registers with the
    resource tracker, but pool workers share the PARENT's tracker
    process and its cache is a set — the re-registration of an
    already-tracked name is a no-op, and the parent's single unlink
    unregisters it exactly once (a worker-side unregister here would
    race other workers and KeyError inside the tracker)."""
    from multiprocessing import shared_memory
    try:
        return shared_memory.SharedMemory(name=name, track=False)
    except TypeError:                      # track= is 3.13+
        return shared_memory.SharedMemory(name=name)


def _install_warmup(payload: tuple) -> int:
    """Worker-side: unpack a warmup shipment into the fusion option
    cache.  Returns the number of (group, SKU) blocks installed (keys
    already present — e.g. on a persistent worker's later rounds — are
    skipped)."""
    import numpy as np

    from . import fusion
    kind = payload[0]
    if kind == "pickle":
        _, matrix, meta = payload
        return fusion.import_option_columns(meta, matrix)
    _, name, shape, dtype, meta = payload
    shm = _attach_shm(name)
    try:
        matrix = np.ndarray(shape, dtype=np.dtype(dtype), buffer=shm.buf)
        return fusion.import_option_columns(meta, matrix)
    finally:
        shm.close()


def _process_worker(enabled: bool, pool: tuple, graph: "OperatorGraph",
                    objective: str, req: "Requirement",
                    ga: "GAConfig", warmup: tuple | None = None
                    ) -> tuple:
    """Entry point run inside a spawned worker process.

    Evaluates one (pool, network) GA through the worker's own
    DEFAULT_ENGINE, so each worker accumulates an independent cache shard
    (engine memo + fusion option caches) that persists across tasks for
    the life of the worker.  `enabled` carries the parent's engine switch
    across the spawn boundary.

    A warmup shipment, when given, is installed into the worker's option
    cache first (so the worker never re-enumerates options any process
    already evaluated), and the options the worker DOES enumerate during
    the task are shipped back for the parent to merge and rebroadcast.
    Returns (result, {installed, enumerated}, (meta, matrix))."""
    from . import fusion
    set_engine_enabled(enabled)
    installed = 0
    if warmup is not None:
        try:
            installed = _install_warmup(warmup)
        except Exception:
            installed = 0
    known = set(fusion._chiplet_option_cache)
    before = fusion.warmup_stats()["enumerated"]
    res = DEFAULT_ENGINE.evaluate_network(list(pool), graph, objective,
                                          req, ga)
    enumerated = fusion.warmup_stats()["enumerated"] - before
    new_keys = [k for k in fusion._chiplet_option_cache if k not in known]
    ship = fusion.export_option_columns(new_keys)
    return res, {"installed": installed, "enumerated": enumerated}, ship


class EvaluationEngine:
    """Memoized, optionally parallel evaluator for (pool, network) pairs.

    The cache key covers everything `fusion.optimize_fusion` depends on:
    the exact pool composition (order-sensitive — the GA's roofline seed
    tie-breaks on pool order), the operator graph, the objective, the
    latency requirement, and the full GA budget.
    """

    def __init__(self, workers: int | None = None,
                 executor: str | None = None,
                 warmup: bool | None = None):
        self.workers = _default_workers() if workers is None else workers
        self.executor = _default_executor() if executor is None else executor
        self.warmup = _default_warmup() if warmup is None else warmup
        self._cache: dict[tuple, "FusionResult | None"] = {}
        self._lock = threading.Lock()
        self._procpool: ProcessPoolExecutor | None = None
        self._procpool_size = 0
        # Option-cache keys already shipped to the CURRENT worker pool
        # (workers are persistent, so each block needs shipping once;
        # reset whenever the pool is recreated).
        self._shipped_keys: set[tuple] = set()
        self.hits = 0
        self.misses = 0
        # Shared-option-cache traffic over the process pool: blocks the
        # workers received prewarmed vs. blocks they had to enumerate.
        self.warmup_hits = 0
        self.worker_enumerations = 0

    def stats(self) -> dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "warmup_hits": self.warmup_hits,
                "worker_enumerations": self.worker_enumerations}

    # -- cache plumbing ------------------------------------------------

    @staticmethod
    def _key(pool: Sequence["Chiplet"], graph: "OperatorGraph",
             objective: str, req: "Requirement", ga: "GAConfig") -> tuple:
        return (tuple(pool), graph, objective, req, astuple(ga))

    def clear(self) -> None:
        with self._lock:
            self._cache.clear()
            self.hits = 0
            self.misses = 0
            self.warmup_hits = 0
            self.worker_enumerations = 0

    # -- process-pool plumbing -----------------------------------------

    def _ensure_process_pool(self, n: int) -> ProcessPoolExecutor:
        """Persistent spawn-context pool (created once, reused across SA
        iterations so the per-worker spawn + import cost is paid once and
        worker cache shards keep accumulating)."""
        if self._procpool is None or self._procpool_size < n:
            if self._procpool is not None:
                self._procpool.shutdown(wait=False, cancel_futures=True)
            # spawn, not fork: fork is unsafe under threads/JAX and the
            # workers must start from a clean interpreter state.
            ctx = multiprocessing.get_context("spawn")
            self._procpool = ProcessPoolExecutor(max_workers=n,
                                                 mp_context=ctx)
            self._procpool_size = n
            self._shipped_keys = set()     # fresh workers know nothing
            atexit.register(self._shutdown_process_pool)
        return self._procpool

    def _shutdown_process_pool(self) -> None:
        if self._procpool is not None:
            # wait=True: a clean join keeps this from racing
            # concurrent.futures' own interpreter-exit hook.
            self._procpool.shutdown(wait=True, cancel_futures=True)
            self._procpool = None
            self._procpool_size = 0
            self._shipped_keys = set()

    def _prepare_warmup(self, pool: Sequence["Chiplet"],
                        networks: dict[str, "OperatorGraph"],
                        miss: list[str],
                        ga: "GAConfig") -> "_WarmupShipment | None":
        """Parent-side pre-fork warmup: enumerate (once, in the parent)
        the option columns for every network's deterministic generation-0
        genomes, then pack what the parent cache holds for this pool —
        including blocks merged back from workers in earlier rounds —
        into one shipment.  Workers are persistent, so only the delta
        not yet shipped to the CURRENT pool goes out (across SA
        iterations that is typically just the mutated SKU's blocks);
        `_shipped_keys` resets whenever the pool is recreated.  A worker
        respawned after a crash misses earlier shipments and simply
        re-enumerates — a perf hiccup, never a correctness issue."""
        from . import fusion
        for name in miss:
            graph = networks[name]
            pop = fusion.initial_population(graph, list(pool), ga)
            fusion.prefetch_population_options(graph, pop, pool, ga)
        keys = [k for k in fusion.matching_option_keys(pool, ga)
                if k not in self._shipped_keys]
        if not keys:
            return None
        self._shipped_keys.update(keys)
        meta, matrix = fusion.export_option_columns(keys)
        if not meta:
            return None
        try:
            from multiprocessing import shared_memory
            shm = shared_memory.SharedMemory(create=True,
                                             size=max(matrix.nbytes, 1))
            import numpy as np
            np.ndarray(matrix.shape, dtype=matrix.dtype,
                       buffer=shm.buf)[:] = matrix
            return _WarmupShipment(("shm", shm.name, matrix.shape,
                                    matrix.dtype.str, meta), shm)
        except Exception:                  # no shm on this platform
            return _WarmupShipment(("pickle", matrix, meta))

    def _map_process(self, pool: Sequence["Chiplet"],
                     networks: dict[str, "OperatorGraph"],
                     names: list[str], objective: str,
                     reqs: dict[str, "Requirement"], ga: "GAConfig",
                     n_workers: int,
                     warmup: bool) -> "list[FusionResult | None] | None":
        """Fan cache misses out over the process pool; None = could not
        use processes (caller falls back to the thread path)."""
        from . import fusion
        from .fusion import Requirement
        keys = {name: self._key(pool, networks[name], objective,
                                reqs.get(name, Requirement()), ga)
                for name in names}
        results: dict[str, "FusionResult | None"] = {}
        miss: list[str] = []
        with self._lock:
            for name in names:
                if keys[name] in self._cache:
                    self.hits += 1
                    results[name] = self._cache[keys[name]]
                else:
                    miss.append(name)
        if miss:
            # Pool first: creating/resizing it resets the shipped-key
            # tracking the delta shipment below is computed against.
            try:
                ex = self._ensure_process_pool(n_workers)
            except Exception:
                self._shutdown_process_pool()
                return None
            warm: "_WarmupShipment | None" = None
            if warmup:
                try:
                    warm = self._prepare_warmup(pool, networks, miss, ga)
                except Exception:
                    warm = None
            try:
                payload = warm.payload if warm is not None else None
                futs = {name: ex.submit(
                    _process_worker, engine_enabled(), tuple(pool),
                    networks[name], objective,
                    reqs.get(name, Requirement()), ga, payload)
                    for name in miss}
                got = {}
                for name, f in futs.items():
                    res, wstats, ship = f.result()
                    got[name] = res
                    with self._lock:
                        self.warmup_hits += wstats["installed"]
                        self.worker_enumerations += wstats["enumerated"]
                    try:
                        # Merge worker-discovered blocks into the parent
                        # cache so the next round's shipment covers them.
                        fusion.import_option_columns(*ship)
                    except Exception:
                        pass
            except Exception:            # spawn/pickle failure: thread path
                self._shutdown_process_pool()
                return None
            finally:
                if warm is not None:
                    warm.close()
            with self._lock:
                for name in miss:
                    key = keys[name]
                    if key in self._cache:   # racing caller filled it
                        self.hits += 1
                        results[name] = self._cache[key]
                    else:
                        self.misses += 1
                        self._cache[key] = got[name]
                        results[name] = got[name]
        return [results[n] for n in names]

    # -- evaluation ----------------------------------------------------

    def evaluate_network(self, pool: Sequence["Chiplet"],
                         graph: "OperatorGraph", objective: str,
                         req: "Requirement",
                         ga: "GAConfig") -> "FusionResult | None":
        from .fusion import optimize_fusion
        key = self._key(pool, graph, objective, req, ga)
        with self._lock:
            if key in self._cache:
                self.hits += 1
                return self._cache[key]
        res = optimize_fusion(graph, pool, objective=objective, req=req,
                              cfg=ga)
        with self._lock:
            # A racing thread may have filled the slot; keep the first
            # result so repeated queries stay consistent.
            if key in self._cache:
                self.hits += 1
                return self._cache[key]
            self.misses += 1
            self._cache[key] = res
        return res

    def evaluate_pool(self, pool: Sequence["Chiplet"],
                      networks: dict[str, "OperatorGraph"],
                      objective: str,
                      reqs: dict[str, "Requirement"] | None,
                      ga: "GAConfig",
                      workers: int | None = None,
                      executor: str | None = None,
                      warmup: bool | None = None
                      ) -> tuple[float, dict[str, "FusionResult"]]:
        """(geomean objective value, per-network best design)."""
        from .fusion import Requirement
        reqs = reqs or {}
        names = list(networks)
        n_workers = self.workers if workers is None else workers
        kind = self.executor if executor is None else executor
        warm = self.warmup if warmup is None else warmup

        def one(name: str) -> "FusionResult | None":
            return self.evaluate_network(pool, networks[name], objective,
                                         reqs.get(name, Requirement()), ga)

        results: "list[FusionResult | None] | None" = None
        if n_workers > 1 and len(names) > 1:
            if kind == "process":
                results = self._map_process(pool, networks, names,
                                            objective, reqs, ga, n_workers,
                                            warm)
            if results is None:
                with ThreadPoolExecutor(max_workers=n_workers) as ex:
                    results = list(ex.map(one, names))
        else:
            results = [one(n) for n in names]

        per: dict[str, "FusionResult"] = {}
        logsum = 0.0
        for name, res in zip(names, results):
            if res is None:
                return math.inf, {}
            per[name] = res
            logsum += math.log(max(res.value, 1e-30))
        return math.exp(logsum / max(len(names), 1)), per


DEFAULT_ENGINE = EvaluationEngine()


def clear_all_caches() -> None:
    """Reset every cross-call cache in the codesign stack (engine memo,
    fusion's stage-option caches + latency-grid memo, perfmodel's
    config-grid/chip-row LRUs) — used for fair before/after timing."""
    from . import fusion, perfmodel
    DEFAULT_ENGINE.clear()
    fusion.clear_option_caches()
    perfmodel.clear_perfmodel_caches()
