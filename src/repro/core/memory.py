"""Memory pool (paper §2: DDR5, LPDDR5, GDDR7, HBM3E).

Per-unit figures are one stack (HBM), one device (GDDR/LPDDR) or one
channel (DDR5).  $ figures follow the paper's sources [50][59][60][30]
to first order; what matters for reproducing Fig. 2 is the *ordering*
(HBM >> GDDR > LPDDR > DDR in both bandwidth and $/GB).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True, order=True)
class MemoryType:
    name: str
    bw_per_unit: float       # bytes/s
    capacity_per_unit: float # bytes
    pj_per_bit: float        # access energy
    usd_per_gb: float
    phy_cost_usd: float      # controller+PHY per unit
    phy_area_mm2: float      # beachfront consumed on the host die

    def units_for(self, capacity_bytes: float, bandwidth_bps: float) -> int:
        by_cap = -(-int(capacity_bytes) // int(self.capacity_per_unit))
        by_bw = -(-int(bandwidth_bps) // int(self.bw_per_unit))
        return max(1, by_cap, by_bw)

    def cost(self, units: int) -> float:
        gb = units * self.capacity_per_unit / 1e9
        return gb * self.usd_per_gb + units * self.phy_cost_usd

    def energy_j(self, bytes_moved: float) -> float:
        return bytes_moved * 8.0 * self.pj_per_bit * 1e-12

    def to_dict(self) -> dict:
        """JSON form.  Stock pool members serialize as their name only;
        custom memory types carry their full parameterization."""
        stock = MEMORY_BY_NAME.get(self.name)
        if stock == self:
            return {"name": self.name}
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "MemoryType":
        if set(d) == {"name"}:
            return MEMORY_BY_NAME[d["name"]]
        return MemoryType(**d)


HBM3 = MemoryType("HBM3", bw_per_unit=819e9, capacity_per_unit=24e9,
                  pj_per_bit=3.9, usd_per_gb=15.0, phy_cost_usd=40.0,
                  phy_area_mm2=12.0)
GDDR7 = MemoryType("GDDR7", bw_per_unit=128e9, capacity_per_unit=2e9,
                   pj_per_bit=7.0, usd_per_gb=8.0, phy_cost_usd=8.0,
                   phy_area_mm2=4.0)
LPDDR5 = MemoryType("LPDDR5", bw_per_unit=51.2e9, capacity_per_unit=8e9,
                    pj_per_bit=4.5, usd_per_gb=4.0, phy_cost_usd=5.0,
                    phy_area_mm2=3.0)
DDR5 = MemoryType("DDR5", bw_per_unit=38.4e9, capacity_per_unit=16e9,
                  pj_per_bit=12.0, usd_per_gb=3.0, phy_cost_usd=4.0,
                  phy_area_mm2=3.0)

MEMORY_POOL: tuple[MemoryType, ...] = (HBM3, GDDR7, LPDDR5, DDR5)
MEMORY_BY_NAME = {m.name: m for m in MEMORY_POOL}
