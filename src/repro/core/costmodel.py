"""CATCH-style cost model (paper §4.5, [25][24][19]).

RE (recurring): wafer/lithography cost through a clustered-defect yield
model (superlinear per-die cost in area), memory, packaging/interposer,
bonding, test.  NRE (non-recurring): masks, EDA/verification, IP,
package design, software — amortized over production volume and, for
chiplets, over every *design* that reuses them (the ecosystem argument).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Iterable, Sequence

from .chiplets import Chiplet
from .memory import MemoryType
from .perfmodel import StageConfig, StageOption

# --- RE constants (14 nm class) --------------------------------------------
WAFER_COST_USD = 4000.0
WAFER_DIAMETER_MM = 300.0
DEFECT_DENSITY_PER_MM2 = 0.0010     # D0, mature 14 nm
YIELD_CLUSTERING_ALPHA = 2.0        # negative-binomial alpha
TEST_COST_FRACTION = 0.05

# Packaging
INTERPOSER_USD_PER_MM2 = {"2D": 0.005, "2.5D": 0.03}
BOND_COST_USD = {"2D": 0.30, "2.5D": 1.00}
ASSEMBLY_YIELD_PER_CHIPLET = 0.995

# --- NRE constants ----------------------------------------------------------
NRE_PER_CHIPLET_DESIGN = 15e6       # masks + EDA + verification + IP, 14 nm
NRE_PER_SYSTEM_DESIGN = 7e6         # package/interposer design + SW stack
NRE_MONOLITHIC_EXTRA = 1.6          # monolithic re-spins cost more per design


def die_yield(area_mm2: float) -> float:
    """Negative binomial yield: superlinear per-die cost in area [24]."""
    return (1.0 + area_mm2 * DEFECT_DENSITY_PER_MM2
            / YIELD_CLUSTERING_ALPHA) ** (-YIELD_CLUSTERING_ALPHA)


def dies_per_wafer(area_mm2: float) -> float:
    d = WAFER_DIAMETER_MM
    side = math.sqrt(area_mm2)
    return max(1.0, (math.pi * (d / 2) ** 2 / area_mm2
                     - math.pi * d / math.sqrt(2.0 * area_mm2)))


def die_cost(area_mm2: float) -> float:
    """K_die / Y_die (paper Eq. in §4.5)."""
    k_die = WAFER_COST_USD / dies_per_wafer(area_mm2)
    return k_die / die_yield(area_mm2) * (1.0 + TEST_COST_FRACTION)


@functools.lru_cache(maxsize=None)
def chiplet_re_cost(c: Chiplet) -> float:
    return die_cost(c.area_mm2) + BOND_COST_USD[c.bonding]


@functools.lru_cache(maxsize=None)
def _stage_hw_cost(chiplet: Chiplet, tp: int, memory: MemoryType,
                   units: int) -> float:
    return chiplet_re_cost(chiplet) * tp + memory.cost(units)


def stage_hw_cost(cfg: StageConfig) -> float:
    """Manufacturing cost of one stage config: tp chiplet dies + the
    stage's memory subsystem (cached per distinct config)."""
    return _stage_hw_cost(cfg.chiplet, cfg.tp, cfg.memory, cfg.mem_units)


def price_stage_options(options: Iterable[StageOption]) -> list[StageOption]:
    """Fill hw_cost_usd: tp chiplet dies + the stage's memory subsystem."""
    return [dataclasses.replace(o, hw_cost_usd=stage_hw_cost(o.cfg))
            for o in options]


@dataclasses.dataclass(frozen=True)
class SystemCost:
    die: float
    memory: float
    packaging: float
    nre_per_unit: float
    total_per_unit: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def system_cost(stages: Sequence[StageOption], *,
                volume: float = 1e6,
                n_networks_sharing: dict[str, int] | None = None,
                monolithic: bool = False) -> SystemCost:
    """Full unit-cost breakdown for a composed BASIC (Fig. 9).

    n_networks_sharing: chiplet label -> number of BASIC designs that reuse
    it.  Pool reuse divides each chiplet's design NRE by (reuse * volume);
    a bespoke/unconstrained design eats the whole NRE itself.
    """
    n_networks_sharing = n_networks_sharing or {}
    die = mem = pack = 0.0
    interposer_area = 0.0
    n_chiplets = 0
    uniq: dict[str, Chiplet] = {}
    for o in stages:
        c = o.cfg.chiplet
        n = o.cfg.tp * max(o.repeat, 1)    # physical copies of this stage
        die += die_cost(c.area_mm2) * n
        mem += o.cfg.memory.cost(o.cfg.mem_units) * max(o.repeat, 1)
        pack += BOND_COST_USD[c.bonding] * n
        interposer_area += c.area_mm2 * n * 1.3          # routing margin
        interposer_area += o.cfg.memory.phy_area_mm2 * max(o.repeat, 1)
        n_chiplets += n
        uniq[c.label] = c
    bond = max(b for b in (o.cfg.chiplet.bonding for o in stages)) \
        if stages else "2D"
    pack += interposer_area * INTERPOSER_USD_PER_MM2[bond]
    # Large slices span multiple packages; known-good-die test + package-
    # level discard bounds the compounding assembly-yield loss at the
    # per-package chiplet count (~24 sites).
    assembly_yield = ASSEMBLY_YIELD_PER_CHIPLET ** min(n_chiplets, 24)
    re = (die + mem + pack) / assembly_yield

    if monolithic:
        area = sum(o.cfg.chiplet.area_mm2 * o.cfg.tp for o in stages)
        re = die_cost(area) + mem / assembly_yield
        nre = NRE_PER_CHIPLET_DESIGN * NRE_MONOLITHIC_EXTRA \
            + NRE_PER_SYSTEM_DESIGN
        nre_unit = nre / volume
    else:
        nre = NRE_PER_SYSTEM_DESIGN
        nre_unit = nre / volume
        for label, c in uniq.items():
            reuse = max(1, n_networks_sharing.get(label, 1))
            nre_unit += NRE_PER_CHIPLET_DESIGN / (reuse * volume)

    return SystemCost(die=die, memory=mem, packaging=pack,
                      nre_per_unit=nre_unit,
                      total_per_unit=re + nre_unit)
