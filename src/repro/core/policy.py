"""Mozart solution -> execution policy for the JAX substrate.

The paper deploys its decisions as silicon; this framework additionally
deploys them as *execution policies* on the TPU substrate (DESIGN.md §2):

  * per-operator-class batch size (Insight 2's non-uniform batching) drives
    the serving engine's microbatch scheduler;
  * tensor-parallel degree per stage drives sharding choices;
  * fusion groups map onto the fused Pallas kernels (flash-attention etc.).

Policies are part of the `repro.mozart` deployment artifact: a compiled
`Deployment` carries one `ExecutionPolicy` per network, the whole
artifact round-trips through JSON (`ExecutionPolicy.to_json` /
`policy_from_json`), and `repro.launch.serve --policy <artifact>`
consumes it — fusion flags select the fused kernels, the batch split
sets the engine's max/decode batch, and the TP degree feeds mesh setup.
"""
from __future__ import annotations

import dataclasses
import json

from .codesign import BasicDesign


@dataclasses.dataclass(frozen=True)
class OperatorPolicy:
    group: str
    batch: int
    tp: int
    memory: str
    chiplet: str
    fused: bool           # >1 operator in the group -> fused kernel

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "OperatorPolicy":
        return OperatorPolicy(group=d["group"], batch=d["batch"],
                              tp=d["tp"], memory=d["memory"],
                              chiplet=d["chiplet"], fused=d["fused"])


@dataclasses.dataclass
class ExecutionPolicy:
    network: str
    interval_s: float                 # target per-sample initiation interval
    operators: list[OperatorPolicy]

    @property
    def batch_agnostic_batch(self) -> int:
        bs = [p.batch for p in self.operators
              if "attention" in p.group or "scan" in p.group]
        return min(bs) if bs else 1

    @property
    def batch_sensitive_batch(self) -> int:
        bs = [p.batch for p in self.operators
              if "attention" not in p.group and "scan" not in p.group]
        return max(bs) if bs else 1

    @property
    def tp_degree(self) -> int:
        """Widest per-stage tensor-parallel degree — the model-axis size
        the serving mesh must provide."""
        return max((p.tp for p in self.operators), default=1)

    def fusion_flags(self) -> dict[str, bool]:
        """Which fused kernels the substrate should enable."""
        flags = {"flash_attention": False, "fused_mlp": False,
                 "fused_norm": False}
        for p in self.operators:
            if not p.fused:
                continue
            if "attention" in p.group:
                flags["flash_attention"] = True
            if "mlp" in p.group:
                flags["fused_mlp"] = True
            if "norm" in p.group:
                flags["fused_norm"] = True
        return flags

    def to_dict(self) -> dict:
        return {
            "network": self.network,
            "interval_s": self.interval_s,
            "operators": [p.to_dict() for p in self.operators],
            # Derived, re-checked on load; kept in the JSON so the
            # artifact is self-describing for non-Python consumers.
            "fusion": self.fusion_flags(),
        }

    @staticmethod
    def from_dict(d: dict) -> "ExecutionPolicy":
        pol = ExecutionPolicy(
            network=d["network"], interval_s=d["interval_s"],
            operators=[OperatorPolicy.from_dict(p)
                       for p in d["operators"]])
        want = d.get("fusion")
        if want is not None and want != pol.fusion_flags():
            raise ValueError(
                f"policy fusion flags {want} do not match the flags "
                f"derived from its operators {pol.fusion_flags()}")
        return pol

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)


def policy_from_json(text: str) -> ExecutionPolicy:
    """Parse `ExecutionPolicy.to_json` output back (exact round-trip)."""
    return ExecutionPolicy.from_dict(json.loads(text))


def policy_from_design(design: BasicDesign) -> ExecutionPolicy:
    ops = []
    for st in design.fusion.solution.stages:
        ops.append(OperatorPolicy(
            group=st.group_name, batch=st.cfg.batch, tp=st.cfg.tp,
            memory=st.cfg.memory.name, chiplet=st.cfg.chiplet.label,
            fused="+" in st.group_name))
    return ExecutionPolicy(network=design.network,
                           interval_s=design.fusion.solution.T,
                           operators=ops)
