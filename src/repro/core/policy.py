"""Mozart solution -> execution policy for the JAX substrate.

The paper deploys its decisions as silicon; this framework additionally
deploys them as *execution policies* on the TPU substrate (DESIGN.md §2):

  * per-operator-class batch size (Insight 2's non-uniform batching) drives
    the serving engine's microbatch scheduler;
  * tensor-parallel degree per stage drives sharding choices;
  * fusion groups map onto the fused Pallas kernels (flash-attention etc.).
"""
from __future__ import annotations

import dataclasses
import json

from .codesign import BasicDesign


@dataclasses.dataclass(frozen=True)
class OperatorPolicy:
    group: str
    batch: int
    tp: int
    memory: str
    chiplet: str
    fused: bool           # >1 operator in the group -> fused kernel


@dataclasses.dataclass
class ExecutionPolicy:
    network: str
    interval_s: float                 # target per-sample initiation interval
    operators: list[OperatorPolicy]

    @property
    def batch_agnostic_batch(self) -> int:
        bs = [p.batch for p in self.operators
              if "attention" in p.group or "scan" in p.group]
        return min(bs) if bs else 1

    @property
    def batch_sensitive_batch(self) -> int:
        bs = [p.batch for p in self.operators
              if "attention" not in p.group and "scan" not in p.group]
        return max(bs) if bs else 1

    def fusion_flags(self) -> dict[str, bool]:
        """Which fused kernels the substrate should enable."""
        flags = {"flash_attention": False, "fused_mlp": False,
                 "fused_norm": False}
        for p in self.operators:
            if not p.fused:
                continue
            if "attention" in p.group:
                flags["flash_attention"] = True
            if "mlp" in p.group:
                flags["fused_mlp"] = True
            if "norm" in p.group:
                flags["fused_norm"] = True
        return flags

    def to_json(self) -> str:
        return json.dumps({
            "network": self.network,
            "interval_s": self.interval_s,
            "operators": [dataclasses.asdict(p) for p in self.operators],
            "fusion": self.fusion_flags(),
        }, indent=2)


def policy_from_design(design: BasicDesign) -> ExecutionPolicy:
    ops = []
    for st in design.fusion.solution.stages:
        ops.append(OperatorPolicy(
            group=st.group_name, batch=st.cfg.batch, tp=st.cfg.tp,
            memory=st.cfg.memory.name, chiplet=st.cfg.chiplet.label,
            fused="+" in st.group_name))
    return ExecutionPolicy(network=design.network,
                           interval_s=design.fusion.solution.T,
                           operators=ops)
