# Mozart's primary contribution — the chiplet ecosystem-accelerator
# codesign stack (operator IR -> perf/energy model -> SA/GA/convex-hull/PnR
# -> cost model -> deployment policy). Sibling subpackages implement the
# JAX execution substrate the policies deploy onto.
from .chiplets import Chiplet, default_pool, full_design_space
from .codesign import (BasicDesign, CodesignResult, best_homogeneous_design,
                       design_for_network, homogeneous_design, run_codesign,
                       unconstrained_design)
from .convexhull import (PipelineJob, PipelineSolution,
                         default_latency_grid, solve_pipeline,
                         solve_pipeline_batch, solve_pipeline_bruteforce)
from .costmodel import (SystemCost, chiplet_re_cost, die_cost, die_yield,
                        price_stage_options, stage_hw_cost, system_cost)
from .engine import (DEFAULT_ENGINE, EvaluationEngine, clear_all_caches,
                     engine_enabled, set_engine_enabled)
from .fusion import (FusionGroup, FusionResult, GAConfig, Genome,
                     Requirement, evaluate_genomes, groups_from_genome,
                     initial_population, optimize_fusion)
from .memory import DDR5, GDDR7, HBM3, LPDDR5, MEMORY_POOL, MemoryType
from .operators import (LMSpec, Operator, OperatorGraph, lm_operator_graph,
                        paper_workloads)
from .perfmodel import (StageConfig, StageOption, StageOptionColumns,
                        StageOptionSet, enumerate_stage_options,
                        evaluate_group, evaluate_group_batch, gpu_eval,
                        is_memory_bound, scale_option)
from .pnr import PnrResult, place_and_route
from .policy import (ExecutionPolicy, OperatorPolicy, policy_from_design,
                     policy_from_json)
from .pool import PoolResult, SAConfig, anneal_pool, evaluate_pool
from .scenarios import (SCENARIOS, Scenario, SpecDecodeScenario,
                        get_scenario)

__all__ = [n for n in dir() if not n.startswith("_")]
