"""First-order performance & energy model (the Timeloop/Accelergy stand-in).

Evaluates a *fusion group* (a consecutive run of operators mapped to one
pipeline stage) on a chiplet + memory configuration, producing the
piecewise-affine energy function of paper §4.3.1:

    E(T) = e_dyn + p_static * T     for T >= t_cmp,   infinite below.

All stage quantities are normalized PER SAMPLE so that stages running
different batch sizes (Insight 2: non-uniform batching) compose into one
pipeline with a common per-sample initiation interval T.
"""
from __future__ import annotations

import bisect
import dataclasses
import functools
import itertools
import math
from typing import Callable, Iterable, Sequence

import numpy as np

from .chiplets import (Chiplet, E_INTERCHIP_BIT, E_MAC_BASE, E_SRAM_BYTE)
from .engine import engine_enabled
from .memory import MEMORY_POOL, MemoryType
from .operators import BATCH_AGNOSTIC, Operator

TP_OPTIONS = (1, 2)                      # paper Table 4
BATCH_OPTIONS = (1, 2, 4, 8, 16, 32)     # per-stage microbatch choices
MEM_UNIT_OPTIONS = (1, 2, 4, 8)


@dataclasses.dataclass(frozen=True)
class StageConfig:
    chiplet: Chiplet
    memory: MemoryType
    mem_units: int
    tp: int
    batch: int

    @property
    def label(self) -> str:
        return (f"{self.chiplet.label}|{self.memory.name}x{self.mem_units}"
                f"|tp{self.tp}|b{self.batch}")

    def to_dict(self) -> dict:
        return {"chiplet": self.chiplet.to_dict(),
                "memory": self.memory.to_dict(),
                "mem_units": self.mem_units, "tp": self.tp,
                "batch": self.batch}

    @staticmethod
    def from_dict(d: dict) -> "StageConfig":
        return StageConfig(chiplet=Chiplet.from_dict(d["chiplet"]),
                           memory=MemoryType.from_dict(d["memory"]),
                           mem_units=d["mem_units"], tp=d["tp"],
                           batch=d["batch"])


@dataclasses.dataclass(frozen=True)
class StageOption:
    """One (chiplet, memory, tp, batch) choice for a fusion group, reduced
    to the piecewise-affine energy form. Per-sample units."""
    t_cmp: float          # min achievable per-sample latency (s)
    e_dyn: float          # dynamic energy per sample (J)
    p_static: float       # leakage power while stage is alive (W)
    hw_cost_usd: float    # manufacturing cost of this stage's hardware
    cfg: StageConfig
    group_name: str = ""
    flops_per_sample: float = 0.0   # useful FLOPs (utilization metrics)
    repeat: int = 1                 # physical copies of this stage

    def energy_at(self, t: float) -> float:
        if t < self.t_cmp:
            return math.inf
        return self.e_dyn + self.p_static * t

    def to_dict(self) -> dict:
        return {"t_cmp": self.t_cmp, "e_dyn": self.e_dyn,
                "p_static": self.p_static, "hw_cost_usd": self.hw_cost_usd,
                "cfg": self.cfg.to_dict(), "group_name": self.group_name,
                "flops_per_sample": self.flops_per_sample,
                "repeat": self.repeat}

    @staticmethod
    def from_dict(d: dict) -> "StageOption":
        return StageOption(
            t_cmp=d["t_cmp"], e_dyn=d["e_dyn"], p_static=d["p_static"],
            hw_cost_usd=d["hw_cost_usd"],
            cfg=StageConfig.from_dict(d["cfg"]),
            group_name=d["group_name"],
            flops_per_sample=d["flops_per_sample"], repeat=d["repeat"])


def _group_dram_bytes(ops: Sequence[Operator], glb_bytes: int,
                      batch: int) -> tuple[float, float]:
    """(dram_bytes, sram_bytes) for one batch-pass of the fused group.

    Tensor fusion keeps inter-operator intermediates in the GLB when they
    fit (half the GLB — the other half is the double buffer); spilled
    intermediates cost a DRAM write + read.
    """
    dram = 0.0
    sram = 0.0
    usable = glb_bytes / 2
    for i, op in enumerate(ops):
        w = op.weight_bytes
        if op.weight_reuse_divisor > 1.0:   # MoE: touched experts only
            w = min(op.weight_bytes,
                    (op.weight_bytes / op.weight_reuse_divisor) * batch)
        dram += w
        a_in = op.act_in_bytes * batch
        a_out = op.act_out_bytes * batch
        if i == 0:
            dram += a_in
        elif ops[i - 1].act_out_bytes * batch > usable:
            dram += a_in                     # re-read the spill
        if i == len(ops) - 1:
            dram += a_out
        elif a_out > usable:
            dram += a_out                    # spill write
        sram += (a_in + a_out)
    return dram, sram


def evaluate_group(ops: Sequence[Operator], cfg: StageConfig,
                   name: str = "") -> StageOption:
    """Roofline latency + energy for a fusion group on one stage config."""
    c, mem, B, tp = cfg.chiplet, cfg.memory, cfg.batch, cfg.tp

    t_compute = 0.0
    e_mac = 0.0
    sram_traffic = 0.0
    for op in ops:
        util = c.utilization(op.kind)
        # Small operators cannot fill a big array (Insight 4 / decode GEMV).
        size_eff = min(1.0, (op.parallel_work * B) / (c.n_pes * tp))
        rate = c.peak_flops * util * size_eff * tp
        t_compute += (op.flops * B) / max(rate, 1.0)
        e_mac += op.flops * B * 0.5 * E_MAC_BASE
        sram_traffic += ((op.act_in_bytes + op.act_out_bytes) * B
                         * c.sram_traffic_factor(op.kind))

    dram_bytes, _ = _group_dram_bytes(ops, c.glb_bytes * tp, B)
    bw = mem.bw_per_unit * cfg.mem_units
    t_mem = dram_bytes / bw

    # Tensor-parallel activation exchange (partial-sum/act reduce per op) +
    # handoff of the stage output to the next stage over the package link.
    out_bytes = ops[-1].act_out_bytes * B
    tp_bytes = sum(o.act_out_bytes for o in ops) * B * (tp - 1)
    t_comm = (tp_bytes + out_bytes) / c.interchip_bw
    e_link = (tp_bytes + out_bytes) * 8.0 * E_INTERCHIP_BIT

    # Double-buffered pipeline: compute overlaps DMA (Fig. 4 template).
    t_batch = max(t_compute, t_mem) + t_comm
    e_dyn = (e_mac + sram_traffic * E_SRAM_BYTE + mem.energy_j(dram_bytes)
             + e_link)
    return StageOption(
        t_cmp=t_batch / B,
        e_dyn=e_dyn / B,
        p_static=c.static_power_w * tp,
        hw_cost_usd=0.0,          # filled by costmodel.price_stage_options
        cfg=cfg,
        group_name=name,
        flops_per_sample=sum(o.flops for o in ops),
    )


def scale_option(o: StageOption, repeat: int) -> StageOption:
    """A fusion group repeated `repeat` times (e.g. one per transformer
    layer) contributes `repeat` physical pipeline stages that share one
    configuration: energy/cost/leakage scale, per-stage latency doesn't."""
    if repeat == 1:
        return o
    return dataclasses.replace(
        o, e_dyn=o.e_dyn * repeat, p_static=o.p_static * repeat,
        hw_cost_usd=o.hw_cost_usd * repeat,
        flops_per_sample=o.flops_per_sample * repeat, repeat=repeat)


@dataclasses.dataclass(frozen=True)
class StageOptionColumns:
    """Column-major form of one (fusion group, chiplet SKU) option block.

    This is the value the per-SKU option cache stores and the process-pool
    warmup ships between processes: four float64 columns plus the shared
    per-block metadata.  StageOption objects are materialized lazily (via
    `option`) only when a solver actually selects one, which skips the
    dominant cost of eager enumeration — constructing tens of thousands
    of dataclass instances that the sweep never touches.
    """

    t_cmp: np.ndarray
    e_dyn: np.ndarray
    p_static: np.ndarray
    hw_cost_usd: np.ndarray
    cfgs: tuple[StageConfig, ...]
    group_name: str = ""
    flops_per_sample: float = 0.0
    repeat: int = 1
    # per-block derived caches (e.g. dominance-pruned indices), keyed by
    # the weighted flag; excluded from equality/repr.
    _derived: dict = dataclasses.field(default_factory=dict, repr=False,
                                       compare=False)

    def __len__(self) -> int:
        return len(self.cfgs)

    def keep_idx(self, weighted: bool) -> np.ndarray:
        """Indices of options not dominated within THIS block.  Within-
        block dominance implies full-set dominance, and dominance is
        transitive (including the earlier-index tie-break, which block
        concatenation order preserves), so pre-pruning per block before
        the cross-SKU pass keeps exactly the full-set survivor set —
        while caching the quadratic mask per block, shared by every
        pool and genome that reuses the block."""
        got = self._derived.get(weighted)
        if got is None:
            w = np.maximum(self.hw_cost_usd, 1e-9) if weighted else 1.0
            got = np.flatnonzero(envelope_keep_mask(
                self.t_cmp, self.p_static * w, self.e_dyn * w))
            self._derived[weighted] = got
        return got

    def option(self, i: int) -> StageOption:
        """Materialize option i — bit-identical to eager enumeration
        (the floats are copied verbatim from the batched evaluation)."""
        return StageOption(
            t_cmp=float(self.t_cmp[i]), e_dyn=float(self.e_dyn[i]),
            p_static=float(self.p_static[i]),
            hw_cost_usd=float(self.hw_cost_usd[i]), cfg=self.cfgs[i],
            group_name=self.group_name,
            flops_per_sample=self.flops_per_sample, repeat=self.repeat)

    def options(self) -> tuple[StageOption, ...]:
        return tuple(self.option(i) for i in range(len(self.cfgs)))


_option_set_uid = itertools.count()


class StageOptionSet(Sequence):
    """A sequence of StageOptions with lazily-built column arrays.

    `solve_pipeline` consumes the (t_cmp, e_dyn, p_static, hw_cost)
    columns directly when sweeping the iso-latency grid, so the arrays
    are built once per cached option set instead of once per GA genome.

    Two construction modes: from materialized StageOptions (the seed
    path), or via `from_blocks` from per-SKU StageOptionColumns (the
    engine path) — there the columns are concatenated array blocks and
    individual StageOptions materialize only on demand (`opts[i]` in a
    solver's second pass).  `uid` is a process-unique token used to
    memoize derived values (e.g. the default latency grid) per option
    set without risking id() reuse after garbage collection.
    """

    __slots__ = ("_options", "_blocks", "_offsets", "_cols", "_pruned",
                 "uid")

    def __init__(self, options: Iterable[StageOption] = ()):
        self._options: tuple[StageOption, ...] | None = tuple(options)
        self._blocks: tuple[StageOptionColumns, ...] | None = None
        self._offsets: list[int] | None = None
        self._cols: tuple[np.ndarray, ...] | None = None
        self._pruned: dict[bool, tuple] = {}
        self.uid = next(_option_set_uid)

    @classmethod
    def from_blocks(cls, blocks: Iterable[StageOptionColumns]
                    ) -> "StageOptionSet":
        self = cls.__new__(cls)
        self._options = None
        self._blocks = tuple(blocks)
        offs = [0]
        for b in self._blocks:
            offs.append(offs[-1] + len(b))
        self._offsets = offs
        self._cols = None
        self._pruned = {}
        self.uid = next(_option_set_uid)
        return self

    @property
    def options(self) -> tuple[StageOption, ...]:
        if self._options is None:
            self._options = tuple(o for b in self._blocks
                                  for o in b.options())
        return self._options

    def __len__(self) -> int:
        if self._options is not None:
            return len(self._options)
        return self._offsets[-1]

    def __getitem__(self, i):
        if self._options is not None:
            return self._options[i]
        if isinstance(i, slice):
            return self.options[i]
        n = self._offsets[-1]
        if i < 0:
            i += n
        if not 0 <= i < n:
            raise IndexError(i)
        b = bisect.bisect_right(self._offsets, i) - 1
        return self._blocks[b].option(i - self._offsets[b])

    def __iter__(self):
        if self._options is not None:
            return iter(self._options)
        return (o for b in self._blocks for o in b.options())

    def columns(self) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                               np.ndarray]:
        if self._cols is None:
            if self._blocks is not None:
                bl = [b for b in self._blocks if len(b)]
                if not bl:
                    empty = np.empty(0, dtype=np.float64)
                    self._cols = (empty,) * 4
                else:
                    self._cols = (
                        np.concatenate([b.t_cmp for b in bl]),
                        np.concatenate([b.e_dyn for b in bl]),
                        np.concatenate([b.p_static for b in bl]),
                        np.concatenate([b.hw_cost_usd for b in bl]))
            else:
                o = self._options
                self._cols = (
                    np.array([x.t_cmp for x in o], dtype=np.float64),
                    np.array([x.e_dyn for x in o], dtype=np.float64),
                    np.array([x.p_static for x in o], dtype=np.float64),
                    np.array([x.hw_cost_usd for x in o], dtype=np.float64))
        return self._cols

    def pruned(self, weighted: bool) -> tuple[np.ndarray, np.ndarray,
                                              np.ndarray, np.ndarray]:
        """(t_cmp, slope, intercept, original_index) restricted to
        non-dominated options — exact: pruning never changes the envelope
        minimum at any latency, nor the hull engine's tie-break winner.

        Block-built sets prune in two exact stages: each block's cached
        within-block survivors first (see StageOptionColumns.keep_idx),
        then the cross-SKU mask over the much smaller concatenation —
        transitivity of the dominance relation makes the final survivor
        set identical to a one-shot full mask."""
        cached = self._pruned.get(weighted)
        if cached is not None:
            return cached
        if self._blocks is not None:
            ts, ss, cs, gs = [], [], [], []
            for b, off in zip(self._blocks, self._offsets):
                if not len(b):
                    continue
                kidx = b.keep_idx(weighted)
                w = (np.maximum(b.hw_cost_usd[kidx], 1e-9) if weighted
                     else 1.0)
                ts.append(b.t_cmp[kidx])
                ss.append(b.p_static[kidx] * w)
                cs.append(b.e_dyn[kidx] * w)
                gs.append(off + kidx)
            if not ts:
                empty = np.empty(0, dtype=np.float64)
                cached = (empty, empty, empty,
                          np.empty(0, dtype=np.intp))
            else:
                t_cmp = np.concatenate(ts)
                slope = np.concatenate(ss)
                icept = np.concatenate(cs)
                gidx = np.concatenate(gs)
                keep = np.flatnonzero(envelope_keep_mask(t_cmp, slope,
                                                         icept))
                cached = (t_cmp[keep], slope[keep], icept[keep],
                          gidx[keep])
        else:
            t_cmp, e_dyn, p_static, hw = self.columns()
            w = np.maximum(hw, 1e-9) if weighted else 1.0
            slope, icept = p_static * w, e_dyn * w
            idx = np.flatnonzero(envelope_keep_mask(t_cmp, slope, icept))
            cached = (t_cmp[idx], slope[idx], icept[idx], idx)
        self._pruned[weighted] = cached
        return cached


def envelope_keep_mask(t_cmp: np.ndarray, slope: np.ndarray,
                       icept: np.ndarray) -> np.ndarray:
    """Mask of options NOT dominated by a single other option.

    Option j is dominated by k when k activates no later (t_cmp_k <=
    t_cmp_j) and its line lies at-or-below j's everywhere (slope and
    intercept both <=), with either some strict inequality or, for exact
    duplicates, an earlier index (matching the hull's first-insert-wins
    tie-break).  Every removed option has a surviving dominator, so the
    envelope minimum — and the chosen payload under the (value, t_cmp,
    index) tie-break — is unchanged at every query latency.
    """
    m = t_cmp.size
    if m <= 2:
        return np.ones(m, dtype=bool)
    le = ((t_cmp[:, None] <= t_cmp) & (slope[:, None] <= slope)
          & (icept[:, None] <= icept))
    strict = ((t_cmp[:, None] < t_cmp) | (slope[:, None] < slope)
              | (icept[:, None] < icept))
    order = np.arange(m)
    dominated = np.any(le & (strict | (order[:, None] < order)), axis=0)
    return ~dominated


class ConfigGrid:
    """A (chiplet, memory, mem_units, tp, batch) config grid with hoisted
    per-config numeric columns.

    The grid for a fusion group depends on the group's ops only through
    its memory-capacity footprint, so identical grids recur constantly
    across fusion groups, genomes, and SA iterations; `config_grid`
    memoizes them.  The config-derived numeric arrays (batch, tp,
    mem_units, bandwidth, DRAM energy) and the per-cost-function cost
    rows are built once per distinct grid and reused by every batched
    group evaluation on it ("grid hoisting")."""

    __slots__ = ("cfgs", "chips", "chip_idx", "_numeric", "_cost_rows")

    def __init__(self, cfgs: Iterable[StageConfig]):
        self.cfgs = tuple(cfgs)
        chip_index: dict[Chiplet, int] = {}
        chips: list[Chiplet] = []
        idx = np.empty(len(self.cfgs), dtype=np.intp)
        for j, cfg in enumerate(self.cfgs):
            i = chip_index.get(cfg.chiplet)
            if i is None:
                i = chip_index[cfg.chiplet] = len(chips)
                chips.append(cfg.chiplet)
            idx[j] = i
        self.chips = tuple(chips)       # first-appearance order
        self.chip_idx = idx
        self._numeric: tuple[np.ndarray, ...] | None = None
        self._cost_rows: dict[Callable, np.ndarray] = {}

    def __len__(self) -> int:
        return len(self.cfgs)

    def numeric(self) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                               np.ndarray, np.ndarray]:
        """(batch, tp, mem_units, bw_per_unit, pj_per_bit) columns."""
        if self._numeric is None:
            cfgs = self.cfgs
            self._numeric = (
                np.array([c.batch for c in cfgs], dtype=np.float64),
                np.array([c.tp for c in cfgs], dtype=np.float64),
                np.array([c.mem_units for c in cfgs], dtype=np.float64),
                np.array([c.memory.bw_per_unit for c in cfgs],
                         dtype=np.float64),
                np.array([c.memory.pj_per_bit for c in cfgs],
                         dtype=np.float64))
        return self._numeric

    def cost_row(self, cost_fn: Callable[[StageConfig], float]
                 ) -> np.ndarray:
        row = self._cost_rows.get(cost_fn)
        if row is None:
            row = np.array([cost_fn(c) for c in self.cfgs],
                           dtype=np.float64)
            self._cost_rows[cost_fn] = row
        return row


def _build_config_grid(pool: tuple[Chiplet, ...],
                       memories: tuple[MemoryType, ...],
                       batches: tuple[int, ...], tps: tuple[int, ...],
                       fixed_batch: int | None, max_mem_units: int,
                       min_units_by_memory: tuple[int, ...]
                       ) -> list[StageConfig]:
    bs = (fixed_batch,) if fixed_batch is not None else batches
    cfgs: list[StageConfig] = []
    for c in pool:
        for m, min_units in zip(memories, min_units_by_memory):
            if min_units > max_mem_units:
                continue
            for units in sorted({min_units, min(min_units * 2, max_mem_units),
                                 max_mem_units}):
                for tp in tps:
                    for b in bs:
                        cfgs.append(StageConfig(chiplet=c, memory=m,
                                                mem_units=units, tp=tp,
                                                batch=b))
    return cfgs


@functools.lru_cache(maxsize=65536)
def _config_grid_cached(pool: tuple, memories: tuple, batches: tuple,
                        tps: tuple, fixed_batch: int | None,
                        max_mem_units: int,
                        min_units_by_memory: tuple[int, ...]) -> ConfigGrid:
    return ConfigGrid(_build_config_grid(pool, memories, batches, tps,
                                         fixed_batch, max_mem_units,
                                         min_units_by_memory))


def _group_capacity(ops: Sequence[Operator]) -> float:
    return sum(o.weight_bytes for o in ops) + \
        max((o.act_in_bytes + o.act_out_bytes) for o in ops)


def config_grid(ops: Sequence[Operator], pool: Sequence[Chiplet],
                memories: Sequence[MemoryType] = MEMORY_POOL,
                batches: Sequence[int] = BATCH_OPTIONS,
                tps: Sequence[int] = TP_OPTIONS,
                fixed_batch: int | None = None,
                max_mem_units: int = 8) -> ConfigGrid:
    """Memoized ConfigGrid for a fusion group (the engine path).

    The group's ops enter the grid only through the per-memory minimum
    unit count its capacity footprint implies, so the cache is keyed on
    that small derived tuple rather than the raw capacity — groups with
    different weights but the same memory-unit needs share one grid
    (and its hoisted numeric columns and cost rows)."""
    capacity = _group_capacity(ops)
    memories = tuple(memories)
    min_units = tuple(m.units_for(capacity, 0) for m in memories)
    return _config_grid_cached(tuple(pool), memories, tuple(batches),
                               tuple(tps), fixed_batch, max_mem_units,
                               min_units)


@functools.lru_cache(maxsize=65536)
def _chip_rows_cached(chips: tuple[Chiplet, ...],
                      kinds: tuple[str, ...]) -> np.ndarray:
    """Chiplet-derived model parameters per (chip set, operator kinds) —
    the only ops-dependence is through the kinds, so rows are shared
    across every fusion group with the same operator-kind signature."""
    return np.array([(c.peak_flops, c.n_pes, c.glb_bytes,
                      c.static_power_w, c.interchip_bw,
                      *(c.utilization(k) for k in kinds),
                      *(c.sram_traffic_factor(k) for k in kinds))
                     for c in chips], dtype=np.float64)


def clear_perfmodel_caches() -> None:
    _config_grid_cached.cache_clear()
    _chip_rows_cached.cache_clear()


def stage_config_grid(ops: Sequence[Operator],
                      pool: Sequence[Chiplet],
                      memories: Sequence[MemoryType] = MEMORY_POOL,
                      batches: Sequence[int] = BATCH_OPTIONS,
                      tps: Sequence[int] = TP_OPTIONS,
                      fixed_batch: int | None = None,
                      max_mem_units: int = 8) -> list[StageConfig]:
    """The exact (chiplet, memory, mem_units, tp, batch) tuples a fusion
    group is evaluated on — the `M` axis of Algorithm 1.  Built fresh on
    every call (the seed path is deliberately uncached; the engine path
    goes through the memoized `config_grid`)."""
    memories = tuple(memories)
    capacity = _group_capacity(ops)
    min_units = tuple(m.units_for(capacity, 0) for m in memories)
    return _build_config_grid(tuple(pool), memories, tuple(batches),
                              tuple(tps), fixed_batch, max_mem_units,
                              min_units)


def _group_numeric(ops: Sequence[Operator], grid: ConfigGrid
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Batched roofline evaluation of a fusion group over a config grid:
    (t_cmp, e_dyn per sample, p_static) columns, before repeat scaling.

    Every arithmetic step mirrors the scalar `evaluate_group` path
    operation-for-operation (same association order, IEEE float64
    throughout), so the columns are bit-identical to per-config calls.
    Chiplet-derived values are computed once per distinct chiplet and
    gathered; config-derived columns come prebuilt from the grid.
    """
    n = len(grid)
    rows = _chip_rows_cached(grid.chips,
                             tuple(op.kind for op in ops))[grid.chip_idx]
    peak, n_pes, glb, p_stat, ic_bw = rows[:, :5].T
    util = rows[:, 5:5 + len(ops)].T
    stf = rows[:, 5 + len(ops):].T
    B, tp, units, bw_pu, pj_bit = grid.numeric()

    t_compute = np.zeros(n)
    e_mac = np.zeros(n)
    sram_traffic = np.zeros(n)
    for i, op in enumerate(ops):
        size_eff = np.minimum(1.0, (op.parallel_work * B) / (n_pes * tp))
        rate = peak * util[i] * size_eff * tp
        t_compute += (op.flops * B) / np.maximum(rate, 1.0)
        e_mac += op.flops * B * 0.5 * E_MAC_BASE
        sram_traffic += (op.act_in_bytes + op.act_out_bytes) * B * stf[i]

    # _group_dram_bytes, vectorized over (glb*tp, B).
    usable = glb * tp / 2
    dram = np.zeros(n)
    for i, op in enumerate(ops):
        w = op.weight_bytes
        if op.weight_reuse_divisor > 1.0:
            dram += np.minimum(
                op.weight_bytes,
                (op.weight_bytes / op.weight_reuse_divisor) * B)
        else:
            dram += w
        a_in = op.act_in_bytes * B
        a_out = op.act_out_bytes * B
        if i == 0:
            dram += a_in
        else:
            dram += np.where(ops[i - 1].act_out_bytes * B > usable,
                             a_in, 0.0)
        if i == len(ops) - 1:
            dram += a_out
        else:
            dram += np.where(a_out > usable, a_out, 0.0)

    bw = bw_pu * units
    t_mem = dram / bw

    out_bytes = ops[-1].act_out_bytes * B
    tp_bytes = sum(o.act_out_bytes for o in ops) * B * (tp - 1)
    t_comm = (tp_bytes + out_bytes) / ic_bw
    e_link = (tp_bytes + out_bytes) * 8.0 * E_INTERCHIP_BIT

    t_batch = np.maximum(t_compute, t_mem) + t_comm
    e_mem = dram * 8.0 * pj_bit * 1e-12
    e_dyn = (e_mac + sram_traffic * E_SRAM_BYTE + e_mem + e_link)

    return t_batch / B, e_dyn / B, p_stat * tp


def _scaled_group_columns(ops: Sequence[Operator], grid: ConfigGrid,
                          cost_fn: Callable[[StageConfig], float] | None,
                          repeat: int
                          ) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                     np.ndarray, float]:
    """(t_cmp, e_dyn, p_static, hw_cost, flops_per_sample) with repeat
    folded in — scale_option semantics: energy/leakage/cost/FLOPs scale
    with the physical copy count, per-stage latency doesn't."""
    t_cmp, e_per, p_static = _group_numeric(ops, grid)
    flops_per_sample = sum(o.flops for o in ops)
    if repeat != 1:
        e_per = e_per * repeat
        p_static = p_static * repeat
        flops_per_sample = flops_per_sample * repeat
    if cost_fn is None:
        hw = np.zeros(len(grid))
    else:
        hw = grid.cost_row(cost_fn) * repeat
    return t_cmp, e_per, p_static, hw, flops_per_sample


def evaluate_group_batch(ops: Sequence[Operator],
                         cfgs: "Sequence[StageConfig] | ConfigGrid",
                         name: str = "",
                         cost_fn: Callable[[StageConfig], float] | None = None,
                         repeat: int = 1) -> list[StageOption]:
    """Vectorized `evaluate_group` over a list of stage configs.

    The numeric core (`_group_numeric`) mirrors the scalar path
    operation-for-operation, so the returned StageOptions are
    bit-identical to per-config `evaluate_group` calls.  repeat > 1
    additionally folds `scale_option` into construction.
    """
    if not len(cfgs):
        return []
    grid = cfgs if isinstance(cfgs, ConfigGrid) else ConfigGrid(cfgs)
    t_cmp, e_per, p_static, hw, flops_per_sample = _scaled_group_columns(
        ops, grid, cost_fn, repeat)
    t_cmp_l = t_cmp.tolist()
    e_per_l = e_per.tolist()
    p_static_l = p_static.tolist()
    hw_l = hw.tolist()
    return [StageOption(
        t_cmp=t_cmp_l[j], e_dyn=e_per_l[j], p_static=p_static_l[j],
        hw_cost_usd=hw_l[j],
        cfg=cfg, group_name=name, flops_per_sample=flops_per_sample,
        repeat=repeat)
        for j, cfg in enumerate(grid.cfgs)]


def evaluate_group_columns(ops: Sequence[Operator], grid: ConfigGrid,
                           name: str = "",
                           cost_fn: Callable[[StageConfig], float]
                           | None = None,
                           repeat: int = 1) -> StageOptionColumns:
    """Column form of `evaluate_group_batch`: same numeric core, no
    per-option object construction."""
    if not len(grid):
        empty = np.empty(0, dtype=np.float64)
        return StageOptionColumns(
            t_cmp=empty, e_dyn=empty, p_static=empty, hw_cost_usd=empty,
            cfgs=(), group_name=name,
            flops_per_sample=(sum(o.flops for o in ops)
                              * (repeat if repeat != 1 else 1)),
            repeat=repeat)
    t_cmp, e_per, p_static, hw, flops_per_sample = _scaled_group_columns(
        ops, grid, cost_fn, repeat)
    return StageOptionColumns(
        t_cmp=t_cmp, e_dyn=e_per, p_static=p_static, hw_cost_usd=hw,
        cfgs=grid.cfgs, group_name=name,
        flops_per_sample=flops_per_sample, repeat=repeat)


def enumerate_stage_options(
        ops: Sequence[Operator],
        pool: Sequence[Chiplet],
        memories: Sequence[MemoryType] = MEMORY_POOL,
        batches: Sequence[int] = BATCH_OPTIONS,
        tps: Sequence[int] = TP_OPTIONS,
        name: str = "",
        fixed_batch: int | None = None,
        max_mem_units: int = 8,
        vectorize: bool | None = None,
        cost_fn: Callable[[StageConfig], float] | None = None,
        repeat: int = 1) -> list[StageOption]:
    """All StageOptions for a fusion group: the `M` of Algorithm 1.

    vectorize=None follows the global engine switch; the scalar and
    batched paths produce identical options.  cost_fn, when given, fills
    hw_cost_usd at construction (saves a re-pricing pass); repeat folds
    `scale_option` into construction.
    """
    if vectorize is None:
        vectorize = engine_enabled()
    if vectorize:
        grid = config_grid(ops, pool, memories=memories, batches=batches,
                           tps=tps, fixed_batch=fixed_batch,
                           max_mem_units=max_mem_units)
        return evaluate_group_batch(ops, grid, name=name, cost_fn=cost_fn,
                                    repeat=repeat)
    cfgs = stage_config_grid(ops, pool, memories=memories, batches=batches,
                             tps=tps, fixed_batch=fixed_batch,
                             max_mem_units=max_mem_units)
    out = [evaluate_group(ops, cfg, name=name) for cfg in cfgs]
    if cost_fn is not None:
        out = [dataclasses.replace(o, hw_cost_usd=cost_fn(o.cfg))
               for o in out]
    if repeat != 1:
        out = [scale_option(o, repeat) for o in out]
    return out


def enumerate_stage_options_by_chiplet(
        ops: Sequence[Operator],
        chiplets: Sequence[Chiplet],
        memories: Sequence[MemoryType] = MEMORY_POOL,
        batches: Sequence[int] = BATCH_OPTIONS,
        tps: Sequence[int] = TP_OPTIONS,
        name: str = "",
        fixed_batch: int | None = None,
        max_mem_units: int = 8,
        cost_fn: Callable[[StageConfig], float] | None = None,
        repeat: int = 1) -> dict[Chiplet, tuple[StageOption, ...]]:
    """One `evaluate_group_batch` call covering several chiplet SKUs at
    once, split back per SKU.

    `stage_config_grid` emits each chiplet's configs contiguously and the
    batched evaluation is row-wise element-wise, so every per-SKU slice is
    bit-identical to a separate single-SKU `enumerate_stage_options` call.
    This is the population-batch entry point: the Layer-2 GA enumerates
    all missing (fusion group, SKU) pairs of a whole genome population
    through it instead of one call per SKU.
    """
    opts = enumerate_stage_options(ops, chiplets, memories=memories,
                                   batches=batches, tps=tps, name=name,
                                   fixed_batch=fixed_batch,
                                   max_mem_units=max_mem_units,
                                   vectorize=True, cost_fn=cost_fn,
                                   repeat=repeat)
    out: dict[Chiplet, list[StageOption]] = {c: [] for c in chiplets}
    for o in opts:
        out[o.cfg.chiplet].append(o)
    return {c: tuple(v) for c, v in out.items()}


def enumerate_stage_columns_by_chiplet(
        ops: Sequence[Operator],
        chiplets: Sequence[Chiplet],
        memories: Sequence[MemoryType] = MEMORY_POOL,
        batches: Sequence[int] = BATCH_OPTIONS,
        tps: Sequence[int] = TP_OPTIONS,
        name: str = "",
        fixed_batch: int | None = None,
        max_mem_units: int = 8,
        cost_fn: Callable[[StageConfig], float] | None = None,
        repeat: int = 1) -> dict[Chiplet, StageOptionColumns]:
    """Column form of `enumerate_stage_options_by_chiplet`: one batched
    evaluation over all SKUs' configs, split back into per-SKU
    StageOptionColumns blocks.

    `config_grid` emits each chiplet's configs contiguously and the
    batched evaluation is row-wise element-wise, so every per-SKU block
    is bit-identical to a separate single-SKU enumeration.  The split
    arrays are copied so a cached block never pins the whole-pool
    evaluation buffers (and stays contiguous for shared-memory export).
    """
    grid = config_grid(ops, chiplets, memories=memories, batches=batches,
                       tps=tps, fixed_batch=fixed_batch,
                       max_mem_units=max_mem_units)
    block = evaluate_group_columns(ops, grid, name=name, cost_fn=cost_fn,
                                   repeat=repeat)
    spans: dict[Chiplet, list[int]] = {}
    for j, cfg in enumerate(grid.cfgs):
        span = spans.get(cfg.chiplet)
        if span is None:
            spans[cfg.chiplet] = [j, j + 1]
        else:
            span[1] = j + 1             # contiguous by construction
    empty = np.empty(0, dtype=np.float64)
    out: dict[Chiplet, StageOptionColumns] = {}
    for c in chiplets:
        span = spans.get(c)
        if span is None:
            out[c] = StageOptionColumns(
                t_cmp=empty, e_dyn=empty, p_static=empty,
                hw_cost_usd=empty, cfgs=(), group_name=name,
                flops_per_sample=block.flops_per_sample, repeat=repeat)
            continue
        lo, hi = span
        out[c] = StageOptionColumns(
            t_cmp=block.t_cmp[lo:hi].copy(),
            e_dyn=block.e_dyn[lo:hi].copy(),
            p_static=block.p_static[lo:hi].copy(),
            hw_cost_usd=block.hw_cost_usd[lo:hi].copy(),
            cfgs=grid.cfgs[lo:hi], group_name=name,
            flops_per_sample=block.flops_per_sample, repeat=repeat)
    return out


def is_memory_bound(op: Operator, chiplet: Chiplet, mem: MemoryType,
                    batch: int = 1) -> bool:
    """Insight 1 classifier: does this operator saturate bandwidth before
    compute on the given hardware?"""
    util = chiplet.utilization(op.kind)
    size_eff = min(1.0, op.parallel_work * batch / chiplet.n_pes)
    t_c = op.flops * batch / max(chiplet.peak_flops * util * size_eff, 1.0)
    t_m = op.dram_bytes(batch) / mem.bw_per_unit
    return t_m > t_c


# ---------------------------------------------------------------------------
# GPU baseline (paper §5) — MODELED, not measured: no A100 in this
# environment.  Parameters documented; benchmarks flag this column
# "modeled".
# ---------------------------------------------------------------------------

GPU_PEAK_FLOPS = 312e12        # A100 bf16 dense
GPU_HBM_BW = 1.555e12          # bytes/s
GPU_TDP_W = 400.0
GPU_IDLE_W = 45.0              # measured idle power cited in paper §5
GPU_COST_USD = 10_000.0        # the paper's optimistic A100 price
GPU_KERNEL_OVERHEAD_S = 4e-6   # per-kernel launch (CUDA-graph amortized)
GPU_UTIL = {"gemm": 0.45, "conv": 0.35, "dwconv": 0.06, "attention": 0.30,
            "elementwise": 0.04, "norm": 0.04, "scan": 0.05, "embed": 0.10}


def gpu_eval(ops: Iterable[Operator], repeats: Iterable[int],
             batch: int = 1) -> tuple[float, float]:
    """(latency_s, energy_J) per batch on the modeled GPU."""
    t_total = 0.0
    for op, r in zip(ops, repeats):
        util = GPU_UTIL[op.kind]
        size_eff = min(1.0, op.parallel_work * batch / (GPU_PEAK_FLOPS / 2e9))
        t_c = op.flops * batch / (GPU_PEAK_FLOPS * util * max(size_eff, 1e-3))
        t_m = op.dram_bytes(batch) / GPU_HBM_BW
        t_total += (max(t_c, t_m) + GPU_KERNEL_OVERHEAD_S) * r
    energy = GPU_TDP_W * t_total
    return t_total, energy
