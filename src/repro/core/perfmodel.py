"""First-order performance & energy model (the Timeloop/Accelergy stand-in).

Evaluates a *fusion group* (a consecutive run of operators mapped to one
pipeline stage) on a chiplet + memory configuration, producing the
piecewise-affine energy function of paper §4.3.1:

    E(T) = e_dyn + p_static * T     for T >= t_cmp,   infinite below.

All stage quantities are normalized PER SAMPLE so that stages running
different batch sizes (Insight 2: non-uniform batching) compose into one
pipeline with a common per-sample initiation interval T.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterable, Sequence

import numpy as np

from .chiplets import (Chiplet, E_INTERCHIP_BIT, E_MAC_BASE, E_SRAM_BYTE)
from .engine import engine_enabled
from .memory import MEMORY_POOL, MemoryType
from .operators import BATCH_AGNOSTIC, Operator

TP_OPTIONS = (1, 2)                      # paper Table 4
BATCH_OPTIONS = (1, 2, 4, 8, 16, 32)     # per-stage microbatch choices
MEM_UNIT_OPTIONS = (1, 2, 4, 8)


@dataclasses.dataclass(frozen=True)
class StageConfig:
    chiplet: Chiplet
    memory: MemoryType
    mem_units: int
    tp: int
    batch: int

    @property
    def label(self) -> str:
        return (f"{self.chiplet.label}|{self.memory.name}x{self.mem_units}"
                f"|tp{self.tp}|b{self.batch}")

    def to_dict(self) -> dict:
        return {"chiplet": self.chiplet.to_dict(),
                "memory": self.memory.to_dict(),
                "mem_units": self.mem_units, "tp": self.tp,
                "batch": self.batch}

    @staticmethod
    def from_dict(d: dict) -> "StageConfig":
        return StageConfig(chiplet=Chiplet.from_dict(d["chiplet"]),
                           memory=MemoryType.from_dict(d["memory"]),
                           mem_units=d["mem_units"], tp=d["tp"],
                           batch=d["batch"])


@dataclasses.dataclass(frozen=True)
class StageOption:
    """One (chiplet, memory, tp, batch) choice for a fusion group, reduced
    to the piecewise-affine energy form. Per-sample units."""
    t_cmp: float          # min achievable per-sample latency (s)
    e_dyn: float          # dynamic energy per sample (J)
    p_static: float       # leakage power while stage is alive (W)
    hw_cost_usd: float    # manufacturing cost of this stage's hardware
    cfg: StageConfig
    group_name: str = ""
    flops_per_sample: float = 0.0   # useful FLOPs (utilization metrics)
    repeat: int = 1                 # physical copies of this stage

    def energy_at(self, t: float) -> float:
        if t < self.t_cmp:
            return math.inf
        return self.e_dyn + self.p_static * t

    def to_dict(self) -> dict:
        return {"t_cmp": self.t_cmp, "e_dyn": self.e_dyn,
                "p_static": self.p_static, "hw_cost_usd": self.hw_cost_usd,
                "cfg": self.cfg.to_dict(), "group_name": self.group_name,
                "flops_per_sample": self.flops_per_sample,
                "repeat": self.repeat}

    @staticmethod
    def from_dict(d: dict) -> "StageOption":
        return StageOption(
            t_cmp=d["t_cmp"], e_dyn=d["e_dyn"], p_static=d["p_static"],
            hw_cost_usd=d["hw_cost_usd"],
            cfg=StageConfig.from_dict(d["cfg"]),
            group_name=d["group_name"],
            flops_per_sample=d["flops_per_sample"], repeat=d["repeat"])


def _group_dram_bytes(ops: Sequence[Operator], glb_bytes: int,
                      batch: int) -> tuple[float, float]:
    """(dram_bytes, sram_bytes) for one batch-pass of the fused group.

    Tensor fusion keeps inter-operator intermediates in the GLB when they
    fit (half the GLB — the other half is the double buffer); spilled
    intermediates cost a DRAM write + read.
    """
    dram = 0.0
    sram = 0.0
    usable = glb_bytes / 2
    for i, op in enumerate(ops):
        w = op.weight_bytes
        if op.weight_reuse_divisor > 1.0:   # MoE: touched experts only
            w = min(op.weight_bytes,
                    (op.weight_bytes / op.weight_reuse_divisor) * batch)
        dram += w
        a_in = op.act_in_bytes * batch
        a_out = op.act_out_bytes * batch
        if i == 0:
            dram += a_in
        elif ops[i - 1].act_out_bytes * batch > usable:
            dram += a_in                     # re-read the spill
        if i == len(ops) - 1:
            dram += a_out
        elif a_out > usable:
            dram += a_out                    # spill write
        sram += (a_in + a_out)
    return dram, sram


def evaluate_group(ops: Sequence[Operator], cfg: StageConfig,
                   name: str = "") -> StageOption:
    """Roofline latency + energy for a fusion group on one stage config."""
    c, mem, B, tp = cfg.chiplet, cfg.memory, cfg.batch, cfg.tp

    t_compute = 0.0
    e_mac = 0.0
    sram_traffic = 0.0
    for op in ops:
        util = c.utilization(op.kind)
        # Small operators cannot fill a big array (Insight 4 / decode GEMV).
        size_eff = min(1.0, (op.parallel_work * B) / (c.n_pes * tp))
        rate = c.peak_flops * util * size_eff * tp
        t_compute += (op.flops * B) / max(rate, 1.0)
        e_mac += op.flops * B * 0.5 * E_MAC_BASE
        sram_traffic += ((op.act_in_bytes + op.act_out_bytes) * B
                         * c.sram_traffic_factor(op.kind))

    dram_bytes, _ = _group_dram_bytes(ops, c.glb_bytes * tp, B)
    bw = mem.bw_per_unit * cfg.mem_units
    t_mem = dram_bytes / bw

    # Tensor-parallel activation exchange (partial-sum/act reduce per op) +
    # handoff of the stage output to the next stage over the package link.
    out_bytes = ops[-1].act_out_bytes * B
    tp_bytes = sum(o.act_out_bytes for o in ops) * B * (tp - 1)
    t_comm = (tp_bytes + out_bytes) / c.interchip_bw
    e_link = (tp_bytes + out_bytes) * 8.0 * E_INTERCHIP_BIT

    # Double-buffered pipeline: compute overlaps DMA (Fig. 4 template).
    t_batch = max(t_compute, t_mem) + t_comm
    e_dyn = (e_mac + sram_traffic * E_SRAM_BYTE + mem.energy_j(dram_bytes)
             + e_link)
    return StageOption(
        t_cmp=t_batch / B,
        e_dyn=e_dyn / B,
        p_static=c.static_power_w * tp,
        hw_cost_usd=0.0,          # filled by costmodel.price_stage_options
        cfg=cfg,
        group_name=name,
        flops_per_sample=sum(o.flops for o in ops),
    )


def scale_option(o: StageOption, repeat: int) -> StageOption:
    """A fusion group repeated `repeat` times (e.g. one per transformer
    layer) contributes `repeat` physical pipeline stages that share one
    configuration: energy/cost/leakage scale, per-stage latency doesn't."""
    if repeat == 1:
        return o
    return dataclasses.replace(
        o, e_dyn=o.e_dyn * repeat, p_static=o.p_static * repeat,
        hw_cost_usd=o.hw_cost_usd * repeat,
        flops_per_sample=o.flops_per_sample * repeat, repeat=repeat)


class StageOptionSet(Sequence):
    """A sequence of StageOptions with lazily-built column arrays.

    `solve_pipeline` consumes the (t_cmp, e_dyn, p_static, hw_cost)
    columns directly when sweeping the iso-latency grid, so the arrays
    are built once per cached option set instead of once per GA genome.
    """

    __slots__ = ("options", "_cols", "_pruned")

    def __init__(self, options: Iterable[StageOption]):
        self.options = tuple(options)
        self._cols: tuple[np.ndarray, ...] | None = None
        self._pruned: dict[bool, tuple] = {}

    def __len__(self) -> int:
        return len(self.options)

    def __getitem__(self, i):
        return self.options[i]

    def __iter__(self):
        return iter(self.options)

    def columns(self) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                               np.ndarray]:
        if self._cols is None:
            o = self.options
            self._cols = (
                np.array([x.t_cmp for x in o], dtype=np.float64),
                np.array([x.e_dyn for x in o], dtype=np.float64),
                np.array([x.p_static for x in o], dtype=np.float64),
                np.array([x.hw_cost_usd for x in o], dtype=np.float64))
        return self._cols

    def pruned(self, weighted: bool) -> tuple[np.ndarray, np.ndarray,
                                              np.ndarray, np.ndarray]:
        """(t_cmp, slope, intercept, original_index) restricted to
        non-dominated options — exact: pruning never changes the envelope
        minimum at any latency, nor the hull engine's tie-break winner."""
        cached = self._pruned.get(weighted)
        if cached is None:
            t_cmp, e_dyn, p_static, hw = self.columns()
            w = np.maximum(hw, 1e-9) if weighted else 1.0
            slope, icept = p_static * w, e_dyn * w
            idx = np.flatnonzero(envelope_keep_mask(t_cmp, slope, icept))
            cached = (t_cmp[idx], slope[idx], icept[idx], idx)
            self._pruned[weighted] = cached
        return cached


def envelope_keep_mask(t_cmp: np.ndarray, slope: np.ndarray,
                       icept: np.ndarray) -> np.ndarray:
    """Mask of options NOT dominated by a single other option.

    Option j is dominated by k when k activates no later (t_cmp_k <=
    t_cmp_j) and its line lies at-or-below j's everywhere (slope and
    intercept both <=), with either some strict inequality or, for exact
    duplicates, an earlier index (matching the hull's first-insert-wins
    tie-break).  Every removed option has a surviving dominator, so the
    envelope minimum — and the chosen payload under the (value, t_cmp,
    index) tie-break — is unchanged at every query latency.
    """
    m = t_cmp.size
    if m <= 2:
        return np.ones(m, dtype=bool)
    le = ((t_cmp[:, None] <= t_cmp) & (slope[:, None] <= slope)
          & (icept[:, None] <= icept))
    strict = ((t_cmp[:, None] < t_cmp) | (slope[:, None] < slope)
              | (icept[:, None] < icept))
    order = np.arange(m)
    dominated = np.any(le & (strict | (order[:, None] < order)), axis=0)
    return ~dominated


def stage_config_grid(ops: Sequence[Operator],
                      pool: Sequence[Chiplet],
                      memories: Sequence[MemoryType] = MEMORY_POOL,
                      batches: Sequence[int] = BATCH_OPTIONS,
                      tps: Sequence[int] = TP_OPTIONS,
                      fixed_batch: int | None = None,
                      max_mem_units: int = 8) -> list[StageConfig]:
    """The exact (chiplet, memory, mem_units, tp, batch) tuples a fusion
    group is evaluated on — the `M` axis of Algorithm 1."""
    capacity = sum(o.weight_bytes for o in ops) + \
        max((o.act_in_bytes + o.act_out_bytes) for o in ops)
    bs = (fixed_batch,) if fixed_batch is not None else tuple(batches)
    cfgs: list[StageConfig] = []
    for c in pool:
        for m in memories:
            min_units = m.units_for(capacity, 0)
            if min_units > max_mem_units:
                continue
            for units in sorted({min_units, min(min_units * 2, max_mem_units),
                                 max_mem_units}):
                for tp in tps:
                    for b in bs:
                        cfgs.append(StageConfig(chiplet=c, memory=m,
                                                mem_units=units, tp=tp,
                                                batch=b))
    return cfgs


def evaluate_group_batch(ops: Sequence[Operator],
                         cfgs: Sequence[StageConfig],
                         name: str = "",
                         cost_fn: Callable[[StageConfig], float] | None = None,
                         repeat: int = 1) -> list[StageOption]:
    """Vectorized `evaluate_group` over a list of stage configs.

    Every arithmetic step mirrors the scalar path operation-for-operation
    (same association order, IEEE float64 throughout), so the returned
    StageOptions are bit-identical to per-config `evaluate_group` calls.
    repeat > 1 additionally folds `scale_option` into construction.
    """
    if not cfgs:
        return []
    n = len(cfgs)
    # Per-config parameter columns; chiplet-derived values are computed
    # once per distinct chiplet and gathered.
    chip_index: dict[Chiplet, int] = {}
    chip_rows: list[tuple] = []
    idx = np.empty(len(cfgs), dtype=np.intp)
    for j, cfg in enumerate(cfgs):
        c = cfg.chiplet
        i = chip_index.get(c)
        if i is None:
            i = chip_index[c] = len(chip_rows)
            chip_rows.append((c.peak_flops, c.n_pes, c.glb_bytes,
                              c.static_power_w, c.interchip_bw,
                              *(c.utilization(op.kind) for op in ops),
                              *(c.sram_traffic_factor(op.kind)
                                for op in ops)))
        idx[j] = i
    rows = np.array(chip_rows, dtype=np.float64)[idx]
    peak, n_pes, glb, p_stat, ic_bw = rows[:, :5].T
    util = rows[:, 5:5 + len(ops)].T
    stf = rows[:, 5 + len(ops):].T
    B = np.array([cfg.batch for cfg in cfgs], dtype=np.float64)
    tp = np.array([cfg.tp for cfg in cfgs], dtype=np.float64)
    units = np.array([cfg.mem_units for cfg in cfgs], dtype=np.float64)
    bw_pu = np.array([cfg.memory.bw_per_unit for cfg in cfgs],
                     dtype=np.float64)
    pj_bit = np.array([cfg.memory.pj_per_bit for cfg in cfgs],
                      dtype=np.float64)

    t_compute = np.zeros(n)
    e_mac = np.zeros(n)
    sram_traffic = np.zeros(n)
    for i, op in enumerate(ops):
        size_eff = np.minimum(1.0, (op.parallel_work * B) / (n_pes * tp))
        rate = peak * util[i] * size_eff * tp
        t_compute += (op.flops * B) / np.maximum(rate, 1.0)
        e_mac += op.flops * B * 0.5 * E_MAC_BASE
        sram_traffic += (op.act_in_bytes + op.act_out_bytes) * B * stf[i]

    # _group_dram_bytes, vectorized over (glb*tp, B).
    usable = glb * tp / 2
    dram = np.zeros(n)
    for i, op in enumerate(ops):
        w = op.weight_bytes
        if op.weight_reuse_divisor > 1.0:
            dram += np.minimum(
                op.weight_bytes,
                (op.weight_bytes / op.weight_reuse_divisor) * B)
        else:
            dram += w
        a_in = op.act_in_bytes * B
        a_out = op.act_out_bytes * B
        if i == 0:
            dram += a_in
        else:
            dram += np.where(ops[i - 1].act_out_bytes * B > usable,
                             a_in, 0.0)
        if i == len(ops) - 1:
            dram += a_out
        else:
            dram += np.where(a_out > usable, a_out, 0.0)

    bw = bw_pu * units
    t_mem = dram / bw

    out_bytes = ops[-1].act_out_bytes * B
    tp_bytes = sum(o.act_out_bytes for o in ops) * B * (tp - 1)
    t_comm = (tp_bytes + out_bytes) / ic_bw
    e_link = (tp_bytes + out_bytes) * 8.0 * E_INTERCHIP_BIT

    t_batch = np.maximum(t_compute, t_mem) + t_comm
    e_mem = dram * 8.0 * pj_bit * 1e-12
    e_dyn = (e_mac + sram_traffic * E_SRAM_BYTE + e_mem + e_link)

    t_cmp = t_batch / B
    e_per = e_dyn / B
    p_static = p_stat * tp
    flops_per_sample = sum(o.flops for o in ops)
    if repeat != 1:
        # scale_option folded in: energy/leakage/cost/FLOPs scale with
        # the physical copy count, per-stage latency doesn't.
        e_per = e_per * repeat
        p_static = p_static * repeat
        flops_per_sample = flops_per_sample * repeat
    t_cmp_l = t_cmp.tolist()
    e_per_l = e_per.tolist()
    p_static_l = p_static.tolist()
    return [StageOption(
        t_cmp=t_cmp_l[j], e_dyn=e_per_l[j], p_static=p_static_l[j],
        hw_cost_usd=0.0 if cost_fn is None else cost_fn(cfg) * repeat,
        cfg=cfg, group_name=name, flops_per_sample=flops_per_sample,
        repeat=repeat)
        for j, cfg in enumerate(cfgs)]


def enumerate_stage_options(
        ops: Sequence[Operator],
        pool: Sequence[Chiplet],
        memories: Sequence[MemoryType] = MEMORY_POOL,
        batches: Sequence[int] = BATCH_OPTIONS,
        tps: Sequence[int] = TP_OPTIONS,
        name: str = "",
        fixed_batch: int | None = None,
        max_mem_units: int = 8,
        vectorize: bool | None = None,
        cost_fn: Callable[[StageConfig], float] | None = None,
        repeat: int = 1) -> list[StageOption]:
    """All StageOptions for a fusion group: the `M` of Algorithm 1.

    vectorize=None follows the global engine switch; the scalar and
    batched paths produce identical options.  cost_fn, when given, fills
    hw_cost_usd at construction (saves a re-pricing pass); repeat folds
    `scale_option` into construction.
    """
    cfgs = stage_config_grid(ops, pool, memories=memories, batches=batches,
                             tps=tps, fixed_batch=fixed_batch,
                             max_mem_units=max_mem_units)
    if vectorize is None:
        vectorize = engine_enabled()
    if vectorize:
        return evaluate_group_batch(ops, cfgs, name=name, cost_fn=cost_fn,
                                    repeat=repeat)
    out = [evaluate_group(ops, cfg, name=name) for cfg in cfgs]
    if cost_fn is not None:
        out = [dataclasses.replace(o, hw_cost_usd=cost_fn(o.cfg))
               for o in out]
    if repeat != 1:
        out = [scale_option(o, repeat) for o in out]
    return out


def enumerate_stage_options_by_chiplet(
        ops: Sequence[Operator],
        chiplets: Sequence[Chiplet],
        memories: Sequence[MemoryType] = MEMORY_POOL,
        batches: Sequence[int] = BATCH_OPTIONS,
        tps: Sequence[int] = TP_OPTIONS,
        name: str = "",
        fixed_batch: int | None = None,
        max_mem_units: int = 8,
        cost_fn: Callable[[StageConfig], float] | None = None,
        repeat: int = 1) -> dict[Chiplet, tuple[StageOption, ...]]:
    """One `evaluate_group_batch` call covering several chiplet SKUs at
    once, split back per SKU.

    `stage_config_grid` emits each chiplet's configs contiguously and the
    batched evaluation is row-wise element-wise, so every per-SKU slice is
    bit-identical to a separate single-SKU `enumerate_stage_options` call.
    This is the population-batch entry point: the Layer-2 GA enumerates
    all missing (fusion group, SKU) pairs of a whole genome population
    through it instead of one call per SKU.
    """
    opts = enumerate_stage_options(ops, chiplets, memories=memories,
                                   batches=batches, tps=tps, name=name,
                                   fixed_batch=fixed_batch,
                                   max_mem_units=max_mem_units,
                                   vectorize=True, cost_fn=cost_fn,
                                   repeat=repeat)
    out: dict[Chiplet, list[StageOption]] = {c: [] for c in chiplets}
    for o in opts:
        out[o.cfg.chiplet].append(o)
    return {c: tuple(v) for c, v in out.items()}


def is_memory_bound(op: Operator, chiplet: Chiplet, mem: MemoryType,
                    batch: int = 1) -> bool:
    """Insight 1 classifier: does this operator saturate bandwidth before
    compute on the given hardware?"""
    util = chiplet.utilization(op.kind)
    size_eff = min(1.0, op.parallel_work * batch / chiplet.n_pes)
    t_c = op.flops * batch / max(chiplet.peak_flops * util * size_eff, 1.0)
    t_m = op.dram_bytes(batch) / mem.bw_per_unit
    return t_m > t_c


# ---------------------------------------------------------------------------
# GPU baseline (paper §5) — MODELED, not measured: no A100 in this
# environment.  Parameters documented; benchmarks flag this column
# "modeled".
# ---------------------------------------------------------------------------

GPU_PEAK_FLOPS = 312e12        # A100 bf16 dense
GPU_HBM_BW = 1.555e12          # bytes/s
GPU_TDP_W = 400.0
GPU_IDLE_W = 45.0              # measured idle power cited in paper §5
GPU_COST_USD = 10_000.0        # the paper's optimistic A100 price
GPU_KERNEL_OVERHEAD_S = 4e-6   # per-kernel launch (CUDA-graph amortized)
GPU_UTIL = {"gemm": 0.45, "conv": 0.35, "dwconv": 0.06, "attention": 0.30,
            "elementwise": 0.04, "norm": 0.04, "scan": 0.05, "embed": 0.10}


def gpu_eval(ops: Iterable[Operator], repeats: Iterable[int],
             batch: int = 1) -> tuple[float, float]:
    """(latency_s, energy_J) per batch on the modeled GPU."""
    t_total = 0.0
    for op, r in zip(ops, repeats):
        util = GPU_UTIL[op.kind]
        size_eff = min(1.0, op.parallel_work * batch / (GPU_PEAK_FLOPS / 2e9))
        t_c = op.flops * batch / (GPU_PEAK_FLOPS * util * max(size_eff, 1e-3))
        t_m = op.dram_bytes(batch) / GPU_HBM_BW
        t_total += (max(t_c, t_m) + GPU_KERNEL_OVERHEAD_S) * r
    energy = GPU_TDP_W * t_total
    return t_total, energy
