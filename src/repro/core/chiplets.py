"""Chiplet design space (paper Table 4).

A chiplet is a compute die: a PE array with a given dataflow
(Row/Weight/Output-Stationary), a global buffer (GLB), and a bonding
technology.  Constants are first-order 14 nm figures in the
Eyeriss [12] / Simba [51] lineage — this module plays the role the
Timeloop architecture description plays in the paper.
"""
from __future__ import annotations

import dataclasses
import itertools

DATAFLOWS = ("RS", "OS", "WS")
PE_SCALES = (1, 2, 3, 4)          # PE array dim = 64 * 2**(scale-1): 64..512
GLB_SCALES = (1, 4, 9, 16)        # GLB bytes = 512 KiB * scale
BONDINGS = ("2D", "2.5D")

CLOCK_HZ = 1e9                    # 1 GHz (Table 4)
BASE_PE_DIM = 64
BASE_GLB_BYTES = 512 * 1024

# Area model (mm^2, 14 nm): a 64x64 MAC array w/ register files ~= 3 mm^2,
# scaling ~quadratically with array dim; SRAM ~= 1 MiB / mm^2.
PE_AREA_BASE_MM2 = 3.0
SRAM_MM2_PER_MIB = 1.0
PERIPHERY_MM2 = 1.5               # NoC, controller, PHY beachfront

# Power model. Leakage density chosen so static power lands near the
# paper's "up to 30% of total power" observation (§4.3.1, [20]).
LEAKAGE_W_PER_MM2 = 0.025

# Energy per 16-bit MAC (J) before dataflow adjustment; 14 nm class.
E_MAC_BASE = 0.4e-12
# GLB SRAM access energy per byte.
E_SRAM_BYTE = 0.8e-12
# Inter-chiplet link energy (Simba [51], Table 4): 1.3 pJ/bit.
E_INTERCHIP_BIT = 1.3e-12
# Inter-chiplet bandwidth per link (2D organic vs 2.5D interposer).
INTERCHIP_GBPS = {"2D": 64e9, "2.5D": 512e9}   # bytes/s

# Dataflow -> operator-kind compute utilization (fraction of peak MACs).
# This is the Timeloop mapping-quality stand-in: each dataflow favors the
# reuse pattern it keeps stationary (Insight 4).
UTILIZATION = {
    ("WS", "gemm"): 0.90, ("WS", "conv"): 0.72, ("WS", "dwconv"): 0.28,
    ("WS", "attention"): 0.45, ("WS", "elementwise"): 0.04,
    ("WS", "norm"): 0.04, ("WS", "scan"): 0.08, ("WS", "embed"): 0.30,
    ("OS", "gemm"): 0.80, ("OS", "conv"): 0.70, ("OS", "dwconv"): 0.38,
    ("OS", "attention"): 0.85, ("OS", "elementwise"): 0.10,
    ("OS", "norm"): 0.10, ("OS", "scan"): 0.15, ("OS", "embed"): 0.30,
    ("RS", "gemm"): 0.70, ("RS", "conv"): 0.90, ("RS", "dwconv"): 0.55,
    ("RS", "attention"): 0.60, ("RS", "elementwise"): 0.08,
    ("RS", "norm"): 0.08, ("RS", "scan"): 0.12, ("RS", "embed"): 0.30,
}

# Dataflow -> operator-kind SRAM traffic multiplier (x operand bytes); a
# well-matched dataflow re-reads operands from GLB fewer times.
SRAM_TRAFFIC = {
    ("WS", "gemm"): 1.5, ("WS", "conv"): 2.5, ("WS", "dwconv"): 3.0,
    ("WS", "attention"): 3.5, ("WS", "elementwise"): 1.0,
    ("WS", "norm"): 1.0, ("WS", "scan"): 2.0, ("WS", "embed"): 1.0,
    ("OS", "gemm"): 2.0, ("OS", "conv"): 2.5, ("OS", "dwconv"): 2.2,
    ("OS", "attention"): 1.6, ("OS", "elementwise"): 1.0,
    ("OS", "norm"): 1.0, ("OS", "scan"): 1.5, ("OS", "embed"): 1.0,
    ("RS", "gemm"): 2.2, ("RS", "conv"): 1.5, ("RS", "dwconv"): 1.6,
    ("RS", "attention"): 2.5, ("RS", "elementwise"): 1.0,
    ("RS", "norm"): 1.0, ("RS", "scan"): 1.8, ("RS", "embed"): 1.0,
}


@dataclasses.dataclass(frozen=True, order=True)
class Chiplet:
    dataflow: str = "WS"
    pe_scale: int = 1
    glb_scale: int = 1
    bonding: str = "2.5D"

    def __post_init__(self):
        assert self.dataflow in DATAFLOWS
        assert self.pe_scale in PE_SCALES
        assert self.glb_scale in GLB_SCALES
        assert self.bonding in BONDINGS

    def __hash__(self):
        # Chiplets key every evaluation-engine cache; memoize the hash
        # (frozen -> fields never change).
        h = self.__dict__.get("_hash")
        if h is None:
            h = hash((self.dataflow, self.pe_scale, self.glb_scale,
                      self.bonding))
            object.__setattr__(self, "_hash", h)
        return h

    @property
    def pe_dim(self) -> int:
        return BASE_PE_DIM * 2 ** (self.pe_scale - 1)

    @property
    def n_pes(self) -> int:
        return self.pe_dim * self.pe_dim

    @property
    def peak_flops(self) -> float:
        return 2.0 * self.n_pes * CLOCK_HZ        # MAC = 2 FLOPs

    @property
    def glb_bytes(self) -> int:
        return BASE_GLB_BYTES * self.glb_scale

    @property
    def area_mm2(self) -> float:
        pe = PE_AREA_BASE_MM2 * (self.pe_dim / BASE_PE_DIM) ** 2
        glb = SRAM_MM2_PER_MIB * self.glb_bytes / (1 << 20)
        return pe + glb + PERIPHERY_MM2

    @property
    def static_power_w(self) -> float:
        return LEAKAGE_W_PER_MM2 * self.area_mm2

    @property
    def interchip_bw(self) -> float:
        return INTERCHIP_GBPS[self.bonding]

    def utilization(self, kind: str) -> float:
        return UTILIZATION[(self.dataflow, kind)]

    def sram_traffic_factor(self, kind: str) -> float:
        return SRAM_TRAFFIC[(self.dataflow, kind)]

    @property
    def label(self) -> str:
        return (f"{self.dataflow}-pe{self.pe_dim}"
                f"-glb{self.glb_bytes // 1024}K-{self.bonding}")

    def to_dict(self) -> dict:
        return {"dataflow": self.dataflow, "pe_scale": self.pe_scale,
                "glb_scale": self.glb_scale, "bonding": self.bonding}

    @staticmethod
    def from_dict(d: dict) -> "Chiplet":
        return Chiplet(dataflow=d["dataflow"], pe_scale=d["pe_scale"],
                       glb_scale=d["glb_scale"], bonding=d["bonding"])


def full_design_space() -> list[Chiplet]:
    """All 96 chiplet configurations (3 dataflows x 4 PE x 4 GLB x 2 bond)."""
    return [Chiplet(d, p, g, b)
            for d, p, g, b in itertools.product(DATAFLOWS, PE_SCALES,
                                                GLB_SCALES, BONDINGS)]


def default_pool() -> list[Chiplet]:
    """A reasonable 8-chiplet starting pool covering the operator classes
    (Mozart's SA search refines from here)."""
    return [
        Chiplet("WS", 4, 9, "2.5D"),    # big-batch GEMM (prefill projections)
        Chiplet("WS", 2, 4, "2.5D"),    # mid GEMM
        Chiplet("OS", 3, 4, "2.5D"),    # attention / reductions
        Chiplet("OS", 1, 1, "2D"),      # small attention / decode
        Chiplet("RS", 3, 9, "2.5D"),    # large conv
        Chiplet("RS", 1, 4, "2D"),      # depthwise / small conv
        Chiplet("WS", 1, 1, "2D"),      # GEMV / decode projections
        Chiplet("OS", 2, 16, "2.5D"),   # fused groups needing big GLB
    ]
