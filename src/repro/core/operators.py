"""Operator-level IR — the granularity at which Mozart reasons.

The paper's central claim (Section 2) is that memory demand, batching
benefit and utilization are properties of *individual operators*.  This
module defines that operator IR and the extractors that lower neural
networks (transformer LMs, CNNs, ViTs) into it.

Units: FLOPs are floating-point operations (1 MAC = 2 FLOPs), bytes are
bytes, all quantities are *per sample* (batch = 1); the performance model
scales them by batch size according to each operator's batching class.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence

# Operator kinds understood by the performance model.
KINDS = ("gemm", "conv", "dwconv", "attention", "elementwise", "norm",
         "scan", "embed")

# Batching classes (Insight 2).
BATCH_SENSITIVE = "sensitive"   # weights reused across samples (projections)
BATCH_AGNOSTIC = "agnostic"     # no cross-sample reuse (attention, scans)


@dataclasses.dataclass(frozen=True)
class Operator:
    """A single computational operator, batch=1 granularity."""
    name: str
    kind: str
    flops: float                 # FLOPs per sample
    weight_bytes: float          # parameter bytes (shared across batch)
    act_in_bytes: float          # activation input bytes per sample
    act_out_bytes: float         # activation output bytes per sample
    parallel_work: float         # independent output lanes (PE utilization)
    batch_scaling: str = BATCH_SENSITIVE
    # For MoE expert GEMMs only `1/weight_reuse_divisor` of the resident
    # weights is touched per token on average (top_k / n_experts).
    weight_reuse_divisor: float = 1.0

    def __post_init__(self):
        assert self.kind in KINDS, self.kind
        assert self.batch_scaling in (BATCH_SENSITIVE, BATCH_AGNOSTIC)

    def __hash__(self):
        # Operator tuples key the per-group option caches; memoize the
        # hash (frozen -> fields never change).
        h = self.__dict__.get("_hash")
        if h is None:
            h = hash((self.name, self.kind, self.flops, self.weight_bytes,
                      self.act_in_bytes, self.act_out_bytes,
                      self.parallel_work, self.batch_scaling,
                      self.weight_reuse_divisor))
            object.__setattr__(self, "_hash", h)
        return h

    def arithmetic_intensity(self, batch: int = 1) -> float:
        """FLOPs per DRAM byte at a given batch size (first-order)."""
        f = self.flops * batch
        b = self.dram_bytes(batch)
        return f / max(b, 1.0)

    def to_dict(self) -> dict:
        return {"name": self.name, "kind": self.kind, "flops": self.flops,
                "weight_bytes": self.weight_bytes,
                "act_in_bytes": self.act_in_bytes,
                "act_out_bytes": self.act_out_bytes,
                "parallel_work": self.parallel_work,
                "batch_scaling": self.batch_scaling,
                "weight_reuse_divisor": self.weight_reuse_divisor}

    @staticmethod
    def from_dict(d: dict) -> "Operator":
        return Operator(**{k: d[k] for k in (
            "name", "kind", "flops", "weight_bytes", "act_in_bytes",
            "act_out_bytes", "parallel_work", "batch_scaling",
            "weight_reuse_divisor")})

    def dram_bytes(self, batch: int = 1) -> float:
        """Bytes that must cross DRAM for one execution at `batch`."""
        w = self.weight_bytes / self.weight_reuse_divisor \
            if self.batch_scaling == BATCH_SENSITIVE else \
            self.weight_bytes * batch / self.weight_reuse_divisor
        # MoE: at batch B, the fraction of experts touched grows; model the
        # touched weights as min(resident, per-token-touched * tokens).
        if self.weight_reuse_divisor > 1.0:
            w = min(self.weight_bytes,
                    (self.weight_bytes / self.weight_reuse_divisor) * batch)
        return w + (self.act_in_bytes + self.act_out_bytes) * batch


@dataclasses.dataclass(frozen=True)
class OperatorGraph:
    """A network (or representative region) lowered to a linear operator
    pipeline.  `repeat` compresses identical repeated segments (layers)."""
    network: str
    phase: str                   # "prefill" | "decode" | "vision"
    operators: tuple[Operator, ...]
    repeats: tuple[int, ...]     # same length as operators

    def __post_init__(self):
        assert len(self.operators) == len(self.repeats)

    @property
    def total_flops(self) -> float:
        return sum(o.flops * r for o, r in zip(self.operators, self.repeats))

    @property
    def total_weight_bytes(self) -> float:
        return sum(o.weight_bytes * r
                   for o, r in zip(self.operators, self.repeats))

    def to_dict(self) -> dict:
        return {"network": self.network, "phase": self.phase,
                "operators": [o.to_dict() for o in self.operators],
                "repeats": list(self.repeats)}

    @staticmethod
    def from_dict(d: dict) -> "OperatorGraph":
        return OperatorGraph(
            network=d["network"], phase=d["phase"],
            operators=tuple(Operator.from_dict(o) for o in d["operators"]),
            repeats=tuple(d["repeats"]))

    def expand(self, max_ops: int | None = None) -> list[Operator]:
        out: list[Operator] = []
        for o, r in zip(self.operators, self.repeats):
            for i in range(r):
                out.append(dataclasses.replace(o, name=f"{o.name}#{i}")
                           if r > 1 else o)
        if max_ops is not None and len(out) > max_ops:
            out = out[:max_ops]
        return out


# ---------------------------------------------------------------------------
# Transformer LM extraction
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LMSpec:
    """Architecture description sufficient for operator extraction.
    Mirrors repro.configs model configs (kept separate so core/ has no JAX
    dependency)."""
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    swiglu: bool = True
    window: int | None = None          # sliding-window attention
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    # MLA (DeepSeek): latent-compressed KV
    mla_kv_rank: int = 0
    mla_q_rank: int = 0
    mla_rope_dim: int = 64
    dtype_bytes: int = 2

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads


def _op(name, kind, flops, w, ain, aout, par, scaling=BATCH_SENSITIVE,
        reuse_div=1.0) -> Operator:
    return Operator(name=name, kind=kind, flops=float(flops),
                    weight_bytes=float(w), act_in_bytes=float(ain),
                    act_out_bytes=float(aout), parallel_work=float(par),
                    batch_scaling=scaling, weight_reuse_divisor=reuse_div)


def lm_layer_operators(spec: LMSpec, seq: int, cache_len: int,
                       phase: str) -> list[Operator]:
    """Operators for ONE transformer layer.

    prefill: seq tokens attend causally over themselves (+window cap).
    decode:  seq == 1 new token attends over cache_len cached tokens.
    """
    d, B = spec.d_model, spec.dtype_bytes
    hd = spec.hd
    q_dim = spec.n_heads * hd
    kv_dim = spec.kv_heads * hd
    S = seq
    ops: list[Operator] = []

    act = S * d * B
    ops.append(_op("norm1", "norm", 5 * S * d, d * B, act, act, S * d))

    if spec.mla_kv_rank:  # DeepSeek MLA
        r_kv, r_q, r_rope = spec.mla_kv_rank, spec.mla_q_rank, spec.mla_rope_dim
        # q down+up, kv down, k/v up projections
        w_q = (d * r_q + r_q * spec.n_heads * (hd + r_rope)) * B
        w_kv = (d * (r_kv + r_rope) + r_kv * spec.n_heads * (hd + hd)) * B
        f_q = 2 * S * (d * r_q + r_q * spec.n_heads * (hd + r_rope))
        f_kv = 2 * S * (d * (r_kv + r_rope) + r_kv * spec.n_heads * 2 * hd)
        ops.append(_op("mla_proj", "gemm", f_q + f_kv, w_q + w_kv,
                       act, S * spec.n_heads * (hd + r_rope) * B * 3,
                       S * spec.n_heads * hd))
        kv_token_bytes = (r_kv + r_rope) * B          # latent cache per token
    else:
        w_qkv = d * (q_dim + 2 * kv_dim) * B
        f_qkv = 2 * S * d * (q_dim + 2 * kv_dim)
        ops.append(_op("qkv_proj", "gemm", f_qkv, w_qkv, act,
                       S * (q_dim + 2 * kv_dim) * B, S * (q_dim + 2 * kv_dim)))
        kv_token_bytes = 2 * kv_dim * B

    # Attention core — batch-AGNOSTIC: zero weights, per-sample KV.
    ctx = cache_len if phase == "decode" else S
    if spec.window:
        ctx = min(ctx, spec.window)
    causal_frac = 0.5 if (phase != "decode" and spec.window is None) else 1.0
    f_attn = 2 * 2 * S * ctx * q_dim * causal_frac      # QK^T + PV
    kv_bytes = ctx * kv_token_bytes                      # cache/keys read
    ops.append(_op("attention", "attention", f_attn, 0.0,
                   S * q_dim * B + kv_bytes, S * q_dim * B,
                   S * spec.n_heads * ctx * causal_frac,
                   scaling=BATCH_AGNOSTIC))

    ops.append(_op("o_proj", "gemm", 2 * S * q_dim * d, q_dim * d * B,
                   S * q_dim * B, act, S * d))
    ops.append(_op("norm2", "norm", 5 * S * d, d * B, act, act, S * d))

    mlp_mults = 3 if spec.swiglu else 2
    if spec.n_experts:
        ops.append(_op("router", "gemm", 2 * S * d * spec.n_experts,
                       d * spec.n_experts * B, act,
                       S * spec.n_experts * B, S * spec.n_experts))
        if spec.n_shared_experts:
            sw = mlp_mults * d * spec.d_ff * spec.n_shared_experts * B
            ops.append(_op("shared_expert", "gemm",
                           2 * mlp_mults * S * d * spec.d_ff
                           * spec.n_shared_experts,
                           sw, act, act, S * spec.d_ff))
        ew = mlp_mults * d * spec.d_ff * spec.n_experts * B
        ops.append(_op("routed_experts", "gemm",
                       2 * mlp_mults * S * d * spec.d_ff * spec.top_k,
                       ew, act * spec.top_k, act, S * spec.d_ff * spec.top_k,
                       reuse_div=spec.n_experts / spec.top_k))
    else:
        ops.append(_op("mlp", "gemm", 2 * mlp_mults * S * d * spec.d_ff,
                       mlp_mults * d * spec.d_ff * B, act, act, S * spec.d_ff))
        ops.append(_op("mlp_act", "elementwise", 4 * S * spec.d_ff,
                       0.0, S * spec.d_ff * B, S * spec.d_ff * B,
                       S * spec.d_ff, scaling=BATCH_AGNOSTIC))
    return ops


def lm_operator_graph(spec: LMSpec, seq: int, phase: str = "prefill",
                      cache_len: int | None = None) -> OperatorGraph:
    """Lower a transformer LM to an operator pipeline.

    phase="prefill": process `seq` tokens.
    phase="decode":  process 1 token against `cache_len` cached tokens.
    """
    if phase == "decode":
        S, C = 1, (cache_len if cache_len is not None else seq)
    else:
        S, C = seq, 0
    d, B = spec.d_model, spec.dtype_bytes
    ops: list[Operator] = []
    repeats: list[int] = []

    # embedding lookup touches only the S gathered rows, not the table
    # (the full-table capacity requirement is handled by memory sizing).
    ops.append(_op("embed", "embed", 2 * S * d, S * d * B,
                   S * 4, S * d * B, S * d))
    repeats.append(1)

    layer = lm_layer_operators(spec, S, C, phase)
    for o in layer:
        ops.append(o)
        repeats.append(spec.n_layers)

    ops.append(_op("final_norm", "norm", 5 * S * d, d * B,
                   S * d * B, S * d * B, S * d))
    repeats.append(1)
    ops.append(_op("lm_head", "gemm", 2 * S * d * spec.vocab,
                   d * spec.vocab * B, S * d * B, S * spec.vocab * B,
                   S * spec.vocab))
    repeats.append(1)
    return OperatorGraph(network=f"{spec.name}_{phase}", phase=phase,
                         operators=tuple(ops), repeats=tuple(repeats))


# ---------------------------------------------------------------------------
# CNN / ViT extraction (paper workload suite: ResNet50, MobileNetV3,
# EfficientNet, RepLKNet-31B, ViT).  Representative regions, as in paper §5.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvLayer:
    name: str
    cin: int
    cout: int
    k: int
    stride: int
    h: int          # input spatial
    w: int
    repeat: int = 1
    depthwise: bool = False


def conv_ops(layer: ConvLayer, dtype_bytes: int = 2) -> Operator:
    ho, wo = layer.h // layer.stride, layer.w // layer.stride
    if layer.depthwise:
        flops = 2 * ho * wo * layer.cout * layer.k * layer.k
        w = layer.cout * layer.k * layer.k * dtype_bytes
        kind = "dwconv"
    else:
        flops = 2 * ho * wo * layer.cout * layer.cin * layer.k * layer.k
        w = layer.cout * layer.cin * layer.k * layer.k * dtype_bytes
        kind = "conv"
    ain = layer.h * layer.w * layer.cin * dtype_bytes
    aout = ho * wo * layer.cout * dtype_bytes
    return _op(layer.name, kind, flops, w, ain, aout, ho * wo * layer.cout)


def cnn_operator_graph(name: str, layers: Sequence[ConvLayer],
                       head_dim: tuple[int, int] | None = None,
                       dtype_bytes: int = 2) -> OperatorGraph:
    ops = [conv_ops(l, dtype_bytes) for l in layers]
    repeats = [l.repeat for l in layers]
    if head_dim is not None:
        cin, nclass = head_dim
        ops.append(_op("fc_head", "gemm", 2 * cin * nclass,
                       cin * nclass * dtype_bytes, cin * dtype_bytes,
                       nclass * dtype_bytes, nclass))
        repeats.append(1)
    return OperatorGraph(network=name, phase="vision",
                         operators=tuple(ops), repeats=tuple(repeats))


def resnet50_graph() -> OperatorGraph:
    L = ConvLayer
    layers = [
        L("stem", 3, 64, 7, 2, 224, 224),
        # bottleneck stages (1x1 reduce, 3x3, 1x1 expand) per block
        L("s1_1x1a", 64, 64, 1, 1, 56, 56, repeat=3),
        L("s1_3x3", 64, 64, 3, 1, 56, 56, repeat=3),
        L("s1_1x1b", 64, 256, 1, 1, 56, 56, repeat=3),
        L("s2_1x1a", 256, 128, 1, 1, 28, 28, repeat=4),
        L("s2_3x3", 128, 128, 3, 1, 28, 28, repeat=4),
        L("s2_1x1b", 128, 512, 1, 1, 28, 28, repeat=4),
        L("s3_1x1a", 512, 256, 1, 1, 14, 14, repeat=6),
        L("s3_3x3", 256, 256, 3, 1, 14, 14, repeat=6),
        L("s3_1x1b", 256, 1024, 1, 1, 14, 14, repeat=6),
        L("s4_1x1a", 1024, 512, 1, 1, 7, 7, repeat=3),
        L("s4_3x3", 512, 512, 3, 1, 7, 7, repeat=3),
        L("s4_1x1b", 512, 2048, 1, 1, 7, 7, repeat=3),
    ]
    return cnn_operator_graph("resnet50", layers, head_dim=(2048, 1000))


def mobilenetv3_graph() -> OperatorGraph:
    L = ConvLayer
    layers = [
        L("stem", 3, 16, 3, 2, 224, 224),
        L("b1_dw", 16, 16, 3, 1, 112, 112, depthwise=True),
        L("b1_pw", 16, 16, 1, 1, 112, 112),
        L("b2_exp", 16, 64, 1, 1, 112, 112),
        L("b2_dw", 64, 64, 3, 2, 112, 112, depthwise=True),
        L("b2_pw", 64, 24, 1, 1, 56, 56),
        L("b3_exp", 24, 120, 1, 1, 56, 56, repeat=3),
        L("b3_dw", 120, 120, 5, 1, 56, 56, repeat=3, depthwise=True),
        L("b3_pw", 120, 40, 1, 1, 56, 56, repeat=3),
        L("b4_exp", 40, 240, 1, 1, 28, 28, repeat=4),
        L("b4_dw", 240, 240, 3, 2, 28, 28, repeat=4, depthwise=True),
        L("b4_pw", 240, 80, 1, 1, 14, 14, repeat=4),
        L("b5_exp", 112, 672, 1, 1, 14, 14, repeat=3),
        L("b5_dw", 672, 672, 5, 1, 14, 14, repeat=3, depthwise=True),
        L("b5_pw", 672, 160, 1, 1, 14, 14, repeat=3),
        L("head", 160, 960, 1, 1, 7, 7),
    ]
    return cnn_operator_graph("mobilenetv3", layers, head_dim=(960, 1000))


def efficientnet_graph() -> OperatorGraph:
    L = ConvLayer
    layers = [
        L("stem", 3, 32, 3, 2, 224, 224),
        L("mb1_dw", 32, 32, 3, 1, 112, 112, depthwise=True),
        L("mb1_pw", 32, 16, 1, 1, 112, 112),
        L("mb2_exp", 16, 96, 1, 1, 112, 112, repeat=2),
        L("mb2_dw", 96, 96, 3, 2, 112, 112, repeat=2, depthwise=True),
        L("mb2_pw", 96, 24, 1, 1, 56, 56, repeat=2),
        L("mb3_exp", 24, 144, 1, 1, 56, 56, repeat=2),
        L("mb3_dw", 144, 144, 5, 2, 56, 56, repeat=2, depthwise=True),
        L("mb3_pw", 144, 40, 1, 1, 28, 28, repeat=2),
        L("mb4_exp", 40, 240, 1, 1, 28, 28, repeat=3),
        L("mb4_dw", 240, 240, 3, 2, 28, 28, repeat=3, depthwise=True),
        L("mb4_pw", 240, 80, 1, 1, 14, 14, repeat=3),
        L("mb6_exp", 112, 672, 1, 1, 14, 14, repeat=4),
        L("mb6_dw", 672, 672, 5, 2, 14, 14, repeat=4, depthwise=True),
        L("mb6_pw", 672, 192, 1, 1, 7, 7, repeat=4),
        L("head", 320, 1280, 1, 1, 7, 7),
    ]
    return cnn_operator_graph("efficientnet", layers, head_dim=(1280, 1000))


def replknet_graph() -> OperatorGraph:
    """RepLKNet-31B: the paper's large-kernel outlier — 31x31 depthwise
    convolutions interleaved with 1x1s (paper §1, §6.1)."""
    L = ConvLayer
    layers = [
        L("stem", 3, 128, 3, 2, 224, 224),
        L("s1_pw1", 128, 128, 1, 1, 56, 56, repeat=2),
        L("s1_lk31", 128, 128, 31, 1, 56, 56, repeat=2, depthwise=True),
        L("s1_pw2", 128, 512, 1, 1, 56, 56, repeat=2),
        L("s1_pw3", 512, 128, 1, 1, 56, 56, repeat=2),
        L("s2_pw1", 256, 256, 1, 1, 28, 28, repeat=2),
        L("s2_lk31", 256, 256, 31, 1, 28, 28, repeat=2, depthwise=True),
        L("s2_pw2", 256, 1024, 1, 1, 28, 28, repeat=2),
        L("s2_pw3", 1024, 256, 1, 1, 28, 28, repeat=2),
        L("s3_pw1", 512, 512, 1, 1, 14, 14, repeat=18),
        L("s3_lk31", 512, 512, 31, 1, 14, 14, repeat=18, depthwise=True),
        L("s3_pw2", 512, 2048, 1, 1, 14, 14, repeat=18),
        L("s3_pw3", 2048, 512, 1, 1, 14, 14, repeat=18),
        L("s4_pw1", 1024, 1024, 1, 1, 7, 7, repeat=2),
        L("s4_lk13", 1024, 1024, 13, 1, 7, 7, repeat=2, depthwise=True),
        L("s4_pw2", 1024, 4096, 1, 1, 7, 7, repeat=2),
        L("s4_pw3", 4096, 1024, 1, 1, 7, 7, repeat=2),
    ]
    return cnn_operator_graph("replknet31b", layers, head_dim=(1024, 1000))


def vit_graph(name: str = "vit_b16", d: int = 768, n_layers: int = 12,
              n_heads: int = 12, d_ff: int = 3072,
              n_tokens: int = 197) -> OperatorGraph:
    spec = LMSpec(name=name, n_layers=n_layers, d_model=d, n_heads=n_heads,
                  kv_heads=n_heads, d_ff=d_ff, vocab=1000, swiglu=False)
    g = lm_operator_graph(spec, seq=n_tokens, phase="prefill")
    return dataclasses.replace(g, network=name, phase="vision")


# Paper LLM workloads --------------------------------------------------------

OPT_66B = LMSpec(name="opt66b", n_layers=64, d_model=9216, n_heads=72,
                 kv_heads=72, d_ff=36864, vocab=50272, swiglu=False)
OPT_1_3B = LMSpec(name="opt1.3b", n_layers=24, d_model=2048, n_heads=32,
                  kv_heads=32, d_ff=8192, vocab=50272, swiglu=False)


def paper_workloads(seq: int = 2048) -> dict[str, OperatorGraph]:
    """The paper's evaluation suite (§5), as operator graphs."""
    return {
        "resnet50": resnet50_graph(),
        "mobilenetv3": mobilenetv3_graph(),
        "efficientnet": efficientnet_graph(),
        "replknet31b": replknet_graph(),
        "vit_b16": vit_graph(),
        "opt66b_prefill": lm_operator_graph(OPT_66B, seq, "prefill"),
        "opt66b_decode": lm_operator_graph(OPT_66B, seq, "decode",
                                           cache_len=seq),
    }
