"""Layer 2: evolutionary search over tensor-fusion grouping + memory
allocation (paper §4.2).

Genome, over the *compressed* operator pipeline (repeated layers share a
template — the paper's "representative regions"):

  * boundaries[i] in {0,1}  — cut between op i and i+1 (1 = stage break);
    cuts are forced where adjacent ops have different repeat counts.
  * mem_gene[i] in MEMORY_POOL — the memory type of the group whose first
    op is i (genes of non-leading ops are silent but inherited by
    crossover, preserving high-quality fusion groups, §4.2).

Fitness is the Layer-3 iso-latency/convex-hull solve (convexhull.py) on
the fusion's stage options.  The initial population is roofline-seeded
(Insight 1: memory-bound groups get fast memory, compute-bound groups get
cheap memory) and encodes Alwani-style early-layer fusion patterns.
"""

from __future__ import annotations

import dataclasses
import functools
import math
import random
from typing import Iterable, Sequence

import numpy as np

from . import costmodel
from .chiplets import Chiplet
from .convexhull import (
    PipelineJob,
    PipelineSolution,
    clear_grid_cache,
    default_latency_grid,
    solve_pipeline,
    solve_pipeline_batch,
)
from .memory import DDR5, HBM3, MEMORY_POOL, MemoryType
from .operators import Operator, OperatorGraph
from .engine import engine_enabled
from .perfmodel import (
    BATCH_OPTIONS,
    StageOption,
    StageOptionColumns,
    StageOptionSet,
    config_grid,
    enumerate_stage_columns_by_chiplet,
    enumerate_stage_options,
    is_memory_bound,
    scale_option,
)


@dataclasses.dataclass(frozen=True)
class Requirement:
    """Latency requirements (paper Table 5). Seconds; None = unconstrained.
    ttft/tpot/e2e all constrain the end-to-end pipeline traversal P*T."""

    ttft: float | None = None
    tpot: float | None = None
    e2e: float | None = None

    @property
    def max_e2e(self) -> float | None:
        vals = [v for v in (self.ttft, self.tpot, self.e2e) if v is not None]
        return min(vals) if vals else None

    def to_dict(self) -> dict:
        return {"ttft": self.ttft, "tpot": self.tpot, "e2e": self.e2e}

    @staticmethod
    def from_dict(d: dict) -> "Requirement":
        return Requirement(ttft=d.get("ttft"), tpot=d.get("tpot"), e2e=d.get("e2e"))


@dataclasses.dataclass(frozen=True)
class Genome:
    boundaries: tuple[int, ...]  # len N-1
    mem_genes: tuple[int, ...]  # len N, index into MEMORY_POOL

    def to_dict(self) -> dict:
        return {"boundaries": list(self.boundaries), "mem_genes": list(self.mem_genes)}

    @staticmethod
    def from_dict(d: dict) -> "Genome":
        return Genome(boundaries=tuple(d["boundaries"]), mem_genes=tuple(d["mem_genes"]))


@dataclasses.dataclass(frozen=True)
class FusionGroup:
    ops: tuple[Operator, ...]
    repeat: int
    memory: MemoryType
    name: str

    def to_dict(self) -> dict:
        return {
            "ops": [o.to_dict() for o in self.ops],
            "repeat": self.repeat,
            "memory": self.memory.to_dict(),
            "name": self.name,
        }

    @staticmethod
    def from_dict(d: dict) -> "FusionGroup":
        return FusionGroup(
            ops=tuple(Operator.from_dict(o) for o in d["ops"]),
            repeat=d["repeat"],
            memory=MemoryType.from_dict(d["memory"]),
            name=d["name"],
        )


@dataclasses.dataclass
class FusionResult:
    genome: Genome
    groups: list[FusionGroup]
    solution: PipelineSolution
    value: float

    def to_dict(self) -> dict:
        return {
            "genome": self.genome.to_dict(),
            "groups": [g.to_dict() for g in self.groups],
            "solution": self.solution.to_dict(),
            "value": self.value,
        }

    @staticmethod
    def from_dict(d: dict) -> "FusionResult":
        return FusionResult(
            genome=Genome.from_dict(d["genome"]),
            groups=[FusionGroup.from_dict(g) for g in d["groups"]],
            solution=PipelineSolution.from_dict(d["solution"]),
            value=d["value"],
        )


@dataclasses.dataclass
class GAConfig:
    population: int = 10  # paper Table 4
    # Paper Table 4 uses 10 generations; the fixed-seed sweep in
    # benchmarks/bench_budget_scaling.py still finds improvement between
    # 16 and 24 generations (elitism makes the axis monotone), so the
    # default budget is 24 (~0.6 s vs 0.3 s on the dev container).
    generations: int = 24
    mutation_rate: float = 0.2
    crossover_rate: float = 0.8
    seed: int = 0
    latency_points: int = 48
    fixed_batch: int | None = None
    batches: tuple[int, ...] = BATCH_OPTIONS

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["batches"] = list(self.batches)
        return d

    @staticmethod
    def from_dict(d: dict) -> "GAConfig":
        d = dict(d)
        d["batches"] = tuple(d.get("batches", BATCH_OPTIONS))
        return GAConfig(**d)


def forced_boundaries(graph: OperatorGraph) -> tuple[int, ...]:
    """Cuts that every genome must contain (repeat-count changes)."""
    r = graph.repeats
    return tuple(1 if r[i] != r[i + 1] else 0 for i in range(len(r) - 1))


def groups_from_genome(graph: OperatorGraph, g: Genome) -> list[FusionGroup]:
    ops, reps = graph.operators, graph.repeats
    forced = forced_boundaries(graph)
    groups: list[FusionGroup] = []
    start = 0
    for i in range(len(ops)):
        last = i == len(ops) - 1
        cut = last or g.boundaries[i] or forced[i]
        if cut:
            seg = ops[start : i + 1]
            mem = MEMORY_POOL[g.mem_genes[start] % len(MEMORY_POOL)]
            groups.append(
                FusionGroup(
                    ops=tuple(seg),
                    repeat=reps[start],
                    memory=mem,
                    name="+".join(o.name for o in seg),
                )
            )
            start = i + 1
    return groups


# Per-(fusion group, SKU) option cache.  A plain dict rather than an
# lru_cache so the population-batch prefetch below can probe and fill it
# wholesale (one vectorized evaluation covering every missing SKU), with
# the same entry bound the old lru_cache had (FIFO eviction — long-lived
# processes sweeping many networks/pools must not grow without bound).
# Values are StageOptionColumns blocks (column arrays + shared config
# tuple), the transport unit of the process-pool warmup below.
# Single-writer: filled from the GA loop of one process (workers hold
# their own shard); cross-process merges go through the warmup shipment.
_chiplet_option_cache: dict[tuple, StageOptionColumns] = {}
_CHIPLET_CACHE_MAX = 500_000

# Option-cache traffic counters.  `enumerated` counts (group, SKU)
# blocks actually evaluated in this process; `installed` counts blocks
# received pre-built through the warmup transport instead.  Workers
# report both to the parent engine (`EvaluationEngine.stats()`).
_warmup_stats = {"installed": 0, "enumerated": 0}


def warmup_stats() -> dict[str, int]:
    return dict(_warmup_stats)


def _chiplet_cache_put(key: tuple, val: StageOptionColumns) -> None:
    if len(_chiplet_option_cache) >= _CHIPLET_CACHE_MAX:
        _chiplet_option_cache.pop(next(iter(_chiplet_option_cache)))
    _chiplet_option_cache[key] = val


def _chiplet_cache_key(
    ops: tuple[Operator, ...],
    repeat: int,
    chiplet: Chiplet,
    memory: MemoryType,
    fixed_batch: int | None,
    batches: tuple[int, ...],
    name: str,
) -> tuple:
    return (ops, repeat, chiplet, memory, fixed_batch, batches, name)


def _chiplet_group_columns(
    ops: tuple[Operator, ...],
    repeat: int,
    chiplet: Chiplet,
    memory: MemoryType,
    fixed_batch: int | None,
    batches: tuple[int, ...],
    name: str,
) -> StageOptionColumns:
    """Option columns for one fusion group on ONE chiplet SKU.  Keyed per
    SKU so a single-SKU pool mutation (the SA neighbor move)
    re-enumerates only the new SKU's options; the other pool members
    come from cache."""
    key = _chiplet_cache_key(ops, repeat, chiplet, memory, fixed_batch, batches, name)
    got = _chiplet_option_cache.get(key)
    if got is None:
        _warmup_stats["enumerated"] += 1
        got = enumerate_stage_columns_by_chiplet(
            ops,
            (chiplet,),
            memories=(memory,),
            batches=batches,
            name=name,
            fixed_batch=fixed_batch,
            cost_fn=costmodel.stage_hw_cost,
            repeat=repeat,
        )[chiplet]
        _chiplet_cache_put(key, got)
    return got


def prefetch_population_options(
    graph: OperatorGraph, genomes: Sequence[Genome], pool: Sequence[Chiplet], cfg: GAConfig
) -> None:
    """Population-batched option enumeration (the Layer-2 vectorization).

    Decodes every genome of a GA population, collects the distinct fusion
    groups they induce, and fills the per-(group, SKU) option cache with
    ONE batched-columns evaluation per distinct group covering all its
    missing SKUs — instead of one scalar enumeration per
    (genome, group, SKU).  Results are bit-identical to the per-SKU path
    (the batched model is row-wise element-wise), so GA fitness values
    are unchanged; only the evaluation shape changes.
    """
    if not engine_enabled():
        return
    _prefetch_group_options(
        (gr for g in genomes for gr in groups_from_genome(graph, g)), pool, cfg
    )


def _prefetch_group_options(
    groups: "Iterable[FusionGroup]", pool: Sequence[Chiplet], cfg: GAConfig
) -> None:
    """Group-level core of the population prefetch: one batched-columns
    evaluation per distinct group covering all its missing SKUs."""
    batches = tuple(cfg.batches)
    # dict keeps insertion order and dedupes caller-supplied dup SKUs
    skus = tuple(dict.fromkeys(pool))
    seen: set[tuple] = set()
    for gr in groups:
        gkey = (gr.ops, gr.repeat, gr.memory, gr.name)
        if gkey in seen:
            continue
        seen.add(gkey)
        missing = [
            c
            for c in skus
            if _chiplet_cache_key(
                gr.ops, gr.repeat, c, gr.memory, cfg.fixed_batch, batches, gr.name
            )
            not in _chiplet_option_cache
        ]
        if not missing:
            continue
        _warmup_stats["enumerated"] += len(missing)
        grouped = enumerate_stage_columns_by_chiplet(
            gr.ops,
            tuple(missing),
            memories=(gr.memory,),
            batches=batches,
            name=gr.name,
            fixed_batch=cfg.fixed_batch,
            cost_fn=costmodel.stage_hw_cost,
            repeat=gr.repeat,
        )
        for c, block in grouped.items():
            _chiplet_cache_put(
                _chiplet_cache_key(
                    gr.ops, gr.repeat, c, gr.memory, cfg.fixed_batch, batches, gr.name
                ),
                block,
            )


# --- shared option-cache transport (process-pool warmup) --------------------


def matching_option_keys(pool: Sequence[Chiplet], cfg: GAConfig) -> list[tuple]:
    """Cache keys shippable to a worker evaluating `pool` under `cfg`:
    the entry's SKU is in the pool and its batch axis matches the GA
    config (the group axis is deliberately unfiltered — any group a
    worker encounters again is worth having)."""
    skus = set(pool)
    batches = tuple(cfg.batches)
    return [
        k
        for k in _chiplet_option_cache
        if k[2] in skus and k[4] == cfg.fixed_batch and k[5] == batches
    ]


def export_option_columns(keys: Sequence[tuple]) -> tuple[list[dict], np.ndarray]:
    """Pack cached (group, SKU) blocks for transport: one flat float64
    matrix with rows (t_cmp, e_dyn, p_static, hw_cost) and a metadata
    list carrying each block's cache key and row span.  The matrix is
    what rides shared memory; everything config-shaped is rebuilt on the
    receiving side from the key (deterministic, bit-identical)."""
    meta: list[dict] = []
    parts: list[np.ndarray] = []
    off = 0
    for key in keys:
        block = _chiplet_option_cache.get(key)
        if block is None:
            continue
        n = len(block)
        meta.append({"key": key, "off": off, "n": n, "flops": block.flops_per_sample})
        if n:
            parts.append(
                np.stack([block.t_cmp, block.e_dyn, block.p_static, block.hw_cost_usd], axis=1)
            )
        off += n
    matrix = np.concatenate(parts, axis=0) if parts else np.empty((0, 4), dtype=np.float64)
    return meta, matrix


def import_option_columns(meta: Sequence[dict], matrix: np.ndarray) -> int:
    """Install transported blocks into this process's option cache,
    skipping keys already present.  Config tuples are rebuilt via the
    memoized `config_grid` (same enumeration the sender ran), so an
    installed block is bit-identical to enumerating locally — minus the
    roofline-model evaluation.  Returns the number of blocks installed.
    """
    installed = 0
    for e in meta:
        key = e["key"]
        if key in _chiplet_option_cache:
            continue
        ops, repeat, chiplet, memory, fixed_batch, batches, name = key
        grid = config_grid(
            ops, (chiplet,), memories=(memory,), batches=batches, fixed_batch=fixed_batch
        )
        if len(grid.cfgs) != e["n"]:  # sender/receiver model drift
            continue
        rows = matrix[e["off"] : e["off"] + e["n"]]
        _chiplet_cache_put(
            key,
            StageOptionColumns(
                t_cmp=np.ascontiguousarray(rows[:, 0]),
                e_dyn=np.ascontiguousarray(rows[:, 1]),
                p_static=np.ascontiguousarray(rows[:, 2]),
                hw_cost_usd=np.ascontiguousarray(rows[:, 3]),
                cfgs=grid.cfgs,
                group_name=name,
                flops_per_sample=e["flops"],
                repeat=repeat,
            ),
        )
        installed += 1
    _warmup_stats["installed"] += installed
    return installed


@functools.lru_cache(maxsize=200_000)
def _group_options_cached(
    ops: tuple[Operator, ...],
    repeat: int,
    pool: tuple[Chiplet, ...],
    memory: MemoryType,
    fixed_batch: int | None,
    batches: tuple[int, ...],
    name: str,
) -> StageOptionSet:
    if engine_enabled():
        out = StageOptionSet.from_blocks(
            _chiplet_group_columns(ops, repeat, c, memory, fixed_batch, batches, name) for c in pool
        )
        out.columns()  # build once, reused by every genome eval
        return out
    raw = enumerate_stage_options(
        ops,
        pool,
        memories=(memory,),
        batches=batches,
        name=name,
        fixed_batch=fixed_batch,
        vectorize=False,
    )
    priced = costmodel.price_stage_options(raw)
    return StageOptionSet(scale_option(o, repeat) for o in priced)


def clear_option_caches() -> None:
    _chiplet_option_cache.clear()
    _group_options_cached.cache_clear()
    clear_grid_cache()
    _warmup_stats["installed"] = 0
    _warmup_stats["enumerated"] = 0


def stage_options_for_groups(
    groups: Sequence[FusionGroup], pool: Sequence[Chiplet], cfg: GAConfig
) -> list[StageOptionSet]:
    return [
        _group_options_cached(
            g.ops, g.repeat, tuple(pool), g.memory, cfg.fixed_batch, tuple(cfg.batches), g.name
        )
        for g in groups
    ]


def evaluate_genome(
    graph: OperatorGraph,
    genome: Genome,
    pool: Sequence[Chiplet],
    objective: str,
    req: Requirement,
    cfg: GAConfig,
    _solution_cache: dict | None = None,
) -> FusionResult | None:
    groups = groups_from_genome(graph, genome)
    # Memory genes of non-leading ops are silent (§4.2): distinct genomes
    # can decode to identical fusion groups.  Collapse them onto one
    # Layer-3 solve via the caller-scoped solution cache.
    key = tuple(groups) if _solution_cache is not None else None
    if key is not None and key in _solution_cache:
        sol = _solution_cache[key]
        if sol is None:
            return None
        return FusionResult(genome=genome, groups=groups, solution=sol, value=sol.value)
    options = stage_options_for_groups(groups, pool, cfg)
    if any(not o for o in options):
        if key is not None:
            _solution_cache[key] = None
        return None
    grid = default_latency_grid(options, n=cfg.latency_points)
    n_stages = sum(g.repeat for g in groups)
    sol = solve_pipeline(options, grid, objective=objective, max_e2e=req.max_e2e, n_stages=n_stages)
    if key is not None:
        _solution_cache[key] = sol
    if sol is None:
        return None
    return FusionResult(genome=genome, groups=groups, solution=sol, value=sol.value)


def evaluate_genomes(
    graph: OperatorGraph,
    genomes: Sequence[Genome],
    pool: Sequence[Chiplet],
    objective: str,
    req: Requirement,
    cfg: GAConfig,
    _solution_cache: dict,
) -> dict[Genome, FusionResult | None]:
    """Generation-batched Layer-3: one `solve_pipeline_batch` call for a
    whole GA generation instead of a Python loop of per-genome
    `solve_pipeline` calls.

    Genomes are decoded, deduped onto distinct fusion plans (memory
    genes of non-leading ops are silent, §4.2), and every plan missing
    from the solution cache becomes one PipelineJob sharing the batched
    sweep.  Results — including tie-breaks — are bit-identical to
    calling `evaluate_genome` per genome, so the GA trajectory is
    unchanged; only the evaluation shape is.
    """
    decoded: list[tuple[Genome, list[FusionGroup], tuple]] = []
    for g in dict.fromkeys(genomes):
        groups = groups_from_genome(graph, g)
        decoded.append((g, groups, tuple(groups)))
    if engine_enabled():
        _prefetch_group_options((gr for _, groups, _ in decoded for gr in groups), pool, cfg)
    jobs: list[PipelineJob] = []
    job_keys: list[tuple] = []
    queued: set[tuple] = set()
    for g, groups, key in decoded:
        if key in _solution_cache or key in queued:
            continue
        options = stage_options_for_groups(groups, pool, cfg)
        if any(not o for o in options):
            _solution_cache[key] = None
            continue
        queued.add(key)
        grid = default_latency_grid(options, n=cfg.latency_points)
        jobs.append(
            PipelineJob(
                options, grid, max_e2e=req.max_e2e, n_stages=sum(gr.repeat for gr in groups)
            )
        )
        job_keys.append(key)
    if jobs:
        sols = solve_pipeline_batch(jobs, objective=objective)
        for key, sol in zip(job_keys, sols):
            _solution_cache[key] = sol
    out: dict[Genome, FusionResult | None] = {}
    for g, groups, key in decoded:
        sol = _solution_cache[key]
        out[g] = (
            None
            if sol is None
            else FusionResult(genome=g, groups=groups, solution=sol, value=sol.value)
        )
    return out


# --- seeding ----------------------------------------------------------------


def _roofline_seed(graph: OperatorGraph, pool: Sequence[Chiplet], fuse: bool) -> Genome:
    """Insight-1 seed: group while intermediates fit the biggest GLB; give
    memory-bound groups HBM, compute-bound groups DDR5."""
    ops, reps = graph.operators, graph.repeats
    forced = forced_boundaries(graph)
    glb = max(c.glb_bytes for c in pool) / 2
    ref_chiplet = sorted(pool, key=lambda c: c.n_pes)[len(pool) // 2]
    bounds = []
    for i in range(len(ops) - 1):
        if not fuse:
            bounds.append(1)
        else:
            spill = ops[i].act_out_bytes > glb
            bounds.append(1 if (forced[i] or spill) else 0)
    hbm_i = MEMORY_POOL.index(HBM3)
    ddr_i = MEMORY_POOL.index(DDR5)
    genes = [hbm_i if is_memory_bound(o, ref_chiplet, HBM3) else ddr_i for o in ops]
    return Genome(boundaries=tuple(bounds), mem_genes=tuple(genes))


def _mutate(g: Genome, rng: random.Random, rate: float) -> Genome:
    b = list(g.boundaries)
    m = list(g.mem_genes)
    for i in range(len(b)):
        if rng.random() < rate:
            b[i] ^= 1
    for i in range(len(m)):
        if rng.random() < rate:
            m[i] = rng.randrange(len(MEMORY_POOL))
    return Genome(tuple(b), tuple(m))


def _crossover(a: Genome, b: Genome, rng: random.Random) -> Genome:
    """Single-point crossover preserving contiguous fusion groups (§4.2)."""
    if len(a.boundaries) == 0:
        return a
    cut = rng.randrange(len(a.boundaries) + 1)
    return Genome(
        a.boundaries[:cut] + b.boundaries[cut:], a.mem_genes[: cut + 1] + b.mem_genes[cut + 1 :]
    )


def initial_population(
    graph: OperatorGraph, pool: Sequence[Chiplet], cfg: GAConfig, rng: random.Random | None = None
) -> list[Genome]:
    """The GA's deterministic generation-0 population: the two roofline
    seeds plus seeded mutations of the fused seed.  Factored out so the
    process-pool warmup can decode the exact genomes a worker's GA will
    evaluate first — without running the GA.  When `rng` is supplied
    (by `optimize_fusion`), its state advances exactly as the inlined
    seeding loop used to, preserving fixed-seed GA trajectories."""
    rng = rng if rng is not None else random.Random(cfg.seed)
    seeds = [_roofline_seed(graph, pool, fuse=True), _roofline_seed(graph, pool, fuse=False)]
    pop: list[Genome] = list(seeds)
    while len(pop) < cfg.population:
        pop.append(_mutate(seeds[0], rng, 0.3))
    return pop


def optimize_fusion(
    graph: OperatorGraph,
    pool: Sequence[Chiplet],
    objective: str = "energy",
    req: Requirement | None = None,
    cfg: GAConfig | None = None,
) -> FusionResult | None:
    """The full Layer-2 GA.  Returns the best feasible FusionResult."""
    req = req if req is not None else Requirement()
    cfg = cfg if cfg is not None else GAConfig()
    rng = random.Random(cfg.seed)
    n = len(graph.operators)

    pop = initial_population(graph, pool, cfg, rng)

    cache: dict[Genome, FusionResult | None] = {}
    solution_cache: dict = {} if engine_enabled() else None

    def fit(g: Genome) -> float:
        if g not in cache:
            cache[g] = evaluate_genome(
                graph, g, pool, objective, req, cfg, _solution_cache=solution_cache
            )
        r = cache[g]
        return math.inf if r is None else r.value

    def batch_eval(genomes: Sequence[Genome]) -> None:
        """Evaluate a whole generation: batched option enumeration across
        every distinct fusion group first, then ONE generation-batched
        Layer-3 solve (`evaluate_genomes`) covering every distinct
        fusion plan.  Selection/crossover/mutation below never touch the
        rng during evaluation, so the GA trajectory is identical to
        scalar per-genome evaluation."""
        todo = [g for g in dict.fromkeys(genomes) if g not in cache]
        if not todo:
            return
        if solution_cache is not None:
            # evaluate_genomes prefetches options for the decoded groups
            # itself (one decode pass shared with the solve batch).
            cache.update(evaluate_genomes(graph, todo, pool, objective, req, cfg, solution_cache))
        else:
            for g in todo:
                fit(g)

    for _ in range(cfg.generations):
        batch_eval(pop)
        scored = sorted(pop, key=fit)
        elite = scored[: max(2, cfg.population // 5)]
        nxt = list(elite)
        while len(nxt) < cfg.population:
            if rng.random() < cfg.crossover_rate and len(scored) >= 2:
                child = _crossover(rng.choice(scored[:5]), rng.choice(scored[:5]), rng)
            else:
                child = rng.choice(elite)
            nxt.append(_mutate(child, rng, cfg.mutation_rate))
        pop = nxt

    batch_eval(pop)  # final generation's children
    best = min(pop, key=fit)
    res = cache.get(best)
    if res is None:
        for g in sorted(cache, key=fit):
            if cache[g] is not None:
                return cache[g]
    return res
