"""Iso-latency layer codesign via the modified convex hull trick
(paper §4.3, Algorithm 1).

Per pipeline stage, every (chiplet, memory, tp, batch) option induces a
piecewise-affine energy function of the stage latency T:

    E(T) = e_dyn + p_static * T   for T >= t_cmp,   +inf below.

Fixing the pipeline initiation interval T decouples the stages, so the
joint O(M^P) search collapses to, per stage, "evaluate the lower envelope
of M piecewise-affine functions at Q latencies".  The *modified* part:
functions activate at different thresholds t_cmp, so the envelope is
maintained incrementally — options are sorted by activation point and
inserted into a dynamic lower hull as the query latency sweeps upward
(Algorithm 1's SortTCompute / BinarySearchInsert / RemoveIrrelevant).

Complexity: O(P * (M log M + Q log M)), as claimed in §4.3.4.

Two interchangeable envelope engines are provided and cross-tested:
  * DynamicLowerHull — the paper's literal structure;
  * LiChaoTree       — same asymptotics, used as an independent oracle.
"""

from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Callable, Sequence

import numpy as np

from .engine import batch_solve_enabled, engine_enabled
from .perfmodel import StageOption, StageOptionSet, envelope_keep_mask


@dataclasses.dataclass
class Line:
    """y = slope * x + intercept, tagged with its originating option."""

    slope: float
    intercept: float
    payload: object = None

    def at(self, x: float) -> float:
        return self.slope * x + self.intercept


# ---------------------------------------------------------------------------
# Dynamic lower hull with arbitrary-order insertion (paper Algorithm 1)
# ---------------------------------------------------------------------------


class DynamicLowerHull:
    """Lower envelope of lines; supports insertion in arbitrary slope order
    (BinarySearchInsert + RemoveIrrelevant) and O(log M) min-queries."""

    def __init__(self):
        self._lines: list[Line] = []  # sorted by slope, envelope-only

    @staticmethod
    def _bad(l1: Line, l2: Line, l3: Line) -> bool:
        """True if l2 is everywhere dominated by l1 and l3."""
        # intersection_x(l1,l3) <= intersection_x(l1,l2)  =>  l2 useless
        return (l3.intercept - l1.intercept) * (l2.slope - l1.slope) <= (
            l2.intercept - l1.intercept
        ) * (l3.slope - l1.slope)

    def insert(self, line: Line) -> None:
        lines = self._lines
        slopes = [l.slope for l in lines]
        pos = bisect.bisect_left(slopes, line.slope)
        # Equal slope: keep only the lower intercept.
        if pos < len(lines) and lines[pos].slope == line.slope:
            if lines[pos].intercept <= line.intercept:
                return
            lines.pop(pos)
        # Would the new line itself be dominated?
        if 0 < pos < len(lines) and self._bad(lines[pos - 1], line, lines[pos]):
            return
        lines.insert(pos, line)
        # RemoveIrrelevant: drop dominated neighbours on both sides.
        i = pos + 1
        while 0 < i < len(lines) - 1 and self._bad(lines[i - 1], lines[i], lines[i + 1]):
            lines.pop(i)
        i = pos - 1
        while 0 < i < len(lines) - 1 and self._bad(lines[i - 1], lines[i], lines[i + 1]):
            lines.pop(i)
            i -= 1

    def query(self, x: float) -> Line | None:
        """Line attaining the envelope minimum at x (binary search over
        breakpoints; the envelope value is unimodal along the hull)."""
        lines = self._lines
        if not lines:
            return None
        lo, hi = 0, len(lines) - 1
        while lo < hi:
            mid = (lo + hi) // 2
            if lines[mid].at(x) <= lines[mid + 1].at(x):
                hi = mid
            else:
                lo = mid + 1
        return lines[lo]


# ---------------------------------------------------------------------------
# Li Chao tree over a fixed query grid (independent oracle, same use)
# ---------------------------------------------------------------------------


class LiChaoTree:
    def __init__(self, xs: Sequence[float]):
        self._xs = sorted(xs)
        self._n = max(1, len(self._xs))
        self._seg: dict[int, Line] = {}

    def _ins(self, node: int, lo: int, hi: int, line: Line) -> None:
        cur = self._seg.get(node)
        if cur is None:
            self._seg[node] = line
            return
        mid = (lo + hi) // 2
        xm = self._xs[mid]
        if line.at(xm) < cur.at(xm):
            self._seg[node], line = line, cur
            cur = self._seg[node]
        if lo == hi:
            return
        if line.at(self._xs[lo]) < cur.at(self._xs[lo]):
            self._ins(2 * node, lo, mid, line)
        elif line.at(self._xs[hi]) < cur.at(self._xs[hi]):
            self._ins(2 * node + 1, mid + 1, hi, line)

    def insert(self, line: Line) -> None:
        self._ins(1, 0, self._n - 1, line)

    def query_idx(self, i: int) -> Line | None:
        node, lo, hi = 1, 0, self._n - 1
        best: Line | None = None
        x = self._xs[i]
        while True:
            cur = self._seg.get(node)
            if cur is not None and (best is None or cur.at(x) < best.at(x)):
                best = cur
            if lo == hi:
                return best
            mid = (lo + hi) // 2
            if i <= mid:
                node, hi = 2 * node, mid
            else:
                node, lo = 2 * node + 1, mid + 1


# ---------------------------------------------------------------------------
# Stage envelope: iso-latency sweep with activation thresholds
# ---------------------------------------------------------------------------


def stage_envelope(
    options: Sequence[StageOption],
    latencies: Sequence[float],
    cost_weight: Callable[[StageOption], float] = lambda o: 1.0,
    engine: str = "hull",
) -> list[tuple[float, StageOption | None]]:
    """For each query latency T (ascending), the minimum of
    cost_weight(o) * (e_dyn + p_static*T) over options with t_cmp <= T.

    Returns [(value, argmin_option)] aligned with `latencies`.
    """
    lat = list(latencies)
    order = sorted(range(len(lat)), key=lat.__getitem__)
    opts = sorted(options, key=lambda o: o.t_cmp)  # SortTCompute
    use_lichao = engine == "lichao"
    hull = LiChaoTree([lat[i] for i in order]) if use_lichao else DynamicLowerHull()

    out: list[tuple[float, StageOption | None]] = [(math.inf, None)] * len(lat)
    j = 0
    for qi, i in enumerate(order):
        T = lat[i]
        while j < len(opts) and opts[j].t_cmp <= T:
            w = cost_weight(opts[j])
            hull.insert(
                Line(slope=opts[j].p_static * w, intercept=opts[j].e_dyn * w, payload=opts[j])
            )
            j += 1
        line = hull.query_idx(qi) if use_lichao else hull.query(T)
        if line is not None:
            out[i] = (line.at(T), line.payload)
    return out


# ---------------------------------------------------------------------------
# Vectorized O((M+Q) log M) hull sweep (the "true" Algorithm 1, batched)
# ---------------------------------------------------------------------------


def _hull_of(slope: np.ndarray, icept: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Lower-envelope hull of a block of lines: (slopes, intercepts,
    reversed breakpoints).  Monotone-chain build over slope-sorted lines
    using the same cross-multiplied dominance predicate as
    DynamicLowerHull._bad, so degenerate cases resolve identically."""
    o = np.lexsort((icept, slope))
    s, c = slope[o], icept[o]
    keep: list[int] = []
    for j in range(s.size):
        if keep and s[keep[-1]] == s[j]:
            continue  # equal slope: lower intercept won
        while len(keep) >= 2:
            i1, i2 = keep[-2], keep[-1]
            if (c[j] - c[i1]) * (s[i2] - s[i1]) <= (c[i2] - c[i1]) * (s[j] - s[i1]):
                keep.pop()  # middle line everywhere dominated
            else:
                break
        keep.append(j)
    hs, hc = s[keep], c[keep]
    # Line i beats line i+1 for T >= bx[i]; hull validity makes bx
    # decreasing in i, so store it reversed (ascending) for searchsorted.
    bxr = ((hc[:-1] - hc[1:]) / (hs[1:] - hs[:-1]))[::-1]
    return hs, hc, bxr


def _hull_eval(hs: np.ndarray, hc: np.ndarray, bxr: np.ndarray, T: np.ndarray) -> np.ndarray:
    """Envelope minimum of a prebuilt hull at each query T (vectorized
    binary search over breakpoints; the ±1 neighbors are evaluated too so
    breakpoint rounding can never miss the true minimum line)."""
    n = hs.size
    if n == 1:
        return hs[0] * T + hc[0]
    idx = (n - 1) - np.searchsorted(bxr, T, side="right")
    lo = np.maximum(idx - 1, 0)
    hi = np.minimum(idx + 1, n - 1)
    return np.minimum(np.minimum(hs[idx] * T + hc[idx], hs[lo] * T + hc[lo]), hs[hi] * T + hc[hi])


def stage_envelope_sweep(
    t_cmp: np.ndarray, slope: np.ndarray, icept: np.ndarray, latencies: np.ndarray
) -> np.ndarray:
    """min over {j : t_cmp_j <= T} of (slope_j*T + icept_j), for every T
    of an ascending latency grid — values only, O((M+Q) log M).

    Options sorted by activation threshold make each query's active set a
    prefix; a prefix [0, k) decomposes into <= log2(M) canonical
    power-of-two blocks (Fenwick ranges), each with a lazily-built static
    hull, queried by vectorized breakpoint binary search.  Total distinct
    blocks across all prefixes is < 2M, so hull construction is
    O(M log M) and the query sweep O(Q log M) — the asymptotics of paper
    Algorithm 1, with the Q-side fully batched.
    """
    lat = np.asarray(latencies, dtype=np.float64)
    out = np.full(lat.size, math.inf)
    if t_cmp.size == 0 or lat.size == 0:
        return out
    order = np.argsort(t_cmp, kind="stable")
    ts, ss, cs = t_cmp[order], slope[order], icept[order]
    ks = np.searchsorted(ts, lat, side="right")  # active prefix per query

    hulls: dict[tuple[int, int], tuple] = {}

    def block(start: int, size: int) -> tuple:
        h = hulls.get((start, size))
        if h is None:
            h = hulls[(start, size)] = _hull_of(ss[start : start + size], cs[start : start + size])
        return h

    q = 0
    while q < lat.size:
        k = int(ks[q])
        end = q + 1
        while end < lat.size and ks[end] == k:
            end += 1
        if k > 0:
            T = lat[q:end]
            acc = np.full(T.size, math.inf)
            pos, rem = 0, k
            while rem:
                size = 1 << (rem.bit_length() - 1)
                acc = np.minimum(acc, _hull_eval(*block(pos, size), T))
                pos += size
                rem -= size
            out[q:end] = acc
        q = end
    return out


def stage_envelope_bruteforce(options, latencies, cost_weight=lambda o: 1.0):
    """O(M*Q) reference used by the property tests."""
    out = []
    for T in latencies:
        best, arg = math.inf, None
        for o in options:
            if o.t_cmp <= T:
                v = cost_weight(o) * (o.e_dyn + o.p_static * T)
                if v < best:
                    best, arg = v, o
        out.append((best, arg))
    return out


# ---------------------------------------------------------------------------
# Pipeline solve (the full Layer-3 of the framework)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PipelineSolution:
    objective: str
    value: float  # objective value (lower is better)
    T: float  # per-sample initiation interval (s)
    energy_per_sample: float  # J
    delay_e2e: float  # s (P * T, balanced pipeline)
    hw_cost_usd: float
    throughput: float  # samples/s
    stages: list[StageOption]

    def metrics(self) -> dict[str, float]:
        e, d, c = self.energy_per_sample, self.delay_e2e, self.hw_cost_usd
        # cost metrics use the solver's per-stage decomposition
        # sum_s E_s*$_s (paper §4.3.3 "multiply by the cost factor"),
        # keeping reported numbers consistent with optimized ones.
        ec = sum((o.e_dyn + o.p_static * self.T) * o.hw_cost_usd for o in self.stages)
        return {
            "energy": e,
            "edp": e * d,
            "energy_cost": ec,
            "edp_cost": ec * d,
            "latency_e2e": d,
            "throughput": self.throughput,
            "hw_cost_usd": c,
            "T": self.T,
        }

    def to_dict(self) -> dict:
        return {
            "objective": self.objective,
            "value": self.value,
            "T": self.T,
            "energy_per_sample": self.energy_per_sample,
            "delay_e2e": self.delay_e2e,
            "hw_cost_usd": self.hw_cost_usd,
            "throughput": self.throughput,
            "stages": [s.to_dict() for s in self.stages],
        }

    @staticmethod
    def from_dict(d: dict) -> "PipelineSolution":
        return PipelineSolution(
            objective=d["objective"],
            value=d["value"],
            T=d["T"],
            energy_per_sample=d["energy_per_sample"],
            delay_e2e=d["delay_e2e"],
            hw_cost_usd=d["hw_cost_usd"],
            throughput=d["throughput"],
            stages=[StageOption.from_dict(s) for s in d["stages"]],
        )


def _cost_weight_fn(objective: str) -> Callable[[StageOption], float]:
    if objective.endswith("_cost"):
        # Per-stage cost factor keeps the function affine and the sum
        # separable (paper §4.3.3: "multiply ... by the cost factor").
        return lambda o: max(o.hw_cost_usd, 1e-9)
    return lambda o: 1.0


def _option_columns(
    opts: Sequence[StageOption],
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    if isinstance(opts, StageOptionSet):
        return opts.columns()
    return (
        np.array([o.t_cmp for o in opts], dtype=np.float64),
        np.array([o.e_dyn for o in opts], dtype=np.float64),
        np.array([o.p_static for o in opts], dtype=np.float64),
        np.array([o.hw_cost_usd for o in opts], dtype=np.float64),
    )


# Per-stage (kept options x latencies) cell count above which the dense
# masked-matrix sweep switches to the O((M+Q) log M) hull sweep.  The
# dense path wins on small grids (pure array ops, no per-block Python);
# measured crossover on the dev container is ~1e7 cells (M=2000, Q=5000:
# 1.5x; M=5000, Q=20000: 4.2x for the sweep), and the dense matrix costs
# 8*M*Q bytes, so switch at 2e6 cells (16 MB) to bound memory too.
HULLVEC_MIN_CELLS = 2_000_000


def _stage_cols(
    stage_options: Sequence[Sequence[StageOption]], weighted: bool
) -> list[tuple] | None:
    """Per-stage pruned (t_cmp, slope, intercept, original_index) columns,
    or None when any stage has no options (infeasible pipeline)."""
    cols: list[tuple] = []
    for opts in stage_options:
        if isinstance(opts, StageOptionSet):
            if len(opts) == 0:
                return None
            cols.append(opts.pruned(weighted))
            continue
        t_cmp, e_dyn, p_static, hw = _option_columns(opts)
        if t_cmp.size == 0:
            return None
        w = np.maximum(hw, 1e-9) if weighted else 1.0
        slope, icept = p_static * w, e_dyn * w
        idx = np.flatnonzero(envelope_keep_mask(t_cmp, slope, icept))
        cols.append((t_cmp[idx], slope[idx], icept[idx], idx))
    return cols


def _build_solution(
    stage_options: Sequence[Sequence[StageOption]],
    cols: list[tuple],
    lat: list[float],
    total: np.ndarray,
    objective: str,
    P: int,
) -> PipelineSolution | None:
    """argmin over the summed grid + second pass recovering each stage's
    winner at the winning T only.  Exact-tie break mirrors the hull
    engine: duplicate lines keep the first inserted, and insertion order
    is ascending t_cmp (stable)."""
    best_i = int(np.argmin(total))
    if not math.isfinite(total[best_i]):
        return None
    best_T = lat[best_i]
    best_stages = []
    for opts, (t_cmp, slope, icept, idx) in zip(stage_options, cols):
        v = slope * best_T + icept
        v[t_cmp > best_T] = math.inf
        cand = np.flatnonzero(v == v.min())
        best_stages.append(opts[int(idx[cand[np.argmin(t_cmp[cand])]])])
    e = sum(o.e_dyn + o.p_static * best_T for o in best_stages)
    cost = sum(o.hw_cost_usd for o in best_stages)
    return PipelineSolution(
        objective=objective,
        value=float(total[best_i]),
        T=best_T,
        energy_per_sample=e,
        delay_e2e=best_T * P,
        hw_cost_usd=cost,
        throughput=1.0 / best_T,
        stages=best_stages,
    )


def _solve_pipeline_numpy(
    stage_options: Sequence[Sequence[StageOption]],
    lat: list[float],
    objective: str,
    P: int,
    force_sweep: bool = False,
) -> PipelineSolution | None:
    """Vectorized iso-latency sweep.  Per stage, envelope values over the
    grid come from either a masked (options x latencies) dense array min
    or, above HULLVEC_MIN_CELLS (or with engine="hullvec"), the
    O((M+Q) log M) prefix-block hull sweep.  Values match the hull engine
    (same slope/intercept formulation) to the last bit; ties between
    exactly-equal options may pick a different argmin."""
    latv = np.asarray(lat, dtype=np.float64)
    weighted = objective.endswith("_cost")
    cols = _stage_cols(stage_options, weighted)
    if cols is None:
        return None
    mins_rows: list[np.ndarray | None] = [None] * len(cols)
    dense = [
        i
        for i, c in enumerate(cols)
        if not force_sweep and c[0].size * latv.size < HULLVEC_MIN_CELLS
    ]
    for i, c in enumerate(cols):
        if i not in dense:
            mins_rows[i] = stage_envelope_sweep(c[0], c[1], c[2], latv)
    if dense:
        # One (sum-of-options x latencies) matrix for the dense stages;
        # per-stage minima via segmented reduction.
        tc = np.concatenate([cols[i][0] for i in dense])
        slope = np.concatenate([cols[i][1] for i in dense])
        icept = np.concatenate([cols[i][2] for i in dense])
        vals = slope[:, None] * latv[None, :]
        vals += icept[:, None]
        vals[tc[:, None] > latv[None, :]] = math.inf
        starts = np.cumsum([0] + [cols[i][0].size for i in dense[:-1]])
        mins = np.minimum.reduceat(vals, starts, axis=0)
        for i, row in zip(dense, mins):
            mins_rows[i] = row
    total = np.zeros(len(lat))
    for row in mins_rows:  # per-stage add order preserved
        total += row
    if objective in ("edp", "edp_cost"):
        total = total * (latv * P)
    return _build_solution(stage_options, cols, lat, total, objective, P)


def solve_pipeline(
    stage_options: Sequence[Sequence[StageOption]],
    latencies: Sequence[float],
    objective: str = "energy",
    max_interval: float | None = None,
    max_e2e: float | None = None,
    n_stages: int | None = None,
    engine: str = "auto",
) -> PipelineSolution | None:
    """Iso-latency with modified convex hull trick over a whole pipeline.

    objective: energy | edp | energy_cost | edp_cost.
    max_interval: TPOT-style bound on T; max_e2e: TTFT/E2E bound on P*T.
    n_stages: physical stage count (sum of repeats) when stage groups are
    compressed; defaults to len(stage_options).
    engine: auto (vectorized NumPy when the evaluation engine is on,
    else hull) | numpy | hullvec (numpy with the O((M+Q) log M) hull
    sweep forced for every stage) | hull | lichao.
    """
    assert objective in ("energy", "edp", "energy_cost", "edp_cost")
    P = n_stages if n_stages is not None else len(stage_options)
    lat = sorted(set(latencies))
    if max_interval is not None:
        lat = [t for t in lat if t <= max_interval]
    if max_e2e is not None:
        lat = [t for t in lat if t * P <= max_e2e]
    if not lat or P == 0:
        return None

    if engine == "auto":
        engine = "numpy" if engine_enabled() else "hull"
    if engine in ("numpy", "hullvec"):
        return _solve_pipeline_numpy(
            stage_options, lat, objective, P, force_sweep=engine == "hullvec"
        )

    w = _cost_weight_fn(objective)
    envs = [stage_envelope(opts, lat, cost_weight=w, engine=engine) for opts in stage_options]

    best_val, best_T, best_stages = math.inf, None, None
    for i, T in enumerate(lat):
        val, stages = 0.0, []
        ok = True
        for env in envs:
            v, o = env[i]
            if o is None:
                ok = False
                break
            val += v
            stages.append(o)
        if not ok:
            continue
        if objective in ("edp", "edp_cost"):
            val *= T * P  # ObjFactor (Algorithm 1 l.23)
        if val < best_val:
            best_val, best_T, best_stages = val, T, stages

    if best_stages is None:
        return None
    e = sum(o.e_dyn + o.p_static * best_T for o in best_stages)
    cost = sum(o.hw_cost_usd for o in best_stages)
    return PipelineSolution(
        objective=objective,
        value=best_val,
        T=best_T,
        energy_per_sample=e,
        delay_e2e=best_T * P,
        hw_cost_usd=cost,
        throughput=1.0 / best_T,
        stages=best_stages,
    )


@dataclasses.dataclass
class PipelineJob:
    """One genome's Layer-3 solve, as an element of a generation batch:
    the per-stage option sets, the latency grid, and the constraints that
    `solve_pipeline` would receive for that genome."""

    stage_options: Sequence[Sequence[StageOption]]
    latencies: Sequence[float]
    max_interval: float | None = None
    max_e2e: float | None = None
    n_stages: int | None = None


# Upper bound on dense cells materialized by one flat generation sweep;
# batches beyond it are processed in chunks (bounds peak memory at
# ~3 full-size float64 temporaries).
BATCH_MAX_CELLS = 8_000_000


def _batch_dense_rows(
    blocks: list[tuple[int, int]], prepared: list, out_rows: dict[tuple[int, int], np.ndarray]
) -> None:
    """Evaluate every dense (job, stage) block of a generation in ONE
    segmented sweep.

    Jobs have ragged grids, so the per-job grids are packed into a
    (jobs x max_grid) matrix (padded columns are never read back) and
    every option row gathers its job's grid row: one multiply, one add,
    one mask over the stacked (all options x max_grid) matrix, then a
    single `np.minimum.reduceat` with one segment per (job, stage)
    block.  Each cell computes slope*T then +intercept — the exact op
    sequence of the per-genome dense sweep — so the resulting rows are
    bit-identical to per-genome `_solve_pipeline_numpy` calls.
    """
    M = np.array([prepared[pi][3][si][0].size for pi, si in blocks], dtype=np.int64)
    t_all = np.concatenate([prepared[pi][3][si][0] for pi, si in blocks])
    s_all = np.concatenate([prepared[pi][3][si][1] for pi, si in blocks])
    c_all = np.concatenate([prepared[pi][3][si][2] for pi, si in blocks])
    job_ids = sorted({pi for pi, _ in blocks})
    job_row = {pi: r for r, pi in enumerate(job_ids)}
    max_q = max(prepared[pi][0].size for pi in job_ids)
    # Padded per-job grid matrix; the pad value only fills cells that are
    # sliced away below, so its value is irrelevant (0 keeps it finite).
    lat_pad = np.zeros((len(job_ids), max_q))
    for pi in job_ids:
        lat_pad[job_row[pi], : prepared[pi][0].size] = prepared[pi][0]
    row_of_option = np.repeat(np.array([job_row[pi] for pi, _ in blocks], dtype=np.intp), M)
    T = lat_pad[row_of_option]  # (total options x max_q)
    vals = s_all[:, None] * T
    vals += c_all[:, None]
    vals[t_all[:, None] > T] = math.inf
    starts = np.concatenate(([0], np.cumsum(M)))[:-1]
    mins = np.minimum.reduceat(vals, starts, axis=0)
    for b, (pi, si) in enumerate(blocks):
        out_rows[(pi, si)] = mins[b, : prepared[pi][0].size]


def _batch_recover(
    blocks: list[tuple[int, int]], prepared: list, best_T: dict[int, float]
) -> dict[tuple[int, int], int]:
    """Batched second pass: for every (job, stage) block, the index (into
    the block's pruned columns) of the winning option at the job's
    winning T — one flat segmented computation replacing the per-job
    Python recovery loop.

    The tie-break is the hull engine's, replicated exactly: among
    options attaining the envelope minimum (exact float equality), the
    smallest t_cmp wins, and among equal t_cmp the lowest index (first
    inserted) wins."""
    M = np.array([prepared[pi][3][si][0].size for pi, si in blocks], dtype=np.int64)
    starts = np.concatenate(([0], np.cumsum(M)))[:-1]
    t_all = np.concatenate([prepared[pi][3][si][0] for pi, si in blocks])
    s_all = np.concatenate([prepared[pi][3][si][1] for pi, si in blocks])
    c_all = np.concatenate([prepared[pi][3][si][2] for pi, si in blocks])
    Tb = np.repeat(np.array([best_T[pi] for pi, _ in blocks]), M)
    v = s_all * Tb
    v += c_all
    v[t_all > Tb] = math.inf
    vmin = np.minimum.reduceat(v, starts)
    elig = v == np.repeat(vmin, M)
    tkey = np.where(elig, t_all, math.inf)
    tmin = np.minimum.reduceat(tkey, starts)
    good = elig & (t_all == np.repeat(tmin, M))
    loc = np.arange(t_all.size, dtype=np.int64) - np.repeat(starts, M)
    win = np.minimum.reduceat(np.where(good, loc, t_all.size), starts)
    return {blk: int(w) for blk, w in zip(blocks, win)}


def solve_pipeline_batch(
    jobs: Sequence[PipelineJob], objective: str = "energy", engine: str = "auto"
) -> list[PipelineSolution | None]:
    """Generation-batched `solve_pipeline`: every job's per-stage
    envelope columns are stacked into one ragged flat array set and the
    iso-latency grids of the whole batch are swept together with
    segmented `minimum.reduceat` reductions; per-job winning stages are
    recovered in a single second pass at each job's winning T.

    Returns one `PipelineSolution | None` per job, aligned with `jobs`,
    bit-identical (values, T, stage configs, tie-breaks) to calling
    `solve_pipeline` per job.  Stages whose (options x latencies) cell
    count crosses HULLVEC_MIN_CELLS still use the O((M+Q) log M) hull
    sweep, exactly as the per-genome path would.  `MOZART_BATCH_SOLVE=0`
    (or a non-numpy engine) falls back to the scalar per-job loop.
    """
    assert objective in ("energy", "edp", "energy_cost", "edp_cost")
    per_genome = False
    if engine == "auto":
        if not engine_enabled():
            engine = "hull"
        elif not batch_solve_enabled():
            engine = "numpy"  # per-genome loop, vectorized path
            per_genome = True
        else:
            engine = "numpy"
    if per_genome or engine not in ("numpy", "hullvec"):
        return [
            solve_pipeline(
                j.stage_options,
                j.latencies,
                objective=objective,
                max_interval=j.max_interval,
                max_e2e=j.max_e2e,
                n_stages=j.n_stages,
                engine=engine,
            )
            for j in jobs
        ]
    force_sweep = engine == "hullvec"
    weighted = objective.endswith("_cost")

    # Per-job preprocessing, mirroring solve_pipeline exactly:
    # (latv, lat list, P, cols) or None for infeasible jobs.
    prepared: list[tuple | None] = []
    for j in jobs:
        P = j.n_stages if j.n_stages is not None else len(j.stage_options)
        lat = sorted(set(j.latencies))
        if j.max_interval is not None:
            lat = [t for t in lat if t <= j.max_interval]
        if j.max_e2e is not None:
            lat = [t for t in lat if t * P <= j.max_e2e]
        if not lat or P == 0:
            prepared.append(None)
            continue
        cols = _stage_cols(j.stage_options, weighted)
        if cols is None:
            prepared.append(None)
            continue
        prepared.append((np.asarray(lat, dtype=np.float64), lat, P, cols))

    # Plan: dense blocks go to the flat batched sweep (chunked to bound
    # memory — the chunk's footprint is (sum of option counts) x (max
    # grid length), since shorter grids are padded up to the longest in
    # the chunk); oversized stages use the per-stage hull sweep.
    rows: dict[tuple[int, int], np.ndarray] = {}
    chunk: list[tuple[int, int]] = []
    chunk_m = 0
    chunk_q = 0
    for pi, prep in enumerate(prepared):
        if prep is None:
            continue
        latv, _, _, cols = prep
        for si, c in enumerate(cols):
            m, q = c[0].size, latv.size
            if force_sweep or m * q >= HULLVEC_MIN_CELLS:
                rows[(pi, si)] = stage_envelope_sweep(c[0], c[1], c[2], latv)
                continue
            if chunk and (chunk_m + m) * max(chunk_q, q) > BATCH_MAX_CELLS:
                _batch_dense_rows(chunk, prepared, rows)
                chunk, chunk_m, chunk_q = [], 0, 0
            chunk.append((pi, si))
            chunk_m += m
            chunk_q = max(chunk_q, q)
    if chunk:
        _batch_dense_rows(chunk, prepared, rows)

    # Per-job totals and winning T (cheap vector ops per job); the
    # per-stage winner recovery across all jobs is batched below.
    totals: dict[int, np.ndarray] = {}
    best_i: dict[int, int] = {}
    best_T: dict[int, float] = {}
    for pi, prep in enumerate(prepared):
        if prep is None:
            continue
        latv, lat, P, cols = prep
        total = np.zeros(len(lat))
        for si in range(len(cols)):  # per-stage add order preserved
            total += rows[(pi, si)]
        if objective in ("edp", "edp_cost"):
            total = total * (latv * P)
        i = int(np.argmin(total))
        if not math.isfinite(total[i]):
            continue
        totals[pi] = total
        best_i[pi] = i
        best_T[pi] = lat[i]

    rec = [(pi, si) for pi in best_T for si in range(len(prepared[pi][3]))]
    winners = _batch_recover(rec, prepared, best_T) if rec else {}

    out: list[PipelineSolution | None] = []
    for pi, (j, prep) in enumerate(zip(jobs, prepared)):
        if prep is None or pi not in best_T:
            out.append(None)
            continue
        _, lat, P, cols = prep
        T = best_T[pi]
        stages = [
            j.stage_options[si][int(cols[si][3][winners[(pi, si)]])] for si in range(len(cols))
        ]
        e = sum(o.e_dyn + o.p_static * T for o in stages)
        cost = sum(o.hw_cost_usd for o in stages)
        out.append(
            PipelineSolution(
                objective=objective,
                value=float(totals[pi][best_i[pi]]),
                T=T,
                energy_per_sample=e,
                delay_e2e=T * P,
                hw_cost_usd=cost,
                throughput=1.0 / T,
                stages=stages,
            )
        )
    return out


def solve_pipeline_bruteforce(
    stage_options, latencies, objective="energy", max_interval=None, max_e2e=None, n_stages=None
):
    """Exponential-in-nothing reference: per-T exhaustive stage scan."""
    P = n_stages if n_stages is not None else len(stage_options)
    lat = sorted(set(latencies))
    if max_interval is not None:
        lat = [t for t in lat if t <= max_interval]
    if max_e2e is not None:
        lat = [t for t in lat if t * P <= max_e2e]
    w = _cost_weight_fn(objective)
    best = None
    for T in lat:
        val, stages, ok = 0.0, [], True
        for opts in stage_options:
            b, arg = math.inf, None
            for o in opts:
                if o.t_cmp <= T:
                    v = w(o) * (o.e_dyn + o.p_static * T)
                    if v < b:
                        b, arg = v, o
            if arg is None:
                ok = False
                break
            val += b
            stages.append(arg)
        if not ok:
            continue
        if objective in ("edp", "edp_cost"):
            val *= T * P
        if best is None or val < best.value:
            e = sum(o.e_dyn + o.p_static * T for o in stages)
            cost = sum(o.hw_cost_usd for o in stages)
            best = PipelineSolution(
                objective=objective,
                value=val,
                T=T,
                energy_per_sample=e,
                delay_e2e=T * P,
                hw_cost_usd=cost,
                throughput=1.0 / T,
                stages=stages,
            )
    return best


# Latency grids memoized per (n, option-set uids): the grid depends only
# on the option sets, and distinct genomes routinely decode to the same
# cached StageOptionSets, so batched and scalar genome evaluations share
# one grid computation per distinct fusion plan.  Keyed by the sets'
# process-unique uid tokens (never reused, unlike id()), FIFO-bounded.
# Single-writer: only the solver loop of one process fills it.
_GRID_CACHE: dict[tuple, list[float]] = {}
_GRID_CACHE_MAX = 65536


def clear_grid_cache() -> None:
    _GRID_CACHE.clear()


def default_latency_grid(
    stage_options: Sequence[Sequence[StageOption]], n: int = 64
) -> list[float]:
    """Geometric grid spanning [min feasible T, max useful T].  Includes
    every stage's t_cmp values (the only points where envelopes change
    shape matter beyond grid resolution).  Memoized per option-set key
    when every stage is a StageOptionSet."""
    key = None
    if stage_options and all(isinstance(o, StageOptionSet) for o in stage_options):
        key = (n, *(o.uid for o in stage_options))
        hit = _GRID_CACHE.get(key)
        if hit is not None:
            return list(hit)
    per_stage = [_option_columns(opts)[0] for opts in stage_options]
    tc = np.concatenate(per_stage) if per_stage else np.empty(0)
    lo, hi = float(tc.min()), float(tc.max())
    hi = max(hi, lo * 4)
    grid = {lo * (hi / lo) ** (i / (n - 1)) for i in range(n)}
    # All bottleneck candidates: the max over stages of per-stage t_cmp's.
    grid.update(float(c.min()) for c in per_stage)
    grid.update(tc[:256].tolist())
    out = sorted(grid)
    if key is not None:
        if len(_GRID_CACHE) >= _GRID_CACHE_MAX:
            _GRID_CACHE.pop(next(iter(_GRID_CACHE)))
        _GRID_CACHE[key] = out
        return list(out)
    return out
