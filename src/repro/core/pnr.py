"""Layer 4: place-and-route feasibility + footprint minimization (§4.4).

Places the accelerator's compute chiplets and on-interposer memory stacks
(HBM) on a 2.5D interposer (or organic substrate for 2D bonding), routes
the linear inter-stage pipeline connections with Manhattan wiring, checks
(1) fit, (2) routability under per-channel wire capacity, (3) a basic
timing constraint on the longest hop, then minimizes the footprint by
sweeping shelf widths.  Results feed wirelength-aware link energy/latency
back to the upper layers (§4.4 "provides feedback to the framework").
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from .memory import HBM3
from .perfmodel import StageOption

MAX_INTERPOSER_MM = {"2.5D": 45.0, "2D": 70.0}   # side, reticle-stitch cap
HBM_STACK_AREA_MM2 = 110.0
ROUTING_HALO = 1.12               # per-chiplet keep-out for bump field
CHANNEL_CAPACITY = 16             # parallel links per routing channel
WIRE_PJ_PER_BIT_MM = 0.10         # incremental link energy vs length
WIRE_NS_PER_MM = 0.10             # ~10 ps/mm RC-repeated wire
MAX_HOP_NS = 5.0                  # basic timing constraint per hop


@dataclasses.dataclass(frozen=True)
class Placement:
    name: str
    x: float
    y: float
    w: float
    h: float

    @property
    def center(self) -> tuple[float, float]:
        return (self.x + self.w / 2, self.y + self.h / 2)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "Placement":
        return Placement(**d)


@dataclasses.dataclass
class PnrResult:
    feasible: bool
    width: float
    height: float
    area_mm2: float
    wirelength_mm: float
    max_hop_mm: float
    n_packages: int
    placements: list[Placement]
    extra_link_energy_pj_per_bit: float
    extra_hop_latency_ns: float
    reason: str = ""

    def to_dict(self) -> dict:
        # asdict deep-converts the nested Placements already
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "PnrResult":
        d = dict(d)
        d["placements"] = [Placement.from_dict(p)
                           for p in d["placements"]]
        return PnrResult(**d)


def _rects_for(stages: Sequence[StageOption]) -> list[tuple[str, float]]:
    """(name, area) rectangles for one pipeline slice: tp compute dies per
    stage plus on-interposer HBM stacks.  DDR/LPDDR/GDDR sit off-package
    (edge PHYs only)."""
    rects: list[tuple[str, float]] = []
    for i, o in enumerate(stages):
        for t in range(o.cfg.tp):
            rects.append((f"s{i}.c{t}",
                          o.cfg.chiplet.area_mm2 * ROUTING_HALO))
        if o.cfg.memory is HBM3 or o.cfg.memory.name == "HBM3":
            for u in range(o.cfg.mem_units):
                rects.append((f"s{i}.hbm{u}", HBM_STACK_AREA_MM2))
    return rects


def _shelf_pack(rects: list[tuple[str, float]],
                width: float) -> tuple[list[Placement], float, float]:
    """First-fit-decreasing shelf packing of near-square rectangles."""
    sized = sorted(((n, math.sqrt(a), math.sqrt(a)) for n, a in rects),
                   key=lambda r: -r[2])
    placements: list[Placement] = []
    x = y = shelf_h = 0.0
    used_w = 0.0
    for name, w, h in sized:
        if x + w > width and x > 0:
            y += shelf_h
            x, shelf_h = 0.0, 0.0
        placements.append(Placement(name, x, y, w, h))
        x += w
        shelf_h = max(shelf_h, h)
        used_w = max(used_w, x)
    return placements, used_w, y + shelf_h


def place_and_route(stages: Sequence[StageOption],
                    bonding: str | None = None) -> PnrResult:
    """Validate physical implementability of one pipeline slice."""
    if not stages:
        return PnrResult(True, 0, 0, 0, 0, 0, 1, [], 0.0, 0.0)
    bonding = bonding or max(o.cfg.chiplet.bonding for o in stages)
    max_side = MAX_INTERPOSER_MM[bonding]
    rects = _rects_for(stages)
    total_area = sum(a for _, a in rects)

    # One package if it can fit; otherwise split the slice across packages.
    n_packages = max(1, math.ceil(total_area / (max_side * max_side * 0.80)))
    per_pkg = rects if n_packages == 1 else \
        rects[: max(1, len(rects) // n_packages)]

    best: tuple[float, list[Placement], float, float] | None = None
    lo = math.sqrt(sum(a for _, a in per_pkg))
    for k in range(6):                         # footprint minimization sweep
        width = min(max_side, lo * (1.0 + 0.25 * k))
        placements, w, h = _shelf_pack(per_pkg, width)
        if w > max_side or h > max_side:
            continue
        bbox = w * h
        if best is None or bbox < best[0]:
            best = (bbox, placements, w, h)
    if best is None:
        return PnrResult(False, 0, 0, total_area, 0, 0, n_packages, [],
                         0.0, 0.0, reason="slice does not fit interposer")

    bbox, placements, w, h = best
    by_name = {p.name: p for p in placements}

    # Route consecutive stages (linear pipeline) with Manhattan wires.
    wirelength = 0.0
    max_hop = 0.0
    hops = 0
    for i in range(len(stages) - 1):
        a = by_name.get(f"s{i}.c0")
        b = by_name.get(f"s{i + 1}.c0")
        if a is None or b is None:
            continue
        (ax, ay), (bx, by) = a.center, b.center
        d = abs(ax - bx) + abs(ay - by)
        wirelength += d
        max_hop = max(max_hop, d)
        hops += 1
    # TP sibling links (skip stages spilled to another package)
    for i, o in enumerate(stages):
        if o.cfg.tp > 1:
            a, b = by_name.get(f"s{i}.c0"), by_name.get(f"s{i}.c1")
            if a is None or b is None:
                continue
            (ax, ay), (bx, by) = a.center, b.center
            wirelength += abs(ax - bx) + abs(ay - by)

    # Routability: wires crossing the vertical mid-cut vs channel capacity.
    mid = w / 2
    crossing = 0
    for i in range(len(stages) - 1):
        a = by_name.get(f"s{i}.c0")
        b = by_name.get(f"s{i + 1}.c0")
        if a and b and (a.center[0] - mid) * (b.center[0] - mid) < 0:
            crossing += 1
    routable = crossing <= CHANNEL_CAPACITY
    hop_ns = max_hop * WIRE_NS_PER_MM
    timing_ok = hop_ns <= MAX_HOP_NS

    feasible = routable and timing_ok
    reason = "" if feasible else \
        ("routing channel overflow" if not routable else "hop timing")
    avg_hop = wirelength / max(hops, 1)
    return PnrResult(feasible=feasible, width=w, height=h, area_mm2=bbox,
                     wirelength_mm=wirelength, max_hop_mm=max_hop,
                     n_packages=n_packages, placements=placements,
                     extra_link_energy_pj_per_bit=avg_hop * WIRE_PJ_PER_BIT_MM,
                     extra_hop_latency_ns=hop_ns, reason=reason)
