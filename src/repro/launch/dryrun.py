import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax-importing module: jax locks
# the device count at first init, and the production meshes below need
# 512 placeholder host devices (single-pod 16x16 uses the first 256).
"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh)
cell with ShapeDtypeStruct inputs — no allocation — then record
memory_analysis(), cost_analysis(), the collective schedule, and the
three roofline terms (launch.analyze).

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json; the
roofline benchmark and EXPERIMENTS.md tables are generated from them.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import analyze
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import batch_specs, decode_specs, params_specs
from repro.models import api
from repro.models.config import ModelConfig
from repro.parallel.sharding import (cache_shardings, data_shardings,
                                     optimizer_shardings, params_shardings)
from repro.training.optimizer import OptimizerConfig, apply_opt, init_opt

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# Per-arch execution policy for the production shapes (the Mozart policy
# layer feeds this; hillclimb iterations edit it — see EXPERIMENTS.md).
ARCH_POLICY: dict[str, dict] = {
    "deepseek-v3-671b": {"fsdp": True, "optimizer": "adafactor"},
    "qwen2.5-32b": {"fsdp": True},
    "mixtral-8x7b": {"fsdp": True},
}


def arch_policy(arch: str) -> dict:
    return {"fsdp": False, "optimizer": "adamw",
            **ARCH_POLICY.get(arch, {})}


def tune_config(cfg: ModelConfig, shape) -> ModelConfig:
    """Production-shape execution knobs (remat for train, chunked attn)."""
    kw = {}
    if shape.kind == "train":
        kw["remat"] = "dots"
    if shape.seq_len >= 32768 and cfg.family == "transformer":
        kw["attn_chunk"] = 2048
    return cfg.replace(**kw) if kw else cfg


def build_step(cfg: ModelConfig, shape, mesh, opt_name: str,
               fsdp: bool):
    """Returns (fn, in_specs_tuple, in_shardings_tuple, donate)."""
    pspec = params_specs(cfg)
    pshard = params_shardings(mesh, pspec, fsdp=fsdp)

    if shape.kind == "train":
        ocfg = OptimizerConfig(name=opt_name)
        ospec = jax.eval_shape(lambda: init_opt(ocfg, pspec))
        oshard = optimizer_shardings(
            mesh, pspec, {"inner": ospec}, fsdp=fsdp)["inner"]
        bspec = batch_specs(cfg, shape)
        bshard = data_shardings(mesh, bspec)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: api.loss_fn(cfg, p, batch))(params)
            params, opt_state, gnorm = apply_opt(ocfg, grads, opt_state,
                                                 params)
            return params, opt_state, loss, gnorm

        scalar = NamedSharding(mesh, P())
        return (train_step, (pspec, ospec, bspec),
                (pshard, oshard, bshard), (0, 1),
                (pshard, oshard, scalar, scalar))

    if shape.kind == "prefill":
        bspec = batch_specs(cfg, shape)
        bshard = data_shardings(mesh, bspec)

        def prefill_step(params, batch):
            return api.prefill(cfg, params, batch, shape.seq_len)

        return prefill_step, (pspec, bspec), (pshard, bshard), (), None

    # decode / long: serve_step — one token against a deep cache
    tspec, cspec = decode_specs(cfg, shape)
    tshard = data_shardings(mesh, {"t": tspec})["t"]
    cshard = cache_shardings(mesh, cspec, cfg.kv_heads,
                             shape.global_batch,
                             seq_shard=cfg.cache_seq_shard)

    def serve_step(params, tokens, cache):
        return api.decode_step(cfg, params, tokens, cache)

    return serve_step, (pspec, tspec, cspec), (pshard, tshard, cshard), (2,), None


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             save: bool = True, verbose: bool = True,
             overrides: dict | None = None, tag: str = "") -> dict:
    shape = configs.SHAPES[shape_name]
    pol = arch_policy(arch)
    cfg = tune_config(configs.get_config(arch), shape)
    if overrides:
        cfg = cfg.replace(**overrides)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.size
    t0 = time.time()
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "n_devices": n_dev, "policy": pol, "ok": False,
              "tag": tag, "overrides": overrides or {}}
    try:
        fn, in_specs, in_shards, donate, out_shards = build_step(
            cfg, shape, mesh, pol["optimizer"], pol["fsdp"])
        # set_mesh (not just the Mesh context manager) so that
        # with_sharding_constraint hints inside the model see the
        # abstract mesh during tracing.
        jax.set_mesh(mesh)
        with mesh:
            jit_kw = {"in_shardings": in_shards,
                      "donate_argnums": donate}
            if out_shards is not None:
                jit_kw["out_shardings"] = out_shards
            # AOT lower/compile analysis: jit is built once per dry-run
            lowered = jax.jit(fn, **jit_kw).lower(*in_specs)  # mzc: ignore[MZC013]
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mf = analyze.model_flops_for(cfg, shape, params_specs(cfg))
        roof = analyze.roofline_from_compiled(compiled, mf, n_dev)
        record.update(ok=True, lower_s=t_lower, compile_s=t_compile,
                      roofline=roof.as_dict())
        try:
            ma = compiled.memory_analysis()
            record["memory_analysis"] = {
                "argument_size_in_bytes": int(ma.argument_size_in_bytes),
                "output_size_in_bytes": int(ma.output_size_in_bytes),
                "temp_size_in_bytes": int(ma.temp_size_in_bytes),
                "alias_size_in_bytes": int(ma.alias_size_in_bytes),
            }
        except Exception:
            record["memory_analysis"] = None
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: OK "
                  f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s)")
            print(f"  memory_analysis: {record['memory_analysis']}")
            ca_keys = ("flops_per_device", "bytes_per_device",
                       "collective_bytes_per_device", "bottleneck",
                       "model_flops_ratio")
            print("  cost_analysis:",
                  {k: record["roofline"][k] for k in ca_keys})
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: "
                  f"FAIL {record['error']}")
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        fn_out = os.path.join(
            OUT_DIR, f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")
        with open(fn_out, "w") as f:
            json.dump(record, f, indent=2, default=float)
    return record


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", choices=configs.ARCH_IDS)
    p.add_argument("--shape", choices=tuple(configs.SHAPES))
    p.add_argument("--mesh", choices=("single", "multi", "both"),
                   default="single")
    p.add_argument("--all", action="store_true",
                   help="sweep every runnable (arch x shape) cell")
    p.add_argument("--no-save", action="store_true")
    p.add_argument("--tag", default="",
                   help="variant label appended to the artifact name")
    p.add_argument("--override", nargs="*", default=[],
                   help="ModelConfig overrides, e.g. gqa_einsum=true")
    args = p.parse_args()

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        overrides[k] = {"true": True, "false": False}.get(
            v.lower(), int(v) if v.isdigit() else v)

    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    if args.all:
        cells = configs.cells()
    else:
        if not args.arch or not args.shape:
            p.error("--arch/--shape required unless --all")
        cells = [(args.arch, args.shape)]

    n_fail = 0
    for arch, shape in cells:
        for mk in meshes:
            rec = run_cell(arch, shape, mk, save=not args.no_save,
                           overrides=overrides, tag=args.tag)
            n_fail += 0 if rec["ok"] else 1
    if n_fail:
        raise SystemExit(f"{n_fail} dry-run cells FAILED")
    print("[dryrun] all requested cells compiled successfully")


if __name__ == "__main__":
    main()
