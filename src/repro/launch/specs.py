"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, no device allocation.  This is the single source of truth for
what each (arch x shape) cell lowers.

  train_*:    train_step(params, opt_state, batch)
  prefill_*:  prefill(params, batch) -> (last_logits, cache)
  decode_* / long_*: serve_step(params, tokens, cache) — one new token
              against a seq_len-deep cache/state (ring-capped for SWA,
              O(1) for SSM/hybrid).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, Shape, get_config
from repro.models import api
from repro.models.config import ModelConfig


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def batch_specs(cfg: ModelConfig, shape: Shape) -> dict:
    """Input batch ShapeDtypeStructs for train/prefill phases."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "whisper":
        dec = s // cfg.dec_seq_factor
        out = {"embeds": sds((b, s, cfg.d_model), cfg.jdtype),
               "tokens": sds((b, dec), jnp.int32)}
        if shape.kind == "train":
            out["labels"] = sds((b, dec), jnp.int32)
        return out
    if cfg.frontend == "vision":
        # 1/4 of the context is patch embeddings, 3/4 text tokens
        p = s // cfg.vision_prefix_factor
        out = {"embeds": sds((b, p, cfg.d_model), cfg.jdtype),
               "tokens": sds((b, s - p), jnp.int32)}
        if shape.kind == "train":
            out["labels"] = sds((b, s - p), jnp.int32)
        return out
    out = {"tokens": sds((b, s), jnp.int32)}
    if shape.kind == "train":
        out["labels"] = sds((b, s), jnp.int32)
    return out


def cache_specs(cfg: ModelConfig, shape: Shape) -> Any:
    """Decode-phase cache ShapeDtypeStructs via eval_shape (no alloc)."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "whisper":
        # cross-KV depth honors the cell's seq_len; decoder self-cache is
        # bounded by the 8192-entry learned position table
        fn = lambda: api.init_cache(cfg, b, min(s // cfg.dec_seq_factor,
                                                8192), enc_len=s)
    else:
        fn = lambda: api.init_cache(cfg, b, s)
    return jax.eval_shape(fn)


def decode_specs(cfg: ModelConfig, shape: Shape) -> tuple:
    """(tokens, cache) specs for serve_step."""
    return (sds((shape.global_batch, 1), jnp.int32),
            cache_specs(cfg, shape))


def params_specs(cfg: ModelConfig) -> Any:
    return jax.eval_shape(
        lambda: api.init_params(cfg, jax.random.PRNGKey(0)))


def input_specs(arch: str, shape_name: str) -> dict:
    """Everything dryrun needs for one cell, as ShapeDtypeStructs."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    out = {"cfg": cfg, "shape": shape, "params": params_specs(cfg)}
    if shape.kind == "decode":
        out["tokens"], out["cache"] = decode_specs(cfg, shape)
    else:
        out["batch"] = batch_specs(cfg, shape)
    return out
