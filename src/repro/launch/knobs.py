"""Central registry of `MOZART_*` environment knobs.

Every env knob the repo reads is declared here with its type, default,
and one-line doc; `tools/mozart_check` (MZC05) fails CI when a
`MOZART_*` read appears outside this registry or when the README table
drifts from it (regenerate the table with
``python -m tools.mozart_check --knob-table``).

This module depends only on the standard library so any layer (core,
serving, launch) can import it without cycles.
"""

from __future__ import annotations

import dataclasses
import os


@dataclasses.dataclass(frozen=True)
class Knob:
    """One environment knob: `type` is "bool" / "int" / "str" (bool knobs
    treat "0"/""/"false"/"no"/"off" as false, anything else as true)."""

    name: str
    type: str
    default: str
    doc: str


KNOBS: tuple[Knob, ...] = (
    Knob(
        name="MOZART_DISABLE_ENGINE",
        type="bool",
        default="0",
        doc="set to 1 to restore the seed's scalar, uncached evaluation behavior exactly",
    ),
    Knob(
        name="MOZART_WORKERS",
        type="int",
        default="0",
        doc="per-network evaluation fan-out width (0 = serial)",
    ),
    Knob(
        name="MOZART_EXECUTOR",
        type="str",
        default="thread",
        doc="worker kind for the evaluation fan-out: `thread` or `process` (spawn-safe pool)",
    ),
    Knob(
        name="MOZART_WARMUP",
        type="bool",
        default="1",
        doc="set to 0 to disable the pre-fork shared option-cache warmup",
    ),
    Knob(
        name="MOZART_BATCH_SOLVE",
        type="bool",
        default="1",
        doc="set to 0 for the per-genome Layer-3 loop instead of the generation batch",
    ),
    Knob(
        name="MOZART_COMPACT_DECODE",
        type="bool",
        default="1",
        doc="set to 0 for the serving engine's full-width schedule emulation instead of "
        "the compacted sub-batch decode",
    ),
    Knob(
        name="MOZART_PAGED_KV",
        type="bool",
        default="1",
        doc="set to 0 for the dense per-slot KV rectangles instead of the block-paged "
        "KV pool + bucketed prefill (transformer family without SWA/MoE only)",
    ),
    Knob(
        name="MOZART_KV_PAGE_SIZE",
        type="int",
        default="16",
        doc="tokens per KV page in the paged serving cache (power of two)",
    ),
    Knob(
        name="MOZART_PREFILL_BUCKET_MIN",
        type="int",
        default="16",
        doc="smallest power-of-two prompt-length bucket padded prefills compile for",
    ),
    Knob(
        name="MOZART_KV_QUANT",
        type="str",
        default="0",
        doc="any truthy value stores paged KV pages as int8 with per-head scales "
        "(~4x slots per HBM byte, token-level parity); the value `dense` also "
        "covers non-paged plain-transformer engines",
    ),
    Knob(
        name="MOZART_ROUTER",
        type="str",
        default="round_robin",
        doc="cluster request-router policy: `round_robin`, `least_loaded` "
        "(most free KV pages), or `shortest_queue` (join-shortest-queue)",
    ),
    Knob(
        name="MOZART_REPLICAS",
        type="int",
        default="1",
        doc="serving-cluster replica count when the caller does not pass one "
        "(`serve --replicas` overrides)",
    ),
    Knob(
        name="MOZART_DEADLINE_SHED",
        type="bool",
        default="1",
        doc="set to 0 to disable admission-control shedding of requests that "
        "cannot meet their deadline (they decode to completion and miss it)",
    ),
    Knob(
        name="MOZART_DEADLINE_DEFAULT_MS",
        type="int",
        default="0",
        doc="per-request deadline (milliseconds) `serve` stamps on generated "
        "requests when no deadline band is given (0 = no deadline)",
    ),
    Knob(
        name="MOZART_QUEUE_BOUND",
        type="int",
        default="0",
        doc="per-replica queue depth bound; a full queue sheds new submissions "
        "(finish_reason=shed) instead of growing without bound (0 = unbounded)",
    ),
    Knob(
        name="MOZART_RETRY_BUDGET",
        type="int",
        default="3",
        doc="failovers a request survives before it is marked poison instead of "
        "requeued — a poison request cannot take down every replica in turn",
    ),
    Knob(
        name="MOZART_WATCHDOG_STALL_STEPS",
        type="int",
        default="50",
        doc="cluster steps a replica may hold work without emitting a token "
        "before the watchdog quarantines it as stalled",
    ),
    Knob(
        name="MOZART_WATCHDOG_NAN",
        type="bool",
        default="1",
        doc="set to 0 to disable the jitted NaN/Inf guard on decode logits "
        "(the watchdog quarantines a replica the step it emits non-finite logits)",
    ),
    Knob(
        name="MOZART_SPEC_K",
        type="int",
        default="4",
        doc="speculative-decode draft window: tokens the draft model proposes "
        "per verify step (`serve --scenario specdec` and bench_specdec)",
    ),
    Knob(
        name="MOZART_SCENARIO",
        type="str",
        default="",
        doc="serving scenario `serve` runs when `--scenario` is not given: "
        "empty = plain engine, `specdec` = in-engine speculative decoding",
    ),
    Knob(
        name="MOZART_CHAOS_SEED",
        type="int",
        default="0",
        doc="seed for `serving.resilience.ChaosSchedule.generate` when the "
        "caller does not pass one (`serve --chaos` and bench_chaos use it)",
    ),
)

_BY_NAME = {k.name: k for k in KNOBS}
_FALSY = ("0", "", "false", "no", "off")


def knob(name: str) -> Knob:
    """The registry entry for `name` (KeyError on unregistered knobs)."""
    return _BY_NAME[name]


def get_raw(name: str) -> str:
    """The raw env value, falling back to the registered default."""
    return os.environ.get(name, _BY_NAME[name].default)


def get_bool(name: str) -> bool:
    return get_raw(name).strip().lower() not in _FALSY


def get_int(name: str) -> int:
    k = _BY_NAME[name]
    try:
        return int(get_raw(name).strip() or k.default)
    except ValueError:
        return int(k.default)


def get_str(name: str) -> str:
    return get_raw(name)
