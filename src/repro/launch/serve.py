"""Serving launcher: continuous-batching engine (optionally with
speculative decoding) on synthetic requests, optionally driven by a
Mozart deployment artifact.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --smoke --requests 8 --max-new 16
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --smoke --specdec
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --smoke --policy deployment.json

`--policy` accepts either a `mozart.compile(...).save()` deployment
artifact or a bare `ExecutionPolicy.to_json` file and *applies* it:
fusion flags select the fused Pallas kernels (flash_attention ->
attn_impl="flash", fused_mlp -> mlp_impl="fused", fused_norm ->
norm_impl="fused"), the policy's batch split sets the engine's
max/decode batch (decode runs COMPACTED at decode_batch width), and the
TP degree builds the mesh the engine shards its params/cache/compute
over.

`--replicas N` (with `--router round_robin|least_loaded|shortest_queue`)
scales the SAME policy out as a serving cluster: the policy's mesh keeps
its "model" (TP) extent inside every replica while the replicas are laid
out along the mesh "data" axis (`parallel.sharding.replica_meshes`), so
`--policy X --replicas N` is the paper's fleet story — N copies of one
composed BASIC behind a router, each with its own paged KV pool.
`--rate R` drives the cluster open-loop at R req/s (Poisson, seeded)
instead of the closed-loop burst.

Resilience flags: `--deadline-ms D` stamps a D-millisecond SLO deadline
on every generated request (default: the `MOZART_DEADLINE_DEFAULT_MS`
knob; 0 = none) — the engines shed requests that cannot meet it
(`finish_reason="shed"`).  `--chaos` replays a seeded fault script
(`MOZART_CHAOS_SEED`; kill/restart/stall/nan events from
`serving.resilience.ChaosSchedule.generate`) against the cluster while
it serves, and the summary reports the shed / poisoned / quarantined /
unrouted counts next to goodput (deadline-met tokens).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.core.policy import ExecutionPolicy
from repro.launch import knobs
from repro.models import api, transformer
from repro.models.config import ModelConfig
from repro.serving.engine import Request, ServingEngine
from repro.serving.specdec import spec_decode_greedy


def apply_policy(pol: ExecutionPolicy, mcfg: ModelConfig,
                 max_batch: int, n_devices: int | None = None
                 ) -> tuple[ModelConfig, dict, list[str]]:
    """Lower an ExecutionPolicy onto the serving substrate.

    Returns (model config, ServingEngine kwargs, log lines).  Pure —
    no engine or mesh is constructed here — so the mapping is unit-
    testable without JAX compilation.
    """
    if n_devices is None:
        n_devices = len(jax.devices())
    lines: list[str] = []
    flags = pol.fusion_flags()

    applied = []
    # the fused kernel hooks live in the transformer family's
    # attention/mlp_block/apply_norm dispatch; every other combination
    # logs the ACTUAL unsupported reason instead of claiming application
    # (the engine serves all families now, so "engine is transformer-
    # only" is no longer the gate — the kernel dispatch is)
    if flags["flash_attention"]:
        if mcfg.family == "transformer":
            mcfg = mcfg.replace(attn_impl="flash")
            applied.append("flash_attention->attn_impl=flash")
        elif mcfg.family == "rglru":
            applied.append("flash_attention(no hook: rglru's interleaved "
                           "attention decodes through its ring-buffer "
                           "window path)")
        elif mcfg.family == "whisper":
            applied.append("flash_attention(no hook: whisper decoder "
                           "blocks interleave cross-attention over the "
                           "encoder window)")
        else:
            applied.append(f"flash_attention(no hook: {mcfg.family} has "
                           f"no softmax-attention operator)")
    if flags["fused_mlp"]:
        if mcfg.family == "transformer":
            mcfg = mcfg.replace(mlp_impl="fused")
            applied.append("fused_mlp->mlp_impl=fused")
        elif mcfg.family == "whisper":
            applied.append("fused_mlp(no hook: whisper cross-attn blocks "
                           "interleave the MLP with encoder reads)")
        else:
            applied.append(f"fused_mlp(no hook: {mcfg.family} uses gated "
                           f"recurrent channel mixing, not the plain MLP "
                           f"the fused kernel covers)")
    if flags["fused_norm"]:
        if mcfg.family == "transformer" and mcfg.norm == "rmsnorm":
            mcfg = mcfg.replace(norm_impl="fused")
            applied.append("fused_norm->norm_impl=fused")
        elif mcfg.family == "transformer":
            applied.append(f"fused_norm(no hook: norm={mcfg.norm}; the "
                           f"fused kernel implements rmsnorm only)")
        else:
            applied.append(f"fused_norm(no hook: {mcfg.family}'s norm "
                           f"dispatch has no fused path, norm="
                           f"{mcfg.norm})")
    lines.append(f"[serve] policy network={pol.network} "
                 f"fusion flags: flash_attention={flags['flash_attention']} "
                 f"fused_mlp={flags['fused_mlp']} "
                 f"fused_norm={flags['fused_norm']} "
                 f"applied=[{', '.join(applied) or 'none'}]")

    # Insight 2's batch split: batch-sensitive stages (projections) set
    # the engine-wide slot count, batch-agnostic stages (attention/scan)
    # bound the lock-step decode batch.  The CLI --max-batch stays a cap
    # (cache memory), the policy drives within it.
    sens, agn = pol.batch_sensitive_batch, pol.batch_agnostic_batch
    eng_batch = max(1, min(max_batch, sens))
    dec_batch = max(1, min(eng_batch, agn))
    lines.append(f"[serve] policy microbatch: max_batch {max_batch}->"
                 f"{eng_batch} (batch_sensitive_batch={sens}), "
                 f"decode_batch={dec_batch} (batch_agnostic_batch={agn})")
    if mcfg.family != "transformer":
        # recurrent / encoder-decoder families decode through the
        # ALWAYS-gathered DecodeState sub-batch, so the policy's
        # batch-agnostic split maps to the gathered lane width directly
        lines.append(f"[serve] policy microbatch: {mcfg.family} decodes "
                     f"gathered at width {dec_batch} (recurrent state is "
                     f"irreversible; no full-width emulation)")

    tp = pol.tp_degree
    if tp > 1 and n_devices % tp == 0 and n_devices >= tp:
        lines.append(f"[serve] policy tp={tp}: building mesh with model "
                     f"axis {tp} over {n_devices} device(s); engine "
                     f"params/cache/compute shard over it")
        mesh_tp = tp
    else:
        if tp > 1:
            lines.append(f"[serve] policy tp={tp}: only {n_devices} "
                         f"device(s), running unsharded (tp=1)")
        mesh_tp = 1
    kwargs = {"max_batch": eng_batch, "decode_batch": dec_batch}
    return mcfg, {**kwargs, "mesh_tp": mesh_tp}, lines


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--max-batch", type=int, default=4)
    p.add_argument("--max-len", type=int, default=128)
    p.add_argument("--specdec", action="store_true",
                   help="speculative decoding demo (draft = thinner config; "
                        "uncached reference loop — see --scenario specdec "
                        "for the live in-engine path)")
    p.add_argument("--k", type=int, default=None,
                   help="spec-decode draft window (default: the "
                        "MOZART_SPEC_K knob)")
    p.add_argument("--scenario", default=None, choices=("", "specdec"),
                   help="serving scenario (default: the MOZART_SCENARIO "
                        "knob): `specdec` serves through the live "
                        "SpecDecodeEngine (SpecDecodeScenario; draft = "
                        "shared-trunk layer truncation)")
    p.add_argument("--policy", default=None, metavar="DEPLOYMENT_JSON",
                   help="mozart deployment artifact (or bare policy JSON) "
                        "to apply: fusion flags, microbatches, TP")
    p.add_argument("--policy-network", default=None,
                   help="which network's policy to take from a "
                        "multi-network artifact")
    p.add_argument("--replicas", type=int, default=None,
                   help="serving-cluster replica count (default: the "
                        "MOZART_REPLICAS knob); >1 maps replicas onto "
                        "the mesh 'data' axis")
    p.add_argument("--router", default=None,
                   choices=("round_robin", "least_loaded",
                            "shortest_queue"),
                   help="cluster routing policy (default: the "
                        "MOZART_ROUTER knob)")
    p.add_argument("--rate", type=float, default=0.0,
                   help="open-loop Poisson arrival rate in req/s for "
                        "the cluster path (0 = closed-loop burst)")
    p.add_argument("--deadline-ms", type=float, default=None,
                   help="per-request SLO deadline in ms (default: the "
                        "MOZART_DEADLINE_DEFAULT_MS knob; 0 = none); "
                        "infeasible requests are shed at admission")
    p.add_argument("--chaos", action="store_true",
                   help="replay a seeded fault script (MOZART_CHAOS_SEED: "
                        "kill/restart/stall/nan) against the cluster "
                        "while it serves")
    args = p.parse_args()

    mcfg = configs.get_smoke_config(args.arch) if args.smoke \
        else configs.get_config(args.arch)

    eng_kwargs = {"max_batch": args.max_batch}
    if args.policy:
        from repro.mozart import load_policy
        pol = load_policy(args.policy, args.policy_network)
        mcfg, kw, lines = apply_policy(pol, mcfg, args.max_batch)
        for ln in lines:
            print(ln)
        mesh_tp = kw.pop("mesh_tp")
        if mesh_tp > 1:
            from repro.launch.mesh import make_host_mesh
            mesh = make_host_mesh(model_axis=mesh_tp)
            axes = dict(zip(mesh.axis_names, mesh.devices.shape))
            print(f"[serve] mesh built: {axes}; engine params/cache "
                  f"placed with parallel.sharding rules")
            kw["mesh"] = mesh
        eng_kwargs = kw

    params = api.init_params(mcfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    spec_k = args.k if args.k is not None else knobs.get_int("MOZART_SPEC_K")

    if args.specdec:
        if mcfg.family != "transformer":
            raise SystemExit("specdec demo targets transformer archs")
        dcfg = mcfg.replace(n_layers=max(1, mcfg.n_layers // 4))
        dparams = api.init_params(dcfg, jax.random.PRNGKey(1))
        # one-shot CLI demo: the jitted pair lives for exactly one
        # spec-decode run, so per-call construction cannot re-trace
        tf = jax.jit(lambda t: transformer.forward(mcfg, params, t))  # mzc: ignore[MZC013]
        df = jax.jit(lambda t: transformer.forward(dcfg, dparams, t))  # mzc: ignore[MZC013]
        prompt = rng.integers(0, mcfg.vocab, size=12).astype(np.int32)
        t0 = time.time()
        out, stats = spec_decode_greedy(tf, df, prompt, k=spec_k,
                                        max_new_tokens=args.max_new)
        dt = time.time() - t0
        print(f"[serve] specdec: {len(out)} tokens in {dt:.2f}s; "
              f"accept={stats.acceptance_rate:.2f} "
              f"tokens/iter={stats.tokens_per_iteration:.2f}")
        return

    scenario = args.scenario if args.scenario is not None \
        else knobs.get_str("MOZART_SCENARIO")
    if scenario == "specdec":
        from repro.core.scenarios import get_scenario
        from repro.serving.specdec import (SpecDecodeEngine,
                                           shared_trunk_draft)
        if mcfg.family != "transformer":
            raise SystemExit("--scenario specdec needs a transformer arch")
        sc = get_scenario("spec_decode")
        try:
            dcfg, dparams = shared_trunk_draft(
                mcfg, params, max(1, mcfg.n_layers // 4))
            draft_src = "shared-trunk"
        except ValueError:
            # scanned/multi-segment archs: fall back to a fresh-init
            # thin draft (acceptance will be whatever it is)
            dcfg = mcfg.replace(n_layers=max(1, mcfg.n_layers // 4))
            dparams = api.init_params(dcfg, jax.random.PRNGKey(1))
            draft_src = "fresh-init"
        eng = SpecDecodeEngine(mcfg, params, dcfg, dparams, k=spec_k,
                               max_len=args.max_len, **eng_kwargs)
        print(f"[serve] scenario={sc.name} (roles={sc.roles}): live "
              f"spec-decode, k={spec_k}, draft={draft_src} "
              f"{dcfg.n_layers}/{mcfg.n_layers} layers")
        for i in range(args.requests):
            plen = int(rng.integers(4, 12))
            eng.submit(Request(
                rid=i, prompt=rng.integers(0, mcfg.vocab,
                                           size=plen).astype(np.int32),
                max_new_tokens=args.max_new))
        t0 = time.time()
        eng.run()
        dt = time.time() - t0
        st = eng.spec_stats
        print(f"[serve] specdec-live: {eng.stats['tokens_out']} tokens in "
              f"{dt:.2f}s ({eng.stats['tokens_out'] / max(dt, 1e-9):.1f} "
              f"tok/s); accept={st.acceptance_rate:.2f} "
              f"tokens/iter={st.tokens_per_iteration:.2f} "
              f"({eng.stats['decode_steps']} verify steps)")
        return

    n_replicas = args.replicas or knobs.get_int("MOZART_REPLICAS")
    if n_replicas > 1:
        from repro.serving.cluster import LoadGenerator, ServingCluster
        from repro.serving.resilience import ChaosSchedule
        mesh = eng_kwargs.pop("mesh", None)
        deadline_ms = args.deadline_ms if args.deadline_ms is not None \
            else float(knobs.get_int("MOZART_DEADLINE_DEFAULT_MS"))
        deadline_bands = (((deadline_ms / 1e3, deadline_ms / 1e3),)
                          if deadline_ms > 0 else None)
        cl = ServingCluster(mcfg, params, n_replicas=n_replicas,
                            router=args.router, mesh=mesh,
                            max_len=args.max_len, **eng_kwargs)
        lg = LoadGenerator(n_requests=args.requests, rate=args.rate,
                           vocab=mcfg.vocab, seed=0,
                           max_new_tokens=args.max_new,
                           deadline_bands=deadline_bands)
        chaos = None
        if args.chaos:
            chaos = ChaosSchedule.generate(
                n_replicas=n_replicas,
                horizon=max(args.requests * args.max_new, 64))
            print(f"[serve] chaos script: "
                  f"{[(e.step, e.kind, e.replica) for e in chaos.events]}")
        t0 = time.time()
        summary = cl.drive(lg.schedule(), chaos=chaos)
        dt = time.time() - t0
        agg = summary["aggregate"]
        print(f"[serve] cluster x{n_replicas} router={cl.router.policy} "
              f"rate={args.rate:g}: {agg['tokens_out']} tokens in "
              f"{dt:.2f}s ({agg['tokens_out'] / max(dt, 1e-9):.1f} tok/s "
              f"aggregate), ttft p50/p99 "
              f"{agg['ttft_p50_ms']:.1f}/{agg['ttft_p99_ms']:.1f}ms, "
              f"tpot p50/p99 "
              f"{agg['tpot_p50_ms']:.2f}/{agg['tpot_p99_ms']:.2f}ms")
        print(f"[serve]   goodput {agg['goodput_tokens']} tokens "
              f"({agg['goodput_tokens'] / max(dt, 1e-9):.1f} tok/s), "
              f"deadlines met/missed "
              f"{agg['deadline_met']}/{agg['deadline_missed']}, "
              f"shed={agg['shed']} poisoned={agg['poisoned']} "
              f"quarantined={agg['quarantined']} "
              f"restarts={agg['restarts']} unrouted={agg['n_unrouted']}")
        for row in summary["per_replica"]:
            print(f"[serve]   replica {row['replica']}: "
                  f"{row['tokens_out']} tokens, {row['prefills']} "
                  f"prefills, {row['preemptions']} preemptions")
        return

    eng = ServingEngine(mcfg, params, max_len=args.max_len, **eng_kwargs)
    for i in range(args.requests):
        plen = int(rng.integers(4, 12))
        eng.submit(Request(
            rid=i, prompt=rng.integers(0, mcfg.vocab,
                                       size=plen).astype(np.int32),
            max_new_tokens=args.max_new))
    t0 = time.time()
    eng.run()
    dt = time.time() - t0
    occ = float(np.mean(eng.stats["slot_occupancy"])) \
        if eng.stats["slot_occupancy"] else 0.0
    print(f"[serve] {eng.stats['tokens_out']} tokens, "
          f"{eng.stats['decode_steps']} steps, "
          f"{eng.stats['prefills']} prefills in {dt:.2f}s "
          f"({eng.stats['tokens_out'] / max(dt, 1e-9):.1f} tok/s, "
          f"occupancy {occ:.2f})")


if __name__ == "__main__":
    main()
