"""Serving launcher: continuous-batching engine (optionally with
speculative decoding) on synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --smoke --requests 8 --max-new 16
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --smoke --specdec
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import api, transformer
from repro.serving.engine import Request, ServingEngine
from repro.serving.specdec import spec_decode_greedy


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--max-batch", type=int, default=4)
    p.add_argument("--max-len", type=int, default=128)
    p.add_argument("--specdec", action="store_true",
                   help="speculative decoding demo (draft = thinner config)")
    p.add_argument("--k", type=int, default=5)
    args = p.parse_args()

    mcfg = configs.get_smoke_config(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    params = api.init_params(mcfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    if args.specdec:
        if mcfg.family != "transformer":
            raise SystemExit("specdec demo targets transformer archs")
        dcfg = mcfg.replace(n_layers=max(1, mcfg.n_layers // 4))
        dparams = api.init_params(dcfg, jax.random.PRNGKey(1))
        tf = jax.jit(lambda t: transformer.forward(mcfg, params, t))
        df = jax.jit(lambda t: transformer.forward(dcfg, dparams, t))
        prompt = rng.integers(0, mcfg.vocab, size=12).astype(np.int32)
        t0 = time.time()
        out, stats = spec_decode_greedy(tf, df, prompt, k=args.k,
                                        max_new_tokens=args.max_new)
        dt = time.time() - t0
        print(f"[serve] specdec: {len(out)} tokens in {dt:.2f}s; "
              f"accept={stats.acceptance_rate:.2f} "
              f"tokens/iter={stats.tokens_per_iteration:.2f}")
        return

    eng = ServingEngine(mcfg, params, max_batch=args.max_batch,
                        max_len=args.max_len)
    for i in range(args.requests):
        plen = int(rng.integers(4, 12))
        eng.submit(Request(
            rid=i, prompt=rng.integers(0, mcfg.vocab,
                                       size=plen).astype(np.int32),
            max_new_tokens=args.max_new))
    t0 = time.time()
    eng.run()
    dt = time.time() - t0
    occ = float(np.mean(eng.stats["slot_occupancy"])) \
        if eng.stats["slot_occupancy"] else 0.0
    print(f"[serve] {eng.stats['tokens_out']} tokens, "
          f"{eng.stats['decode_steps']} steps, "
          f"{eng.stats['prefills']} prefills in {dt:.2f}s "
          f"({eng.stats['tokens_out'] / max(dt, 1e-9):.1f} tok/s, "
          f"occupancy {occ:.2f})")


if __name__ == "__main__":
    main()
