"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single-pod: 16x16 = 256 chips ("data",
"model").  Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model") — the
"pod" axis carries pure data parallelism across pods (DCN-class links),
"model" carries TP/EP within a pod (ICI).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model_axis: int | None = None):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    m = model_axis or (2 if n % 2 == 0 and n > 1 else 1)
    d = n // m
    return jax.make_mesh(
        (d, m), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
