"""Roofline analysis from compiled dry-run artifacts (§Roofline).

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

cost_analysis() and as_text() come from the SPMD-PARTITIONED module, so
flops / bytes / collective bytes are PER-DEVICE quantities; the roofline
terms below divide by per-chip peaks, which is algebraically identical to
the global form  term = global_qty / (chips * peak).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s/link (one direction)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:e[0-9a-z]+)?)\[([0-9,]*)\]")


def _shape_bytes(tok_dtype: str, dims: str) -> int:
    bt = _DTYPE_BYTES.get(tok_dtype)
    if bt is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * bt


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result bytes of every collective op in (per-device) HLO."""
    out: dict[str, float] = {k: 0.0 for k in COLLECTIVE_OPS}
    out["start_done_dedup"] = 0.0
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*(.+?)\s+(" + "|".join(COLLECTIVE_OPS)
                      + r")(-start|-done)?\(", line)
        if not m:
            continue
        result_part, op, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":        # avoid double counting start/done
            continue
        nbytes = sum(_shape_bytes(d, s)
                     for d, s in _SHAPE_RE.findall(result_part))
        out[op] += float(nbytes)
    out["total"] = sum(out[k] for k in COLLECTIVE_OPS)
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collectives: dict
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float
    model_flops_ratio: float          # useful / compiled compute
    arg_bytes_per_device: float = 0.0
    temp_bytes_per_device: float = 0.0
    out_bytes_per_device: float = 0.0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline_from_compiled(compiled, model_flops_global: float,
                           n_devices: int) -> Roofline:
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    colls = collective_bytes(compiled.as_text())
    cb = colls["total"]
    t_c = flops / PEAK_FLOPS
    t_m = nbytes / HBM_BW
    t_x = cb / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bott = max(terms, key=terms.get)
    mf_dev = model_flops_global / max(n_devices, 1)
    try:
        ma = compiled.memory_analysis()
        arg_b = float(ma.argument_size_in_bytes)
        tmp_b = float(ma.temp_size_in_bytes)
        out_b = float(ma.output_size_in_bytes)
    except Exception:
        arg_b = tmp_b = out_b = 0.0
    return Roofline(
        flops_per_device=flops, bytes_per_device=nbytes,
        collective_bytes_per_device=cb, collectives=colls,
        t_compute=t_c, t_memory=t_m, t_collective=t_x, bottleneck=bott,
        model_flops=model_flops_global,
        model_flops_ratio=(mf_dev / flops) if flops else 0.0,
        arg_bytes_per_device=arg_b, temp_bytes_per_device=tmp_b,
        out_bytes_per_device=out_b)


# --- MODEL_FLOPS ------------------------------------------------------------

def matmul_param_counts(params_shape: Any) -> tuple[float, float]:
    """(total, active) matmul-participating params.  MoE experts count
    `top_k/n_experts` toward active. Embedding tables excluded, LM head
    included (it is real matmul compute)."""
    import jax
    flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    total = active = 0.0
    for path, leaf in flat:
        keys = [getattr(p, "key", getattr(p, "idx", "")) for p in path]
        name = "/".join(str(k) for k in keys)
        if getattr(leaf, "ndim", 0) < 2:
            continue
        if name.endswith("embed") or "dec_pos" in name:
            continue
        n = 1.0
        for d in leaf.shape:
            n *= d
        total += n
        active += n          # corrected below for experts
    return total, active


def model_flops_for(cfg, shape, params_shape) -> float:
    """6*N*D (train) / 2*N*D (prefill) / 2*N_active*B (decode, per step),
    N = matmul params (active for MoE)."""
    import jax
    flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    total = 0.0
    expert_total = 0.0
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", "")))
                        for p in path)
        if getattr(leaf, "ndim", 0) < 2 or name.endswith("embed") \
                or "dec_pos" in name:
            continue
        n = 1.0
        for d in leaf.shape:
            n *= d
        total += n
        if "experts_" in name:
            expert_total += n
    active = total
    if cfg.use_moe and cfg.n_experts:
        active = total - expert_total * (1.0 - cfg.top_k / cfg.n_experts)
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        return 2.0 * active * tokens
    # decode: one token per sequence per step
    return 2.0 * active * shape.global_batch
