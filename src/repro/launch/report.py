"""Render §Dry-run / §Roofline markdown tables from the dry-run JSON
artifacts.

    PYTHONPATH=src python -m repro.launch.report [--mesh single]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from .analyze import HBM_BW, ICI_BW, PEAK_FLOPS

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")


def load(mesh: str | None = None, tag: str = "") -> list[dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(fn) as f:
            r = json.load(f)
        if (mesh is None or r.get("mesh") == mesh) \
                and r.get("tag", "") == tag:
            recs.append(r)
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    recs.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9),
                             r["mesh"]))
    return recs


def _fmt_t(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def _fmt_b(x: float) -> str:
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x / div:.2f}{unit}"
    return f"{x:.0f}B"


def roofline_table(mesh: str = "single") -> str:
    rows = ["| arch | shape | t_comp | t_mem | t_coll | bottleneck | "
            "roofline-frac | MF-ratio | HBM/dev |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in load(mesh):
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | FAIL "
                        f"{r.get('error', '')[:40]} | | | | | | |")
            continue
        rf = r["roofline"]
        t = (rf["t_compute"], rf["t_memory"], rf["t_collective"])
        dom = max(t)
        frac = rf["t_compute"] / dom if dom else 0.0
        ma = r.get("memory_analysis") or {}
        hbm = (ma.get("argument_size_in_bytes", 0)
               + ma.get("temp_size_in_bytes", 0))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_t(t[0])} "
            f"| {_fmt_t(t[1])} | {_fmt_t(t[2])} | {rf['bottleneck']} "
            f"| {frac:.2f} | {rf['model_flops_ratio']:.2f} "
            f"| {_fmt_b(hbm)} |")
    return "\n".join(rows)


def dryrun_table() -> str:
    rows = ["| arch | shape | mesh | ok | FLOPs/dev | bytes/dev | "
            "coll bytes/dev | args/dev | temps/dev | compile |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for r in load():
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                        f"| FAIL | | | | | | |")
            continue
        rf = r["roofline"]
        ma = r.get("memory_analysis") or {}
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | yes "
            f"| {rf['flops_per_device']:.3g} "
            f"| {_fmt_b(rf['bytes_per_device'])} "
            f"| {_fmt_b(rf['collective_bytes_per_device'])} "
            f"| {_fmt_b(ma.get('argument_size_in_bytes', 0))} "
            f"| {_fmt_b(ma.get('temp_size_in_bytes', 0))} "
            f"| {r.get('compile_s', 0):.0f}s |")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--table", choices=("roofline", "dryrun"),
                    default="roofline")
    args = ap.parse_args()
    if args.table == "roofline":
        print(roofline_table(args.mesh))
    else:
        print(dryrun_table())


if __name__ == "__main__":
    main()
