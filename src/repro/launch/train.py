"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --smoke --steps 200 --ckpt-dir /tmp/ckpt

--smoke uses the reduced same-family config (CPU-sized); otherwise the
full assigned config is used (real hardware).  The mesh is built from
whatever devices exist; on a TPU pod slice this is the production mesh.
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.training.loop import TrainConfig, train
from repro.training.optimizer import OptimizerConfig


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    p.add_argument("--smoke", action="store_true",
                   help="reduced config (CPU-sized)")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--optimizer", default="adamw",
                   choices=("adamw", "adafactor"))
    p.add_argument("--grad-compression", action="store_true")
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=100)
    p.add_argument("--fail-at-step", type=int, default=None,
                   help="inject a failure (restart drill)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    mcfg = configs.get_smoke_config(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    if mcfg.family == "whisper" or mcfg.frontend == "vision":
        raise SystemExit(
            f"{args.arch}: modality-stub archs train via input_specs-"
            "provided embeddings; use examples/ or the dry-run for them")
    ocfg = OptimizerConfig(name=args.optimizer, lr=args.lr,
                           warmup_steps=max(10, args.steps // 20),
                           total_steps=args.steps)
    tcfg = TrainConfig(steps=args.steps, microbatches=args.microbatches,
                       ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                       grad_compression=args.grad_compression,
                       seed=args.seed)
    dcfg = DataConfig(vocab=mcfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed)
    mesh = make_host_mesh() if len(jax.devices()) > 1 else None
    out = train(mcfg, ocfg, tcfg, dcfg, mesh=mesh,
                fail_at_step=args.fail_at_step)
    first, last = out["losses"][0][1], out["losses"][-1][1]
    print(f"[train] done: loss {first:.4f} -> {last:.4f} in "
          f"{out['wall_s']:.1f}s; stragglers={out['straggler_events']}")


if __name__ == "__main__":
    main()
