"""Flash attention Pallas TPU kernel (tensor fusion of QK^T, softmax, PV —
paper technique (2) applied at kernel granularity).

Grid: (batch*q_heads, num_q_blocks, num_kv_blocks) with the kv axis
"arbitrary" (sequential) — running max/denominator live in VMEM scratch
and the output block is finalized on the last kv step.  GQA is handled in
the K/V index_map (query head -> kv head) so grouped KV is never
materialized at H query heads.  Causal and sliding-window masks are
applied with block-level skipping (fully-masked kv blocks do no compute).

Block shapes default to (128, 128): MXU-aligned (multiples of 128 on the
matmul dims) and small enough that q/k/v/acc tiles fit VMEM at hd<=256.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 bq: int, bk: int, sk: int, causal: bool,
                 window: int | None, n_kv_blocks: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq
    k_start = ik * bk
    # Block-level skip: no valid (q, k) pair in this tile.
    relevant = jnp.asarray(True)
    if causal:
        relevant &= k_start <= q_start + bq - 1
    if window is not None:
        relevant &= k_start + bk - 1 > q_start - window

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bq, bk)
        s *= 1.0 / math.sqrt(q.shape[-1])
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < sk
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new[:, None]))
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         window: int | None = None, bq: int = 128,
                         bk: int = 128, interpret: bool = False):
    """q: (BH, Sq, hd); k/v: (BHkv, Sk, hd) with BH % BHkv == 0 (GQA).
    Returns (BH, Sq, hd)."""
    bh, sq, hd = q.shape
    bh_kv, sk, _ = k.shape
    group = bh // bh_kv
    bq = min(bq, sq)
    bk = min(bk, sk)
    pad_q = (-sq) % bq
    pad_k = (-sk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    nq = q.shape[1] // bq
    nk = k.shape[1] // bk

    kernel = functools.partial(
        _attn_kernel, bq=bq, bk=bk, sk=sk, causal=causal, window=window,
        n_kv_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, q.shape[1], hd), q.dtype),
        scratch_shapes=[
            _vmem((bq,), jnp.float32),      # running max
            _vmem((bq,), jnp.float32),      # running denominator
            _vmem((bq, hd), jnp.float32),   # output accumulator
        ],
        compiler_params=_tpu_params(),
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _tpu_params():
    try:
        from repro.kernels._compat import tpu_compiler_params
        return tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except Exception:
        return None


# ---------------------------------------------------------------------------
# Paged decode attention: one query token per sequence, KV behind a page
# table.  The page axis is the innermost (sequential) grid dim; each step
# the K/V index_maps dereference `tables[b, p]` — a scalar-prefetch
# lookup, so the DMA engine fetches exactly the pages the slot owns and
# the dense (B, C, ...) cache view is never materialized.
# ---------------------------------------------------------------------------


def _paged_decode_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref,
                         o_ref, m_ref, l_ref, acc_ref, *, ps: int,
                         n_pages_per_slot: int):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]

    # Block-level skip: pages wholly past the slot's live length hold
    # either stale KV or the null page — no compute, no mask fixups.
    @pl.when(p * ps < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (1, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (ps, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (1, ps)
        s *= 1.0 / math.sqrt(q.shape[-1])
        kpos = p * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        pr = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new[:, None]))
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + pr.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            pr, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(p == n_pages_per_slot - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention_hp(q, k_pages, v_pages, tables, lengths, *,
                              interpret: bool = False):
    """Single-token decode attention through a page table.

    q: (B, H, hd) — the current token's queries; k_pages/v_pages:
    (Hkv, P, ps, hd) page pools with H % Hkv == 0 (GQA); tables:
    (B, n_pages_per_slot) int32 physical page ids (0 = null page);
    lengths: (B,) int32 live tokens per slot — the query sits at
    position lengths[b]-1, so causality is just `kpos < length`.
    Returns (B, H, hd)."""
    from jax.experimental.pallas import tpu as pltpu

    bsz, h, hd = q.shape
    hkv, _, ps, _ = k_pages.shape
    npp = tables.shape[1]
    group = h // hkv

    kernel = functools.partial(_paged_decode_kernel, ps=ps,
                               n_pages_per_slot=npp)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bsz, h, npp),
        in_specs=[
            pl.BlockSpec((1, 1, hd),
                         lambda b, i, p, tbl, ln: (b, i, 0)),
            pl.BlockSpec((1, 1, ps, hd),
                         lambda b, i, p, tbl, ln, g=group:
                         (i // g, tbl[b, p], 0, 0)),
            pl.BlockSpec((1, 1, ps, hd),
                         lambda b, i, p, tbl, ln, g=group:
                         (i // g, tbl[b, p], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd),
                               lambda b, i, p, tbl, ln: (b, i, 0)),
        scratch_shapes=[
            _vmem((1,), jnp.float32),       # running max
            _vmem((1,), jnp.float32),       # running denominator
            _vmem((1, hd), jnp.float32),    # output accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, h, hd), q.dtype),
        compiler_params=_tpu_params(),
        interpret=interpret,
    )(tables, lengths, q, k_pages, v_pages)
