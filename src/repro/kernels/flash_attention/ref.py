"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int | None = None) -> jnp.ndarray:
    """q: (BH, Sq, hd); k/v: (BHkv, Sk, hd). GQA via head-group repeat."""
    bh, sq, hd = q.shape
    bh_kv, sk, _ = k.shape
    group = bh // bh_kv
    if group > 1:
        k = jnp.repeat(k, group, axis=0)
        v = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)) \
        .astype(q.dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, tables,
                               lengths) -> jnp.ndarray:
    """Oracle for the paged decode op: gather the page table into a dense
    cache view, then mask-and-softmax exactly like dense decode.
    q (B, 1, H, hd); k_pages/v_pages (P, ps, Hkv, hd);
    tables (B, npp) i32; lengths (B,) i32 (incl. the current token)."""
    b, _, h, hd = q.shape
    npp = tables.shape[1]
    ps = k_pages.shape[1]
    hkv = k_pages.shape[2]

    def dense(pages):                      # (B, npp*ps, Hkv, hd)
        g = jnp.take(pages, tables, axis=0)
        return g.reshape(b, npp * ps, hkv, hd)

    k, v = dense(k_pages), dense(v_pages)
    group = h // hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
    s = jnp.einsum("bqhd,bchd->bhqc", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    kpos = jnp.arange(npp * ps)[None, :]
    mask = kpos < lengths[:, None]
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqc,bchd->bqhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


# pre-PR-6 name, kept importable
attention_ref = flash_attention_ref
