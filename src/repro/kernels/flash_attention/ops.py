"""Jit'd public wrapper for the flash attention kernel.

Model-layout API: q (B, Sq, H, hd), k/v (B, Sk, Hkv, hd) — reshaped to the
kernel's (B*H, S, hd) layout.  On non-TPU backends this falls back to
interpret mode (the kernel body runs in Python on CPU) so the SAME code
path is exercised everywhere; on TPU it compiles via Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels._compat import on_tpu as _on_tpu

from .kernel import flash_attention_bhsd, paged_decode_attention_hp


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None, bq: int = 128, bk: int = 128,
                    interpret: bool | None = None) -> jnp.ndarray:
    b, sq, h, hd = q.shape
    _, sk, hkv, _ = k.shape
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, sk, hd)
    # (B*H) layout must group query heads of one kv head contiguously:
    # reorder q so head-major grouping matches kv: index = b*H + h where
    # heads h in [g*group, (g+1)*group) share kv head g.  transpose above
    # already yields exactly that layout.
    it = (not _on_tpu()) if interpret is None else interpret
    of = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                              bq=bq, bk=bk, interpret=it)
    return of.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pages, v_pages, tables, lengths, *,
                           interpret: bool | None = None) -> jnp.ndarray:
    """Paged single-token decode attention (vLLM-style): attend one query
    per sequence through a page table instead of a dense (B, C, ...)
    cache slab.

    Model-layout API matching the serving page pools: q (B, 1, H, hd) —
    the current token; k_pages/v_pages (P, ps, Hkv, hd) — one layer's
    page pool from `models.api.init_paged_cache` (page 0 reserved as the
    never-read null page); tables (B, n_pages_per_slot) int32 physical
    page ids; lengths (B,) int32 live tokens per slot INCLUDING the
    current token (whose k/v must already be scattered into the pages).
    Returns (B, 1, H, hd)."""
    b, _, h, hd = q.shape
    kp = k_pages.transpose(2, 0, 1, 3)   # (Hkv, P, ps, hd)
    vp = v_pages.transpose(2, 0, 1, 3)
    it = (not _on_tpu()) if interpret is None else interpret
    out = paged_decode_attention_hp(
        q[:, 0], kp, vp, tables.astype(jnp.int32),
        lengths.astype(jnp.int32), interpret=it)
    return out[:, None]
