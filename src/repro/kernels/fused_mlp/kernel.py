"""Fused dense gated-MLP (SwiGLU) Pallas TPU kernel.

The serving policy's ``fused_mlp`` flag maps onto this kernel: the whole
MLP block

    out = (silu(x @ wg) * (x @ wi)) @ wo        (swiglu)
    out = gelu(x @ wi) @ wo                     (plain gelu MLP)

runs as one kernel, so the (N, F) hidden activation never exists in HBM
(the paper's tensor-fusion technique applied to the projection hot path).

Grid: (token_blocks, ff_blocks); the ff axis is sequential and the
(bt, d) output tile accumulates in VMEM scratch.  This is the dense
single-expert sibling of the grouped ``moe_mlp`` kernel — dense serving
MLPs have no expert dim, so the grid drops to two axes and the weight
tiles are shared across all token blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import tpu_compiler_params


def _accumulate(o_ref, acc_ref, h, wo_ref, jf, n_ff_blocks):
    @pl.when(jf == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    wo = wo_ref[...].astype(jnp.float32)  # (bf, d)
    acc_ref[...] += jax.lax.dot_general(
        h, wo, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(jf == n_ff_blocks - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _swiglu_mlp_kernel(
    x_ref, wg_ref, wi_ref, wo_ref, o_ref, acc_ref, *, n_ff_blocks: int
):
    jf = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)  # (bt, d)
    wi = wi_ref[...].astype(jnp.float32)  # (d, bf)
    h = jax.lax.dot_general(
        x, wi, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    wg = wg_ref[...].astype(jnp.float32)
    g = jax.lax.dot_general(
        x, wg, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    h = (g * jax.nn.sigmoid(g)) * h  # silu(g) * h
    _accumulate(o_ref, acc_ref, h, wo_ref, jf, n_ff_blocks)


def _gelu_mlp_kernel(x_ref, wi_ref, wo_ref, o_ref, acc_ref, *, n_ff_blocks: int):
    # no gate: wg never enters VMEM, halving up-projection weight traffic
    jf = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)
    wi = wi_ref[...].astype(jnp.float32)
    h = jax.lax.dot_general(
        x, wi, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    h = jax.nn.gelu(h)
    _accumulate(o_ref, acc_ref, h, wo_ref, jf, n_ff_blocks)


def fused_mlp_pallas(
    x,
    wg,
    wi,
    wo,
    *,
    swiglu: bool = True,
    bt: int = 128,
    bf: int = 512,
    interpret: bool = False,
):
    """x: (N, d); wg/wi: (d, F); wo: (F, d).  Returns (N, d).  With
    swiglu=False the gate is skipped entirely (wg may be None)."""
    n, d = x.shape
    f = wi.shape[-1]
    bt = min(bt, n)
    bf = min(bf, f)
    pad_n = (-n) % bt
    pad_f = (-f) % bf
    if pad_n:
        x = jnp.pad(x, ((0, pad_n), (0, 0)))
    if pad_f:
        wi = jnp.pad(wi, ((0, 0), (0, pad_f)))
        wo = jnp.pad(wo, ((0, pad_f), (0, 0)))
        if swiglu:
            wg = jnp.pad(wg, ((0, 0), (0, pad_f)))
    nt, nf = x.shape[0] // bt, wi.shape[-1] // bf

    x_spec = pl.BlockSpec((bt, d), lambda it, jf: (it, 0))
    up_spec = pl.BlockSpec((d, bf), lambda it, jf: (0, jf))
    down_spec = pl.BlockSpec((bf, d), lambda it, jf: (jf, 0))
    if swiglu:
        kernel = functools.partial(_swiglu_mlp_kernel, n_ff_blocks=nf)
        operands = (x, wg, wi, wo)
        in_specs = [x_spec, up_spec, up_spec, down_spec]
    else:
        kernel = functools.partial(_gelu_mlp_kernel, n_ff_blocks=nf)
        operands = (x, wi, wo)
        in_specs = [x_spec, up_spec, down_spec]

    out = pl.pallas_call(
        kernel,
        grid=(nt, nf),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bt, d), lambda it, jf: (it, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, d), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*operands)
    return out[:n]
