"""Pure-jnp oracle for the fused dense gated-MLP kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_mlp_ref(x, wg, wi, wo, *, swiglu: bool = True):
    xf = x.astype(jnp.float32)
    h = xf @ wi.astype(jnp.float32)
    if swiglu:
        g = xf @ wg.astype(jnp.float32)
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return (h @ wo.astype(jnp.float32)).astype(x.dtype)
