"""Jit'd public wrapper for the fused dense gated-MLP kernel.

Model-layout API: x is (..., d) — leading dims are flattened into one
token axis for the kernel.  On non-TPU backends this falls back to
interpret mode (the kernel body runs in Python on CPU) so the SAME code
path is exercised everywhere; on TPU it compiles via Mosaic.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels._compat import on_tpu as _on_tpu

from .kernel import fused_mlp_pallas


@functools.partial(jax.jit, static_argnames=("swiglu", "bt", "bf", "interpret"))
def fused_mlp(
    x,
    wg,
    wi,
    wo,
    *,
    swiglu: bool = True,
    bt: int = 128,
    bf: int = 512,
    interpret: bool | None = None,
):
    """wg is only read when swiglu=True; pass None for plain GELU MLPs."""
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    it = (not _on_tpu()) if interpret is None else interpret
    out = fused_mlp_pallas(xf, wg, wi, wo, swiglu=swiglu, bt=bt, bf=bf, interpret=it)
    return out.reshape(*lead, x.shape[-1])
