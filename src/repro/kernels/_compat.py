"""JAX version compatibility for the Pallas TPU kernels.

The TPU compiler-params class was renamed `TPUCompilerParams` ->
`CompilerParams` across JAX releases; resolve whichever this JAX ships.
"""
from __future__ import annotations


def on_tpu() -> bool:
    """Shared backend probe: the ops wrappers default to interpret mode
    off-TPU so the same kernel bodies run everywhere."""
    import jax
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def tpu_compiler_params(**kwargs):
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams", None)
    if cls is None:     # pragma: no cover - very old / CPU-only pallas
        return None
    return cls(**kwargs)
