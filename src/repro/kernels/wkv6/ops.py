"""Jit'd wrapper for the WKV6 kernel with CPU interpret fallback."""
from __future__ import annotations

import functools

import jax

from .kernel import wkv6_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, logw, u, s0, *, chunk: int = 64,
         interpret: bool | None = None):
    it = (jax.default_backend() != "tpu") if interpret is None else interpret
    return wkv6_pallas(r, k, v, logw, u, s0, chunk=chunk, interpret=it)
