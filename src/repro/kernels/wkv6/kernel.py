"""RWKV6 (Finch) WKV recurrence as a chunked Pallas TPU kernel.

Per head (k-dim i, v-dim j), with data-dependent per-channel decay w_t:

    o_t[j]   = sum_i r_t[i] * (S_{t-1}[i,j] + u[i] k_t[i] v_t[j])
    S_t[i,j] = w_t[i] * S_{t-1}[i,j] + k_t[i] v_t[j]

Grid: (batch*heads, time_chunks); the chunk axis is sequential and the
(D, D) state matrix lives in VMEM scratch.  Within a chunk the recurrence
is evaluated in closed form (GLA-style): pairwise decay factors
exp(Lp[t]-L[s]) have non-positive exponents, so the chunked form is exact
and overflow-safe, and all FLOPs are MXU matmuls rather than a hidden
sequential loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import tpu_compiler_params


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, o_ref, s_ref,
                *, chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)          # (C, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)        # log decay, <= 0
    u = u_ref[0].astype(jnp.float32)          # (1, D) bonus
    state = s_ref[...]                        # (D, Dv)

    L = jnp.cumsum(lw, axis=0)                # inclusive
    Lp = L - lw                               # exclusive
    # inter-chunk
    o = jax.lax.dot_general(r * jnp.exp(Lp), state,
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # intra-chunk: A[t,s] = sum_i r[t,i] k[s,i] exp(Lp[t,i]-L[s,i]), s<t
    c = chunk
    P = jnp.exp(jnp.clip(Lp[:, None, :] - L[None, :, :], -60.0, 0.0))
    tmask = (jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
             > jax.lax.broadcasted_iota(jnp.int32, (c, c), 1))
    A = jnp.einsum("ti,si,tsi->ts", r, k, P,
                   preferred_element_type=jnp.float32)
    A = jnp.where(tmask, A, 0.0)
    diag = jnp.sum(r * u * k, axis=1)         # (C,)
    A = A + jnp.where(
        jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
        == jax.lax.broadcasted_iota(jnp.int32, (c, c), 1),
        diag[:, None], 0.0)
    o = o + jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[0, :, :] = o.astype(o_ref.dtype)
    # carry state
    decay_all = jnp.exp(L[-1])                # (D,)
    decay_tail = jnp.exp(jnp.clip(L[-1][None, :] - L, -60.0, 0.0))
    s_new = state * decay_all[:, None] + jax.lax.dot_general(
        (k * decay_tail), v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_ref[...] = s_new


def wkv6_pallas(r, k, v, logw, u, s0, *, chunk: int = 64,
                interpret: bool = False):
    """r/k/v/logw: (BH, S, D); u: (BH, 1, D); s0: (BH, D, Dv).
    Returns (o (BH, S, Dv), s_final is NOT returned — use ref for state).
    """
    bh, s, d = r.shape
    dv = v.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        z = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0)))
    nc = r.shape[1] // chunk

    out = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, d), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, d), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, d), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, d), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, d, dv), lambda b, c: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, r.shape[1], dv), r.dtype),
        scratch_shapes=[pltpu.VMEM((d, dv), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, logw, u, s0)
    return out[:, :s]
