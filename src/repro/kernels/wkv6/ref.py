"""Pure-jnp oracle for the WKV6 kernel (sequential recurrence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, logw, u, s0):
    """r/k/v/logw: (BH, S, D); u: (BH, 1, D); s0: (BH, D, Dv).
    Returns (o, s_final)."""
    w = jnp.exp(logw)

    def step(s, xs):
        rt, kt, vt, wt = xs                       # (BH, D)
        kv = jnp.einsum("bi,bj->bij", kt, vt)
        o = jnp.einsum("bi,bij->bj", rt, s + u[:, 0, :, None] * kv)
        s_new = wt[..., None] * s + kv
        return s_new, o

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    s, o = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(o, 0, 1).astype(r.dtype), s
