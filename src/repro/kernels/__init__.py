# Pallas TPU kernels for the perf-critical compute layers, each with a
# pl.pallas_call + BlockSpec kernel, a jit'd ops.py wrapper, and a
# pure-jnp ref.py oracle (validated in interpret mode on CPU):
#   flash_attention/ — fused QK^T-softmax-PV (tensor fusion, GQA, SWA)
#   rglru_scan/      — RG-LRU diagonal linear recurrence
#   wkv6/            — RWKV6 chunked WKV recurrence
#   moe_mlp/         — fused grouped expert-MLP (grouped GEMM + activation)
#   fused_mlp/       — fused dense gated-MLP (SwiGLU; serving fused_mlp flag)
#   fused_norm/      — fused RMSNorm(+residual) (serving fused_norm flag)
from . import (flash_attention, fused_mlp, fused_norm, moe_mlp, rglru_scan,
               wkv6)
