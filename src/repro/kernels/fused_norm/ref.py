"""Pure-jnp oracle for the fused RMSNorm(+residual) kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_rmsnorm_ref(x, scale, *, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def fused_rmsnorm_residual_ref(x, res, scale, *, eps: float = 1e-6):
    s = x + res
    return s, fused_rmsnorm_ref(s, scale, eps=eps)


# pre-PR-6 names, kept importable
rmsnorm_ref = fused_rmsnorm_ref
rmsnorm_residual_ref = fused_rmsnorm_residual_ref
