"""Jit'd public wrappers for the fused RMSNorm(+residual) kernel.

Model-layout API: x (and res) are (..., d) — leading dims are flattened
into one token axis for the kernel.  On non-TPU backends this falls back
to interpret mode (the kernel body runs in Python on CPU) so the SAME
code path is exercised everywhere; on TPU it compiles via Mosaic.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels._compat import on_tpu as _on_tpu

from .kernel import fused_rmsnorm_pallas, fused_rmsnorm_residual_pallas


@functools.partial(jax.jit, static_argnames=("eps", "bt", "interpret"))
def fused_rmsnorm(
    x, scale, *, eps: float = 1e-6, bt: int = 256, interpret: bool | None = None
):
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    it = (not _on_tpu()) if interpret is None else interpret
    out = fused_rmsnorm_pallas(xf, scale, eps=eps, bt=bt, interpret=it)
    return out.reshape(*lead, x.shape[-1])


@functools.partial(jax.jit, static_argnames=("eps", "bt", "interpret"))
def fused_rmsnorm_residual(
    x, res, scale, *, eps: float = 1e-6, bt: int = 256, interpret: bool | None = None
):
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    rf = res.reshape(-1, res.shape[-1])
    it = (not _on_tpu()) if interpret is None else interpret
    s, out = fused_rmsnorm_residual_pallas(
        xf, rf, scale, eps=eps, bt=bt, interpret=it
    )
    return s.reshape(*lead, x.shape[-1]), out.reshape(*lead, x.shape[-1])
