"""Fused RMSNorm(+residual) Pallas TPU kernel.

The serving policy's ``fused_norm`` flag maps onto this kernel: the
residual add and the RMSNorm that follows it run as ONE kernel, so the
summed residual stream makes a single HBM round-trip instead of three
(add out, norm in, norm out):

    s = x + res                 (residual variant only)
    y = rmsnorm(s) * (1 + scale)

Grid: (token_blocks,) — each step loads a (bt, d) row tile, reduces the
mean-of-squares on the VPU, and writes the normalized tile (plus the
summed stream for the residual variant).  Numerics follow
``models.common.rmsnorm``: the reduction and scaling happen in float32
and the result is cast back to the input dtype (agreement is to within
float32 rounding of the XLA-fused reference, ~1 ulp).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import tpu_compiler_params


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (bt, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    g = g_ref[...].astype(jnp.float32)  # (1, d)
    o_ref[...] = (y * (1.0 + g)).astype(o_ref.dtype)


def _rmsnorm_residual_kernel(x_ref, r_ref, g_ref, s_ref, o_ref, *, eps: float):
    s = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    # the unfused reference adds in model dtype and norms the ROUNDED sum;
    # round-trip through the output dtype so numerics match it exactly
    s_out = s.astype(s_ref.dtype)
    s_ref[...] = s_out
    sf = s_out.astype(jnp.float32)
    var = jnp.mean(jnp.square(sf), axis=-1, keepdims=True)
    y = sf * jax.lax.rsqrt(var + eps)
    g = g_ref[...].astype(jnp.float32)
    o_ref[...] = (y * (1.0 + g)).astype(o_ref.dtype)


def fused_rmsnorm_pallas(
    x, scale, *, eps: float = 1e-6, bt: int = 256, interpret: bool = False
):
    """x: (N, d); scale: (d,).  Returns rmsnorm(x) * (1 + scale)."""
    n, d = x.shape
    bt = min(bt, n)
    pad_n = (-n) % bt
    if pad_n:
        x = jnp.pad(x, ((0, pad_n), (0, 0)))
    nt = x.shape[0] // bt
    g = scale.reshape(1, d)

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda it: (it, 0)),
            pl.BlockSpec((1, d), lambda it: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda it: (it, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], d), x.dtype),
        compiler_params=tpu_compiler_params(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, g)
    return out[:n]


def fused_rmsnorm_residual_pallas(
    x, res, scale, *, eps: float = 1e-6, bt: int = 256, interpret: bool = False
):
    """x/res: (N, d); scale: (d,).  Returns (x + res,
    rmsnorm(x + res) * (1 + scale)) in one pass."""
    n, d = x.shape
    bt = min(bt, n)
    pad_n = (-n) % bt
    if pad_n:
        x = jnp.pad(x, ((0, pad_n), (0, 0)))
        res = jnp.pad(res, ((0, pad_n), (0, 0)))
    nt = x.shape[0] // bt
    g = scale.reshape(1, d)

    s, out = pl.pallas_call(
        functools.partial(_rmsnorm_residual_kernel, eps=eps),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda it: (it, 0)),
            pl.BlockSpec((bt, d), lambda it: (it, 0)),
            pl.BlockSpec((1, d), lambda it: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt, d), lambda it: (it, 0)),
            pl.BlockSpec((bt, d), lambda it: (it, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((x.shape[0], d), x.dtype),
            jax.ShapeDtypeStruct((x.shape[0], d), x.dtype),
        ],
        compiler_params=tpu_compiler_params(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, res, g)
    return s[:n], out[:n]
