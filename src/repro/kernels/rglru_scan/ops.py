"""Jit'd wrapper for the RG-LRU scan kernel with CPU interpret fallback."""
from __future__ import annotations

import functools

import jax

from .kernel import rglru_scan_pallas


@functools.partial(jax.jit, static_argnames=("bs", "bw", "interpret"))
def rglru_scan(a, b, h0, *, bs: int = 256, bw: int = 128,
               interpret: bool | None = None):
    it = (jax.default_backend() != "tpu") if interpret is None else interpret
    return rglru_scan_pallas(a, b, h0, bs=bs, bw=bw, interpret=it)
