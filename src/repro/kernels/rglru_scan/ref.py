"""Pure-jnp oracle for the RG-LRU scan kernel (sequential recurrence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a, b, h0):
    """h_t = a_t * h_{t-1} + b_t; a/b: (B,S,W), h0: (B,W) -> (B,S,W)."""
    def step(h, xs):
        at, bt = xs
        h = at * h + bt
        return h, h
    xs = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0))
    _, h = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(h, 0, 1).astype(a.dtype)
