"""RG-LRU diagonal linear recurrence as a Pallas TPU kernel.

    h_t = a_t * h_{t-1} + b_t        (elementwise over the channel dim)

Grid: (batch, channel_blocks, time_blocks); the time axis is sequential
("arbitrary") and the running hidden state lives in VMEM scratch, so HBM
traffic is exactly one read of (a, b) and one write of h — the recurrence
itself never round-trips.  Channel blocks are 128-lane aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import tpu_compiler_params


def _rglru_kernel(h0_ref, a_ref, b_ref, o_ref, h_ref, *, bs: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a_ref[0, t, :].astype(jnp.float32) * h \
            + b_ref[0, t, :].astype(jnp.float32)
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bs, step, h_ref[...])
    h_ref[...] = h


def rglru_scan_pallas(a, b, h0, *, bs: int = 256, bw: int = 128,
                      interpret: bool = False):
    """a, b: (B, S, W); h0: (B, W). Returns h: (B, S, W)."""
    bsz, s, w = a.shape
    bs = min(bs, s)
    bw = min(bw, w)
    pad_s = (-s) % bs
    pad_w = (-w) % bw
    if pad_s or pad_w:
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, pad_w)))
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, pad_w)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_w)))
    ns, nw = a.shape[1] // bs, a.shape[2] // bw

    out = pl.pallas_call(
        functools.partial(_rglru_kernel, bs=bs),
        grid=(bsz, nw, ns),
        in_specs=[
            pl.BlockSpec((1, bw), lambda ib, iw, it: (ib, iw)),
            pl.BlockSpec((1, bs, bw), lambda ib, iw, it: (ib, it, iw)),
            pl.BlockSpec((1, bs, bw), lambda ib, iw, it: (ib, it, iw)),
        ],
        out_specs=pl.BlockSpec((1, bs, bw), lambda ib, iw, it: (ib, it, iw)),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(h0, a, b)
    return out[:, :s, :w]
