"""Fused grouped expert-MLP Pallas TPU kernel (grouped GEMM + activation
fusion — the paper's tensor-fusion technique on the MoE hot path).

Computes, per expert e over its capacity buffer:

    out[e] = (silu(x[e] @ wg[e]) * (x[e] @ wi[e])) @ wo[e]

Grid: (experts, token_blocks, ff_blocks); the ff axis is sequential and
the (bt, d) output tile accumulates in VMEM scratch — the (C, F) hidden
activation never exists in HBM.  This is simultaneously the grouped-GEMM
kernel: expert weight tiles are selected by the grid's expert index, so
one kernel serves both dense MLP (E=1) and MoE (E>1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import tpu_compiler_params


def _moe_mlp_kernel(x_ref, wg_ref, wi_ref, wo_ref, o_ref, acc_ref, *,
                    n_ff_blocks: int, swiglu: bool):
    jf = pl.program_id(2)

    @pl.when(jf == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)                  # (bt, d)
    wi = wi_ref[0].astype(jnp.float32)                # (d, bf)
    h = jax.lax.dot_general(x, wi, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if swiglu:
        wg = wg_ref[0].astype(jnp.float32)
        g = jax.lax.dot_general(x, wg, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        h = (g * jax.nn.sigmoid(g)) * h               # silu(g) * h
    else:
        h = jax.nn.gelu(h)
    wo = wo_ref[0].astype(jnp.float32)                # (bf, d)
    acc_ref[...] += jax.lax.dot_general(h, wo, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)

    @pl.when(jf == n_ff_blocks - 1)
    def _finalize():
        o_ref[0, :, :] = acc_ref[...].astype(o_ref.dtype)


def moe_mlp_pallas(x, wg, wi, wo, *, swiglu: bool = True, bt: int = 128,
                   bf: int = 512, interpret: bool = False):
    """x: (E, C, d); wg/wi: (E, d, F); wo: (E, F, d). Returns (E, C, d)."""
    e, c, d = x.shape
    f = wi.shape[-1]
    bt = min(bt, c)
    bf = min(bf, f)
    pad_c = (-c) % bt
    pad_f = (-f) % bf
    if pad_c:
        x = jnp.pad(x, ((0, 0), (0, pad_c), (0, 0)))
    if pad_f:
        wi = jnp.pad(wi, ((0, 0), (0, 0), (0, pad_f)))
        wg = jnp.pad(wg, ((0, 0), (0, 0), (0, pad_f)))
        wo = jnp.pad(wo, ((0, 0), (0, pad_f), (0, 0)))
    nt, nf = x.shape[1] // bt, wi.shape[-1] // bf

    out = pl.pallas_call(
        functools.partial(_moe_mlp_kernel, n_ff_blocks=nf, swiglu=swiglu),
        grid=(e, nt, nf),
        in_specs=[
            pl.BlockSpec((1, bt, d), lambda ie, it, jf: (ie, it, 0)),
            pl.BlockSpec((1, d, bf), lambda ie, it, jf: (ie, 0, jf)),
            pl.BlockSpec((1, d, bf), lambda ie, it, jf: (ie, 0, jf)),
            pl.BlockSpec((1, bf, d), lambda ie, it, jf: (ie, jf, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, d), lambda ie, it, jf: (ie, it, 0)),
        out_shape=jax.ShapeDtypeStruct((e, x.shape[1], d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, d), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, wg, wi, wo)
    return out[:, :c]
