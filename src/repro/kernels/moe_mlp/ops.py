"""Jit'd wrapper for the fused grouped expert-MLP kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import moe_mlp_pallas


@functools.partial(jax.jit, static_argnames=("swiglu", "bt", "bf",
                                             "interpret"))
def moe_mlp(x, wg, wi, wo, *, swiglu: bool = True, bt: int = 128,
            bf: int = 512, interpret: bool | None = None):
    it = (jax.default_backend() != "tpu") if interpret is None else interpret
    return moe_mlp_pallas(x, wg, wi, wo, swiglu=swiglu, bt=bt, bf=bf,
                          interpret=it)
