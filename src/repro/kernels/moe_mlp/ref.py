"""Pure-jnp oracle for the fused grouped expert-MLP kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_mlp_ref(x, wg, wi, wo, *, swiglu: bool = True):
    xf = x.astype(jnp.float32)
    h = jnp.einsum("ecd,edf->ecf", xf, wi.astype(jnp.float32))
    if swiglu:
        g = jnp.einsum("ecd,edf->ecf", xf, wg.astype(jnp.float32))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h,
                      wo.astype(jnp.float32)).astype(x.dtype)
