"""SLO-aware resilience primitives for the serving cluster.

The paper's two deployment stories are exactly the settings where
failures and overload are the norm: datacenter LLM serving (fig10's
energy numbers assume sustained traffic through a fleet) and AV
perception under hard per-request deadlines (fig12).  A throughput
number measured on a cluster that crashes on total outage, never sheds,
and cannot detect a wedged or NaN-emitting replica is not a number you
can trust under churn.  This module holds the pieces
`serving.cluster.ServingCluster` threads through its step loop:

* **NaN/Inf guard** — `logits_finite` is a cheap jitted all-finite
  reduction the engine runs on every decode's logits BEFORE sampling, so
  a corrupted KV page (HBM bit flip, bad kernel) can never leak garbage
  tokens into a request's stream: the engine raises its
  ``health["nan_detected"]`` flag and emits nothing, and the cluster
  watchdog quarantines the replica that same step.
* **`Watchdog`** — per-replica liveness tracking: a replica that holds
  work (queued or in-flight requests) but has not emitted a token for
  `stall_steps` cluster steps is quarantined exactly like
  `kill_replica` (token-exact requeue of everything it held), as is a
  replica whose engine flagged non-finite logits.
* **`ChaosSchedule`** — a seeded, deterministic fault script
  (kill / restart / stall / unstall / nan events at fixed step offsets)
  the chaos benchmark replays against a live cluster; `generate` draws a
  schedule from a seed, or build one from explicit `ChaosEvent`s.
* **`inject_nan`** — the nan event's implementation: poisons one live
  KV page (scales for int8 pools, the dense slot slab otherwise) so the
  next decode over it produces non-finite logits — a transient data
  corruption the guard + requeue path must recover from token-exactly.
* **goodput** — `goodput_tokens` counts only tokens of requests that
  finished within their deadline (no deadline = always counted); tokens
  of deadline-missing, shed, poison, or rejected requests are NOT
  goodput, which is what the chaos gate holds above a fraction of the
  fault-free run.

Everything here is host-side and duck-typed against the engine/cluster
(no imports from them), so `engine.py` and `cluster.py` can both import
this module without cycles.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import knobs

# one executable per logits shape (decode width is fixed in steady
# state), reused across engines via the module-level jit cache
_ALL_FINITE = jax.jit(lambda x: jnp.isfinite(x).all())


def logits_finite(logits) -> bool:
    """True iff every logit is finite — the decode-output health guard.
    Jitted scalar reduction: the host syncs on one bool, not the array."""
    return bool(_ALL_FINITE(logits))


def goodput_tokens(reqs) -> int:
    """Tokens of requests that completed WITHIN their deadline.

    Shed / poison / rejected requests contribute nothing, and neither
    does a request that finished past its deadline — late tokens are
    wasted work, not goodput.  Requests without a deadline count fully.
    """
    total = 0
    for r in reqs:
        if r.t_done is None or r.finish_reason in ("shed", "poison", "rejected"):
            continue
        if r.deadline_s is not None and (r.t_done - r.t_submit) > r.deadline_s:
            continue
        total += len(r.out_tokens)
    return total


def goodput_violations(reqs) -> int:
    """Requests whose tokens `goodput_tokens` would count despite having
    missed their deadline — an independent recount the chaos gate pins
    at zero (a nonzero value means the goodput accounting is broken)."""
    bad = 0
    for r in reqs:
        if r.t_done is None or r.finish_reason in ("shed", "poison", "rejected"):
            continue
        if r.deadline_s is None:
            continue
        counted = (r.t_done - r.t_submit) <= r.deadline_s
        missed = (r.t_done - r.t_submit) > r.deadline_s
        if counted and missed:
            bad += 1
    return bad


class Watchdog:
    """Detects replicas that hold work but make no progress.

    `check` is called once per cluster step per healthy replica and
    returns a quarantine reason ("nan" / "stall") or None.  Progress is
    token emission: a replica with queued or in-flight requests whose
    `tokens_out` counter has not moved for `stall_steps` consecutive
    checks is stalled (covers wedged hosts, livelocked admission, and
    chaos-injected stalls alike).  An engine whose decode emitted
    non-finite logits flags itself; the watchdog surfaces that flag the
    same step so no further decodes run on the sick replica.
    """

    def __init__(
        self, n_replicas: int, *, stall_steps: int | None = None, nan_check: bool | None = None
    ):
        self.stall_steps = (
            stall_steps
            if stall_steps is not None
            else knobs.get_int("MOZART_WATCHDOG_STALL_STEPS")
        )
        self.nan_check = (
            nan_check if nan_check is not None else knobs.get_bool("MOZART_WATCHDOG_NAN")
        )
        self._last_tokens = [0] * n_replicas
        self._idle = [0] * n_replicas
        self.events: list[tuple[int, int, str]] = []  # (step, replica, reason)

    def reset(self, i: int) -> None:
        """Forget replica `i`'s history (call after a restart rebuilds
        its engine — the fresh engine's counters start at zero)."""
        self._last_tokens[i] = 0
        self._idle[i] = 0

    def check(self, i: int, eng) -> str | None:
        if self.nan_check and eng.health.get("nan_detected"):
            return "nan"
        tokens = eng.stats["tokens_out"]
        has_work = bool(eng.queue) or any(s is not None for s in eng.slots)
        if not has_work or tokens > self._last_tokens[i]:
            self._last_tokens[i] = tokens
            self._idle[i] = 0
            return None
        self._idle[i] += 1
        if self._idle[i] >= self.stall_steps:
            return "stall"
        return None


def inject_nan(eng) -> bool:
    """Poison one live KV page of `eng` (transient-corruption chaos).

    Targets the first page owned by the first live slot so the very next
    decode over that slot attends through NaN and produces non-finite
    logits.  Int8 pools cannot hold a NaN, so their per-page SCALES are
    poisoned instead (the dequantized gather then carries the NaN).
    Returns False (no-op) when the engine holds no live slot to poison.
    """
    live = [b for b, r in enumerate(eng.slots) if r is not None]
    if not live:
        return False
    if eng.paged:
        pages = eng.pool.owned(live[0])
        if not pages:
            return False
        p = pages[0]
        if eng.pool.quant:
            eng.pool.scales = jax.tree.map(lambda s: s.at[:, p].set(jnp.nan), eng.pool.scales)
        else:
            eng.pool.segments = jax.tree.map(lambda a: a.at[:, p].set(jnp.nan), eng.pool.segments)
    else:
        b = live[0]
        state = getattr(eng, "state", None)
        if state is not None and getattr(state, "quantized", False):
            # int8 rectangles: poison the slot's scales, like quant pools
            state.scales = jax.tree.map(lambda s: s.at[:, b].set(jnp.nan), state.scales)
        elif "segments" in eng.cache:
            # transformer dense rectangles: leaves are (L, B, C, ...)
            eng.cache["segments"] = jax.tree.map(
                lambda a: a.at[:, b].set(jnp.nan) if a.ndim >= 2 else a, eng.cache["segments"]
            )
        else:
            # recurrent / cross-attn layers layout: batch on axis 0
            eng.cache["layers"] = jax.tree.map(
                lambda a: a.at[b].set(jnp.nan) if a.ndim >= 1 else a, eng.cache["layers"]
            )
    return True


CHAOS_KINDS = ("kill", "restart", "stall", "unstall", "nan")


@dataclasses.dataclass(frozen=True, order=True)
class ChaosEvent:
    """One scripted fault: at cluster step `step`, do `kind` to
    `replica`.  Ordering is (step, replica, kind) so a schedule sorts
    deterministically."""

    step: int
    replica: int
    kind: str

    def __post_init__(self):
        if self.kind not in CHAOS_KINDS:
            raise ValueError(f"unknown chaos kind {self.kind!r}; pick one of {CHAOS_KINDS}")


class ChaosSchedule:
    """A deterministic fault script replayed against a live cluster.

    `apply(cluster, step)` fires every event whose step offset has come
    due (events are keyed to `cluster.stats['steps']`, not wall clock,
    so a fixed schedule reproduces exactly regardless of host speed).
    Build one from explicit events, or `generate` a seeded random script
    — same seed, same events, every time.
    """

    def __init__(self, events):
        self.events: list[ChaosEvent] = sorted(events)
        self._i = 0
        self.fired: list[tuple[int, ChaosEvent]] = []

    @property
    def pending(self) -> bool:
        return self._i < len(self.events)

    def apply(self, cluster, step: int) -> list[ChaosEvent]:
        """Fire all events due at or before `step`; returns them."""
        fired: list[ChaosEvent] = []
        while self._i < len(self.events) and self.events[self._i].step <= step:
            ev = self.events[self._i]
            self._i += 1
            if ev.kind == "kill":
                cluster.kill_replica(ev.replica)
            elif ev.kind == "restart":
                cluster.restart_replica(ev.replica)
            elif ev.kind == "stall":
                cluster.stall_replica(ev.replica)
            elif ev.kind == "unstall":
                cluster.unstall_replica(ev.replica)
            elif ev.kind == "nan":
                inject_nan(cluster.replicas[ev.replica])
            self.fired.append((step, ev))
            fired.append(ev)
        return fired

    @classmethod
    def generate(
        cls,
        seed: int | None = None,
        *,
        n_replicas: int,
        horizon: int,
        kills: int = 1,
        stalls: int = 1,
        nans: int = 1,
        restart_after: int = 12,
    ) -> "ChaosSchedule":
        """Seeded random fault script over `horizon` cluster steps.

        Each kill and stall is paired with a recovery (`restart` /
        `unstall`) `restart_after` steps later, and at least one replica
        is always left untouched per event so the schedule alone cannot
        wedge the whole fleet (total outage is a deliberate drill, not a
        dice roll).  One rng drives every draw: the seed pins the script.
        """
        rng = np.random.default_rng(knobs.get_int("MOZART_CHAOS_SEED") if seed is None else seed)
        events: list[ChaosEvent] = []
        span = max(horizon - restart_after - 1, 1)
        for kind, reco, n in (("kill", "restart", kills), ("stall", "unstall", stalls)):
            for _ in range(n):
                step = int(rng.integers(1, span + 1))
                replica = int(rng.integers(0, max(n_replicas - 1, 1)))
                events.append(ChaosEvent(step, replica, kind))
                events.append(ChaosEvent(step + restart_after, replica, reco))
        for _ in range(nans):
            step = int(rng.integers(1, span + 1))
            replica = int(rng.integers(0, max(n_replicas - 1, 1)))
            events.append(ChaosEvent(step, replica, "nan"))
            # the watchdog quarantines the poisoned replica; schedule
            # its recovery so the script converges back to full health
            events.append(ChaosEvent(step + restart_after, replica, "restart"))
        return cls(events)
