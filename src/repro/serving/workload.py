"""Seeded, importable serving workloads.

One deterministic implementation of the request mixes the serving
benchmarks and the cluster load generator both draw from, so a fixed
seed produces the identical request trace whether it is replayed
closed-loop through `benchmarks/bench_serving.py` or open-loop through
`serving.cluster.LoadGenerator`:

* `zipf_mix_requests` — the Zipf-weighted short/medium/long prompt mix
  (band i is drawn with weight 1/(i+1)): short prompts dominate, but the
  tail crosses every power-of-two prefill-bucket boundary, so the mix
  exercises each bucketed-prefill executable.
* `poisson_arrivals` — open-loop Poisson arrival offsets (exponential
  inter-arrival gaps at a fixed rate), independent of service times, the
  arrival process the paper's datacenter serving story (fig10/table2)
  assumes when it sizes fleets for heavy traffic.

Both take a caller-owned `numpy.random.Generator`: the caller seeds it,
and the draw ORDER here is part of the contract — reordering the calls
would silently change every fixed-seed benchmark baseline.
"""

from __future__ import annotations

import numpy as np

from .engine import Request

# short/medium/long prompt-length bands spanning the 16/32/64 prefill
# buckets of a max_len=64 engine (the benchmarks' default geometry)
DEFAULT_BANDS: tuple[tuple[int, int], ...] = ((4, 15), (17, 31), (33, 60))


def zipf_band_weights(n_bands: int) -> np.ndarray:
    """Normalized Zipf weights 1/(i+1) over `n_bands` length bands."""
    w = 1.0 / (1.0 + np.arange(n_bands, dtype=np.float64))
    return w / w.sum()


# an SLO mix in the fig12 spirit: most traffic is best-effort (None),
# a band of interactive requests carries tight-ish deadlines, a band of
# batch requests carries loose ones.  Seconds; None = no deadline.
DEFAULT_DEADLINE_BANDS: tuple[tuple[float, float] | None, ...] = (
    None,
    (0.5, 2.0),
    (10.0, 30.0),
)


def zipf_mix_requests(
    rng: np.random.Generator,
    n: int,
    vocab: int,
    *,
    bands: tuple[tuple[int, int], ...] = DEFAULT_BANDS,
    max_new_tokens: int = 16,
    rid0: int = 0,
    deadline_bands: tuple[tuple[float, float] | None, ...] | None = None,
    model: str | None = None,
) -> list[Request]:
    """`n` requests with Zipf-weighted prompt lengths over `bands`.

    Draw order per request: band choice, prompt length, prompt tokens —
    fixed, so a seeded `rng` reproduces the exact trace everywhere.
    `deadline_bands` (e.g. `DEFAULT_DEADLINE_BANDS`) adds a per-request
    SLO mix: a uniformly chosen band, then a uniform `deadline_s` inside
    it (`None` bands mean no deadline).  Deadlines draw from a SPAWNED
    child generator, never from `rng`'s own stream, so attaching an SLO
    mix leaves the prompt trace (and any draws the caller makes from
    `rng` afterwards, e.g. Poisson arrivals) byte-for-byte unchanged —
    and `deadline_bands=None` is the exact historical trace.
    `model` stamps every request's routing tag for mixed-family fleets
    (host-side metadata: the token trace is untouched).
    """
    weights = zipf_band_weights(len(bands))
    dl_rng = rng.spawn(1)[0] if deadline_bands is not None else None
    reqs = []
    for i in range(n):
        lo, hi = bands[int(rng.choice(len(bands), p=weights))]
        deadline = None
        prompt = rng.integers(0, vocab, size=int(rng.integers(lo, hi + 1))).astype(
            np.int32
        )
        if dl_rng is not None:
            band = deadline_bands[int(dl_rng.integers(0, len(deadline_bands)))]
            if band is not None:
                deadline = float(dl_rng.uniform(band[0], band[1]))
        reqs.append(
            Request(
                rid=rid0 + i,
                prompt=prompt,
                max_new_tokens=max_new_tokens,
                deadline_s=deadline,
                model=model,
            )
        )
    return reqs


def synthetic_frames(
    rng: np.random.Generator, n_frames: int, d_model: int
) -> np.ndarray:
    """A (n_frames, d_model) float32 block of standard-normal encoder
    frame embeddings — the whisper requests' `Request.frames` payload
    (the serving layer pads/truncates it to the engine's fixed window).
    Drawn from the caller's `rng` so a seed pins the audio trace just
    like the token traces."""
    return rng.standard_normal((n_frames, d_model)).astype(np.float32)


def interleave_tagged(traces: list[list[Request]]) -> list[Request]:
    """Round-robin merge of per-model request traces into one submission
    order (trace i's requests keep their relative order), re-numbering
    `rid` so the merged trace has unique ids.  The deterministic mixer
    the mixed-family cluster benchmarks and tests submit."""
    merged: list[Request] = []
    cursors = [0] * len(traces)
    while any(c < len(t) for c, t in zip(cursors, traces)):
        for j, t in enumerate(traces):
            if cursors[j] < len(t):
                merged.append(t[cursors[j]])
                cursors[j] += 1
    for i, r in enumerate(merged):
        r.rid = i
    return merged


def poisson_arrivals(rng: np.random.Generator, n: int, rate: float) -> np.ndarray:
    """`n` open-loop arrival offsets (seconds from t0) of a Poisson
    process at `rate` requests/second: cumulative exponential gaps.
    `rate <= 0` means all-at-once (a closed-loop burst at t=0)."""
    if rate <= 0.0:
        return np.zeros(n, np.float64)
    gaps = rng.exponential(scale=1.0 / rate, size=n)
    return np.cumsum(gaps)
