"""DecodeState: family-agnostic cache management behind the serving engine.

The engine's scheduling machinery (admission, EDF shedding, slot
rotation, preemption, failover requeue) never touches cache layout — it
talks to a DecodeState, which owns the per-slot model state and knows
how to (a) splice a prefilled request into slot b and (b) advance the
active slots one decode step at a static lane width.  Four states cover
the model zoo:

* `DenseKVState`   — transformer dense `max_batch x max_len` KV
  rectangles ({"segments": [(L, B, C, ...)], "index": (B,)}); the
  compacted gather/scatter sub-batch decode and the legacy full-width
  emulation both live here, bit-identical to the pre-refactor engine.
  `quantized=True` stores the rectangles int8 with per-(layer, slot,
  head) absmax scales (`serving.quant`) — the decode step dequantizes,
  runs the unchanged f32 math, zeroes stale positions, and re-quantizes
  with fresh scales, all inside ONE jitted executable.
* `PagedKVState`   — the block-paged pool (`serving.paged.PagePool`),
  bucketed prefill, and the gathered paged decode (optionally int8).
* `RecurrentState` — rglru conv+hidden / rwkv6 wkv state
  ({"layers": [(B, ...)], "index": (B,)}).  Recurrent state advances
  IRREVERSIBLY (there is no per-position cache to rewind), so decode is
  ALWAYS the gathered sub-batch form: only the active slots' states are
  touched, padding lanes duplicate a real slot (idempotent writes), and
  slot rotation/compaction work exactly like the transformer path.
* `CrossAttnState` — whisper encoder outputs (cross KV) + decoder self
  KV.  Prefill encodes the request's frame embeddings (padded to a
  fixed `enc_len` so one executable serves every request) and the
  decoder prompt; decode is gathered like `RecurrentState`.

Every state exposes the same surface:

    prefill(fn, params, b, seq, frames=None) -> last-token logits
    decode(fn, params, next_token, active)   -> (logits, lane-map)
    release(b); place(mesh); capacity; paged/pool/buckets/cache

`fn` is the ENGINE's jitted decode/prefill attribute, passed per call —
tests stub `engine._decode`/`engine._prefill` after construction and the
state must honor the stub, not a captured original.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.config import ModelConfig
from . import paged as paged_kv
from . import quant

Params = Any


# -- generic tree helpers (re-exported by engine.py for test access) ----------

def _tree_set_slot(batched, single, b: int):
    """Write `single` (batch dim 1 or absent on index leaves) into slot b
    of `batched` along the batch dimension."""
    def leaf(dst, src):
        if dst.ndim == 0:
            return src if src.ndim == 0 else src.reshape(())
        # find the batch dim: first dim where dst differs from src by
        # factor max_batch vs 1 — conventionally dims named (B,...) or
        # (L,B,...) (stacked segments).
        if dst.ndim == src.ndim:
            for axis in range(dst.ndim):
                if src.shape[axis] == 1 and dst.shape[axis] > 1:
                    idx = [slice(None)] * dst.ndim
                    idx[axis] = slice(b, b + 1)
                    return dst.at[tuple(idx)].set(src.astype(dst.dtype))
        return dst
    return jax.tree.map(leaf, batched, single)


def _gather_slots(cache, sel):
    """Compact the cache slices of slots `sel` into a dense sub-cache.
    Segment leaves are (L, B, C, ...) — batch on axis 1; "index" is (B,)."""
    return {
        "segments": jax.tree.map(lambda a: jnp.take(a, sel, axis=1),
                                 cache["segments"]),
        "index": jnp.take(cache["index"], sel, axis=0),
    }


def _scatter_slots(cache, sub, sel):
    """Write an advanced sub-cache back into slots `sel`.  Padding lanes
    duplicate a real slot with identical content, so repeated indices in
    `sel` write identical values (scatter order is irrelevant)."""
    segs = jax.tree.map(
        lambda full, part: full.at[:, sel].set(part.astype(full.dtype)),
        cache["segments"], sub["segments"])
    idx = cache["index"].at[sel].set(sub["index"])
    return {"segments": segs, "index": idx}


def _gather_layers(cache, sel):
    """Layers-layout gather: every leaf carries the batch on axis 0
    ({"layers": [(B, ...)], "index": (B,)} — rglru/rwkv6/whisper)."""
    return {
        "layers": jax.tree.map(lambda a: jnp.take(a, sel, axis=0),
                               cache["layers"]),
        "index": jnp.take(cache["index"], sel, axis=0),
    }


def _scatter_layers(cache, sub, sel):
    layers = jax.tree.map(
        lambda full, part: full.at[sel].set(part.astype(full.dtype)),
        cache["layers"], sub["layers"])
    idx = cache["index"].at[sel].set(sub["index"])
    return {"layers": layers, "index": idx}


def _rewind_inactive(index, inactive: list[int]):
    """ONE batched scatter-add rewinding every slot that did not advance
    this step (the PR-4 code dispatched a separate `.at[b].add(-1)` per
    inactive slot)."""
    return index.at[jnp.asarray(inactive, jnp.int32)].add(-1)


_GATHER = jax.jit(_gather_slots)
# the state drops the old cache the moment the scatter returns, so the
# full-size buffers are donated — on accelerators the scatter updates in
# place instead of allocating a second (L, max_batch, clen, ...) cache
_SCATTER = jax.jit(_scatter_slots, donate_argnums=(0,))
_GATHER_L = jax.jit(_gather_layers)
_SCATTER_L = jax.jit(_scatter_layers, donate_argnums=(0,))


@functools.lru_cache(maxsize=8)
def _decode_fn(mcfg: ModelConfig):
    """Shared per-config jitted decode (engines with the same config —
    e.g. benchmark variants — reuse one trace cache).  Bounded: a config
    sweep evicts old executables instead of retaining them forever."""
    return jax.jit(lambda p, t, c: api.decode_step(mcfg, p, t, c))


@functools.lru_cache(maxsize=8)
def _prefill_fn(mcfg: ModelConfig, max_len: int):
    return jax.jit(
        lambda p, toks: api.prefill(mcfg, p, {"tokens": toks}, max_len))


@functools.lru_cache(maxsize=8)
def _whisper_prefill_fn(mcfg: ModelConfig, max_len: int):
    """Whisper prefill takes (params, frames, tokens): encode the frame
    embeddings, run the decoder prompt, fill self+cross caches."""
    return jax.jit(
        lambda p, frames, toks: api.prefill(
            mcfg, p, {"embeds": frames, "tokens": toks}, max_len))


def _lane_map(sel: list[int]) -> dict[int, int]:
    """slot id -> first lane carrying it (padding lanes repeat slots)."""
    lane: dict[int, int] = {}
    for j, b in enumerate(sel):
        lane.setdefault(b, j)
    return lane


def state_for(mcfg: ModelConfig, family: str | None = None) -> type:
    """The DecodeState class serving `mcfg`'s family (dense layouts)."""
    fam = family or mcfg.family
    if fam == "transformer":
        return DenseKVState
    if fam == "whisper":
        return CrossAttnState
    return RecurrentState


# -- dense transformer rectangles ---------------------------------------------

@functools.lru_cache(maxsize=8)
def _dense_quant_step_fn(mcfg: ModelConfig):
    """One jitted executable for the int8 dense decode step: gather the
    selected slots' codes+scales, dequantize, run the unchanged f32
    `decode_step`, zero positions past each slot's new length (stale
    garbage would inflate the absmax), re-quantize with fresh scales,
    scatter back.  Codes/scales are donated — the update is in place."""
    def run(params, toks, codes, scales, index, sel):
        sub_codes = jax.tree.map(lambda a: jnp.take(a, sel, axis=1), codes)
        sub_scales = jax.tree.map(lambda a: jnp.take(a, sel, axis=1), scales)
        sub_idx = jnp.take(index, sel, axis=0)
        segs = jax.tree.map(
            lambda q, s: quant.dequantize_block(q, s, mcfg.jdtype),
            sub_codes, sub_scales)
        logits, new = api.decode_step(mcfg, params, toks,
                                      {"segments": segs, "index": sub_idx})

        def mask_stale(leaf):
            # live positions after this step: j <= old index (the step
            # wrote slot `old index`); leaf axes are (L, w, C, ...)
            live = jnp.arange(leaf.shape[2])[None, :] <= sub_idx[:, None]
            m = jnp.expand_dims(live, axis=(0,) + tuple(range(3, leaf.ndim)))
            return jnp.where(m, leaf, 0.0)

        masked = jax.tree.map(mask_stale, new["segments"])
        new_codes = jax.tree.map(lambda x: quant.quantize_block(x, 2)[0],
                                 masked)
        new_scales = jax.tree.map(lambda x: quant.page_scales(x, 2), masked)
        codes = jax.tree.map(lambda full, part: full.at[:, sel].set(part),
                             codes, new_codes)
        scales = jax.tree.map(lambda full, part: full.at[:, sel].set(part),
                              scales, new_scales)
        return logits, codes, scales, index.at[sel].set(new["index"])
    return jax.jit(run, donate_argnums=(2, 3))


def _quant_scale_shape(a) -> tuple:
    shape = list(a.shape)
    for ax in (2, a.ndim - 1):
        shape[ax] = 1
    return tuple(shape)


class DenseKVState:
    """Transformer dense KV rectangles; optional int8 storage."""

    kind = "dense"
    paged = False
    pool = None
    buckets: tuple = ()

    def __init__(self, mcfg: ModelConfig, max_batch: int, max_len: int, *,
                 decode_batch: int, compact: bool, quantized: bool = False,
                 rewind_hook=None):
        self.mcfg = mcfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.decode_batch = decode_batch
        self.compact = compact
        self.capacity = max_len
        self.quantized = quantized
        # late-bound so tests can monkeypatch engine._rewind_inactive
        self._rewind = rewind_hook or _rewind_inactive
        base = api.init_cache(mcfg, max_batch, max_len)
        if quantized:
            self.cache = {
                "segments": jax.tree.map(
                    lambda a: jnp.zeros(a.shape, jnp.int8), base["segments"]),
                "index": jnp.zeros((max_batch,), jnp.int32)}
            self.scales = jax.tree.map(
                lambda a: jnp.zeros(_quant_scale_shape(a), jnp.float32),
                base["segments"])
        else:
            self.cache = base
            # per-slot cache lengths (vector index -> mixed-length batching)
            self.cache["index"] = jnp.zeros((max_batch,), jnp.int32)
            self.scales = None

    def place(self, mesh) -> None:
        if self.quantized:
            return      # int8 rectangles stay on the default placement
        from repro.parallel.sharding import cache_shardings
        self.cache = jax.device_put(
            self.cache, cache_shardings(mesh, self.cache, self.mcfg.kv_heads,
                                        self.max_batch))

    def prefill(self, fn, params, b: int, seq: np.ndarray, frames=None):
        toks = jnp.asarray(seq[None, :], jnp.int32)
        last, cache1 = fn(params, toks)
        if self.quantized:
            codes1 = jax.tree.map(lambda x: quant.quantize_block(x, 2)[0],
                                  cache1["segments"])
            scales1 = jax.tree.map(lambda x: quant.page_scales(x, 2),
                                   cache1["segments"])
            segs = _tree_set_slot(self.cache["segments"], codes1, b)
            self.scales = _tree_set_slot(self.scales, scales1, b)
            self.cache = {"segments": segs,
                          "index": self.cache["index"].at[b].set(len(seq))}
        else:
            idx_vec = self.cache["index"]
            self.cache = _tree_set_slot(self.cache, cache1, b)
            self.cache["index"] = idx_vec.at[b].set(len(seq))
        return last

    def decode(self, fn, params, next_token: np.ndarray, active: list[int]):
        if self.quantized:
            # always gathered: only active slots dequantize/requantize,
            # so the full-width rewind never runs over int8 codes
            sel = active + [active[0]] * (self.decode_batch - len(active))
            sel_arr = jnp.asarray(sel, jnp.int32)
            qfn = _dense_quant_step_fn(self.mcfg)
            logits, segs, scales, idx = qfn(
                params, jnp.asarray(next_token[sel]),
                self.cache["segments"], self.scales,
                self.cache["index"], sel_arr)
            self.cache = {"segments": segs, "index": idx}
            self.scales = scales
            return logits, _lane_map(sel)
        if self.compact and self.decode_batch < self.max_batch:
            # compacted sub-batch decode: gather the active slots' cache
            # slices, decode at static width decode_batch, scatter back.
            # Padding lanes (fewer active than decode_batch) repeat the
            # first active slot — identical inputs give identical lane
            # results, so the duplicate scatter writes are idempotent.
            sel = active + [active[0]] * (self.decode_batch - len(active))
            sel_arr = jnp.asarray(sel, jnp.int32)
            sub = _GATHER(self.cache, sel_arr)
            logits, new_sub = fn(params, jnp.asarray(next_token[sel]), sub)
            self.cache = _SCATTER(self.cache, new_sub, sel_arr)
            return logits, _lane_map(sel)
        logits, new_cache = fn(params, jnp.asarray(next_token), self.cache)
        self.cache = new_cache
        # full-width decode advanced every slot; slots not advancing
        # this step must not advance their cache index (one batched
        # scatter-add, not a per-slot dispatch loop)
        inactive = [b for b in range(self.max_batch) if b not in active]
        if inactive:
            self.cache["index"] = self._rewind(self.cache["index"], inactive)
        return logits, {b: b for b in active}

    def release(self, b: int) -> None:
        pass


# -- block-paged transformer pool ---------------------------------------------

class PagedKVState:
    """Block-paged KV: PagePool + bucketed prefill + gathered decode."""

    kind = "paged"
    paged = True
    cache = None

    def __init__(self, mcfg: ModelConfig, max_batch: int, max_len: int, *,
                 decode_batch: int, compact: bool, page_size: int,
                 num_pages: int | None, bucket_min: int,
                 quantized: bool = False):
        self.mcfg = mcfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.decode_batch = decode_batch
        self.compact = compact
        self.quantized = quantized
        self.pool = paged_kv.PagePool(
            mcfg, max_batch, max_len, page_size=page_size,
            num_pages=num_pages, quant=quantized)
        self.buckets = paged_kv.prefill_buckets(max_len, bucket_min)
        self.capacity = paged_kv.pool_token_capacity(self.pool, max_len)

    def place(self, mesh) -> None:
        from repro.parallel.sharding import paged_cache_shardings
        self.pool.segments = jax.device_put(
            self.pool.segments,
            paged_cache_shardings(mesh, self.pool.segments,
                                  self.mcfg.kv_heads))
        if self.quantized:
            # scale leaves keep kvh on axis 3 (keepdims layout),
            # so the same placement rule applies
            self.pool.scales = jax.device_put(
                self.pool.scales,
                paged_cache_shardings(mesh, self.pool.scales,
                                      self.mcfg.kv_heads))

    def prefill(self, fn, params, b: int, seq: np.ndarray, frames=None):
        """Bucket-padded prefill of `seq` into slot b's pages; returns
        the (1, 1, V) last-real-token logits."""
        plen = len(seq)
        bucket = paged_kv.bucket_for(plen, self.buckets)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = seq
        pfn = paged_kv.paged_prefill_fn(self.mcfg, bucket,
                                        self.pool.page_size, self.quantized)
        trow = self.pool.table_row(b, bucket // self.pool.page_size)
        if self.quantized:
            last, self.pool.segments, self.pool.scales = pfn(
                params, toks, plen, self.pool.segments,
                self.pool.scales, trow)
        else:
            last, self.pool.segments = pfn(
                params, toks, plen, self.pool.segments, trow)
        self.pool.index[b] = plen
        return last

    def decode(self, fn, params, next_token: np.ndarray, active: list[int]):
        """One gathered decode over the page pool at a fixed lane width
        (decode_batch when compacting, max_batch for the full-width
        emulation) — a single executable either way."""
        width = self.decode_batch if self.compact else self.max_batch
        sel = active + [active[0]] * (width - len(active))
        tables_sel = self.pool.tables[np.asarray(sel)]
        index_sel = self.pool.index[np.asarray(sel)]
        if self.quantized:
            logits, self.pool.segments, self.pool.scales = fn(
                params, jnp.asarray(next_token[sel]),
                self.pool.segments, self.pool.scales, tables_sel, index_sel)
        else:
            logits, self.pool.segments = fn(
                params, jnp.asarray(next_token[sel]),
                self.pool.segments, tables_sel, index_sel)
        # page-table bookkeeping is host-side numpy: advance the lengths
        # here instead of round-tripping them through the device
        self.pool.index[np.asarray(active)] += 1
        return logits, _lane_map(sel)

    def release(self, b: int) -> None:
        self.pool.release(b)


# -- recurrent (rglru / rwkv6) and encoder-decoder (whisper) ------------------

class _LayersState:
    """Shared machinery for {"layers": [(B, ...)], "index": (B,)} caches:
    per-slot vector-indexed gather/scatter with the batch on axis 0.

    Decode is ALWAYS the gathered sub-batch form at static width
    `decode_batch`: recurrent state advances irreversibly, so inactive
    slots must never be run through the model (the transformer
    full-width emulation rewinds a position index; a wkv/conv state has
    nothing to rewind).  Padding lanes duplicate a real slot; the
    duplicate scatter writes are identical, hence idempotent."""

    paged = False
    pool = None
    buckets: tuple = ()

    def __init__(self, mcfg: ModelConfig, max_batch: int, max_len: int, *,
                 decode_batch: int, enc_len: int | None = None):
        self.mcfg = mcfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.decode_batch = decode_batch
        self.compact = True          # gathered decode is structural here
        self.capacity = max_len
        self.enc_len = enc_len or max_len
        self.cache = api.init_cache(mcfg, max_batch, max_len,
                                    enc_len=self.enc_len)
        self.cache["index"] = jnp.zeros((max_batch,), jnp.int32)

    def place(self, mesh) -> None:
        # params shard over the mesh; recurrent/cross-attn state leaves
        # are small (B, ...) tensors and stay on the default placement
        pass

    def _splice(self, b: int, cache1, plen: int) -> None:
        idx_vec = self.cache["index"]
        self.cache = _tree_set_slot(self.cache, cache1, b)
        self.cache["index"] = idx_vec.at[b].set(plen)

    def decode(self, fn, params, next_token: np.ndarray, active: list[int]):
        sel = active + [active[0]] * (self.decode_batch - len(active))
        sel_arr = jnp.asarray(sel, jnp.int32)
        sub = _GATHER_L(self.cache, sel_arr)
        logits, new_sub = fn(params, jnp.asarray(next_token[sel]), sub)
        self.cache = _SCATTER_L(self.cache, new_sub, sel_arr)
        return logits, _lane_map(sel)

    def release(self, b: int) -> None:
        pass


class RecurrentState(_LayersState):
    """rglru conv+hidden / rwkv6 wkv state (plus rglru's ring KV on its
    interleaved attention layers)."""

    kind = "recurrent"

    def prefill(self, fn, params, b: int, seq: np.ndarray, frames=None):
        toks = jnp.asarray(seq[None, :], jnp.int32)
        last, cache1 = fn(params, toks)
        self._splice(b, cache1, len(seq))
        return last


class CrossAttnState(_LayersState):
    """Whisper: encoder outputs (cross KV) + decoder self KV.  Request
    frame embeddings are padded/truncated to the fixed `enc_len` window
    so every prefill of a given prompt length shares one executable;
    requests without frames encode a zero (silence) window."""

    kind = "cross_attn"

    def _fixed_frames(self, frames) -> jnp.ndarray:
        d = self.mcfg.d_model
        out = np.zeros((1, self.enc_len, d), np.float32)
        if frames is not None:
            f = np.asarray(frames, np.float32)
            if f.ndim == 3:
                f = f[0]
            take = min(f.shape[0], self.enc_len)
            out[0, :take] = f[:take]
        return jnp.asarray(out)

    def prefill(self, fn, params, b: int, seq: np.ndarray, frames=None):
        toks = jnp.asarray(seq[None, :], jnp.int32)
        last, cache1 = fn(params, self._fixed_frames(frames), toks)
        self._splice(b, cache1, len(seq))
        return last
