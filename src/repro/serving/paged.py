"""Block-paged KV cache + bucketed prefill for the serving engine.

The dense engine reserves a `(max_batch, max_len)` KV rectangle per slot
and compiles a fresh prefill executable for every distinct prompt
length.  This module replaces both:

* **Pages** — KV lives in per-layer pools of fixed-size pages
  (`models.api.init_paged_cache`); each slot owns a list of physical
  pages recorded in a per-slot page table, so HBM holds live tokens, not
  rectangles.  Page 0 is a reserved null page: every unused table entry
  points at it and its contents are never read (attention masks by
  per-slot length).  Allocation/free is host-side free-list accounting
  (`PagePool`), cheap and exact.
* **Bucketed prefill** — prompts are right-padded to the next
  power-of-two bucket, so an arbitrary prompt mix compiles at most
  `len(prefill_buckets(...))` prefill executables.  Causal attention
  makes the padding exact: positions `< plen` never attend to the pad
  tail, and the pad tail's garbage KV is overwritten by decode before
  its position becomes visible.

Decode gathers the selected slots' pages into the dense `(n, C, ...)`
layout `transformer.decode_step` already understands, runs the unchanged
decode math, and scatters the advanced pages back — so paged decode is
bit-identical to the dense cache path.  Page tables and per-slot lengths
live as host `numpy` arrays and enter the jitted functions as plain
array arguments: every step passes the same shapes, so steady-state
serving dispatches zero fresh compiles no matter how tables churn.  The
jitted builders are module-level and `lru_cache`'d per config, so
engines sharing a config reuse one trace cache.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api, transformer
from repro.models.config import ModelConfig


def prefill_buckets(max_len: int, min_bucket: int = 16) -> tuple[int, ...]:
    """Power-of-two prompt-length buckets: `min_bucket, 2*min_bucket, ...`
    up to the first bucket that covers `max_len - 1` (prompts of
    `max_len` or longer are rejected at admission — decode needs at
    least one free position)."""
    b = 1 << max(0, int(min_bucket) - 1).bit_length()
    if b < 1:
        b = 1
    out = [b]
    while out[-1] < max_len - 1:
        out.append(out[-1] * 2)
    return tuple(out)


def bucket_for(plen: int, buckets: tuple[int, ...]) -> int:
    """Smallest bucket that holds a `plen`-token prompt."""
    for b in buckets:
        if plen <= b:
            return b
    raise ValueError(f"prompt of {plen} tokens exceeds the largest bucket {buckets[-1]}")


class PagePool:
    """Fixed-size KV pages with per-slot page tables and host-side
    free-list accounting.  Not thread-safe: the serving engine is the
    single writer."""

    def __init__(
        self,
        mcfg: ModelConfig,
        max_batch: int,
        max_len: int,
        *,
        page_size: int = 16,
        num_pages: int | None = None,
        dtype=None,
    ):
        if page_size < 1 or page_size & (page_size - 1):
            raise ValueError(f"page_size must be a power of two, got {page_size}")
        self.page_size = page_size
        self.max_batch = max_batch
        self.pages_per_slot = -(-max_len // page_size)
        # default: capacity parity with the dense cache (+1 null page);
        # pass a smaller num_pages to trade capacity for density — the
        # engine preempts under pressure instead of overflowing
        self.num_pages = num_pages or 1 + max_batch * self.pages_per_slot
        if self.num_pages < 2:
            raise ValueError("need at least one allocatable page beyond the null page")
        self.segments = api.init_paged_cache(mcfg, self.num_pages, page_size, dtype)
        # tables/index are HOST state (numpy): they enter jitted code as
        # ordinary array arguments, never as baked-in constants, so page
        # churn can't mint fresh executables
        self.tables = np.zeros((max_batch, self.pages_per_slot), np.int32)
        self.index = np.zeros((max_batch,), np.int32)
        self._free = list(range(self.num_pages - 1, 0, -1))  # pop() allocates ascending
        self._owned: list[list[int]] = [[] for _ in range(max_batch)]
        self.stats = {"page_allocs": 0, "page_frees": 0, "peak_pages_in_use": 0}

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def owned(self, b: int) -> tuple[int, ...]:
        return tuple(self._owned[b])

    def ensure(self, b: int, n_tokens: int) -> bool:
        """Grow slot `b` to hold `n_tokens`; False if the free list is
        short (caller preempts or waits).  Never partially allocates."""
        need = self.pages_for(n_tokens)
        have = len(self._owned[b])
        if need <= have:
            return True
        if need - have > len(self._free) or need > self.pages_per_slot:
            return False
        fresh = [self._free.pop() for _ in range(need - have)]
        self._owned[b].extend(fresh)
        self.tables[b, have:need] = fresh
        self.stats["page_allocs"] += len(fresh)
        self.stats["peak_pages_in_use"] = max(self.stats["peak_pages_in_use"], self.pages_in_use)
        return True

    def release(self, b: int) -> None:
        """Return slot `b`'s pages to the free list and null its table."""
        pages = self._owned[b]
        if pages:
            self.stats["page_frees"] += len(pages)
            self._free.extend(reversed(pages))
            self._owned[b] = []
            self.tables[b] = 0
        self.index[b] = 0

    def table_row(self, b: int, n_entries: int) -> np.ndarray:
        """The first `n_entries` table entries of slot `b` (null-padded) —
        the bucket-sized view a padded prefill scatters through."""
        row = (self._owned[b] + [0] * n_entries)[:n_entries]
        return np.asarray(row, np.int32)


def _gather_pages(segments, tables_sel):
    """Pool pages -> the dense (n, C, ...) cache layout, via per-slot
    tables.  tables_sel: (n, pages_per_slot) physical page ids."""
    n, npp = tables_sel.shape

    def leaf(a):  # (L, P, ps, ...)
        g = jnp.take(a, tables_sel, axis=1)  # (L, n, npp, ps, ...)
        return g.reshape(a.shape[0], n, npp * a.shape[2], *a.shape[3:])

    return jax.tree.map(leaf, segments)


def _scatter_pages(segments, dense, tables_sel):
    """Write an advanced dense sub-cache back through the page tables.
    Duplicate physical ids only occur for padding lanes (identical
    content) and the never-read null page, so scatter order is
    irrelevant."""
    n, npp = tables_sel.shape

    def leaf(a, d):  # a: (L, P, ps, ...); d: (L, n, C, ...)
        dp = d.reshape(a.shape[0], n, npp, a.shape[2], *a.shape[3:])
        return a.at[:, tables_sel].set(dp.astype(a.dtype))

    return jax.tree.map(leaf, segments, dense)


@functools.lru_cache(maxsize=8)
def paged_decode_fn(mcfg: ModelConfig):
    """Jitted gather -> decode -> scatter over the page pool.  One
    executable per (config, selection width); the pool buffers are
    donated so the scatter updates in place.  Slot lengths advance on the
    host (the caller knows exactly which slots stepped), so only logits
    and the pool round-trip the device."""

    def fn(params, tokens, segments, tables_sel, index_sel):
        dense = _gather_pages(segments, tables_sel)
        logits, new = api.decode_step(
            mcfg, params, tokens, {"segments": dense, "index": index_sel}
        )
        return logits, _scatter_pages(segments, new["segments"], tables_sel)

    return jax.jit(fn, donate_argnums=(2,))


@functools.lru_cache(maxsize=32)
def paged_prefill_fn(mcfg: ModelConfig, bucket: int, page_size: int):
    """Jitted padded prefill + page scatter for one bucket length.  The
    prompt arrives right-padded to `bucket`; `plen` (traced) selects the
    real last-token logits, and the prompt's KV lands in the pages named
    by `table_row`.  Pad positions `>= plen` write garbage into the tail
    of the last real page (overwritten by decode before ever unmasked)
    and into the null page (never read)."""
    if bucket % page_size:
        raise ValueError(f"bucket {bucket} is not a multiple of page_size {page_size}")
    npp_b = bucket // page_size

    def fn(params, toks, plen, segments, table_row):
        logits, _, kvs = transformer.forward(mcfg, params, toks, collect_kv=True)
        last = jax.lax.dynamic_slice_in_dim(logits, plen - 1, 1, axis=1)

        def leaf(a, kv):  # a: (L, P, ps, ...); kv: (L, 1, bucket, ...)
            pages = kv[:, 0].reshape(a.shape[0], npp_b, page_size, *kv.shape[3:])
            return a.at[:, table_row].set(pages.astype(a.dtype))

        new_segs = []
        for seg_kv, seg_pool in zip(kvs, segments):
            if mcfg.use_mla:
                kv_tree = {"latent": seg_kv[0]}
            else:
                kv_tree = {"k": seg_kv[0], "v": seg_kv[1]}
            new_segs.append(jax.tree.map(leaf, seg_pool, kv_tree))
        return last, new_segs

    return jax.jit(fn, donate_argnums=(3,))


def paged_supported(mcfg: ModelConfig) -> bool:
    """Paged + bucketed serving is exact only where the gather/bucket
    assumptions hold: the transformer cache layout, no sliding-window
    ring (pages map positions, not ring slots), and no MoE (pad tokens
    would consume router capacity and perturb real tokens)."""
    return mcfg.family == "transformer" and not mcfg.window and not mcfg.use_moe


def pool_token_capacity(pool: PagePool, max_len: int) -> int:
    """Hard per-slot token ceiling: the engine finishes a request at this
    boundary instead of overrunning its pages."""
    return min(max_len, pool.pages_per_slot * pool.page_size)
