"""Block-paged KV cache + bucketed prefill for the serving engine.

The dense engine reserves a `(max_batch, max_len)` KV rectangle per slot
and compiles a fresh prefill executable for every distinct prompt
length.  This module replaces both:

* **Pages** — KV lives in per-layer pools of fixed-size pages
  (`models.api.init_paged_cache`); each slot owns a list of physical
  pages recorded in a per-slot page table, so HBM holds live tokens, not
  rectangles.  Page 0 is a reserved null page: every unused table entry
  points at it and its contents are never read (attention masks by
  per-slot length).  Allocation/free is host-side free-list accounting
  (`PagePool`), cheap and exact.
* **Bucketed prefill** — prompts are right-padded to the next
  power-of-two bucket, so an arbitrary prompt mix compiles at most
  `len(prefill_buckets(...))` prefill executables.  Causal attention
  makes the padding exact: positions `< plen` never attend to the pad
  tail.  The prefill scatter is RAGGED (per-page): pad positions are
  zeroed and table entries whose page starts at or past `plen` are
  redirected to the null page inside the trace, so bucket padding never
  occupies — or pollutes — pages past the true prompt length; a page's
  only nonzero contents are real KV.
* **Int8 quantization** — with `quant=True` (`MOZART_KV_QUANT=1`) pages
  are stored int8 with per-(layer, page, kv-head) float32 scales
  (`serving.quant`): gather dequantizes into the f32 dense sub-cache the
  unchanged decode math runs over, scatter re-quantizes with fresh
  scales, and positions at or past each slot's length are zeroed before
  re-quantization so stale garbage in reused pages can never inflate a
  scale and crush the live tokens' resolution.  Same decode loop, ~4x
  the slots per HBM byte (`quant.pages_for_byte_budget`).

Decode gathers the selected slots' pages into the dense `(n, C, ...)`
layout `transformer.decode_step` already understands, runs the unchanged
decode math, and scatters the advanced pages back — so paged decode is
bit-identical to the dense cache path.  Page tables and per-slot lengths
live as host `numpy` arrays and enter the jitted functions as plain
array arguments: every step passes the same shapes, so steady-state
serving dispatches zero fresh compiles no matter how tables churn.  The
jitted builders are module-level and `lru_cache`'d per config, so
engines sharing a config reuse one trace cache.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api, transformer
from repro.models.config import ModelConfig

from . import quant as kvq


def prefill_buckets(max_len: int, min_bucket: int = 16) -> tuple[int, ...]:
    """Power-of-two prompt-length buckets: `min_bucket, 2*min_bucket, ...`
    up to the first bucket that covers `max_len - 1` (prompts of
    `max_len` or longer are rejected at admission — decode needs at
    least one free position)."""
    b = 1 << max(0, int(min_bucket) - 1).bit_length()
    if b < 1:
        b = 1
    out = [b]
    while out[-1] < max_len - 1:
        out.append(out[-1] * 2)
    return tuple(out)


def bucket_for(plen: int, buckets: tuple[int, ...]) -> int:
    """Smallest bucket that holds a `plen`-token prompt."""
    for b in buckets:
        if plen <= b:
            return b
    raise ValueError(f"prompt of {plen} tokens exceeds the largest bucket {buckets[-1]}")


class PagePool:
    """Fixed-size KV pages with per-slot page tables and host-side
    free-list accounting.  Not thread-safe: the serving engine is the
    single writer."""

    def __init__(
        self,
        mcfg: ModelConfig,
        max_batch: int,
        max_len: int,
        *,
        page_size: int = 16,
        num_pages: int | None = None,
        dtype=None,
        quant: bool = False,
    ):
        if page_size < 1 or page_size & (page_size - 1):
            raise ValueError(f"page_size must be a power of two, got {page_size}")
        self.page_size = page_size
        self.max_batch = max_batch
        self.pages_per_slot = -(-max_len // page_size)
        # default: capacity parity with the dense cache (+1 null page);
        # pass a smaller num_pages to trade capacity for density — the
        # engine preempts under pressure instead of overflowing
        self.num_pages = num_pages or 1 + max_batch * self.pages_per_slot
        if self.num_pages < 2:
            raise ValueError("need at least one allocatable page beyond the null page")
        # quant: int8 pages + per-(layer, page, kv-head) f32 scales; the
        # prefill/decode builders below dequantize on gather and
        # re-quantize on scatter (serving.quant)
        self.quant = quant
        self.segments = api.init_paged_cache(
            mcfg, self.num_pages, page_size, jnp.int8 if quant else dtype
        )
        self.scales = kvq.scale_struct(self.segments) if quant else None
        # tables/index are HOST state (numpy): they enter jitted code as
        # ordinary array arguments, never as baked-in constants, so page
        # churn can't mint fresh executables
        self.tables = np.zeros((max_batch, self.pages_per_slot), np.int32)
        self.index = np.zeros((max_batch,), np.int32)
        self._free = list(range(self.num_pages - 1, 0, -1))  # pop() allocates ascending
        self._owned: list[list[int]] = [[] for _ in range(max_batch)]
        self.stats = {"page_allocs": 0, "page_frees": 0, "peak_pages_in_use": 0}

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    @property
    def page_nbytes(self) -> int:
        """HBM bytes one page costs across every layer's pools (plus its
        scale entries when quantized) — the unit `quant.
        pages_for_byte_budget` sizes byte-matched pools with."""
        total = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(self.segments))
        if self.quant:
            total += sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(self.scales))
        return total // self.num_pages

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def owned(self, b: int) -> tuple[int, ...]:
        return tuple(self._owned[b])

    def ensure(self, b: int, n_tokens: int) -> bool:
        """Grow slot `b` to hold `n_tokens`; False if the free list is
        short (caller preempts or waits).  Never partially allocates."""
        need = self.pages_for(n_tokens)
        have = len(self._owned[b])
        if need <= have:
            return True
        if need - have > len(self._free) or need > self.pages_per_slot:
            return False
        fresh = [self._free.pop() for _ in range(need - have)]
        self._owned[b].extend(fresh)
        self.tables[b, have:need] = fresh
        self.stats["page_allocs"] += len(fresh)
        self.stats["peak_pages_in_use"] = max(self.stats["peak_pages_in_use"], self.pages_in_use)
        return True

    def release(self, b: int) -> None:
        """Return slot `b`'s pages to the free list and null its table."""
        pages = self._owned[b]
        if pages:
            self.stats["page_frees"] += len(pages)
            self._free.extend(reversed(pages))
            self._owned[b] = []
            self.tables[b] = 0
        self.index[b] = 0

    def table_row(self, b: int, n_entries: int) -> np.ndarray:
        """The first `n_entries` table entries of slot `b` (null-padded) —
        the bucket-sized view a padded prefill scatters through."""
        row = (self._owned[b] + [0] * n_entries)[:n_entries]
        return np.asarray(row, np.int32)


def _gather_pages(segments, tables_sel):
    """Pool pages -> the dense (n, C, ...) cache layout, via per-slot
    tables.  tables_sel: (n, pages_per_slot) physical page ids."""
    n, npp = tables_sel.shape

    def leaf(a):  # (L, P, ps, ...)
        g = jnp.take(a, tables_sel, axis=1)  # (L, n, npp, ps, ...)
        return g.reshape(a.shape[0], n, npp * a.shape[2], *a.shape[3:])

    return jax.tree.map(leaf, segments)


def _scatter_pages(segments, dense, tables_sel):
    """Write an advanced dense sub-cache back through the page tables.
    Duplicate physical ids only occur for padding lanes (identical
    content) and the never-read null page, so scatter order is
    irrelevant."""
    n, npp = tables_sel.shape

    def leaf(a, d):  # a: (L, P, ps, ...); d: (L, n, C, ...)
        dp = d.reshape(a.shape[0], n, npp, a.shape[2], *a.shape[3:])
        return a.at[:, tables_sel].set(dp.astype(a.dtype))

    return jax.tree.map(leaf, segments, dense)


def _gather_pages_dequant(segments, scales, tables_sel):
    """Int8 pool pages -> dequantized f32 dense (n, C, ...) cache layout.
    Each page's scale broadcasts over its positions (and head_dim) via
    the keepdims-1 axes `quant.page_scales` left in place."""
    n, npp = tables_sel.shape

    def leaf(a, s):  # a: (L, P, ps, ...) int8; s: (L, P, 1, ...) f32
        g = jnp.take(a, tables_sel, axis=1)  # (L, n, npp, ps, ...)
        gs = jnp.take(s, tables_sel, axis=1)  # (L, n, npp, 1, ...)
        d = kvq.dequantize_block(g, gs)
        return d.reshape(a.shape[0], n, npp * a.shape[2], *a.shape[3:])

    return jax.tree.map(leaf, segments, scales)


def _scatter_pages_quant(segments, scales, dense, tables_sel, new_len):
    """Re-quantize an advanced dense sub-cache back into int8 pages with
    FRESH per-page scales.  Positions at or past each lane's new length
    (`new_len`, (n,)) are zeroed first: a reused page's stale garbage —
    or the never-read null page's — must not inflate a scale and crush
    the resolution of the page's live tokens."""
    n, npp = tables_sel.shape
    seg_leaves, treedef = jax.tree.flatten(segments)
    scale_leaves = jax.tree.leaves(scales)
    dense_leaves = jax.tree.leaves(dense)
    out_segs, out_scales = [], []
    for a, s, d in zip(seg_leaves, scale_leaves, dense_leaves):
        pos = jnp.arange(d.shape[2])
        live = (pos[None, :] < new_len[:, None]).reshape(
            1, n, d.shape[2], *([1] * (d.ndim - 3))
        )
        dp = jnp.where(live, d, 0).reshape(
            a.shape[0], n, npp, a.shape[2], *a.shape[3:]
        )
        q, qs = kvq.quantize_block(dp, ps_axis=3)
        out_segs.append(a.at[:, tables_sel].set(q))
        out_scales.append(s.at[:, tables_sel].set(qs))
    return (
        jax.tree.unflatten(treedef, out_segs),
        jax.tree.unflatten(jax.tree.structure(scales), out_scales),
    )


@functools.lru_cache(maxsize=8)
def paged_decode_fn(mcfg: ModelConfig, quantized: bool = False):
    """Jitted gather -> decode -> scatter over the page pool.  One
    executable per (config, selection width); the pool buffers are
    donated so the scatter updates in place.  Slot lengths advance on the
    host (the caller knows exactly which slots stepped), so only logits
    and the pool round-trip the device.  The quantized variant takes and
    returns the scale tree alongside the int8 pool."""

    def fn(params, tokens, segments, tables_sel, index_sel):
        dense = _gather_pages(segments, tables_sel)
        logits, new = api.decode_step(
            mcfg, params, tokens, {"segments": dense, "index": index_sel}
        )
        return logits, _scatter_pages(segments, new["segments"], tables_sel)

    def fn_q(params, tokens, segments, scales, tables_sel, index_sel):
        dense = _gather_pages_dequant(segments, scales, tables_sel)
        logits, new = api.decode_step(
            mcfg, params, tokens, {"segments": dense, "index": index_sel}
        )
        segs2, scales2 = _scatter_pages_quant(
            segments, scales, new["segments"], tables_sel, new["index"]
        )
        return logits, segs2, scales2

    if quantized:
        return jax.jit(fn_q, donate_argnums=(2, 3))
    return jax.jit(fn, donate_argnums=(2,))


@functools.lru_cache(maxsize=32)
def paged_prefill_fn(
    mcfg: ModelConfig, bucket: int, page_size: int, quantized: bool = False
):
    """Jitted padded prefill + RAGGED per-page scatter for one bucket
    length.  The prompt arrives right-padded to `bucket`; `plen`
    (traced) selects the real last-token logits, and the prompt's KV
    lands in the pages named by `table_row`.  Pad positions `>= plen`
    are zeroed and table entries whose page starts at or past `plen` are
    redirected to the null page, so the whole-bucket rectangle never
    lands in pages past the true prompt length: a slot's pages hold real
    KV and zeros, nothing else (which is also what keeps the quantized
    variant's per-page absmax scales driven by live tokens only)."""
    if bucket % page_size:
        raise ValueError(f"bucket {bucket} is not a multiple of page_size {page_size}")
    npp_b = bucket // page_size

    def _masked_kv(plen, kvs):
        """Per-segment KV trees with pad positions zeroed, plus the
        null-redirected table-row transform for pages past plen."""
        valid = jnp.arange(bucket) < plen  # (bucket,)
        page_live = (jnp.arange(npp_b) * page_size) < plen  # (npp_b,)
        trees = []
        for seg_kv in kvs:
            if mcfg.use_mla:
                kv_tree = {"latent": seg_kv[0]}
            else:
                kv_tree = {"k": seg_kv[0], "v": seg_kv[1]}
            trees.append(
                jax.tree.map(
                    lambda kv: jnp.where(
                        valid.reshape(1, 1, bucket, *([1] * (kv.ndim - 3))), kv, 0
                    ),
                    kv_tree,
                )
            )
        return trees, page_live

    def _pages(kv):  # (L, 1, bucket, ...) -> (L, npp_b, page_size, ...)
        return kv[:, 0].reshape(kv.shape[0], npp_b, page_size, *kv.shape[3:])

    def fn(params, toks, plen, segments, table_row):
        logits, _, kvs = transformer.forward(mcfg, params, toks, collect_kv=True)
        last = jax.lax.dynamic_slice_in_dim(logits, plen - 1, 1, axis=1)
        kv_trees, page_live = _masked_kv(plen, kvs)
        row = jnp.where(page_live, table_row, 0)
        new_segs = [
            jax.tree.map(
                lambda a, kv: a.at[:, row].set(_pages(kv).astype(a.dtype)),
                seg_pool,
                kv_tree,
            )
            for seg_pool, kv_tree in zip(segments, kv_trees)
        ]
        return last, new_segs

    def fn_q(params, toks, plen, segments, scales, table_row):
        logits, _, kvs = transformer.forward(mcfg, params, toks, collect_kv=True)
        last = jax.lax.dynamic_slice_in_dim(logits, plen - 1, 1, axis=1)
        kv_trees, page_live = _masked_kv(plen, kvs)
        row = jnp.where(page_live, table_row, 0)
        new_segs, new_scales = [], []
        for seg_pool, seg_scale, kv_tree in zip(segments, scales, kv_trees):
            seg_leaves, treedef = jax.tree.flatten(seg_pool)
            scale_leaves = jax.tree.leaves(seg_scale)
            kv_leaves = jax.tree.leaves(kv_tree)
            out_a, out_s = [], []
            for a, s, kv in zip(seg_leaves, scale_leaves, kv_leaves):
                q, qs = kvq.quantize_block(_pages(kv), ps_axis=2)
                out_a.append(a.at[:, row].set(q))
                out_s.append(s.at[:, row].set(qs))
            new_segs.append(jax.tree.unflatten(treedef, out_a))
            new_scales.append(
                jax.tree.unflatten(jax.tree.structure(seg_scale), out_s)
            )
        return last, new_segs, new_scales

    if quantized:
        return jax.jit(fn_q, donate_argnums=(3, 4))
    return jax.jit(fn, donate_argnums=(3,))


def paged_supported(mcfg: ModelConfig) -> bool:
    """Paged + bucketed serving is exact only where the gather/bucket
    assumptions hold: the transformer cache layout, no sliding-window
    ring (pages map positions, not ring slots), and no MoE (pad tokens
    would consume router capacity and perturb real tokens)."""
    return mcfg.family == "transformer" and not mcfg.window and not mcfg.use_moe


def pool_token_capacity(pool: PagePool, max_len: int) -> int:
    """Hard per-slot token ceiling: the engine finishes a request at this
    boundary instead of overrunning its pages."""
    return min(max_len, pool.pages_per_slot * pool.page_size)
