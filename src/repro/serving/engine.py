"""Serving engine: slot-based continuous batching with the Mozart
operator-level batching policy (Insight 2).

A fixed pool of `max_batch` cache slots decodes in lock-step (static
shapes); finished slots are refilled by prefilling queued requests and
splicing their cache into the slot.  The paper's non-uniform batching
maps here as: decode batch size and prefill parallelism are set from the
Mozart `ExecutionPolicy` (batch-agnostic attention wants small per-op
batch with high TP; batch-sensitive projections want the opposite — the
engine's `decode_batch` honors the policy's compromise).

MODEL STATE.  The engine is family-agnostic: per-slot model state lives
behind a `serving.state.DecodeState`, so the SAME admission / EDF
shedding / rotation / preemption / failover machinery serves every
family in `configs/`:

* transformer — `PagedKVState` (block-paged pool, default) or
  `DenseKVState` (dense rectangles, optionally int8 via
  `MOZART_KV_QUANT=dense`);
* rglru / rwkv6 — `RecurrentState` (conv+hidden / wkv state with
  per-slot vector-indexed gather/scatter; decode is always the gathered
  sub-batch form because recurrent state cannot be rewound);
* whisper — `CrossAttnState` (encoder outputs + decoder self KV; the
  request's `frames` embeddings are encoded at admission).

KV STORAGE.  By default (`MOZART_PAGED_KV=1`, transformer family without
SWA/MoE) the KV cache is BLOCK-PAGED: fixed-size pages from a shared
pool, owned per-slot through page tables (`serving.paged.PagePool`),
allocated on admission/growth and freed on finish — HBM holds live
tokens, not `max_batch x max_len` rectangles.  Prefill pads prompts to
power-of-two BUCKETS so an arbitrary prompt-length mix compiles at most
`len(engine.buckets)` prefill executables plus one decode executable.
Decode gathers the active slots' pages into the dense layout
`decode_step` expects and scatters back, so paged decode is bit-exact
against the dense cache.  When the free list runs dry the engine
preempts the youngest-admitted slot (requeued at the queue front and
later resumed by re-prefilling its tokens).  `paged=False` (or
`MOZART_PAGED_KV=0`) restores the dense rectangles.  `MOZART_KV_QUANT`
stores KV int8 with per-head scales (`serving.quant`): any truthy value
quantizes the paged pool (gather dequantizes, scatter re-quantizes, the
same HBM holds ~4x the slots at token-level — not bit-level — parity);
the value `dense` additionally covers non-paged transformer engines
(per-(layer, slot, head) scales over the dense rectangles).

When `decode_batch < max_batch` the engine runs a COMPACTED sub-batch
decode: the active slots' cache slices are gathered into a dense
(decode_batch, ...) sub-cache, one static-shaped decode runs over that
width, and the advanced slices are scattered back — so the policy's
batch split saves real per-step FLOPs, not just schedule steps.  Slots
rotate in slot-id order (the cursor is keyed to slot ids, not positions,
so admission/finish churn cannot starve or double-serve a slot).  Set
`compact=False` (or `MOZART_COMPACT_DECODE=0`) for the legacy full-width
round-robin emulation, kept for benchmarking against the PR-4 behavior
(transformer only — recurrent/cross-attn states are always gathered).

A `mesh` with a >1 "model" axis makes the policy's TP degree real:
params and KV cache (dense slabs or page pools) are placed with
`parallel.sharding`'s rules and the jitted prefill/decode run sharded
over the mesh.  `mesh=None` is the single-device no-op path.

Requests carry wall-clock marks (`t_submit`/`t_first`/`t_done`) so the
serving benchmark can report TTFT/TPOT percentiles, and a
`finish_reason` ("eos", "max_new_tokens", "length" at the cache
boundary, "rejected" for prompts that cannot fit, "capacity" when a lone
request exhausts the page pool, "shed" for deadline/overload shedding,
"poison" when a request exhausts its cluster retry budget).

SLO RESILIENCE.  Requests may carry a `deadline_s` (seconds from
submission).  Admission is deadline-aware: the queue drains
earliest-deadline-first (resumed requests keep their front priority so
preemption/failover recovery stays token-exact; FIFO among requests
without deadlines), and a request whose deadline has already passed —
or whose remaining budget cannot fit its remaining tokens at the
engine's measured per-step pace — is SHED at admission
(`finish_reason="shed"`) instead of wasting decode lanes on tokens
nobody can use (`MOZART_DEADLINE_SHED=0` disables the feasibility
check).  `queue_bound` (`MOZART_QUEUE_BOUND`) bounds the queue: a full
queue sheds new submissions instead of growing without bound —
backpressure the cluster router reads to route around hot replicas.
Every decode's logits pass a cheap jitted all-finite guard
(`MOZART_WATCHDOG_NAN`) BEFORE sampling: non-finite logits set
`health["nan_detected"]` and the step emits nothing, so corrupted KV
can never leak garbage tokens — the cluster watchdog quarantines the
replica and the requeue path recovers its requests token-exactly.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import numpy as np

from repro.launch import knobs
from repro.models.config import ModelConfig
from . import paged as paged_kv
from . import resilience
from . import state as state_mod
from .sampling import sample

# re-exports: tests and downstream modules address these through the
# engine module (and monkeypatch _rewind_inactive by this name)
from .state import (_GATHER, _SCATTER, _decode_fn, _gather_slots,  # noqa: F401
                    _prefill_fn, _rewind_inactive, _scatter_slots,
                    _tree_set_slot)

Params = Any


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    # SLO deadline in seconds from t_submit; None = no deadline.  The
    # engine sheds the request at admission when it cannot be met.
    deadline_s: float | None = None
    # whisper: precomputed encoder frame embeddings (F, d_model); other
    # families ignore it.  None encodes a zero (silence) window.
    frames: np.ndarray | None = None
    # cluster routing tag: only replicas whose engine serves this model
    # name may run the request (None = any replica)
    model: str | None = None
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: str | None = None
    # wall-clock marks for TTFT/TPOT accounting (monotonic seconds)
    t_submit: float | None = None
    t_first: float | None = None
    t_done: float | None = None
    admit_seq: int = -1           # engine admission order (preemption picks max)
    requeues: int = 0             # failovers survived (cluster retry budget)


def _rewind_hook(index, inactive):
    """Late-bound module-global lookup so tests monkeypatching
    `engine._rewind_inactive` observe the dense full-width rewind."""
    return _rewind_inactive(index, inactive)


def _kv_quant_mode(kv_quant, paged: bool, mcfg: ModelConfig) -> str:
    """Resolve the engine's KV-quant mode: "paged" (int8 page pool),
    "dense" (int8 dense rectangles), or "" (off).  Any truthy value
    quantizes a paged engine; the explicit value `dense` additionally
    covers dense transformer engines (rings excluded: the stale-position
    zeroing assumes slot j holds position j)."""
    raw = knobs.get_str("MOZART_KV_QUANT") if kv_quant is None else kv_quant
    mode = str(raw).strip().lower()
    if mode in ("0", "", "false", "no", "off"):
        return ""
    if paged:
        return "paged"
    if mode == "dense" and mcfg.family == "transformer" and not mcfg.window:
        return "dense"
    return ""


class ServingEngine:
    def __init__(self, mcfg: ModelConfig, params: Params, *,
                 max_batch: int = 4, max_len: int = 512,
                 decode_batch: int | None = None, eos_id: int = -1,
                 compact: bool | None = None, mesh=None,
                 paged: bool | None = None, page_size: int | None = None,
                 num_pages: int | None = None,
                 kv_quant: bool | str | None = None,
                 enc_len: int | None = None,
                 queue_bound: int | None = None,
                 guard_nan: bool | None = None,
                 shed_deadlines: bool | None = None):
        self.mcfg = mcfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        # Mozart Insight 2: batch-agnostic stages (attention) may want a
        # smaller lock-step decode batch than the slot count; when
        # decode_batch < max_batch only that many active slots advance
        # per step, in slot-id rotation, over a compacted sub-cache.
        self.decode_batch = decode_batch or max_batch
        if compact is None:
            compact = knobs.get_bool("MOZART_COMPACT_DECODE")
        # transformer engines honor the knob; recurrent/cross-attn state
        # cannot be rewound, so their decode is ALWAYS the gathered
        # sub-batch form (see serving.state._LayersState)
        self.compact = compact if mcfg.family == "transformer" else True
        if paged is None:
            paged = knobs.get_bool("MOZART_PAGED_KV")
        # paged + bucketed serving is exact only for the plain transformer
        # cache (no SWA ring, no MoE capacity router) — see paged_supported
        self.paged = paged and paged_kv.paged_supported(mcfg)
        quant_mode = _kv_quant_mode(kv_quant, self.paged, mcfg)
        self.kv_quant = quant_mode == "paged"
        self.kv_quant_dense = quant_mode == "dense"
        self._next_slot = 0           # rotation cursor: a SLOT ID
        self.eos_id = eos_id
        self._admit_counter = 0
        # KV headroom one decode step needs; spec-decode engines write
        # k+1 positions per iteration and raise this accordingly
        self._headroom = 1
        # -- resilience knobs: bounded queue, deadline shedding, NaN guard --
        self.queue_bound = queue_bound if queue_bound is not None \
            else knobs.get_int("MOZART_QUEUE_BOUND")
        self.guard_nan = guard_nan if guard_nan is not None \
            else knobs.get_bool("MOZART_WATCHDOG_NAN")
        self.shed_deadlines = shed_deadlines if shed_deadlines is not None \
            else knobs.get_bool("MOZART_DEADLINE_SHED")
        # a sick engine raises flags here instead of raising exceptions;
        # the cluster watchdog reads them and quarantines the replica
        self.health = {"nan_detected": False}
        # EWMA of step wall time: the deadline-feasibility estimate
        self._est_step_s = 0.0
        if self.paged:
            ps = page_size or knobs.get_int("MOZART_KV_PAGE_SIZE")
            self.state = state_mod.PagedKVState(
                mcfg, max_batch, max_len, decode_batch=self.decode_batch,
                compact=self.compact, page_size=ps, num_pages=num_pages,
                bucket_min=knobs.get_int("MOZART_PREFILL_BUCKET_MIN"),
                quantized=self.kv_quant)
        elif mcfg.family == "whisper":
            self.state = state_mod.CrossAttnState(
                mcfg, max_batch, max_len, decode_batch=self.decode_batch,
                enc_len=enc_len)
        elif mcfg.family == "transformer":
            self.state = state_mod.DenseKVState(
                mcfg, max_batch, max_len, decode_batch=self.decode_batch,
                compact=self.compact, quantized=self.kv_quant_dense,
                rewind_hook=_rewind_hook)
        else:
            self.state = state_mod.RecurrentState(
                mcfg, max_batch, max_len, decode_batch=self.decode_batch)
        self.pool = self.state.pool
        self.buckets = self.state.buckets
        self.capacity = self.state.capacity
        self.mesh = mesh
        if mesh is not None:
            from repro.parallel.sharding import params_shardings
            self.params = jax.device_put(
                params, params_shardings(mesh, params))
            self.state.place(mesh)
        self.slots: list[Request | None] = [None] * max_batch
        self.queue: list[Request] = []
        self.next_token = np.zeros((max_batch, 1), np.int32)
        self.key = jax.random.PRNGKey(0)
        self._decode = _decode_fn(mcfg)
        self._prefill = state_mod._whisper_prefill_fn(mcfg, max_len) \
            if mcfg.family == "whisper" else _prefill_fn(mcfg, max_len)
        self._paged_decode = \
            paged_kv.paged_decode_fn(mcfg, self.kv_quant) if self.paged \
            else None
        self.stats = {"decode_steps": 0, "prefills": 0,
                      "tokens_out": 0, "slot_occupancy": [],
                      "preemptions": 0, "rejected": 0,
                      "shed": 0, "nan_steps": 0}

    @property
    def cache(self):
        """The live model-state pytree (None for paged engines) — owned
        by the DecodeState; exposed for chaos injection and tests."""
        return self.state.cache

    # -- request lifecycle --------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Queue a request; returns False when the bounded queue sheds it
        (`finish_reason="shed"`) instead — backpressure, not growth."""
        if req.t_submit is None:
            req.t_submit = time.monotonic()
        if self.queue_bound > 0 and len(self.queue) >= self.queue_bound:
            self._shed(req)
            return False
        self.queue.append(req)
        return True

    @property
    def queue_full(self) -> bool:
        return self.queue_bound > 0 and len(self.queue) >= self.queue_bound

    def _shed(self, req: Request) -> None:
        req.done = True
        req.finish_reason = "shed"
        req.t_done = time.monotonic()
        self.stats["shed"] += 1

    def _slot_pos(self, b: int) -> int:
        """Cache length of slot b = prompt + decoded-in KV.  The newest
        sampled token is in out_tokens but its KV has not been written
        yet (that happens on its decode step), hence the -1."""
        req = self.slots[b]
        return len(req.prompt) + len(req.out_tokens) - 1

    def _finish(self, b: int, reason: str) -> None:
        req = self.slots[b]
        req.done = True
        if req.finish_reason is None:
            req.finish_reason = reason
        req.t_done = time.monotonic()
        self.slots[b] = None
        self.state.release(b)

    def _preempt(self, b: int) -> None:
        """Evict slot b under page pressure: free its pages and requeue
        it at the front; a later admission re-prefills prompt+output and
        resumes decoding where it stopped."""
        req = self.slots[b]
        self.slots[b] = None
        self.state.release(b)
        self.queue.insert(0, req)
        self.stats["preemptions"] += 1

    def _admission_key(self, j: int) -> tuple:
        """Queue drain order: resumed requests first (their front-of-queue
        priority keeps preemption/failover recovery token-exact), then
        earliest deadline (None sorts last), then submission order — so a
        queue with no deadlines drains exactly like the old FIFO."""
        req = self.queue[j]
        dl = req.deadline_s
        return (0 if req.out_tokens else 1,
                dl if dl is not None else float("inf"), j)

    def _deadline_infeasible(self, req: Request) -> bool:
        """True when `req` can no longer meet its deadline: it already
        expired, or the remaining budget cannot fit the remaining tokens
        at the engine's measured per-step pace (EWMA; until a first
        measurement exists only hard-expired requests are shed)."""
        if not self.shed_deadlines or req.deadline_s is None:
            return False
        now = time.monotonic()
        remaining = (req.t_submit or now) + req.deadline_s - now
        if remaining <= 0:
            return True
        left = max(req.max_new_tokens - len(req.out_tokens), 0)
        return self._est_step_s > 0.0 and self._est_step_s * left > remaining

    def _next_admission(self) -> int | None:
        """Index of the next queue entry to admit (deadline-aware), or
        None when the queue is empty.  Requests that cannot meet their
        deadline any more are shed here — admission control — instead of
        occupying a slot to produce tokens past their SLO."""
        while self.queue:
            j = min(range(len(self.queue)), key=self._admission_key)
            req = self.queue[j]
            if self._deadline_infeasible(req):
                self.queue.pop(j)
                self._shed(req)
                continue
            return j
        return None

    def _admit(self) -> None:
        """Prefill queued requests into free slots (continuous batching).
        Prompts that could never decode a single token inside the cache
        are rejected up front instead of silently overrunning the slot."""
        for b in range(self.max_batch):
            if self.slots[b] is not None or not self.queue:
                continue
            qi = self._next_admission()
            if qi is None:
                break
            req = self.queue[qi]
            resumed = bool(req.out_tokens)
            if resumed:
                # re-prefill everything but the newest token (whose KV
                # would have been written by its decode step)
                seq = np.concatenate([
                    np.asarray(req.prompt, np.int32),
                    np.asarray(req.out_tokens[:-1], np.int32)])
            else:
                seq = np.asarray(req.prompt, np.int32)
            plen = len(seq)
            if plen < 1 or plen + self._headroom > self.capacity:
                self.queue.pop(qi)
                req.done = True
                req.finish_reason = "rejected"
                req.t_done = time.monotonic()
                self.stats["rejected"] += 1
                continue
            if self.paged:
                # +1: the next decode writes KV at position plen
                if not self.pool.ensure(b, plen + 1):
                    break       # pool dry — wait for decode-side frees
                last = self.state.prefill(self._prefill, self.params,
                                          b, seq)
            else:
                last = self._dense_prefill(b, seq, req)
            self.queue.pop(qi)
            self.slots[b] = req
            req.admit_seq = self._admit_counter
            self._admit_counter += 1
            self.stats["prefills"] += 1
            if resumed:
                self.next_token[b, 0] = req.out_tokens[-1]
                continue
            self.key, k = jax.random.split(self.key)
            tok = int(sample(last[0, -1:], k,
                             temperature=req.temperature)[0])
            req.out_tokens.append(tok)
            if req.t_first is None:
                req.t_first = time.monotonic()
            self.next_token[b, 0] = tok
            self.stats["tokens_out"] += 1
            if len(req.out_tokens) >= req.max_new_tokens or \
                    tok == self.eos_id:
                # budget spent at admission — never decode past max_new
                self._finish(b, "eos" if tok == self.eos_id
                             else "max_new_tokens")

    def _dense_prefill(self, b: int, seq: np.ndarray, req: Request):
        """Dense-state prefill hook (SpecDecodeEngine also prefills the
        draft cache here)."""
        return self.state.prefill(self._prefill, self.params, b, seq,
                                  frames=req.frames)

    def _select_active(self, all_active: list[int]) -> list[int]:
        """Pick up to decode_batch slots in slot-id rotation.  The cursor
        is a slot id (not a position into the active list), so slots
        finishing or being admitted between steps cannot re-alias the
        rotation into starving or double-serving a slot."""
        if self.decode_batch >= len(all_active):
            return list(all_active)
        ordered = [b for b in all_active if b >= self._next_slot] + \
                  [b for b in all_active if b < self._next_slot]
        active = ordered[:self.decode_batch]
        self._next_slot = (active[-1] + 1) % self.max_batch
        return active

    # -- decode tick ---------------------------------------------------------
    def step(self) -> int:
        """One lock-step decode over active slots; returns #active."""
        if self.health["nan_detected"]:
            # sick engine: hold all state for the watchdog's quarantine
            # (the requeue path recovers every request token-exactly)
            return 0
        t_step = time.monotonic()
        self._admit()
        live = [b for b, r in enumerate(self.slots) if r is not None]
        # cache-boundary: a slot whose next KV write(s) would land at or
        # past capacity finishes NOW instead of silently overrunning it
        for b in list(live):
            if self._slot_pos(b) + self._headroom > self.capacity:
                self._finish(b, "length")
                live.remove(b)
        if self.paged:
            live = self._grow_pages(live)
        if not live:
            return 0
        active = self._select_active(live)
        if not self._advance(active):
            return 0            # non-finite logits: emitted nothing
        self.stats["decode_steps"] += 1
        self.stats["slot_occupancy"].append(
            len(live) / self.max_batch)
        dt = time.monotonic() - t_step
        # EWMA per-step pace: the deadline-feasibility estimate _admit
        # sheds against (first measurement seeds it directly)
        self._est_step_s = dt if self._est_step_s == 0.0 \
            else 0.8 * self._est_step_s + 0.2 * dt
        return len(active)

    def _advance(self, active: list[int]) -> bool:
        """Decode the active slots one step, guard, sample, finish.
        Returns False when the NaN guard swallowed the step.  Subclasses
        (spec-decode) replace this with multi-token propose/verify."""
        fn = self._paged_decode if self.paged else self._decode
        logits, lane = self.state.decode(fn, self.params,
                                         self.next_token, active)
        if self.guard_nan and not resilience.logits_finite(logits):
            # corrupted KV / sick kernel: emit NOTHING from non-finite
            # logits (garbage tokens would poison the requests' streams
            # beyond token-exact recovery); flag for the watchdog
            self.health["nan_detected"] = True
            self.stats["nan_steps"] += 1
            return False
        for b in active:
            req = self.slots[b]
            self.key, k = jax.random.split(self.key)
            tok = int(sample(logits[lane[b], -1:], k,
                             temperature=req.temperature)[0])
            req.out_tokens.append(tok)
            self.next_token[b, 0] = tok
            self.stats["tokens_out"] += 1
            if len(req.out_tokens) >= req.max_new_tokens or \
                    tok == self.eos_id:
                self._finish(b, "eos" if tok == self.eos_id
                             else "max_new_tokens")
        return True

    def _grow_pages(self, live: list[int]) -> list[int]:
        """Make every live slot's next KV write backed by a page,
        preempting the youngest-admitted slot under pool pressure; a lone
        slot that exhausts the pool finishes with reason "capacity"."""
        for b in list(live):
            while b in live and \
                    not self.pool.ensure(b, self._slot_pos(b) + 1):
                victims = [v for v in live if v != b]
                if not victims:
                    self._finish(b, "capacity")
                    live.remove(b)
                else:
                    v = max(victims,
                            key=lambda s: self.slots[s].admit_seq)
                    self._preempt(v)
                    live.remove(v)
        return live

    def run(self, max_steps: int = 10_000) -> None:
        steps = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and steps < max_steps:
            if self.health["nan_detected"]:
                # a standalone sick engine stops instead of spinning;
                # under a cluster the watchdog quarantines it first
                break
            self.step()
            steps += 1
