"""Serving engine: slot-based continuous batching with the Mozart
operator-level batching policy (Insight 2).

A fixed pool of `max_batch` cache slots decodes in lock-step (static
shapes); finished slots are refilled by prefilling queued requests and
splicing their cache into the slot.  The paper's non-uniform batching
maps here as: decode batch size and prefill parallelism are set from the
Mozart `ExecutionPolicy` (batch-agnostic attention wants small per-op
batch with high TP; batch-sensitive projections want the opposite — the
engine's `decode_batch` honors the policy's compromise).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.config import ModelConfig
from .sampling import sample

Params = Any


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


def _tree_set_slot(batched, single, b: int):
    """Write `single` (batch dim 1 or absent on index leaves) into slot b
    of `batched` along the batch dimension."""
    def leaf(dst, src):
        if dst.ndim == 0:
            return src if src.ndim == 0 else src.reshape(())
        # find the batch dim: first dim where dst differs from src by
        # factor max_batch vs 1 — conventionally dims named (B,...) or
        # (L,B,...) (stacked segments).
        if dst.ndim == src.ndim:
            for axis in range(dst.ndim):
                if src.shape[axis] == 1 and dst.shape[axis] > 1:
                    idx = [slice(None)] * dst.ndim
                    idx[axis] = slice(b, b + 1)
                    return dst.at[tuple(idx)].set(src.astype(dst.dtype))
        return dst
    return jax.tree.map(leaf, batched, single)


class ServingEngine:
    def __init__(self, mcfg: ModelConfig, params: Params, *,
                 max_batch: int = 4, max_len: int = 512,
                 decode_batch: int | None = None, eos_id: int = -1):
        self.mcfg = mcfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        # Mozart Insight 2: batch-agnostic stages (attention) may want a
        # smaller lock-step decode batch than the slot count; when
        # decode_batch < max_batch only that many active slots advance
        # per step, round-robin (the others' cache indices are rolled
        # back exactly like idle slots, so results are unchanged).
        # NOTE: the decode itself is static-shaped over max_batch slots,
        # so on this substrate sub-batching changes the *schedule* (more
        # steps, fewer tokens each), not the per-step compute — it
        # emulates the policy's batching semantics; compute savings need
        # a compacted gather (ROADMAP).
        self.decode_batch = decode_batch or max_batch
        self._rr = 0                  # round-robin cursor for sub-batching
        self.eos_id = eos_id
        self.cache = api.init_cache(mcfg, max_batch, max_len)
        # per-slot cache lengths (vector index -> mixed-length batching)
        self.cache["index"] = jnp.zeros((max_batch,), jnp.int32)
        self.slots: list[Request | None] = [None] * max_batch
        self.queue: list[Request] = []
        self.next_token = np.zeros((max_batch, 1), np.int32)
        self.key = jax.random.PRNGKey(0)
        self._decode = jax.jit(
            lambda p, t, c: api.decode_step(mcfg, p, t, c))
        self._prefill = jax.jit(
            lambda p, toks: api.prefill(mcfg, p, {"tokens": toks}, max_len))
        self.stats = {"decode_steps": 0, "prefills": 0,
                      "tokens_out": 0, "slot_occupancy": []}

    # -- request lifecycle --------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        """Prefill queued requests into free slots (continuous batching)."""
        for b in range(self.max_batch):
            if self.slots[b] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            toks = jnp.asarray(req.prompt[None, :], jnp.int32)
            last, cache1 = self._prefill(self.params, toks)
            idx_vec = self.cache["index"]
            self.cache = _tree_set_slot(self.cache, cache1, b)
            self.cache["index"] = idx_vec.at[b].set(len(req.prompt))
            self.slots[b] = req
            tok = int(jnp.argmax(last[0, -1]))
            req.out_tokens.append(tok)
            self.next_token[b, 0] = tok
            self.stats["prefills"] += 1

    # -- decode tick ---------------------------------------------------------
    def step(self) -> int:
        """One lock-step decode over active slots; returns #active."""
        self._admit()
        all_active = [b for b, r in enumerate(self.slots) if r is not None]
        if not all_active:
            return 0
        if self.decode_batch < len(all_active):
            start = self._rr % len(all_active)
            active = (all_active + all_active)[start:
                                              start + self.decode_batch]
            self._rr += self.decode_batch
        else:
            active = all_active
        logits, new_cache = self._decode(
            self.params, jnp.asarray(self.next_token), self.cache)
        self.cache = new_cache
        self.stats["decode_steps"] += 1
        self.stats["slot_occupancy"].append(
            len(all_active) / self.max_batch)
        # slots not advancing this step must not advance their cache index
        inactive = [b for b in range(self.max_batch) if b not in active]
        if inactive:
            idx = self.cache["index"]
            for b in inactive:
                idx = idx.at[b].add(-1)
            self.cache["index"] = idx
        for b in active:
            req = self.slots[b]
            self.key, k = jax.random.split(self.key)
            tok = int(sample(logits[b, -1:], k,
                             temperature=req.temperature)[0])
            req.out_tokens.append(tok)
            self.next_token[b, 0] = tok
            self.stats["tokens_out"] += 1
            if len(req.out_tokens) >= req.max_new_tokens or \
                    tok == self.eos_id:
                req.done = True
                self.slots[b] = None
        return len(active)

    def run(self, max_steps: int = 10_000) -> None:
        steps = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and steps < max_steps:
            self.step()
            steps += 1
