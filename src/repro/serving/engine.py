"""Serving engine: slot-based continuous batching with the Mozart
operator-level batching policy (Insight 2).

A fixed pool of `max_batch` cache slots decodes in lock-step (static
shapes); finished slots are refilled by prefilling queued requests and
splicing their cache into the slot.  The paper's non-uniform batching
maps here as: decode batch size and prefill parallelism are set from the
Mozart `ExecutionPolicy` (batch-agnostic attention wants small per-op
batch with high TP; batch-sensitive projections want the opposite — the
engine's `decode_batch` honors the policy's compromise).

When `decode_batch < max_batch` the engine runs a COMPACTED sub-batch
decode: the active slots' cache slices are gathered into a dense
(decode_batch, ...) sub-cache, one static-shaped decode runs over that
width, and the advanced slices are scattered back — so the policy's
batch split saves real per-step FLOPs, not just schedule steps.  Slots
rotate in slot-id order (the cursor is keyed to slot ids, not positions,
so admission/finish churn cannot starve or double-serve a slot).  Set
`compact=False` (or `MOZART_COMPACT_DECODE=0`) for the legacy full-width
round-robin emulation, kept for benchmarking against the PR-4 behavior.

A `mesh` with a >1 "model" axis makes the policy's TP degree real:
params and KV cache are placed with `parallel.sharding`'s rules and the
jitted prefill/decode run sharded over the mesh.  `mesh=None` is the
single-device no-op path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import knobs
from repro.models import api
from repro.models.config import ModelConfig
from .sampling import sample

Params = Any


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


def _tree_set_slot(batched, single, b: int):
    """Write `single` (batch dim 1 or absent on index leaves) into slot b
    of `batched` along the batch dimension."""
    def leaf(dst, src):
        if dst.ndim == 0:
            return src if src.ndim == 0 else src.reshape(())
        # find the batch dim: first dim where dst differs from src by
        # factor max_batch vs 1 — conventionally dims named (B,...) or
        # (L,B,...) (stacked segments).
        if dst.ndim == src.ndim:
            for axis in range(dst.ndim):
                if src.shape[axis] == 1 and dst.shape[axis] > 1:
                    idx = [slice(None)] * dst.ndim
                    idx[axis] = slice(b, b + 1)
                    return dst.at[tuple(idx)].set(src.astype(dst.dtype))
        return dst
    return jax.tree.map(leaf, batched, single)


def _gather_slots(cache, sel):
    """Compact the cache slices of slots `sel` into a dense sub-cache.
    Segment leaves are (L, B, C, ...) — batch on axis 1; "index" is (B,)."""
    return {
        "segments": jax.tree.map(lambda a: jnp.take(a, sel, axis=1),
                                 cache["segments"]),
        "index": jnp.take(cache["index"], sel, axis=0),
    }


def _scatter_slots(cache, sub, sel):
    """Write an advanced sub-cache back into slots `sel`.  Padding lanes
    duplicate a real slot with identical content, so repeated indices in
    `sel` write identical values (scatter order is irrelevant)."""
    segs = jax.tree.map(
        lambda full, part: full.at[:, sel].set(part.astype(full.dtype)),
        cache["segments"], sub["segments"])
    idx = cache["index"].at[sel].set(sub["index"])
    return {"segments": segs, "index": idx}


_GATHER = jax.jit(_gather_slots)
# the engine drops the old cache the moment the scatter returns, so the
# full-size buffers are donated — on accelerators the scatter updates in
# place instead of allocating a second (L, max_batch, clen, ...) cache
_SCATTER = jax.jit(_scatter_slots, donate_argnums=(0,))


@functools.lru_cache(maxsize=8)
def _decode_fn(mcfg: ModelConfig):
    """Shared per-config jitted decode (engines with the same config —
    e.g. benchmark variants — reuse one trace cache).  Bounded: a config
    sweep evicts old executables instead of retaining them forever."""
    return jax.jit(lambda p, t, c: api.decode_step(mcfg, p, t, c))


@functools.lru_cache(maxsize=8)
def _prefill_fn(mcfg: ModelConfig, max_len: int):
    return jax.jit(
        lambda p, toks: api.prefill(mcfg, p, {"tokens": toks}, max_len))


class ServingEngine:
    def __init__(self, mcfg: ModelConfig, params: Params, *,
                 max_batch: int = 4, max_len: int = 512,
                 decode_batch: int | None = None, eos_id: int = -1,
                 compact: bool | None = None, mesh=None):
        self.mcfg = mcfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        # Mozart Insight 2: batch-agnostic stages (attention) may want a
        # smaller lock-step decode batch than the slot count; when
        # decode_batch < max_batch only that many active slots advance
        # per step, in slot-id rotation, over a compacted sub-cache.
        self.decode_batch = decode_batch or max_batch
        if compact is None:
            compact = knobs.get_bool("MOZART_COMPACT_DECODE")
        # the gather/scatter helpers know the transformer cache layout
        # ({"segments": [(L, B, C, ...)], "index": (B,)}); other families
        # ({"layers": [(B, ...)]}) fall back to the schedule emulation
        self.compact = compact and mcfg.family == "transformer"
        self._next_slot = 0           # rotation cursor: a SLOT ID
        self.eos_id = eos_id
        self.cache = api.init_cache(mcfg, max_batch, max_len)
        # per-slot cache lengths (vector index -> mixed-length batching)
        self.cache["index"] = jnp.zeros((max_batch,), jnp.int32)
        self.mesh = mesh
        if mesh is not None:
            from repro.parallel.sharding import (cache_shardings,
                                                 params_shardings)
            self.params = jax.device_put(
                params, params_shardings(mesh, params))
            self.cache = jax.device_put(
                self.cache, cache_shardings(mesh, self.cache,
                                            mcfg.kv_heads, max_batch))
        self.slots: list[Request | None] = [None] * max_batch
        self.queue: list[Request] = []
        self.next_token = np.zeros((max_batch, 1), np.int32)
        self.key = jax.random.PRNGKey(0)
        self._decode = _decode_fn(mcfg)
        self._prefill = _prefill_fn(mcfg, max_len)
        self.stats = {"decode_steps": 0, "prefills": 0,
                      "tokens_out": 0, "slot_occupancy": []}

    # -- request lifecycle --------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        """Prefill queued requests into free slots (continuous batching)."""
        for b in range(self.max_batch):
            if self.slots[b] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            toks = jnp.asarray(req.prompt[None, :], jnp.int32)
            last, cache1 = self._prefill(self.params, toks)
            idx_vec = self.cache["index"]
            self.cache = _tree_set_slot(self.cache, cache1, b)
            self.cache["index"] = idx_vec.at[b].set(len(req.prompt))
            self.slots[b] = req
            self.key, k = jax.random.split(self.key)
            tok = int(sample(last[0, -1:], k,
                             temperature=req.temperature)[0])
            req.out_tokens.append(tok)
            self.next_token[b, 0] = tok
            self.stats["prefills"] += 1
            self.stats["tokens_out"] += 1
            if len(req.out_tokens) >= req.max_new_tokens or \
                    tok == self.eos_id:
                req.done = True          # budget spent at admission —
                self.slots[b] = None     # never decode past max_new

    def _select_active(self, all_active: list[int]) -> list[int]:
        """Pick up to decode_batch slots in slot-id rotation.  The cursor
        is a slot id (not a position into the active list), so slots
        finishing or being admitted between steps cannot re-alias the
        rotation into starving or double-serving a slot."""
        if self.decode_batch >= len(all_active):
            return list(all_active)
        ordered = [b for b in all_active if b >= self._next_slot] + \
                  [b for b in all_active if b < self._next_slot]
        active = ordered[:self.decode_batch]
        self._next_slot = (active[-1] + 1) % self.max_batch
        return active

    # -- decode tick ---------------------------------------------------------
    def step(self) -> int:
        """One lock-step decode over active slots; returns #active."""
        self._admit()
        all_active = [b for b, r in enumerate(self.slots) if r is not None]
        if not all_active:
            return 0
        active = self._select_active(all_active)
        if self.compact and self.decode_batch < self.max_batch:
            # compacted sub-batch decode: gather the active slots' cache
            # slices, decode at static width decode_batch, scatter back.
            # Padding lanes (fewer active than decode_batch) repeat the
            # first active slot — identical inputs give identical lane
            # results, so the duplicate scatter writes are idempotent.
            sel = active + [active[0]] * (self.decode_batch - len(active))
            sel_arr = jnp.asarray(sel, jnp.int32)
            sub = _GATHER(self.cache, sel_arr)
            logits, new_sub = self._decode(
                self.params, jnp.asarray(self.next_token[sel]), sub)
            self.cache = _SCATTER(self.cache, new_sub, sel_arr)
            lane: dict[int, int] = {}
            for j, b in enumerate(sel):
                lane.setdefault(b, j)
        else:
            logits, new_cache = self._decode(
                self.params, jnp.asarray(self.next_token), self.cache)
            self.cache = new_cache
            # full-width decode advanced every slot; slots not advancing
            # this step must not advance their cache index
            inactive = [b for b in range(self.max_batch)
                        if b not in active]
            if inactive:
                idx = self.cache["index"]
                for b in inactive:
                    idx = idx.at[b].add(-1)
                self.cache["index"] = idx
            lane = {b: b for b in active}
        self.stats["decode_steps"] += 1
        self.stats["slot_occupancy"].append(
            len(all_active) / self.max_batch)
        for b in active:
            req = self.slots[b]
            self.key, k = jax.random.split(self.key)
            tok = int(sample(logits[lane[b], -1:], k,
                             temperature=req.temperature)[0])
            req.out_tokens.append(tok)
            self.next_token[b, 0] = tok
            self.stats["tokens_out"] += 1
            if len(req.out_tokens) >= req.max_new_tokens or \
                    tok == self.eos_id:
                req.done = True
                self.slots[b] = None
        return len(active)

    def run(self, max_steps: int = 10_000) -> None:
        steps = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and steps < max_steps:
            self.step()
            steps += 1
