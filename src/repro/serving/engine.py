"""Serving engine: slot-based continuous batching with the Mozart
operator-level batching policy (Insight 2).

A fixed pool of `max_batch` cache slots decodes in lock-step (static
shapes); finished slots are refilled by prefilling queued requests and
splicing their cache into the slot.  The paper's non-uniform batching
maps here as: decode batch size and prefill parallelism are set from the
Mozart `ExecutionPolicy` (batch-agnostic attention wants small per-op
batch with high TP; batch-sensitive projections want the opposite — the
engine's `decode_batch` honors the policy's compromise).

KV STORAGE.  By default (`MOZART_PAGED_KV=1`, transformer family without
SWA/MoE) the KV cache is BLOCK-PAGED: fixed-size pages from a shared
pool, owned per-slot through page tables (`serving.paged.PagePool`),
allocated on admission/growth and freed on finish — HBM holds live
tokens, not `max_batch x max_len` rectangles.  Prefill pads prompts to
power-of-two BUCKETS so an arbitrary prompt-length mix compiles at most
`len(engine.buckets)` prefill executables plus one decode executable.
Decode gathers the active slots' pages into the dense layout
`decode_step` expects and scatters back, so paged decode is bit-exact
against the dense cache.  When the free list runs dry the engine
preempts the youngest-admitted slot (requeued at the queue front and
later resumed by re-prefilling its tokens).  `paged=False` (or
`MOZART_PAGED_KV=0`) restores the dense rectangles.  `kv_quant=True`
(`MOZART_KV_QUANT=1`, paged only) stores pages int8 with per-head scales
(`serving.quant`): the gather dequantizes, the scatter re-quantizes, and
the same HBM holds ~4x the slots at token-level (not bit-level) parity.

When `decode_batch < max_batch` the engine runs a COMPACTED sub-batch
decode: the active slots' cache slices are gathered into a dense
(decode_batch, ...) sub-cache, one static-shaped decode runs over that
width, and the advanced slices are scattered back — so the policy's
batch split saves real per-step FLOPs, not just schedule steps.  Slots
rotate in slot-id order (the cursor is keyed to slot ids, not positions,
so admission/finish churn cannot starve or double-serve a slot).  Set
`compact=False` (or `MOZART_COMPACT_DECODE=0`) for the legacy full-width
round-robin emulation, kept for benchmarking against the PR-4 behavior.

A `mesh` with a >1 "model" axis makes the policy's TP degree real:
params and KV cache (dense slabs or page pools) are placed with
`parallel.sharding`'s rules and the jitted prefill/decode run sharded
over the mesh.  `mesh=None` is the single-device no-op path.

Requests carry wall-clock marks (`t_submit`/`t_first`/`t_done`) so the
serving benchmark can report TTFT/TPOT percentiles, and a
`finish_reason` ("eos", "max_new_tokens", "length" at the cache
boundary, "rejected" for prompts that cannot fit, "capacity" when a lone
request exhausts the page pool, "shed" for deadline/overload shedding,
"poison" when a request exhausts its cluster retry budget).

SLO RESILIENCE.  Requests may carry a `deadline_s` (seconds from
submission).  Admission is deadline-aware: the queue drains
earliest-deadline-first (resumed requests keep their front priority so
preemption/failover recovery stays token-exact; FIFO among requests
without deadlines), and a request whose deadline has already passed —
or whose remaining budget cannot fit its remaining tokens at the
engine's measured per-step pace — is SHED at admission
(`finish_reason="shed"`) instead of wasting decode lanes on tokens
nobody can use (`MOZART_DEADLINE_SHED=0` disables the feasibility
check).  `queue_bound` (`MOZART_QUEUE_BOUND`) bounds the queue: a full
queue sheds new submissions instead of growing without bound —
backpressure the cluster router reads to route around hot replicas.
Every decode's logits pass a cheap jitted all-finite guard
(`MOZART_WATCHDOG_NAN`) BEFORE sampling: non-finite logits set
`health["nan_detected"]` and the step emits nothing, so corrupted KV
can never leak garbage tokens — the cluster watchdog quarantines the
replica and the requeue path recovers its requests token-exactly.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import knobs
from repro.models import api
from repro.models.config import ModelConfig
from . import paged as paged_kv
from . import resilience
from .sampling import sample

Params = Any


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    # SLO deadline in seconds from t_submit; None = no deadline.  The
    # engine sheds the request at admission when it cannot be met.
    deadline_s: float | None = None
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: str | None = None
    # wall-clock marks for TTFT/TPOT accounting (monotonic seconds)
    t_submit: float | None = None
    t_first: float | None = None
    t_done: float | None = None
    admit_seq: int = -1           # engine admission order (preemption picks max)
    requeues: int = 0             # failovers survived (cluster retry budget)


def _tree_set_slot(batched, single, b: int):
    """Write `single` (batch dim 1 or absent on index leaves) into slot b
    of `batched` along the batch dimension."""
    def leaf(dst, src):
        if dst.ndim == 0:
            return src if src.ndim == 0 else src.reshape(())
        # find the batch dim: first dim where dst differs from src by
        # factor max_batch vs 1 — conventionally dims named (B,...) or
        # (L,B,...) (stacked segments).
        if dst.ndim == src.ndim:
            for axis in range(dst.ndim):
                if src.shape[axis] == 1 and dst.shape[axis] > 1:
                    idx = [slice(None)] * dst.ndim
                    idx[axis] = slice(b, b + 1)
                    return dst.at[tuple(idx)].set(src.astype(dst.dtype))
        return dst
    return jax.tree.map(leaf, batched, single)


def _gather_slots(cache, sel):
    """Compact the cache slices of slots `sel` into a dense sub-cache.
    Segment leaves are (L, B, C, ...) — batch on axis 1; "index" is (B,)."""
    return {
        "segments": jax.tree.map(lambda a: jnp.take(a, sel, axis=1),
                                 cache["segments"]),
        "index": jnp.take(cache["index"], sel, axis=0),
    }


def _scatter_slots(cache, sub, sel):
    """Write an advanced sub-cache back into slots `sel`.  Padding lanes
    duplicate a real slot with identical content, so repeated indices in
    `sel` write identical values (scatter order is irrelevant)."""
    segs = jax.tree.map(
        lambda full, part: full.at[:, sel].set(part.astype(full.dtype)),
        cache["segments"], sub["segments"])
    idx = cache["index"].at[sel].set(sub["index"])
    return {"segments": segs, "index": idx}


def _rewind_inactive(index, inactive: list[int]):
    """ONE batched scatter-add rewinding every slot that did not advance
    this step (the PR-4 code dispatched a separate `.at[b].add(-1)` per
    inactive slot)."""
    return index.at[jnp.asarray(inactive, jnp.int32)].add(-1)


_GATHER = jax.jit(_gather_slots)
# the engine drops the old cache the moment the scatter returns, so the
# full-size buffers are donated — on accelerators the scatter updates in
# place instead of allocating a second (L, max_batch, clen, ...) cache
_SCATTER = jax.jit(_scatter_slots, donate_argnums=(0,))


@functools.lru_cache(maxsize=8)
def _decode_fn(mcfg: ModelConfig):
    """Shared per-config jitted decode (engines with the same config —
    e.g. benchmark variants — reuse one trace cache).  Bounded: a config
    sweep evicts old executables instead of retaining them forever."""
    return jax.jit(lambda p, t, c: api.decode_step(mcfg, p, t, c))


@functools.lru_cache(maxsize=8)
def _prefill_fn(mcfg: ModelConfig, max_len: int):
    return jax.jit(
        lambda p, toks: api.prefill(mcfg, p, {"tokens": toks}, max_len))


class ServingEngine:
    def __init__(self, mcfg: ModelConfig, params: Params, *,
                 max_batch: int = 4, max_len: int = 512,
                 decode_batch: int | None = None, eos_id: int = -1,
                 compact: bool | None = None, mesh=None,
                 paged: bool | None = None, page_size: int | None = None,
                 num_pages: int | None = None,
                 kv_quant: bool | None = None,
                 queue_bound: int | None = None,
                 guard_nan: bool | None = None,
                 shed_deadlines: bool | None = None):
        self.mcfg = mcfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        # Mozart Insight 2: batch-agnostic stages (attention) may want a
        # smaller lock-step decode batch than the slot count; when
        # decode_batch < max_batch only that many active slots advance
        # per step, in slot-id rotation, over a compacted sub-cache.
        self.decode_batch = decode_batch or max_batch
        if compact is None:
            compact = knobs.get_bool("MOZART_COMPACT_DECODE")
        # the gather/scatter helpers know the transformer cache layout
        # ({"segments": [(L, B, C, ...)], "index": (B,)}); other families
        # ({"layers": [(B, ...)]}) fall back to the schedule emulation
        self.compact = compact and mcfg.family == "transformer"
        if paged is None:
            paged = knobs.get_bool("MOZART_PAGED_KV")
        # paged + bucketed serving is exact only for the plain transformer
        # cache (no SWA ring, no MoE capacity router) — see paged_supported
        self.paged = paged and paged_kv.paged_supported(mcfg)
        if kv_quant is None:
            kv_quant = knobs.get_bool("MOZART_KV_QUANT")
        # int8 KV rides the paged gather/scatter round-trip, so it is
        # paged-only: the dense rectangles silently stay f32
        self.kv_quant = bool(kv_quant) and self.paged
        self._next_slot = 0           # rotation cursor: a SLOT ID
        self.eos_id = eos_id
        self._admit_counter = 0
        # -- resilience knobs: bounded queue, deadline shedding, NaN guard --
        self.queue_bound = queue_bound if queue_bound is not None \
            else knobs.get_int("MOZART_QUEUE_BOUND")
        self.guard_nan = guard_nan if guard_nan is not None \
            else knobs.get_bool("MOZART_WATCHDOG_NAN")
        self.shed_deadlines = shed_deadlines if shed_deadlines is not None \
            else knobs.get_bool("MOZART_DEADLINE_SHED")
        # a sick engine raises flags here instead of raising exceptions;
        # the cluster watchdog reads them and quarantines the replica
        self.health = {"nan_detected": False}
        # EWMA of step wall time: the deadline-feasibility estimate
        self._est_step_s = 0.0
        if self.paged:
            ps = page_size or knobs.get_int("MOZART_KV_PAGE_SIZE")
            self.pool = paged_kv.PagePool(
                mcfg, max_batch, max_len, page_size=ps, num_pages=num_pages,
                quant=self.kv_quant)
            self.buckets = paged_kv.prefill_buckets(
                max_len, knobs.get_int("MOZART_PREFILL_BUCKET_MIN"))
            self.capacity = paged_kv.pool_token_capacity(self.pool, max_len)
            self.cache = None
        else:
            self.pool = None
            self.buckets = ()
            self.capacity = max_len
            self.cache = api.init_cache(mcfg, max_batch, max_len)
            # per-slot cache lengths (vector index -> mixed-length batching)
            self.cache["index"] = jnp.zeros((max_batch,), jnp.int32)
        self.mesh = mesh
        if mesh is not None:
            from repro.parallel.sharding import (cache_shardings,
                                                 paged_cache_shardings,
                                                 params_shardings)
            self.params = jax.device_put(
                params, params_shardings(mesh, params))
            if self.paged:
                self.pool.segments = jax.device_put(
                    self.pool.segments,
                    paged_cache_shardings(mesh, self.pool.segments,
                                          mcfg.kv_heads))
                if self.kv_quant:
                    # scale leaves keep kvh on axis 3 (keepdims layout),
                    # so the same placement rule applies
                    self.pool.scales = jax.device_put(
                        self.pool.scales,
                        paged_cache_shardings(mesh, self.pool.scales,
                                              mcfg.kv_heads))
            else:
                self.cache = jax.device_put(
                    self.cache, cache_shardings(mesh, self.cache,
                                                mcfg.kv_heads, max_batch))
        self.slots: list[Request | None] = [None] * max_batch
        self.queue: list[Request] = []
        self.next_token = np.zeros((max_batch, 1), np.int32)
        self.key = jax.random.PRNGKey(0)
        self._decode = _decode_fn(mcfg)
        self._prefill = _prefill_fn(mcfg, max_len)
        self._paged_decode = \
            paged_kv.paged_decode_fn(mcfg, self.kv_quant) if self.paged \
            else None
        self.stats = {"decode_steps": 0, "prefills": 0,
                      "tokens_out": 0, "slot_occupancy": [],
                      "preemptions": 0, "rejected": 0,
                      "shed": 0, "nan_steps": 0}

    # -- request lifecycle --------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Queue a request; returns False when the bounded queue sheds it
        (`finish_reason="shed"`) instead — backpressure, not growth."""
        if req.t_submit is None:
            req.t_submit = time.monotonic()
        if self.queue_bound > 0 and len(self.queue) >= self.queue_bound:
            self._shed(req)
            return False
        self.queue.append(req)
        return True

    @property
    def queue_full(self) -> bool:
        return self.queue_bound > 0 and len(self.queue) >= self.queue_bound

    def _shed(self, req: Request) -> None:
        req.done = True
        req.finish_reason = "shed"
        req.t_done = time.monotonic()
        self.stats["shed"] += 1

    def _slot_pos(self, b: int) -> int:
        """Cache length of slot b = prompt + decoded-in KV.  The newest
        sampled token is in out_tokens but its KV has not been written
        yet (that happens on its decode step), hence the -1."""
        req = self.slots[b]
        return len(req.prompt) + len(req.out_tokens) - 1

    def _finish(self, b: int, reason: str) -> None:
        req = self.slots[b]
        req.done = True
        if req.finish_reason is None:
            req.finish_reason = reason
        req.t_done = time.monotonic()
        self.slots[b] = None
        if self.paged:
            self.pool.release(b)

    def _preempt(self, b: int) -> None:
        """Evict slot b under page pressure: free its pages and requeue
        it at the front; a later admission re-prefills prompt+output and
        resumes decoding where it stopped."""
        req = self.slots[b]
        self.slots[b] = None
        self.pool.release(b)
        self.queue.insert(0, req)
        self.stats["preemptions"] += 1

    def _admission_key(self, j: int) -> tuple:
        """Queue drain order: resumed requests first (their front-of-queue
        priority keeps preemption/failover recovery token-exact), then
        earliest deadline (None sorts last), then submission order — so a
        queue with no deadlines drains exactly like the old FIFO."""
        req = self.queue[j]
        dl = req.deadline_s
        return (0 if req.out_tokens else 1,
                dl if dl is not None else float("inf"), j)

    def _deadline_infeasible(self, req: Request) -> bool:
        """True when `req` can no longer meet its deadline: it already
        expired, or the remaining budget cannot fit the remaining tokens
        at the engine's measured per-step pace (EWMA; until a first
        measurement exists only hard-expired requests are shed)."""
        if not self.shed_deadlines or req.deadline_s is None:
            return False
        now = time.monotonic()
        remaining = (req.t_submit or now) + req.deadline_s - now
        if remaining <= 0:
            return True
        left = max(req.max_new_tokens - len(req.out_tokens), 0)
        return self._est_step_s > 0.0 and self._est_step_s * left > remaining

    def _next_admission(self) -> int | None:
        """Index of the next queue entry to admit (deadline-aware), or
        None when the queue is empty.  Requests that cannot meet their
        deadline any more are shed here — admission control — instead of
        occupying a slot to produce tokens past their SLO."""
        while self.queue:
            j = min(range(len(self.queue)), key=self._admission_key)
            req = self.queue[j]
            if self._deadline_infeasible(req):
                self.queue.pop(j)
                self._shed(req)
                continue
            return j
        return None

    def _admit(self) -> None:
        """Prefill queued requests into free slots (continuous batching).
        Prompts that could never decode a single token inside the cache
        are rejected up front instead of silently overrunning the slot."""
        for b in range(self.max_batch):
            if self.slots[b] is not None or not self.queue:
                continue
            qi = self._next_admission()
            if qi is None:
                break
            req = self.queue[qi]
            resumed = bool(req.out_tokens)
            if resumed:
                # re-prefill everything but the newest token (whose KV
                # would have been written by its decode step)
                seq = np.concatenate([
                    np.asarray(req.prompt, np.int32),
                    np.asarray(req.out_tokens[:-1], np.int32)])
            else:
                seq = np.asarray(req.prompt, np.int32)
            plen = len(seq)
            if plen < 1 or plen >= self.capacity:
                self.queue.pop(qi)
                req.done = True
                req.finish_reason = "rejected"
                req.t_done = time.monotonic()
                self.stats["rejected"] += 1
                continue
            if self.paged:
                # +1: the next decode writes KV at position plen
                if not self.pool.ensure(b, plen + 1):
                    break       # pool dry — wait for decode-side frees
                last = self._paged_prefill(b, seq)
            else:
                toks = jnp.asarray(seq[None, :], jnp.int32)
                last, cache1 = self._prefill(self.params, toks)
                idx_vec = self.cache["index"]
                self.cache = _tree_set_slot(self.cache, cache1, b)
                self.cache["index"] = idx_vec.at[b].set(plen)
            self.queue.pop(qi)
            self.slots[b] = req
            req.admit_seq = self._admit_counter
            self._admit_counter += 1
            self.stats["prefills"] += 1
            if resumed:
                self.next_token[b, 0] = req.out_tokens[-1]
                continue
            self.key, k = jax.random.split(self.key)
            tok = int(sample(last[0, -1:], k,
                             temperature=req.temperature)[0])
            req.out_tokens.append(tok)
            if req.t_first is None:
                req.t_first = time.monotonic()
            self.next_token[b, 0] = tok
            self.stats["tokens_out"] += 1
            if len(req.out_tokens) >= req.max_new_tokens or \
                    tok == self.eos_id:
                # budget spent at admission — never decode past max_new
                self._finish(b, "eos" if tok == self.eos_id
                             else "max_new_tokens")

    def _paged_prefill(self, b: int, seq: np.ndarray):
        """Bucket-padded prefill of `seq` into slot b's pages; returns
        the (1, 1, V) last-real-token logits."""
        plen = len(seq)
        bucket = paged_kv.bucket_for(plen, self.buckets)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = seq
        fn = paged_kv.paged_prefill_fn(self.mcfg, bucket, self.pool.page_size,
                                       self.kv_quant)
        trow = self.pool.table_row(b, bucket // self.pool.page_size)
        if self.kv_quant:
            last, self.pool.segments, self.pool.scales = fn(
                self.params, toks, plen, self.pool.segments,
                self.pool.scales, trow)
        else:
            last, self.pool.segments = fn(
                self.params, toks, plen, self.pool.segments, trow)
        self.pool.index[b] = plen
        return last

    def _select_active(self, all_active: list[int]) -> list[int]:
        """Pick up to decode_batch slots in slot-id rotation.  The cursor
        is a slot id (not a position into the active list), so slots
        finishing or being admitted between steps cannot re-alias the
        rotation into starving or double-serving a slot."""
        if self.decode_batch >= len(all_active):
            return list(all_active)
        ordered = [b for b in all_active if b >= self._next_slot] + \
                  [b for b in all_active if b < self._next_slot]
        active = ordered[:self.decode_batch]
        self._next_slot = (active[-1] + 1) % self.max_batch
        return active

    # -- decode tick ---------------------------------------------------------
    def step(self) -> int:
        """One lock-step decode over active slots; returns #active."""
        if self.health["nan_detected"]:
            # sick engine: hold all state for the watchdog's quarantine
            # (the requeue path recovers every request token-exactly)
            return 0
        t_step = time.monotonic()
        self._admit()
        live = [b for b, r in enumerate(self.slots) if r is not None]
        # cache-boundary: a slot whose next KV write would land at or past
        # capacity finishes NOW instead of silently overrunning the slot
        for b in list(live):
            if self._slot_pos(b) >= self.capacity:
                self._finish(b, "length")
                live.remove(b)
        if self.paged:
            live = self._grow_pages(live)
        if not live:
            return 0
        active = self._select_active(live)
        if self.paged:
            logits, lane = self._paged_step(active)
        elif self.compact and self.decode_batch < self.max_batch:
            # compacted sub-batch decode: gather the active slots' cache
            # slices, decode at static width decode_batch, scatter back.
            # Padding lanes (fewer active than decode_batch) repeat the
            # first active slot — identical inputs give identical lane
            # results, so the duplicate scatter writes are idempotent.
            sel = active + [active[0]] * (self.decode_batch - len(active))
            sel_arr = jnp.asarray(sel, jnp.int32)
            sub = _GATHER(self.cache, sel_arr)
            logits, new_sub = self._decode(
                self.params, jnp.asarray(self.next_token[sel]), sub)
            self.cache = _SCATTER(self.cache, new_sub, sel_arr)
            lane = {}
            for j, b in enumerate(sel):
                lane.setdefault(b, j)
        else:
            logits, new_cache = self._decode(
                self.params, jnp.asarray(self.next_token), self.cache)
            self.cache = new_cache
            # full-width decode advanced every slot; slots not advancing
            # this step must not advance their cache index (one batched
            # scatter-add, not a per-slot dispatch loop)
            inactive = [b for b in range(self.max_batch)
                        if b not in active]
            if inactive:
                self.cache["index"] = _rewind_inactive(
                    self.cache["index"], inactive)
            lane = {b: b for b in active}
        if self.guard_nan and not resilience.logits_finite(logits):
            # corrupted KV / sick kernel: emit NOTHING from non-finite
            # logits (garbage tokens would poison the requests' streams
            # beyond token-exact recovery); flag for the watchdog
            self.health["nan_detected"] = True
            self.stats["nan_steps"] += 1
            return 0
        self.stats["decode_steps"] += 1
        self.stats["slot_occupancy"].append(
            len(live) / self.max_batch)
        for b in active:
            req = self.slots[b]
            self.key, k = jax.random.split(self.key)
            tok = int(sample(logits[lane[b], -1:], k,
                             temperature=req.temperature)[0])
            req.out_tokens.append(tok)
            self.next_token[b, 0] = tok
            self.stats["tokens_out"] += 1
            if len(req.out_tokens) >= req.max_new_tokens or \
                    tok == self.eos_id:
                self._finish(b, "eos" if tok == self.eos_id
                             else "max_new_tokens")
        dt = time.monotonic() - t_step
        # EWMA per-step pace: the deadline-feasibility estimate _admit
        # sheds against (first measurement seeds it directly)
        self._est_step_s = dt if self._est_step_s == 0.0 \
            else 0.8 * self._est_step_s + 0.2 * dt
        return len(active)

    def _grow_pages(self, live: list[int]) -> list[int]:
        """Make every live slot's next KV write backed by a page,
        preempting the youngest-admitted slot under pool pressure; a lone
        slot that exhausts the pool finishes with reason "capacity"."""
        for b in list(live):
            while b in live and \
                    not self.pool.ensure(b, self._slot_pos(b) + 1):
                victims = [v for v in live if v != b]
                if not victims:
                    self._finish(b, "capacity")
                    live.remove(b)
                else:
                    v = max(victims,
                            key=lambda s: self.slots[s].admit_seq)
                    self._preempt(v)
                    live.remove(v)
        return live

    def _paged_step(self, active: list[int]):
        """One gathered decode over the page pool at a fixed lane width
        (decode_batch when compacting, max_batch for the full-width
        emulation) — a single executable either way."""
        width = self.decode_batch if self.compact else self.max_batch
        sel = active + [active[0]] * (width - len(active))
        tables_sel = self.pool.tables[np.asarray(sel)]
        index_sel = self.pool.index[np.asarray(sel)]
        if self.kv_quant:
            logits, self.pool.segments, self.pool.scales = self._paged_decode(
                self.params, jnp.asarray(self.next_token[sel]),
                self.pool.segments, self.pool.scales, tables_sel, index_sel)
        else:
            logits, self.pool.segments = self._paged_decode(
                self.params, jnp.asarray(self.next_token[sel]),
                self.pool.segments, tables_sel, index_sel)
        # page-table bookkeeping is host-side numpy: advance the lengths
        # here instead of round-tripping them through the device
        self.pool.index[np.asarray(active)] += 1
        lane: dict[int, int] = {}
        for j, b in enumerate(sel):
            lane.setdefault(b, j)
        return logits, lane

    def run(self, max_steps: int = 10_000) -> None:
        steps = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and steps < max_steps:
            if self.health["nan_detected"]:
                # a standalone sick engine stops instead of spinning;
                # under a cluster the watchdog quarantines it first
                break
            self.step()
            steps += 1
