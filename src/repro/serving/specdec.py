"""Speculative decoding (Leviathan et al. [38]; paper §6.2.1 case study).

A small draft model proposes k tokens; the target verifies them in one
batched forward pass.  The draft path is latency-critical while the
verifier is throughput-oriented — exactly the operator-level
latency/throughput split Mozart exploits (draft -> speed-optimized
chiplets, verifier -> throughput-optimized ones).

Two tiers live here:

* the REFERENCE loops — `spec_decode_greedy` is exactly equivalent to
  target-only greedy decoding (the property the tests assert) and
  `spec_decode_sampled` implements the stochastic acceptance rule; both
  re-run full uncached forwards and exist for correctness cross-checks;
* the LIVE engine — `SpecDecodeEngine` co-locates draft and target in
  ONE `ServingEngine` (the paper's fig11 deployment, measured instead of
  analytical): both models keep per-slot KV caches behind
  `serving.state.DenseKVState`, each iteration runs a single jitted
  k-step draft scan (propose) plus a single jitted target
  `decode_window` pass (verify) over the gathered active slots, and
  greedy outputs are token-exact vs the target-only engine — so all the
  admission / deadline / rotation machinery applies unchanged while each
  decode tick lands up to k tokens per slot.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import knobs
from repro.models import api, transformer
from repro.models.config import ModelConfig
from . import state as state_mod
from .engine import Request, ServingEngine
from .state import _GATHER, _SCATTER, _lane_map

Params = Any
Forward = Callable[[jnp.ndarray], jnp.ndarray]   # tokens (1,S) -> logits


@dataclasses.dataclass
class SpecStats:
    iterations: int = 0
    proposed: int = 0
    accepted: int = 0
    bonus: int = 0

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(self.proposed, 1)

    @property
    def tokens_per_iteration(self) -> float:
        return (self.accepted + self.bonus) / max(self.iterations, 1)


def spec_decode_greedy(target_fwd: Forward, draft_fwd: Forward,
                       prompt: np.ndarray, *, k: int = 5,
                       max_new_tokens: int = 32
                       ) -> tuple[np.ndarray, SpecStats]:
    """Greedy speculative decoding; output == greedy decode of target."""
    toks = list(int(t) for t in prompt)
    stats = SpecStats()
    while len(toks) - len(prompt) < max_new_tokens:
        stats.iterations += 1
        # draft proposes k tokens autoregressively (greedy)
        d = list(toks)
        for _ in range(k):
            logits = draft_fwd(jnp.asarray([d], jnp.int32))
            d.append(int(jnp.argmax(logits[0, -1])))
        proposal = d[len(toks):]
        stats.proposed += k
        # target verifies in ONE forward over [toks + proposal]
        logits = target_fwd(jnp.asarray([d], jnp.int32))
        # target's greedy choice at each position that predicts
        # proposal[i] is index len(toks)-1+i
        n_accept = 0
        base = len(toks) - 1
        tgt_choice = np.asarray(jnp.argmax(logits[0], axis=-1))
        for i in range(k):
            if tgt_choice[base + i] == proposal[i]:
                n_accept += 1
            else:
                break
        stats.accepted += n_accept
        toks.extend(proposal[:n_accept])
        # bonus token: target's own prediction at the divergence point
        bonus = int(tgt_choice[base + n_accept])
        toks.append(bonus)
        stats.bonus += 1
        if len(toks) - len(prompt) >= max_new_tokens:
            break
    new = toks[len(prompt):len(prompt) + max_new_tokens]
    return np.asarray(new, np.int32), stats


def spec_decode_sampled(target_fwd: Forward, draft_fwd: Forward,
                        prompt: np.ndarray, key, *, k: int = 5,
                        max_new_tokens: int = 32,
                        temperature: float = 1.0
                        ) -> tuple[np.ndarray, SpecStats]:
    """Stochastic speculative sampling with the p/q acceptance rule —
    distributionally equivalent to sampling from the target alone."""
    toks = list(int(t) for t in prompt)
    stats = SpecStats()

    def probs(fwd, seq):
        lg = fwd(jnp.asarray([seq], jnp.int32))[0].astype(jnp.float32)
        return jax.nn.softmax(lg / temperature, axis=-1)

    while len(toks) - len(prompt) < max_new_tokens:
        stats.iterations += 1
        d = list(toks)
        qs = []
        for _ in range(k):
            q = probs(draft_fwd, d)[-1]
            key, kk = jax.random.split(key)
            t = int(jax.random.categorical(kk, jnp.log(q + 1e-30)))
            qs.append((t, q))
            d.append(t)
        stats.proposed += k
        p_all = probs(target_fwd, d)
        base = len(toks) - 1
        n_accept = 0
        for i, (t, q) in enumerate(qs):
            p = p_all[base + i]
            key, kk = jax.random.split(key)
            r = float(jax.random.uniform(kk))
            if r < min(1.0, float(p[t]) / max(float(q[t]), 1e-30)):
                n_accept += 1
            else:
                # resample from max(0, p - q) normalized
                resid = jnp.maximum(p - q, 0.0)
                resid = resid / jnp.maximum(resid.sum(), 1e-30)
                key, kk = jax.random.split(key)
                bonus = int(jax.random.categorical(
                    kk, jnp.log(resid + 1e-30)))
                break
        stats.accepted += n_accept
        toks.extend(t for t, _ in qs[:n_accept])
        if n_accept == k:       # all accepted: sample bonus from target
            key, kk = jax.random.split(key)
            bonus = int(jax.random.categorical(
                kk, jnp.log(p_all[base + k] + 1e-30)))
        toks.append(bonus)
        stats.bonus += 1
    new = toks[len(prompt):len(prompt) + max_new_tokens]
    return np.asarray(new, np.int32), stats


# -- live in-engine speculative decoding --------------------------------------

@functools.lru_cache(maxsize=8)
def _propose_fn(dcfg: ModelConfig, k: int):
    """ONE jitted executable for the k-step greedy draft scan: starting
    from each lane's pending token, decode k draft steps (writing the
    pending token and the first k-1 proposals into the draft cache) and
    return the (w, k) proposal block.  The gathered sub-cache is donated
    — the scan threads it in place."""
    def run(params, tok, cache):
        def step(carry, _):
            t, c = carry
            logits, c = api.decode_step(dcfg, params, t, c)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            return (nxt, c), nxt[:, 0]
        (_, cache), drafts = jax.lax.scan(step, (tok, cache), None, length=k)
        return jnp.swapaxes(drafts, 0, 1), cache       # (w, k)
    return jax.jit(run, donate_argnums=(2,))


@functools.lru_cache(maxsize=8)
def _verify_fn(mcfg: ModelConfig):
    """ONE jitted executable for the target verify: a k-token
    `decode_window` forward returning the target's greedy choice at every
    window position plus an all-finite health bit (the NaN guard runs on
    device so the host syncs one bool, not the logits)."""
    def run(params, window, cache):
        logits, cache = api.decode_window(mcfg, params, window, cache)
        choice = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (w, k)
        return choice, jnp.isfinite(logits).all(), cache
    return jax.jit(run, donate_argnums=(2,))


class SpecDecodeEngine(ServingEngine):
    """A ServingEngine whose decode tick is a batched propose/verify
    iteration: draft and target are CO-RESIDENT (each with a dense
    per-slot KV cache), every admitted request is prefilled into both,
    and one `step()` lands between 1 and k tokens per active slot.

    Greedy only (the engine rejects `temperature > 0` requests at
    submission): each iteration the draft proposes `k` tokens in one
    jitted scan, the target verifies the k-token window
    [pending, d_1..d_{k-1}] in one jitted `decode_window` pass, the
    longest matching prefix (capped at k-1 so the draft cache always
    holds every consumed position) is accepted, and the target's own
    argmax at the divergence point is the bonus token — so the emitted
    stream is TOKEN-EXACT vs target-only greedy decoding, the property
    `tests` and `bench_specdec`'s gate assert.  Acceptance bookkeeping
    lives in `spec_stats`.

    Restrictions (checked at construction): plain-attention transformer
    target (`transformer.window_supported`), dense un-quantized KV
    (paged growth of two coupled caches is future work).
    """

    def __init__(self, mcfg: ModelConfig, params: Params,
                 draft_cfg: ModelConfig, draft_params: Params, *,
                 k: int | None = None, **kw):
        if not transformer.window_supported(mcfg):
            raise ValueError(
                "SpecDecodeEngine needs a plain-attention transformer "
                f"target (family={mcfg.family}, use_mla={mcfg.use_mla}, "
                f"window={mcfg.window})")
        if not transformer.window_supported(draft_cfg):
            raise ValueError("draft config must be a plain-attention "
                             "transformer too")
        self.k = k if k is not None else knobs.get_int("MOZART_SPEC_K")
        if self.k < 2:
            raise ValueError(f"spec-decode needs k >= 2, got {self.k}")
        kw["paged"] = False
        kw["kv_quant"] = "0"
        super().__init__(mcfg, params, **kw)
        # the verify window writes k KV positions starting at the slot's
        # current length — finish a slot before the window would overrun
        self._headroom = self.k
        self.draft_cfg = draft_cfg
        self.draft_params = draft_params
        self.draft_state = state_mod.DenseKVState(
            draft_cfg, self.max_batch, self.max_len,
            decode_batch=self.decode_batch, compact=True)
        self._draft_prefill = state_mod._prefill_fn(draft_cfg, self.max_len)
        self._propose = _propose_fn(draft_cfg, self.k)
        self._verify = _verify_fn(mcfg)
        self.spec_stats = SpecStats()

    def submit(self, req: Request) -> bool:
        if req.temperature > 0.0:
            raise ValueError(
                "SpecDecodeEngine is greedy-only (temperature=0); "
                f"request {req.rid} has temperature={req.temperature}")
        return super().submit(req)

    def _dense_prefill(self, b: int, seq: np.ndarray, req: Request):
        """Prefill BOTH caches so draft and target share the context."""
        last = self.state.prefill(self._prefill, self.params, b, seq)
        self.draft_state.prefill(self._draft_prefill, self.draft_params,
                                 b, seq)
        return last

    def _advance(self, active: list[int]) -> bool:
        """One propose/verify iteration over the gathered active slots.

        Both sub-caches advance k positions on device; the host then
        rewinds each lane's index to `base + emitted` (stale KV past the
        index is never attended and is overwritten in place by later
        writes).  Padding lanes duplicate `active[0]` and are assigned
        its consumed count, so the duplicate scatter writes stay
        identical (scatter order irrelevant)."""
        k = self.k
        sel = active + [active[0]] * (self.decode_batch - len(active))
        sel_arr = jnp.asarray(sel, jnp.int32)
        tok = jnp.asarray(self.next_token[sel])
        dft_sub = _GATHER(self.draft_state.cache, sel_arr)
        drafts, dft_sub = self._propose(self.draft_params, tok, dft_sub)
        tgt_sub = _GATHER(self.state.cache, sel_arr)
        window = jnp.concatenate([tok, drafts[:, :-1]], axis=1)   # (w, k)
        choice, finite, tgt_sub = self._verify(self.params, window, tgt_sub)
        if self.guard_nan and not bool(finite):
            self.health["nan_detected"] = True
            self.stats["nan_steps"] += 1
            return False        # sub-caches dropped: nothing scattered
        drafts_np = np.asarray(drafts)
        choice_np = np.asarray(choice)
        lane = _lane_map(sel)
        consumed_by_slot: dict[int, int] = {}
        for b in active:
            j = lane[b]
            req = self.slots[b]
            n = 0
            while n < k - 1 and drafts_np[j, n] == choice_np[j, n]:
                n += 1
            emitted = [int(t) for t in drafts_np[j, :n]] + \
                [int(choice_np[j, n])]
            self.spec_stats.iterations += 1
            self.spec_stats.proposed += k - 1
            self.spec_stats.accepted += n
            self.spec_stats.bonus += 1
            # budget / eos truncation: a cut always finishes the slot,
            # so the dropped tail's (already written) KV is never read
            out = emitted[:req.max_new_tokens - len(req.out_tokens)]
            if self.eos_id in out:
                out = out[:out.index(self.eos_id) + 1]
            req.out_tokens.extend(out)
            self.next_token[b, 0] = out[-1]
            self.stats["tokens_out"] += len(out)
            consumed_by_slot[b] = len(out)
            if len(req.out_tokens) >= req.max_new_tokens or \
                    out[-1] == self.eos_id:
                self._finish(b, "eos" if out[-1] == self.eos_id
                             else "max_new_tokens")
        consumed = jnp.asarray([consumed_by_slot[b] for b in sel],
                               jnp.int32)
        tgt_sub = {"segments": tgt_sub["segments"],
                   "index": tgt_sub["index"] - k + consumed}
        dft_sub = {"segments": dft_sub["segments"],
                   "index": dft_sub["index"] - k + consumed}
        self.state.cache = _SCATTER(self.state.cache, tgt_sub, sel_arr)
        self.draft_state.cache = _SCATTER(self.draft_state.cache,
                                          dft_sub, sel_arr)
        return True


def shared_trunk_draft(cfg: ModelConfig, params: Params, n_draft: int
                       ) -> tuple[ModelConfig, Params]:
    """A draft model = the target's first `n_draft` layers with shared
    embed / final norm / head (the standard shared-trunk draft).  Plain
    single-segment transformers only."""
    if cfg.family != "transformer" or cfg.scan_layers or \
            len(params["segments"]) != 1:
        raise ValueError("shared_trunk_draft needs a plain unscanned "
                         "single-segment transformer")
    if not 0 < n_draft < cfg.n_layers:
        raise ValueError(f"n_draft must be in (0, {cfg.n_layers})")
    seg = params["segments"][0]
    kind = next(iter(seg))
    dcfg = cfg.replace(n_layers=n_draft)
    dparams = {**{k: v for k, v in params.items() if k != "segments"},
               "segments": [
                   {kind: jax.tree.map(lambda a: a[:n_draft], seg[kind])}]}
    return dcfg, dparams


def high_tar_pair(cfg: ModelConfig, params: Params, n_draft: int
                  ) -> tuple[Params, ModelConfig, Params]:
    """(target_params, draft_cfg, draft_params) with a 100% token
    acceptance rate BY CONSTRUCTION: the target's residual-stream writes
    past layer `n_draft` are zeroed (`attn.wo` / `mlp.w_out`), so the
    deep target computes the exact same function as its first-`n_draft`-
    layer shared-trunk draft while still paying the full-depth FLOPs.

    This is the controlled experiment `bench_specdec` measures: it
    isolates the SERVING-SIDE spec-decode speedup (k tokens per verify
    pass vs one per decode step) at the paper's high-TAR operating point
    without needing trained checkpoints whose draft actually agrees."""
    dcfg, dparams = shared_trunk_draft(cfg, params, n_draft)
    seg = params["segments"][0]
    kind = next(iter(seg))
    layers = dict(seg[kind])
    attn = dict(layers["attn"])
    attn["wo"] = attn["wo"].at[n_draft:].set(0.0)
    layers["attn"] = attn
    mlp = dict(layers["mlp"])
    mlp["w_out"] = mlp["w_out"].at[n_draft:].set(0.0)
    layers["mlp"] = mlp
    tparams = {**params, "segments": [{kind: layers}]}
    return tparams, dcfg, dparams
