"""Speculative decoding (Leviathan et al. [38]; paper §6.2.1 case study).

A small draft model proposes k tokens; the target verifies them in one
batched forward pass.  The draft path is latency-critical while the
verifier is throughput-oriented — exactly the operator-level
latency/throughput split Mozart exploits (draft -> speed-optimized
chiplets, verifier -> throughput-optimized ones).

`spec_decode_greedy` is exactly equivalent to target-only greedy decoding
(the property the tests assert).  `spec_decode_sampled` implements the
stochastic acceptance rule.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Params = Any
Forward = Callable[[jnp.ndarray], jnp.ndarray]   # tokens (1,S) -> logits


@dataclasses.dataclass
class SpecStats:
    iterations: int = 0
    proposed: int = 0
    accepted: int = 0
    bonus: int = 0

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(self.proposed, 1)

    @property
    def tokens_per_iteration(self) -> float:
        return (self.accepted + self.bonus) / max(self.iterations, 1)


def spec_decode_greedy(target_fwd: Forward, draft_fwd: Forward,
                       prompt: np.ndarray, *, k: int = 5,
                       max_new_tokens: int = 32
                       ) -> tuple[np.ndarray, SpecStats]:
    """Greedy speculative decoding; output == greedy decode of target."""
    toks = list(int(t) for t in prompt)
    stats = SpecStats()
    while len(toks) - len(prompt) < max_new_tokens:
        stats.iterations += 1
        # draft proposes k tokens autoregressively (greedy)
        d = list(toks)
        for _ in range(k):
            logits = draft_fwd(jnp.asarray([d], jnp.int32))
            d.append(int(jnp.argmax(logits[0, -1])))
        proposal = d[len(toks):]
        stats.proposed += k
        # target verifies in ONE forward over [toks + proposal]
        logits = target_fwd(jnp.asarray([d], jnp.int32))
        # target's greedy choice at each position that predicts
        # proposal[i] is index len(toks)-1+i
        n_accept = 0
        base = len(toks) - 1
        tgt_choice = np.asarray(jnp.argmax(logits[0], axis=-1))
        for i in range(k):
            if tgt_choice[base + i] == proposal[i]:
                n_accept += 1
            else:
                break
        stats.accepted += n_accept
        toks.extend(proposal[:n_accept])
        # bonus token: target's own prediction at the divergence point
        bonus = int(tgt_choice[base + n_accept])
        toks.append(bonus)
        stats.bonus += 1
        if len(toks) - len(prompt) >= max_new_tokens:
            break
    new = toks[len(prompt):len(prompt) + max_new_tokens]
    return np.asarray(new, np.int32), stats


def spec_decode_sampled(target_fwd: Forward, draft_fwd: Forward,
                        prompt: np.ndarray, key, *, k: int = 5,
                        max_new_tokens: int = 32,
                        temperature: float = 1.0
                        ) -> tuple[np.ndarray, SpecStats]:
    """Stochastic speculative sampling with the p/q acceptance rule —
    distributionally equivalent to sampling from the target alone."""
    toks = list(int(t) for t in prompt)
    stats = SpecStats()

    def probs(fwd, seq):
        lg = fwd(jnp.asarray([seq], jnp.int32))[0].astype(jnp.float32)
        return jax.nn.softmax(lg / temperature, axis=-1)

    while len(toks) - len(prompt) < max_new_tokens:
        stats.iterations += 1
        d = list(toks)
        qs = []
        for _ in range(k):
            q = probs(draft_fwd, d)[-1]
            key, kk = jax.random.split(key)
            t = int(jax.random.categorical(kk, jnp.log(q + 1e-30)))
            qs.append((t, q))
            d.append(t)
        stats.proposed += k
        p_all = probs(target_fwd, d)
        base = len(toks) - 1
        n_accept = 0
        for i, (t, q) in enumerate(qs):
            p = p_all[base + i]
            key, kk = jax.random.split(key)
            r = float(jax.random.uniform(kk))
            if r < min(1.0, float(p[t]) / max(float(q[t]), 1e-30)):
                n_accept += 1
            else:
                # resample from max(0, p - q) normalized
                resid = jnp.maximum(p - q, 0.0)
                resid = resid / jnp.maximum(resid.sum(), 1e-30)
                key, kk = jax.random.split(key)
                bonus = int(jax.random.categorical(
                    kk, jnp.log(resid + 1e-30)))
                break
        stats.accepted += n_accept
        toks.extend(t for t, _ in qs[:n_accept])
        if n_accept == k:       # all accepted: sample bonus from target
            key, kk = jax.random.split(key)
            bonus = int(jax.random.categorical(
                kk, jnp.log(p_all[base + k] + 1e-30)))
        toks.append(bonus)
        stats.bonus += 1
    new = toks[len(prompt):len(prompt) + max_new_tokens]
    return np.asarray(new, np.int32), stats
