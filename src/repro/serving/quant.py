"""Int8 KV-cache quantization for the paged serving pool.

KV pages are stored as int8 with one float32 scale per (layer, page,
kv-head): a page leaf `(L, P, ps, kvh, hd)` carries scales
`(L, P, 1, kvh, 1)` (MLA latents `(L, P, ps, D)` carry `(L, P, 1, 1)` —
no head dim to resolve).  Symmetric absmax quantization:

    scale = max(|x|) / 127   over the page's positions and head_dim
    q     = clip(round(x / scale), -127, 127)   (int8)
    x'    = q * scale

so the same HBM holds ~4x the KV bytes (scales are ~1/(2*page_size*hd)
overhead).  The paged gather/scatter round-trips through these helpers:
gather dequantizes pages into the f32 dense sub-cache the unchanged
decode math runs over, scatter re-quantizes with FRESH per-page scales —
stale scales never linger, and a page whose absmax shrinks regains
precision.

Per-page scales only work because the ragged prefill scatter zeroes pad
positions (`paged.paged_prefill_fn`): garbage in a page's tail would
inflate its absmax and crush the real tokens' resolution to ~0.

Everything here is pure `jax.numpy` and shape-polymorphic over the page
axis, so the same helpers serve the pool layout `(L, P, ps, ...)` and
the gathered block layout `(L, n, npp, ps, ...)`; all are traceable
inside the jitted paged prefill/decode builders.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

INT8_MAX = 127.0
# floor for the absmax scale: an all-zero page quantizes to zeros instead
# of dividing by zero, and dequantizes back to exact zeros
SCALE_FLOOR = 1e-8


def _reduce_axes(ndim: int, ps_axis: int) -> tuple[int, int]:
    """Scales reduce over the page's position axis and the trailing
    feature axis (head_dim, or the MLA latent dim), keeping the kv-head
    axis (when present) — "per-head scales"."""
    return (ps_axis, ndim - 1)


def page_scales(x, ps_axis: int):
    """Per-(page, head) absmax/127 scales for `x` with positions on
    `ps_axis`; keepdims=True so the result broadcasts against `x`."""
    amax = jnp.max(jnp.abs(x), axis=_reduce_axes(x.ndim, ps_axis), keepdims=True)
    return jnp.maximum(amax / INT8_MAX, SCALE_FLOOR).astype(jnp.float32)


def quantize_block(x, ps_axis: int):
    """(int8 codes, f32 scales) for a page block; symmetric absmax."""
    s = page_scales(x, ps_axis)
    q = jnp.clip(jnp.round(x / s), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, s


def dequantize_block(q, s, dtype=jnp.float32):
    return q.astype(dtype) * s.astype(dtype)


def scale_struct(segments):
    """Zero-initialized scale trees matching a paged pool's segment
    leaves (pool layout: page axis 1, positions axis 2)."""

    def leaf(a):
        shape = list(a.shape)
        for ax in _reduce_axes(a.ndim, 2):
            shape[ax] = 1
        return jnp.zeros(tuple(shape), jnp.float32)

    return jax.tree.map(leaf, segments)


def kv_page_nbytes(mcfg, page_size: int, quant: bool) -> int:
    """HBM bytes one KV page costs (including its scales when `quant`),
    computed from shape structs — nothing is allocated.  The capacity
    story in one number: int8 pages cost ~1/4 of f32 pages, so a fixed
    byte budget holds ~4x the slots."""
    from repro.models import api

    segs = jax.eval_shape(
        lambda: api.init_paged_cache(mcfg, 1, page_size, jnp.int8 if quant else None)
    )
    total = sum(leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(segs))
    if quant:
        scales = jax.eval_shape(lambda: scale_struct(segs))
        total += sum(leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(scales))
    return int(total)


def pages_for_byte_budget(mcfg, budget_bytes: int, page_size: int, quant: bool) -> int:
    """How many allocatable pages (beyond the null page) fit in
    `budget_bytes` of KV HBM — the apples-to-apples pool sizing the
    quant-vs-f32 capacity comparison uses."""
    per = kv_page_nbytes(mcfg, page_size, quant)
    return max(int(budget_bytes) // per - 1, 1)
