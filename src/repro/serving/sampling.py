"""Token sampling: greedy / temperature / top-k / top-p."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits, key, *, temperature: float = 0.0, top_k: int = 0,
           top_p: float = 1.0):
    """logits: (B, V) -> (B,) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lf = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        kth = jax.lax.top_k(lf, top_k)[0][..., -1:]
        lf = jnp.where(lf < kth, -1e30, lf)
    if top_p < 1.0:
        sorted_l = jnp.sort(lf, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_l, axis=-1)
        csum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(csum < top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_l, cutoff_idx, axis=-1)
        lf = jnp.where(lf < cutoff, -1e30, lf)
    return jax.random.categorical(key, lf, axis=-1).astype(jnp.int32)
