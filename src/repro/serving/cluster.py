"""Multi-replica serving cluster: router, load generator, metrics.

The paper's datacenter story (fig10/table2) is about FLEETS of composite
BASICs absorbing heavy traffic; a single `ServingEngine` replica caps
concurrency at its HBM slot count.  `ServingCluster` scales the same
engine out: N replicas share one set of model weights (placed per
replica — over per-replica submeshes carved from the mesh "data" axis by
`parallel.sharding.replica_meshes` when devices allow, plain per-replica
placement otherwise), each replica owns an independent paged KV pool,
and a `Router` spreads requests across them:

* ``round_robin``     — cycle over healthy replicas;
* ``least_loaded``    — most free KV pages (free slots for dense
  engines): admission pressure follows HBM headroom, which is what
  actually gates a paged replica;
* ``shortest_queue``  — join-shortest-queue over queued + in-flight
  requests.

MIXED-FAMILY FLEETS.  `replica_models` gives each replica its own
(config, params) pair — e.g. transformer chat replicas next to rglru
long-context ones next to whisper transcription ones, the heterogeneous
workload mix Mozart composes chiplets for.  A request tagged with
`Request.model` routes only to replicas serving that model name
(untagged requests route anywhere); a tagged request whose replica is
down parks until that replica restarts.  Everything else — failover,
watchdog, chaos, metrics — is family-agnostic because the engines'
`DecodeState` layer is.

Failure injection: `kill_replica(i)` marks a replica unhealthy and
re-routes everything it held — queued requests as-is, in-flight slot
requests through the engine's resume path (re-prefill of
prompt + emitted tokens, continuing from the last sampled token) — onto
the surviving replicas, at the front of their queues.  Greedy decoding
makes the recovery exact: a killed replica's requests finish elsewhere
with the token stream an uninterrupted run would have produced, no
tokens lost or duplicated.

RESILIENCE (serving.resilience).  Each failover spends one unit of a
request's retry budget (`MOZART_RETRY_BUDGET`); a request that keeps
landing on dying replicas is marked `finish_reason="poison"` instead of
being requeued forever — one poison request cannot take the whole fleet
down replica by replica.  Killing the LAST healthy replica no longer
raises: everything it held is PARKED on the cluster (surfaced as
`n_unrouted` in `ClusterMetrics`), submissions during the outage park
too, and `restart_replica(i)` — which rebuilds the replica's engine and
page pool from the stored construction args — rejoins it to the healthy
set and drains the parked queue through the router, completing every
held request token-exactly.  A `Watchdog` runs every cluster step: a
replica that holds work but emits no tokens for
`MOZART_WATCHDOG_STALL_STEPS` steps, or whose engine flagged non-finite
decode logits (`health["nan_detected"]`, see the engine's jitted
guard), is QUARANTINED exactly like `kill_replica`.  `stall_replica` /
`unstall_replica` wedge a replica without killing it (it keeps its work
and makes no progress) — the fault `ChaosSchedule`'s "stall" events
inject and the watchdog must catch.  Bounded per-replica queues
(`MOZART_QUEUE_BOUND`) give backpressure: the router skips full
replicas, and when every healthy replica's queue is full the submission
is shed (`finish_reason="shed"`) instead of buffered without bound.

`LoadGenerator` is an OPEN-LOOP Poisson source (seeded): arrival times
are drawn up front, independent of service times — the arrival process a
fleet sized for heavy traffic actually faces, and the one that exposes
queueing delay that closed-loop (submit-on-completion) driving hides.
Prompts come from `serving.workload`'s Zipf mix, the same deterministic
generator `benchmarks/bench_serving.py` replays.

`ClusterMetrics` samples per-replica queue depth, live slots, and
page-pool occupancy every step, and reduces request timing marks into
aggregate and per-replica TTFT/TPOT p50/p99 plus
preemption/rejection/requeue counts.

The cluster steps replicas round-robin in one host loop (the engines'
jitted work is async-dispatched; on multi-device meshes the replicas'
device programs overlap).  Everything here is host-side orchestration —
no new jitted code, so steady-state serving stays within the engines'
compiled-executable budget.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.launch import knobs
from repro.models.config import ModelConfig

from . import resilience, workload
from .engine import Request, ServingEngine

ROUTER_POLICIES = ("round_robin", "least_loaded", "shortest_queue")


def _free_capacity(eng: ServingEngine) -> int:
    """A replica's admission headroom: free KV pages for paged engines,
    free slots (in page-equivalents they are not, but the ordering is
    what matters) for dense ones."""
    if eng.paged:
        return eng.pool.free_pages
    return sum(1 for s in eng.slots if s is None)


def _queue_load(eng: ServingEngine) -> int:
    return len(eng.queue) + sum(1 for s in eng.slots if s is not None)


class Router:
    """Pluggable request-routing policy over the healthy replicas.
    Ties break on the lowest replica id, so routing is deterministic for
    a fixed submission order."""

    def __init__(self, policy: str | None = None):
        policy = policy or knobs.get_str("MOZART_ROUTER")
        if policy not in ROUTER_POLICIES:
            raise ValueError(
                f"unknown router policy {policy!r}; pick one of {ROUTER_POLICIES}"
            )
        self.policy = policy
        self._rr = 0

    def pick(self, replicas: list[ServingEngine], healthy: list[int]) -> int:
        if not healthy:
            raise RuntimeError("no healthy replicas to route to")
        if self.policy == "round_robin":
            # cycle over replica ids so a dead replica's turn passes to
            # the next healthy one instead of skewing the rotation
            for _ in range(len(replicas)):
                i = self._rr % len(replicas)
                self._rr += 1
                if i in healthy:
                    return i
            return healthy[0]
        if self.policy == "least_loaded":
            return max(healthy, key=lambda i: (_free_capacity(replicas[i]), -i))
        return min(healthy, key=lambda i: (_queue_load(replicas[i]), i))


@dataclasses.dataclass
class LoadGenerator:
    """Seeded open-loop Poisson source over the Zipf prompt mix.

    `rate` is in requests/second of wall-clock driving time; `rate <= 0`
    degenerates to a closed-loop burst (every request due at t=0).
    """

    n_requests: int
    rate: float
    vocab: int
    seed: int = 0
    max_new_tokens: int = 16
    bands: tuple[tuple[int, int], ...] = workload.DEFAULT_BANDS
    # per-request SLO mix (see workload.DEFAULT_DEADLINE_BANDS); None
    # keeps the historical no-deadline trace byte-identical
    deadline_bands: tuple[tuple[float, float] | None, ...] | None = None

    def schedule(self) -> list[tuple[float, Request]]:
        """[(arrival_offset_seconds, request)], arrival-sorted.  One rng
        drives both draws, so a seed pins the entire trace."""
        rng = np.random.default_rng(self.seed)
        reqs = workload.zipf_mix_requests(
            rng,
            self.n_requests,
            self.vocab,
            bands=self.bands,
            max_new_tokens=self.max_new_tokens,
            deadline_bands=self.deadline_bands,
        )
        times = workload.poisson_arrivals(rng, self.n_requests, self.rate)
        return list(zip(times.tolist(), reqs))


class ClusterMetrics:
    """Per-step occupancy time series + request-mark reductions."""

    def __init__(self, n_replicas: int):
        self.n_replicas = n_replicas
        self.series: dict[str, list[tuple[int, ...]]] = {
            "queue_depth": [],
            "live_slots": [],
            "free_pages": [],
        }

    def tick(self, replicas: list[ServingEngine]) -> None:
        self.series["queue_depth"].append(tuple(len(r.queue) for r in replicas))
        self.series["live_slots"].append(
            tuple(sum(1 for s in r.slots if s is not None) for r in replicas)
        )
        self.series["free_pages"].append(
            tuple(r.pool.free_pages if r.paged else 0 for r in replicas)
        )

    @staticmethod
    def _pct_ms(samples: list[float], q: float) -> float:
        return float(np.percentile(np.asarray(samples), q) * 1e3) if samples else 0.0

    @classmethod
    def _latency(cls, reqs: list[Request]) -> dict[str, float]:
        ttft = [r.t_first - r.t_submit for r in reqs if r.t_first is not None]
        tpot = [
            (r.t_done - r.t_first) / (len(r.out_tokens) - 1)
            for r in reqs
            if r.t_done is not None and r.t_first is not None and len(r.out_tokens) > 1
        ]
        with_dl = [
            r
            for r in reqs
            if r.deadline_s is not None
            and r.t_done is not None
            and r.finish_reason not in ("shed", "poison", "rejected")
        ]
        return {
            "ttft_p50_ms": cls._pct_ms(ttft, 50),
            "ttft_p99_ms": cls._pct_ms(ttft, 99),
            "tpot_p50_ms": cls._pct_ms(tpot, 50),
            "tpot_p99_ms": cls._pct_ms(tpot, 99),
            "n_finished": sum(1 for r in reqs if r.t_done is not None),
            "deadline_met": sum(1 for r in with_dl if r.t_done - r.t_submit <= r.deadline_s),
            "deadline_missed": sum(1 for r in with_dl if r.t_done - r.t_submit > r.deadline_s),
        }

    def summary(self, cluster: "ServingCluster") -> dict:
        """Aggregate + per-replica latency percentiles, engine counters,
        and occupancy peaks — the numbers the cluster bench gates on."""
        per_replica = []
        for i, eng in enumerate(cluster.replicas):
            mine = [r for r in cluster.requests if cluster.assignment.get(r.rid) == i]
            row = dict(self._latency(mine))
            row.update(
                replica=i,
                healthy=i in cluster.healthy,
                tokens_out=eng.stats["tokens_out"],
                decode_steps=eng.stats["decode_steps"],
                prefills=eng.stats["prefills"],
                preemptions=eng.stats["preemptions"],
                rejected=eng.stats["rejected"],
            )
            per_replica.append(row)
        agg = dict(self._latency(cluster.requests))
        agg.update(
            n_replicas=len(cluster.replicas),
            router=cluster.router.policy,
            # engines retired by restart_replica fold their counters
            # back in — a rebuild never loses serving history
            tokens_out=sum(r["tokens_out"] for r in per_replica) + cluster._retired["tokens_out"],
            preemptions=sum(r["preemptions"] for r in per_replica)
            + cluster._retired["preemptions"],
            rejected=sum(r["rejected"] for r in per_replica) + cluster._retired["rejected"],
            requeued=cluster.stats["requeued"],
            replica_failures=cluster.stats["replica_failures"],
            # resilience surface: requests currently HELD because no
            # replica is healthy, plus shed/poison/watchdog counters
            n_unrouted=len(cluster.parked),
            shed=cluster.stats["shed"]
            + cluster._retired["shed"]
            + sum(e.stats["shed"] for e in cluster.replicas),
            poisoned=cluster.stats["poisoned"],
            quarantined=cluster.stats["quarantined"],
            restarts=cluster.stats["restarts"],
            goodput_tokens=resilience.goodput_tokens(cluster.requests),
            peak_queue_depth=max(
                (sum(t) for t in self.series["queue_depth"]), default=0
            ),
            min_free_pages=min(
                (min(t) for t in self.series["free_pages"]), default=0
            ),
        )
        return {"aggregate": agg, "per_replica": per_replica}


class ServingCluster:
    """N `ServingEngine` replicas behind one router.

    Every replica is built from the same model config and host params;
    `mesh` (optional) is split along its "data" axis into per-replica
    submeshes, so the policy's TP degree stays intact inside each replica
    while replicas spread across the data axis — `serve --replicas` maps
    the deployment policy onto exactly that layout.
    """

    def __init__(
        self,
        mcfg: ModelConfig,
        params,
        *,
        n_replicas: int | None = None,
        router: Router | str | None = None,
        mesh=None,
        retry_budget: int | None = None,
        watchdog: resilience.Watchdog | None = None,
        replica_models: list[tuple[ModelConfig, object]] | None = None,
        **engine_kwargs,
    ):
        if replica_models is not None:
            n = n_replicas or len(replica_models)
            if len(replica_models) != n:
                raise ValueError(
                    f"replica_models has {len(replica_models)} entries "
                    f"for {n} replicas"
                )
        else:
            n = n_replicas or knobs.get_int("MOZART_REPLICAS")
        if n < 1:
            raise ValueError(f"need at least one replica, got {n}")
        if mesh is not None:
            from repro.parallel.sharding import replica_meshes

            meshes = replica_meshes(mesh, n)
        else:
            meshes = [None] * n
        # restart_replica rebuilds a dead replica's engine (fresh page
        # pool, clean health flags) from exactly these construction args.
        # A MIXED-FAMILY fleet passes `replica_models`: per-replica
        # (config, params) pairs — requests tagged with `Request.model`
        # route only to replicas serving that model name.
        self._mcfg = mcfg
        self._params = params
        self._replica_models = (
            list(replica_models)
            if replica_models is not None
            else [(mcfg, params)] * n
        )
        self._meshes = meshes
        self._engine_kwargs = dict(engine_kwargs)
        self.replicas = [
            ServingEngine(c, p, mesh=meshes[i], **engine_kwargs)
            for i, (c, p) in enumerate(self._replica_models)
        ]
        self.router = router if isinstance(router, Router) else Router(router)
        self.healthy: list[int] = list(range(n))
        self.requests: list[Request] = []
        self.assignment: dict[int, int] = {}  # rid -> serving replica
        self.metrics = ClusterMetrics(n)
        self.retry_budget = (
            retry_budget if retry_budget is not None else knobs.get_int("MOZART_RETRY_BUDGET")
        )
        self.watchdog = watchdog or resilience.Watchdog(n)
        # requests HELD while zero replicas are healthy (total outage):
        # restart_replica drains them; surfaced as n_unrouted in metrics
        self.parked: list[Request] = []
        # chaos-wedged replicas: healthy but skipped by step() — they
        # hold their work and make no progress until the watchdog acts
        self.stalled: set[int] = set()
        self.stats = {
            "requeued": 0,
            "replica_failures": 0,
            "steps": 0,
            "shed": 0,
            "poisoned": 0,
            "quarantined": 0,
            "restarts": 0,
            "unrouted_total": 0,
        }
        # counters of engines retired by restart_replica, folded back
        # into the metrics aggregate so a rebuild never loses history
        self._retired = {"tokens_out": 0, "preemptions": 0, "rejected": 0, "shed": 0}

    # -- request lifecycle ---------------------------------------------------

    def _eligible(self, req: Request, candidates: list[int]) -> list[int]:
        """Replicas allowed to serve `req`: all of `candidates` for an
        untagged request, else only those whose engine serves the tagged
        model name (mixed-family fleets)."""
        if req.model is None:
            return candidates
        return [i for i in candidates if self.replicas[i].mcfg.name == req.model]

    def submit(self, req: Request) -> int:
        """Route one request to a healthy replica; returns its index.
        With zero healthy (and model-eligible) replicas the request is
        PARKED (-1) until a restart; with every healthy queue full it is
        SHED (-1)."""
        if req.t_submit is None:
            req.t_submit = time.monotonic()
        self.requests.append(req)
        eligible = self._eligible(req, self.healthy)
        if not eligible:
            self.parked.append(req)
            self.stats["unrouted_total"] += 1
            return -1
        # backpressure: bounded queues take a replica out of the routable
        # set; a fleet with every queue full sheds instead of buffering
        routable = [i for i in eligible if not self.replicas[i].queue_full]
        if not routable:
            req.done = True
            req.finish_reason = "shed"
            req.t_done = time.monotonic()
            self.stats["shed"] += 1
            return -1
        i = self.router.pick(self.replicas, routable)
        self.assignment[req.rid] = i
        self.replicas[i].submit(req)
        return i

    def _requeue(self, req: Request) -> None:
        """Failover path: spend one retry, then park (no healthy
        replica) or front-queue on a survivor.  A request that exhausts
        its retry budget is POISON — it has now taken down (or been
        caught in) `retry_budget`+1 replicas and must not be given
        another one to crash."""
        req.requeues += 1
        if self.retry_budget >= 0 and req.requeues > self.retry_budget:
            req.done = True
            req.finish_reason = "poison"
            req.t_done = time.monotonic()
            self.stats["poisoned"] += 1
            return
        eligible = self._eligible(req, self.healthy)
        if not eligible:
            self.parked.append(req)
            self.stats["unrouted_total"] += 1
            return
        j = self.router.pick(self.replicas, eligible)
        self.assignment[req.rid] = j
        self.replicas[j].queue.insert(0, req)
        self.stats["requeued"] += 1

    def kill_replica(self, i: int) -> int:
        """Fail replica `i`: requeue everything it held onto the
        survivors (in-flight slots resume via the engines' re-prefill
        path), or PARK it on the cluster when no survivor exists (total
        outage — `restart_replica` drains the parked queue later).
        Returns the number of requests re-routed or parked."""
        if i not in self.healthy:
            return 0
        self.healthy.remove(i)
        eng = self.replicas[i]
        stranded: list[Request] = []
        for b, req in enumerate(eng.slots):
            if req is None:
                continue
            eng.slots[b] = None
            eng.state.release(b)
            stranded.append(req)
        stranded.extend(eng.queue)
        eng.queue.clear()
        # retry priority: a failed-over request goes to the FRONT of its
        # new replica's queue, mirroring the engines' preemption requeue
        for req in stranded:
            if req.done:
                continue
            self._requeue(req)
        self.stats["replica_failures"] += 1
        return len(stranded)

    def restart_replica(self, i: int) -> int:
        """Recover replica `i`: rebuild its engine (fresh page pool,
        clean health flags) from the stored construction args, rejoin
        the healthy set so the router picks it up, and drain any parked
        (total-outage) requests back through the router.  Returns the
        number of parked requests drained."""
        if i in self.healthy:
            return 0
        old = self.replicas[i]
        for key in self._retired:
            self._retired[key] += old.stats[key]
        rcfg, rparams = self._replica_models[i]
        self.replicas[i] = ServingEngine(
            rcfg, rparams, mesh=self._meshes[i], **self._engine_kwargs
        )
        self.healthy.append(i)
        self.healthy.sort()
        self.stalled.discard(i)
        self.watchdog.reset(i)
        self.stats["restarts"] += 1
        parked, self.parked = self.parked, []
        drained = 0
        # front-of-queue priority, original order preserved: the parked
        # requests waited out the outage and resume token-exactly
        for req in reversed(parked):
            if req.done:
                continue
            eligible = self._eligible(req, self.healthy)
            if not eligible:
                # tagged for a model whose replica is still down: keep
                # parking until ITS replica restarts
                self.parked.insert(0, req)
                continue
            j = self.router.pick(self.replicas, eligible)
            self.assignment[req.rid] = j
            self.replicas[j].queue.insert(0, req)
            self.stats["requeued"] += 1
            drained += 1
        return drained

    # -- fault injection / watchdog ------------------------------------------

    def stall_replica(self, i: int) -> None:
        """Wedge replica `i` (chaos): it stays 'healthy' and keeps its
        queue and slots but step() skips it — the hung-host failure mode
        the watchdog must detect by missing token progress."""
        self.stalled.add(i)

    def unstall_replica(self, i: int) -> None:
        self.stalled.discard(i)

    def quarantine(self, i: int, reason: str) -> int:
        """Watchdog action: exactly `kill_replica` (token-exact requeue
        of everything held) plus the quarantine bookkeeping."""
        if i not in self.healthy:
            return 0
        moved = self.kill_replica(i)
        self.stats["quarantined"] += 1
        self.watchdog.events.append((self.stats["steps"], i, reason))
        return moved

    # -- drive loops ---------------------------------------------------------

    @property
    def pending_work(self) -> bool:
        return any(
            self.replicas[i].queue
            or any(s is not None for s in self.replicas[i].slots)
            for i in self.healthy
        )

    def step(self) -> int:
        """One round-robin pass: every healthy, unstalled replica with
        work takes one engine step, then the watchdog scans for sick
        replicas and quarantines them (token-exact requeue).  Returns
        the number of active slots stepped."""
        active = 0
        for i in self.healthy:
            if i in self.stalled:
                continue
            eng = self.replicas[i]
            if eng.queue or any(s is not None for s in eng.slots):
                active += eng.step()
        for i in list(self.healthy):
            reason = self.watchdog.check(i, self.replicas[i])
            if reason is not None:
                self.quarantine(i, reason)
        self.metrics.tick(self.replicas)
        self.stats["steps"] += 1
        return active

    def run(self, max_steps: int = 100_000, chaos=None) -> None:
        """Closed-loop drive to completion.  `chaos` (a
        `resilience.ChaosSchedule`) fires its events keyed to
        `stats["steps"]` before each step.  A TOTAL OUTAGE (zero healthy
        replicas, nothing left that could revive them) returns instead
        of raising: unfinished requests stay parked — surfaced as
        `n_unrouted` — for a later `restart_replica` to drain."""
        steps = 0
        while steps < max_steps:
            if chaos is not None:
                chaos.apply(self, self.stats["steps"])
            if not (self.pending_work or (chaos is not None and chaos.pending)):
                break
            self.step()
            steps += 1

    def drive(self, schedule: list[tuple[float, Request]], max_steps: int = 1_000_000, chaos=None):
        """Open-loop replay: submit each request at (or after) its
        arrival offset while continuously stepping the replicas; idle
        gaps sleep until the next arrival instead of spinning.  `chaos`
        events fire against the step counter, exactly as in `run`."""
        t0 = time.monotonic()
        idx, steps = 0, 0
        n = len(schedule)
        while steps < max_steps:
            if chaos is not None:
                chaos.apply(self, self.stats["steps"])
            now = time.monotonic() - t0
            while idx < n and schedule[idx][0] <= now:
                self.submit(schedule[idx][1])
                idx += 1
            if not (idx < n or self.pending_work or (chaos is not None and chaos.pending)):
                break
            if self.pending_work:
                self.step()
                steps += 1
            elif idx < n:
                time.sleep(min(max(schedule[idx][0] - now, 0.0), 0.05))
            else:
                # only chaos events remain (e.g. a scheduled restart
                # that will drain the parked queue): let them fire
                self.step()
                steps += 1
        return self.metrics.summary(self)
