from . import cluster, engine, paged, quant, sampling, specdec, workload
