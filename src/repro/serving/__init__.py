from . import engine, sampling, specdec
