from .pipeline import DataConfig, DataPipeline, SyntheticLM
