"""Token data pipeline: deterministic, shard-aware, straggler-tolerant.

Synthetic corpus (seeded Zipfian token stream with induced bigram
structure so losses actually go down) or a binary token file.  Batches
are a pure function of (seed, step) — exact resume after preemption needs
no data-loader state, only the step counter from the checkpoint.

Straggler mitigation: a background prefetch thread keeps a bounded queue;
`next_batch(timeout)` falls back to synchronous generation if the
prefetcher stalls (and logs the event) — the training loop never blocks
on a sick host.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    seed: int = 1234
    kind: str = "synthetic"       # synthetic | file
    path: str | None = None
    prefetch: int = 4
    straggler_timeout_s: float = 5.0


class SyntheticLM:
    """Zipfian unigram mixed with a deterministic bigram successor table:
    predictable structure a model can learn in a few hundred steps."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        self._succ = rng.integers(0, v, size=(v,), dtype=np.int32)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self._p = p / p.sum()

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s = cfg.global_batch, cfg.seq_len
        toks = np.empty((b, s + 1), dtype=np.int32)
        toks[:, 0] = rng.choice(cfg.vocab, size=b, p=self._p)
        follow = rng.random((b, s)) < 0.7      # 70% bigram-determined
        rand = rng.choice(cfg.vocab, size=(b, s), p=self._p)
        for t in range(s):
            nxt = self._succ[toks[:, t]]
            toks[:, t + 1] = np.where(follow[:, t], nxt, rand[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class FileLM:
    """Memory-mapped flat int32 token file, strided deterministically."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._data = np.memmap(cfg.path, dtype=np.int32, mode="r")

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        b, s = cfg.global_batch, cfg.seq_len
        n = len(self._data) - (s + 1)
        rng = np.random.default_rng((cfg.seed, step))
        starts = rng.integers(0, n, size=b)
        toks = np.stack([self._data[i:i + s + 1] for i in starts])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


class DataPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._src = FileLM(cfg) if cfg.kind == "file" else SyntheticLM(cfg)
        self._q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        self._next_step = 0
        self.straggler_events = 0

    # -- synchronous API (always available) --
    def batch(self, step: int) -> dict:
        return self._src.batch(step)

    # -- prefetching API --
    def start(self, start_step: int = 0) -> None:
        self._next_step = start_step
        self._stop.clear()

        def worker():
            step = start_step
            while not self._stop.is_set():
                b = self._src.batch(step)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, b), timeout=0.2)
                        break
                    except queue.Full:
                        continue
                step += 1

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def next_batch(self, step: int) -> dict:
        """Prefetched batch for `step`; falls back to synchronous
        generation if the prefetcher is behind (straggler mitigation)."""
        deadline = time.monotonic() + self.cfg.straggler_timeout_s
        while time.monotonic() < deadline:
            try:
                got_step, b = self._q.get(timeout=0.1)
            except queue.Empty:
                continue
            if got_step == step:
                return b
            if got_step > step:       # we resumed behind the prefetcher
                break
        self.straggler_events += 1
        return self._src.batch(step)

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None
