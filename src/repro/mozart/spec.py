"""Declarative deployment specs for the Mozart codesign stack.

A `MozartSpec` names *what* to build — the networks, the deployment
scenario(s) constraining them, the objective, and the search budgets —
and `repro.mozart.compile` turns it into a `Deployment` artifact.  Specs
are plain data: they serialize to JSON (`to_dict` / `from_dict`) and are
echoed verbatim into every compiled artifact, so an artifact always
records the spec that produced it.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.core.fusion import GAConfig, Requirement
from repro.core.operators import OperatorGraph, paper_workloads
from repro.core.pool import SAConfig
from repro.core.scenarios import Scenario, get_scenario

BASELINE_KINDS = ("best_homogeneous", "unconstrained")


def _resolve_scenario(s: str | Scenario | None) -> Scenario | None:
    if isinstance(s, str):
        return get_scenario(s)
    return s


def _scenario_to_jsonable(s: str | Scenario | None) -> str | dict | None:
    if isinstance(s, Scenario):
        return s.to_dict()
    return s


def _scenario_from_jsonable(s: str | dict | None) -> str | Scenario | None:
    if isinstance(s, dict):
        return Scenario.from_dict(s)
    return s


@dataclasses.dataclass(frozen=True)
class NetworkSpec:
    """One network of a deployment spec.

    `workload` is either a `repro.core.operators.paper_workloads` key
    (e.g. "resnet50", "opt66b_decode") or an explicit `OperatorGraph`.
    `role` selects a per-role requirement from role-aware scenarios
    (speculative decoding: "draft" / "target"); an explicit
    `requirement` overrides the scenario entirely.
    """

    workload: str | OperatorGraph
    scenario: str | Scenario | None = None
    role: str = ""
    requirement: Requirement | None = None

    def graph(self, seq: int) -> OperatorGraph:
        if isinstance(self.workload, OperatorGraph):
            return self.workload
        named = paper_workloads(seq=seq)
        try:
            return named[self.workload]
        except KeyError:
            raise KeyError(
                f"unknown workload {self.workload!r}; known: "
                f"{sorted(named)} (or pass an OperatorGraph)"
            ) from None

    def to_dict(self) -> dict:
        w = self.workload
        req = None if self.requirement is None else self.requirement.to_dict()
        return {
            "workload": w.to_dict() if isinstance(w, OperatorGraph) else w,
            "scenario": _scenario_to_jsonable(self.scenario),
            "role": self.role,
            "requirement": req,
        }

    @staticmethod
    def from_dict(d: dict) -> "NetworkSpec":
        w = d["workload"]
        req = d.get("requirement")
        return NetworkSpec(
            workload=OperatorGraph.from_dict(w) if isinstance(w, dict) else w,
            scenario=_scenario_from_jsonable(d.get("scenario")),
            role=d.get("role", ""),
            requirement=None if req is None else Requirement.from_dict(req),
        )


@dataclasses.dataclass(frozen=True)
class ResolvedSpec:
    """A `MozartSpec` lowered to exactly what `run_codesign` consumes."""

    networks: dict[str, OperatorGraph]
    reqs: dict[str, Requirement]
    objective: str
    pool_size: int
    sa: SAConfig
    ga: GAConfig
    baselines: tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class MozartSpec:
    """Declarative input of `repro.mozart.compile`.

    networks   — name -> NetworkSpec | OperatorGraph | workload name
    scenario   — spec-wide scenario (name or object); per-network
                 NetworkSpec.scenario overrides it
    objective  — codesign metric; defaults to the scenario's metric,
                 then "energy"
    pool_size  — Layer-1 chiplet pool size
    seq        — sequence length for named LLM workloads
    sa / ga    — Layer-1 / Layer-2 budgets (defaults: the raised,
                 benchmark-justified budgets)
    baselines  — which comparison designs to compile into the artifact
    workers / executor — evaluation fan-out, folded into `sa`
    """

    networks: Mapping[str, NetworkSpec | OperatorGraph | str]
    scenario: str | Scenario | None = None
    objective: str | None = None
    pool_size: int = 8
    seq: int = 2048
    sa: SAConfig | None = None
    ga: GAConfig | None = None
    baselines: tuple[str, ...] = BASELINE_KINDS
    workers: int | None = None
    executor: str | None = None

    def network_specs(self) -> dict[str, NetworkSpec]:
        """Entries normalized to `NetworkSpec`."""
        out: dict[str, NetworkSpec] = {}
        for name, entry in self.networks.items():
            if isinstance(entry, NetworkSpec):
                out[name] = entry
            else:
                out[name] = NetworkSpec(workload=entry)
        return out

    def scenario_for(self, net: NetworkSpec) -> Scenario | None:
        s = net.scenario if net.scenario is not None else self.scenario
        return _resolve_scenario(s)

    def resolve(self) -> ResolvedSpec:
        if not self.networks:
            raise ValueError("MozartSpec needs at least one network")
        bad = [b for b in self.baselines if b not in BASELINE_KINDS]
        if bad:
            raise ValueError(f"unknown baselines {bad}; known: {BASELINE_KINDS}")
        specs = self.network_specs()
        graphs: dict[str, OperatorGraph] = {}
        reqs: dict[str, Requirement] = {}
        metrics: list[str] = []
        for name, net in specs.items():
            graphs[name] = net.graph(self.seq)
            scen = self.scenario_for(net)
            if net.requirement is not None:
                reqs[name] = net.requirement
            elif scen is not None:
                reqs[name] = scen.requirement_for(net.role)
            else:
                reqs[name] = Requirement()
            if scen is not None:
                metrics.append(scen.metric)
        if self.objective is not None:
            objective = self.objective
        elif not metrics:
            objective = "energy"
        elif len(set(metrics)) == 1:
            objective = metrics[0]
        else:
            raise ValueError(
                f"scenarios disagree on the metric ({sorted(set(metrics))}); "
                f"set MozartSpec.objective explicitly"
            )
        sa = self.sa if self.sa is not None else SAConfig()
        if self.workers is not None:
            sa = dataclasses.replace(sa, workers=self.workers)
        if self.executor is not None:
            sa = dataclasses.replace(sa, executor=self.executor)
        ga = self.ga if self.ga is not None else GAConfig()
        return ResolvedSpec(
            networks=graphs,
            reqs=reqs,
            objective=objective,
            pool_size=self.pool_size,
            sa=sa,
            ga=ga,
            baselines=tuple(self.baselines),
        )

    def to_dict(self) -> dict:
        return {
            "networks": {name: net.to_dict() for name, net in self.network_specs().items()},
            "scenario": _scenario_to_jsonable(self.scenario),
            "objective": self.objective,
            "pool_size": self.pool_size,
            "seq": self.seq,
            "sa": None if self.sa is None else self.sa.to_dict(),
            "ga": None if self.ga is None else self.ga.to_dict(),
            "baselines": list(self.baselines),
            "workers": self.workers,
            "executor": self.executor,
        }

    @staticmethod
    def from_dict(d: dict) -> "MozartSpec":
        sa = d.get("sa")
        ga = d.get("ga")
        return MozartSpec(
            networks={name: NetworkSpec.from_dict(nd) for name, nd in d["networks"].items()},
            scenario=_scenario_from_jsonable(d.get("scenario")),
            objective=d.get("objective"),
            pool_size=d.get("pool_size", 8),
            seq=d.get("seq", 2048),
            sa=None if sa is None else SAConfig.from_dict(sa),
            ga=None if ga is None else GAConfig.from_dict(ga),
            baselines=tuple(d.get("baselines", BASELINE_KINDS)),
            workers=d.get("workers"),
            executor=d.get("executor"),
        )
