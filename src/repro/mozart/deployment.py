"""Compiled deployment artifacts: spec in, serializable `Deployment` out.

`compile(spec)` runs the four-layer Mozart stack (SA pool -> GA fusion ->
iso-latency convex hull -> P&R) for every network of a `MozartSpec`,
extracts one `ExecutionPolicy` per network, compiles the requested
baseline designs, and returns a `Deployment`.  The artifact round-trips
through JSON (`Deployment.save` / `load`): chiplet pool, fusion
solutions, per-stage configs, P&R placements, policies, and baselines
all reload bit-exact, so one codesign run becomes a reusable artifact —
CI can diff it, and `repro.launch.serve --policy <artifact>` consumes it.
With `serve --replicas N` the same artifact drives a multi-replica
`serving.cluster.ServingCluster`: the policy's TP layout is kept intact
inside each replica while the replicas are mapped onto disjoint slices
of the mesh's "data" axis (`parallel.sharding.replica_meshes`).
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Sequence

from repro.core.chiplets import Chiplet
from repro.core.codesign import (
    BasicDesign,
    best_homogeneous_design,
    chiplet_reuse,
    run_codesign,
    unconstrained_design,
)
from repro.core.policy import ExecutionPolicy, policy_from_design

from .spec import MozartSpec

SCHEMA = "mozart-deployment/v1"


@dataclasses.dataclass
class Deployment:
    """The output artifact of one `compile` run.

    designs    — per-network composed BASICs (fusion + stage configs + P&R)
    policies   — per-network execution policies for the JAX substrate
    baselines  — per-network {"best_homogeneous": ..., "unconstrained": ...}
                 comparison designs (entries may be None when infeasible)
    spec       — the declarative `MozartSpec` echo (plain JSON dict)
    """

    objective: str
    pool: list[Chiplet]
    designs: dict[str, BasicDesign]
    policies: dict[str, ExecutionPolicy]
    baselines: dict[str, dict[str, BasicDesign | None]]
    spec: dict

    # -- accessors ------------------------------------------------------

    @property
    def networks(self) -> list[str]:
        return list(self.designs)

    def pool_labels(self) -> list[str]:
        return [c.label for c in self.pool]

    def chiplet_reuse(self) -> dict[str, int]:
        """How many BASIC designs use each pool chiplet (NRE sharing);
        keys in pipeline-stage order, deterministic across runs."""
        return chiplet_reuse(self.designs.values())

    def policy(self, network: str | None = None) -> ExecutionPolicy:
        """One network's policy; with one network the name is optional."""
        if network is None:
            if len(self.policies) != 1:
                raise ValueError(
                    f"deployment has {len(self.policies)} policies "
                    f"({sorted(self.policies)}); name one"
                )
            return next(iter(self.policies.values()))
        return self.policies[network]

    def best_homogeneous(self, network: str) -> BasicDesign | None:
        return self.baselines.get(network, {}).get("best_homogeneous")

    def unconstrained(self, network: str) -> BasicDesign | None:
        return self.baselines.get(network, {}).get("unconstrained")

    # -- paper-style metric reductions ----------------------------------

    def metrics(self) -> dict[str, dict[str, float]]:
        return {name: d.metrics for name, d in self.designs.items()}

    def summary(self) -> dict:
        """Per-network objective values and baseline ratios, plus the
        ecosystem geomean — the numbers the paper's tables report.

        vs_best_homogeneous > 1 means the composed BASIC beats the best
        single-SKU accelerator by that factor; vs_unconstrained >= 1 is
        the price of the shared pool vs unlimited chiplet variety.
        """
        per: dict[str, dict] = {}
        logsum = 0.0
        for name, d in self.designs.items():
            v = d.fusion.value
            logsum += math.log(max(v, 1e-30))
            row: dict = {
                "value": v,
                "energy_per_sample": d.fusion.solution.energy_per_sample,
                "throughput": d.fusion.solution.throughput,
                "pnr_feasible": d.pnr.feasible,
            }
            homog = self.best_homogeneous(name)
            if homog is not None:
                row["vs_best_homogeneous"] = homog.fusion.value / v
            unc = self.unconstrained(name)
            if unc is not None:
                row["vs_unconstrained"] = v / unc.fusion.value
            per[name] = row
        n = max(len(self.designs), 1)
        return {
            "objective": self.objective,
            "geomean_value": math.exp(logsum / n),
            "per_network": per,
            "chiplet_reuse": self.chiplet_reuse(),
        }

    # -- (de)serialization ----------------------------------------------

    def to_dict(self) -> dict:
        return {
            "schema": SCHEMA,
            "objective": self.objective,
            "spec": self.spec,
            "pool": [c.to_dict() for c in self.pool],
            "designs": {n: d.to_dict() for n, d in self.designs.items()},
            "policies": {n: p.to_dict() for n, p in self.policies.items()},
            "baselines": {
                n: {kind: None if d is None else d.to_dict() for kind, d in per.items()}
                for n, per in self.baselines.items()
            },
        }

    @staticmethod
    def from_dict(d: dict) -> "Deployment":
        schema = d.get("schema")
        if schema != SCHEMA:
            raise ValueError(
                f"not a mozart deployment artifact (schema={schema!r}, "
                f"expected {SCHEMA!r})"
            )
        return Deployment(
            objective=d["objective"],
            pool=[Chiplet.from_dict(c) for c in d["pool"]],
            designs={n: BasicDesign.from_dict(b) for n, b in d["designs"].items()},
            policies={n: ExecutionPolicy.from_dict(p) for n, p in d["policies"].items()},
            baselines={
                n: {
                    kind: None if b is None else BasicDesign.from_dict(b)
                    for kind, b in per.items()
                }
                for n, per in d["baselines"].items()
            },
            spec=d.get("spec", {}),
        )

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    def save(self, path: str | os.PathLike) -> str:
        path = os.fspath(path)
        with open(path, "w", encoding="utf-8") as f:
            f.write(self.to_json())
            f.write("\n")
        return path


def compile(spec: MozartSpec) -> Deployment:
    """Run the full four-layer stack for a declarative spec.

    Raises RuntimeError when any network of the spec has no feasible
    design under its requirement — an artifact is only produced when the
    whole ecosystem closed.
    """
    rs = spec.resolve()
    result = run_codesign(
        rs.networks,
        objective=rs.objective,
        pool_size=rs.pool_size,
        reqs=rs.reqs,
        sa=rs.sa,
        final_ga=rs.ga,
    )
    missing = sorted(set(rs.networks) - set(result.designs))
    if missing:
        raise RuntimeError(
            f"no feasible design for {missing} under objective "
            f"{rs.objective!r}; relax the requirement or raise budgets"
        )
    policies = {name: policy_from_design(d) for name, d in result.designs.items()}
    baselines: dict[str, dict[str, BasicDesign | None]] = {}
    for name, graph in rs.networks.items():
        per: dict[str, BasicDesign | None] = {}
        if "best_homogeneous" in rs.baselines:
            per["best_homogeneous"] = best_homogeneous_design(
                graph,
                objective=rs.objective,
                req=rs.reqs[name],
                ga=rs.ga,
            )
        if "unconstrained" in rs.baselines:
            per["unconstrained"] = unconstrained_design(
                graph,
                objective=rs.objective,
                req=rs.reqs[name],
                ga=rs.ga,
            )
        baselines[name] = per
    return Deployment(
        objective=rs.objective,
        pool=list(result.pool),
        designs=dict(result.designs),
        policies=policies,
        baselines=baselines,
        spec=spec.to_dict(),
    )


def load(path: str | os.PathLike) -> Deployment:
    """Reload a saved `Deployment` artifact."""
    with open(os.fspath(path), encoding="utf-8") as f:
        return Deployment.from_dict(json.load(f))


def load_policy(
    path: str | os.PathLike,
    network: str | None = None,
) -> ExecutionPolicy:
    """A policy from either a full deployment artifact or a bare
    `ExecutionPolicy.to_json` file.

    With a deployment artifact and no `network`, a single-network
    artifact yields its only policy; multi-network artifacts require the
    name.  Bare policy files ignore `network`.
    """
    with open(os.fspath(path), encoding="utf-8") as f:
        blob = json.load(f)
    if blob.get("schema") == SCHEMA:
        return Deployment.from_dict(blob).policy(network)
    return ExecutionPolicy.from_dict(blob)
