"""The unified Mozart deployment API.

One declarative flow from scenario to running engine:

    from repro import mozart

    spec = mozart.MozartSpec(
        networks={"resnet50": "resnet50", "vit": "vit_b16"},
        scenario="av_33ms",
        pool_size=4,
    )
    dep = mozart.compile(spec)       # four-layer codesign -> artifact
    dep.save("deployment.json")      # reusable, JSON, bit-exact reload
    dep.summary()                    # paper-style metric reductions

    # later / elsewhere:
    dep = mozart.load("deployment.json")
    pol = dep.policy("resnet50")     # feeds `serve --policy`

Scenarios come from `repro.core.scenarios` (chatbot, summarization,
av_10ms, av_33ms, spec_decode); `NetworkSpec(role="draft")` selects
per-role requirements from role-aware scenarios.
"""

from repro.core.scenarios import SCENARIOS, Scenario, get_scenario

from .deployment import Deployment, compile, load, load_policy
from .spec import BASELINE_KINDS, MozartSpec, NetworkSpec, ResolvedSpec

__all__ = [
    "BASELINE_KINDS",
    "Deployment",
    "MozartSpec",
    "NetworkSpec",
    "ResolvedSpec",
    "SCENARIOS",
    "Scenario",
    "compile",
    "get_scenario",
    "load",
    "load_policy",
]
