"""rwkv6-3b "Finch" [arXiv:2404.05892; hf] — attention-free with
data-dependent decay.  32L d_model=2560 d_ff=8960 vocab=65536,
head_dim=64 (40 heads)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", family="rwkv6",
        n_layers=32, d_model=2560, n_heads=40, kv_heads=40, head_dim=64,
        d_ff=8960, vocab=65536, wkv_chunk=32)
