"""qwen2.5-32b [hf:Qwen/Qwen2.5 family] — GQA with QKV bias.
64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b", family="transformer",
        n_layers=64, d_model=5120, n_heads=40, kv_heads=8, head_dim=128,
        d_ff=27648, vocab=152064, swiglu=True, qkv_bias=True,
        rope_theta=1000000.0)
