"""qwen2-vl-2b [arXiv:2409.12191; hf] — VLM text backbone with M-RoPE;
dynamic-resolution vision frontend is a STUB (input_specs() provides
patch embeddings).  28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b", family="transformer",
        n_layers=28, d_model=1536, n_heads=12, kv_heads=2, head_dim=128,
        d_ff=8960, vocab=151936, swiglu=True, qkv_bias=True,
        mrope_sections=(16, 24, 24), frontend="vision",
        rope_theta=1000000.0)
