"""h2o-danube-1.8b [arXiv:2401.16818; hf] — llama+mistral mix with
sliding-window attention. 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b", family="transformer",
        n_layers=24, d_model=2560, n_heads=32, kv_heads=8, head_dim=80,
        d_ff=6912, vocab=32000, swiglu=True, window=4096,
        rope_theta=10000.0)
