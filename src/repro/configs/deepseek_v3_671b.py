"""deepseek-v3-671b [arXiv:2412.19437; hf] — MLA latent attention,
1 shared + 256 routed experts top-8, MTP.  61L d_model=7168 128H
d_ff=2048 (per the assignment) vocab=129280."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="transformer",
        n_layers=61, d_model=7168, n_heads=128, kv_heads=128, head_dim=128,
        d_ff=2048, vocab=129280, swiglu=True,
        n_experts=256, top_k=8, n_shared_experts=1, first_dense_layers=3,
        moe_d_ff=2048, mla_q_rank=1536, mla_kv_rank=512, mla_rope_dim=64,
        mtp=True, rope_theta=10000.0)
