"""whisper-base [arXiv:2212.04356] — enc-dec audio backbone; the conv
frame frontend is a STUB (input_specs() provides frame embeddings).
6L enc + 6L dec, d_model=512 8H d_ff=2048 vocab=51865, LayerNorm+GELU."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="whisper",
        n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, kv_heads=8,
        head_dim=64, d_ff=2048, vocab=51865, norm="layernorm",
        swiglu=False, frontend="audio", dec_seq_factor=4)
