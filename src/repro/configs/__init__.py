"""Architecture registry: the 10 assigned archs (+ the paper's own
workloads live in repro.core.operators).  `--arch <id>` everywhere
resolves through ARCHS.

Shapes (assignment): every arch pairs with the LM shape set below.
`decode_*`/`long_*` lower `serve_step` (one token against a seq_len
cache); `long_500k` only runs for sub-quadratic archs (SWA / SSM /
hybrid) — skips are recorded per arch and documented in DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig, smoke_config

_MODULES = {
    "h2o-danube-1.8b": ".h2o_danube_1_8b",
    "smollm-135m": ".smollm_135m",
    "internlm2-1.8b": ".internlm2_1_8b",
    "qwen2.5-32b": ".qwen2_5_32b",
    "mixtral-8x7b": ".mixtral_8x7b",
    "deepseek-v3-671b": ".deepseek_v3_671b",
    "qwen2-vl-2b": ".qwen2_vl_2b",
    "recurrentgemma-2b": ".recurrentgemma_2b",
    "whisper-base": ".whisper_base",
    "rwkv6-3b": ".rwkv6_3b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(_MODULES[arch], __name__)
    cfg = mod.config()
    cfg.validate()
    return cfg


def get_smoke_config(arch: str) -> ModelConfig:
    return smoke_config(get_config(arch))


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}

# Sub-quadratic context handling => long_500k is runnable.
LONG_CONTEXT_OK = {
    "h2o-danube-1.8b": True,       # SWA: O(S*W)
    "smollm-135m": False,          # full attention
    "internlm2-1.8b": False,
    "qwen2.5-32b": False,
    "mixtral-8x7b": True,          # SWA
    "deepseek-v3-671b": False,     # MLA compresses KV but is still O(S^2)
    "qwen2-vl-2b": False,
    "recurrentgemma-2b": True,     # RG-LRU state + 2k-window local attn
    "whisper-base": False,         # enc-dec full attention
    "rwkv6-3b": True,              # linear recurrence, O(1) state
}


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; skips filtered unless asked."""
    out = []
    for arch in ARCH_IDS:
        for shape in SHAPES.values():
            skipped = shape.name == "long_500k" and not LONG_CONTEXT_OK[arch]
            if skipped and not include_skipped:
                continue
            out.append((arch, shape.name))
    return out
