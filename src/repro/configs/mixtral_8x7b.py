"""mixtral-8x7b [arXiv:2401.04088; hf] — 8-expert top-2 MoE with SWA.
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="transformer",
        n_layers=32, d_model=4096, n_heads=32, kv_heads=8, head_dim=128,
        d_ff=14336, vocab=32000, swiglu=True, window=4096,
        n_experts=8, top_k=2, rope_theta=1000000.0)
