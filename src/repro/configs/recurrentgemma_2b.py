"""recurrentgemma-2b [arXiv:2402.19427; hf] — Griffin: RG-LRU recurrent
blocks + local attention in a 1:2 pattern.  26L d_model=2560 10H
(MQA kv=1) d_ff=7680 vocab=256000, lru_width=2560, window=2048."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="rglru",
        n_layers=26, d_model=2560, n_heads=10, kv_heads=1, head_dim=256,
        d_ff=7680, vocab=256000, lru_width=2560, attn_every=3,
        window=2048, conv_width=4)
