"""internlm2-1.8b [arXiv:2403.17297; hf] — GQA dense transformer.
24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b", family="transformer",
        n_layers=24, d_model=2048, n_heads=16, kv_heads=8, head_dim=128,
        d_ff=8192, vocab=92544, swiglu=True, rope_theta=1000000.0)
