"""smollm-135m [hf:HuggingFaceTB/SmolLM-135M] — llama-arch small model.
30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152, tied embeddings."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m", family="transformer",
        n_layers=30, d_model=576, n_heads=9, kv_heads=3, head_dim=64,
        d_ff=1536, vocab=49152, swiglu=True, tie_embeddings=True,
        rope_theta=10000.0)
