from . import loop, optimizer
