"""Training loop: jit'd step (with microbatch gradient accumulation and
optional int8 gradient compression), sharded via pjit when a mesh is
given, checkpoint/resume, and failure-injection hooks for the
fault-tolerance tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, DataPipeline
from repro.models import api
from repro.models.config import ModelConfig
from repro.parallel import compression
from repro.parallel.sharding import data_shardings, params_shardings
from .optimizer import OptimizerConfig, apply_opt, init_opt

Params = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    microbatches: int = 1
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    ckpt_keep: int = 3
    grad_compression: bool = False
    seed: int = 0


def make_train_step(mcfg: ModelConfig, ocfg: OptimizerConfig,
                    tcfg: TrainConfig) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    n_micro = tcfg.microbatches

    def loss_of(params, batch):
        return api.loss_fn(mcfg, params, batch)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            def micro(carry, mb):
                acc, lsum = carry
                l, g = jax.value_and_grad(loss_of)(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, lsum + l), None

            split = jax.tree.map(
                lambda x: x.reshape(n_micro, x.shape[0] // n_micro,
                                    *x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), split)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = lsum / n_micro

        if tcfg.grad_compression:
            ef = opt_state["error_feedback"]
            grads, ef = compression.compressed_gradients(grads, ef)
            inner = opt_state["inner"]
        else:
            ef = None
            inner = opt_state["inner"]

        params, inner, gnorm = apply_opt(ocfg, grads, inner, params)
        new_state = {"inner": inner}
        if ef is not None:
            new_state["error_feedback"] = ef
        elif "error_feedback" in opt_state:
            new_state["error_feedback"] = opt_state["error_feedback"]
        return params, new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def init_train_state(mcfg: ModelConfig, ocfg: OptimizerConfig,
                     tcfg: TrainConfig, key) -> tuple[Params, Params]:
    params = api.init_params(mcfg, key)
    opt_state: dict = {"inner": init_opt(ocfg, params)}
    if tcfg.grad_compression:
        opt_state["error_feedback"] = \
            compression.init_error_feedback(params)
    return params, opt_state


def train(mcfg: ModelConfig, ocfg: OptimizerConfig, tcfg: TrainConfig,
          dcfg: DataConfig, *, mesh: Mesh | None = None,
          fail_at_step: int | None = None,
          log_fn: Callable[[str], None] = print) -> dict:
    """Run (or resume) a training job.  Returns summary metrics.

    fail_at_step: raise after that step's checkpoint (fault-injection for
    the restart tests)."""
    step_fn = make_train_step(mcfg, ocfg, tcfg)
    params, opt_state = init_train_state(
        mcfg, ocfg, tcfg, jax.random.PRNGKey(tcfg.seed))

    if mesh is not None:
        pshard = params_shardings(mesh, params)
        oshard = jax.tree.map(
            lambda x: NamedSharding(mesh, P(*([None] * x.ndim))),
            opt_state)
        params = jax.device_put(params, pshard)
        opt_state = jax.device_put(opt_state, oshard)
        # jitted once per train() invocation and reused every step
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))  # mzc: ignore[MZC013]
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))  # mzc: ignore[MZC013]

    ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.ckpt_keep) \
        if tcfg.ckpt_dir else None
    start_step = 0
    if ckpt is not None and ckpt.latest_step() is not None:
        (params, opt_state), meta = ckpt.restore((params, opt_state))
        start_step = int(meta["next_step"])
        log_fn(f"[train] resumed from step {start_step}")

    data = DataPipeline(dcfg)
    data.start(start_step)
    losses = []
    t0 = time.monotonic()
    try:
        for step in range(start_step, tcfg.steps):
            batch = {k: jnp.asarray(v)
                     for k, v in data.next_batch(step).items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
                l = float(metrics["loss"])
                losses.append((step, l))
                log_fn(f"[train] step={step} loss={l:.4f} "
                       f"gnorm={float(metrics['grad_norm']):.3f}")
            if ckpt is not None and (step + 1) % tcfg.ckpt_every == 0:
                ckpt.save(step + 1, (params, opt_state),
                          meta={"next_step": step + 1})
            if fail_at_step is not None and step + 1 >= fail_at_step:
                raise RuntimeError(f"injected failure at step {step + 1}")
    finally:
        data.stop()
    if ckpt is not None:
        ckpt.save(tcfg.steps, (params, opt_state),
                  meta={"next_step": tcfg.steps})
    return {"losses": losses, "params": params,
            "wall_s": time.monotonic() - t0,
            "straggler_events": data.straggler_events}
