"""Optimizers (pure JAX, no optax): AdamW, Adafactor, global-norm clip,
LR schedules, and optional int8 optimizer-state quantization.

Adafactor exists specifically for the 671B-class configs where AdamW's
two fp32 moments exceed the per-chip HBM budget (see EXPERIMENTS.md
§Dry-run memory notes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"           # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"      # cosine | linear | constant
    moment_dtype: str = "float32" # float32 | bfloat16 (memory saver)


def lr_at(cfg: OptimizerConfig, step) -> jnp.ndarray:
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), \
        norm


# --- AdamW ------------------------------------------------------------------

def adamw_init(cfg: OptimizerConfig, params: Params) -> Params:
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: OptimizerConfig, grads, state, params):
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    t = step.astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    def upd(g, mu, nu, p):
        g = g.astype(jnp.float32)
        mu_n = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu_n = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = mu_n / c1
        nhat = nu_n / c2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_n = p.astype(jnp.float32) - lr * delta
        return (p_n.astype(p.dtype), mu_n.astype(mu.dtype),
                nu_n.astype(nu.dtype))

    out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}


# --- Adafactor (factored second moment; no first moment) ---------------------

def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 2 and shape[-2] >= 2


def adafactor_init(cfg: OptimizerConfig, params: Params) -> Params:
    def mk(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"v": jax.tree.map(mk, params,
                              is_leaf=lambda x: hasattr(x, "shape")),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: OptimizerConfig, grads, state, params):
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    beta2 = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

    def upd(g, v, p):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if _factored(p.shape):
            vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(-1)
            vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(-2)
            denom = (vr / jnp.maximum(vr.mean(-1, keepdims=True), 1e-30)
                     )[..., None] * vc[..., None, :]
            update = g * jax.lax.rsqrt(denom + 1e-30)
            v_n = {"vr": vr, "vc": vc}
        else:
            vv = beta2 * v["v"] + (1 - beta2) * g2
            update = g * jax.lax.rsqrt(vv + 1e-30)
            v_n = {"v": vv}
        # update clipping (RMS<=1) as in the paper
        rms = jnp.sqrt(jnp.mean(update * update) + 1e-30)
        update = update / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * update).astype(p.dtype), v_n)

    out = jax.tree.map(upd, grads, state["v"], params,
                       is_leaf=lambda x: isinstance(x, dict)
                       and ("vr" in x or "v" in x))
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"v": new_v, "step": step}


# --- facade ------------------------------------------------------------------

def init_opt(cfg: OptimizerConfig, params: Params) -> Params:
    return adafactor_init(cfg, params) if cfg.name == "adafactor" \
        else adamw_init(cfg, params)


def apply_opt(cfg: OptimizerConfig, grads, state, params):
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    if cfg.name == "adafactor":
        new_p, new_s = adafactor_update(cfg, grads, state, params)
    else:
        new_p, new_s = adamw_update(cfg, grads, state, params)
    return new_p, new_s, gnorm
