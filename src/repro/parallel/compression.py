"""Gradient compression for the DP all-reduce (distributed-optimization
trick): per-tensor int8 quantization with error feedback.

Usage inside a shard_map'd or pmap'd step:
    q, scale = quantize(g + err)
    q_sum    = lax.psum(q.astype(f32), axis)      # 4x fewer wire bytes
    g_hat    = dequantize(q_sum, scale_max) / n
    err_new  = (g + err) - dequantize(q, scale)

Under pjit/SPMD the all-reduce is compiler-inserted, so `compressed_mean`
exposes the same math as a drop-in for the gradient tree; error feedback
state rides in the optimizer state.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = Any


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_tree(grads: Params, err: Params
                  ) -> tuple[Params, Params, Params]:
    """(quantized ints, scales, new error feedback)."""
    def one(g, e):
        ge = g.astype(jnp.float32) + e
        q, s = quantize_int8(ge)
        return q, s, ge - dequantize_int8(q, s)
    out = jax.tree.map(one, grads, err)
    is_t = lambda x: isinstance(x, tuple)
    return (jax.tree.map(lambda o: o[0], out, is_leaf=is_t),
            jax.tree.map(lambda o: o[1], out, is_leaf=is_t),
            jax.tree.map(lambda o: o[2], out, is_leaf=is_t))


def decompress_tree(q: Params, scales: Params) -> Params:
    return jax.tree.map(dequantize_int8, q, scales)


def compressed_gradients(grads: Params, err: Params
                         ) -> tuple[Params, Params]:
    """Quantize-dequantize the gradient tree with error feedback: what the
    wire would carry under int8 DP all-reduce.  Returns (g_hat, err_new).
    """
    q, s, err_new = compress_tree(grads, err)
    return decompress_tree(q, s), err_new
