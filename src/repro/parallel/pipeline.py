"""GPipe-style pipeline parallelism over a named mesh axis via shard_map +
collective_permute (the paper's deep-pipeline architecture template,
Fig. 4, mapped onto jax-native constructs per DESIGN.md).

The model's repeated layer stack is split into `n_stages` contiguous
stages placed along the `pp` mesh axis; microbatches stream through with
a steady-state schedule of depth n_stages + n_micro - 1.  Double
buffering in the paper maps to XLA's overlap of the collective_permute
with the next microbatch's compute.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

Params = Any


def split_stages(stacked_params: Params, n_stages: int) -> Params:
    """(L, ...) stacked layer params -> (n_stages, L/n_stages, ...)."""
    def re(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape(n_stages, l // n_stages, *x.shape[1:])
    return jax.tree.map(re, stacked_params)


def pipeline_apply(layer_fn: Callable, stage_params: Params, x,
                   *, mesh: Mesh, axis: str = "pp"):
    """Run x (n_micro, mb, ...) through the pipeline on `axis`.

    layer_fn(params_slice, h) -> h applies this stage's layer block.
    stage_params leaves have leading dim n_stages (sharded over `axis`).
    Returns outputs in microbatch order, (n_micro, mb, ...).
    """
    n_stages = mesh.shape[axis]

    def staged(params, xs):
        # params: this stage's slice (leading dim 1); xs: all microbatches
        params = jax.tree.map(lambda a: a[0], params)
        stage = jax.lax.axis_index(axis)
        n_micro = xs.shape[0]
        total = n_micro + n_stages - 1
        buf = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, cur = carry
            # stage 0 injects microbatch t (when valid)
            inject = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, n_micro - 1), 0, keepdims=False)
            h_in = jnp.where(stage == 0, inject, cur)
            h_out = layer_fn(params, h_in)
            # last stage collects its result at position t - (n_stages-1)
            pos = t - (n_stages - 1)
            valid = (pos >= 0) & (stage == n_stages - 1)
            buf = jax.lax.cond(
                valid,
                lambda b: jax.lax.dynamic_update_index_in_dim(
                    b, h_out, jnp.clip(pos, 0, n_micro - 1), 0),
                lambda b: b, buf)
            # shift activations downstream
            nxt = jax.lax.ppermute(
                h_out, axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return buf, nxt

        buf, _ = jax.lax.fori_loop(
            0, total, tick, (buf, jnp.zeros_like(xs[0])))
        # broadcast the last stage's buffer to all stages
        out = jax.lax.psum(
            jnp.where(stage == n_stages - 1, buf, jnp.zeros_like(buf)),
            axis)
        return out

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    fn = shard_map(staged, mesh=mesh,
                   in_specs=(pspec, P()), out_specs=P(),
                   check_rep=False)
    return fn(stage_params, x)
