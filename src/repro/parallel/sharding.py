"""Sharding rules: one place that maps every parameter / activation /
cache leaf to a PartitionSpec over the ("pod", "data", "model") mesh.

Conventions (TP over "model", DP over ("pod","data"), EP = experts over
"model", SP = long-context sequence sharding where batch cannot shard):

  * attention: wq/wuq sharded on the head (output) dim, wo on the input
    dim; wk/wv sharded when kv_dim divides the model axis, else
    replicated (GQA with few kv heads).
  * MLP: w_in/w_gate on d_ff, w_out on d_ff (input dim).
  * MoE: experts_* sharded on the expert dim (EP).
  * embed/head: vocab-sharded.
  * batch dims of activations/caches over ("pod","data"); dims only shard
    when divisible (`_div` guard) — otherwise replicate and let the
    roofline show the cost.

Stacked-layer params have a leading layer axis which never shards.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, (tuple, list)):
        return int(np.prod([axis_size(mesh, n) for n in name]))
    return mesh.shape[name] if name in mesh.shape else 1


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


DP_AXES = ("pod", "data")


def dp_axes(mesh: Mesh):
    return tuple(a for a in DP_AXES if a in mesh.shape) or None


# --- parameter rules --------------------------------------------------------

# (regex on the '/'-joined path, spec builder given (mesh, shape)).
# Specs are written for the UNSTACKED layer shape; a leading stacked-layer
# dim is detected by rank and padded with None.
#
# fsdp=True additionally shards the chosen dim over the DP axes (ZeRO-3
# style): required for 100B+ params where TP-only replication overflows
# HBM.  The axis candidates are tried widest-first with a divisibility
# guard.

def _shard_axis(mesh, dim: int, fsdp: bool):
    cands = ([("pod", "data", "model"), ("data", "model"), "model"]
             if fsdp else ["model"])
    for c in cands:
        names = c if isinstance(c, tuple) else (c,)
        if all(n in mesh.shape for n in names) and \
                _div(dim, axis_size(mesh, c)):
            return c
    return None


def _param_rules():
    def col(mesh, shape, fsdp):     # shard last dim
        return P(*([None] * (len(shape) - 1)),
                 _shard_axis(mesh, shape[-1], fsdp))

    def row(mesh, shape, fsdp):     # shard first-of-matrix dim
        return P(_shard_axis(mesh, shape[0], fsdp),
                 *([None] * (len(shape) - 1)))

    def expert_in(mesh, shape, fsdp):   # (E, d, f): EP, else TP on f
        ax = _shard_axis(mesh, shape[0], fsdp)
        if ax is not None:
            return P(ax, *([None] * (len(shape) - 1)))
        # §Perf: E < mesh axis (e.g. mixtral 8e on 16-way model) would
        # replicate ~90GB of expert weights per device; shard d_ff.
        return P(None, *([None] * (len(shape) - 2)),
                 _shard_axis(mesh, shape[-1], False))

    def expert_out(mesh, shape, fsdp):  # (E, f, d): EP, else TP on f
        ax = _shard_axis(mesh, shape[0], fsdp)
        if ax is not None:
            return P(ax, *([None] * (len(shape) - 1)))
        return P(None, _shard_axis(mesh, shape[1], False),
                 *([None] * (len(shape) - 2)))

    def repl(mesh, shape, fsdp):
        return P(*([None] * len(shape)))

    return [
        (r"(^|/)embed$", row),                      # (V, d) vocab-sharded
        (r"(^|/)head$", col),                       # (d, V)
        (r"(^|/)dec_pos$", repl),
        (r"/attn/w(q|uq)$", col),
        (r"/attn/w(k|v)$", col),
        (r"/attn/wo$", row),
        (r"/attn/w(dq|dkv)$", repl),
        (r"/attn/w(uk|uv)$", col),
        (r"/(self_attn|cross_attn)/w[qkv]$", col),
        (r"/(self_attn|cross_attn)/wo$", row),
        (r"/mlp/w_(in|gate)$", col),
        (r"/mlp/w_out$", row),
        (r"/moe/experts_(in|gate)$", expert_in),
        (r"/moe/experts_out$", expert_out),
        (r"/moe/router$", repl),
        (r"/moe/shared/w_(in|gate)$", col),
        (r"/moe/shared/w_out$", row),
        # rglru
        (r"/rec/w_(x|gate)$", col),
        (r"/rec/w_out$", row),
        (r"/rec/(wa|wx_in)$", col),
        (r"/rec/(conv_w|conv_b|lam)$", repl),
        # rwkv6
        (r"/att/w[rkvg]$", col),
        (r"/att/wo$", row),
        (r"/att/w[ab]$", repl),
        (r"/ffn/wk$", col),
        (r"/ffn/wv$", row),
        (r"/ffn/wr$", col),
        (r"/mtp/proj$", repl),
    ]


_RULES = _param_rules()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(mesh: Mesh, path: str, shape, stacked: bool,
               fsdp: bool = False) -> P:
    base_shape = shape[1:] if stacked else shape
    for pat, fn in _RULES:
        if re.search(pat, path):
            spec = fn(mesh, base_shape, fsdp)
            if stacked:
                spec = P(None, *spec)
            return spec
    return P(*([None] * len(shape)))


def param_spec_map(mesh: Mesh, params_shape: Any,
                   fsdp: bool = False) -> dict[str, P]:
    """path string -> PartitionSpec for every param leaf."""
    out = {}
    for path, x in jax.tree_util.tree_flatten_with_path(params_shape)[0]:
        ps = _path_str(path)
        stacked = "segments/" in ps and hasattr(x, "ndim") and x.ndim >= 1
        out[ps] = param_spec(mesh, ps, x.shape, stacked, fsdp)
    return out


def params_shardings(mesh: Mesh, params_shape: Any,
                     fsdp: bool = False) -> Any:
    """NamedShardings for a params pytree (of arrays or ShapeDtypeStructs).
    Leaves under a 'segments'/'layers' list of stacked layer params get a
    leading unsharded layer dim iff their rank exceeds the rule's shape."""
    def leaf(path, x):
        ps = _path_str(path)
        stacked = "segments/" in ps and hasattr(x, "ndim") and x.ndim >= 1
        return NamedSharding(mesh,
                             param_spec(mesh, ps, x.shape, stacked, fsdp))
    return jax.tree_util.tree_map_with_path(leaf, params_shape)


def optimizer_shardings(mesh: Mesh, params_shape: Any, opt_shape: Any,
                        fsdp: bool = False) -> Any:
    """Shardings for the optimizer-state tree: AdamW moments mirror their
    parameter's spec; Adafactor factored stats drop the corresponding
    spec dim (vr drops last, vc drops second-to-last)."""
    pmap = param_spec_map(mesh, params_shape, fsdp)

    def leaf(path, x):
        ps = _path_str(path)
        rest, tail = ps, None
        for prefix in ("inner/mu/", "inner/nu/", "inner/v/",
                       "error_feedback/"):
            if ps.startswith(prefix):
                rest = ps[len(prefix):]
                break
        for t in ("/vr", "/vc", "/v"):
            if rest.endswith(t):
                tail, rest = t, rest[: -len(t)]
                break
        spec = pmap.get(rest)
        if spec is None:
            return NamedSharding(mesh, P(*([None] * x.ndim)))
        parts = list(spec)
        if tail == "/vr":
            parts = parts[:-1]
        elif tail == "/vc":
            parts = parts[:-2] + parts[-1:]
        parts = (parts + [None] * x.ndim)[: x.ndim]
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(leaf, opt_shape)


# --- activation / batch / cache rules ----------------------------------------

def batch_spec(mesh: Mesh, batch_size: int, ndim: int) -> P:
    dp = dp_axes(mesh)
    if dp and _div(batch_size, axis_size(mesh, dp)):
        return P(dp, *([None] * (ndim - 1)))
    return P(*([None] * ndim))


def data_shardings(mesh: Mesh, batch: Any) -> Any:
    """Shard every leaf's leading (batch) dim over DP axes when divisible;
    embeds (B,S,d) likewise."""
    def leaf(x):
        return NamedSharding(mesh, batch_spec(mesh, x.shape[0], x.ndim))
    return jax.tree.map(leaf, batch)


def cache_shardings(mesh: Mesh, cache: Any, kv_heads: int,
                    batch_size: int, seq_shard: bool = False) -> Any:
    """KV caches: batch over DP; head dim over model when divisible; for
    batch=1 long-context cells, shard the sequence dim over "data" (SP).

    seq_shard (§Perf): when kv heads cannot shard over "model" (GQA with
    few kv heads), shard the cache LENGTH dim over "model" instead —
    attention becomes a sequence-parallel partial-softmax reduction and
    per-device cache traffic drops by the model-axis size."""
    dp = dp_axes(mesh)
    dsz = axis_size(mesh, dp) if dp else 1
    msz = axis_size(mesh, "model")

    def leaf(path, x):
        ps = _path_str(path)
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        dims: list = [None] * x.ndim
        # layouts: (L,B,C,kvh,hd) | (B,C,kvh,hd) | (L,B,C,D) | (B,H,D,D)...
        bdim = 1 if ("segments" in ps and x.ndim >= 3) else 0
        if _div(x.shape[bdim], dsz) and x.shape[bdim] > 1 and dp:
            dims[bdim] = dp
        elif x.ndim > bdim + 1 and _div(x.shape[bdim + 1], dsz) and dp \
                and x.shape[bdim] == 1 and x.shape[bdim + 1] >= dsz:
            dims[bdim + 1] = dp            # SP on the cache length dim
        # shard kv-head-ish dim on model when divisible
        assigned = False
        for i in range(x.ndim - 2, x.ndim):
            if i > bdim and dims[i] is None and _div(x.shape[i], msz) \
                    and x.shape[i] >= msz and i != x.ndim - 1:
                dims[i] = "model"
                assigned = True
                break
        cdim = bdim + 1
        if seq_shard and not assigned and x.ndim >= cdim + 2 \
                and dims[cdim] is None and _div(x.shape[cdim], msz) \
                and x.shape[cdim] >= 4 * msz:
            dims[cdim] = "model"
        return NamedSharding(mesh, P(*dims))
    return jax.tree_util.tree_map_with_path(leaf, cache)


def paged_cache_shardings(mesh: Mesh, pool_segments: Any,
                          kv_heads: int) -> Any:
    """Paged KV page pools: leaves are (L, pages, page_size, kvh, hd) —
    or an MLA latent (L, pages, page_size, D).  Only the kv-head dim
    TP-shards (over "model", when divisible); the page dims never shard,
    because pages form one global pool addressed through per-slot tables
    and splitting the pool would turn every table lookup into a
    cross-device gather.  The dense sub-caches the engine gathers out of
    the pool then inherit the same head sharding `cache_shardings` would
    have assigned, so the decode math shards identically to the dense
    path."""
    msz = axis_size(mesh, "model")

    def leaf(x):
        dims: list = [None] * x.ndim
        if x.ndim == 5 and x.shape[3] == kv_heads \
                and _div(kv_heads, msz) and kv_heads >= msz:
            dims[3] = "model"
        return NamedSharding(mesh, P(*dims))
    return jax.tree.map(leaf, pool_segments)


# --- multi-replica serving ----------------------------------------------------

def replica_meshes(mesh: Mesh | None, n: int) -> list:
    """Split `mesh` into `n` per-replica submeshes along its "data" axis.

    This is how `serve --replicas N` maps the serving cluster onto the
    deployment policy's mesh: each replica keeps the full "model" (TP)
    and "pod" extent — so the policy's TP degree stays intact inside a
    replica — while the data axis is carved into N equal blocks, one per
    replica.  Params placed with `params_shardings` on a submesh are
    replicated across that replica's data block (no param rule shards
    over "data" unless fsdp), which is exactly the cluster contract:
    N replicas of the same weights, independent KV pools.

    `mesh=None` (single-device serving) returns `[None] * n`; `n == 1`
    returns the mesh unchanged.  A mesh whose data axis does not divide
    by `n` is an error — silently replicating would double-book devices.
    """
    if mesh is None:
        return [None] * n
    if n == 1:
        return [mesh]
    names = list(mesh.axis_names)
    if "data" not in names:
        raise ValueError(
            f"mesh {names} has no 'data' axis to split {n} replicas over")
    d_ax = names.index("data")
    dsz = mesh.devices.shape[d_ax]
    if dsz % n != 0:
        raise ValueError(
            f"data axis of size {dsz} does not divide into {n} replicas")
    chunk = dsz // n
    out = []
    for i in range(n):
        sl: list = [slice(None)] * mesh.devices.ndim
        sl[d_ax] = slice(i * chunk, (i + 1) * chunk)
        out.append(Mesh(mesh.devices[tuple(sl)], mesh.axis_names))
    return out
