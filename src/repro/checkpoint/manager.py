"""Fault-tolerant checkpointing: atomic writes, keep-latest-K, exact
resume, and ELASTIC re-sharding (a checkpoint saved on mesh A restores
onto mesh B — checkpoints store fully-replicated numpy leaves plus the
tree structure, and placement is re-derived from the target mesh's
sharding rules at restore time).

Layout (one directory per step):
    <dir>/step_000042.tmp/...   -> atomically renamed to step_000042/
        index.msgpack           tree structure + dtypes + shapes + meta
        arr_000000.npy ...      one file per leaf
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import struct
from typing import Any

import jax
import numpy as np

try:
    import msgpack
    _HAVE_MSGPACK = True
except Exception:
    _HAVE_MSGPACK = False

Params = Any


def _tree_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        out.append(("/".join(parts), leaf))
    return out


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)

    # -- save -----------------------------------------------------------
    def save(self, step: int, tree: Params, meta: dict | None = None) -> str:
        name = f"step_{step:09d}"
        tmp = os.path.join(self.directory, name + ".tmp")
        final = os.path.join(self.directory, name)
        if os.path.exists(final):      # idempotent: step already published
            return final
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = _tree_paths(tree)
        index = {"step": step, "meta": meta or {}, "leaves": []}
        for i, (path, leaf) in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            fn = f"arr_{i:06d}.npy"
            np.save(os.path.join(tmp, fn), arr)
            index["leaves"].append({"path": path, "file": fn,
                                    "dtype": str(arr.dtype),
                                    "shape": list(arr.shape)})
        blob = (msgpack.packb(index) if _HAVE_MSGPACK
                else json.dumps(index).encode())
        with open(os.path.join(tmp, "index.msgpack"), "wb") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, final)                      # atomic publish
        self._gc()
        return final

    # -- restore ----------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template: Params, step: int | None = None,
                shardings: Params | None = None) -> tuple[Params, dict]:
        """Restore into the structure of `template`.  If `shardings` is
        given (a pytree of NamedSharding matching template), leaves are
        device_put with those shardings — this is the elastic-reshard
        path: the target mesh may differ from the save-time mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:09d}")
        with open(os.path.join(d, "index.msgpack"), "rb") as f:
            blob = f.read()
        index = (msgpack.unpackb(blob) if _HAVE_MSGPACK
                 else json.loads(blob.decode()))
        by_path = {e["path"]: e for e in index["leaves"]}
        tpl = _tree_paths(template)
        shard_leaves = _tree_paths(shardings)[:] if shardings is not None \
            else None
        out_leaves = []
        for i, (path, leaf) in enumerate(tpl):
            e = by_path.get(path)
            if e is None:
                raise KeyError(f"checkpoint missing leaf {path}")
            arr = np.load(os.path.join(d, e["file"]))
            want = tuple(getattr(leaf, "shape", arr.shape))
            if tuple(arr.shape) != want:
                raise ValueError(
                    f"shape mismatch for {path}: ckpt {arr.shape} vs "
                    f"template {want}")
            if shard_leaves is not None:
                arr = jax.device_put(arr, shard_leaves[i][1])
            out_leaves.append(arr)
        treedef = jax.tree_util.tree_structure(template)
        return jax.tree_util.tree_unflatten(treedef, out_leaves), \
            index["meta"]

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)
