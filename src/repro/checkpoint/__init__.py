from .manager import CheckpointManager
