"""Tier-1 serving-engine scheduling tests: slot-stable rotation under
churn, sampled admission, compacted sub-batch gather/scatter, and
compacted-vs-full decode parity on a real (tiny) model.

The scheduling tests drive the engine with a STUB model (the jitted
decode/prefill attributes are replaced after construction), so they
exercise the host-side slot logic without any XLA compilation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import api
from repro.models.config import ModelConfig
from repro.serving import engine as eng_mod
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampling import sample

TINY = ModelConfig(
    name="tiny",
    n_layers=1,
    d_model=32,
    n_heads=2,
    kv_heads=1,
    head_dim=16,
    d_ff=64,
    vocab=61,
    dtype="float32",
    param_dtype="float32",
    scan_layers=False,
)


def _stub_engine(max_batch=4, decode_batch=None, compact=True, vocab=61):
    """Engine whose decode/prefill are pure-Python fakes: decode emits
    logits peaked at (slot_index + step) % vocab and advances the cache
    index; prefill fills a length-1 cache."""
    eng = ServingEngine(
        TINY,
        params={},
        max_batch=max_batch,
        max_len=16,
        decode_batch=decode_batch,
        compact=compact,
        paged=False,  # the fakes replace the DENSE decode/prefill path
    )

    def fake_decode(params, tokens, cache):
        b = tokens.shape[0]
        step = int(np.asarray(cache["index"]).max())
        logits = np.full((b, 1, vocab), -1e9, np.float32)
        for j in range(b):
            logits[j, 0, (j + step) % vocab] = 0.0
        return jnp.asarray(logits), {
            "segments": cache["segments"],
            "index": cache["index"] + 1,
        }

    def fake_prefill(params, toks):
        cache = api.init_cache(TINY, 1, eng.max_len)
        logits = np.zeros((1, 1, vocab), np.float32)
        logits[0, 0, int(toks[0, -1]) % vocab] = 5.0
        return jnp.asarray(logits), cache

    eng._decode = fake_decode
    eng._prefill = fake_prefill
    return eng


def test_non_transformer_family_always_compacts():
    """Recurrent families advance state irreversibly — there is nothing
    to rewind after a full-width emulation step — so their DecodeState is
    ALWAYS the gathered sub-batch form, regardless of the compact knob."""
    rglru_cfg = ModelConfig(
        name="tiny-rglru",
        family="rglru",
        n_layers=2,
        d_model=32,
        n_heads=2,
        kv_heads=2,
        head_dim=16,
        d_ff=64,
        vocab=61,
        attn_every=2,
        lru_width=32,
        dtype="float32",
        param_dtype="float32",
        scan_layers=False,
    )
    eng = ServingEngine(
        rglru_cfg, params={}, max_batch=4, max_len=16, decode_batch=2
    )
    assert eng.compact is True
    assert eng.state.kind == "recurrent"
    eng_full = ServingEngine(
        rglru_cfg, params={}, max_batch=4, max_len=16, decode_batch=2,
        compact=False
    )
    assert eng_full.compact is True
    tf_eng = _stub_engine(max_batch=4, decode_batch=2)
    assert tf_eng.compact is True


def test_select_active_rotation_is_slot_stable():
    eng = _stub_engine(max_batch=4, decode_batch=2)
    assert eng._select_active([0, 1, 2, 3]) == [0, 1]
    assert eng._select_active([0, 1, 2, 3]) == [2, 3]
    # slot 1 finishes: remaining slots keep their cyclic order — the
    # cursor is a slot id, so the shrink cannot re-alias the rotation
    assert eng._select_active([0, 2, 3]) == [0, 2]
    assert eng._select_active([0, 2, 3]) == [3, 0]
    # slot 1 slot is re-admitted mid-cycle: it joins at its slot id
    assert eng._select_active([0, 1, 2, 3]) == [1, 2]
    assert eng._select_active([0, 1, 2, 3]) == [3, 0]
    # fewer active than the sub-batch width: everyone advances
    assert eng._select_active([2]) == [2]


def test_rotation_fairness_under_churn():
    """Under admission/finish churn every concurrently-active slot is
    served within one rotation of every other (the PR-4 cursor, taken
    modulo the shifting active COUNT, starved or double-served slots)."""
    eng = _stub_engine(max_batch=4, decode_batch=2)
    served: list[list[int]] = []
    orig = eng._select_active

    def spy(all_active):
        picked = orig(all_active)
        served.append((list(all_active), list(picked)))
        return picked

    eng._select_active = spy
    # staggered lengths force churn: slots finish and re-fill mid-run
    lengths = [3, 9, 5, 7, 4, 6, 8, 3]
    for i, n in enumerate(lengths):
        eng.submit(
            Request(rid=i, prompt=np.asarray([i + 1], np.int32), max_new_tokens=n)
        )
    eng.run()
    assert all(r is None for r in eng.slots) and not eng.queue
    # fairness: within every window where the active set is unchanged,
    # serve counts differ by at most one across the set's slots
    i = 0
    while i < len(served):
        j = i
        while j < len(served) and served[j][0] == served[i][0]:
            j += 1
        counts = {b: 0 for b in served[i][0]}
        for _, picked in served[i:j]:
            for b in picked:
                counts[b] += 1
        if len(counts) > 1:
            assert max(counts.values()) - min(counts.values()) <= 1, (
                served[i][0],
                counts,
            )
        i = j
    # every step serves min(width, active) distinct slots
    for all_active, picked in served:
        assert len(set(picked)) == len(picked)
        assert len(picked) == min(2, len(all_active))


def test_admit_samples_with_request_temperature():
    """The first (prefill) token goes through sampling.sample with the
    request's temperature/key and is counted in tokens_out."""
    eng = _stub_engine(max_batch=1)
    req = Request(
        rid=0, prompt=np.asarray([7], np.int32), max_new_tokens=1, temperature=3.0
    )
    eng.submit(req)
    # replicate the engine's key stream for the admission sample
    key0 = jax.random.PRNGKey(0)
    _, k = jax.random.split(key0)
    logits = np.zeros((1, 61), np.float32)
    logits[0, 7] = 5.0
    want = int(sample(jnp.asarray(logits), k, temperature=3.0)[0])
    eng.step()
    assert req.out_tokens[0] == want
    assert eng.stats["tokens_out"] == 1
    assert eng.stats["prefills"] == 1


def test_gather_scatter_roundtrip():
    cache = api.init_cache(TINY, 4, 8)
    cache["index"] = jnp.asarray([3, 1, 4, 2], jnp.int32)
    cache["segments"] = jax.tree.map(
        lambda a: jax.random.normal(jax.random.PRNGKey(a.ndim), a.shape),
        cache["segments"],
    )
    sel = jnp.asarray([2, 0], jnp.int32)
    sub = eng_mod._gather_slots(cache, sel)
    assert int(sub["index"][0]) == 4 and int(sub["index"][1]) == 3
    for full, part in zip(
        jax.tree_util.tree_leaves(cache["segments"]),
        jax.tree_util.tree_leaves(sub["segments"]),
    ):
        np.testing.assert_array_equal(np.asarray(part), np.asarray(full[:, [2, 0]]))
    back = eng_mod._scatter_slots(cache, sub, sel)
    for a, b in zip(jax.tree_util.tree_leaves(cache), jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_steady_state_serving_does_not_recompile():
    """tracecheck (the runtime half of MZC01): after one warm-up run,
    compacted decode steps and prefills of an already-seen prompt length
    build 0 new XLA executables — the static shapes really are static."""
    from tools.mozart_check.tracecheck import CompileMonitor

    params = api.init_params(TINY, jax.random.PRNGKey(0))

    def drive(n_reqs):
        eng = ServingEngine(
            TINY, params, max_batch=4, max_len=32, decode_batch=2, compact=True
        )
        reqs = [
            Request(
                rid=i,
                prompt=(np.arange(6) % TINY.vocab).astype(np.int32),
                max_new_tokens=4,
            )
            for i in range(n_reqs)
        ]
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert eng.stats["decode_steps"] > 0
        assert all(r.done for r in reqs)
        return eng

    # warm-up compiles the length-6 prefill, the compacted decode, and
    # the gather/scatter pair (the jitted builders are lru-cached per
    # config, so a fresh engine below reuses every executable)
    drive(4)
    with CompileMonitor() as mon:
        # more requests than slots: steady-state decode plus repeated
        # same-length prefills through admission churn
        drive(6)
    assert mon.count == 0, mon.events


@pytest.mark.parametrize("temperature", [0.0])
def test_compacted_decode_matches_full_batch(temperature):
    """Fixed-seed bit-parity: compacted sub-batch decode, the legacy
    full-width emulation, and plain full-batch decode emit identical
    tokens; compaction trades steps for narrow width."""
    params = api.init_params(TINY, jax.random.PRNGKey(0))
    prompts = [np.arange(5, dtype=np.int32) + i for i in range(4)]

    def run(decode_batch, compact):
        eng = ServingEngine(
            TINY,
            params,
            max_batch=4,
            max_len=32,
            decode_batch=decode_batch,
            compact=compact,
        )
        reqs = [
            Request(rid=i, prompt=p, max_new_tokens=5, temperature=temperature)
            for i, p in enumerate(prompts)
        ]
        for r in reqs:
            eng.submit(r)
        eng.run()
        return [r.out_tokens for r in reqs], eng.stats["decode_steps"]

    full, steps_full = run(4, True)
    comp, steps_comp = run(2, True)
    emul, steps_emul = run(2, False)
    assert comp == full
    assert emul == full
    assert steps_comp == steps_emul > steps_full
