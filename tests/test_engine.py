"""Evaluation-engine regression tests: the cached/vectorized stack must
reproduce the seed (scalar, uncached) implementation exactly for a fixed
seed, and actually cache."""
import dataclasses
import random

import numpy as np
import pytest

from repro.core import costmodel, engine, operators
from repro.core.chiplets import Chiplet, default_pool
from repro.core.convexhull import solve_pipeline
from repro.core.fusion import GAConfig, Requirement, optimize_fusion
from repro.core.memory import HBM3
from repro.core.perfmodel import (StageConfig, StageOption, StageOptionSet,
                                  enumerate_stage_options,
                                  envelope_keep_mask)
from repro.core.pool import SAConfig, _neighbor, anneal_pool, evaluate_pool


@pytest.fixture(autouse=True)
def _engine_state():
    """Each test starts engine-enabled with cold caches and restores the
    global switch afterwards."""
    was = engine.engine_enabled()
    engine.set_engine_enabled(True)
    engine.clear_all_caches()
    yield
    engine.set_engine_enabled(was)
    engine.clear_all_caches()


def _graphs():
    ws = operators.paper_workloads(seq=512)
    return {"resnet50": ws["resnet50"],
            "opt66b_decode": ws["opt66b_decode"]}


# --- vectorized perf model == scalar perf model -----------------------------

def test_batched_enumeration_bit_identical_to_scalar():
    ops = tuple(operators.lm_layer_operators(
        operators.OPT_66B, 128, 0, "prefill")[:4])
    pool = default_pool()
    scalar = enumerate_stage_options(ops, pool, vectorize=False)
    batched = enumerate_stage_options(ops, pool, vectorize=True)
    assert len(scalar) == len(batched) > 100
    for s, b in zip(scalar, batched):
        assert s.cfg == b.cfg
        assert s.t_cmp == b.t_cmp                 # bit-exact, not approx
        assert s.e_dyn == b.e_dyn
        assert s.p_static == b.p_static
        assert s.flops_per_sample == b.flops_per_sample


def test_batched_enumeration_with_pricing_and_repeat():
    ops = tuple(operators.lm_layer_operators(
        operators.OPT_66B, 128, 0, "prefill")[:2])
    pool = default_pool()[:3]
    scalar = enumerate_stage_options(ops, pool, vectorize=False,
                                     cost_fn=costmodel.stage_hw_cost,
                                     repeat=24)
    batched = enumerate_stage_options(ops, pool, vectorize=True,
                                      cost_fn=costmodel.stage_hw_cost,
                                      repeat=24)
    for s, b in zip(scalar, batched):
        assert s == b                             # full dataclass equality


def test_moe_group_parity():
    spec = operators.LMSpec(name="moe", n_layers=2, d_model=512, n_heads=8,
                            kv_heads=8, d_ff=1024, vocab=1000,
                            n_experts=8, top_k=2)
    g = operators.lm_operator_graph(spec, 128, "prefill")
    moe_ops = tuple(o for o in g.operators if o.weight_reuse_divisor > 1.0)
    assert moe_ops
    scalar = enumerate_stage_options(moe_ops, default_pool(),
                                     vectorize=False)
    batched = enumerate_stage_options(moe_ops, default_pool(),
                                      vectorize=True)
    for s, b in zip(scalar, batched):
        assert s.t_cmp == b.t_cmp and s.e_dyn == b.e_dyn


# --- vectorized Layer-3 == hull Layer-3 -------------------------------------

def _rand_option(rng):
    cfg = StageConfig(Chiplet(), HBM3, 1, 1, 1)
    return StageOption(t_cmp=rng.uniform(0.05, 10.0),
                       e_dyn=rng.uniform(0.1, 100.0),
                       p_static=rng.uniform(0.01, 5.0),
                       hw_cost_usd=rng.uniform(1.0, 1000.0), cfg=cfg)


def test_numpy_solver_matches_hull_exactly():
    for seed in range(40):
        rng = random.Random(seed)
        stages = [[_rand_option(rng) for _ in range(rng.randint(1, 15))]
                  for _ in range(rng.randint(1, 5))]
        if seed % 2:
            stages = [StageOptionSet(s) for s in stages]
        lat = sorted(rng.uniform(0.01, 15.0)
                     for _ in range(rng.randint(1, 25)))
        for obj in ("energy", "edp", "energy_cost", "edp_cost"):
            a = solve_pipeline(stages, lat, objective=obj, engine="numpy")
            h = solve_pipeline(stages, lat, objective=obj, engine="hull")
            assert (a is None) == (h is None)
            if a is not None:
                assert a.value == h.value and a.T == h.T


def test_envelope_keep_mask_preserves_minimum():
    rng = random.Random(7)
    for _ in range(30):
        m = rng.randint(1, 60)
        tc = np.array([rng.uniform(0.0, 5.0) for _ in range(m)])
        sl = np.array([rng.choice([0.5, 1.0, 2.0]) for _ in range(m)])
        ic = np.array([rng.choice([1.0, 3.0, 9.0]) for _ in range(m)])
        keep = envelope_keep_mask(tc, sl, ic)
        assert keep.any()
        for t in np.linspace(0.0, 6.0, 13):
            active = tc <= t
            full = np.where(active, sl * t + ic, np.inf).min()
            pruned = np.where(active & keep, sl * t + ic, np.inf).min()
            assert full == pruned


# --- memoization ------------------------------------------------------------

def test_engine_caches_repeat_pool_evaluations():
    graphs = _graphs()
    ga = GAConfig(population=4, generations=1)
    ev = engine.EvaluationEngine()
    pool = default_pool()[:3]
    s1, per1 = ev.evaluate_pool(pool, graphs, "energy", None, ga)
    assert ev.misses == len(graphs) and ev.hits == 0
    s2, per2 = ev.evaluate_pool(pool, graphs, "energy", None, ga)
    assert ev.misses == len(graphs) and ev.hits == len(graphs)
    assert s1 == s2
    assert {n: r.value for n, r in per1.items()} == \
           {n: r.value for n, r in per2.items()}
    # a different pool is a miss, not a stale hit
    ev.evaluate_pool(default_pool()[:4], graphs, "energy", None, ga)
    assert ev.misses == 2 * len(graphs)


def test_engine_parallel_workers_match_serial():
    graphs = _graphs()
    ga = GAConfig(population=4, generations=1)
    pool = default_pool()[:3]
    s_serial, per_serial = engine.EvaluationEngine(workers=0).evaluate_pool(
        pool, graphs, "energy", None, ga)
    s_par, per_par = engine.EvaluationEngine(workers=4).evaluate_pool(
        pool, graphs, "energy", None, ga)
    assert s_serial == s_par
    assert {n: r.value for n, r in per_serial.items()} == \
           {n: r.value for n, r in per_par.items()}


def test_fixed_seed_anneal_identical_through_engine():
    """The headline regression: cached+vectorized anneal_pool returns the
    identical best pool, score, and stage configs as the seed path."""
    graphs = _graphs()
    sa = SAConfig(iterations=3, inner_ga=GAConfig(population=4,
                                                  generations=1))
    engine.set_engine_enabled(False)
    engine.clear_all_caches()
    legacy = anneal_pool(graphs, objective="energy", pool_size=4, cfg=sa)
    engine.set_engine_enabled(True)
    engine.clear_all_caches()
    fast = anneal_pool(graphs, objective="energy", pool_size=4, cfg=sa)
    assert [c.label for c in legacy.pool] == [c.label for c in fast.pool]
    assert legacy.score == fast.score
    for name in graphs:
        sl = [o.cfg.label for o in legacy.per_network[name].solution.stages]
        sf = [o.cfg.label for o in fast.per_network[name].solution.stages]
        assert sl == sf, name


# --- bugfix regressions -----------------------------------------------------

def test_neighbor_never_returns_duplicate_skus():
    rng = random.Random(0)
    pool = default_pool()[:4]
    for _ in range(300):
        cand = _neighbor(pool, rng)
        assert len(set(cand)) == len(cand)
        pool = cand


def test_no_shared_mutable_default_configs():
    import inspect
    from repro.core import codesign, fusion, pool as pool_mod
    for fn in (fusion.optimize_fusion, pool_mod.evaluate_pool,
               pool_mod.anneal_pool, codesign.design_for_network,
               codesign.run_codesign, codesign.unconstrained_design,
               codesign.homogeneous_design,
               codesign.best_homogeneous_design):
        for p in inspect.signature(fn).parameters.values():
            assert not dataclasses.is_dataclass(p.default), \
                f"{fn.__name__} still shares a mutable default " \
                f"{p.name}={p.default!r}"


def test_evaluate_pool_engine_off_matches_engine_on():
    graphs = _graphs()
    ga = GAConfig(population=4, generations=1)
    pool = default_pool()[:3]
    engine.set_engine_enabled(False)
    engine.clear_all_caches()
    s_off, per_off = evaluate_pool(pool, graphs, "energy", ga=ga)
    engine.set_engine_enabled(True)
    engine.clear_all_caches()
    s_on, per_on = evaluate_pool(pool, graphs, "energy", ga=ga)
    assert s_off == s_on
    assert {n: r.value for n, r in per_off.items()} == \
           {n: r.value for n, r in per_on.items()}
