"""PR-2 regressions: population-batched GA parity, process-pool
evaluate_pool, the O((M+Q) log M) vectorized hull sweep, raised default
budgets, and the benchmark-gate plumbing (run.py exit codes, compare.py
thresholds)."""
import json
import math
import os
import random
import subprocess
import sys

import numpy as np
import pytest

from repro.core import engine, operators
from repro.core.chiplets import Chiplet, default_pool
from repro.core.convexhull import solve_pipeline, stage_envelope_sweep
from repro.core.fusion import (GAConfig, _chiplet_option_cache,
                               clear_option_caches, groups_from_genome,
                               optimize_fusion, prefetch_population_options,
                               _roofline_seed)
from repro.core.memory import HBM3
from repro.core.perfmodel import StageConfig, StageOption
from repro.core.pool import SAConfig, _neighbor, evaluate_pool

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _engine_state():
    was = engine.engine_enabled()
    engine.set_engine_enabled(True)
    engine.clear_all_caches()
    yield
    engine.set_engine_enabled(was)
    engine.clear_all_caches()


def _graphs():
    ws = operators.paper_workloads(seq=512)
    return {"resnet50": ws["resnet50"],
            "opt66b_decode": ws["opt66b_decode"]}


# --- population-batched GA == scalar GA -------------------------------------

def test_population_batched_ga_matches_scalar_fixed_seed():
    """Equal budget, equal seed: the population-batched engine GA must
    return the scalar GA's best design exactly."""
    g = _graphs()["resnet50"]
    cfg = GAConfig(population=6, generations=3)
    engine.set_engine_enabled(False)
    engine.clear_all_caches()
    scalar = optimize_fusion(g, default_pool(), objective="energy", cfg=cfg)
    engine.set_engine_enabled(True)
    engine.clear_all_caches()
    batched = optimize_fusion(g, default_pool(), objective="energy",
                              cfg=cfg)
    assert scalar is not None and batched is not None
    assert scalar.value == batched.value
    assert scalar.genome == batched.genome
    assert [o.cfg.label for o in scalar.solution.stages] == \
           [o.cfg.label for o in batched.solution.stages]


def test_prefetch_fills_per_sku_option_cache():
    g = _graphs()["opt66b_decode"]
    cfg = GAConfig(population=4, generations=1)
    pool = default_pool()[:3]
    clear_option_caches()
    seed = _roofline_seed(g, pool, fuse=True)
    prefetch_population_options(g, [seed], pool, cfg)
    n_groups = len(groups_from_genome(g, seed))
    assert len(_chiplet_option_cache) == n_groups * len(pool)
    # idempotent: a second prefetch enumerates nothing new
    prefetch_population_options(g, [seed], pool, cfg)
    assert len(_chiplet_option_cache) == n_groups * len(pool)


# --- vectorized hull sweep ---------------------------------------------------

def test_hull_sweep_exact_vs_dense_bruteforce():
    for seed in range(60):
        rng = random.Random(seed)
        m, q = rng.randint(1, 80), rng.randint(1, 80)
        tc = np.array([rng.uniform(0.0, 10.0) for _ in range(m)])
        sl = np.array([rng.uniform(0.0, 5.0) for _ in range(m)])
        ic = np.array([rng.uniform(-10.0, 100.0) for _ in range(m)])
        lat = np.array(sorted(rng.uniform(0.01, 15.0) for _ in range(q)))
        got = stage_envelope_sweep(tc, sl, ic, lat)
        want = np.where(tc[:, None] <= lat[None, :],
                        sl[:, None] * lat[None, :] + ic[:, None],
                        np.inf).min(axis=0)
        assert np.array_equal(got, want), seed


def _rand_option(rng):
    cfg = StageConfig(Chiplet(), HBM3, 1, 1, 1)
    return StageOption(t_cmp=rng.uniform(0.05, 10.0),
                       e_dyn=rng.uniform(0.1, 100.0),
                       p_static=rng.uniform(0.01, 5.0),
                       hw_cost_usd=rng.uniform(1.0, 1000.0), cfg=cfg)


def test_solve_pipeline_hullvec_matches_hull_and_numpy():
    for seed in range(30):
        rng = random.Random(seed)
        stages = [[_rand_option(rng) for _ in range(rng.randint(1, 15))]
                  for _ in range(rng.randint(1, 5))]
        lat = sorted(rng.uniform(0.01, 15.0)
                     for _ in range(rng.randint(1, 25)))
        for obj in ("energy", "edp", "energy_cost", "edp_cost"):
            v = solve_pipeline(stages, lat, objective=obj, engine="hullvec")
            h = solve_pipeline(stages, lat, objective=obj, engine="hull")
            n = solve_pipeline(stages, lat, objective=obj, engine="numpy")
            assert (v is None) == (h is None) == (n is None)
            if v is not None:
                assert v.value == h.value == n.value
                assert v.T == h.T == n.T


# --- process/thread executors ------------------------------------------------

def test_evaluate_pool_thread_executor_matches_serial():
    graphs = _graphs()
    ga = GAConfig(population=4, generations=1)
    pool = default_pool()[:3]
    s0, per0 = engine.EvaluationEngine(workers=0).evaluate_pool(
        pool, graphs, "energy", None, ga)
    s1, per1 = engine.EvaluationEngine(
        workers=2, executor="thread").evaluate_pool(
        pool, graphs, "energy", None, ga)
    assert s0 == s1
    assert {n: r.value for n, r in per0.items()} == \
           {n: r.value for n, r in per1.items()}


def test_evaluate_pool_process_executor_matches_serial():
    """MOZART_WORKERS>1 with the spawn-safe process executor returns
    results identical to serial (and falls back to threads rather than
    failing if the platform cannot spawn)."""
    graphs = _graphs()
    ga = GAConfig(population=4, generations=1)
    pool = default_pool()[:3]
    s0, per0 = engine.EvaluationEngine(workers=0).evaluate_pool(
        pool, graphs, "energy", None, ga)
    ev = engine.EvaluationEngine(workers=2, executor="process")
    try:
        s1, per1 = ev.evaluate_pool(pool, graphs, "energy", None, ga)
        # results land in the parent memo: a repeat call is all hits
        s2, _ = ev.evaluate_pool(pool, graphs, "energy", None, ga)
    finally:
        ev._shutdown_process_pool()
    assert s0 == s1 == s2
    assert ev.hits >= len(graphs)
    assert {n: r.value for n, r in per0.items()} == \
           {n: r.value for n, r in per1.items()}


def test_executor_env_knobs(monkeypatch):
    monkeypatch.setenv("MOZART_WORKERS", "3")
    monkeypatch.setenv("MOZART_EXECUTOR", "process")
    ev = engine.EvaluationEngine()
    assert ev.workers == 3 and ev.executor == "process"
    monkeypatch.setenv("MOZART_EXECUTOR", "bogus")
    monkeypatch.setenv("MOZART_WORKERS", "not-a-number")
    ev = engine.EvaluationEngine()
    assert ev.workers == 0 and ev.executor == "thread"


def test_evaluate_pool_accepts_executor_kwarg():
    graphs = _graphs()
    ga = GAConfig(population=4, generations=1)
    s0, _ = evaluate_pool(default_pool()[:3], graphs, "energy", ga=ga,
                          workers=2, executor="thread")
    s1, _ = evaluate_pool(default_pool()[:3], graphs, "energy", ga=ga)
    assert s0 == s1


# --- SA neighbor move --------------------------------------------------------

def test_neighbor_1000_mutations_never_shrinks_pool():
    rng = random.Random(123)
    pool = default_pool()[:4]
    size = len(pool)
    for _ in range(1000):
        pool = _neighbor(pool, rng)
        assert len(pool) == size
        assert len(set(pool)) == size


# --- raised default budgets --------------------------------------------------

def test_default_budgets_raised_past_paper_toy_settings():
    """PAPER Table 4 is 5 SA iterations and 10 GA generations; the
    defaults were raised on the strength of bench_budget_scaling data."""
    assert SAConfig().iterations > 5
    assert GAConfig().generations > 10
    # the escape hatch to the exact-seed scalar path must still exist
    assert hasattr(engine, "set_engine_enabled")
    assert os.environ.get("MOZART_DISABLE_ENGINE", "0") in ("0", "1")


# --- benchmark harness plumbing ----------------------------------------------

def test_benchmarks_run_exits_nonzero_on_module_failure():
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only",
         "no_such_benchmark_module"],
        cwd=REPO_ROOT, capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"}, timeout=120)
    assert proc.returncode != 0
    assert "benchmarks failed" in proc.stderr


def test_compare_gate_thresholds(tmp_path):
    sys.path.insert(0, REPO_ROOT)
    try:
        from benchmarks.compare import check
    finally:
        sys.path.pop(0)
    baselines = {"codesign_search": {"min_speedup": 2.0},
                 "budget_scaling": {"require_monotone": True},
                 "batch_solve": {"min_speedup_vs_pr3": 1.5},
                 "serving": {"min_speedup_compacted": 1.1},
                 "cluster": {"min_speedup_multi": 1.5,
                             "require_equal_tokens": True,
                             "min_quant_token_match": 0.8,
                             "min_quant_capacity_ratio": 2.0},
                 "chaos": {"min_goodput_frac": 0.6,
                           "max_goodput_violations": 0,
                           "require_exact_tokens": True,
                           "require_outage_survival": True,
                           "min_quarantined": 2},
                 "specdec": {"min_speedup": 1.2,
                             "require_token_exact": True,
                             "min_acceptance": 0.99,
                             "max_steady_state_recompiles": 0}}

    def write(speedup, identical, mono, batch_speedup=3.0,
              batch_identical=True, serving_speedup=1.5,
              serving_identical=True, cluster_speedup=1.8,
              cluster_equal=True, quant_match=0.9, quant_cap=3.5,
              goodput_frac=0.8, goodput_viol=0, chaos_exact=True,
              outage_ok=True, quarantined=2, spec_speedup=1.6,
              spec_exact=True, spec_acc=1.0, spec_rec=0):
        (tmp_path / "BENCH_codesign_search.json").write_text(json.dumps(
            {"speedup": speedup, "identical_best_design": identical}))
        (tmp_path / "BENCH_budget_scaling.json").write_text(json.dumps(
            {"monotone_sa": mono, "monotone_ga": mono,
             "sa_levels": [], "ga_levels": []}))
        (tmp_path / "BENCH_batch_solve.json").write_text(json.dumps(
            {"speedup_vs_pr3": batch_speedup,
             "identical_solutions": batch_identical}))
        (tmp_path / "BENCH_serving.json").write_text(json.dumps(
            {"speedup_compacted_vs_emulated": serving_speedup,
             "identical_outputs": serving_identical}))
        (tmp_path / "BENCH_cluster.json").write_text(json.dumps(
            {"n_replicas": 4,
             "speedup_multi_vs_single": cluster_speedup,
             "equal_tokens": cluster_equal,
             "quant_token_match_frac": quant_match,
             "quant_capacity_ratio": quant_cap}))
        (tmp_path / "BENCH_chaos.json").write_text(json.dumps(
            {"goodput_frac": goodput_frac,
             "goodput_violations": goodput_viol,
             "completed_tokens_exact": chaos_exact,
             "outage_survived": outage_ok,
             "outage_tokens_exact": outage_ok,
             "outage_unrouted": 4,
             "quarantined": quarantined}))
        (tmp_path / "BENCH_specdec.json").write_text(json.dumps(
            {"speedup_specdec_vs_target": spec_speedup,
             "token_exact": spec_exact,
             "acceptance_rate": spec_acc,
             "steady_state_recompiles": {"specdec": spec_rec,
                                         "target_only": 0}}))

    write(5.0, True, True)
    assert check(str(tmp_path), baselines) == []
    write(1.2, True, True)           # speedup regression
    assert any("regressed" in f for f in check(str(tmp_path), baselines))
    write(5.0, False, True)          # parity break
    assert any("identical" in f for f in check(str(tmp_path), baselines))
    write(5.0, True, False)          # non-monotone budget scaling
    assert any("monotone" in f for f in check(str(tmp_path), baselines))
    write(5.0, True, True, batch_speedup=1.1)   # batched-solve regression
    assert any("batch_solve" in f and "regressed" in f
               for f in check(str(tmp_path), baselines))
    write(5.0, True, True, batch_identical=False)
    assert any("identical solutions" in f
               for f in check(str(tmp_path), baselines))
    write(5.0, True, True, serving_speedup=1.0)  # compacted-decode regression
    assert any("serving" in f and "regressed" in f
               for f in check(str(tmp_path), baselines))
    write(5.0, True, True, serving_identical=False)
    assert any("emulated schedule" in f
               for f in check(str(tmp_path), baselines))
    write(5.0, True, True, cluster_speedup=1.1)  # scale-out regression
    assert any("cluster" in f and "regressed" in f
               for f in check(str(tmp_path), baselines))
    write(5.0, True, True, cluster_equal=False)  # unequal token counts
    assert any("token counts" in f for f in check(str(tmp_path), baselines))
    write(5.0, True, True, quant_match=0.5)      # int8-KV parity break
    assert any("token match" in f for f in check(str(tmp_path), baselines))
    write(5.0, True, True, quant_cap=1.2)        # int8-KV capacity loss
    assert any("capacity ratio" in f for f in check(str(tmp_path), baselines))
    write(5.0, True, True, goodput_frac=0.3)     # goodput collapse under chaos
    assert any("goodput regressed" in f for f in check(str(tmp_path), baselines))
    write(5.0, True, True, goodput_viol=1)       # accounting counted late tokens
    assert any("deadline-violating" in f for f in check(str(tmp_path), baselines))
    write(5.0, True, True, chaos_exact=False)    # failover no longer token-exact
    assert any("diverged" in f for f in check(str(tmp_path), baselines))
    write(5.0, True, True, outage_ok=False)      # total-outage drill failed
    assert any("total-outage" in f for f in check(str(tmp_path), baselines))
    write(5.0, True, True, quarantined=1)        # watchdog missed a silent fault
    assert any("quarantined only" in f for f in check(str(tmp_path), baselines))
    write(5.0, True, True, spec_speedup=1.0)     # live spec-decode regression
    assert any("specdec" in f and "regressed" in f
               for f in check(str(tmp_path), baselines))
    write(5.0, True, True, spec_exact=False)     # verify/rewind no longer exact
    assert any("target-only engine" in f
               for f in check(str(tmp_path), baselines))
    write(5.0, True, True, spec_acc=0.5)         # acceptance below the ceiling
    assert any("acceptance" in f for f in check(str(tmp_path), baselines))
    write(5.0, True, True, spec_rec=3)           # spec loop retraces per step
    assert any("specdec" in f and "recompiles" in f
               for f in check(str(tmp_path), baselines))
    assert any("missing artifact" in f
               for f in check(str(tmp_path / "nope"), baselines))
