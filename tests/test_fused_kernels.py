"""Tier-1 parity tests for the serving fused kernels (interpret mode on
CPU): fused dense gated-MLP and fused RMSNorm(+residual) vs their
pure-jnp oracles, plus the ModelConfig mlp_impl/norm_impl dispatch
through the transformer forward/decode paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fused_mlp.ops import fused_mlp
from repro.kernels.fused_mlp.ref import fused_mlp_ref
from repro.kernels.fused_norm.ops import fused_rmsnorm, fused_rmsnorm_residual
from repro.kernels.fused_norm.ref import fused_rmsnorm_ref, fused_rmsnorm_residual_ref
from repro.models import api
from repro.models.config import ModelConfig


@pytest.mark.parametrize(
    "n,d,f,swiglu,bt,bf,dt",
    [
        (8, 16, 32, True, 4, 8, jnp.float32),
        (10, 16, 48, False, 128, 512, jnp.float32),  # ragged + no gate
        (3, 8, 8, True, 2, 4, jnp.float32),  # padding on both axes
        (6, 16, 32, True, 4, 16, jnp.bfloat16),
    ],
)
def test_fused_mlp_matches_ref(n, d, f, swiglu, bt, bf, dt):
    ks = jax.random.split(jax.random.PRNGKey(n * 31 + f), 4)
    x = jax.random.normal(ks[0], (n, d), dt)
    wg = jax.random.normal(ks[1], (d, f), dt)
    wi = jax.random.normal(ks[2], (d, f), dt)
    wo = jax.random.normal(ks[3], (f, d), dt)
    # the gate operand is skipped entirely for plain-GELU MLPs
    out = fused_mlp(x, wg if swiglu else None, wi, wo, swiglu=swiglu, bt=bt, bf=bf)
    ref = fused_mlp_ref(x, wg, wi, wo, swiglu=swiglu)
    tol = 2.5e-2 if dt == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_fused_mlp_batched_layout():
    """(B, S, d) inputs flatten through the wrapper unchanged."""
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (2, 5, 16), jnp.float32)
    wg = jax.random.normal(ks[1], (16, 32), jnp.float32)
    wi = jax.random.normal(ks[2], (16, 32), jnp.float32)
    wo = jax.random.normal(ks[3], (32, 16), jnp.float32)
    out = fused_mlp(x, wg, wi, wo, bt=4, bf=16)
    ref = fused_mlp_ref(x.reshape(-1, 16), wg, wi, wo).reshape(2, 5, 16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "n,d,dt,tol",
    [
        (8, 16, jnp.float32, 1e-6),
        (5, 32, jnp.float32, 1e-6),  # padding (bt=4 over 5 rows)
        (6, 16, jnp.bfloat16, 2.5e-2),
    ],
)
def test_fused_rmsnorm_matches_ref(n, d, dt, tol):
    ks = jax.random.split(jax.random.PRNGKey(n * 7 + d), 3)
    x = jax.random.normal(ks[0], (2, n, d), dt)
    res = jax.random.normal(ks[1], (2, n, d), dt)
    scale = jax.random.normal(ks[2], (d,), dt)
    out = fused_rmsnorm(x, scale, bt=4)
    ref = fused_rmsnorm_ref(x, scale)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )
    s, y = fused_rmsnorm_residual(x, res, scale, bt=4)
    s_ref, y_ref = fused_rmsnorm_residual_ref(x, res, scale)
    np.testing.assert_allclose(
        np.asarray(s, np.float32), np.asarray(s_ref, np.float32), rtol=tol, atol=tol
    )
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32), rtol=tol, atol=tol
    )


BASE = ModelConfig(
    name="tiny",
    n_layers=1,
    d_model=32,
    n_heads=2,
    kv_heads=1,
    head_dim=16,
    d_ff=64,
    vocab=61,
    dtype="float32",
    param_dtype="float32",
    scan_layers=False,
)


@pytest.mark.parametrize(
    "kw",
    [
        {"mlp_impl": "fused"},
        {"norm_impl": "fused"},
        {"mlp_impl": "fused", "norm_impl": "fused"},
    ],
)
def test_model_fused_impls_match_dense(kw):
    """forward + decode_step with the fused Pallas impls agree with the
    dense/ref paths on the same params."""
    from repro.models import transformer as T

    params = api.init_params(BASE, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, BASE.vocab)
    want = np.asarray(T.forward(BASE, params, toks))
    cfg = BASE.replace(**kw)
    got = np.asarray(T.forward(cfg, params, toks))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    last, cache = api.prefill(BASE, params, {"tokens": toks}, 16)
    step = jnp.argmax(last, -1).astype(jnp.int32)
    lg_want, _ = api.decode_step(BASE, params, step, cache)
    lg_got, _ = api.decode_step(cfg, params, step, cache)
    np.testing.assert_allclose(
        np.asarray(lg_got), np.asarray(lg_want), rtol=2e-5, atol=2e-5
    )


def test_config_validates_impls():
    BASE.replace(mlp_impl="fused", norm_impl="fused").validate()
    with pytest.raises(AssertionError):
        BASE.replace(mlp_impl="bogus").validate()
    with pytest.raises(AssertionError):
        BASE.replace(norm_impl="bogus").validate()
