"""PR-4 regressions: the generation-batched Layer-3 solve
(`convexhull.solve_pipeline_batch` / `fusion.evaluate_genomes`) must be
bit-identical to the per-genome path, and the process-pool shared
option-cache warmup must ship bit-identical columns."""

import random

import numpy as np
import pytest

from repro.core import convexhull, engine, fusion, operators
from repro.core.chiplets import Chiplet, default_pool
from repro.core.convexhull import (
    PipelineJob,
    default_latency_grid,
    solve_pipeline,
    solve_pipeline_batch,
)
from repro.core.fusion import (
    GAConfig,
    Requirement,
    _mutate,
    evaluate_genome,
    evaluate_genomes,
    export_option_columns,
    groups_from_genome,
    import_option_columns,
    initial_population,
    matching_option_keys,
    optimize_fusion,
    prefetch_population_options,
    stage_options_for_groups,
)
from repro.core.memory import HBM3
from repro.core.perfmodel import StageConfig, StageOption, StageOptionSet


@pytest.fixture(autouse=True)
def _engine_state():
    was = engine.engine_enabled()
    engine.set_engine_enabled(True)
    engine.clear_all_caches()
    yield
    engine.set_engine_enabled(was)
    engine.clear_all_caches()


def _rand_option(rng):
    cfg = StageConfig(Chiplet(), HBM3, 1, 1, 1)
    return StageOption(
        t_cmp=rng.uniform(0.05, 10.0),
        e_dyn=rng.uniform(0.1, 100.0),
        p_static=rng.uniform(0.01, 5.0),
        hw_cost_usd=rng.uniform(1.0, 1000.0),
        cfg=cfg,
    )


def _rand_jobs(rng, allow_empty=True, as_sets=False):
    jobs = []
    for _ in range(rng.randint(1, 8)):
        stages = []
        for _ in range(rng.randint(1, 5)):
            lo = 0 if allow_empty and rng.random() < 0.15 else 1
            stages.append([_rand_option(rng) for _ in range(rng.randint(lo, 15))])
        if as_sets:
            stages = [StageOptionSet(s) for s in stages]
        if rng.random() < 0.2 and len(stages[0]):
            # exact duplicate options stress the tie-break rules
            dup = list(stages[0])
            dup.append(dup[0])
            stages[0] = StageOptionSet(dup) if as_sets else dup
        lat = sorted(rng.uniform(0.01, 15.0) for _ in range(rng.randint(1, 25)))
        jobs.append(
            PipelineJob(
                stages,
                lat,
                max_interval=rng.choice([None, 5.0]),
                max_e2e=rng.choice([None, 30.0]),
                n_stages=rng.choice([None, len(stages) * 2]),
            )
        )
    return jobs


def _assert_batch_matches_scalar(jobs, objective, engine_kind="auto"):
    got = solve_pipeline_batch(jobs, objective=objective, engine=engine_kind)
    assert len(got) == len(jobs)
    scalar_engine = "numpy" if engine_kind == "auto" else engine_kind
    for j, g in zip(jobs, got):
        want = solve_pipeline(
            j.stage_options,
            j.latencies,
            objective=objective,
            max_interval=j.max_interval,
            max_e2e=j.max_e2e,
            n_stages=j.n_stages,
            engine=scalar_engine,
        )
        assert (g is None) == (want is None)
        if g is None:
            continue
        # bit-exact, not approx
        assert g.value == want.value and g.T == want.T
        assert g.energy_per_sample == want.energy_per_sample
        assert g.hw_cost_usd == want.hw_cost_usd
        assert [o.cfg.label for o in g.stages] == [o.cfg.label for o in want.stages]
        assert [o.t_cmp for o in g.stages] == [o.t_cmp for o in want.stages]


@pytest.mark.parametrize("objective", ["energy", "edp", "energy_cost", "edp_cost"])
def test_batch_bit_identical_all_objectives(objective):
    for seed in range(25):
        rng = random.Random(seed)
        _assert_batch_matches_scalar(_rand_jobs(rng, as_sets=seed % 2 == 0), objective)


def test_batch_empty_option_stages_yield_none():
    cfg = StageConfig(Chiplet(), HBM3, 1, 1, 1)
    opt = StageOption(1.0, 1.0, 1.0, 1.0, cfg)
    jobs = [
        PipelineJob([[opt], []], [1.0, 2.0]),
        PipelineJob([[opt]], [1.0, 2.0]),
        PipelineJob([StageOptionSet([])], [1.0, 2.0]),
        PipelineJob([[opt]], [0.5]),  # infeasible: T < t_cmp
        PipelineJob([[opt]], [1.0, 2.0], max_interval=1.0),
    ]
    got = solve_pipeline_batch(jobs, objective="energy")
    assert got[0] is None
    assert got[1] is not None
    assert got[2] is None
    assert got[3] is None
    assert got[4] is not None and got[4].T <= 1.0


def test_batch_dense_vs_hullvec_crossover(monkeypatch):
    """Stages crossing HULLVEC_MIN_CELLS switch to the hull sweep inside
    a batch exactly as the per-genome path does — force a tiny crossover
    so one batch mixes dense and sweep stages."""
    monkeypatch.setattr(convexhull, "HULLVEC_MIN_CELLS", 60)
    for seed in range(10):
        rng = random.Random(seed)
        _assert_batch_matches_scalar(_rand_jobs(rng), "energy")
        _assert_batch_matches_scalar(_rand_jobs(rng), "edp_cost")


def test_batch_forced_hullvec_engine():
    for seed in range(6):
        rng = random.Random(seed)
        _assert_batch_matches_scalar(_rand_jobs(rng), "energy", engine_kind="hullvec")


def test_batch_chunking(monkeypatch):
    """A batch larger than BATCH_MAX_CELLS is processed in chunks with
    identical results."""
    monkeypatch.setattr(convexhull, "BATCH_MAX_CELLS", 200)
    rng = random.Random(0)
    _assert_batch_matches_scalar(_rand_jobs(rng, allow_empty=False), "energy")


def test_batch_solve_env_escape_hatch(monkeypatch):
    monkeypatch.setenv("MOZART_BATCH_SOLVE", "0")
    assert not engine.batch_solve_enabled()
    rng = random.Random(3)
    _assert_batch_matches_scalar(_rand_jobs(rng), "energy")
    monkeypatch.delenv("MOZART_BATCH_SOLVE")
    assert engine.batch_solve_enabled()


# --- generation-batched GA ---------------------------------------------------


def _graph():
    return operators.paper_workloads(seq=512)["resnet50"]


def _genomes(graph, pool, cfg, n=16, seed=7):
    rng = random.Random(seed)
    pop = initial_population(graph, pool, cfg)
    out = list(pop)
    while len(out) < n:
        out.append(_mutate(rng.choice(pop), rng, 0.25))
    return out


def test_evaluate_genomes_matches_per_genome_loop():
    graph = _graph()
    pool = default_pool()[:4]
    cfg = GAConfig(population=8, generations=2)
    req = Requirement()
    genomes = _genomes(graph, pool, cfg)
    batched = evaluate_genomes(graph, genomes, pool, "energy", req, cfg, {})
    scalar = {
        g: evaluate_genome(graph, g, pool, "energy", req, cfg, _solution_cache={})
        for g in genomes
    }
    assert set(batched) == set(scalar)
    for g in genomes:
        b, s = batched[g], scalar[g]
        assert (b is None) == (s is None)
        if b is None:
            continue
        assert b.value == s.value
        assert b.solution.T == s.solution.T
        b_labels = [o.cfg.label for o in b.solution.stages]
        s_labels = [o.cfg.label for o in s.solution.stages]
        assert b_labels == s_labels


def test_fixed_seed_ga_identical_batched_vs_scalar_solve(monkeypatch):
    """Equal budget, equal seed: the generation-batched GA returns the
    exact design of the per-genome solve loop (MOZART_BATCH_SOLVE=0)
    and of the engine-off scalar GA."""
    graph = _graph()
    cfg = GAConfig(population=6, generations=3)
    batched = optimize_fusion(graph, default_pool(), objective="energy", cfg=cfg)
    engine.clear_all_caches()
    monkeypatch.setenv("MOZART_BATCH_SOLVE", "0")
    loop = optimize_fusion(graph, default_pool(), objective="energy", cfg=cfg)
    monkeypatch.delenv("MOZART_BATCH_SOLVE")
    engine.set_engine_enabled(False)
    engine.clear_all_caches()
    seedpath = optimize_fusion(graph, default_pool(), objective="energy", cfg=cfg)
    assert batched.value == loop.value == seedpath.value
    assert batched.genome == loop.genome == seedpath.genome
    labels = [
        [o.cfg.label for o in r.solution.stages] for r in (batched, loop, seedpath)
    ]
    assert labels[0] == labels[1] == labels[2]


def test_requirement_constraint_respected_in_batch():
    graph = _graph()
    pool = default_pool()[:3]
    cfg = GAConfig(population=6, generations=1)
    req = Requirement(e2e=5e-3)
    genomes = _genomes(graph, pool, cfg, n=8)
    batched = evaluate_genomes(graph, genomes, pool, "energy", req, cfg, {})
    scalar = {
        g: evaluate_genome(graph, g, pool, "energy", req, cfg, _solution_cache={})
        for g in genomes
    }
    for g in genomes:
        b, s = batched[g], scalar[g]
        assert (b is None) == (s is None)
        if b is not None:
            assert b.solution.delay_e2e <= 5e-3 + 1e-12
            assert b.value == s.value


# --- latency-grid memoization (satellite bugfix) -----------------------------


def test_default_latency_grid_memoized_per_option_set_key():
    graph = _graph()
    pool = default_pool()[:3]
    cfg = GAConfig(population=4, generations=1)
    g = initial_population(graph, pool, cfg)[0]
    options = stage_options_for_groups(groups_from_genome(graph, g), pool, cfg)
    convexhull.clear_grid_cache()
    grid1 = default_latency_grid(options, n=cfg.latency_points)
    key = (cfg.latency_points, *(o.uid for o in options))
    assert key in convexhull._GRID_CACHE
    grid2 = default_latency_grid(options, n=cfg.latency_points)
    assert grid1 == grid2
    # callers get copies: mutating a returned grid can't poison the memo
    grid2[0] = -1.0
    assert default_latency_grid(options, n=cfg.latency_points) == grid1
    # a different n is a different key, not a stale hit
    grid3 = default_latency_grid(options, n=cfg.latency_points + 8)
    assert len(set(grid3)) >= len(set(grid1))


def test_plain_list_inputs_not_cached():
    rng = random.Random(0)
    stages = [[_rand_option(rng) for _ in range(6)]]
    convexhull.clear_grid_cache()
    default_latency_grid(stages, n=16)
    assert not convexhull._GRID_CACHE


# --- shared option-cache transport (process-pool warmup) ---------------------


def test_export_import_option_columns_roundtrip_bit_exact():
    graph = _graph()
    pool = default_pool()[:3]
    cfg = GAConfig(population=4, generations=1)
    pop = initial_population(graph, pool, cfg)
    prefetch_population_options(graph, pop, pool, cfg)
    keys = matching_option_keys(pool, cfg)
    assert keys
    before = {k: fusion._chiplet_option_cache[k] for k in keys}
    meta, matrix = export_option_columns(keys)
    assert matrix.shape[1] == 4 and len(meta) == len(keys)

    fusion.clear_option_caches()
    installed = import_option_columns(meta, matrix)
    assert installed == len(keys)
    assert fusion.warmup_stats()["installed"] == len(keys)
    for k in keys:
        a, b = before[k], fusion._chiplet_option_cache[k]
        assert np.array_equal(a.t_cmp, b.t_cmp)
        assert np.array_equal(a.e_dyn, b.e_dyn)
        assert np.array_equal(a.p_static, b.p_static)
        assert np.array_equal(a.hw_cost_usd, b.hw_cost_usd)
        assert a.cfgs == b.cfgs
        assert a.options() == b.options()  # full dataclass equality

    # idempotent: re-import installs nothing new
    assert import_option_columns(meta, matrix) == 0


def test_import_skips_on_model_drift():
    graph = _graph()
    pool = default_pool()[:1]
    cfg = GAConfig(population=2, generations=1)
    pop = initial_population(graph, pool, cfg)
    prefetch_population_options(graph, pop, pool, cfg)
    keys = matching_option_keys(pool, cfg)
    meta, matrix = export_option_columns(keys)
    fusion.clear_option_caches()
    meta[0] = dict(meta[0], n=meta[0]["n"] + 1)  # claim a wrong span
    installed = import_option_columns(meta[:1], matrix)
    assert installed == 0


def test_engine_stats_exposed():
    ev = engine.EvaluationEngine(workers=0)
    s = ev.stats()
    assert set(s) == {"hits", "misses", "warmup_hits", "worker_enumerations"}
    assert all(v == 0 for v in s.values())


def test_warmup_env_knob(monkeypatch):
    monkeypatch.setenv("MOZART_WARMUP", "0")
    assert engine.EvaluationEngine().warmup is False
    monkeypatch.delenv("MOZART_WARMUP")
    assert engine.EvaluationEngine().warmup is True
    assert engine.EvaluationEngine(warmup=False).warmup is False


def test_process_warmup_parity_and_counters(monkeypatch):
    """MOZART_EXECUTOR=process with the shared-column warmup returns
    results identical to serial, and the warmup-hit counter shows the
    workers received pre-built blocks.  With a generations-0 GA the
    deterministic generation-0 population is the whole search, so a
    warmed worker enumerates NOTHING.  (Falls back to threads rather
    than failing where spawn is unavailable — counters stay 0 there.)"""
    ws = operators.paper_workloads(seq=512)
    graphs = {"resnet50": ws["resnet50"], "opt66b_decode": ws["opt66b_decode"]}
    ga = GAConfig(population=4, generations=0)
    pool = default_pool()[:3]
    s0, per0 = engine.EvaluationEngine(workers=0).evaluate_pool(
        pool, graphs, "energy", None, ga
    )
    monkeypatch.setenv("MOZART_EXECUTOR", "process")
    monkeypatch.setenv("MOZART_WORKERS", "2")
    ev = engine.EvaluationEngine()
    assert ev.executor == "process" and ev.warmup
    try:
        s1, per1 = ev.evaluate_pool(pool, graphs, "energy", None, ga)
    finally:
        ev._shutdown_process_pool()
    assert s0 == s1
    assert {n: r.value for n, r in per0.items()} == {
        n: r.value for n, r in per1.items()
    }
    stats = ev.stats()
    if stats["warmup_hits"]:  # process path actually ran
        assert stats["worker_enumerations"] == 0


def test_process_warmup_off_still_identical():
    ws = operators.paper_workloads(seq=512)
    graphs = {"resnet50": ws["resnet50"], "opt66b_decode": ws["opt66b_decode"]}
    ga = GAConfig(population=4, generations=1)
    pool = default_pool()[:3]
    s0, _ = engine.EvaluationEngine(workers=0).evaluate_pool(
        pool, graphs, "energy", None, ga
    )
    ev = engine.EvaluationEngine(workers=2, executor="process", warmup=False)
    try:
        s1, _ = ev.evaluate_pool(pool, graphs, "energy", None, ga)
    finally:
        ev._shutdown_process_pool()
    assert s0 == s1
    assert ev.stats()["warmup_hits"] == 0
