"""Multi-device correctness program — run in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
process keeps its single CPU device.  Exits nonzero on any failure."""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                                                   # noqa: E402
import jax.numpy as jnp                                      # noqa: E402
import numpy as np                                           # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa

from repro.models import api, transformer as T               # noqa: E402
from repro.models.config import ModelConfig                  # noqa: E402
from repro.parallel.pipeline import pipeline_apply, split_stages  # noqa
from repro.parallel.sharding import (cache_shardings, data_shardings,
                                     optimizer_shardings,
                                     params_shardings)       # noqa: E402
from repro.training.optimizer import OptimizerConfig, init_opt  # noqa

CFG = ModelConfig(name="tp", n_layers=2, d_model=64, n_heads=4,
                  kv_heads=4, head_dim=16, d_ff=128, vocab=128,
                  dtype="float32", param_dtype="float32",
                  scan_min_layers=2)


def make_mesh(shape, names):
    """jax.make_mesh across JAX versions: axis_types only where it exists."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, names,
                             axis_types=(axis_type.Auto,) * len(names))
    return jax.make_mesh(shape, names)


def check_tp_dp_forward_matches_single():
    assert len(jax.devices()) == 8
    mesh = make_mesh((2, 4), ("data", "model"))
    params = api.init_params(CFG, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                              CFG.vocab)
    want = np.asarray(T.forward(CFG, params, toks))
    pshard = params_shardings(mesh, params)
    dshard = data_shardings(mesh, {"tokens": toks})
    with mesh:
        p = jax.device_put(params, pshard)
        t = jax.device_put(toks, dshard["tokens"])
        # one-shot parity check: traced once, then discarded
        got = jax.jit(lambda pp, tt: T.forward(CFG, pp, tt))(p, t)  # mzc: ignore[MZC013]
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4,
                               atol=2e-4)
    print("tp_dp_forward ok")


def check_sharded_decode_matches_single():
    mesh = make_mesh((2, 4), ("data", "model"))
    params = api.init_params(CFG, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0,
                              CFG.vocab)
    last, cache = api.prefill(CFG, params, {"tokens": toks}, 32)
    lg_want, _ = api.decode_step(
        CFG, params, jnp.argmax(last, -1).astype(jnp.int32), cache)
    pshard = params_shardings(mesh, params)
    cshard = cache_shardings(mesh, cache, CFG.kv_heads, 4)
    with mesh:
        p = jax.device_put(params, pshard)
        c = jax.device_put(cache, cshard)
        # one-shot parity check: traced once, then discarded
        lg, _ = jax.jit(lambda pp, tt, cc: api.decode_step(  # mzc: ignore[MZC013]
            CFG, pp, tt, cc))(p, jnp.argmax(last, -1).astype(jnp.int32),
                              c)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_want),
                               rtol=2e-4, atol=2e-4)
    print("sharded_decode ok")


def check_serving_engine_tp_matches_single():
    """ServingEngine with a TP mesh (policy tp consumed) must emit the
    same tokens as the unsharded single-device engine."""
    from repro.serving.engine import Request, ServingEngine
    mesh = make_mesh((2, 4), ("data", "model"))
    params = api.init_params(CFG, jax.random.PRNGKey(0))
    prompts = [np.arange(4 + i, dtype=np.int32) + i for i in range(4)]

    def run(mesh_arg, decode_batch=None):
        eng = ServingEngine(CFG, params, max_batch=4, max_len=32,
                            decode_batch=decode_batch, mesh=mesh_arg)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=5)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        return [r.out_tokens for r in reqs]

    want = run(None)
    got = run(mesh)
    assert got == want, (got, want)
    got_sub = run(mesh, decode_batch=2)   # compacted decode, sharded
    assert got_sub == want, (got_sub, want)
    print("serving_tp ok")


def check_pipeline_parallel():
    mesh = make_mesh((8,), ("pp",))
    n_stages, n_micro, mb, d = 8, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), n_stages)
    ws = jnp.stack([jax.random.normal(k, (d, d)) * 0.3 for k in ks])
    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))

    def layer(w, h):
        return jnp.tanh(h @ w["w"])

    stage_params = {"w": ws}
    got = pipeline_apply(layer, stage_params, x, mesh=mesh, axis="pp")
    want = x
    for i in range(n_stages):
        want = jnp.tanh(want @ ws[i])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    print("pipeline_parallel ok")


def check_optimizer_shardings_cover_tree():
    mesh = make_mesh((2, 4), ("data", "model"))
    params = jax.eval_shape(
        lambda: api.init_params(CFG, jax.random.PRNGKey(0)))
    for name in ("adamw", "adafactor"):
        ocfg = OptimizerConfig(name=name)
        opt = jax.eval_shape(lambda: init_opt(ocfg, params))
        sh = optimizer_shardings(mesh, params, {"inner": opt})
        n = len(jax.tree_util.tree_leaves(sh))
        assert n == len(jax.tree_util.tree_leaves(opt)), (name, n)
    print("optimizer_shardings ok")


def check_elastic_reshard_roundtrip(tmpdir):
    """Save on mesh A (2x4), restore onto mesh B (4x2)."""
    from repro.checkpoint.manager import CheckpointManager
    params = api.init_params(CFG, jax.random.PRNGKey(0))
    mesh_a = make_mesh((2, 4), ("data", "model"))
    mesh_b = make_mesh((4, 2), ("data", "model"))
    pa = jax.device_put(params, params_shardings(mesh_a, params))
    m = CheckpointManager(tmpdir)
    m.save(1, pa)
    shard_b = params_shardings(mesh_b, params)
    out, _ = m.restore(params, shardings=shard_b)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    leaf = jax.tree_util.tree_leaves(out)[0]
    assert leaf.sharding.mesh.shape == mesh_b.shape
    print("elastic_reshard ok")


if __name__ == "__main__":
    import tempfile
    check_tp_dp_forward_matches_single()
    check_sharded_decode_matches_single()
    check_serving_engine_tp_matches_single()
    check_pipeline_parallel()
    check_optimizer_shardings_cover_tree()
    with tempfile.TemporaryDirectory() as td:
        check_elastic_reshard_roundtrip(td)
    print("ALL_PARALLEL_OK")
