"""Tier-1 tests for the multi-replica serving cluster + int8 KV quant.

Covers the ISSUE-8 acceptance surface: router policies (round-robin
rotation over healthy replicas, least-loaded-by-free-pages,
join-shortest-queue), the seeded open-loop workload/LoadGenerator
determinism (and that the hoisted Zipf mix replays bench_serving's
pre-hoist trace), churn fairness under a cluster, the replica-failure
injection contract (a killed replica's in-flight requests finish on the
survivors with the token streams an uninterrupted run produces — zero
lost or duplicated tokens), cluster metrics, the `replica_meshes` data-
axis split, and the int8-quantized paged KV path (token-level parity
tolerance vs f32, >= 2x pages per HBM byte, per-page scale shapes, and
stale-data hygiene on page reuse).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import api
from repro.models.config import ModelConfig
from repro.serving import quant as kvq
from repro.serving import workload
from repro.serving.cluster import (
    ClusterMetrics,
    LoadGenerator,
    Router,
    ServingCluster,
)
from repro.serving.engine import Request, ServingEngine

TINY = ModelConfig(
    name="tiny-cluster",
    n_layers=2,
    d_model=32,
    n_heads=4,
    kv_heads=2,
    head_dim=8,
    d_ff=64,
    vocab=61,
    dtype="float32",
    param_dtype="float32",
    scan_layers=False,
)


@pytest.fixture(scope="module")
def tiny_params():
    return api.init_params(TINY, jax.random.PRNGKey(0))


def _mk_requests(n, seed=3, max_new=6, bands=((4, 9), (10, 14))):
    rng = np.random.default_rng(seed)
    return workload.zipf_mix_requests(
        rng, n, TINY.vocab, bands=bands, max_new_tokens=max_new
    )


def _mk_cluster(params, **kw):
    kw.setdefault("n_replicas", 2)
    kw.setdefault("router", "round_robin")
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("page_size", 8)
    kw.setdefault("num_pages", 33)
    return ServingCluster(TINY, params, **kw)


# -- workload / load generator ------------------------------------------------


def test_zipf_mix_matches_pre_hoist_trace():
    """The hoisted generator must replay the exact draw order of the old
    inline bench_serving mix, or every fixed-seed baseline shifts."""
    bands = workload.DEFAULT_BANDS
    weights = np.asarray([1.0, 1 / 2.0, 1 / 3.0])
    weights = weights / weights.sum()
    rng_old = np.random.default_rng(7)
    old = []
    for _ in range(12):
        lo, hi = bands[int(rng_old.choice(len(bands), p=weights))]
        old.append(
            rng_old.integers(0, 512, size=int(rng_old.integers(lo, hi + 1))).astype(
                np.int32
            )
        )
    new = workload.zipf_mix_requests(np.random.default_rng(7), 12, 512)
    assert [len(p) for p in old] == [len(r.prompt) for r in new]
    assert all(np.array_equal(p, r.prompt) for p, r in zip(old, new))


def test_poisson_arrivals_seeded_and_monotone():
    a = workload.poisson_arrivals(np.random.default_rng(5), 20, rate=100.0)
    b = workload.poisson_arrivals(np.random.default_rng(5), 20, rate=100.0)
    assert np.array_equal(a, b)
    assert np.all(np.diff(a) > 0)
    assert np.all(workload.poisson_arrivals(np.random.default_rng(5), 4, 0.0) == 0.0)


def test_load_generator_schedule_deterministic():
    mk = lambda: LoadGenerator(n_requests=6, rate=50.0, vocab=61, seed=11).schedule()
    s1, s2 = mk(), mk()
    assert [t for t, _ in s1] == [t for t, _ in s2]
    assert all(np.array_equal(a.prompt, b.prompt) for (_, a), (_, b) in zip(s1, s2))
    assert [t for t, _ in s1] == sorted(t for t, _ in s1)


# -- router policies ----------------------------------------------------------


def test_router_rejects_unknown_policy():
    with pytest.raises(ValueError):
        Router("bogus")


def test_round_robin_cycles_and_skips_dead(tiny_params):
    cl = _mk_cluster(tiny_params, n_replicas=3)
    picks = [cl.router.pick(cl.replicas, [0, 1, 2]) for _ in range(6)]
    assert picks == [0, 1, 2, 0, 1, 2]
    # a dead replica's turn passes to the next healthy one
    picks = [cl.router.pick(cl.replicas, [0, 2]) for _ in range(4)]
    assert 1 not in picks and set(picks) == {0, 2}


def test_least_loaded_routes_to_free_pages(tiny_params):
    cl = _mk_cluster(tiny_params, router="least_loaded")
    # drain pages from replica 0: the router must prefer replica 1
    assert cl.replicas[0].pool.ensure(0, 64)
    assert cl.router.pick(cl.replicas, [0, 1]) == 1
    cl.replicas[0].pool.release(0)
    # tie -> lowest id
    assert cl.router.pick(cl.replicas, [0, 1]) == 0


def test_shortest_queue_balances_queued_plus_live(tiny_params):
    cl = _mk_cluster(tiny_params, router="shortest_queue")
    reqs = _mk_requests(3)
    cl.replicas[0].queue.extend(reqs[:2])
    assert cl.router.pick(cl.replicas, [0, 1]) == 1
    cl.replicas[1].queue.extend(reqs)
    assert cl.router.pick(cl.replicas, [0, 1]) == 0


# -- cluster serving ----------------------------------------------------------


def test_cluster_completes_all_requests_and_attributes_metrics(tiny_params):
    cl = _mk_cluster(tiny_params)
    reqs = _mk_requests(6)
    for r in reqs:
        cl.submit(r)
    cl.run()
    assert all(r.done for r in reqs)
    s = cl.metrics.summary(cl)
    assert s["aggregate"]["n_finished"] == 6
    assert s["aggregate"]["tokens_out"] == sum(len(r.out_tokens) for r in reqs)
    assert sum(r["n_finished"] for r in s["per_replica"]) == 6
    assert len(cl.metrics.series["free_pages"]) == cl.stats["steps"]


def test_cluster_matches_single_engine_tokens(tiny_params):
    """Routing must not change what any request decodes (greedy)."""
    single = _mk_requests(6, seed=9)
    eng = ServingEngine(
        TINY, tiny_params, max_batch=2, max_len=64, page_size=8, num_pages=33
    )
    for r in single:
        eng.submit(r)
    eng.run()
    clustered = _mk_requests(6, seed=9)
    cl = _mk_cluster(tiny_params)
    for r in clustered:
        cl.submit(r)
    cl.run()
    assert [r.out_tokens for r in clustered] == [r.out_tokens for r in single]


def test_churn_fairness_under_cluster_preemption(tiny_params):
    """Page-pool churn inside a replica (preempt/resume) must not starve
    or corrupt any request routed to it: every request finishes with
    exactly max_new tokens and no preempted request is lost."""
    cl = _mk_cluster(tiny_params, num_pages=6, max_batch=3)
    reqs = _mk_requests(8, seed=2, max_new=12, bands=((4, 8),))
    for r in reqs:
        cl.submit(r)
    cl.run()
    assert all(r.done for r in reqs)
    assert all(r.finish_reason == "max_new_tokens" for r in reqs)
    assert all(len(r.out_tokens) == 12 for r in reqs)
    agg = cl.metrics.summary(cl)["aggregate"]
    assert agg["preemptions"] > 0, "geometry no longer exercises churn"
    assert agg["rejected"] == 0


def test_kill_replica_finishes_elsewhere_with_exact_tokens(tiny_params):
    """ISSUE-8 acceptance: kill a replica mid-decode; its queued AND
    in-flight requests finish on the survivors with the token streams an
    uninterrupted run produces — zero lost, zero duplicated."""
    base = _mk_requests(8, seed=5)
    eng = ServingEngine(
        TINY, tiny_params, max_batch=2, max_len=64, page_size=8, num_pages=33
    )
    for r in base:
        eng.submit(r)
    eng.run()
    want = [list(r.out_tokens) for r in base]

    reqs = _mk_requests(8, seed=5)
    cl = _mk_cluster(tiny_params)
    for r in reqs:
        cl.submit(r)
    for _ in range(3):  # let replica 0 admit and decode a few tokens
        cl.step()
    assert any(s is not None for s in cl.replicas[0].slots)
    moved = cl.kill_replica(0)
    assert moved > 0
    cl.run()
    assert all(r.done for r in reqs)
    assert [list(r.out_tokens) for r in reqs] == want
    assert cl.stats["replica_failures"] == 1
    assert cl.stats["requeued"] == moved
    # the dead replica took no further work
    assert 0 not in cl.healthy
    assert all(s is None for s in cl.replicas[0].slots)
    assert not cl.replicas[0].queue


def test_kill_replica_guards(tiny_params):
    """ISSUE-9 semantics: killing the LAST healthy replica no longer
    raises — its work parks on the cluster for a later restart."""
    cl = _mk_cluster(tiny_params)
    reqs = _mk_requests(2, seed=6)
    for r in reqs:
        cl.submit(r)
    cl.step()
    cl.kill_replica(0)
    cl.kill_replica(1)  # total outage: parks, does not raise
    assert not cl.healthy
    assert len(cl.parked) == sum(1 for r in reqs if not r.done)
    assert cl.metrics.summary(cl)["aggregate"]["n_unrouted"] == len(cl.parked)
    assert cl.kill_replica(0) == 0  # already dead: no-op


def test_submits_after_failure_avoid_dead_replica(tiny_params):
    cl = _mk_cluster(tiny_params, n_replicas=3)
    cl.kill_replica(1)
    reqs = _mk_requests(6, seed=8)
    picks = {cl.submit(r) for r in reqs}
    assert 1 not in picks
    cl.run()
    assert all(r.done for r in reqs)


def test_open_loop_drive_completes_and_reports(tiny_params):
    cl = _mk_cluster(tiny_params, router="least_loaded")
    lg = LoadGenerator(n_requests=5, rate=200.0, vocab=TINY.vocab, seed=4)
    summary = cl.drive(lg.schedule())
    assert summary["aggregate"]["n_finished"] == 5
    assert summary["aggregate"]["ttft_p99_ms"] >= summary["aggregate"]["ttft_p50_ms"]


def test_cluster_metrics_empty_summary(tiny_params):
    cl = _mk_cluster(tiny_params)
    s = cl.metrics.summary(cl)
    assert s["aggregate"]["n_finished"] == 0
    assert s["aggregate"]["tokens_out"] == 0


# -- replica meshes -----------------------------------------------------------


def test_replica_meshes_split_data_axis():
    from repro.parallel.sharding import replica_meshes

    assert replica_meshes(None, 3) == [None, None, None]
    devs = np.array(jax.devices()[:1]).reshape(1, 1, 1)
    from jax.sharding import Mesh

    mesh = Mesh(devs, ("pod", "data", "model"))
    assert replica_meshes(mesh, 1) == [mesh]
    with pytest.raises(ValueError):
        replica_meshes(mesh, 2)  # data axis of 1 cannot split into 2
    mesh2 = Mesh(np.array(jax.devices()[:1] * 2).reshape(1, 2, 1),
                 ("pod", "data", "model"))
    subs = replica_meshes(mesh2, 2)
    assert len(subs) == 2
    assert all(m.devices.shape == (1, 1, 1) for m in subs)
    assert all(m.axis_names == ("pod", "data", "model") for m in subs)


# -- int8 KV quant ------------------------------------------------------------


def test_quant_roundtrip_tolerance():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 8, 2, 8)) * 3.0
    q, s = kvq.quantize_block(x, ps_axis=2)
    assert q.dtype == jnp.int8
    assert s.shape == (2, 4, 1, 2, 1)
    back = kvq.dequantize_block(q, s)
    err = jnp.abs(back - x).max() / jnp.abs(x).max()
    assert float(err) < 1.0 / 127.0


def test_quant_scales_are_per_page_and_head():
    x = jnp.zeros((1, 3, 4, 2, 8))
    # one hot page/head combination: only its scale moves off the floor
    x = x.at[0, 1, :, 1, :].set(100.0)
    _, s = kvq.quantize_block(x, ps_axis=2)
    assert float(s[0, 1, 0, 1, 0]) == pytest.approx(100.0 / 127.0)
    assert float(s[0, 1, 0, 0, 0]) == pytest.approx(kvq.SCALE_FLOOR)
    assert float(s[0, 0, 0, 1, 0]) == pytest.approx(kvq.SCALE_FLOOR)


def test_quant_pool_capacity_at_least_2x():
    budget = kvq.kv_page_nbytes(TINY, 8, quant=False) * 64
    f32 = kvq.pages_for_byte_budget(TINY, budget, 8, quant=False)
    int8 = kvq.pages_for_byte_budget(TINY, budget, 8, quant=True)
    assert int8 >= 2 * f32


def test_quant_engine_token_parity_tolerance(tiny_params):
    """ISSUE-8 acceptance: int8-KV serving is token-parity within
    tolerance vs f32 on fixed seeds (prefix-match fraction)."""
    outs = {}
    for q in (False, True):
        reqs = _mk_requests(6, seed=9, max_new=6)
        eng = ServingEngine(
            TINY, tiny_params, max_batch=2, max_len=64, page_size=8,
            num_pages=33, kv_quant=q,
        )
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done for r in reqs)
        outs[q] = [r.out_tokens for r in reqs]
    total = sum(len(t) for t in outs[False])
    matched = 0
    for a, b in zip(outs[False], outs[True]):
        for x, y in zip(a, b):
            if x != y:
                break
            matched += 1
    assert matched / total >= 0.7, (matched, total, outs)


def test_quant_pool_storage_is_int8_with_scales(tiny_params):
    eng = ServingEngine(
        TINY, tiny_params, max_batch=2, max_len=64, page_size=8,
        num_pages=17, kv_quant=True,
    )
    assert eng.kv_quant
    for leaf in jax.tree.leaves(eng.pool.segments):
        assert leaf.dtype == jnp.int8
    for k, g in zip(jax.tree.leaves(eng.pool.segments),
                    jax.tree.leaves(eng.pool.scales)):
        assert g.shape == k.shape[:2] + (1,) + k.shape[3:4] + (1,)
    f32 = ServingEngine(
        TINY, tiny_params, max_batch=2, max_len=64, page_size=8,
        num_pages=17, kv_quant=False,
    )
    assert f32.pool.page_nbytes >= 2 * eng.pool.page_nbytes


def test_quant_dense_engines_ignore_kv_quant(tiny_params):
    eng = ServingEngine(TINY, tiny_params, max_batch=2, max_len=32,
                        paged=False, kv_quant=True)
    assert not eng.kv_quant  # int8 rides the paged gather/scatter only


def test_quant_page_reuse_does_not_poison_scales(tiny_params):
    """A freed page re-allocated to a new request must not let stale
    int8 garbage inflate the fresh scatter's absmax scales: serve two
    churny waves through a small pool and require decode to stay exact
    per-request (all requests same length => same token count)."""
    reqs = _mk_requests(6, seed=1, max_new=5, bands=((6, 10),))
    eng = ServingEngine(
        TINY, tiny_params, max_batch=2, max_len=64, page_size=8,
        num_pages=9, kv_quant=True,
    )
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done and r.finish_reason == "max_new_tokens" for r in reqs)
    assert all(len(r.out_tokens) == 5 for r in reqs)
    assert eng.pool.free_pages == 8  # everything released after churn
