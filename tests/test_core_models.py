"""Mozart analytical core: operator IR, perf model, cost model, P&R."""
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import costmodel, operators
from repro.core.chiplets import Chiplet, default_pool, full_design_space
from repro.core.memory import DDR5, HBM3, MEMORY_POOL
from repro.core.operators import (BATCH_AGNOSTIC, BATCH_SENSITIVE, OPT_66B,
                                  lm_operator_graph, paper_workloads)
from repro.core.perfmodel import (StageConfig, enumerate_stage_options,
                                  evaluate_group, gpu_eval, is_memory_bound,
                                  scale_option)
from repro.core.pnr import place_and_route


# --- operator IR ------------------------------------------------------------

def test_paper_workloads_shapes():
    ws = paper_workloads()
    assert set(ws) >= {"resnet50", "mobilenetv3", "efficientnet",
                       "replknet31b", "vit_b16", "opt66b_prefill",
                       "opt66b_decode"}
    for name, g in ws.items():
        assert g.total_flops > 0, name
        assert g.total_weight_bytes > 0, name
        assert len(g.operators) == len(g.repeats)


def test_opt66b_flops_magnitude():
    # prefill of 2048 tokens on ~65e9 matmul params: ~2*N*D FLOPs
    g = lm_operator_graph(OPT_66B, 2048, "prefill")
    assert 2.0e14 < g.total_flops < 4.5e14


def test_decode_graph_is_memory_heavy():
    gp = lm_operator_graph(OPT_66B, 2048, "prefill")
    gd = lm_operator_graph(OPT_66B, 2048, "decode", cache_len=2048)
    ai_p = gp.total_flops / sum(o.dram_bytes(1) * r for o, r in
                                zip(gp.operators, gp.repeats))
    ai_d = gd.total_flops / sum(o.dram_bytes(1) * r for o, r in
                                zip(gd.operators, gd.repeats))
    assert ai_p > 50 * ai_d      # decode is drastically less intense


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 64))
def test_batch_scaling_classes(batch):
    ops = {o.name: o for o in operators.lm_layer_operators(
        OPT_66B, seq=1, cache_len=2048, phase="decode")}
    att, mlp = ops["attention"], ops["mlp"]
    assert att.batch_scaling == BATCH_AGNOSTIC
    assert mlp.batch_scaling == BATCH_SENSITIVE
    # intensity: constant for attention, growing for mlp
    assert att.arithmetic_intensity(batch) == pytest.approx(
        att.arithmetic_intensity(1), rel=1e-6)
    if batch > 1:
        assert mlp.arithmetic_intensity(batch) > \
            mlp.arithmetic_intensity(1)


def test_moe_weight_reuse_divisor():
    spec = operators.LMSpec(name="moe", n_layers=2, d_model=512,
                            n_heads=8, kv_heads=8, d_ff=1024, vocab=1000,
                            n_experts=8, top_k=2)
    g = lm_operator_graph(spec, 128, "prefill")
    routed = [o for o in g.operators if o.name == "routed_experts"][0]
    assert routed.weight_reuse_divisor == pytest.approx(4.0)
    # at batch 1 only ~1/4 of expert weights are touched
    assert routed.dram_bytes(1) < routed.weight_bytes


# --- perf model ---------------------------------------------------------------

def test_roofline_latency_monotone_in_bandwidth():
    op = operators.lm_layer_operators(OPT_66B, 1, 2048, "decode")[2]
    c = Chiplet("OS", 2, 4, "2.5D")
    t = []
    for units in (1, 2, 4):
        so = evaluate_group([op], StageConfig(c, HBM3, units, 1, 1))
        t.append(so.t_cmp)
    assert t[0] >= t[1] >= t[2]


def test_small_op_underutilizes_big_array():
    op = operators.lm_layer_operators(OPT_66B, 1, 2048, "decode")[2]
    small = evaluate_group([op], StageConfig(Chiplet("WS", 1, 1, "2D"),
                                             HBM3, 4, 1, 1))
    big = evaluate_group([op], StageConfig(Chiplet("WS", 4, 1, "2.5D"),
                                           HBM3, 4, 1, 1))
    # the big array cannot be proportionally faster on a GEMV
    assert big.t_cmp > small.t_cmp / 64


def test_is_memory_bound_classifier():
    ops = {o.name: o for o in operators.lm_layer_operators(
        OPT_66B, 1, 2048, "decode")}
    c = Chiplet("WS", 3, 4, "2.5D")
    assert is_memory_bound(ops["mlp"], c, HBM3, batch=1)       # GEMV
    prefill_ops = {o.name: o for o in operators.lm_layer_operators(
        OPT_66B, 2048, 0, "prefill")}
    assert not is_memory_bound(prefill_ops["mlp"], c, HBM3, batch=4)


def test_fusion_reduces_dram_traffic():
    ops = operators.lm_layer_operators(OPT_66B, 128, 0, "prefill")[:4]
    cfg = StageConfig(Chiplet("WS", 4, 16, "2.5D"), HBM3, 2, 1, 1)
    fused = evaluate_group(ops, cfg)
    separate = [evaluate_group([o], cfg) for o in ops]
    assert fused.e_dyn <= sum(s.e_dyn for s in separate) + 1e-12


def test_enumerate_stage_options_nonempty_and_priced():
    ops = operators.lm_layer_operators(OPT_66B, 128, 0, "prefill")[:2]
    opts = enumerate_stage_options(ops, default_pool())
    assert len(opts) > 50
    priced = costmodel.price_stage_options(opts)
    assert all(o.hw_cost_usd > 0 for o in priced)


def test_gpu_eval_positive():
    g = paper_workloads()["resnet50"]
    lat, e = gpu_eval(g.operators, g.repeats, batch=1)
    assert lat > 0 and e > 0


# --- cost model ---------------------------------------------------------------

def test_yield_decreases_with_area():
    ys = [costmodel.die_yield(a) for a in (10, 50, 200, 800)]
    assert ys == sorted(ys, reverse=True)
    assert 0 < ys[-1] < ys[0] <= 1.0


def test_die_cost_superlinear_in_area():
    # cost(2A) > 2*cost(A): the economic case for disaggregation [24]
    assert costmodel.die_cost(400.0) > 2.0 * costmodel.die_cost(200.0)


def test_nre_amortization():
    ops = operators.lm_layer_operators(OPT_66B, 128, 0, "prefill")[:2]
    opts = costmodel.price_stage_options(
        enumerate_stage_options(ops, default_pool()[:2]))[:3]
    lone = costmodel.system_cost(opts, volume=1e6, n_networks_sharing={})
    shared = costmodel.system_cost(
        opts, volume=1e6,
        n_networks_sharing={o.cfg.chiplet.label: 200 for o in opts})
    assert shared.nre_per_unit < lone.nre_per_unit
    highvol = costmodel.system_cost(opts, volume=3e6,
                                    n_networks_sharing={})
    assert highvol.nre_per_unit < lone.nre_per_unit


# --- P&R ----------------------------------------------------------------------

def test_pnr_no_overlap_and_fits():
    ops = operators.lm_layer_operators(OPT_66B, 128, 0, "prefill")[:3]
    opts = costmodel.price_stage_options(
        enumerate_stage_options(ops, default_pool()[:3]))
    stages = opts[:6]
    r = place_and_route(stages)
    assert r.placements
    for i, a in enumerate(r.placements):
        assert a.x >= -1e-9 and a.y >= -1e-9
        assert a.x + a.w <= r.width + 1e-6
        assert a.y + a.h <= r.height + 1e-6
        for b in r.placements[i + 1:]:
            overlap_x = min(a.x + a.w, b.x + b.w) - max(a.x, b.x)
            overlap_y = min(a.y + a.h, b.y + b.h) - max(a.y, b.y)
            assert not (overlap_x > 1e-6 and overlap_y > 1e-6), \
                (a, b)
    # deterministic
    r2 = place_and_route(stages)
    assert r2.area_mm2 == r.area_mm2 and r2.wirelength_mm == r.wirelength_mm
