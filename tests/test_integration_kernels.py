"""Integration: the Pallas kernels driven THROUGH the model stack (the
fusion flags the Mozart policy layer toggles), interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import api, rglru, rwkv6, transformer as T
from repro.models.config import ModelConfig

pytestmark = pytest.mark.slow   # multi-minute JAX compile/run; excluded from tier-1

BASE = dict(n_layers=2, d_model=64, n_heads=4, kv_heads=2, head_dim=16,
            d_ff=128, vocab=97, dtype="float32", param_dtype="float32",
            scan_min_layers=2)


def test_model_with_flash_attention_kernel():
    cfg_ref = ModelConfig(name="ref", attn_impl="einsum", **BASE)
    cfg_fl = cfg_ref.replace(attn_impl="flash")
    params = T.init_params(cfg_ref, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 97)
    a = T.forward(cfg_ref, params, toks)
    b = T.forward(cfg_fl, params, toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-3, atol=2e-3)


def test_model_with_flash_attention_swa():
    cfg_ref = ModelConfig(name="ref", attn_impl="einsum", window=8, **BASE)
    cfg_fl = cfg_ref.replace(attn_impl="flash")
    params = T.init_params(cfg_ref, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, 97)
    a = T.forward(cfg_ref, params, toks)
    b = T.forward(cfg_fl, params, toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-3, atol=2e-3)


def test_rglru_kernel_matches_model_scan():
    """The rglru_scan Pallas kernel computes the same recurrence the
    model's associative scan does."""
    from repro.kernels.rglru_scan.ops import rglru_scan as kscan
    a = jax.random.uniform(jax.random.PRNGKey(0), (2, 40, 64),
                           minval=0.05, maxval=0.98)
    b = jax.random.normal(jax.random.PRNGKey(1), (2, 40, 64))
    h0 = jnp.zeros((2, 64))
    model_out = rglru.rglru_scan(a, b, h0)
    kernel_out = kscan(a, b, h0, bs=8, bw=32)
    np.testing.assert_allclose(np.asarray(model_out),
                               np.asarray(kernel_out),
                               rtol=1e-4, atol=1e-4)


def test_wkv6_kernel_matches_model_chunked():
    from repro.kernels.wkv6.ops import wkv6 as kwkv
    B, S, H, D = 2, 32, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    r = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, D))) * 0.9 + 0.05
    u = jax.random.normal(ks[4], (H, D)) * 0.1
    s0 = jnp.zeros((B, H, D, D))
    model_out, _ = rwkv6.wkv_chunked(r, k, v, w, u, s0, chunk=8)
    rf = r.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    wf = jnp.log(w).transpose(0, 2, 1, 3).reshape(B * H, S, D)
    uf = jnp.broadcast_to(u[None], (B, H, D)).reshape(B * H, 1, D)
    sf = s0.reshape(B * H, D, D)
    kernel_out = kwkv(rf, kf, vf, wf, uf, sf, chunk=8)
    kernel_out = kernel_out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(model_out),
                               np.asarray(kernel_out),
                               rtol=1e-4, atol=1e-4)


def test_moe_mlp_kernel_matches_model_experts():
    """The fused grouped-MLP kernel reproduces the model's expert math."""
    from repro.kernels.moe_mlp.ops import moe_mlp
    cfg = ModelConfig(name="m", n_experts=4, top_k=2,
                      capacity_factor=4.0, **BASE)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    seg = params["segments"][0]["kind_moe"]["moe"]
    wi = jax.tree.map(lambda a: a[0], seg["experts_in"])
    wg = jax.tree.map(lambda a: a[0], seg["experts_gate"])
    wo = jax.tree.map(lambda a: a[0], seg["experts_out"])
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 16, 64)) * 0.5
    want = jnp.einsum("ecf,efd->ecd",
                      jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, wg))
                      * jnp.einsum("ecd,edf->ecf", x, wi), wo)
    got = moe_mlp(x, wg, wi, wo, swiglu=True, bt=8, bf=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
