"""Tier-1 tests for the SLO-aware resilience layer (ISSUE-9).

Covers the acceptance surface: deadline plumbing and the EDF admission
order (exact FIFO when no deadlines exist, resumed requests first so
recovery stays token-exact), admission-control shedding of infeasible
deadlines, bounded queues with cluster-level backpressure shed, the
total-outage contract (park — never raise — then restart and finish
token-exactly), retry budgets classifying serial failovers as poison,
the watchdog (stall detection by missing token progress, NaN-flag
surfacing) with token-exact recovery after quarantine, the jitted
non-finite logits guard, NaN injection into live KV, the seeded
`ChaosSchedule` determinism, the deadline-band workload mix (and that
`deadline_bands=None` reproduces the historical trace byte-for-byte),
and the goodput accounting the chaos gate relies on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import api
from repro.models.config import ModelConfig
from repro.serving import resilience, workload
from repro.serving.cluster import ServingCluster
from repro.serving.engine import Request, ServingEngine
from repro.serving.resilience import (
    ChaosEvent,
    ChaosSchedule,
    Watchdog,
    goodput_tokens,
    goodput_violations,
    inject_nan,
    logits_finite,
)

TINY = ModelConfig(
    name="tiny-resilience",
    n_layers=2,
    d_model=32,
    n_heads=4,
    kv_heads=2,
    head_dim=8,
    d_ff=64,
    vocab=61,
    dtype="float32",
    param_dtype="float32",
    scan_layers=False,
)


@pytest.fixture(scope="module")
def tiny_params():
    return api.init_params(TINY, jax.random.PRNGKey(0))


def _mk_requests(n, seed=3, max_new=6, bands=((4, 9), (10, 14)), **kw):
    rng = np.random.default_rng(seed)
    return workload.zipf_mix_requests(
        rng, n, TINY.vocab, bands=bands, max_new_tokens=max_new, **kw
    )


def _req(rid, deadline_s=None, max_new=4, plen=4):
    prompt = np.arange(plen, dtype=np.int32) + 1
    return Request(rid=rid, prompt=prompt, max_new_tokens=max_new, deadline_s=deadline_s)


def _mk_engine(params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("page_size", 8)
    kw.setdefault("num_pages", 33)
    return ServingEngine(TINY, params, **kw)


def _mk_cluster(params, **kw):
    kw.setdefault("n_replicas", 2)
    kw.setdefault("router", "round_robin")
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("page_size", 8)
    kw.setdefault("num_pages", 33)
    return ServingCluster(TINY, params, **kw)


def _reference_tokens(params, reqs):
    eng = _mk_engine(params)
    for r in reqs:
        eng.submit(r)
    eng.run()
    return [list(r.out_tokens) for r in reqs]


# -- deadlines: workload mix, EDF admission, shedding -------------------------


def test_deadline_bands_leave_historical_trace_unchanged():
    """Deadline draws come from a spawned child generator, so attaching
    an SLO mix must NOT move the prompt draws (nor any draws the caller
    makes from the same rng afterwards, e.g. Poisson arrivals): the
    PR-8 fixed-seed traces stay byte-for-byte intact."""
    r_old, r_new = np.random.default_rng(7), np.random.default_rng(7)
    old = workload.zipf_mix_requests(r_old, 10, TINY.vocab)
    new = workload.zipf_mix_requests(
        r_new, 10, TINY.vocab, deadline_bands=workload.DEFAULT_DEADLINE_BANDS
    )
    assert all(np.array_equal(a.prompt, b.prompt) for a, b in zip(old, new))
    assert all(r.deadline_s is None for r in old)
    # the caller's continuation stream (arrival draws) is untouched too
    assert np.array_equal(
        workload.poisson_arrivals(r_old, 5, 10.0), workload.poisson_arrivals(r_new, 5, 10.0)
    )


def test_deadline_band_mix_is_seeded_and_in_band():
    bands = workload.DEFAULT_DEADLINE_BANDS
    a = _mk_requests(40, seed=11, deadline_bands=bands)
    b = _mk_requests(40, seed=11, deadline_bands=bands)
    assert [r.deadline_s for r in a] == [r.deadline_s for r in b]
    live = [r.deadline_s for r in a if r.deadline_s is not None]
    assert live, "the mix never drew a deadline band"
    assert any(r.deadline_s is None for r in a)
    for d in live:
        assert any(band is not None and band[0] <= d <= band[1] for band in bands)


def test_edf_admission_order_and_fifo_fallback(tiny_params):
    eng = _mk_engine(tiny_params)
    # no deadlines anywhere -> exact FIFO (submission order)
    for rid in range(3):
        eng.submit(_req(rid))
    assert eng.queue[eng._next_admission()].rid == 0
    eng.queue.clear()
    # tightest deadline first; None sorts after every real deadline
    for rid, dl in ((0, None), (1, 9.0), (2, 3.0)):
        eng.submit(_req(rid, deadline_s=dl))
    order = []
    while eng.queue:
        j = eng._next_admission()
        order.append(eng.queue.pop(j).rid)
    assert order == [2, 1, 0]
    # a resumed request (failover/preemption, has out_tokens) beats even
    # the tightest fresh deadline: recovery priority is what keeps the
    # kill/requeue path token-exact
    resumed = _req(7)
    resumed.out_tokens.append(5)
    eng.submit(_req(8, deadline_s=0.5))
    eng.submit(resumed)
    assert eng.queue[eng._next_admission()].rid == 7


def test_expired_deadline_is_shed_at_admission(tiny_params):
    eng = _mk_engine(tiny_params)
    doomed = _req(0, deadline_s=1e-9)
    fine = _req(1)
    eng.submit(doomed)
    eng.submit(fine)
    eng.run()
    assert doomed.finish_reason == "shed" and not doomed.out_tokens
    assert fine.done and len(fine.out_tokens) == 4
    assert eng.stats["shed"] == 1


def test_pace_infeasible_deadline_is_shed(tiny_params):
    """Once the EWMA pace exists, a deadline that cannot fit the
    remaining tokens is shed without wasting a slot on it."""
    eng = _mk_engine(tiny_params)
    eng.submit(_req(0))
    eng.run()
    assert eng._est_step_s > 0.0
    # feasible remaining time for ~0 tokens, infeasible for 1000
    slow = _req(1, deadline_s=eng._est_step_s * 5, max_new=1000)
    assert eng._deadline_infeasible(slow)
    assert not eng._deadline_infeasible(_req(2, deadline_s=60.0, max_new=1))
    assert not eng._deadline_infeasible(_req(3, max_new=1000))  # no deadline


def test_shed_disabled_keeps_expired_deadlines(tiny_params):
    eng = _mk_engine(tiny_params, shed_deadlines=False)
    req = _req(0, deadline_s=1e-9, max_new=2)
    eng.submit(req)
    eng.run()
    assert req.done and req.finish_reason != "shed"
    assert len(req.out_tokens) == 2


# -- bounded queues / backpressure --------------------------------------------


def test_engine_queue_bound_sheds(tiny_params):
    eng = _mk_engine(tiny_params, queue_bound=2)
    reqs = _mk_requests(4, seed=5)
    accepted = [eng.submit(r) for r in reqs]
    assert accepted == [True, True, False, False]
    assert eng.queue_full
    assert all(r.finish_reason == "shed" for r in reqs[2:])
    eng.run()
    assert all(r.done for r in reqs[:2])
    assert eng.stats["shed"] == 2


def test_cluster_backpressure_sheds_when_all_queues_full(tiny_params):
    cl = _mk_cluster(tiny_params, queue_bound=1)
    reqs = _mk_requests(4, seed=5)
    picks = [cl.submit(r) for r in reqs]
    # one per replica queue, then every healthy queue is full -> shed
    assert picks[:2] == [0, 1] and picks[2:] == [-1, -1]
    assert all(r.finish_reason == "shed" for r in reqs[2:])
    cl.run()
    agg = cl.metrics.summary(cl)["aggregate"]
    assert agg["shed"] == 2
    assert all(r.done for r in reqs)


# -- total outage / restart ---------------------------------------------------


def test_total_outage_parks_then_restart_finishes_exact(tiny_params):
    reqs = _mk_requests(4, seed=9)
    want = _reference_tokens(tiny_params, _mk_requests(4, seed=9))
    cl = _mk_cluster(tiny_params)
    for r in reqs:
        cl.submit(r)
    for _ in range(2):
        cl.step()
    cl.kill_replica(0)
    cl.kill_replica(1)  # total outage — must hold, not raise
    cl.run()  # nothing healthy: returns immediately
    assert not cl.healthy
    held = len(cl.parked)
    assert held == sum(1 for r in reqs if not r.done) > 0
    agg = cl.metrics.summary(cl)["aggregate"]
    assert agg["n_unrouted"] == held
    # submissions during the outage park too
    late = _req(99, max_new=3, plen=5)
    assert cl.submit(late) == -1
    assert len(cl.parked) == held + 1
    drained = cl.restart_replica(0)
    assert drained == held + 1 and not cl.parked
    cl.run()
    assert all(r.done for r in reqs) and late.done
    assert [list(r.out_tokens) for r in reqs] == want
    assert cl.stats["restarts"] == 1


def test_restart_rejoins_router_and_folds_stats(tiny_params):
    cl = _mk_cluster(tiny_params)
    reqs = _mk_requests(4, seed=2)
    for r in reqs:
        cl.submit(r)
    for _ in range(2):
        cl.step()
    before = cl.metrics.summary(cl)["aggregate"]["tokens_out"]
    cl.kill_replica(0)
    assert cl.restart_replica(0) == 0  # nothing parked to drain
    assert cl.healthy == [0, 1]
    assert cl.restart_replica(0) == 0  # already healthy: no-op
    cl.run()
    assert all(r.done for r in reqs)
    # the replaced engine's pre-kill counters folded into the aggregate
    agg = cl.metrics.summary(cl)["aggregate"]
    assert agg["tokens_out"] >= before
    assert agg["tokens_out"] >= sum(len(r.out_tokens) for r in reqs)
    # fresh engine actually took new work after rejoining
    picks = {cl.submit(r) for r in _mk_requests(4, seed=4)}
    assert 0 in picks
    cl.run()


def test_retry_budget_exhaustion_poisons(tiny_params):
    cl = _mk_cluster(tiny_params, n_replicas=3, retry_budget=1)
    req = _req(0, max_new=8, plen=6)
    cl.submit(req)
    cl.step()
    cl.kill_replica(cl.assignment[req.rid])  # retry 1: requeued
    assert not req.done and req.requeues == 1
    cl.kill_replica(cl.assignment[req.rid])  # retry 2: budget blown
    assert req.done and req.finish_reason == "poison"
    assert cl.stats["poisoned"] == 1
    cl.run()  # the survivor keeps serving; poison never re-enters
    agg = cl.metrics.summary(cl)["aggregate"]
    assert agg["poisoned"] == 1
    assert goodput_tokens([req]) == 0


# -- watchdog -----------------------------------------------------------------


def test_watchdog_quarantines_stall_token_exact(tiny_params):
    reqs = _mk_requests(6, seed=5)
    want = _reference_tokens(tiny_params, _mk_requests(6, seed=5))
    cl = _mk_cluster(tiny_params, watchdog=Watchdog(2, stall_steps=3))
    for r in reqs:
        cl.submit(r)
    for _ in range(2):
        cl.step()
    cl.stall_replica(0)
    cl.run()
    assert all(r.done for r in reqs)
    assert [list(r.out_tokens) for r in reqs] == want
    assert 0 not in cl.healthy
    assert cl.stats["quarantined"] == 1
    assert any(why == "stall" and i == 0 for _, i, why in cl.watchdog.events)


def test_watchdog_idle_replica_is_not_a_stall(tiny_params):
    wd = Watchdog(1, stall_steps=2)
    eng = _mk_engine(tiny_params)
    for _ in range(5):  # no work at all: never quarantined
        assert wd.check(0, eng) is None
    eng.submit(_req(0, max_new=2))
    assert wd.check(0, eng) is None  # work, no progress: strike 1
    assert wd.check(0, eng) == "stall"  # strike 2 = stall_steps
    eng.run()
    wd.reset(0)
    assert wd.check(0, eng) is None


def test_nan_guard_quarantine_and_exact_recovery(tiny_params):
    reqs = _mk_requests(6, seed=8)
    want = _reference_tokens(tiny_params, _mk_requests(6, seed=8))
    cl = _mk_cluster(tiny_params)
    for r in reqs:
        cl.submit(r)
    for _ in range(2):
        cl.step()
    assert inject_nan(cl.replicas[0])
    cl.run()
    assert all(r.done for r in reqs)
    # the guard fired BEFORE sampling: no garbage token ever entered a
    # stream, so recovery is byte-identical to the undisturbed run
    assert [list(r.out_tokens) for r in reqs] == want
    assert cl.replicas[0].health["nan_detected"]
    assert cl.replicas[0].stats["nan_steps"] >= 1
    assert any(why == "nan" for _, _, why in cl.watchdog.events)
    cl.restart_replica(0)
    assert not cl.replicas[0].health["nan_detected"]


def test_inject_nan_without_live_slots_is_noop(tiny_params):
    assert not inject_nan(_mk_engine(tiny_params))


def test_logits_finite_guard():
    ok = jnp.zeros((2, 61))
    assert logits_finite(ok)
    assert not logits_finite(ok.at[1, 3].set(jnp.nan))
    assert not logits_finite(ok.at[0, 0].set(jnp.inf))


# -- chaos schedule -----------------------------------------------------------


def test_chaos_event_rejects_unknown_kind():
    with pytest.raises(ValueError):
        ChaosEvent(1, 0, "meteor")


def test_chaos_generate_is_seeded_and_paired():
    a = ChaosSchedule.generate(seed=42, n_replicas=3, horizon=60)
    b = ChaosSchedule.generate(seed=42, n_replicas=3, horizon=60)
    assert a.events == b.events
    c = ChaosSchedule.generate(seed=43, n_replicas=3, horizon=60)
    assert a.events != c.events
    kinds = [e.kind for e in a.events]
    assert kinds.count("kill") == kinds.count("restart") - kinds.count("nan")
    assert kinds.count("stall") == kinds.count("unstall")
    # the last replica is never a fault target: the generated script
    # alone can never produce a total outage
    assert all(e.replica < 2 for e in a.events)
    assert a.pending and not a.fired


def test_chaos_apply_fires_in_step_order(tiny_params):
    cl = _mk_cluster(tiny_params)
    sched = ChaosSchedule([ChaosEvent(5, 0, "restart"), ChaosEvent(2, 0, "kill")])
    assert [e.step for e in sched.events] == [2, 5]
    assert sched.apply(cl, 1) == []
    fired = sched.apply(cl, 3)
    assert [e.kind for e in fired] == ["kill"] and cl.healthy == [1]
    assert sched.pending
    sched.apply(cl, 5)
    assert cl.healthy == [0, 1] and not sched.pending
    assert [ev.kind for _, ev in sched.fired] == ["kill", "restart"]


# -- goodput ------------------------------------------------------------------


def test_goodput_accounting():
    def fin(rid, n_tok, dl, late=False, reason="max_new_tokens"):
        r = _req(rid, deadline_s=dl, max_new=n_tok, plen=3)
        r.out_tokens = list(range(n_tok))
        r.t_submit = 100.0
        r.t_done = 100.0 + (dl * 2 if late and dl else 0.5)
        r.done = True
        r.finish_reason = reason
        return r

    reqs = [
        fin(0, 4, None),  # no deadline: counts
        fin(1, 3, 10.0),  # met deadline: counts
        fin(2, 5, 1.0, late=True),  # missed: wasted work
        fin(3, 2, None, reason="shed"),  # shed: never goodput
        fin(4, 2, None, reason="poison"),
        fin(5, 2, None, reason="rejected"),
        _req(6, max_new=2, plen=3),  # unfinished
    ]
    assert goodput_tokens(reqs) == 7
    assert goodput_violations(reqs) == 0
    assert resilience.goodput_tokens([]) == 0
