"""Fallback property-testing shim for environments without `hypothesis`.

Exposes the tiny subset the test-suite uses (`given`, `settings`,
`strategies.integers/sampled_from/booleans/floats`).  The fallback runs
each property against a deterministic seeded sample sweep — weaker than
real hypothesis (no shrinking, no example database) but it keeps the
property tests exercising the same code paths.  When `hypothesis` is
installed it is re-exported unchanged.
"""
from __future__ import annotations

import functools
import random

try:                                    # pragma: no cover - prefer the real thing
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng: random.Random):
            return self._sample(rng)

    class st:  # noqa: N801 - mirrors `hypothesis.strategies` spelling
        @staticmethod
        def integers(min_value=0, max_value=1 << 30):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def settings(max_examples: int = 20, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 20)
                rng = random.Random(0xC0FFEE)
                for _ in range(n):
                    drawn = [s.sample(rng) for s in arg_strategies]
                    kdrawn = {k: s.sample(rng)
                              for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **kdrawn)
            # pytest must see the zero-arg wrapper signature, not the
            # wrapped function's (it would demand fixtures for the
            # strategy parameters).
            del wrapper.__dict__["__wrapped__"]
            return wrapper
        return deco
