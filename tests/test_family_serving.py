"""Family-agnostic serving: the ISSUE-10 acceptance surface.

Every model family in the zoo decodes through the SAME engine loop via
its `serving.state.DecodeState`; these tests pin the contract:

* engine-vs-model parity per family — the engine's slot-churned decode
  must emit exactly the tokens a model-level `api.prefill` +
  `api.decode_step` greedy loop emits for each request;
* the pre-refactor GOLDEN token trace — a fixed-seed transformer run
  whose tokens were captured before the DecodeState refactor; every
  engine path (paged/dense x compact/full x f32/int8) must still
  reproduce it byte-for-byte;
* RecurrentState gather/scatter roundtrips under slot churn (property
  test: padding lanes duplicate real slots, untouched slots stay
  bitwise identical);
* mixed-family ServingCluster — tagged requests route only to replicas
  serving their model and finish with the tokens a per-family
  single-engine run produces;
* live speculative decoding — greedy token-exact vs the target-only
  engine, acceptance at the `high_tar_pair` shared-prefix ceiling, and
  the sampled-temperature rejection.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import api
from repro.models.config import ModelConfig
from repro.serving import workload
from repro.serving.cluster import ServingCluster
from repro.serving.engine import Request, ServingEngine
from repro.serving.specdec import SpecDecodeEngine, high_tar_pair
from repro.serving.state import RecurrentState, _gather_layers, _scatter_layers

TINY = dict(n_layers=2, d_model=32, d_ff=64, vocab=61,
            dtype="float32", param_dtype="float32")
ENC_LEN = 8

FAMILY_CFGS = {
    "transformer": ModelConfig(name="fam-tf", n_heads=2, kv_heads=1,
                               head_dim=16, scan_layers=False, **TINY),
    "rglru": ModelConfig(name="fam-rg", family="rglru", n_heads=2,
                         kv_heads=1, head_dim=16, lru_width=48,
                         attn_every=2, window=8, **TINY),
    "rwkv6": ModelConfig(name="fam-rw", family="rwkv6", head_dim=16,
                         wkv_chunk=8, **TINY),
    "whisper": ModelConfig(name="fam-wh", family="whisper", n_enc_layers=1,
                           n_heads=2, kv_heads=2, norm="layernorm",
                           swiglu=False, **TINY),
}


@pytest.fixture(scope="module")
def family_params():
    return {fam: api.init_params(cfg, jax.random.PRNGKey(0))
            for fam, cfg in FAMILY_CFGS.items()}


def _family_requests(cfg, n=3, max_new=5, seed=5):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(3, 9))
        frames = None
        if cfg.family == "whisper":
            frames = workload.synthetic_frames(rng, ENC_LEN, cfg.d_model)
        reqs.append(Request(rid=i,
                            prompt=rng.integers(0, cfg.vocab, size=plen)
                            .astype(np.int32),
                            max_new_tokens=max_new, frames=frames))
    return reqs


def _model_greedy(cfg, params, req, max_len=32):
    """Model-level reference: api.prefill + api.decode_step, batch=1,
    greedy argmax — the oracle the engine must match token-for-token."""
    toks = jnp.asarray(req.prompt[None, :], jnp.int32)
    if cfg.family == "whisper":
        # same fixed-window padding CrossAttnState applies at admission
        frames = np.zeros((1, ENC_LEN, cfg.d_model), np.float32)
        f = np.asarray(req.frames, np.float32)
        frames[0, :min(len(f), ENC_LEN)] = f[:ENC_LEN]
        last, cache = api.prefill(cfg, params, {
            "embeds": jnp.asarray(frames), "tokens": toks}, max_len)
    else:
        last, cache = api.prefill(cfg, params, {"tokens": toks}, max_len)
    out = [int(jnp.argmax(last[0, -1]))]
    while len(out) < req.max_new_tokens:
        lg, cache = api.decode_step(
            cfg, params, jnp.asarray([[out[-1]]], jnp.int32), cache)
        out.append(int(jnp.argmax(lg[0, -1])))
    return out


@pytest.mark.parametrize("family", sorted(FAMILY_CFGS))
def test_engine_matches_model_decode(family, family_params):
    """Slot-churned engine decode (max_batch=2 over 3 requests, so one
    request admits mid-flight) == per-request model-level greedy."""
    cfg = FAMILY_CFGS[family]
    params = family_params[family]
    reqs = _family_requests(cfg)
    eng = ServingEngine(cfg, params, max_batch=2, max_len=32,
                        paged=False, enc_len=ENC_LEN)
    assert eng.state.kind == {"transformer": "dense", "rglru": "recurrent",
                              "rwkv6": "recurrent",
                              "whisper": "cross_attn"}[family]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        assert r.finish_reason == "max_new_tokens"
        assert r.out_tokens == _model_greedy(cfg, params, r), \
            f"{family} engine diverged from model-level decode"


# -- pre-refactor golden trace ------------------------------------------------

GOLDEN_CFG = ModelConfig(name="golden", n_layers=2, d_model=64, n_heads=4,
                         kv_heads=2, head_dim=16, d_ff=128, vocab=97,
                         dtype="float32", param_dtype="float32",
                         scan_layers=False)
# captured from the pre-DecodeState engine (PR 9) at these exact seeds;
# any engine path changing ANY of these tokens broke decode
GOLDEN_TOKENS = [[71, 48, 48, 48, 48, 48],
                 [70, 16, 68, 80, 11, 54],
                 [92, 4, 90, 18, 45, 92],
                 [63, 22, 20, 96, 91, 22],
                 [77, 41, 84, 4, 7, 52],
                 [77, 89, 92, 36, 1, 77]]


def _golden_requests():
    rng = np.random.default_rng(7)
    reqs = []
    for i in range(6):
        plen = int(rng.integers(3, 9))
        reqs.append(Request(rid=i,
                            prompt=rng.integers(0, 97, size=plen)
                            .astype(np.int32),
                            max_new_tokens=6))
    return reqs


@pytest.mark.parametrize("paged,compact,kv_quant", [
    (True, True, "0"), (False, True, "0"), (False, False, "0"),
    (True, True, "1"), (False, True, "dense"),
])
def test_golden_trace_survives_refactor(paged, compact, kv_quant):
    params = api.init_params(GOLDEN_CFG, jax.random.PRNGKey(0))
    eng = ServingEngine(GOLDEN_CFG, params, max_batch=4, max_len=32,
                        paged=paged, compact=compact, kv_quant=kv_quant)
    reqs = _golden_requests()
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert [r.out_tokens for r in reqs] == GOLDEN_TOKENS


# -- RecurrentState gather/scatter roundtrip (property) -----------------------

RS_CFG = FAMILY_CFGS["rwkv6"]


def _filled_state(max_batch=4, max_len=16):
    """RecurrentState whose every leaf row b is filled with value b+1,
    so slot provenance is readable off any element."""
    state = RecurrentState(RS_CFG, max_batch, max_len, decode_batch=2)
    state.cache["layers"] = jax.tree.map(
        lambda a: jnp.broadcast_to(
            jnp.arange(1, max_batch + 1, dtype=a.dtype)
            .reshape((max_batch,) + (1,) * (a.ndim - 1)), a.shape).copy()
        if a.ndim >= 1 and a.shape[0] == max_batch else a,
        state.cache["layers"])
    state.cache["index"] = jnp.arange(max_batch, dtype=jnp.int32) * 3
    return state


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_recurrent_gather_scatter_roundtrip(seed):
    """Under arbitrary slot churn (random active sets, padding lanes
    duplicating active[0]) a gather->scatter with an unmodified sub-cache
    is the identity, and a modified sub-cache writes ONLY the selected
    slots — inactive recurrent state must stay bitwise untouched."""
    rng = np.random.default_rng(seed)
    max_batch = 4
    state = _filled_state(max_batch)
    before = jax.tree.map(lambda a: np.asarray(a), state.cache)
    n_active = int(rng.integers(1, max_batch + 1))
    active = sorted(rng.choice(max_batch, size=n_active, replace=False)
                    .tolist())
    sel = active + [active[0]] * (state.decode_batch - len(active)) \
        if n_active < state.decode_batch else active[:state.decode_batch]
    sel_arr = jnp.asarray(sel, jnp.int32)

    sub = _gather_layers(state.cache, sel_arr)
    for j, b in enumerate(sel):
        got = np.asarray(jax.tree.leaves(sub["layers"])[0])[j]
        assert np.all(got == b + 1)
    # identity roundtrip
    back = _scatter_layers(state.cache, sub, sel_arr)
    for a, c in zip(jax.tree.leaves(back), jax.tree.leaves(before)):
        np.testing.assert_array_equal(np.asarray(a), c)
    # modified sub touches exactly the selected slots
    bumped = {"layers": jax.tree.map(lambda a: a + 100, sub["layers"]),
              "index": sub["index"] + 1}
    after = _scatter_layers(state.cache, bumped, sel_arr)
    touched = set(sel)
    for leaf_a, leaf_b in zip(jax.tree.leaves(after["layers"]),
                              jax.tree.leaves(before["layers"])):
        for b in range(max_batch):
            if b in touched:
                assert np.all(np.asarray(leaf_a)[b] == leaf_b[b] + 100)
            else:
                np.testing.assert_array_equal(np.asarray(leaf_a)[b],
                                              leaf_b[b])


# -- mixed-family cluster -----------------------------------------------------

def test_mixed_family_cluster_token_parity(family_params):
    """A fleet with one transformer and one rwkv6 replica: tagged
    requests route only to their family's replica and finish with
    exactly the tokens the per-family single-engine runs produce."""
    tf_cfg, rw_cfg = FAMILY_CFGS["transformer"], FAMILY_CFGS["rwkv6"]
    tf_p, rw_p = family_params["transformer"], family_params["rwkv6"]

    def traces():
        tf_t = workload.zipf_mix_requests(
            np.random.default_rng(2), 4, tf_cfg.vocab,
            bands=((3, 8),), max_new_tokens=5, model=tf_cfg.name)
        rw_t = workload.zipf_mix_requests(
            np.random.default_rng(9), 4, rw_cfg.vocab,
            bands=((3, 8),), max_new_tokens=5, model=rw_cfg.name)
        return tf_t, rw_t

    tf_trace, rw_trace = traces()
    cluster = ServingCluster(
        tf_cfg, tf_p, replica_models=[(tf_cfg, tf_p), (rw_cfg, rw_p)],
        max_batch=2, max_len=32, paged=False)
    merged = workload.interleave_tagged([tf_trace, rw_trace])
    for r in merged:
        cluster.submit(r)
    cluster.run()
    # every tagged request landed on the one eligible replica
    for r in merged:
        assert r.finish_reason == "max_new_tokens"
        i = cluster.assignment[r.rid]
        assert cluster.replicas[i].mcfg.name == r.model

    ref_tf, ref_rw = traces()
    for cfg, p, trace in ((tf_cfg, tf_p, ref_tf), (rw_cfg, rw_p, ref_rw)):
        eng = ServingEngine(cfg, p, max_batch=2, max_len=32, paged=False)
        for r in trace:
            eng.submit(r)
        eng.run()
    assert [r.out_tokens for r in tf_trace] == [r.out_tokens for r in ref_tf]
    assert [r.out_tokens for r in rw_trace] == [r.out_tokens for r in ref_rw]


# -- live speculative decoding ------------------------------------------------

SPEC_CFG = ModelConfig(name="fam-spec", n_layers=4, d_model=32, n_heads=2,
                       kv_heads=1, head_dim=16, d_ff=64, vocab=61,
                       dtype="float32", param_dtype="float32",
                       scan_layers=False)


def _spec_requests(n=4, max_new=8):
    rng = np.random.default_rng(13)
    return [Request(rid=i,
                    prompt=rng.integers(0, SPEC_CFG.vocab,
                                        size=int(rng.integers(3, 8)))
                    .astype(np.int32),
                    max_new_tokens=max_new) for i in range(n)]


def test_specdec_token_exact_vs_target_only():
    """Greedy spec-decode emits EXACTLY the target-only stream even with
    a random (near-zero-acceptance) draft — acceptance only buys speed."""
    params = api.init_params(SPEC_CFG, jax.random.PRNGKey(0))
    dcfg = SPEC_CFG.replace(name="fam-spec-d", n_layers=1)
    dparams = api.init_params(dcfg, jax.random.PRNGKey(1))
    ref = ServingEngine(SPEC_CFG, params, max_batch=2, max_len=32,
                        paged=False)
    ref_reqs = _spec_requests()
    for r in ref_reqs:
        ref.submit(r)
    ref.run()
    eng = SpecDecodeEngine(SPEC_CFG, params, dcfg, dparams, k=3,
                           max_batch=2, max_len=32)
    reqs = _spec_requests()
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert [r.out_tokens for r in reqs] == \
        [r.out_tokens for r in ref_reqs]
    assert eng.spec_stats.iterations > 0


def test_specdec_high_tar_pair_full_acceptance():
    """high_tar_pair zeroes the target's residual writes past n_draft, so
    the draft IS the target's prefix: every proposal must be accepted."""
    params = api.init_params(SPEC_CFG, jax.random.PRNGKey(0))
    tparams, dcfg, dparams = high_tar_pair(SPEC_CFG, params, 2)
    eng = SpecDecodeEngine(SPEC_CFG, tparams, dcfg, dparams, k=3,
                           max_batch=2, max_len=32)
    reqs = _spec_requests()
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert eng.spec_stats.acceptance_rate == pytest.approx(1.0)
    assert eng.spec_stats.tokens_per_iteration == pytest.approx(3.0)


def test_specdec_rejects_sampled_requests():
    params = api.init_params(SPEC_CFG, jax.random.PRNGKey(0))
    dcfg = SPEC_CFG.replace(name="fam-spec-d2", n_layers=1)
    dparams = api.init_params(dcfg, jax.random.PRNGKey(1))
    eng = SpecDecodeEngine(SPEC_CFG, params, dcfg, dparams, k=2,
                           max_batch=2, max_len=32)
    with pytest.raises(ValueError, match="greedy"):
        eng.submit(Request(rid=0, prompt=np.asarray([1, 2, 3], np.int32),
                           temperature=0.7))
