"""Serving engine: continuous batching correctness, sampling, spec-decode
equivalence properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import api, transformer as T
from repro.models.config import ModelConfig
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampling import sample
from repro.serving.specdec import spec_decode_greedy, spec_decode_sampled

pytestmark = pytest.mark.slow   # multi-minute JAX compile/run; excluded from tier-1

CFG = ModelConfig(name="tiny", n_layers=2, d_model=64, n_heads=4,
                  kv_heads=2, head_dim=16, d_ff=128, vocab=97,
                  dtype="float32", param_dtype="float32",
                  scan_min_layers=2)


@pytest.fixture(scope="module")
def params():
    return api.init_params(CFG, jax.random.PRNGKey(0))


def _single_decode(params, prompt, n=8):
    toks = jnp.asarray(prompt[None], jnp.int32)
    last, cache = api.prefill(CFG, params, {"tokens": toks}, 64)
    out = [int(jnp.argmax(last[0, -1]))]
    for _ in range(n - 1):
        lg, cache = api.decode_step(
            CFG, params, jnp.asarray([[out[-1]]], jnp.int32), cache)
        out.append(int(jnp.argmax(lg[0, -1])))
    return out


def test_continuous_batching_matches_single(params):
    prompts = [np.arange(4 + i, dtype=np.int32) + i for i in range(5)]
    want = [_single_decode(params, p) for p in prompts]
    eng = ServingEngine(CFG, params, max_batch=3, max_len=64)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=8)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r, w in zip(reqs, want):
        assert r.out_tokens == w, r.rid
    assert eng.stats["prefills"] == 5
    assert 0 < np.mean(eng.stats["slot_occupancy"]) <= 1.0


def test_sampling_modes():
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]])
    key = jax.random.PRNGKey(0)
    assert int(sample(logits, key)[0]) == 1                   # greedy
    s = sample(logits, key, temperature=1.0, top_k=1)
    assert int(s[0]) == 1                                     # top-1
    draws = [int(sample(logits, jax.random.PRNGKey(i),
                        temperature=1.0, top_p=0.5)[0])
             for i in range(20)]
    assert set(draws) == {1}                                  # p mass top-1


def test_specdec_greedy_equals_target(params):
    dcfg = CFG.replace(n_layers=1, d_model=32, n_heads=2, kv_heads=1,
                       d_ff=64)
    dparams = api.init_params(dcfg, jax.random.PRNGKey(1))
    # per-test closures over params: retracing is the point of the test
    tf = jax.jit(lambda t: T.forward(CFG, params, t))  # mzc: ignore[MZC013]
    df = jax.jit(lambda t: T.forward(dcfg, dparams, t))  # mzc: ignore[MZC013]
    prompt = np.arange(6, dtype=np.int32)
    out, stats = spec_decode_greedy(tf, df, prompt, k=4,
                                    max_new_tokens=12)
    ref = list(prompt)
    for _ in range(12):
        lg = tf(jnp.asarray([ref], jnp.int32))
        ref.append(int(jnp.argmax(lg[0, -1])))
    assert list(out) == ref[len(prompt):]
    assert stats.iterations >= 1
    assert stats.tokens_per_iteration >= 1.0


def test_specdec_self_draft_accepts_everything(params):
    """Draft == target => every proposal accepted, k+1 tokens/iter."""
    tf = jax.jit(lambda t: T.forward(CFG, params, t))  # mzc: ignore[MZC013]
    prompt = np.arange(5, dtype=np.int32)
    out, stats = spec_decode_greedy(tf, tf, prompt, k=4,
                                    max_new_tokens=10)
    assert stats.acceptance_rate == pytest.approx(1.0)
    assert stats.tokens_per_iteration == pytest.approx(5.0)


def test_specdec_sampled_runs(params):
    dcfg = CFG.replace(n_layers=1)
    dparams = api.init_params(dcfg, jax.random.PRNGKey(2))
    tf = jax.jit(lambda t: T.forward(CFG, params, t))  # mzc: ignore[MZC013]
    df = jax.jit(lambda t: T.forward(dcfg, dparams, t))  # mzc: ignore[MZC013]
    out, stats = spec_decode_sampled(tf, df, np.arange(4, dtype=np.int32),
                                     jax.random.PRNGKey(3), k=3,
                                     max_new_tokens=8)
    assert len(out) == 8
    assert 0.0 <= stats.acceptance_rate <= 1.0
