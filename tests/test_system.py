"""End-to-end behaviour tests for the paper's system: the full Mozart
codesign stack feeding an execution policy into the JAX substrate."""
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import operators
from repro.core.codesign import design_for_network, run_codesign
from repro.core.fusion import GAConfig, Requirement
from repro.core.policy import policy_from_design
from repro.core.pool import SAConfig
from repro.models import api
from repro.models.config import ModelConfig


def test_codesign_to_execution_policy_to_substrate():
    """Paper pipeline end to end: operator graph -> 4-layer DSE ->
    execution policy -> policy-configured substrate runs."""
    graph = operators.lm_operator_graph(
        operators.OPT_1_3B, seq=256, phase="decode", cache_len=256)
    design = design_for_network(
        graph, None or __import__(
            "repro.core.chiplets", fromlist=["default_pool"]
        ).default_pool(),
        objective="energy_cost",
        req=Requirement(tpot=0.15),
        ga=GAConfig(population=5, generations=2))
    assert design is not None
    assert design.pnr.placements
    pol = policy_from_design(design)
    blob = json.loads(pol.to_json())

    # Insight 2 must show up in the deployed policy
    assert pol.batch_agnostic_batch <= pol.batch_sensitive_batch

    # apply the policy to the substrate: fusion flags select kernels,
    # decode batch comes from the policy's batching decision
    flags = pol.fusion_flags()
    attn_impl = "flash" if flags["flash_attention"] else "einsum"
    cfg = ModelConfig(name="deploy", n_layers=2, d_model=64, n_heads=4,
                      kv_heads=2, head_dim=16, d_ff=128, vocab=128,
                      dtype="float32", param_dtype="float32",
                      attn_impl=attn_impl, scan_min_layers=2)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    b = max(1, min(pol.batch_agnostic_batch, 4))
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, 16), 0,
                              cfg.vocab)
    logits = api.forward(cfg, params, {"tokens": toks})
    assert logits.shape == (b, 16, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_full_codesign_small():
    ws = operators.paper_workloads(seq=256)
    nets = {"resnet50": ws["resnet50"],
            "opt66b_decode": ws["opt66b_decode"]}
    out = run_codesign(nets, objective="edp", pool_size=4,
                       sa=SAConfig(iterations=2,
                                   inner_ga=GAConfig(population=4,
                                                     generations=1)),
                       final_ga=GAConfig(population=5, generations=2))
    assert set(out.designs) == set(nets)
    # heterogeneity: the two networks should not share every stage SKU
    skus = {n: {o.cfg.chiplet.label
                for o in d.fusion.solution.stages}
            for n, d in out.designs.items()}
    assert skus["resnet50"] or skus["opt66b_decode"]
    # ecosystem reuse is reported
    assert out.chiplet_reuse()
