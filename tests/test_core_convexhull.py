"""Property tests for the iso-latency / modified convex hull layer —
the paper's Algorithm 1 against brute force, via hypothesis."""
import math
import random

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.chiplets import Chiplet
from repro.core.convexhull import (DynamicLowerHull, LiChaoTree, Line,
                                   default_latency_grid, solve_pipeline,
                                   solve_pipeline_bruteforce,
                                   stage_envelope,
                                   stage_envelope_bruteforce)
from repro.core.memory import HBM3
from repro.core.perfmodel import StageConfig, StageOption


def mk_option(rng) -> StageOption:
    cfg = StageConfig(Chiplet(), HBM3, 1, 1, 1)
    return StageOption(t_cmp=rng.uniform(0.05, 10.0),
                       e_dyn=rng.uniform(0.1, 100.0),
                       p_static=rng.uniform(0.01, 5.0),
                       hw_cost_usd=rng.uniform(1.0, 1000.0),
                       cfg=cfg)


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 10_000))
def test_envelope_engines_match_bruteforce(seed):
    rng = random.Random(seed)
    opts = [mk_option(rng) for _ in range(rng.randint(1, 40))]
    lat = sorted(rng.uniform(0.01, 15.0)
                 for _ in range(rng.randint(1, 40)))
    bf = stage_envelope_bruteforce(opts, lat)
    for engine in ("hull", "lichao"):
        env = stage_envelope(opts, lat, engine=engine)
        for (v1, _), (v2, _) in zip(env, bf):
            if math.isinf(v2):
                assert math.isinf(v1)
            else:
                assert math.isclose(v1, v2, rel_tol=1e-9), engine


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000),
       st.sampled_from(["energy", "edp", "energy_cost", "edp_cost"]))
def test_solve_pipeline_matches_bruteforce(seed, objective):
    rng = random.Random(seed)
    stages = [[mk_option(rng) for _ in range(rng.randint(1, 15))]
              for _ in range(rng.randint(1, 5))]
    lat = sorted(rng.uniform(0.01, 15.0)
                 for _ in range(rng.randint(1, 25)))
    a = solve_pipeline(stages, lat, objective=objective)
    b = solve_pipeline_bruteforce(stages, lat, objective=objective)
    assert (a is None) == (b is None)
    if a is not None:
        assert math.isclose(a.value, b.value, rel_tol=1e-9)
        assert math.isclose(a.T, b.T, rel_tol=1e-9)


def test_constraints_respected():
    rng = random.Random(0)
    stages = [[mk_option(rng) for _ in range(10)] for _ in range(3)]
    lat = sorted(rng.uniform(0.01, 20.0) for _ in range(50))
    sol = solve_pipeline(stages, lat, objective="energy", max_e2e=30.0)
    if sol is not None:
        assert sol.delay_e2e <= 30.0 + 1e-12
    sol2 = solve_pipeline(stages, lat, objective="energy",
                          max_interval=0.001)
    assert sol2 is None or sol2.T <= 0.001


def test_repeat_scaling_changes_objective():
    rng = random.Random(1)
    base = [mk_option(rng) for _ in range(5)]
    from repro.core.perfmodel import scale_option
    scaled = [scale_option(o, 4) for o in base]
    lat = [max(o.t_cmp for o in base) * 2]
    a = solve_pipeline([base], lat, objective="energy")
    b = solve_pipeline([scaled], lat, objective="energy", n_stages=4)
    assert b.energy_per_sample == pytest.approx(4 * a.energy_per_sample)
    assert b.delay_e2e == pytest.approx(4 * a.delay_e2e)


def test_dynamic_hull_dominated_line_dropped():
    h = DynamicLowerHull()
    h.insert(Line(1.0, 0.0))
    h.insert(Line(-1.0, 10.0))
    h.insert(Line(0.0, 100.0))    # dominated everywhere on envelope
    for x in (0.0, 2.0, 5.0, 8.0):
        want = min(x, -x + 10.0, 100.0)
        assert h.query(x).at(x) == pytest.approx(want)


def test_default_latency_grid_covers_feasible_range():
    rng = random.Random(2)
    stages = [[mk_option(rng) for _ in range(8)] for _ in range(3)]
    grid = default_latency_grid(stages, n=32)
    assert min(grid) <= min(o.t_cmp for opts in stages for o in opts)
    bottleneck = max(min(o.t_cmp for o in opts) for opts in stages)
    assert any(t >= bottleneck for t in grid)
