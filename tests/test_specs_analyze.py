"""launch/ specs + roofline analysis units (no 512-device init here —
dryrun.py is exercised via subprocess in test_parallel.py)."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.launch import analyze
from repro.launch.specs import (batch_specs, cache_specs, decode_specs,
                                input_specs, params_specs)


def test_input_specs_train_shapes():
    out = input_specs("smollm-135m", "train_4k")
    b = out["batch"]
    assert b["tokens"].shape == (256, 4096)
    assert b["labels"].shape == (256, 4096)


def test_input_specs_decode_shapes():
    out = input_specs("qwen2.5-32b", "decode_32k")
    assert out["tokens"].shape == (128, 1)
    cache = out["cache"]
    k = cache["segments"][0]["k"]
    assert k.shape == (64, 128, 32768, 8, 128)   # (L, B, C, kvh, hd)


def test_swa_cache_is_ring_capped():
    out = input_specs("h2o-danube-1.8b", "long_500k")
    k = out["cache"]["segments"][0]["k"]
    assert k.shape[2] == 4096        # window, not 524288


def test_mla_cache_is_latent():
    out = input_specs("deepseek-v3-671b", "decode_32k")
    lat = out["cache"]["segments"][1]["latent"]
    # latent width = kv_rank + rope_dim = 576, per token
    assert lat.shape[-1] == 576


def test_rwkv_state_o1():
    out = input_specs("rwkv6-3b", "long_500k")
    wkv = out["cache"]["layers"][0]["wkv"]
    assert wkv.shape == (1, 40, 64, 64)   # O(1) in sequence length


def test_vlm_and_whisper_stub_embeds():
    v = input_specs("qwen2-vl-2b", "train_4k")["batch"]
    assert "embeds" in v and v["embeds"].shape[-1] == 1536
    assert v["embeds"].shape[1] + v["tokens"].shape[1] == 4096
    w = input_specs("whisper-base", "train_4k")["batch"]
    assert w["embeds"].shape == (256, 4096, 512)
    assert w["tokens"].shape == (256, 1024)


SAMPLE_HLO = """
 %all-reduce.1 = f32[8,512]{1,0} all-reduce(%dot), channel_id=1
 %ag = bf16[16,1024]{1,0} all-gather(%p0), dimensions={0}
 %rs.5 = (f32[4,4]{1,0}, f32[2,2]{1,0}) reduce-scatter(%x, %y), dims={0}
 %cp-start = bf16[128]{0} collective-permute-start(%z)
 %cp-done = bf16[128]{0} collective-permute-done(%cp-start)
 %notacoll = f32[9,9]{1,0} dot(%a, %b)
"""


def test_collective_bytes_parser():
    out = analyze.collective_bytes(SAMPLE_HLO)
    assert out["all-reduce"] == 8 * 512 * 4
    assert out["all-gather"] == 16 * 1024 * 2
    assert out["reduce-scatter"] == 4 * 4 * 4 + 2 * 2 * 4
    assert out["collective-permute"] == 128 * 2     # start only, not done
    assert out["total"] == (out["all-reduce"] + out["all-gather"]
                            + out["reduce-scatter"]
                            + out["collective-permute"])


def test_model_flops_accounting():
    cfg = configs.get_config("mixtral-8x7b")
    ps = params_specs(cfg)
    shape = configs.SHAPES["train_4k"]
    mf = analyze.model_flops_for(cfg, shape, ps)
    # active params ~ 13B of 47B; 6*N*D with D = 2^20 tokens
    n_active = mf / (6 * shape.global_batch * shape.seq_len)
    assert 11e9 < n_active < 16e9
    mf_dec = analyze.model_flops_for(cfg, configs.SHAPES["decode_32k"], ps)
    assert mf_dec == pytest.approx(2 * n_active * 128, rel=1e-6)
