"""Analyzer self-tests: every MZC code fires exactly once on its
known-bad fixture, suppression comments work at code / family / bare
granularity, the shipped tree is self-clean, and the tracecheck runtime
counter sees fresh compiles but not cache hits."""

from pathlib import Path

import pytest

from tools.mozart_check import ALL_CHECKERS, run_checkers

REPO = Path(__file__).resolve().parents[1]

# Minimal pallas_call boilerplate shared by the MZC02x kernel fixtures;
# the checker parses ASTs, so nothing here is ever imported or executed.
_KERNEL_HEADER = (
    "import jax.numpy as jnp\n"
    "from jax.experimental import pallas as pl\n"
    "from jax.experimental.pallas import tpu as pltpu\n\n\n"
)

MZC011_SRC = """import jax


@jax.jit
def f(x):
    if x > 0:
        return x
    return -x
"""

# fixture name -> {relative path: source}; each must yield EXACTLY one
# finding, of the code the fixture is named after
CASES = {
    "MZC011": {"fix.py": MZC011_SRC},
    "MZC012": {
        "fix.py": "import jax\n\n\n@jax.jit\ndef f(x):\n    return int(x)\n",
    },
    "MZC013": {
        "fix.py": "import jax\n\n\ndef make(fn):\n    return jax.jit(fn)\n",
    },
    "MZC021": {
        "kernels/foo/kernel.py": _KERNEL_HEADER
        + "def run(x):\n"
        + "    return pl.pallas_call(\n"
        + "        kern,\n"
        + "        grid=(4, 4),\n"
        + "        in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],\n"
        + "        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, 0)),\n"
        + "    )(x)\n",
        "kernels/foo/ops.py": "",
        "kernels/foo/ref.py": "",
    },
    "MZC022": {
        "kernels/foo/kernel.py": _KERNEL_HEADER
        + "def run(x):\n"
        + "    return pl.pallas_call(\n"
        + "        kern,\n"
        + "        grid=(4, 4),\n"
        + "        in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, 0, 0))],\n"
        + "        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, 0)),\n"
        + "    )(x)\n",
        "kernels/foo/ops.py": "",
        "kernels/foo/ref.py": "",
    },
    "MZC023": {
        "kernels/foo/kernel.py": _KERNEL_HEADER
        + "def run(x):\n"
        + "    return pl.pallas_call(\n"
        + "        kern,\n"
        + "        grid=(4,),\n"
        + "        scratch_shapes=[pltpu.VMEM((8, 8), jnp.bfloat16)],\n"
        + "    )(x)\n",
        "kernels/foo/ops.py": "",
        "kernels/foo/ref.py": "",
    },
    "MZC024": {
        "kernels/bar/kernel.py": "def run(x):\n    return x\n",
        "kernels/bar/ops.py": "def foo(x):\n    return x\n",
        "kernels/bar/ref.py": "",
    },
    "MZC031": {
        "fix.py": "import dataclasses\n\n\n"
        "@dataclasses.dataclass\n"
        "class A:\n"
        "    x: int = 0\n\n"
        "    def to_dict(self):\n"
        '        return {"x": self.x}\n',
    },
    "MZC032": {
        "fix.py": "import dataclasses\n\n\n"
        "@dataclasses.dataclass\n"
        "class B:\n"
        "    x: int = 0\n"
        "    y: int = 0\n\n"
        "    def to_dict(self):\n"
        '        return {"x": self.x, "y": self.y}\n\n'
        "    @staticmethod\n"
        "    def from_dict(d):\n"
        '        return B(x=d["x"])\n',
    },
    "MZC041": {
        "fix.py": "def f(x, acc=[]):\n    acc.append(x)\n    return acc\n",
    },
    "MZC042": {
        "fix.py": "CACHE = {}\n",
    },
    "MZC051": {
        "fix.py": 'import os\n\nFLAG = os.environ.get("MOZART_FIXTURE", "0")\n',
    },
    "MZC052": {
        "launch/knobs.py": "KNOBS = (\n"
        '    Knob(name="MOZART_X", type="bool", default="1", doc="a knob"),\n'
        ")\n",
        "README.md": "# fixture readme with no knob table\n",
    },
    "MZC053": {
        "launch/knobs.py": "KNOBS = (\n"
        '    Knob(name="MOZART_Y", type="int", default="0"),\n'
        ")\n",
    },
}


def _materialize(tmp_path, files):
    for rel, content in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(content)


def _run(tmp_path):
    return run_checkers([str(tmp_path)], ALL_CHECKERS, root=str(tmp_path))


@pytest.mark.parametrize("code", sorted(CASES))
def test_fixture_fires_exactly_once(tmp_path, code):
    _materialize(tmp_path, CASES[code])
    findings = _run(tmp_path)
    assert [f.code for f in findings] == [code], [f.render() for f in findings]


@pytest.mark.parametrize(
    "marker",
    ["# mzc: ignore[MZC011]", "# mzc: ignore[MZC01]", "# mzc: ignore"],
)
def test_suppression_comment_silences_the_line(tmp_path, marker):
    _materialize(
        tmp_path, {"fix.py": MZC011_SRC.replace("if x > 0:", f"if x > 0:  {marker}")}
    )
    assert _run(tmp_path) == []


def test_suppression_for_other_code_does_not_apply(tmp_path):
    src = MZC011_SRC.replace("if x > 0:", "if x > 0:  # mzc: ignore[MZC02]")
    _materialize(tmp_path, {"fix.py": src})
    assert [f.code for f in _run(tmp_path)] == ["MZC011"]


def test_syntax_error_is_reported_not_raised(tmp_path):
    _materialize(tmp_path, {"fix.py": "def broken(:\n"})
    assert [f.code for f in _run(tmp_path)] == ["MZC000"]


def test_tree_is_self_clean():
    # mirror the CI job's path set: src benchmarks examples tools tests
    paths = [str(REPO / d) for d in ("src", "benchmarks", "examples", "tools", "tests")]
    findings = run_checkers(paths, ALL_CHECKERS, root=str(REPO))
    assert findings == [], [f.render() for f in findings]


def test_compile_monitor_counts_fresh_compiles_only():
    import jax
    import jax.numpy as jnp

    from tools.mozart_check.tracecheck import CompileMonitor

    # local jit IS the fixture here: the monitor must see its compile
    f = jax.jit(lambda x: x * 2 + 1)  # mzc: ignore[MZC013]
    with CompileMonitor() as cold:
        f(jnp.ones((3,)))
    with CompileMonitor() as warm:
        f(jnp.ones((3,)))
    assert cold.count >= 1
    assert warm.count == 0, warm.events
