"""Multi-device tests run in a subprocess (device count is locked at
first jax init, so the 8-device cases can't share this process)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

pytestmark = pytest.mark.slow   # multi-minute JAX compile/run; excluded from tier-1


def _run(script: str, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", script)],
        capture_output=True, text=True, timeout=timeout, env=env,
        cwd=ROOT)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\n" \
                                f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_multidevice_parallelism():
    stdout = _run("parallel_prog.py")
    assert "ALL_PARALLEL_OK" in stdout
    for marker in ("tp_dp_forward ok", "sharded_decode ok",
                   "serving_tp ok", "pipeline_parallel ok",
                   "optimizer_shardings ok", "elastic_reshard ok"):
        assert marker in stdout
