"""End-to-end Mozart DSE: GA fusion, SA pool, full codesign, policy."""
import json

import pytest

from repro.core import operators
from repro.core.chiplets import Chiplet, default_pool
from repro.core.codesign import (best_homogeneous_design,
                                 design_for_network, run_codesign,
                                 unconstrained_design)
from repro.core.fusion import (GAConfig, Requirement, forced_boundaries,
                               groups_from_genome, optimize_fusion,
                               _roofline_seed)
from repro.core.policy import policy_from_design
from repro.core.pool import SAConfig, anneal_pool, evaluate_pool

GA_SMALL = GAConfig(population=5, generations=2)


@pytest.fixture(scope="module")
def graphs():
    ws = operators.paper_workloads(seq=512)
    return {"resnet50": ws["resnet50"],
            "opt66b_decode": ws["opt66b_decode"]}


def test_forced_boundaries_respected(graphs):
    g = graphs["opt66b_decode"]
    seed = _roofline_seed(g, default_pool(), fuse=True)
    groups = groups_from_genome(g, seed)
    # ops with different repeat counts can never share a group
    for gr in groups:
        assert len({gr.repeat}) == 1
    flat = [o.name for gr in groups for o in gr.ops]
    assert flat == [o.name for o in g.operators]


def test_ga_feasible_and_latency_constraint(graphs):
    g = graphs["resnet50"]
    res = optimize_fusion(g, default_pool(), objective="edp",
                          cfg=GA_SMALL)
    assert res is not None and res.value > 0
    # latency-constrained: 33ms AV deadline
    res_c = optimize_fusion(g, default_pool(), objective="edp",
                            req=Requirement(e2e=0.033),
                            cfg=GAConfig(population=5, generations=2,
                                         fixed_batch=1))
    assert res_c is not None
    assert res_c.solution.delay_e2e <= 0.033 + 1e-9


def test_pool_dominates_single_sku(graphs):
    """The 8-SKU pool's optimum can't be (much) worse than the best
    single SKU (GA noise tolerance 5%)."""
    g = graphs["opt66b_decode"]
    homog = best_homogeneous_design(g, objective="edp",
                                    ga=GAConfig(population=4,
                                                generations=1))
    pool = optimize_fusion(g, default_pool(), objective="edp",
                           cfg=GAConfig(population=8, generations=4))
    assert pool.value <= homog.fusion.value * 1.05


def test_anneal_pool_runs_and_improves(graphs):
    sa = SAConfig(iterations=3, inner_ga=GAConfig(population=4,
                                                  generations=1))
    res = anneal_pool(graphs, objective="energy", pool_size=4, cfg=sa)
    assert len(res.pool) == 4
    assert len(set(res.pool)) == len(res.pool)     # distinct SKUs
    assert res.per_network and res.score > 0


def test_run_codesign_end_to_end(graphs):
    out = run_codesign(graphs, objective="energy", pool_size=4,
                       sa=SAConfig(iterations=2,
                                   inner_ga=GAConfig(population=4,
                                                     generations=1)),
                       final_ga=GA_SMALL)
    assert set(out.designs) == set(graphs)
    reuse = out.chiplet_reuse()
    assert reuse and max(reuse.values()) >= 1
    for d in out.designs.values():
        assert d.pnr.placements
        assert d.fusion.value > 0


def test_policy_extraction(graphs):
    d = design_for_network(graphs["opt66b_decode"], default_pool(),
                           objective="energy", ga=GA_SMALL)
    pol = policy_from_design(d)
    blob = json.loads(pol.to_json())
    assert blob["network"] == d.network
    assert blob["operators"]
    assert set(blob["fusion"]) == {"flash_attention", "fused_mlp",
                                   "fused_norm"}
    # Insight 2 in the policy: attention batch <= projection batch
    assert pol.batch_agnostic_batch <= pol.batch_sensitive_batch


def test_unconstrained_at_least_as_good(graphs):
    g = graphs["resnet50"]
    pool8 = optimize_fusion(g, default_pool(), objective="energy",
                            cfg=GA_SMALL)
    unc = unconstrained_design(g, objective="energy",
                               ga=GAConfig(population=8, generations=3))
    assert unc.fusion.value <= pool8.value * 1.10   # search-noise slack
