"""Checkpoint manager: atomic publish, keep-K, roundtrip, corruption
resistance, elastic template restore."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.int32),
                  "d": [jnp.zeros(()), jnp.full((5,), 7.0)]}}


def test_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path))
    t = tree()
    m.save(3, t, meta={"next_step": 3})
    out, meta = m.restore(jax.tree.map(jnp.zeros_like, t))
    assert meta["next_step"] == 3
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_gc(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        m.save(s, {"x": jnp.full((2,), float(s))})
    assert m.steps() == [3, 4]
    out, _ = m.restore({"x": jnp.zeros((2,))})
    assert float(out["x"][0]) == 4.0


def test_stale_tmp_ignored_and_atomicity(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(5, {"x": jnp.ones((2,))})
    # a crashed half-written checkpoint must be invisible
    os.makedirs(tmp_path / "step_000000009.tmp")
    assert m.latest_step() == 5
    # idempotent re-save of the same step
    m.save(5, {"x": jnp.ones((2,))})
    assert m.steps() == [5]


def test_shape_mismatch_rejected(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(1, {"x": jnp.ones((2,))})
    with pytest.raises(ValueError, match="shape mismatch"):
        m.restore({"x": jnp.ones((3,))})


def test_missing_leaf_rejected(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(1, {"x": jnp.ones((2,))})
    with pytest.raises(KeyError):
        m.restore({"x": jnp.ones((2,)), "y": jnp.ones((2,))})
