"""Per-assigned-architecture smoke tests (assignment deliverable f):
reduced same-family config, one forward + one TRAIN step on CPU,
asserting output shapes and no NaNs.  Full configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api
from repro.training.optimizer import OptimizerConfig, apply_opt, init_opt

pytestmark = pytest.mark.slow   # multi-minute JAX compile/run; excluded from tier-1


def _batch_for(cfg, B=2, S=32, key=jax.random.PRNGKey(1)):
    ks = jax.random.split(key, 2)
    toks = jax.random.randint(ks[0], (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "whisper":
        batch["embeds"] = jax.random.normal(
            ks[1], (B, S * 2, cfg.d_model)) * 0.1
    elif cfg.frontend == "vision":
        batch["embeds"] = jax.random.normal(
            ks[1], (B, 8, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = configs.get_smoke_config(arch)
    cfg.validate()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)

    logits = api.forward(cfg, params, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch

    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = init_opt(ocfg, params)

    @jax.jit
    def step(p, o, b):
        loss, g = jax.value_and_grad(
            lambda pp: api.loss_fn(cfg, pp, b))(p)
        p2, o2, gn = apply_opt(ocfg, g, o, p)
        return p2, o2, loss

    p2, o2, loss = step(params, opt, batch)
    assert np.isfinite(float(loss)), arch
    # the step must actually move the parameters
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), params, p2)
    assert max(jax.tree_util.tree_leaves(moved)) > 0, arch


@pytest.mark.parametrize("arch", [a for a in configs.ARCH_IDS
                                  if configs.get_config(a).family
                                  != "whisper"])
def test_arch_smoke_decode(arch):
    cfg = configs.get_smoke_config(arch)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    if cfg.frontend == "vision":
        emb = jax.random.normal(jax.random.PRNGKey(2),
                                (B, 4, cfg.d_model)) * 0.1
        last, cache = api.prefill(cfg, params,
                                  {"tokens": toks, "embeds": emb}, S + 12)
    else:
        last, cache = api.prefill(cfg, params, {"tokens": toks}, S + 8)
    lg, cache = api.decode_step(
        cfg, params, jnp.argmax(last, -1).astype(jnp.int32), cache)
    assert lg.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(lg, np.float32)).all(), arch


def test_registry_complete():
    assert len(configs.ARCH_IDS) == 10
    runnable = configs.cells()
    skipped = [c for c in configs.cells(include_skipped=True)
               if c not in runnable]
    # 6 archs skip long_500k (full attention), 4 run it
    assert len(skipped) == 6
    assert len(runnable) == 34
    for arch in configs.ARCH_IDS:
        c = configs.get_config(arch)
        assert c.name == arch
