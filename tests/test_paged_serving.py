"""Tier-1 tests for the block-paged KV cache + bucketed prefill.

Covers the ISSUE-7 acceptance surface: page lifecycle (alloc/free under
churn, preemption, the reserved null page), bucket-boundary prefill
parity (prompt lengths at bucket, bucket-1, bucket+1), paged-vs-dense
decode bit-parity on fixed seeds, the CompileMonitor-verified prefill
executable budget over a mixed prompt-length run, the cache-boundary
admission/decode bugfixes, and the paged-attention kernel triplet.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import api
from repro.models.config import ModelConfig
from repro.serving import paged as paged_mod
from repro.serving.engine import Request, ServingEngine
from repro.serving.paged import PagePool, bucket_for, prefill_buckets

TINY = ModelConfig(
    name="tiny-paged",
    n_layers=2,
    d_model=32,
    n_heads=4,
    kv_heads=2,
    head_dim=8,
    d_ff=64,
    vocab=61,
    dtype="float32",
    param_dtype="float32",
    scan_layers=False,
)


@pytest.fixture(scope="module")
def tiny_params():
    return api.init_params(TINY, jax.random.PRNGKey(0))


def _prompt(rng, n):
    return rng.integers(1, TINY.vocab - 1, size=n).astype(np.int32)


def _run_engine(params, prompts, *, max_new=8, **kw):
    eng = ServingEngine(TINY, params, **kw)
    reqs = [
        Request(rid=i, prompt=p, max_new_tokens=max_new) for i, p in enumerate(prompts)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return eng, reqs


# -- bucket math --------------------------------------------------------------


def test_prefill_buckets_cover_admissible_lengths():
    buckets = prefill_buckets(512, 16)
    assert buckets == (16, 32, 64, 128, 256, 512)
    assert prefill_buckets(64, 16) == (16, 32, 64)
    # a non-power-of-two max_len is covered by the next bucket up
    assert prefill_buckets(100, 16)[-1] >= 99
    for plen in (1, 16, 17, 99):
        assert bucket_for(plen, prefill_buckets(100, 16)) >= plen
    with pytest.raises(ValueError):
        bucket_for(1000, prefill_buckets(64, 16))


# -- page pool lifecycle ------------------------------------------------------


def test_page_pool_alloc_free_churn():
    pool = PagePool(TINY, max_batch=4, max_len=64, page_size=16)
    total = pool.num_pages - 1  # page 0 is the reserved null page
    assert pool.free_pages == total
    assert pool.ensure(0, 20)  # 2 pages
    assert pool.ensure(1, 16)  # 1 page
    assert pool.owned(0) != pool.owned(1)
    assert 0 not in pool.owned(0) and 0 not in pool.owned(1)
    assert pool.free_pages == total - 3
    # growth is incremental and idempotent
    assert pool.ensure(0, 21)
    assert pool.ensure(0, 33)
    assert len(pool.owned(0)) == 3
    # table rows mirror ownership, null-padded to the requested width
    row = pool.table_row(0, 4)
    assert tuple(row[:3]) == pool.owned(0) and row[3] == 0
    pool.release(0)
    assert pool.free_pages == total - 1
    assert pool.owned(0) == () and not pool.tables[0].any()
    # churn: repeated alloc/release cycles conserve the pool exactly
    for i in range(25):
        b = i % 4
        assert pool.ensure(b, 1 + (i * 7) % 60)
        pool.release(b)
    pool.release(1)
    assert pool.free_pages == total
    assert pool.stats["page_allocs"] == pool.stats["page_frees"]
    assert pool.stats["peak_pages_in_use"] <= total


def test_page_pool_exhaustion_is_atomic():
    pool = PagePool(TINY, max_batch=2, max_len=64, page_size=16, num_pages=4)
    assert pool.ensure(0, 32)  # 2 of 3 usable pages
    free_before = pool.free_pages
    assert not pool.ensure(1, 32)  # needs 2, only 1 left: no partial alloc
    assert pool.free_pages == free_before and pool.owned(1) == ()
    assert pool.ensure(1, 16)
    with pytest.raises(ValueError):
        PagePool(TINY, max_batch=1, max_len=64, page_size=24)


def test_eviction_under_churn_frees_every_page(tiny_params):
    """A pool far too small for the offered load forces preemptions; all
    requests still finish and every page returns to the free list."""
    rng = np.random.default_rng(3)
    prompts = [_prompt(rng, n) for n in (20, 30, 25, 18, 22, 27)]
    eng, reqs = _run_engine(
        tiny_params,
        prompts,
        max_new=16,
        max_batch=4,
        max_len=64,
        paged=True,
        page_size=16,
        num_pages=9,
    )
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 16 for r in reqs)
    assert eng.stats["preemptions"] > 0
    assert eng.pool.free_pages == eng.pool.num_pages - 1
    assert eng.pool.stats["page_allocs"] == eng.pool.stats["page_frees"]


def test_lone_request_exhausting_pool_finishes_with_capacity(tiny_params):
    eng = ServingEngine(
        TINY, tiny_params, max_batch=1, max_len=512, paged=True, page_size=16, num_pages=3
    )
    req = Request(rid=0, prompt=np.arange(1, 11, dtype=np.int32), max_new_tokens=400)
    eng.submit(req)
    eng.run()
    assert req.done and req.finish_reason == "capacity"
    # 2 usable pages = 32 positions; prompt used 10
    assert len(req.out_tokens) == 32 - 10 + 1


# -- parity against the dense cache -------------------------------------------


@pytest.mark.parametrize("delta", [-1, 0, 1])
def test_bucket_boundary_prefill_parity(tiny_params, delta):
    """Prompt lengths straddling a bucket edge (bucket-1, bucket, and
    bucket+1, which spills into the next bucket) emit exactly the dense
    engine's tokens."""
    bucket = 16
    rng = np.random.default_rng(40 + delta)
    prompts = [_prompt(rng, bucket + delta)]
    kw = dict(max_new=8, max_batch=2, max_len=64)
    _, dense = _run_engine(tiny_params, prompts, paged=False, **kw)
    _, paged = _run_engine(tiny_params, prompts, paged=True, **kw)
    assert [r.out_tokens for r in paged] == [r.out_tokens for r in dense]


@pytest.mark.parametrize("compact", [True, False])
def test_paged_matches_dense_on_fixed_seed_mix(tiny_params, compact):
    """Fixed-seed bit-parity over a mixed-length workload with admission
    churn, in both the compacted and full-width-emulation schedules."""
    rng = np.random.default_rng(7)
    prompts = [_prompt(rng, n) for n in (5, 17, 33, 9, 21, 40, 2, 13)]
    kw = dict(max_new=10, max_batch=4, max_len=64, decode_batch=2, compact=compact)
    _, dense = _run_engine(tiny_params, prompts, paged=False, **kw)
    _, paged = _run_engine(tiny_params, prompts, paged=True, **kw)
    assert [r.out_tokens for r in paged] == [r.out_tokens for r in dense]
    assert [r.finish_reason for r in paged] == [r.finish_reason for r in dense]


def test_paged_decode_gather_is_bit_identical(tiny_params):
    """The decode path (gather -> decode_step -> scatter) is BIT-exact
    against the dense cache, not just token-exact: copy one dense cache
    into pool pages by hand and compare the decode logits bitwise."""
    from repro.serving.engine import _decode_fn
    from repro.serving.paged import paged_decode_fn

    rng = np.random.default_rng(11)
    max_len, bsz = 64, 2
    toks = jnp.asarray(np.stack([_prompt(rng, 33), _prompt(rng, 33)]))
    _, cache = api.prefill(TINY, tiny_params, {"tokens": toks}, max_len)
    index = np.asarray([33, 33], np.int32)
    cache = {"segments": cache["segments"], "index": jnp.asarray(index)}
    pool = PagePool(TINY, max_batch=bsz, max_len=max_len, page_size=16)
    ps = pool.page_size
    for b in range(bsz):
        assert pool.ensure(b, 34)
        pool.index[b] = 33
    new_segs = []
    for seg_d, seg_p in zip(cache["segments"], pool.segments):

        def place(pages, dense):
            out = np.asarray(pages).copy()
            for b in range(bsz):
                for j, pg in enumerate(pool.owned(b)):
                    out[:, pg] = dense[:, b, j * ps : (j + 1) * ps]
            return jnp.asarray(out)

        new_segs.append(jax.tree.map(place, seg_p, seg_d))
    pool.segments = new_segs
    tok = jnp.asarray([[7], [9]], jnp.int32)
    sel = np.asarray([0, 1])
    logits_d, _ = _decode_fn(TINY)(tiny_params, tok, cache)
    logits_p, _ = paged_decode_fn(TINY)(
        tiny_params, tok, pool.segments, pool.tables[sel], pool.index[sel]
    )
    np.testing.assert_array_equal(np.asarray(logits_d), np.asarray(logits_p))


# -- compile budget -----------------------------------------------------------


def test_prefill_executable_budget_over_mixed_lengths(tiny_params):
    """CompileMonitor-verified: once each bucket has been seen once, a
    mixed run over MANY distinct prompt lengths compiles NOTHING — i.e.
    the whole admissible length space needs at most len(buckets) prefill
    executables (plus one decode executable)."""
    from tools.mozart_check.tracecheck import CompileMonitor

    rng = np.random.default_rng(13)
    eng = ServingEngine(
        TINY, tiny_params, max_batch=4, max_len=64, decode_batch=2, paged=True
    )
    assert eng.buckets == (16, 32, 64)
    # warm exactly one prompt per bucket
    for i, n in enumerate((5, 20, 40)):
        eng.submit(Request(rid=i, prompt=_prompt(rng, n), max_new_tokens=4))
    eng.run()
    with CompileMonitor() as mon:
        for i, n in enumerate((3, 7, 11, 19, 23, 37, 50, 61, 13, 29)):
            eng.submit(Request(rid=100 + i, prompt=_prompt(rng, n), max_new_tokens=4))
        eng.run()
    assert mon.count == 0, mon.events


# -- cache-boundary bugfix regressions ----------------------------------------


@pytest.mark.parametrize("paged", [True, False])
def test_admit_rejects_prompts_at_or_past_capacity(tiny_params, paged):
    """Regression (ISSUE 7): prompts with len(prompt) >= max_len used to
    prefill anyway and decode past the end of the slot."""
    rng = np.random.default_rng(17)
    too_long = Request(rid=0, prompt=_prompt(rng, 32), max_new_tokens=4)
    way_too_long = Request(rid=1, prompt=_prompt(rng, 50), max_new_tokens=4)
    fits = Request(rid=2, prompt=_prompt(rng, 8), max_new_tokens=4)
    eng = ServingEngine(TINY, tiny_params, max_batch=2, max_len=32, paged=paged)
    for r in (too_long, way_too_long, fits):
        eng.submit(r)
    eng.run()
    assert too_long.done and too_long.finish_reason == "rejected"
    assert way_too_long.finish_reason == "rejected"
    assert too_long.out_tokens == [] and way_too_long.out_tokens == []
    assert fits.finish_reason == "max_new_tokens" and len(fits.out_tokens) == 4
    assert eng.stats["rejected"] == 2


@pytest.mark.parametrize("paged", [True, False])
def test_decode_finishes_at_cache_boundary(tiny_params, paged):
    """Regression (ISSUE 7): a generous max_new_tokens used to decode
    past max_len, silently overwriting the slot's last cache position."""
    rng = np.random.default_rng(19)
    req = Request(rid=0, prompt=_prompt(rng, 28), max_new_tokens=100)
    eng = ServingEngine(TINY, tiny_params, max_batch=2, max_len=32, paged=paged)
    eng.submit(req)
    eng.run()
    assert req.done and req.finish_reason == "length"
    # positions 28..31 hold decoded KV; the +1 token's KV was never written
    assert len(req.out_tokens) == 32 - 28 + 1


def test_timing_marks_are_monotone(tiny_params):
    rng = np.random.default_rng(23)
    _, reqs = _run_engine(
        tiny_params, [_prompt(rng, 9)], max_new=4, max_batch=2, max_len=32, paged=True
    )
    (req,) = reqs
    assert req.t_submit is not None and req.t_first is not None
    assert req.t_submit <= req.t_first <= req.t_done


# -- paged-attention kernel triplet -------------------------------------------


@pytest.mark.parametrize("group", [1, 4])
def test_paged_decode_attention_matches_ref(group):
    from repro.kernels.flash_attention.ops import paged_decode_attention
    from repro.kernels.flash_attention.ref import paged_decode_attention_ref

    rng = np.random.default_rng(29)
    bsz, hkv, hd, pages, ps, npp = 4, 2, 16, 11, 8, 4
    h = hkv * group
    q = jnp.asarray(rng.normal(size=(bsz, 1, h, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(pages, ps, hkv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(pages, ps, hkv, hd)), jnp.float32)
    tables = np.zeros((bsz, npp), np.int32)
    perm = rng.permutation(np.arange(1, pages))
    lens = np.asarray([5, 8, 17, 30], np.int32)
    off = 0
    for b in range(bsz):
        n = -(-int(lens[b]) // ps)
        tables[b, :n] = perm[off : off + n]
        off += n
    want = paged_decode_attention_ref(q, kp, vp, jnp.asarray(tables), jnp.asarray(lens))
    got = paged_decode_attention(q, kp, vp, jnp.asarray(tables), jnp.asarray(lens))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_paged_decode_attention_ignores_null_and_stale_pages():
    """Garbage in the null page and in positions past `lengths` must not
    leak into the output: poisoning them leaves the result unchanged."""
    from repro.kernels.flash_attention.ops import paged_decode_attention

    rng = np.random.default_rng(31)
    bsz, h, hd, pages, ps, npp = 2, 2, 8, 6, 4, 3
    q = jnp.asarray(rng.normal(size=(bsz, 1, h, hd)), jnp.float32)
    kp = np.asarray(rng.normal(size=(pages, ps, h, hd)), np.float32)
    vp = np.asarray(rng.normal(size=(pages, ps, h, hd)), np.float32)
    tables = jnp.asarray([[1, 2, 0], [3, 0, 0]], jnp.int32)
    lens = jnp.asarray([6, 3], jnp.int32)
    base = paged_decode_attention(q, jnp.asarray(kp), jnp.asarray(vp), tables, lens)
    kp2, vp2 = kp.copy(), vp.copy()
    kp2[0], vp2[0] = 1e6, 1e6  # null page
    kp2[2, 2:], vp2[2, 2:] = -1e6, -1e6  # positions 6,7 of slot 0 (past length)
    kp2[3, 3:], vp2[3, 3:] = 1e6, -1e6  # position 3 of slot 1 (past length)
    got = paged_decode_attention(q, jnp.asarray(kp2), jnp.asarray(vp2), tables, lens)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(got))


def test_models_api_paged_cache_is_transformer_only():
    pool = api.init_paged_cache(TINY, num_pages=4, page_size=8)
    for seg in pool:
        assert seg["k"].shape == (TINY.n_layers, 4, 8, TINY.kv_heads, TINY.hd)
    rnn = ModelConfig(
        name="tiny-rglru",
        family="rglru",
        n_layers=2,
        d_model=32,
        n_heads=2,
        kv_heads=2,
        head_dim=16,
        d_ff=64,
        vocab=61,
        attn_every=2,
        lru_width=32,
    )
    with pytest.raises(NotImplementedError):
        api.init_paged_cache(rnn, num_pages=4, page_size=8)
    eng = ServingEngine(rnn, params={}, max_batch=2, max_len=16, paged=True)
    assert eng.paged is False  # silent fallback to the dense cache


def test_full_width_rewind_is_vectorized(tiny_params, monkeypatch):
    """Regression (ISSUE 7): the full-width emulation used one
    `.at[b].add(-1)` dispatch PER inactive slot; it must issue exactly
    one batched rewind covering all inactive slots per decode step."""
    from repro.serving import engine as eng_mod

    rng = np.random.default_rng(37)
    eng = ServingEngine(
        TINY, tiny_params, max_batch=4, max_len=32, decode_batch=1, compact=False, paged=False
    )
    for i in range(4):
        eng.submit(Request(rid=i, prompt=_prompt(rng, 4), max_new_tokens=3))
    calls = []
    orig = eng_mod._rewind_inactive

    def spy(index, inactive):
        calls.append(list(inactive))
        return orig(index, inactive)

    monkeypatch.setattr(eng_mod, "_rewind_inactive", spy)
    steps = 0
    while any(s is not None for s in eng.slots) or eng.queue:
        before = len(calls)
        eng.step()
        steps += 1
        assert len(calls) - before <= 1  # one batched rewind per step, max
        if steps > 50:
            raise AssertionError("engine did not drain")
    # with decode_batch=1 the first full step rewinds THREE slots at once
    assert any(len(c) == 3 for c in calls)
    # and every request still decoded correctly
    assert all(s is None for s in eng.slots)
