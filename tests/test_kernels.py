"""Pallas kernel validation (interpret mode on CPU): shape/dtype sweeps +
hypothesis-driven shapes, all against the pure-jnp ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

pytestmark = pytest.mark.slow   # multi-minute JAX compile/run; excluded from tier-1

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.moe_mlp.ops import moe_mlp
from repro.kernels.moe_mlp.ref import moe_mlp_ref
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.wkv6.ops import wkv6
from repro.kernels.wkv6.ref import wkv6_ref


def _flash_case(B, Sq, Sk, H, Hkv, hd, causal, window, dt, bq=32, bk=32):
    ks = jax.random.split(jax.random.PRNGKey(Sq * 7 + Sk), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dt)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, hd), dt)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, hd), dt)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          bq=bq, bk=bk)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, hd)
    ref = flash_attention_ref(qf, kf, vf, causal=causal, window=window) \
        .reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
    tol = 2.5e-2 if dt == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("case", [
    (2, 64, 64, 4, 2, 32, True, None, jnp.float32),
    (1, 100, 100, 4, 1, 64, True, None, jnp.float32),     # ragged + MQA
    (2, 128, 256, 8, 8, 32, True, 40, jnp.float32),       # SWA window
    (1, 1, 96, 4, 2, 32, False, None, jnp.float32),       # decode shape
    (2, 64, 64, 4, 4, 32, True, None, jnp.bfloat16),      # bf16
    (1, 32, 32, 2, 2, 128, True, None, jnp.float32),      # big head dim
])
def test_flash_attention_cases(case):
    _flash_case(*case)


@settings(max_examples=8, deadline=None)
@given(sq=st.integers(1, 80), sk=st.integers(8, 120),
       group=st.sampled_from([1, 2, 4]),
       causal=st.booleans())
def test_flash_attention_hypothesis(sq, sk, group, causal):
    if causal and sq > sk:
        sq = sk
    _flash_case(1, sq, sk, 2 * group, 2, 16, causal, None, jnp.float32,
                bq=16, bk=16)


@pytest.mark.parametrize("B,S,W,bs,bw", [
    (2, 64, 128, 16, 64), (1, 100, 96, 32, 32), (3, 7, 250, 4, 128)])
def test_rglru_scan_kernel(B, S, W, bs, bw):
    ks = jax.random.split(jax.random.PRNGKey(S), 3)
    a = jax.random.uniform(ks[0], (B, S, W), minval=0.1, maxval=0.99)
    b = jax.random.normal(ks[1], (B, S, W))
    h0 = jax.random.normal(ks[2], (B, W))
    np.testing.assert_allclose(np.asarray(rglru_scan(a, b, h0, bs=bs,
                                                     bw=bw)),
                               np.asarray(rglru_scan_ref(a, b, h0)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("BH,S,D,C", [(3, 64, 32, 16), (2, 50, 64, 16),
                                      (1, 16, 16, 8)])
def test_wkv6_kernel(BH, S, D, C):
    ks = jax.random.split(jax.random.PRNGKey(S), 6)
    r = jax.random.normal(ks[0], (BH, S, D))
    k = jax.random.normal(ks[1], (BH, S, D))
    v = jax.random.normal(ks[2], (BH, S, D))
    logw = -jax.nn.softplus(jax.random.normal(ks[3], (BH, S, D)))
    u = jax.random.normal(ks[4], (BH, 1, D)) * 0.1
    s0 = jax.random.normal(ks[5], (BH, D, D)) * 0.1
    out = wkv6(r, k, v, logw, u, s0, chunk=C)
    ref, _ = wkv6_ref(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("E,C,d,F,sw,dt", [
    (4, 32, 64, 128, True, jnp.float32),
    (1, 100, 32, 200, False, jnp.float32),     # dense-MLP degenerate case
    (2, 16, 128, 96, True, jnp.float32),
    (2, 32, 64, 128, True, jnp.bfloat16),
])
def test_moe_mlp_kernel(E, C, d, F, sw, dt):
    ks = jax.random.split(jax.random.PRNGKey(E * C), 4)
    x = (jax.random.normal(ks[0], (E, C, d)) * 0.5).astype(dt)
    wg = (jax.random.normal(ks[1], (E, d, F)) * 0.1).astype(dt)
    wi = (jax.random.normal(ks[2], (E, d, F)) * 0.1).astype(dt)
    wo = (jax.random.normal(ks[3], (E, F, d)) * 0.1).astype(dt)
    out = moe_mlp(x, wg, wi, wo, swiglu=sw, bt=16, bf=64)
    ref = moe_mlp_ref(x, wg, wi, wo, swiglu=sw)
    tol = 2e-2 if dt == jnp.bfloat16 else 3e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)
